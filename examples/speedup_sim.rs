//! Speedup study (Figure 10): simulate the paper's 32-machine cluster for
//! all three systems, plus a real-thread asynch-SGBDT scaling measurement
//! on this machine.
//!
//! ```bash
//! cargo run --release --example speedup_sim
//! ```

use asgbdt::config::TrainConfig;
use asgbdt::coordinator::train_async;
use asgbdt::data::synthetic;
use asgbdt::simulator::{eq13_upper_bound, speedup_sweep, ClusterSpec, PhaseTimes};

fn main() -> anyhow::Result<()> {
    // ---- simulated cluster (the paper's Era testbed substitute)
    for (name, times) in [
        ("real-sim", PhaseTimes::realsim_like()),
        ("E2006-log1p", PhaseTimes::e2006_like()),
    ] {
        println!("\n=== simulated cluster: {name} ===");
        println!(
            "Eq.13 worker upper bound: {:.1}",
            eq13_upper_bound(&times, &ClusterSpec::new(32))
        );
        println!(
            "{:<14} {:>7} {:>9} {:>9}",
            "system", "workers", "speedup", "tau_mean"
        );
        for row in speedup_sweep(&times, &[1, 2, 4, 8, 16, 32], 200, 0.15, 42) {
            println!(
                "{:<14} {:>7} {:>9.2} {:>9.2}",
                row.system.as_str(),
                row.workers,
                row.speedup,
                row.mean_staleness
            );
        }
    }

    // ---- real threads on this machine (like the paper's validity runs)
    println!("\n=== real threads (asynch-SGBDT, this machine) ===");
    let ds = synthetic::realsim_like(4_000, 11);
    let mut base_tps = 0.0;
    for workers in [1usize, 2, 4, 8] {
        let mut cfg = TrainConfig::default();
        cfg.workers = workers;
        cfg.n_trees = 60;
        cfg.step_length = 0.1;
        cfg.tree.max_leaves = 32;
        cfg.max_bins = 32;
        cfg.eval_every = 60;
        let rep = train_async(&cfg, &ds, None)?;
        let tps = rep.trees_per_sec();
        if workers == 1 {
            base_tps = tps;
        }
        println!(
            "  workers {:>2}: {:>6.2} trees/s  speedup {:>5.2}  staleness mean {:.2}",
            workers,
            tps,
            tps / base_tps,
            rep.staleness.mean()
        );
    }
    Ok(())
}
