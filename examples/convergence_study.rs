//! Convergence study: the paper's validity-experiment story (Figures 5–8)
//! on one screen — worker sweeps and sampling-rate sweeps on both the
//! asynch-friendly (real-sim-like) and asynch-hostile (Higgs-like)
//! datasets, reporting the loss-AUC sensitivity measure.
//!
//! ```bash
//! cargo run --release --example convergence_study -- [rows]
//! ```

use asgbdt::config::TrainConfig;
use asgbdt::coordinator::train;
use asgbdt::data::{synthetic, Dataset};
use asgbdt::util::Rng;

fn study(name: &str, ds: &Dataset, leaves: usize) -> anyhow::Result<()> {
    println!("\n=== {name}: {} rows x {} features, {} species ===",
        ds.n_rows(), ds.n_features(), ds.n_species());
    let mut rng = Rng::new(7);
    let (tr, te) = ds.split(0.2, &mut rng);

    println!("-- worker sweep (rate fixed 0.8) --");
    let mut aucs = Vec::new();
    for workers in [1usize, 2, 4, 8] {
        let mut cfg = TrainConfig::default();
        cfg.workers = workers;
        cfg.n_trees = 80;
        cfg.step_length = 0.1;
        cfg.tree.max_leaves = leaves;
        cfg.max_bins = 32;
        cfg.eval_every = 10;
        let rep = train(&cfg, &tr, Some(&te))?;
        let auc = rep.curve.train_loss_auc();
        aucs.push(auc);
        println!(
            "  workers {:>2}: loss-AUC {:.5}, final {:.5}, staleness mean {:.2}",
            workers,
            auc,
            rep.curve.final_train_loss().unwrap(),
            rep.staleness.mean()
        );
    }
    let sens = aucs.iter().cloned().fold(f64::MIN, f64::max)
        - aucs.iter().cloned().fold(f64::MAX, f64::min);
    println!("  sensitivity to workers (AUC spread): {sens:.5}");

    println!("-- sampling-rate sweep (4 workers) --");
    for rate in [0.2f64, 0.5, 0.8] {
        let mut cfg = TrainConfig::default();
        cfg.workers = 4;
        cfg.n_trees = 80;
        cfg.step_length = 0.1;
        cfg.sampling_rate = rate;
        cfg.tree.max_leaves = leaves;
        cfg.max_bins = 32;
        cfg.eval_every = 10;
        let rep = train(&cfg, &tr, Some(&te))?;
        println!(
            "  rate {rate:.1}: loss-AUC {:.5}, final {:.5}",
            rep.curve.train_loss_auc(),
            rep.curve.final_train_loss().unwrap()
        );
    }
    Ok(())
}

fn main() -> anyhow::Result<()> {
    let rows: usize = std::env::args()
        .nth(1)
        .map(|s| s.parse())
        .transpose()?
        .unwrap_or(3_000);
    // high diversity: insensitive to workers (paper Fig. 6/8)
    study("realsim-like (high diversity)", &synthetic::realsim_like(rows, 99), 32)?;
    // low diversity: sensitive to workers (paper Fig. 5/7)
    study("higgs-like (low diversity)", &synthetic::higgs_like(rows, 99), 20)?;
    println!("\nExpected: the higgs-like AUC spread exceeds the realsim-like one —");
    println!("the paper's asynch-SGBDT requirements in action (§V.B).");
    Ok(())
}
