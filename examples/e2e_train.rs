//! End-to-end validation driver (DESIGN.md §6): exercises every layer of
//! the stack on a realistic workload and records the run for
//! EXPERIMENTS.md.
//!
//! Full path: synthetic real-sim-like corpus → quantile binning → PS
//! server thread owning the **AOT PJRT gradient engine** (HLO artifacts
//! from the JAX/Pallas compile path) → N asynchronous worker threads
//! building histogram trees → loss curve + staleness telemetry →
//! `results/e2e_train.csv` + `results/e2e_train_summary.json`.
//!
//! ```bash
//! make artifacts   # enables the AOT engine (otherwise native fallback)
//! cargo run --release --example e2e_train -- [rows] [trees] [workers]
//! ```

use asgbdt::config::TrainConfig;
use asgbdt::coordinator::train;
use asgbdt::data::synthetic;
use asgbdt::runtime::EngineKind;
use asgbdt::util::Rng;

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let rows: usize = args.first().map(|s| s.parse()).transpose()?.unwrap_or(12_000);
    let trees: usize = args.get(1).map(|s| s.parse()).transpose()?.unwrap_or(400);
    let workers: usize = args.get(2).map(|s| s.parse()).transpose()?.unwrap_or(8);

    println!("== asynch-SGBDT end-to-end driver ==");
    let ds = synthetic::realsim_like(rows, 2026);
    let mut rng = Rng::new(2026);
    let (train_ds, test_ds) = ds.split(0.2, &mut rng);
    println!(
        "corpus: {} train / {} test rows, {} features, density {:.3}%, {} species",
        train_ds.n_rows(),
        test_ds.n_rows(),
        train_ds.n_features(),
        train_ds.x.density() * 100.0,
        train_ds.n_species(),
    );

    let mut cfg = TrainConfig::default(); // paper defaults: v=0.01, rate 0.8
    cfg.n_trees = trees;
    cfg.workers = workers;
    cfg.tree.max_leaves = 100; // paper's real-sim setting
    cfg.max_bins = 32;
    cfg.eval_every = (trees / 40).max(1);

    let report = train(&cfg, &train_ds, Some(&test_ds))?;

    println!(
        "\nengine: {}   ({} = full AOT path: JAX/Pallas → HLO text → PJRT)",
        report.engine,
        EngineKind::Aot
    );
    println!(
        "{} trees in {:.1}s => {:.2} trees/s with {} workers",
        report.trees_accepted,
        report.wall_secs,
        report.trees_per_sec(),
        report.workers
    );
    println!(
        "staleness: mean {:.2}, p-max {}; rejected {}",
        report.staleness.mean(),
        report.staleness.max(),
        report.trees_rejected
    );
    println!("\nloss curve (every {} trees):", cfg.eval_every);
    for p in &report.curve.points {
        println!(
            "  trees {:>4}  train {:.5}  test {:.5}  err {:.4}  t={:.1}s",
            p.n_trees, p.train_loss, p.test_loss, p.test_error, p.wall_secs
        );
    }
    println!("\nserver phase profile:\n{}", report.timer.report());

    let first = report.curve.points.first().unwrap();
    let last = report.curve.points.last().unwrap();
    anyhow::ensure!(
        last.train_loss < first.train_loss - 0.02,
        "loss did not descend ({:.4} -> {:.4})",
        first.train_loss,
        last.train_loss
    );

    std::fs::create_dir_all("results")?;
    report
        .curve
        .write_csv(std::path::Path::new("results/e2e_train.csv"), "e2e")?;
    report.write_summary(std::path::Path::new("results/e2e_train_summary.json"))?;
    println!("\nwrote results/e2e_train.csv + results/e2e_train_summary.json");
    Ok(())
}
