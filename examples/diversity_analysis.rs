//! Diversity analysis (Figure 4): why sample diversity decides whether a
//! dataset meets the asynch-SGBDT requirements.
//!
//! Prints Ω, Δ, ρ and the expected Q′ density across sampling rates for
//! the paper's two illustrative corpora plus the three benchmark
//! datasets' synthetic stand-ins.
//!
//! ```bash
//! cargo run --release --example diversity_analysis
//! ```

use asgbdt::data::stats::diversity_report;
use asgbdt::data::synthetic;

fn main() {
    let datasets = vec![
        ("fig4a: 3 species x {10k,20k,30k}", synthetic::fig4_low_diversity(1)),
        ("fig4b: 14k singletons", synthetic::fig4_high_diversity(1)),
        ("realsim-like (4k)", synthetic::realsim_like(4_000, 2)),
        ("higgs-like (4k)", synthetic::higgs_like(4_000, 2)),
        ("e2006-like (2k)", synthetic::e2006_like(2_000, 2)),
    ];
    let rates = [0.000005f64, 0.001, 0.01, 0.1, 0.5, 0.8];

    for (name, ds) in &datasets {
        println!("\n=== {name} ===");
        println!(
            "rows {}  species {}  diversity ratio {:.4}",
            ds.n_rows(),
            ds.n_species(),
            ds.n_species() as f64 / ds.n_rows() as f64
        );
        println!(
            "{:>10} {:>8} {:>8} {:>10} {:>8}",
            "rate", "delta", "rho", "q'density", "omega"
        );
        for &r in &rates {
            let rep = diversity_report(ds, r);
            println!(
                "{:>10} {:>8.4} {:>8.4} {:>10.5} {:>8}",
                r, rep.delta, rep.rho, rep.qprime_density, rep.omega
            );
        }
    }
    println!("\nReading: low-diversity sets keep Q' dense (delta→1) even at tiny");
    println!("rates — high ρ/Δ — so they are sensitive to asynchrony (paper §V.B).");
}
