//! Quickstart: train asynch-SGBDT on a small synthetic high-dimensional
//! sparse dataset with 4 asynchronous workers, then evaluate.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use asgbdt::config::TrainConfig;
use asgbdt::coordinator::train;
use asgbdt::data::synthetic;
use asgbdt::loss::metrics;
use asgbdt::util::Rng;

fn main() -> anyhow::Result<()> {
    // 1. data: a real-sim-like sparse corpus, 80/20 split
    let ds = synthetic::realsim_like(4_000, 42);
    let mut rng = Rng::new(42);
    let (train_ds, test_ds) = ds.split(0.2, &mut rng);
    println!(
        "dataset: {} rows x {} features, density {:.3}%",
        train_ds.n_rows(),
        train_ds.n_features(),
        train_ds.x.density() * 100.0
    );

    // 2. config: 4 async workers, 120 trees (paper defaults otherwise)
    let mut cfg = TrainConfig::default();
    cfg.workers = 4;
    cfg.n_trees = 120;
    cfg.step_length = 0.1;
    cfg.tree.max_leaves = 32;
    cfg.eval_every = 20;

    // 3. train on the parameter server
    let report = train(&cfg, &train_ds, Some(&test_ds))?;
    println!(
        "trained {} trees in {:.2}s with {} workers (engine: {})",
        report.trees_accepted, report.wall_secs, report.workers, report.engine
    );
    println!(
        "observed staleness: mean {:.2}, max {}",
        report.staleness.mean(),
        report.staleness.max()
    );
    for p in &report.curve.points {
        println!(
            "  trees {:>4}  train_loss {:.5}  test_loss {:.5}  test_err {:.4}",
            p.n_trees, p.train_loss, p.test_loss, p.test_error
        );
    }

    // 4. predict with the returned forest
    let margins = report.forest.predict_all(&test_ds.x);
    let w = vec![1.0f32; test_ds.n_rows()];
    println!(
        "test AUC {:.4}, accuracy {:.4}",
        metrics::auc(&margins, &test_ds.y, &w),
        metrics::accuracy(&margins, &test_ds.y, &w)
    );
    Ok(())
}
