"""Pure-jnp oracle for the fused logistic grad/hess/loss kernel.

This module intentionally contains no Pallas: it is the ground truth the
kernel (and, transitively, the Rust fallback in ``rust/src/loss/``) is
validated against. Keep the math here boring and obviously correct.

Paper loss (Section III.A): p = e^F/(e^F + e^-F) = sigmoid(2F),
l(y, F) = -y log p - (1-y) log(1-p), y in {0, 1}.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def ref_prob(f):
    """p = sigmoid(2F)."""
    return jax.nn.sigmoid(2.0 * f)


def ref_loss_elem(f, y, w):
    """Per-element weighted logistic loss, numerically stable."""
    two_f = 2.0 * f
    sp_pos = jnp.logaddexp(0.0, two_f)   # softplus(2F)
    sp_neg = jnp.logaddexp(0.0, -two_f)  # softplus(-2F)
    return w * (y * sp_neg + (1.0 - y) * sp_pos)


def ref_grad_elem(f, y, w):
    """g = w * 2(p - y)."""
    return w * 2.0 * (ref_prob(f) - y)


def ref_hess_elem(f, y, w):
    """h = w * 4 p (1-p)."""
    p = ref_prob(f)
    return w * 4.0 * p * (1.0 - p)


def ref_grad_hess_loss(f, y, w):
    """Oracle counterpart of kernels.grad_hess.grad_hess_loss_pallas."""
    return ref_grad_elem(f, y, w), ref_hess_elem(f, y, w), ref_loss_elem(f, y, w)


def ref_err_elem(f, y, w):
    """Weighted 0/1 error, threshold F > 0."""
    pred = (f > 0.0).astype(jnp.float32)
    return w * jnp.abs(pred - y)


def ref_autodiff_grad(f, y, w):
    """Gradient of the summed loss via jax autodiff — independent check
    that the closed-form g equals d(sum loss)/dF."""
    return jax.grad(lambda ff: jnp.sum(ref_loss_elem(ff, y, w)))(f)
