"""L1 — Pallas kernel: fused logistic gradient / hessian / loss.

This is the compute hot-spot of asynch-SGBDT's "produce the target"
sub-step (server side, Algorithm 3 step 4): given the forest's prediction
vector ``F``, labels ``y`` and per-sample stochastic weights
``w_i = m'_i = sum_j Q_ij / R_ij`` (Eq. 10 of the paper), produce

    g_i    = w_i * l'(y_i, F_i)   = w_i * 2 (p_i - y_i)
    h_i    = w_i * l''(y_i, F_i)  = w_i * 4 p_i (1 - p_i)
    loss_i = w_i * l(y_i, F_i)

with the paper's logistic loss (Section III.A):

    p = e^F / (e^F + e^-F) = sigmoid(2F)
    l(y, F) = -y log p - (1 - y) log(1 - p)
            = y softplus(-2F) + (1 - y) softplus(2F)

Padding rows carry ``w = 0`` and therefore contribute exactly zero to every
output, which is what lets the Rust runtime pad batches to fixed bucket
sizes.

The kernel is purely element-wise and streams over the sample axis in
``BLOCK``-sized tiles via ``BlockSpec`` — on a real TPU this is a
VPU/bandwidth-bound kernel (3 input + 3 output f32 blocks = 24 KiB of VMEM
per grid step at BLOCK=1024; no MXU involvement). ``interpret=True`` is
mandatory here: the CPU PJRT plugin cannot execute Mosaic custom-calls, and
interpret mode lowers the kernel to plain HLO so the same artifact runs
everywhere.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Minimum tile size along the sample axis. All AOT bucket sizes are
# multiples of this, so the grid always divides evenly and no masking is
# needed inside the kernel (padding is handled by w == 0).
BLOCK = 1024

# Interpret-mode pallas_call lowers the grid to an XLA while-loop whose
# body updates the full output via dynamic-update-slice — O(n) per grid
# step, i.e. O(n * grid) total. Capping the grid at GRID_TARGET steps by
# scaling the block with n keeps the lowered module linear in n
# (EXPERIMENTS.md §Perf, L1 item). On a real TPU the same cap keeps VMEM
# working sets well under budget (7 f32 arrays x 32k lanes = 896 KiB at
# the largest bucket).
GRID_TARGET = 8


def pick_block(n: int) -> int:
    """Block size for a padded length n: grid <= GRID_TARGET, block >= BLOCK."""
    if n % BLOCK != 0:
        raise ValueError(f"n={n} must be a multiple of BLOCK={BLOCK}")
    block = max(BLOCK, n // GRID_TARGET)
    # ensure the block divides n (n and BLOCK are powers-of-two multiples)
    while n % block != 0:
        block += BLOCK
    return block


def _softplus(x):
    """Numerically stable softplus: max(x, 0) + log1p(exp(-|x|))."""
    return jnp.maximum(x, 0.0) + jnp.log1p(jnp.exp(-jnp.abs(x)))


def _grad_hess_loss_kernel(f_ref, y_ref, w_ref, g_ref, h_ref, loss_ref):
    """Element-wise fused body. All refs are (BLOCK,) f32 tiles."""
    f = f_ref[...]
    y = y_ref[...]
    w = w_ref[...]

    # p = sigmoid(2F); express grad/hess in terms of p.
    p = jax.nn.sigmoid(2.0 * f)
    g_ref[...] = w * (2.0 * (p - y))
    h_ref[...] = w * (4.0 * p * (1.0 - p))
    # loss = y*softplus(-2F) + (1-y)*softplus(2F), stable for |F| >> 1.
    two_f = 2.0 * f
    loss_ref[...] = w * (y * _softplus(-two_f) + (1.0 - y) * _softplus(two_f))


@functools.partial(jax.jit, static_argnames=("block",))
def grad_hess_loss_pallas(f, y, w, *, block: int = BLOCK):
    """Run the fused kernel over length-N f32 vectors (N % block == 0).

    Returns ``(g, h, loss_elem)`` — per-element outputs; reductions are done
    by the caller (L2) so XLA can fuse them into the same pass.
    """
    n = f.shape[0]
    if n % block != 0:
        raise ValueError(f"n={n} must be a multiple of block={block}")
    grid = (n // block,)
    spec = pl.BlockSpec((block,), lambda i: (i,))
    out_shape = jax.ShapeDtypeStruct((n,), jnp.float32)
    return pl.pallas_call(
        _grad_hess_loss_kernel,
        grid=grid,
        in_specs=[spec, spec, spec],
        out_specs=[spec, spec, spec],
        out_shape=[out_shape, out_shape, out_shape],
        interpret=True,  # CPU-PJRT cannot run Mosaic custom-calls
    )(f, y, w)


def _eval_kernel(f_ref, y_ref, w_ref, loss_ref, err_ref):
    """Evaluation pass: per-element weighted loss and 0/1 error."""
    f = f_ref[...]
    y = y_ref[...]
    w = w_ref[...]
    two_f = 2.0 * f
    loss_ref[...] = w * (y * _softplus(-two_f) + (1.0 - y) * _softplus(two_f))
    # predicted class = 1 iff F > 0; mismatch indicator, weighted.
    pred = (f > 0.0).astype(jnp.float32)
    err_ref[...] = w * jnp.abs(pred - y)


@functools.partial(jax.jit, static_argnames=("block",))
def eval_pallas(f, y, w, *, block: int = BLOCK):
    """Fused evaluation kernel: returns (loss_elem, err_elem)."""
    n = f.shape[0]
    if n % block != 0:
        raise ValueError(f"n={n} must be a multiple of block={block}")
    grid = (n // block,)
    spec = pl.BlockSpec((block,), lambda i: (i,))
    out_shape = jax.ShapeDtypeStruct((n,), jnp.float32)
    return pl.pallas_call(
        _eval_kernel,
        grid=grid,
        in_specs=[spec, spec, spec],
        out_specs=[spec, spec],
        out_shape=[out_shape, out_shape],
        interpret=True,
    )(f, y, w)
