"""AOT emitter: lower the L2 model functions to HLO *text* artifacts.

Run once at build time (``make artifacts``); the Rust runtime
(``rust/src/runtime/``) loads the text with ``HloModuleProto::from_text_file``
and compiles it on the PJRT CPU client. Python is never on the request path.

Interchange format is HLO text, NOT ``lowered.compile().serialize()`` and
NOT serialized HloModuleProto: jax >= 0.5 emits protos with 64-bit
instruction ids which xla_extension 0.5.1 rejects (``proto.id() <=
INT_MAX``); the text parser reassigns ids and round-trips cleanly. See
/opt/xla-example/gen_hlo.py.

Artifacts are emitted per batch-size *bucket*; the Rust side pads each
request to the smallest bucket >= N. Padding rows carry weight 0 and are
exact no-ops in every model function.

Usage (from python/):  python -m compile.aot --out ../artifacts
"""

from __future__ import annotations

import argparse
import json
import os
import sys

import jax
from jax._src.lib import xla_client as xc

from compile.kernels.grad_hess import BLOCK
from compile.model import MODEL_FNS, example_args

#: Default bucket sizes (samples). Chosen so the smallest covers unit-test
#: datasets and the largest covers the paper-scale synthetic corpora
#: (real-sim ~72k rows, Higgs subsets) with <2x padding waste.
DEFAULT_BUCKETS = (4096, 16384, 65536, 131072, 262144)


def to_hlo_text(lowered) -> str:
    """StableHLO MLIR -> XlaComputation -> HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_entry(name: str, n: int) -> str:
    """Lower MODEL_FNS[name] at bucket size n to HLO text."""
    fn, _doc = MODEL_FNS[name]
    lowered = jax.jit(fn).lower(*example_args(n))
    return to_hlo_text(lowered)


def emit(out_dir: str, buckets=DEFAULT_BUCKETS, names=None, verbose=True) -> dict:
    """Emit all artifacts + manifest.json into out_dir. Returns manifest."""
    os.makedirs(out_dir, exist_ok=True)
    names = list(names or MODEL_FNS.keys())
    entries = []
    for name in names:
        fn, doc = MODEL_FNS[name]
        for n in buckets:
            text = lower_entry(name, n)
            fname = f"{name}_{n}.hlo.txt"
            path = os.path.join(out_dir, fname)
            with open(path, "w") as fh:
                fh.write(text)
            entries.append(
                {
                    "name": name,
                    "doc": doc,
                    "n": n,
                    "block": BLOCK,
                    "file": fname,
                    "inputs": ["f", "y", "w"],
                    "dtype": "f32",
                }
            )
            if verbose:
                print(f"  wrote {fname} ({len(text)} chars)", file=sys.stderr)
    manifest = {
        "format": "hlo-text",
        "version": 1,
        "buckets": list(buckets),
        "block": BLOCK,
        "entries": entries,
    }
    with open(os.path.join(out_dir, "manifest.json"), "w") as fh:
        json.dump(manifest, fh, indent=2)
    return manifest


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts", help="output directory")
    ap.add_argument(
        "--buckets",
        default=",".join(str(b) for b in DEFAULT_BUCKETS),
        help="comma-separated bucket sizes (multiples of %d)" % BLOCK,
    )
    ap.add_argument("--only", default=None, help="emit a single model fn")
    args = ap.parse_args()
    buckets = tuple(int(b) for b in args.buckets.split(","))
    names = [args.only] if args.only else None
    manifest = emit(args.out, buckets=buckets, names=names)
    print(
        f"emitted {len(manifest['entries'])} artifacts "
        f"({len(manifest['buckets'])} buckets) to {args.out}"
    )


if __name__ == "__main__":
    main()
