"""L2 — the JAX "model" of asynch-SGBDT's produce-target sub-step.

For a GBDT the paper's compute graph on the server hot path is not a neural
forward/backward but the stochastic-gradient construction of Eq. 10:

    L'_random = [m'_1 l'_1, ..., m'_N l'_N]

plus the loss/error reductions used for convergence monitoring. Both are
expressed here as jitted JAX functions that call the L1 Pallas kernel, so
that kernel and reductions lower into one HLO module per batch-size bucket
(``aot.py``). The Rust runtime executes these artifacts via PJRT; Python is
never on the training path.

All functions take fixed-shape padded f32 vectors; padding rows carry
weight 0 and are exact no-ops.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from compile.kernels.grad_hess import (
    BLOCK,
    eval_pallas,
    grad_hess_loss_pallas,
    pick_block,
)


def grad_hess_loss(f, y, w):
    """Server produce-target step.

    Args:
      f: (N,) f32 — forest predictions F_i (padded).
      y: (N,) f32 — labels in {0, 1} (padding value irrelevant).
      w: (N,) f32 — stochastic weights m'_i = sum_j Q_ij / R_ij; 0 on padding.

    Returns (tuple of 4):
      g: (N,) f32 — stochastic gradient target  m'_i * l'_i.
      h: (N,) f32 — stochastic hessian          m'_i * l''_i.
      loss_sum: () f32 — sum_i w_i * l(y_i, F_i).
      w_sum:    () f32 — sum_i w_i (normaliser for the mean loss).
    """
    g, h, loss_elem = grad_hess_loss_pallas(f, y, w, block=pick_block(f.shape[0]))
    return g, h, jnp.sum(loss_elem), jnp.sum(w)


def eval_metrics(f, y, w):
    """Held-out evaluation: weighted logloss + 0/1 error sums.

    Returns (loss_sum, err_sum, w_sum), all scalar f32.
    """
    loss_elem, err_elem = eval_pallas(f, y, w, block=pick_block(f.shape[0]))
    return jnp.sum(loss_elem), jnp.sum(err_elem), jnp.sum(w)


def example_args(n: int):
    """ShapeDtypeStructs for lowering at bucket size ``n``."""
    if n % BLOCK != 0:
        raise ValueError(f"bucket n={n} must be a multiple of BLOCK={BLOCK}")
    spec = jax.ShapeDtypeStruct((n,), jnp.float32)
    return (spec, spec, spec)


#: The artifact catalogue: name -> (callable, doc). aot.py lowers each entry
#: once per bucket size.
MODEL_FNS = {
    "grad_hess": (grad_hess_loss, "produce-target: (f,y,w) -> (g,h,loss_sum,w_sum)"),
    "eval": (eval_metrics, "evaluation: (f,y,w) -> (loss_sum,err_sum,w_sum)"),
}
