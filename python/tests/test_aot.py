"""AOT emitter tests: HLO-text artifacts + manifest, round-trip checked
through the same XLA client the Rust side uses (CPU PJRT)."""

import json
import os

import jax.numpy as jnp
import numpy as np
import pytest

from compile.aot import DEFAULT_BUCKETS, emit, lower_entry, to_hlo_text
from compile.kernels.grad_hess import BLOCK
from compile.kernels import ref
from compile.model import MODEL_FNS

SMALL_BUCKETS = (BLOCK, 2 * BLOCK)


@pytest.fixture(scope="module")
def emitted(tmp_path_factory):
    out = tmp_path_factory.mktemp("artifacts")
    manifest = emit(str(out), buckets=SMALL_BUCKETS, verbose=False)
    return str(out), manifest


class TestEmit:
    def test_manifest_structure(self, emitted):
        out, manifest = emitted
        assert manifest["format"] == "hlo-text"
        assert manifest["buckets"] == list(SMALL_BUCKETS)
        assert manifest["block"] == BLOCK
        assert len(manifest["entries"]) == len(MODEL_FNS) * len(SMALL_BUCKETS)
        ondisk = json.load(open(os.path.join(out, "manifest.json")))
        assert ondisk == manifest

    def test_artifact_files_exist_and_are_hlo(self, emitted):
        out, manifest = emitted
        for e in manifest["entries"]:
            path = os.path.join(out, e["file"])
            assert os.path.exists(path)
            text = open(path).read()
            assert "HloModule" in text
            assert "ENTRY" in text

    def test_entry_fields(self, emitted):
        _, manifest = emitted
        for e in manifest["entries"]:
            assert e["name"] in MODEL_FNS
            assert e["n"] % BLOCK == 0
            assert e["inputs"] == ["f", "y", "w"]
            assert e["dtype"] == "f32"

    def test_default_buckets_are_block_multiples(self):
        for b in DEFAULT_BUCKETS:
            assert b % BLOCK == 0
        assert sorted(DEFAULT_BUCKETS) == list(DEFAULT_BUCKETS)


class TestRoundTrip:
    """Compile + execute the emitted HLO text on the same CPU PJRT client
    the Rust runtime uses; numerics must match the oracle."""

    def _run_hlo(self, hlo_text, args):
        from jax._src.lib import xla_client as xc

        client = xc.make_cpu_client()
        # Parse the HLO text back into a computation and execute it.
        comp = xc._xla.hlo_module_from_text(hlo_text)
        # hlo_module_from_text gives an HloModule; wrap as computation proto
        xla_comp = xc.XlaComputation(comp.as_serialized_hlo_module_proto())
        exe = client.compile(xla_comp.as_serialized_hlo_module_proto())
        bufs = [client.buffer_from_pyval(a) for a in args]
        outs = exe.execute(bufs)
        return [np.asarray(o) for o in outs]

    def test_grad_hess_hlo_executes_and_matches_ref(self):
        n = BLOCK
        text = lower_entry("grad_hess", n)
        rng = np.random.default_rng(0)
        f = rng.normal(0, 2, n).astype(np.float32)
        y = (rng.random(n) < 0.5).astype(np.float32)
        w = rng.exponential(1.0, n).astype(np.float32)
        try:
            outs = self._run_hlo(text, [f, y, w])
        except Exception as exc:  # pragma: no cover - API drift guard
            pytest.skip(f"in-process HLO execution unavailable: {exc}")
        g, h, loss_sum, w_sum = outs
        rg, rh, rl = ref.ref_grad_hess_loss(jnp.asarray(f), jnp.asarray(y), jnp.asarray(w))
        np.testing.assert_allclose(g, rg, atol=1e-5, rtol=1e-5)
        np.testing.assert_allclose(h, rh, atol=1e-5, rtol=1e-5)
        np.testing.assert_allclose(loss_sum, np.asarray(rl).sum(), rtol=1e-4)
        np.testing.assert_allclose(w_sum, w.sum(), rtol=1e-5)

    def test_hlo_text_is_deterministic(self):
        a = lower_entry("eval", BLOCK)
        b = lower_entry("eval", BLOCK)
        assert a == b

    def test_to_hlo_text_mentions_parameters(self):
        import jax

        from compile.model import example_args, grad_hess_loss

        text = to_hlo_text(jax.jit(grad_hess_loss).lower(*example_args(BLOCK)))
        # three f32[N] parameters must appear in the entry computation
        assert text.count(f"f32[{BLOCK}]") >= 3
