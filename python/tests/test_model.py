"""L2 model tests: reductions, shapes, and lowering sanity."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.grad_hess import BLOCK
from compile.kernels import ref
from compile.model import MODEL_FNS, eval_metrics, example_args, grad_hess_loss


def _rand(n, seed, scale=3.0):
    rng = np.random.default_rng(seed)
    f = rng.normal(0.0, scale, n).astype(np.float32)
    y = (rng.random(n) < 0.5).astype(np.float32)
    w = rng.exponential(1.0, n).astype(np.float32)
    return jnp.asarray(f), jnp.asarray(y), jnp.asarray(w)


class TestGradHessLoss:
    def test_output_shapes(self):
        f, y, w = _rand(BLOCK, 0)
        g, h, loss_sum, w_sum = grad_hess_loss(f, y, w)
        assert g.shape == (BLOCK,)
        assert h.shape == (BLOCK,)
        assert loss_sum.shape == ()
        assert w_sum.shape == ()

    def test_reductions_match_ref(self):
        f, y, w = _rand(2 * BLOCK, 1)
        _, _, loss_sum, w_sum = grad_hess_loss(f, y, w)
        rl = ref.ref_loss_elem(f, y, w)
        np.testing.assert_allclose(loss_sum, rl.sum(), rtol=1e-5)
        np.testing.assert_allclose(w_sum, w.sum(), rtol=1e-6)

    def test_mean_loss_at_f0_is_log2(self):
        n = BLOCK
        f = jnp.zeros(n)
        y = jnp.asarray((np.arange(n) % 2).astype(np.float32))
        w = jnp.ones(n)
        _, _, loss_sum, w_sum = grad_hess_loss(f, y, w)
        assert float(loss_sum / w_sum) == pytest.approx(np.log(2.0), rel=1e-6)

    def test_jit_lowerable_at_all_example_shapes(self):
        for n in (BLOCK, 4 * BLOCK):
            lowered = jax.jit(grad_hess_loss).lower(*example_args(n))
            assert lowered is not None

    def test_example_args_rejects_bad_bucket(self):
        with pytest.raises(ValueError):
            example_args(BLOCK + 7)


class TestEvalMetrics:
    def test_eval_sums(self):
        f, y, w = _rand(BLOCK, 2)
        loss_sum, err_sum, w_sum = eval_metrics(f, y, w)
        np.testing.assert_allclose(loss_sum, ref.ref_loss_elem(f, y, w).sum(), rtol=1e-5)
        np.testing.assert_allclose(err_sum, ref.ref_err_elem(f, y, w).sum(), rtol=1e-5)
        np.testing.assert_allclose(w_sum, w.sum(), rtol=1e-6)

    def test_error_rate_random_classifier_near_half(self):
        n = 16 * BLOCK
        f, y, w = _rand(n, 3)
        w = jnp.ones(n)
        _, err_sum, w_sum = eval_metrics(f, y, w)
        rate = float(err_sum / w_sum)
        assert 0.45 < rate < 0.55


class TestCatalogue:
    def test_model_fns_catalogue(self):
        assert set(MODEL_FNS) == {"grad_hess", "eval"}
        for name, (fn, doc) in MODEL_FNS.items():
            assert callable(fn)
            assert isinstance(doc, str) and doc


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(min_value=0, max_value=2**31 - 1))
def test_hypothesis_loss_decreases_along_negative_gradient(seed):
    """One explicit gradient step on F must reduce the summed loss —
    the foundational property the whole SGBDT iteration relies on."""
    f, y, w = _rand(BLOCK, seed, scale=1.5)
    g, _, loss0, _ = grad_hess_loss(f, y, w)
    step = 0.05
    _, _, loss1, _ = grad_hess_loss(f - step * g, y, w)
    assert float(loss1) <= float(loss0) + 1e-6
