"""Kernel-vs-oracle correctness: the CORE numeric signal of the stack.

Everything downstream (the AOT artifacts the Rust server executes, and the
Rust fallback implementation) is validated against ``kernels.ref``; this
file pins the Pallas kernel to that oracle across shapes, value ranges and
adversarial inputs, with hypothesis driving the sweeps.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.grad_hess import (
    BLOCK,
    eval_pallas,
    grad_hess_loss_pallas,
)
from compile.kernels import ref

ATOL = 1e-5
RTOL = 1e-5


def _rand(n, seed, scale=5.0):
    rng = np.random.default_rng(seed)
    f = rng.normal(0.0, scale, n).astype(np.float32)
    y = (rng.random(n) < 0.5).astype(np.float32)
    w = rng.exponential(1.0, n).astype(np.float32)
    return jnp.asarray(f), jnp.asarray(y), jnp.asarray(w)


def assert_matches_ref(f, y, w):
    g, h, loss = grad_hess_loss_pallas(f, y, w)
    rg, rh, rloss = ref.ref_grad_hess_loss(f, y, w)
    np.testing.assert_allclose(g, rg, atol=ATOL, rtol=RTOL)
    np.testing.assert_allclose(h, rh, atol=ATOL, rtol=RTOL)
    np.testing.assert_allclose(loss, rloss, atol=ATOL, rtol=RTOL)


# ------------------------------------------------------------------ basic


class TestGradHessBasics:
    def test_single_block(self):
        assert_matches_ref(*_rand(BLOCK, 0))

    def test_multi_block(self):
        assert_matches_ref(*_rand(4 * BLOCK, 1))

    def test_zero_logits(self):
        n = BLOCK
        f = jnp.zeros(n)
        y = jnp.ones(n)
        w = jnp.ones(n)
        g, h, loss = grad_hess_loss_pallas(f, y, w)
        # p = 0.5: g = 2(0.5-1) = -1, h = 4*0.25 = 1, loss = log 2
        np.testing.assert_allclose(g, -np.ones(n), atol=ATOL)
        np.testing.assert_allclose(h, np.ones(n), atol=ATOL)
        np.testing.assert_allclose(loss, np.full(n, np.log(2.0)), atol=ATOL)

    def test_padding_rows_are_exact_noops(self):
        f, y, w = _rand(2 * BLOCK, 2)
        w = w.at[BLOCK:].set(0.0)
        g, h, loss = grad_hess_loss_pallas(f, y, w)
        assert float(jnp.abs(g[BLOCK:]).max()) == 0.0
        assert float(jnp.abs(h[BLOCK:]).max()) == 0.0
        assert float(jnp.abs(loss[BLOCK:]).max()) == 0.0

    def test_extreme_logits_are_finite(self):
        # |F| up to 80 — naive exp overflows f32 at ~88; stable softplus must
        # stay finite and the saturated grads must be ±2w / 0.
        n = BLOCK
        f = jnp.concatenate([jnp.full(n // 2, 80.0), jnp.full(n // 2, -80.0)])
        y = jnp.concatenate([jnp.zeros(n // 2), jnp.ones(n // 2)])
        w = jnp.full(n, 3.0)
        g, h, loss = grad_hess_loss_pallas(f, y, w)
        assert bool(jnp.isfinite(g).all())
        assert bool(jnp.isfinite(h).all())
        assert bool(jnp.isfinite(loss).all())
        # saturated: p -> 1 (F=80, y=0): g -> +2w; p -> 0 (F=-80, y=1): g -> -2w
        np.testing.assert_allclose(g[: n // 2], 6.0, atol=1e-3)
        np.testing.assert_allclose(g[n // 2 :], -6.0, atol=1e-3)
        np.testing.assert_allclose(h, 0.0, atol=1e-3)

    def test_rejects_non_multiple_of_block(self):
        f = jnp.zeros(BLOCK + 1)
        with pytest.raises(ValueError):
            grad_hess_loss_pallas(f, f, f)

    def test_grad_is_derivative_of_loss(self):
        # closed-form g must equal autodiff d(sum loss)/dF
        f, y, w = _rand(BLOCK, 3, scale=2.0)
        g, _, _ = grad_hess_loss_pallas(f, y, w)
        ag = ref.ref_autodiff_grad(f, y, w)
        np.testing.assert_allclose(g, ag, atol=ATOL, rtol=RTOL)

    def test_hess_is_derivative_of_grad(self):
        f, y, w = _rand(BLOCK, 4, scale=2.0)
        _, h, _ = grad_hess_loss_pallas(f, y, w)
        # d g / d F elementwise via jacfwd of the ref grad
        dg = jax.vmap(jax.grad(lambda ff, yy, ww: ref.ref_grad_elem(ff, yy, ww)))(
            f, y, w
        )
        np.testing.assert_allclose(h, dg, atol=ATOL, rtol=RTOL)


# ------------------------------------------------------------------ eval


class TestEvalKernel:
    def test_matches_ref(self):
        f, y, w = _rand(2 * BLOCK, 5)
        loss, err = eval_pallas(f, y, w)
        np.testing.assert_allclose(loss, ref.ref_loss_elem(f, y, w), atol=ATOL, rtol=RTOL)
        np.testing.assert_allclose(err, ref.ref_err_elem(f, y, w), atol=ATOL, rtol=RTOL)

    def test_perfect_classifier_zero_error(self):
        n = BLOCK
        y = (np.arange(n) % 2).astype(np.float32)
        f = jnp.asarray((y - 0.5) * 10.0)
        y = jnp.asarray(y)
        w = jnp.ones(n)
        _, err = eval_pallas(f, y, w)
        assert float(err.sum()) == 0.0

    def test_anti_classifier_full_error(self):
        n = BLOCK
        y = (np.arange(n) % 2).astype(np.float32)
        f = jnp.asarray((0.5 - y) * 10.0)
        y = jnp.asarray(y)
        w = jnp.ones(n)
        _, err = eval_pallas(f, y, w)
        assert float(err.sum()) == pytest.approx(n)


# ------------------------------------------------------------------ hypothesis sweeps


@settings(max_examples=25, deadline=None)
@given(
    blocks=st.integers(min_value=1, max_value=4),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
    scale=st.floats(min_value=0.01, max_value=30.0),
)
def test_hypothesis_shapes_and_ranges(blocks, seed, scale):
    assert_matches_ref(*_rand(blocks * BLOCK, seed, scale))


@settings(max_examples=25, deadline=None)
@given(
    seed=st.integers(min_value=0, max_value=2**31 - 1),
    frac_pad=st.floats(min_value=0.0, max_value=1.0),
)
def test_hypothesis_padding_invariance(seed, frac_pad):
    """Appending zero-weight padding must not change the reductions."""
    f, y, w = _rand(BLOCK, seed)
    n_pad = int(frac_pad * BLOCK)
    rng = np.random.default_rng(seed + 1)
    f2 = jnp.concatenate([f, jnp.asarray(rng.normal(0, 50, BLOCK).astype(np.float32))])
    y2 = jnp.concatenate([y, jnp.asarray((rng.random(BLOCK) < 0.5).astype(np.float32))])
    w2 = jnp.concatenate([w, jnp.zeros(BLOCK)])
    del n_pad  # padding is a full extra block (shape must stay divisible)
    g1, h1, l1 = grad_hess_loss_pallas(f, y, w)
    g2, h2, l2 = grad_hess_loss_pallas(f2, y2, w2)
    np.testing.assert_allclose(g1.sum(), g2.sum(), atol=1e-3, rtol=1e-5)
    np.testing.assert_allclose(h1.sum(), h2.sum(), atol=1e-3, rtol=1e-5)
    np.testing.assert_allclose(l1.sum(), l2.sum(), atol=1e-3, rtol=1e-5)


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(min_value=0, max_value=2**31 - 1))
def test_hypothesis_weight_linearity(seed):
    """Outputs are linear in w: k*w must scale g/h/loss by k exactly."""
    f, y, w = _rand(BLOCK, seed)
    g1, h1, l1 = grad_hess_loss_pallas(f, y, w)
    g2, h2, l2 = grad_hess_loss_pallas(f, y, 2.5 * w)
    np.testing.assert_allclose(2.5 * g1, g2, atol=ATOL, rtol=RTOL)
    np.testing.assert_allclose(2.5 * h1, h2, atol=ATOL, rtol=RTOL)
    np.testing.assert_allclose(2.5 * l1, l2, atol=ATOL, rtol=RTOL)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(min_value=0, max_value=2**31 - 1))
def test_hypothesis_hess_nonneg_loss_nonneg(seed):
    f, y, w = _rand(2 * BLOCK, seed, scale=10.0)
    _, h, loss = grad_hess_loss_pallas(f, y, w)
    assert float(h.min()) >= -ATOL
    assert float(loss.min()) >= -ATOL
