//! The `.sgbdt` artifact contract (DESIGN.md §16), pinned end to end:
//! save→load round-trips are bit-identical, every corruption case fails
//! with the named [`SgbdtError`] variant (never a panic, never a garbage
//! forest), checkpoint/resume reproduces the uninterrupted run bit for
//! bit in all three trainer modes, and the committed golden fixture —
//! written by an independent Python implementation of the layout —
//! loads and scores exactly.

use std::path::{Path, PathBuf};

use asgbdt::config::{TrainConfig, TrainMode};
use asgbdt::coordinator::{train, train_resumed, TrainReport};
use asgbdt::data::{synthetic, CsrMatrix, Dataset};
use asgbdt::forest::{FlatForest, ScratchPool};
use asgbdt::io::artifact::{
    self, fnv64, hex16, ArtifactMeta, SgbdtError, MAGIC, SCHEMA_VERSION,
};
use asgbdt::io::Json;
use asgbdt::loss::LossKind;
use asgbdt::serve::require_scalar_loss;
use asgbdt::util::{Executor, PoolMode, Rng};

fn tmp_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("asgbdt_artifact_{tag}"));
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

fn meta() -> ArtifactMeta {
    ArtifactMeta {
        config_fingerprint: hex16(0x1234),
        seed: 7,
        loss: "logistic".to_string(),
        train_secs: 0.5,
        trainer: None,
    }
}

/// Train a small serial model so fixtures carry real split structure
/// (negative thresholds, multi-level trees), not hand-built stumps.
fn trained(ds: &Dataset) -> TrainReport {
    let mut cfg = TrainConfig::default();
    cfg.mode = TrainMode::Serial;
    cfg.n_trees = 10;
    cfg.step_length = 0.3;
    cfg.sampling_rate = 0.9;
    cfg.tree.max_leaves = 8;
    cfg.max_bins = 16;
    cfg.eval_every = 5;
    train(&cfg, ds, None).unwrap()
}

// ------------------------------------------------------------- round trips

#[test]
fn roundtrip_margins_bit_identical_across_pool_and_thread_sweeps() {
    // one sparse fixture (real-sim-like) and one dense (higgs-like)
    for (tag, ds) in [
        ("sparse", synthetic::realsim_like(300, 7)),
        ("dense", synthetic::higgs_like(200, 9)),
    ] {
        let rep = trained(&ds);
        let flat = FlatForest::from_forest(&rep.forest);
        let path = tmp_dir("roundtrip").join(format!("{tag}.sgbdt"));
        artifact::save(&path, &flat, &rep.cuts, &meta()).unwrap();
        let a = artifact::load(&path).unwrap();
        assert_eq!(a.forest.trees, flat.trees, "{tag}: SoA arrays changed");
        assert_eq!(a.forest.base_score, flat.base_score);
        assert_eq!(a.cuts, rep.cuts, "{tag}: cuts changed");
        // margins bit-identical whichever executor scores the loaded copy
        for pool_mode in [PoolMode::Persistent, PoolMode::Scoped] {
            for threads in [1usize, 4] {
                let exec = Executor::new(pool_mode, threads);
                let mut sp = ScratchPool::new();
                let want = flat.predict_all_raw(&ds.x, &exec, &mut sp);
                let got = a.forest.predict_all_raw(&ds.x, &exec, &mut sp);
                assert_eq!(got, want, "{tag}: pool={pool_mode:?} threads={threads}");
            }
        }
    }
}

// ------------------------------------------------------- corruption matrix

fn fixture_bytes() -> Vec<u8> {
    let ds = synthetic::realsim_like(200, 13);
    let rep = trained(&ds);
    artifact::to_bytes(&FlatForest::from_forest(&rep.forest), &rep.cuts, &meta())
}

/// (payload start, parsed manifest) of an artifact byte image.
fn manifest_of(bytes: &[u8]) -> (usize, Json) {
    let mlen = u64::from_le_bytes(bytes[8..16].try_into().unwrap()) as usize;
    let j = Json::parse(std::str::from_utf8(&bytes[16..16 + mlen]).unwrap()).unwrap();
    (16 + mlen, j)
}

fn section_range(j: &Json, name: &str) -> (usize, usize) {
    let s = j
        .req("sections")
        .unwrap()
        .as_arr()
        .unwrap()
        .iter()
        .find(|s| s.req_str("name").unwrap() == name)
        .unwrap();
    (s.req_usize("offset").unwrap(), s.req_usize("len").unwrap())
}

#[test]
fn corruption_matrix_rejects_each_case_with_the_named_variant() {
    let bytes = fixture_bytes();
    let (payload_start, manifest) = manifest_of(&bytes);

    // header truncated
    match artifact::load_bytes(&bytes[..10]).unwrap_err() {
        SgbdtError::Truncated { section, .. } => assert_eq!(section, "header"),
        other => panic!("expected Truncated(header), got {other}"),
    }
    // manifest truncated
    match artifact::load_bytes(&bytes[..payload_start - 1]).unwrap_err() {
        SgbdtError::Truncated { section, .. } => assert_eq!(section, "manifest"),
        other => panic!("expected Truncated(manifest), got {other}"),
    }
    // payload truncated: manifest/payload length disagreement
    match artifact::load_bytes(&bytes[..bytes.len() - 5]).unwrap_err() {
        SgbdtError::LengthMismatch { manifest, actual } => {
            assert_eq!(manifest, actual + 5);
        }
        other => panic!("expected LengthMismatch, got {other}"),
    }
    // extra trailing bytes: same named failure, other direction
    let mut longer = bytes.clone();
    longer.push(0);
    match artifact::load_bytes(&longer).unwrap_err() {
        SgbdtError::LengthMismatch { manifest, actual } => assert_eq!(manifest + 1, actual),
        other => panic!("expected LengthMismatch, got {other}"),
    }
    // one flipped byte inside each payload section -> that section's
    // checksum fails, by name, before any decode
    for name in ["forest", "cuts"] {
        let (off, len) = section_range(&manifest, name);
        assert!(len > 0);
        let mut corrupt = bytes.clone();
        corrupt[payload_start + off + len / 2] ^= 0x01;
        match artifact::load_bytes(&corrupt).unwrap_err() {
            SgbdtError::ChecksumMismatch { section, expected, found } => {
                assert_eq!(section, name);
                assert_ne!(expected, found);
            }
            other => panic!("flip in '{name}': expected ChecksumMismatch, got {other}"),
        }
    }
    // a tampered manifest checksum is also a named mismatch: rewrite the
    // forest section's recorded hex in place (manifest bytes only — the
    // payload stays intact, so `found` is the true checksum)
    let (off, len) = section_range(&manifest, "forest");
    let sum = fnv64(&bytes[payload_start + off..payload_start + off + len]);
    let needle = hex16(sum);
    let pos = bytes[..payload_start]
        .windows(16)
        .position(|w| w == needle.as_bytes())
        .expect("manifest records the forest checksum");
    let mut tampered = bytes.clone();
    tampered[pos..pos + 16].copy_from_slice(hex16(sum ^ 1).as_bytes());
    match artifact::load_bytes(&tampered).unwrap_err() {
        SgbdtError::ChecksumMismatch { section, expected, found } => {
            assert_eq!(section, "forest");
            assert_eq!(expected, sum ^ 1);
            assert_eq!(found, sum);
        }
        other => panic!("expected ChecksumMismatch, got {other}"),
    }
    // unknown schema version (the writer itself refuses to produce one —
    // io::artifact unit tests — so forge the bytes directly)
    let ds = synthetic::realsim_like(200, 13);
    let rep = trained(&ds);
    let future = artifact::to_bytes_with_schema(
        &FlatForest::from_forest(&rep.forest),
        &rep.cuts,
        &meta(),
        99,
    );
    match artifact::load_bytes(&future).unwrap_err() {
        SgbdtError::UnknownSchemaVersion { found, supported } => {
            assert_eq!(found, 99);
            assert_eq!(supported, SCHEMA_VERSION);
        }
        other => panic!("expected UnknownSchemaVersion, got {other}"),
    }
    // wrong magic: not an .sgbdt file at all
    let mut not_ours = bytes.clone();
    not_ours[0] ^= 0xff;
    assert!(matches!(
        artifact::load_bytes(&not_ours).unwrap_err(),
        SgbdtError::BadMagic { .. }
    ));
    // a flipped byte inside the manifest itself (the format tag) is a
    // manifest failure naming expected-vs-found
    let fmt = bytes
        .windows(7)
        .position(|w| w == b"\"sgbdt\"")
        .expect("manifest carries the format tag");
    let mut bad_fmt = bytes.clone();
    bad_fmt[fmt + 1] ^= 0x01; // "sgbdt" -> "rgbdt"
    match artifact::load_bytes(&bad_fmt).unwrap_err() {
        SgbdtError::MalformedManifest { detail } => {
            assert!(detail.contains("sgbdt") && detail.contains("rgbdt"), "{detail}");
        }
        other => panic!("expected MalformedManifest, got {other}"),
    }
}

#[test]
fn corruption_never_panics_and_never_yields_a_garbage_forest() {
    let bytes = fixture_bytes();
    let reference = artifact::load_bytes(&bytes).unwrap();
    // every strict prefix must be rejected
    for cut in (0..bytes.len()).step_by(41).chain([bytes.len() - 1]) {
        assert!(
            artifact::load_bytes(&bytes[..cut]).is_err(),
            "prefix of {cut} bytes loaded"
        );
    }
    // single-byte flips across the whole image: either rejected, or (a
    // flip in a non-load-bearing manifest field like provenance) the
    // decoded forest and cuts are still exactly the reference — a wrong
    // model can never come back without an error
    for i in (0..bytes.len()).step_by(23) {
        let mut corrupt = bytes.clone();
        corrupt[i] ^= 0x10;
        if let Ok(a) = artifact::load_bytes(&corrupt) {
            assert_eq!(a.forest.trees, reference.forest.trees, "flip at byte {i}");
            assert_eq!(a.forest.base_score, reference.forest.base_score);
            assert_eq!(a.cuts, reference.cuts, "flip at byte {i}");
        }
    }
}

// -------------------------------------------------------- checkpoint/resume

fn resume_cfg(mode: TrainMode, dir: &Path) -> TrainConfig {
    let mut cfg = TrainConfig::default();
    cfg.mode = mode;
    cfg.n_trees = 60;
    cfg.step_length = 0.2;
    cfg.sampling_rate = 0.8;
    cfg.workers = 3;
    cfg.tree.max_leaves = 8;
    cfg.max_bins = 16;
    cfg.eval_every = 10;
    if mode == TrainMode::Async {
        // the async determinism envelope: only fresh pushes are accepted
        // (so the accepted sequence is timing-independent) and
        // feature_rate=1 keeps worker builds pure functions of the
        // target — see coordinator::train_async_resumed
        cfg.max_staleness = Some(0);
        cfg.tree.feature_rate = 1.0;
    }
    cfg.checkpoint_every = 20;
    cfg.checkpoint_path = Some(dir.join(format!("ck_{}.sgbdt", mode.as_str())));
    cfg
}

#[test]
fn resume_is_bit_identical_in_all_three_modes() {
    let ds = synthetic::realsim_like(300, 11);
    let mut rng = Rng::new(5);
    let (tr, te) = ds.split(0.2, &mut rng);
    let dir = tmp_dir("resume");
    for mode in [TrainMode::Serial, TrainMode::Sync, TrainMode::Async] {
        let cfg = resume_cfg(mode, &dir);
        let full = train(&cfg, &tr, Some(&te)).unwrap();
        assert_eq!(full.trees_accepted, 60);
        let full_json = full.forest.to_json().to_string();
        let base = cfg.checkpoint_path.clone().unwrap();
        for k in [20usize, 40] {
            let ck = artifact::load(&artifact::checkpoint_file(&base, k)).unwrap();
            assert_eq!(ck.forest.n_trees(), k, "{mode:?} checkpoint at {k}");
            let t = ck.trainer.as_ref().expect("checkpoints carry a trainer stanza");
            assert_eq!(t.mode, mode.as_str());
            assert_eq!(t.trees_done, k);
            let resumed = train_resumed(&cfg, &tr, Some(&te), Some(&ck)).unwrap();
            // final forest bit-identical to the uninterrupted run
            assert_eq!(
                resumed.forest.to_json().to_string(),
                full_json,
                "{mode:?} resumed from {k} diverged"
            );
            // ...and so are the final test loss and test error
            assert_eq!(
                resumed.curve.final_test_loss(),
                full.curve.final_test_loss(),
                "{mode:?} from {k}"
            );
            let (rp, fp) = (
                resumed.curve.points.last().unwrap(),
                full.curve.points.last().unwrap(),
            );
            assert_eq!(rp.test_error, fp.test_error, "{mode:?} from {k}");
        }
    }
}

#[test]
fn resume_refuses_a_checkpoint_from_another_mode() {
    let ds = synthetic::realsim_like(200, 12);
    let dir = tmp_dir("resume_mode");
    let mut serial = resume_cfg(TrainMode::Serial, &dir);
    serial.n_trees = 30;
    train(&serial, &ds, None).unwrap();
    let ck = artifact::load(&artifact::checkpoint_file(
        serial.checkpoint_path.as_ref().unwrap(),
        20,
    ))
    .unwrap();
    let mut sync = serial.clone();
    sync.mode = TrainMode::Sync;
    let err = train_resumed(&sync, &ds, None, Some(&ck)).unwrap_err().to_string();
    assert!(err.contains("mode=serial") && err.contains("mode=sync"), "{err}");
    // a final model (no trainer stanza) is refused by name, too
    let flat = FlatForest::from_forest(&asgbdt::forest::Forest::new(0.0));
    let final_bytes = artifact::to_bytes(&flat, &ck.cuts, &meta());
    let final_model = artifact::load_bytes(&final_bytes).unwrap();
    let err = train_resumed(&serial, &ds, None, Some(&final_model))
        .unwrap_err()
        .to_string();
    assert!(err.contains("trainer stanza"), "{err}");
}

// ------------------------------------------------------------ loss metadata

#[test]
fn manifest_round_trips_every_loss_name() {
    let ds = synthetic::realsim_like(200, 17);
    let rep = trained(&ds);
    let flat = FlatForest::from_forest(&rep.forest);
    for name in ["logistic", "squared", "huber", "multiclass"] {
        let mut m = meta();
        m.loss = name.to_string();
        let bytes = artifact::to_bytes(&flat, &rep.cuts, &m);
        let a = artifact::load_bytes(&bytes).unwrap();
        assert_eq!(a.loss, name, "manifest dropped the loss name");
    }
}

#[test]
fn resume_refuses_a_checkpoint_from_another_loss() {
    // a squared-loss checkpoint's margins are squared-loss margins;
    // resuming them under huber would silently change what every
    // F-update means, so restore refuses by naming both losses
    let ds = synthetic::regression_like(220, 19);
    let dir = tmp_dir("resume_loss");
    let mut sq = resume_cfg(TrainMode::Serial, &dir);
    sq.loss = LossKind::Squared;
    sq.n_trees = 30;
    sq.checkpoint_path = Some(dir.join("ck_sq.sgbdt"));
    train(&sq, &ds, None).unwrap();
    let ck = artifact::load(&artifact::checkpoint_file(
        sq.checkpoint_path.as_ref().unwrap(),
        20,
    ))
    .unwrap();
    assert_eq!(ck.loss, "squared", "checkpoints must record their loss");
    let mut hu = sq.clone();
    hu.loss = LossKind::Huber;
    let err = train_resumed(&hu, &ds, None, Some(&ck)).unwrap_err().to_string();
    assert!(
        err.contains("loss=squared") && err.contains("loss=huber"),
        "error must name both losses: {err}"
    );
}

#[test]
fn the_serving_gate_refuses_a_multiclass_artifact_by_name() {
    let ds = synthetic::realsim_like(200, 23);
    let rep = trained(&ds);
    let mut m = meta();
    m.loss = "multiclass".to_string();
    let bytes = artifact::to_bytes(&FlatForest::from_forest(&rep.forest), &rep.cuts, &m);
    let a = artifact::load_bytes(&bytes).unwrap();
    // the artifact itself loads fine — only the scalar scoring surfaces
    // (serve/predict) refuse it, by name
    let err = format!("{:#}", require_scalar_loss(&a.loss, "serve").unwrap_err());
    assert!(
        err.contains("serve") && err.contains("loss=multiclass"),
        "{err}"
    );
    for scalar in ["logistic", "squared", "huber"] {
        assert!(require_scalar_loss(scalar, "serve").is_ok());
    }
}

// ----------------------------------------------------------- golden fixture

#[test]
fn golden_fixture_loads_and_scores_exactly() {
    let path = Path::new(env!("CARGO_MANIFEST_DIR")).join("tests/fixtures/golden.sgbdt");
    assert!(artifact::sniff(&path).unwrap(), "golden fixture lost its magic");
    let a = artifact::load(&path).unwrap();
    assert_eq!(a.schema_version, SCHEMA_VERSION);
    assert_eq!(a.build, "make_golden.py", "golden bytes come from the Python twin");
    assert_eq!(a.seed, 42);
    assert_eq!(a.forest.n_trees(), 1);
    assert_eq!(a.forest.base_score, 0.5);
    assert_eq!(a.cuts.n_features(), 1);
    assert!(a.trainer.is_none());
    // the stump splits feature 0 at 2.0 with v=0.5, leaves -1/+1:
    // margin(1.0) = 0.5 + 0.5*(-1) = 0.0; margin(3.0) = 0.5 + 0.5*1 = 1.0
    let x = CsrMatrix::from_dense(2, 1, &[1.0, 3.0]).unwrap();
    let exec = Executor::scoped(1);
    let mut pool = ScratchPool::new();
    assert_eq!(a.forest.predict_all_raw(&x, &exec, &mut pool), vec![0.0, 1.0]);
}

#[test]
fn golden_fixture_magic_matches_the_crate_constant() {
    let path = Path::new(env!("CARGO_MANIFEST_DIR")).join("tests/fixtures/golden.sgbdt");
    let head = &std::fs::read(path).unwrap()[..8];
    assert_eq!(head, MAGIC);
}
