//! Tree-learner integration: learning power, consistency between binned
//! and raw prediction, boosting end-to-end with the forest, and the
//! Subtract/Rebuild histogram-strategy equivalence property.
//!
//! Dataset setup comes from `testkit::logistic_fixture` (binned dataset
//! + margin-0 logistic targets + full row list) — the block every test
//! here used to hand-roll.

use asgbdt::data::{synthetic, BinnedDataset, Dataset};
use asgbdt::forest::Forest;
use asgbdt::loss::{logistic, metrics};
use asgbdt::testkit::logistic_fixture;
use asgbdt::tree::{
    build_tree, build_tree_pooled, HistogramPool, HistogramStrategy, Node, Tree, TreeParams,
};
use asgbdt::util::Rng;

#[test]
fn single_tree_reduces_training_loss() {
    let ds = synthetic::realsim_like(1_000, 1);
    let b = BinnedDataset::from_dataset(&ds, 32).unwrap();
    let base = Forest::base_from_positive_rate(ds.positive_rate());
    let f0 = vec![base; ds.n_rows()];
    let w = ds.m.clone();
    let gh = logistic::grad_hess_loss(&f0, &ds.y, &w);
    let rows: Vec<u32> = (0..ds.n_rows() as u32).collect();
    let params = TreeParams { max_leaves: 32, feature_rate: 1.0, ..Default::default() };
    let tree = build_tree(&b, &rows, &gh.grad, &gh.hess, &params, &mut Rng::new(2));
    // full Newton step for the fitted tree
    let f1: Vec<f32> = (0..ds.n_rows())
        .map(|r| f0[r] + tree.predict_binned(&b, r))
        .collect();
    let l0 = metrics::logloss(&f0, &ds.y, &w);
    let l1 = metrics::logloss(&f1, &ds.y, &w);
    assert!(l1 < l0, "tree step must reduce loss: {l0} -> {l1}");
}

#[test]
fn binned_and_raw_prediction_agree_on_training_data() {
    let ds = synthetic::realsim_like(500, 3);
    let fx = logistic_fixture(&ds, 64);
    let params = TreeParams { max_leaves: 64, feature_rate: 1.0, ..Default::default() };
    let tree = build_tree(&fx.binned, &fx.rows, &fx.grad, &fx.hess, &params, &mut Rng::new(4));
    for r in 0..ds.n_rows() {
        let pb = tree.predict_binned(&fx.binned, r);
        let pr = tree.predict_raw(&ds.x, r);
        assert_eq!(pb, pr, "row {r}: binned {pb} vs raw {pr}");
    }
}

#[test]
fn boosting_loop_overfits_small_data() {
    // 10 boosting steps with big leaves should drive training error to ~0
    let ds = synthetic::realsim_like(300, 5);
    let b = BinnedDataset::from_dataset(&ds, 64).unwrap();
    let mut forest = Forest::new(Forest::base_from_positive_rate(ds.positive_rate()));
    let w = ds.m.clone();
    let mut f = vec![forest.base_score; ds.n_rows()];
    let params = TreeParams {
        max_leaves: 128,
        feature_rate: 1.0,
        lambda: 0.1,
        ..Default::default()
    };
    let rows: Vec<u32> = (0..ds.n_rows() as u32).collect();
    let mut rng = Rng::new(6);
    for _ in 0..10 {
        let gh = logistic::grad_hess_loss(&f, &ds.y, &w);
        let tree = build_tree(&b, &rows, &gh.grad, &gh.hess, &params, &mut rng);
        for r in 0..ds.n_rows() {
            f[r] += 0.5 * tree.predict_binned(&b, r);
        }
        forest.push(0.5, tree);
    }
    let err = metrics::error_rate(&f, &ds.y, &w);
    assert!(err < 0.05, "training error {err} after 10 overfit steps");
    // forest predictions must agree with the accumulated margins
    let fp = forest.predict_all_binned(&b);
    for r in 0..ds.n_rows() {
        assert!((fp[r] - f[r]).abs() < 1e-4);
    }
}

#[test]
fn feature_sampling_restricts_split_features() {
    let ds = synthetic::realsim_like(400, 7);
    let fx = logistic_fixture(&ds, 32);
    // rate 0.05: only ~5% of features available; tree still builds
    let params = TreeParams { max_leaves: 8, feature_rate: 0.05, ..Default::default() };
    let tree = build_tree(&fx.binned, &fx.rows, &fx.grad, &fx.hess, &params, &mut Rng::new(8));
    tree.validate().unwrap();
    assert!(tree.n_leaves() >= 1);
}

/// Boost `n_trees` trees with the given histogram strategy, sharing one
/// pool across trees (the worker-loop shape), and return them.
fn boost_forest(
    strategy: HistogramStrategy,
    ds: &Dataset,
    b: &BinnedDataset,
    n_trees: usize,
) -> (Vec<Tree>, HistogramPool) {
    let w = vec![1.0f32; ds.n_rows()];
    let mut f = vec![0.0f32; ds.n_rows()];
    let params = TreeParams {
        max_leaves: 24,
        feature_rate: 0.8,
        strategy,
        ..Default::default()
    };
    let mut rng = Rng::new(77);
    let mut pool = HistogramPool::new(b.total_bins());
    let rows: Vec<u32> = (0..ds.n_rows() as u32).collect();
    let mut trees = Vec::new();
    for _ in 0..n_trees {
        let gh = logistic::grad_hess_loss(&f, &ds.y, &w);
        let t = build_tree_pooled(b, &rows, &gh.grad, &gh.hess, &params, &mut rng, &mut pool);
        for r in 0..ds.n_rows() {
            f[r] += 0.3 * t.predict_binned(b, r);
        }
        trees.push(t);
    }
    (trees, pool)
}

/// The equivalence property of the sibling-subtraction engine: `Subtract`
/// and `Rebuild` must grow identical forests — same split features, bins
/// and thresholds, leaf values within 1e-5 (the only difference between
/// the strategies is f64 rounding inside the gain computation).
#[test]
fn subtract_and_rebuild_strategies_grow_identical_forests() {
    for seed in [1u64, 9, 23, 41] {
        let ds = synthetic::realsim_like(700, seed);
        let b = BinnedDataset::from_dataset(&ds, 32).unwrap();
        let (sub, _) = boost_forest(HistogramStrategy::Subtract, &ds, &b, 5);
        let (reb, _) = boost_forest(HistogramStrategy::Rebuild, &ds, &b, 5);
        assert_eq!(sub.len(), reb.len());
        for (ti, (ts, tr)) in sub.iter().zip(&reb).enumerate() {
            assert_eq!(
                ts.nodes.len(),
                tr.nodes.len(),
                "seed {seed} tree {ti}: node count"
            );
            for (ni, (ns, nr)) in ts.nodes.iter().zip(&tr.nodes).enumerate() {
                match (ns, nr) {
                    (
                        Node::Split { feature: fs, bin: bs, threshold: hs, left: ls, right: rs },
                        Node::Split { feature: fr, bin: br, threshold: hr, left: lr, right: rr },
                    ) => {
                        assert_eq!(fs, fr, "seed {seed} tree {ti} node {ni}: split feature");
                        assert_eq!(bs, br, "seed {seed} tree {ti} node {ni}: split bin");
                        assert_eq!(hs, hr, "seed {seed} tree {ti} node {ni}: threshold");
                        assert_eq!((ls, rs), (lr, rr), "seed {seed} tree {ti} node {ni}: children");
                    }
                    (Node::Leaf { value: vs }, Node::Leaf { value: vr }) => {
                        assert!(
                            (vs - vr).abs() < 1e-5,
                            "seed {seed} tree {ti} node {ni}: leaf {vs} vs {vr}"
                        );
                    }
                    _ => panic!("seed {seed} tree {ti} node {ni}: structure mismatch"),
                }
            }
        }
    }
}

/// The pool contract across trees: after the first tree, steady-state
/// boosting takes every buffer from the free list — total allocations
/// stay bounded by the peak working set (live leaves + parent + child),
/// never growing with the number of trees.
#[test]
fn histogram_pool_allocations_bounded_across_trees() {
    let ds = synthetic::realsim_like(500, 13);
    let b = BinnedDataset::from_dataset(&ds, 32).unwrap();
    let (_, pool) = boost_forest(HistogramStrategy::Subtract, &ds, &b, 6);
    assert!(
        pool.allocated() <= 24 + 2,
        "6 pooled tree builds allocated {} buffers (expected <= max_leaves + 2)",
        pool.allocated()
    );
    // every buffer taken during the builds was returned to the pool
    assert_eq!(pool.idle(), pool.allocated(), "pool leaked buffers");
}

#[test]
fn forest_serialization_roundtrip_with_real_trees() {
    let ds = synthetic::realsim_like(200, 9);
    let fx = logistic_fixture(&ds, 16);
    let params = TreeParams { max_leaves: 16, feature_rate: 0.8, ..Default::default() };
    let mut forest = Forest::new(0.1);
    let mut rng = Rng::new(10);
    for _ in 0..3 {
        forest.push(
            0.01,
            build_tree(&fx.binned, &fx.rows, &fx.grad, &fx.hess, &params, &mut rng),
        );
    }
    let path = std::env::temp_dir().join("asgbdt_it_forest.json");
    forest.save(&path).unwrap();
    let loaded = Forest::load(&path).unwrap();
    for r in 0..ds.n_rows() {
        assert_eq!(forest.predict_raw(&ds.x, r), loaded.predict_raw(&ds.x, r));
    }
    std::fs::remove_file(&path).ok();
}
