//! Tree-learner integration: learning power, consistency between binned
//! and raw prediction, boosting end-to-end with the forest.

use asgbdt::data::{synthetic, BinnedDataset};
use asgbdt::forest::Forest;
use asgbdt::loss::{logistic, metrics};
use asgbdt::tree::{build_tree, TreeParams};
use asgbdt::util::Rng;

#[test]
fn single_tree_reduces_training_loss() {
    let ds = synthetic::realsim_like(1_000, 1);
    let b = BinnedDataset::from_dataset(&ds, 32).unwrap();
    let base = Forest::base_from_positive_rate(ds.positive_rate());
    let f0 = vec![base; ds.n_rows()];
    let w = ds.m.clone();
    let gh = logistic::grad_hess_loss(&f0, &ds.y, &w);
    let rows: Vec<u32> = (0..ds.n_rows() as u32).collect();
    let params = TreeParams { max_leaves: 32, feature_rate: 1.0, ..Default::default() };
    let tree = build_tree(&b, &rows, &gh.grad, &gh.hess, &params, &mut Rng::new(2));
    // full Newton step for the fitted tree
    let f1: Vec<f32> = (0..ds.n_rows())
        .map(|r| f0[r] + tree.predict_binned(&b, r))
        .collect();
    let l0 = metrics::logloss(&f0, &ds.y, &w);
    let l1 = metrics::logloss(&f1, &ds.y, &w);
    assert!(l1 < l0, "tree step must reduce loss: {l0} -> {l1}");
}

#[test]
fn binned_and_raw_prediction_agree_on_training_data() {
    let ds = synthetic::realsim_like(500, 3);
    let b = BinnedDataset::from_dataset(&ds, 64).unwrap();
    let f0 = vec![0.0f32; ds.n_rows()];
    let w = vec![1.0f32; ds.n_rows()];
    let gh = logistic::grad_hess_loss(&f0, &ds.y, &w);
    let rows: Vec<u32> = (0..ds.n_rows() as u32).collect();
    let params = TreeParams { max_leaves: 64, feature_rate: 1.0, ..Default::default() };
    let tree = build_tree(&b, &rows, &gh.grad, &gh.hess, &params, &mut Rng::new(4));
    for r in 0..ds.n_rows() {
        let pb = tree.predict_binned(&b, r);
        let pr = tree.predict_raw(&ds.x, r);
        assert_eq!(pb, pr, "row {r}: binned {pb} vs raw {pr}");
    }
}

#[test]
fn boosting_loop_overfits_small_data() {
    // 10 boosting steps with big leaves should drive training error to ~0
    let ds = synthetic::realsim_like(300, 5);
    let b = BinnedDataset::from_dataset(&ds, 64).unwrap();
    let mut forest = Forest::new(Forest::base_from_positive_rate(ds.positive_rate()));
    let w = ds.m.clone();
    let mut f = vec![forest.base_score; ds.n_rows()];
    let params = TreeParams { max_leaves: 128, feature_rate: 1.0, lambda: 0.1, ..Default::default() };
    let rows: Vec<u32> = (0..ds.n_rows() as u32).collect();
    let mut rng = Rng::new(6);
    for _ in 0..10 {
        let gh = logistic::grad_hess_loss(&f, &ds.y, &w);
        let tree = build_tree(&b, &rows, &gh.grad, &gh.hess, &params, &mut rng);
        for r in 0..ds.n_rows() {
            f[r] += 0.5 * tree.predict_binned(&b, r);
        }
        forest.push(0.5, tree);
    }
    let err = metrics::error_rate(&f, &ds.y, &w);
    assert!(err < 0.05, "training error {err} after 10 overfit steps");
    // forest predictions must agree with the accumulated margins
    let fp = forest.predict_all_binned(&b);
    for r in 0..ds.n_rows() {
        assert!((fp[r] - f[r]).abs() < 1e-4);
    }
}

#[test]
fn feature_sampling_restricts_split_features() {
    let ds = synthetic::realsim_like(400, 7);
    let b = BinnedDataset::from_dataset(&ds, 32).unwrap();
    let f0 = vec![0.0f32; ds.n_rows()];
    let w = vec![1.0f32; ds.n_rows()];
    let gh = logistic::grad_hess_loss(&f0, &ds.y, &w);
    let rows: Vec<u32> = (0..ds.n_rows() as u32).collect();
    // rate 0.05: only ~5% of features available; tree still builds
    let params = TreeParams { max_leaves: 8, feature_rate: 0.05, ..Default::default() };
    let tree = build_tree(&b, &rows, &gh.grad, &gh.hess, &params, &mut Rng::new(8));
    tree.validate().unwrap();
    assert!(tree.n_leaves() >= 1);
}

#[test]
fn forest_serialization_roundtrip_with_real_trees() {
    let ds = synthetic::realsim_like(200, 9);
    let b = BinnedDataset::from_dataset(&ds, 16).unwrap();
    let f0 = vec![0.0f32; ds.n_rows()];
    let w = vec![1.0f32; ds.n_rows()];
    let gh = logistic::grad_hess_loss(&f0, &ds.y, &w);
    let rows: Vec<u32> = (0..ds.n_rows() as u32).collect();
    let params = TreeParams { max_leaves: 16, feature_rate: 0.8, ..Default::default() };
    let mut forest = Forest::new(0.1);
    let mut rng = Rng::new(10);
    for _ in 0..3 {
        forest.push(0.01, build_tree(&b, &rows, &gh.grad, &gh.hess, &params, &mut rng));
    }
    let path = std::env::temp_dir().join("asgbdt_it_forest.json");
    forest.save(&path).unwrap();
    let loaded = Forest::load(&path).unwrap();
    for r in 0..ds.n_rows() {
        assert_eq!(forest.predict_raw(&ds.x, r), loaded.predict_raw(&ds.x, r));
    }
    std::fs::remove_file(&path).ok();
}
