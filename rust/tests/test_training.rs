//! End-to-end trainer integration: all three modes, convergence quality,
//! determinism, and the paper's validity-experiment contrasts in miniature.

use asgbdt::config::{GradMode, TrainConfig, TrainMode};
use asgbdt::coordinator::{train, train_async, train_serial, train_sync};
use asgbdt::data::synthetic;
use asgbdt::util::Rng;

fn cfg(mode: TrainMode, workers: usize, n_trees: usize) -> TrainConfig {
    let mut c = TrainConfig::default();
    c.mode = mode;
    c.workers = workers;
    c.n_trees = n_trees;
    c.step_length = 0.2;
    c.sampling_rate = 0.8;
    c.tree.max_leaves = 16;
    c.max_bins = 32;
    c.eval_every = 8;
    c
}

#[test]
fn all_three_modes_descend_on_realsim() {
    let ds = synthetic::realsim_like(600, 1);
    let mut rng = Rng::new(1);
    let (tr, te) = ds.split(0.2, &mut rng);
    for mode in [TrainMode::Serial, TrainMode::Sync, TrainMode::Async] {
        let rep = train(&cfg(mode, 4, 32), &tr, Some(&te)).unwrap();
        let first = rep.curve.points.first().unwrap().train_loss;
        let last = rep.curve.points.last().unwrap().train_loss;
        assert!(
            last < first - 0.03,
            "{:?} did not descend: {first} -> {last}",
            mode
        );
        assert_eq!(rep.trees_accepted, 32);
        assert!(rep.curve.points.last().unwrap().test_loss.is_finite());
    }
}

#[test]
fn async_with_few_workers_tracks_serial_on_high_diversity_data() {
    // the paper's core validity claim, miniaturised: on a high-diversity
    // dataset, async convergence per tree ~ serial convergence per tree.
    let ds = synthetic::realsim_like(800, 2);
    let serial = train_serial(&cfg(TrainMode::Serial, 1, 40), &ds, None).unwrap();
    let async4 = train_async(&cfg(TrainMode::Async, 4, 40), &ds, None).unwrap();
    let ls = serial.curve.final_train_loss().unwrap();
    let la = async4.curve.final_train_loss().unwrap();
    assert!(
        (la - ls).abs() < 0.08,
        "async diverged from serial: {la} vs {ls}"
    );
}

#[test]
fn newton_mode_converges_faster_per_tree_than_gradient_mode() {
    let ds = synthetic::realsim_like(600, 3);
    let mut base = cfg(TrainMode::Serial, 1, 25);
    base.grad_mode = GradMode::Gradient;
    let grad = train_serial(&base, &ds, None).unwrap();
    base.grad_mode = GradMode::Newton;
    let newton = train_serial(&base, &ds, None).unwrap();
    // Newton leaf values use true curvature: at least as good per tree
    let lg = grad.curve.final_train_loss().unwrap();
    let ln = newton.curve.final_train_loss().unwrap();
    assert!(ln <= lg + 0.02, "newton {ln} much worse than gradient {lg}");
}

#[test]
fn sync_and_serial_produce_identical_forests() {
    let ds = synthetic::realsim_like(400, 4);
    let a = train_serial(&cfg(TrainMode::Serial, 1, 10), &ds, None).unwrap();
    let b = train_sync(&cfg(TrainMode::Sync, 4, 10), &ds, None).unwrap();
    assert_eq!(a.forest.n_trees(), b.forest.n_trees());
    for r in 0..50 {
        assert!(
            (a.forest.predict_raw(&ds.x, r) - b.forest.predict_raw(&ds.x, r)).abs() < 1e-4,
            "row {r}"
        );
    }
}

#[test]
fn scoring_engines_train_bit_identically() {
    // flat blocked scoring vs the per-row enum reference: same F vector
    // after every accepted tree, therefore the same sampled targets, the
    // same trees, and the same loss curve — exactly, not approximately.
    let ds = synthetic::realsim_like(1_400, 9);
    let mut rng = Rng::new(9);
    let (tr, te) = ds.split(0.25, &mut rng);
    let mut flat_cfg = cfg(TrainMode::Serial, 1, 14);
    flat_cfg.scoring = asgbdt::forest::ScoreMode::Flat;
    flat_cfg.score_threads = 4;
    let mut ref_cfg = flat_cfg.clone();
    // the per-row engine lives on the serial accept path, so this run
    // also pins the fused pipeline (flat_cfg, default) against it
    ref_cfg.target = asgbdt::ps::TargetMode::Serial;
    ref_cfg.scoring = asgbdt::forest::ScoreMode::PerRow;
    ref_cfg.score_threads = 1;
    let a = train_serial(&flat_cfg, &tr, Some(&te)).unwrap();
    let b = train_serial(&ref_cfg, &tr, Some(&te)).unwrap();
    let la: Vec<f64> = a.curve.points.iter().map(|p| p.train_loss).collect();
    let lb: Vec<f64> = b.curve.points.iter().map(|p| p.train_loss).collect();
    assert_eq!(la, lb, "train curves diverged between scoring engines");
    let ta: Vec<f64> = a.curve.points.iter().map(|p| p.test_loss).collect();
    let tb: Vec<f64> = b.curve.points.iter().map(|p| p.test_loss).collect();
    assert_eq!(ta, tb, "test curves diverged between scoring engines");
    assert_eq!(a.forest.n_trees(), b.forest.n_trees());
    for r in 0..tr.n_rows() {
        assert_eq!(
            a.forest.predict_raw(&tr.x, r),
            b.forest.predict_raw(&tr.x, r),
            "forests diverged at row {r}"
        );
    }
}

#[test]
fn fused_and_serial_accept_paths_train_identically() {
    // end-to-end half of the fused-pipeline acceptance bar: identical
    // targets per version ⇒ identical trees ⇒ identical curves and
    // forests, with the fused pass sharded across threads
    let ds = synthetic::realsim_like(1_300, 11);
    let mut rng = Rng::new(11);
    let (tr, te) = ds.split(0.25, &mut rng);
    let mut fused_cfg = cfg(TrainMode::Serial, 1, 12);
    fused_cfg.score_threads = 3; // default target=fused
    let mut serial_cfg = cfg(TrainMode::Serial, 1, 12);
    serial_cfg.target = asgbdt::ps::TargetMode::Serial;
    serial_cfg.score_threads = 1;
    let a = train_serial(&fused_cfg, &tr, Some(&te)).unwrap();
    let b = train_serial(&serial_cfg, &tr, Some(&te)).unwrap();
    let la: Vec<f64> = a.curve.points.iter().map(|p| p.train_loss).collect();
    let lb: Vec<f64> = b.curve.points.iter().map(|p| p.train_loss).collect();
    assert_eq!(la, lb, "train curves diverged between accept paths");
    let ta: Vec<f64> = a.curve.points.iter().map(|p| p.test_loss).collect();
    let tb: Vec<f64> = b.curve.points.iter().map(|p| p.test_loss).collect();
    assert_eq!(ta, tb, "test curves diverged between accept paths");
    for r in 0..tr.n_rows() {
        assert_eq!(
            a.forest.predict_raw(&tr.x, r),
            b.forest.predict_raw(&tr.x, r),
            "forests diverged at row {r}"
        );
    }
}

#[test]
fn tiny_sampling_rate_still_trains() {
    // paper Figure 9's extreme: ~2% of rows per pass
    let ds = synthetic::realsim_like(1_000, 5);
    let mut c = cfg(TrainMode::Async, 2, 30);
    c.sampling_rate = 0.02;
    let rep = train_async(&c, &ds, None).unwrap();
    assert_eq!(rep.trees_accepted, 30);
    let last = rep.curve.final_train_loss().unwrap();
    assert!(last.is_finite() && last > 0.0);
}

#[test]
fn model_predicts_on_unseen_data_better_than_chance() {
    let ds = synthetic::realsim_like(1_200, 6);
    let mut rng = Rng::new(6);
    let (tr, te) = ds.split(0.25, &mut rng);
    let rep = train_async(&cfg(TrainMode::Async, 4, 60), &tr, Some(&te)).unwrap();
    let final_err = rep.curve.points.last().unwrap().test_error;
    assert!(
        final_err < 0.45,
        "test error {final_err} not better than chance"
    );
}

#[test]
fn reports_carry_phase_timings() {
    let ds = synthetic::realsim_like(300, 7);
    // fused accept path (default): one fused pass per accepted tree,
    // plus the shared init target production
    let rep = train_serial(&cfg(TrainMode::Serial, 1, 8), &ds, None).unwrap();
    assert!(rep.timer.count("server/fused_pass") == 8);
    assert!(rep.timer.count("server/flatten_tree") == 8);
    assert!(rep.timer.count("server/sample") >= 1); // init pass (version 0)
    assert!(rep.build_times.n == 8);
    // serial accept path: the separate per-phase sweeps stay measurable
    let mut serial_cfg = cfg(TrainMode::Serial, 1, 8);
    serial_cfg.target = asgbdt::ps::TargetMode::Serial;
    let rep = train_serial(&serial_cfg, &ds, None).unwrap();
    assert!(rep.timer.count("server/produce_target") >= 8);
    assert!(rep.timer.count("server/update_f") == 8);
    assert!(rep.timer.count("server/sample") >= 8);
    assert!(rep.timer.count("server/fused_pass") == 0);
}

#[test]
fn to_json_summary_is_complete() {
    let ds = synthetic::realsim_like(200, 8);
    let rep = train_serial(&cfg(TrainMode::Serial, 1, 5), &ds, None).unwrap();
    let j = rep.to_json();
    assert_eq!(j.req_usize("trees_accepted").unwrap(), 5);
    assert!(j.req_f64("final_train_loss").unwrap().is_finite());
    assert_eq!(j.req_str("mode").unwrap(), "serial");
}
