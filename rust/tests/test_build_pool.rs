//! The worker-side build pool's equivalence matrix: tree construction
//! must be **bit-identical** across `pool=persistent|scoped` at every
//! histogram strategy and thread count (node-by-node, not just
//! predictions), the work-stealing split search must pin the serial
//! scan's lower-feature-id tie-break under any chunk scheduling, and one
//! persistent executor must survive an entire (≥100-tree) training run.

use asgbdt::config::TrainConfig;
use asgbdt::coordinator::{train_async, train_sync};
use asgbdt::data::{synthetic, CsrMatrix, Dataset};
use asgbdt::testkit::{self, BinnedFixture};
use asgbdt::tree::histogram::Histogram;
use asgbdt::tree::split::{best_split, best_split_for_feature, SplitConstraints};
use asgbdt::tree::{
    best_split_parallel, build_tree_feature_parallel, HistogramPool, HistogramStrategy, Node,
    Tree, TreeParams,
};
use asgbdt::util::{Executor, PoolMode, Rng};

/// Assert two trees are identical node by node, with enough context in
/// the failure message to localise the divergence.
fn assert_trees_identical(a: &Tree, b: &Tree, at: &str) {
    assert_eq!(a.nodes.len(), b.nodes.len(), "{at}: node count");
    for (ni, (na, nb)) in a.nodes.iter().zip(&b.nodes).enumerate() {
        match (na, nb) {
            (
                Node::Split { feature: fa, bin: ba, threshold: ta, left: la, right: ra },
                Node::Split { feature: fb, bin: bb, threshold: tb, left: lb, right: rb },
            ) => {
                assert_eq!(fa, fb, "{at} node {ni}: split feature");
                assert_eq!(ba, bb, "{at} node {ni}: split bin");
                assert_eq!(ta, tb, "{at} node {ni}: threshold (must be bit-equal)");
                assert_eq!((la, ra), (lb, rb), "{at} node {ni}: children");
            }
            (Node::Leaf { value: va }, Node::Leaf { value: vb }) => {
                // bit-identity, not tolerance: both pool modes must run the
                // exact same f64 reductions in the exact same order
                assert_eq!(va, vb, "{at} node {ni}: leaf value");
            }
            _ => panic!("{at} node {ni}: structure mismatch"),
        }
    }
}

fn build_with(fx: &BinnedFixture, params: &TreeParams, seed: u64, exec: &Executor) -> Tree {
    let mut pool = HistogramPool::new(fx.binned.total_bins());
    build_tree_feature_parallel(
        &fx.binned, &fx.rows, &fx.grad, &fx.hess, params, &mut Rng::new(seed), exec, &mut pool,
    )
}

/// Satellite: the full equivalence matrix —
/// `histogram=subtract|rebuild` × `pool=persistent|scoped` × 1/2/4/8
/// threads, on a sparse (real-sim-like) and a dense (higgs-like)
/// dataset. Within each (strategy, threads) cell the two pool modes must
/// grow the identical tree; shard boundaries and merge order depend only
/// on the thread count, so this is structural, and any regression
/// (a mode-dependent threshold, a scheduling-dependent merge) trips it.
#[test]
fn tree_building_is_bit_identical_across_pool_modes() {
    let datasets = [
        ("sparse", synthetic::realsim_like(700, 51)),
        ("dense", synthetic::higgs_like(500, 52)),
    ];
    for (kind, ds) in &datasets {
        let fx = testkit::logistic_fixture(ds, 32);
        for strategy in [HistogramStrategy::Subtract, HistogramStrategy::Rebuild] {
            let params = TreeParams {
                max_leaves: 16,
                feature_rate: 1.0,
                strategy,
                ..Default::default()
            };
            for threads in [1usize, 2, 4, 8] {
                let scoped = build_with(&fx, &params, 31, &Executor::scoped(threads));
                let persistent =
                    build_with(&fx, &params, 31, &Executor::new(PoolMode::Persistent, threads));
                let at = format!(
                    "{kind} histogram={} threads={threads}",
                    strategy.as_str()
                );
                assert_trees_identical(&scoped, &persistent, &at);
            }
        }
    }
}

/// Feature-subsampled trees share the same RNG stream in both modes, so
/// the matrix holds under `feature_rate < 1` too (the mask is drawn
/// before any parallel section runs).
#[test]
fn pool_modes_agree_under_feature_subsampling() {
    let ds = synthetic::realsim_like(400, 53);
    let fx = testkit::logistic_fixture(&ds, 16);
    let params = TreeParams {
        max_leaves: 12,
        feature_rate: 0.5,
        ..Default::default()
    };
    for threads in [2usize, 4] {
        let a = build_with(&fx, &params, 77, &Executor::scoped(threads));
        let b = build_with(&fx, &params, 77, &Executor::new(PoolMode::Persistent, threads));
        assert_trees_identical(&a, &b, &format!("feature_rate=0.5 threads={threads}"));
    }
}

/// Satellite: property test — `best_split_parallel` ≡ serial
/// [`best_split`] on histograms engineered to contain equal-gain ties.
/// Every generated feature column is duplicated (column 2k+1 is a copy
/// of column 2k), so the two columns bin identically and their best
/// splits tie at *exactly* equal f64 gain; the winner must be the lower
/// feature id no matter which work-stealing scanner saw it first.
#[test]
fn parallel_split_search_pins_lower_feature_tie_break() {
    let execs: Vec<Executor> = [2usize, 4, 8]
        .iter()
        .flat_map(|&t| [Executor::scoped(t), Executor::new(PoolMode::Persistent, t)])
        .collect();
    testkit::check("best_split_parallel ≡ best_split under ties", 32, 0xBEEF, |g| {
        let n_rows = 20 + g.usize_in(0, 180);
        // up to 24 duplicated pairs = 48 features: enough candidates to
        // engage the work-stealing path (≥ 2 chunks) in the larger cases
        let n_base = 2 + g.usize_in(0, 22);
        let mut mat: Vec<Vec<(u32, f32)>> = vec![Vec::new(); n_rows];
        for f in 0..n_base {
            for row in mat.iter_mut() {
                if g.rng.bernoulli(0.7) {
                    // few distinct values => well-populated bins => ties
                    // between non-duplicate features happen too
                    let v = 1.0 + g.rng.below(3) as f32;
                    row.push((2 * f as u32, v));
                    row.push((2 * f as u32 + 1, v));
                }
            }
        }
        let x = CsrMatrix::from_rows(2 * n_base, &mat).map_err(|e| e.to_string())?;
        let ds = Dataset::new("ties", x, g.labels(n_rows));
        let fx = testkit::logistic_fixture(&ds, 8);
        let mut hist = Histogram::zeros(fx.binned.total_bins());
        hist.build(&fx.binned, &fx.rows, &fx.grad, &fx.hess);
        let mask = vec![true; 2 * n_base];
        let cons = SplitConstraints::default();
        let serial = best_split(&hist, &fx.binned, &mask, &cons);
        for exec in &execs {
            let par = best_split_parallel(&hist, &fx.binned, &mask, &cons, exec);
            asgbdt::prop_assert!(
                par == serial,
                "parallel {:?} != serial {:?} (threads={} mode={:?})",
                par,
                serial,
                exec.threads(),
                exec.mode()
            );
        }
        if let Some(s) = serial {
            // the engineered tie must be real and broken downwards: the
            // winner is the even (lower) id of its duplicated pair, and
            // its odd twin scores exactly the same gain
            asgbdt::prop_assert!(
                s.feature % 2 == 0,
                "tie broke upwards: winner {} has a lower-id duplicate",
                s.feature
            );
            let twin =
                best_split_for_feature(&hist, &fx.binned, s.feature as usize + 1, &cons);
            asgbdt::prop_assert!(
                twin.map(|t| t.gain) == Some(s.gain),
                "duplicate column gain diverged: {:?} vs {}",
                twin.map(|t| t.gain),
                s.gain
            );
        }
        Ok(())
    });
}

/// The generated-fixture path exercises the whole engine end to end:
/// random sparse datasets from `Gen::binned_dataset`, every pool mode
/// agreeing with the single-thread serial build structurally.
#[test]
fn generated_datasets_build_identically_across_modes() {
    testkit::check("feature-parallel build matrix on generated data", 12, 0xFEED, |g| {
        let n_rows = 30 + g.usize_in(0, 170);
        let n_feat = 4 + g.usize_in(0, 28);
        let fx = g.binned_dataset(n_rows, n_feat, 0.6);
        let params = TreeParams {
            max_leaves: 8,
            feature_rate: 1.0,
            ..Default::default()
        };
        for threads in [2usize, 4] {
            let a = build_with(&fx, &params, 3, &Executor::scoped(threads));
            let b = build_with(&fx, &params, 3, &Executor::new(PoolMode::Persistent, threads));
            asgbdt::prop_assert!(
                a == b,
                "pool modes diverged at threads={threads} ({} rows)",
                fx.rows.len()
            );
        }
        Ok(())
    });
}

fn lifecycle_cfg(n_trees: usize) -> TrainConfig {
    let mut cfg = TrainConfig::default();
    cfg.n_trees = n_trees;
    cfg.step_length = 0.2;
    cfg.sampling_rate = 0.9;
    cfg.tree.max_leaves = 6;
    cfg.max_bins = 16;
    cfg.eval_every = 25;
    cfg.build_threads = 2;
    cfg.pool = PoolMode::Persistent;
    cfg
}

/// Satellite: worker-side pool lifecycle — each async worker's one
/// persistent executor serves every fork-join section of ≥100 trees
/// (dozens of dispatches per tree) without wedging, leaking, or
/// corrupting a build.
#[test]
fn worker_build_pool_survives_100_tree_async_run() {
    let ds = synthetic::realsim_like(500, 71);
    let mut cfg = lifecycle_cfg(100);
    cfg.workers = 2;
    let rep = train_async(&cfg, &ds, None).unwrap();
    assert_eq!(rep.trees_accepted, 100);
    assert_eq!(rep.forest.n_trees(), 100);
    let first = rep.curve.points.first().unwrap().train_loss;
    let last = rep.curve.points.last().unwrap().train_loss;
    assert!(last < first, "loss did not descend: {first} -> {last}");
}

/// The sync trainer is deterministic, so its persistent and scoped twins
/// must match bit for bit over a long run — trainer-level proof that a
/// build pool reused across 120 trees never drifts from per-call spawns.
#[test]
fn sync_trainer_pool_modes_identical_over_long_run() {
    let ds = synthetic::realsim_like(400, 72);
    let mut cfg = lifecycle_cfg(120);
    cfg.mode = asgbdt::config::TrainMode::Sync;
    // sync's fork-join width is its worker count; build_threads>1 with
    // mode=sync is a validate()-rejected pair
    cfg.build_threads = 1;
    cfg.workers = 3;
    let mut cfg_scoped = cfg.clone();
    cfg_scoped.pool = PoolMode::Scoped;
    let a = train_sync(&cfg, &ds, None).unwrap();
    let b = train_sync(&cfg_scoped, &ds, None).unwrap();
    assert_eq!(a.trees_accepted, 120);
    let la: Vec<f64> = a.curve.points.iter().map(|p| p.train_loss).collect();
    let lb: Vec<f64> = b.curve.points.iter().map(|p| p.train_loss).collect();
    assert_eq!(la, lb, "persistent and scoped sync runs diverged");
    for (ti, ((va, ta), (vb, tb))) in a.forest.trees.iter().zip(&b.forest.trees).enumerate() {
        assert_eq!(va, vb, "sync tree {ti}: step length");
        assert_trees_identical(ta, tb, &format!("sync tree {ti}"));
    }
}
