//! Property-based tests (via the crate's `testkit`; proptest is offline-
//! unavailable) over the coordinator's core invariants: sampling
//! unbiasedness, histogram algebra, tree structure, loss math, routing
//! and serialization.

use asgbdt::data::{synthetic, BinnedDataset, CsrMatrix, Dataset};
use asgbdt::forest::{FlatForest, Forest, ScratchPool};
use asgbdt::io::Json;
use asgbdt::loss::logistic;
use asgbdt::prop_assert;
use asgbdt::sampling::{BernoulliSampler, SampleKey};
use asgbdt::testkit::{check, close, Gen};
use asgbdt::tree::histogram::Histogram;
use asgbdt::tree::{build_tree, FlatTree, TreeParams};
use asgbdt::util::{Backoff, Executor, PoolMode, Rng};

fn random_dataset(g: &mut Gen) -> Dataset {
    let n = 20 + g.usize_in(0, 300);
    let d = 2 + g.usize_in(0, 40);
    let rows: Vec<Vec<(u32, f32)>> = (0..n)
        .map(|_| {
            let mut cols: Vec<u32> = (0..d as u32)
                .filter(|_| g.rng.bernoulli(0.3))
                .collect();
            cols.dedup();
            cols.iter()
                .map(|&c| (c, (g.rng.normal() as f32 * 2.0)))
                .filter(|&(_, v)| v != 0.0)
                .collect()
        })
        .collect();
    let x = CsrMatrix::from_rows(d, &rows).unwrap();
    let y = g.labels(n);
    Dataset::new("prop", x, y)
}

#[test]
fn prop_sampling_weights_unbiased_and_supported() {
    check("sampling_unbiased", 20, 101, |g| {
        let ds = random_dataset(g);
        let rate = g.f64_in(0.05, 1.0);
        let sampler = BernoulliSampler::uniform(&ds, rate);
        let seed = g.rng.next_u64();
        let draws = 300;
        let mut sums = vec![0.0f64; ds.n_rows()];
        for v in 0..draws {
            let p = sampler.draw(SampleKey { seed, version: v as u64 });
            // support/weight consistency every draw
            for (i, &w) in p.weights.iter().enumerate() {
                let in_rows = p.rows.binary_search(&(i as u32)).is_ok();
                prop_assert!((w > 0.0) == in_rows, "support mismatch at {i}");
            }
            for i in 0..ds.n_rows() {
                sums[i] += p.weights[i] as f64;
            }
        }
        // E[m'] = m = 1, checked on the average across rows
        let mean: f64 =
            sums.iter().map(|s| s / draws as f64).sum::<f64>() / ds.n_rows() as f64;
        close(mean, 1.0, 0.15).map_err(|e| format!("unbiasedness: {e}"))
    });
}

/// Satellite of the fused accept pipeline: the counter-based sampler
/// must draw the **identical** row set and weights no matter how its
/// rows are sharded — 1, 2 and 8 contiguous shards, across random
/// seeds, versions, rates and dataset sizes.
#[test]
fn prop_keyed_sampling_is_shard_invariant() {
    check("sampling_shard_invariant", 25, 111, |g| {
        let ds = random_dataset(g);
        let n = ds.n_rows();
        let rate = g.f64_in(0.01, 1.0);
        let sampler = BernoulliSampler::uniform(&ds, rate);
        let key = SampleKey {
            seed: g.rng.next_u64(),
            version: g.rng.below(1000),
        };
        let full = sampler.draw(key);
        prop_assert!(
            full.rows.windows(2).all(|w| w[0] < w[1]),
            "rows not ascending"
        );
        for n_shards in [1usize, 2, 8] {
            let mut weights = vec![0.0f32; n];
            let mut rows = Vec::new();
            // deliberately uneven, non-aligned shard boundaries
            let per = n.div_ceil(n_shards);
            let mut lo = 0usize;
            while lo < n {
                let hi = (lo + per).min(n);
                sampler.draw_range(key, lo, hi, &mut weights[lo..hi], &mut rows);
                lo = hi;
            }
            prop_assert!(weights == full.weights, "weights differ at {n_shards} shards");
            prop_assert!(rows == full.rows, "rows differ at {n_shards} shards");
        }
        // replaying the key is a no-op change; a different version is not
        let replay = sampler.draw(key);
        prop_assert!(replay.rows == full.rows, "replay diverged");
        Ok(())
    });
}

#[test]
fn prop_histogram_totals_equal_sum_of_rows() {
    check("hist_totals", 25, 102, |g| {
        let ds = random_dataset(g);
        let b = BinnedDataset::from_dataset(&ds, 16).unwrap();
        let grad = g.vec_normal(ds.n_rows(), 2.0);
        let hess = g.weights(ds.n_rows());
        let k = g.usize_in(1, ds.n_rows());
        let rows: Vec<u32> = g
            .rng
            .sample_indices(ds.n_rows(), k)
            .into_iter()
            .map(|i| i as u32)
            .collect();
        let mut h = Histogram::zeros(b.total_bins());
        h.build(&b, &rows, &grad, &hess);
        let gsum: f64 = rows.iter().map(|&r| grad[r as usize] as f64).sum();
        prop_assert!(h.totals.count == rows.len() as u64, "count mismatch");
        close(h.totals.grad, gsum, 1e-6).map_err(|e| format!("grad sum: {e}"))?;
        // per-feature: explicit + zero stats == totals
        for f in 0..b.n_features {
            let ex = h.feature_explicit_stats(&b, f);
            let z = h.feature_zero_stats(&b, f);
            prop_assert!(
                ex.count + z.count == h.totals.count,
                "feature {f} partition broken"
            );
        }
        Ok(())
    });
}

#[test]
fn prop_histogram_subtraction_associates() {
    check("hist_subtract", 20, 103, |g| {
        let ds = random_dataset(g);
        let b = BinnedDataset::from_dataset(&ds, 16).unwrap();
        let grad = g.vec_normal(ds.n_rows(), 1.0);
        let hess = g.weights(ds.n_rows());
        let all: Vec<u32> = (0..ds.n_rows() as u32).collect();
        let cut = 1 + g.usize_in(0, ds.n_rows() - 1);
        let (left, right) = all.split_at(cut);
        let mut hp = Histogram::zeros(b.total_bins());
        hp.build(&b, &all, &grad, &hess);
        let mut hl = Histogram::zeros(b.total_bins());
        hl.build(&b, left, &grad, &hess);
        let mut hr_direct = Histogram::zeros(b.total_bins());
        hr_direct.build(&b, right, &grad, &hess);
        let mut hr_sub = Histogram::zeros(b.total_bins());
        hr_sub.subtract_from(&hp, &hl);
        for i in 0..b.total_bins() {
            close(hr_sub.grad[i], hr_direct.grad[i], 1e-6)
                .map_err(|e| format!("slot {i}: {e}"))?;
            prop_assert!(hr_sub.count[i] == hr_direct.count[i], "count slot {i}");
        }
        Ok(())
    });
}

#[test]
fn prop_trees_are_valid_and_bounded() {
    check("tree_structure", 20, 104, |g| {
        let ds = random_dataset(g);
        let b = BinnedDataset::from_dataset(&ds, 16).unwrap();
        let f0 = vec![0.0f32; ds.n_rows()];
        let w: Vec<f32> = (0..ds.n_rows()).map(|_| 1.0).collect();
        let gh = logistic::grad_hess_loss(&f0, &ds.y, &w);
        let max_leaves = 1 + g.usize_in(1, 32);
        let params = TreeParams {
            max_leaves,
            feature_rate: g.f64_in(0.2, 1.0),
            ..Default::default()
        };
        let rows: Vec<u32> = (0..ds.n_rows() as u32).collect();
        let tree = build_tree(&b, &rows, &gh.grad, &gh.hess, &params, &mut g.rng.fork(2));
        tree.validate().map_err(|e| e.to_string())?;
        prop_assert!(tree.n_leaves() <= max_leaves.max(1), "leaf cap broken");
        // leaf values bounded by max |g|/lambda-ish: |v| <= max|g| * n
        let max_g = gh.grad.iter().fold(0.0f32, |a, &x| a.max(x.abs()));
        prop_assert!(
            tree.max_abs_leaf() <= max_g * ds.n_rows() as f32 + 1.0,
            "insane leaf value"
        );
        // binned and raw prediction agree on training rows
        for r in 0..ds.n_rows() {
            let pb = tree.predict_binned(&b, r);
            let pr = tree.predict_raw(&ds.x, r);
            prop_assert!(pb == pr, "row {r}: binned {pb} != raw {pr}");
        }
        Ok(())
    });
}

#[test]
fn prop_grad_is_zero_exactly_at_optimum() {
    check("grad_zero_at_opt", 30, 105, |g| {
        // for y in {0,1} and p = sigmoid(2F): grad = 0 iff p == y, which
        // cannot happen at finite F — but grad must always point towards
        // the label: sign(g) == sign(p - y)
        let n = 16 * (1 + g.usize_in(0, 16));
        let f = g.vec_normal(n, 5.0);
        let y = g.labels(n);
        let w: Vec<f32> = (0..n).map(|_| 1.0).collect();
        let gh = logistic::grad_hess_loss(&f, &y, &w);
        for i in 0..n {
            let p = logistic::prob(f[i]);
            prop_assert!(
                (gh.grad[i] >= 0.0) == (p >= y[i]),
                "sign mismatch at {i}: g={} p={} y={}",
                gh.grad[i],
                p,
                y[i]
            );
            prop_assert!(gh.hess[i] >= 0.0, "negative hessian at {i}");
        }
        Ok(())
    });
}

#[test]
fn prop_forest_prediction_is_additive() {
    check("forest_additive", 15, 106, |g| {
        let ds = random_dataset(g);
        let b = BinnedDataset::from_dataset(&ds, 16).unwrap();
        let f0 = vec![0.0f32; ds.n_rows()];
        let w = vec![1.0f32; ds.n_rows()];
        let gh = logistic::grad_hess_loss(&f0, &ds.y, &w);
        let rows: Vec<u32> = (0..ds.n_rows() as u32).collect();
        let params = TreeParams {
            max_leaves: 8,
            feature_rate: 1.0,
            ..Default::default()
        };
        let mut forest = Forest::new(g.f64_in(-1.0, 1.0) as f32);
        let mut rng = g.rng.fork(3);
        let v = g.f64_in(0.01, 0.5) as f32;
        for _ in 0..3 {
            forest.push(v, build_tree(&b, &rows, &gh.grad, &gh.hess, &params, &mut rng));
        }
        for r in 0..ds.n_rows().min(20) {
            let direct = forest.predict_raw(&ds.x, r);
            let manual: f32 = forest.base_score
                + forest
                    .trees
                    .iter()
                    .map(|(vv, t)| vv * t.predict_raw(&ds.x, r))
                    .sum::<f32>();
            close(direct as f64, manual as f64, 1e-5)
                .map_err(|e| format!("row {r}: {e}"))?;
        }
        Ok(())
    });
}

#[test]
fn prop_json_roundtrips_arbitrary_forests() {
    check("forest_json", 15, 107, |g| {
        let ds = random_dataset(g);
        let b = BinnedDataset::from_dataset(&ds, 16).unwrap();
        let f0 = vec![0.0f32; ds.n_rows()];
        let w = vec![1.0f32; ds.n_rows()];
        let gh = logistic::grad_hess_loss(&f0, &ds.y, &w);
        let rows: Vec<u32> = (0..ds.n_rows() as u32).collect();
        let params = TreeParams {
            max_leaves: 1 + g.usize_in(1, 16),
            feature_rate: 1.0,
            ..Default::default()
        };
        let mut forest = Forest::new(0.5);
        forest.push(
            0.1,
            build_tree(&b, &rows, &gh.grad, &gh.hess, &params, &mut g.rng.fork(4)),
        );
        let text = forest.to_json().to_string();
        let parsed = Json::parse(&text).map_err(|e| e.to_string())?;
        let back = Forest::from_json(&parsed).map_err(|e| e.to_string())?;
        for r in 0..ds.n_rows().min(10) {
            prop_assert!(
                forest.predict_raw(&ds.x, r) == back.predict_raw(&ds.x, r),
                "prediction changed after roundtrip"
            );
        }
        Ok(())
    });
}

/// A fully dense dataset (every cell nonzero) — the partition pass's
/// worst case for CSR lookups, and the layout where blocked scoring and
/// per-row scoring disagree first if anything is off.
fn random_dense_dataset(g: &mut Gen) -> Dataset {
    let n = 10 + g.usize_in(0, 200);
    let d = 2 + g.usize_in(0, 12);
    let data: Vec<f32> = (0..n * d)
        .map(|_| {
            let v = g.rng.normal() as f32 * 3.0;
            if v == 0.0 {
                1.0
            } else {
                v
            }
        })
        .collect();
    let x = CsrMatrix::from_dense(n, d, &data).unwrap();
    let y = g.labels(n);
    Dataset::new("dense", x, y)
}

/// Boost a few trees so the forest has real structure (varied depths,
/// sparse and dense splits, per-tree feature subsets).
fn random_forest(g: &mut Gen, ds: &Dataset, b: &BinnedDataset) -> Forest {
    let w = vec![1.0f32; ds.n_rows()];
    let mut f = vec![0.0f32; ds.n_rows()];
    let mut forest = Forest::new(g.f64_in(-0.5, 0.5) as f32);
    let rows: Vec<u32> = (0..ds.n_rows() as u32).collect();
    let n_trees = 1 + g.usize_in(0, 4);
    let v = g.f64_in(0.05, 0.5) as f32;
    for k in 0..n_trees {
        let params = TreeParams {
            max_leaves: 2 + g.usize_in(0, 24),
            feature_rate: g.f64_in(0.3, 1.0),
            ..Default::default()
        };
        let gh = logistic::grad_hess_loss(&f, &ds.y, &w);
        let t = build_tree(b, &rows, &gh.grad, &gh.hess, &params, &mut g.rng.fork(40 + k as u64));
        for r in 0..ds.n_rows() {
            f[r] += v * t.predict_binned(b, r);
        }
        forest.push(v, t);
    }
    forest
}

/// The scoring-engine equivalence property (PR 2 acceptance bar): the
/// blocked SoA frontier pass is **bit-identical** to the per-row enum
/// walk — for every tree, every forest, raw and binned, at every thread
/// count, on sparse and dense data.
#[test]
fn prop_flat_blocked_scoring_bit_identical_to_per_row() {
    check("flat_scoring", 12, 110, |g| {
        let dense = g.rng.bernoulli(0.5);
        let ds = if dense {
            random_dense_dataset(g)
        } else {
            random_dataset(g)
        };
        let b = BinnedDataset::from_dataset(&ds, 4 + g.usize_in(0, 28)).unwrap();
        let forest = random_forest(g, &ds, &b);
        let flat = FlatForest::from_forest(&forest);
        let mut pool = ScratchPool::new();
        // single-tree walks: flat SoA vs enum, per row
        for (_, t) in &forest.trees {
            let ft = FlatTree::from_tree(t);
            for r in 0..ds.n_rows() {
                prop_assert!(
                    ft.predict_binned(&b, r) == t.predict_binned(&b, r),
                    "tree walk (binned) differs at row {r}"
                );
                prop_assert!(
                    ft.predict_raw(&ds.x, r) == t.predict_raw(&ds.x, r),
                    "tree walk (raw) differs at row {r}"
                );
            }
        }
        // whole-forest blocked scoring vs the per-row reference, both
        // traversal spaces, across thread counts and executor modes
        let ref_raw = forest.predict_all_per_row(&ds.x);
        let ref_binned = forest.predict_all_binned_per_row(&b);
        for mode in [PoolMode::Persistent, PoolMode::Scoped] {
            for threads in [1usize, 2, 4] {
                let exec = Executor::new(mode, threads);
                let raw = flat.predict_all_raw(&ds.x, &exec, &mut pool);
                let binned = flat.predict_all_binned(&b, &exec, &mut pool);
                prop_assert!(
                    raw == ref_raw,
                    "raw margins differ (dense={dense}, threads={threads}, {mode:?})"
                );
                prop_assert!(
                    binned == ref_binned,
                    "binned margins differ (dense={dense}, threads={threads}, {mode:?})"
                );
            }
        }
        // routed entry points stay on the same bits
        prop_assert!(
            forest.predict_all(&ds.x) == ref_raw,
            "predict_all diverged from reference"
        );
        Ok(())
    });
}

#[test]
fn prop_binning_preserves_order() {
    check("binning_order", 25, 108, |g| {
        let n = 5 + g.usize_in(0, 200);
        let vals: Vec<f32> = (0..n).map(|_| g.rng.normal() as f32 * 10.0).collect();
        let mapper =
            asgbdt::data::binning::BinMapper::from_values(vals.clone(), 4 + g.usize_in(0, 60));
        let mut rng = Rng::new(g.rng.next_u64());
        for _ in 0..50 {
            let a = vals[rng.below(n as u64) as usize];
            let c = vals[rng.below(n as u64) as usize];
            if a <= c {
                prop_assert!(
                    mapper.bin_of(a) <= mapper.bin_of(c),
                    "order broken: {a} -> {}, {c} -> {}",
                    mapper.bin_of(a),
                    mapper.bin_of(c)
                );
            }
        }
        Ok(())
    });
}

/// Satellite of the serving layer: request-time binning on extracted
/// cuts ([`asgbdt::data::BinCuts`]) must reproduce training-time
/// binning of the same matrix exactly — pattern, bin ids, offsets —
/// for sparse and dense matrices alike, and row-at-a-time `bin_row`
/// must agree with the whole-matrix `bin_batch`.
#[test]
fn prop_request_time_binning_matches_training_binning() {
    check("bin_batch_matches_training", 25, 131, |g| {
        let max_bins = 4 + g.usize_in(0, 60);
        // sparse: the random CSR the other properties use
        let sparse = random_dataset(g).x;
        // dense: every cell populated (from_dense drops exact zeros,
        // which normal() draws with probability ~0)
        let dn = 5 + g.usize_in(0, 40);
        let dd = 2 + g.usize_in(0, 10);
        let cells: Vec<f32> = (0..dn * dd)
            .map(|_| g.rng.normal() as f32 * 2.0)
            .collect();
        let dense = CsrMatrix::from_dense(dn, dd, &cells).unwrap();
        for (kind, x) in [("sparse", &sparse), ("dense", &dense)] {
            let trained =
                BinnedDataset::from_csr(x, max_bins).map_err(|e| format!("{kind}: {e}"))?;
            let cuts = trained.cuts();
            let served = cuts.bin_batch(x).map_err(|e| format!("{kind}: {e}"))?;
            prop_assert!(served.indptr == trained.indptr, "{kind}: indptr diverged");
            prop_assert!(served.feat_ids == trained.feat_ids, "{kind}: pattern diverged");
            prop_assert!(served.bins == trained.bins, "{kind}: bin ids diverged");
            prop_assert!(served.offsets == trained.offsets, "{kind}: offsets diverged");
            prop_assert!(served.n_rows == trained.n_rows, "{kind}: row count diverged");
            // row-at-a-time must agree with the batch, including the
            // implicit-zero resolution of bin_of
            let (mut feats, mut bins) = (Vec::new(), Vec::new());
            for r in 0..x.n_rows() {
                let row: Vec<(u32, f32)> = x.row(r).collect();
                feats.clear();
                bins.clear();
                cuts.bin_row(&row, &mut feats, &mut bins)
                    .map_err(|e| format!("{kind}: {e}"))?;
                let lo = trained.indptr[r];
                let hi = trained.indptr[r + 1];
                prop_assert!(
                    feats[..] == trained.feat_ids[lo..hi] && bins[..] == trained.bins[lo..hi],
                    "{kind}: bin_row diverged at row {r}"
                );
                for f in 0..x.n_cols() as u32 {
                    prop_assert!(
                        served.bin_of(r, f) == trained.bin_of(r, f),
                        "{kind}: bin_of diverged at ({r}, {f})"
                    );
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_dataset_split_preserves_rows() {
    check("split_preserves", 20, 109, |g| {
        let ds = random_dataset(g);
        let frac = g.f64_in(0.05, 0.9);
        let mut rng = g.rng.fork(5);
        let (tr, te) = ds.split(frac, &mut rng);
        prop_assert!(
            tr.n_rows() + te.n_rows() == ds.n_rows(),
            "row count changed"
        );
        prop_assert!(
            tr.n_features() == ds.n_features() && te.n_features() == ds.n_features(),
            "feature count changed"
        );
        Ok(())
    });
}

/// The worker idle-backoff schedule is a pure function of the round;
/// pin its wrap/cap edge cases: monotone non-decreasing everywhere,
/// capped at the documented maximum, and total — no round (including
/// `u32::MAX` and the values straddling every internal boundary) may
/// panic or overflow.
#[test]
fn prop_backoff_schedule_wrap_and_cap_edges() {
    check("backoff_schedule", 20, 112, |g| {
        // random probe points plus the adversarial boundary rounds
        let mut rounds: Vec<u32> = (0..200).map(|_| g.rng.next_u64() as u32).collect();
        rounds.extend([
            0,
            1,
            u32::MAX,
            u32::MAX - 1,
            62,
            63,
            64,
            65,
            u32::MAX / 2,
        ]);
        let cap = Backoff::pause_after(u32::MAX).expect("huge rounds must sleep");
        for &r in &rounds {
            let d = Backoff::pause_after(r);
            if let Some(d) = d {
                prop_assert!(d <= cap, "round {r} exceeds cap: {d:?} > {cap:?}");
                prop_assert!(d.as_micros() > 0, "round {r} sleeps for zero");
            }
            // monotone non-decreasing into the saturating region
            if r < u32::MAX {
                let next = Backoff::pause_after(r + 1);
                match (d, next) {
                    (Some(a), Some(b)) => {
                        prop_assert!(b >= a, "schedule decreased at round {r}")
                    }
                    (Some(_), None) => {
                        return Err(format!("sleep regressed to yield at round {r}"))
                    }
                    _ => {}
                }
            }
        }
        // the saturating tail is flat at the cap
        prop_assert!(
            Backoff::pause_after(1_000) == Some(cap) && Backoff::pause_after(100_000) == Some(cap),
            "tail not flat at cap"
        );
        // a fresh (or reset) backoff starts in the yield phase
        prop_assert!(Backoff::pause_after(0).is_none(), "round 0 must yield");
        Ok(())
    });
}

/// Sparse-aggregation equivalence (the sharded-PS satellite): the
/// union-of-touched-bins merge across row × feature shards must equal
/// the dense whole-matrix `Histogram::build` bin for bin. The fixture's
/// margin-0 logistic targets are dyadic (grad ±1.0, hess 1.0), so every
/// f64 partial sum is exact and bit-equality is well-defined at any
/// grouping of the summands.
#[test]
fn prop_sparse_shard_aggregation_equals_dense_build() {
    use asgbdt::ps::{aggregate_sharded, FeaturePartition, LocalTransport, RowPartition};

    check("sparse_shard_agg", 8, 113, |g| {
        let n = 600 + g.usize_in(0, 2_500);
        let d = 3 + g.usize_in(0, 24);
        let fx = g.binned_dataset(n, d, g.f64_in(0.0, 0.9));
        let b = &fx.binned;
        // ascending build subset — some rows sampled out, like a server pass
        let rows: Vec<u32> = (0..n as u32).filter(|_| g.rng.bernoulli(0.7)).collect();
        let mut dense = Histogram::zeros(b.total_bins());
        dense.build(b, &rows, &fx.grad, &fx.hess);
        let exec = Executor::scoped(2);
        for row_shards in [1usize, 3] {
            for feat_shards in [1usize, 2, 5] {
                let rowp = RowPartition::new(n, row_shards);
                let featp = FeaturePartition::new(b, feat_shards);
                let transport = LocalTransport::new(featp.n_shards());
                let got = aggregate_sharded(
                    b, &rows, &fx.grad, &fx.hess, &rowp, &featp, &transport, &exec, 0,
                );
                let at = format!("{row_shards}x{feat_shards} shards");
                prop_assert!(got.totals == dense.totals, "totals diverged ({at})");
                for slot in 0..b.total_bins() {
                    prop_assert!(
                        got.grad[slot] == dense.grad[slot]
                            && got.hess[slot] == dense.hess[slot]
                            && got.count[slot] == dense.count[slot],
                        "slot {slot} diverged ({at})"
                    );
                }
                // union of touched slots matches the dense touched set
                let mut gt = got.touched.clone();
                let mut dt = dense.touched.clone();
                gt.sort_unstable();
                dt.sort_unstable();
                prop_assert!(gt == dt, "touched-set union diverged ({at})");
                // sparse budget: each source's rows are a subset of the
                // dense build's, so every shipped slot is dense-touched —
                // cross-shard traffic never exceeds shards × touched bins
                let cap = (rowp.n_shards() * dense.touched.len() * 24) as u64;
                prop_assert!(
                    transport.bytes_sent() <= cap,
                    "traffic {} exceeds sparse budget {cap} ({at})",
                    transport.bytes_sent()
                );
            }
        }
        Ok(())
    });
}

/// The row partition is a pure function of (row count, shard ask): a
/// contiguous, exact, `ROW_BLOCK`-aligned cover whose boundaries depend
/// on nothing else — the shard-invariance half of the sharded-PS
/// bit-identity argument.
#[test]
fn prop_row_partition_is_a_pure_block_aligned_cover() {
    use asgbdt::forest::score::ROW_BLOCK;
    use asgbdt::ps::RowPartition;

    check("row_partition", 40, 114, |g| {
        let n = 1 + g.usize_in(0, 20_000);
        let s = 1 + g.usize_in(0, 12);
        let part = RowPartition::new(n, s);
        prop_assert!(
            part == RowPartition::new(n, s),
            "not a pure function of (n={n}, shards={s})"
        );
        prop_assert!(part.n_rows() == n, "row count changed");
        prop_assert!(
            part.n_shards() >= 1 && part.n_shards() <= s,
            "shard count {} outside [1, {s}]",
            part.n_shards()
        );
        // contiguous exact cover with no empty shard
        let mut covered = 0usize;
        for shard in 0..part.n_shards() {
            let r = part.range(shard);
            prop_assert!(r.start == covered, "gap/overlap at shard {shard}");
            prop_assert!(r.end > r.start, "empty shard {shard}");
            covered = r.end;
        }
        prop_assert!(covered == n, "cover incomplete: {covered} != {n}");
        // interior boundaries sit on whole ROW_BLOCKs (the carving rule
        // the fused accept pass and the eval fold both rely on)
        for &bnd in &part.boundaries()[1..part.n_shards()] {
            prop_assert!(bnd % ROW_BLOCK == 0, "boundary {bnd} not block-aligned");
        }
        // shard_of_row inverts range()
        for _ in 0..50 {
            let row = g.rng.below(n as u64) as usize;
            let owner = part.shard_of_row(row);
            prop_assert!(
                part.range(owner).contains(&row),
                "shard_of_row({row}) -> {owner} does not own it"
            );
        }
        Ok(())
    });
}

/// Board::version() must be monotone non-decreasing from every reader's
/// point of view while a publisher races it, and can never lag a
/// snapshot the same reader already pulled — the PR 3 regression
/// (version stored after the snapshot swap) as a property over many
/// interleavings.
#[test]
fn prop_board_version_monotone_under_concurrent_publishes() {
    use asgbdt::ps::{Board, TargetSnapshot};
    use std::sync::Arc;

    for trial in 0..3u64 {
        let board = Arc::new(Board::new());
        let publishes = 1_500u64;
        std::thread::scope(|s| {
            let readers: Vec<_> = (0..4)
                .map(|_| {
                    let b = board.clone();
                    s.spawn(move || {
                        let mut last_seen = 0u64;
                        while !b.is_shutdown() {
                            let snap = b.pull();
                            let v = b.version();
                            assert!(
                                v >= snap.version,
                                "version() {v} lagged pulled snapshot {}",
                                snap.version
                            );
                            assert!(
                                snap.version >= last_seen,
                                "pulled versions went backwards: {} after {last_seen}",
                                snap.version
                            );
                            last_seen = last_seen.max(v);
                        }
                        last_seen
                    })
                })
                .collect();
            for v in 1..=publishes {
                board.publish(TargetSnapshot {
                    version: v,
                    grad: Arc::new(vec![0.0; 2]),
                    hess: Arc::new(vec![0.0; 2]),
                    rows: Arc::new(vec![0]),
                });
            }
            board.request_shutdown();
            for r in readers {
                let last = r.join().unwrap();
                assert!(last <= publishes, "reader saw unpublished version {last}");
            }
        });
        assert_eq!(board.version(), publishes, "trial {trial}");
    }
}
