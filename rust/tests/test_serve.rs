//! Serving-layer integration tests: hot-swap version tagging and
//! bit-identity of served margins against direct forest scoring, the
//! batch/thread/pool equivalence sweep, shutdown draining, and request
//! validation (DESIGN.md §15).

use std::sync::mpsc::channel;
use std::sync::Arc;
use std::time::Duration;

use asgbdt::data::{synthetic, BinCuts, BinnedDataset, CsrMatrix, Dataset};
use asgbdt::forest::{FlatForest, Forest, ScratchPool};
use asgbdt::loss::logistic;
use asgbdt::serve::{drive_replay, ModelSlot, ServeOptions, Service};
use asgbdt::tree::{build_tree, TreeParams};
use asgbdt::util::{Executor, PoolMode, Rng};

fn boosted(ds: &Dataset, b: &BinnedDataset, n_trees: usize, seed: u64) -> Forest {
    let w = vec![1.0f32; ds.n_rows()];
    let mut f = vec![0.0f32; ds.n_rows()];
    let mut forest = Forest::new(0.3);
    let rows: Vec<u32> = (0..ds.n_rows() as u32).collect();
    let params = TreeParams {
        max_leaves: 12,
        feature_rate: 0.9,
        ..Default::default()
    };
    let mut rng = Rng::new(seed);
    for _ in 0..n_trees {
        let gh = logistic::grad_hess_loss(&f, &ds.y, &w);
        let t = build_tree(b, &rows, &gh.grad, &gh.hess, &params, &mut rng);
        for r in 0..ds.n_rows() {
            f[r] += 0.2 * t.predict_binned(b, r);
        }
        forest.push(0.2, t);
    }
    forest
}

/// Expected margin per source row under a forest, computed the
/// reference way: rebin the whole matrix on the serving cuts, score it
/// in one call. The service scores micro-batched subsets of these rows;
/// per-row margins are base + per-tree adds in push order, independent
/// of batch composition, so bit-equality is the requirement, not an
/// approximation.
fn reference_margins(flat: &FlatForest, cuts: &BinCuts, x: &CsrMatrix) -> Vec<f32> {
    let batch = cuts.bin_batch(x).unwrap();
    let exec = Executor::scoped(1);
    let mut pool = ScratchPool::new();
    flat.predict_all_binned(&batch, &exec, &mut pool)
}

fn opts(batch: usize, threads: usize, pool: PoolMode) -> ServeOptions {
    ServeOptions {
        batch,
        max_wait: Duration::from_micros(500),
        threads,
        pool,
    }
}

#[test]
fn hot_swap_mid_stream_tags_versions_and_stays_bit_identical() {
    let ds = synthetic::realsim_like(900, 31);
    let b = BinnedDataset::from_dataset(&ds, 32).unwrap();
    let cuts = b.cuts();
    // two genuinely different forests, so a wrongly-tagged or
    // mixed-version response cannot produce the right margin by luck
    let flat_a = FlatForest::from_forest(&boosted(&ds, &b, 5, 1));
    let flat_b = FlatForest::from_forest(&boosted(&ds, &b, 9, 2));
    let exp_a = reference_margins(&flat_a, &cuts, &ds.x);
    let exp_b = reference_margins(&flat_b, &cuts, &ds.x);

    let slot = Arc::new(ModelSlot::new(flat_a, cuts.clone()));
    let service = Service::start(Arc::clone(&slot), opts(16, 2, PoolMode::Persistent));
    let n = 600;
    let swap_at = 300;
    let inflight = 32;
    let outcome = drive_replay(
        &service,
        &ds.x,
        n,
        inflight,
        Some((swap_at, flat_b, cuts.clone())),
    )
    .unwrap();
    let stats = service.shutdown();
    assert_eq!(stats.requests as usize, n);
    assert_eq!(stats.swaps_seen, 1);

    // every response must be bit-identical to scoring its row on the
    // forest its version tag names — no response mixes two versions
    for id in 0..n {
        let row = id % ds.n_rows();
        let expected = match outcome.version_of[id] {
            1 => exp_a[row],
            2 => exp_b[row],
            v => panic!("request {id} tagged unknown version {v}"),
        };
        assert_eq!(
            outcome.margin_of[id].to_bits(),
            expected.to_bits(),
            "request {id} (version {})",
            outcome.version_of[id]
        );
    }
    // the publish lands before request `swap_at` is submitted: by then
    // all but `inflight` earlier requests were already answered under
    // version 1, and everything submitted after must be tagged 2
    let before = &outcome.version_of[..swap_at];
    let v1_before = before.iter().filter(|&&v| v == 1).count();
    assert!(v1_before >= swap_at - inflight);
    assert!(outcome.version_of[swap_at..].iter().all(|&v| v == 2));
    // FIFO queue + per-batch versioning: tags are monotone in id order
    assert!(outcome.version_of.windows(2).all(|w| w[0] <= w[1]));
}

#[test]
fn served_margins_bit_identical_across_batch_thread_pool_sweep() {
    let ds = synthetic::realsim_like(500, 33);
    let b = BinnedDataset::from_dataset(&ds, 32).unwrap();
    let cuts = b.cuts();
    let flat = FlatForest::from_forest(&boosted(&ds, &b, 3, 9));
    let expected = reference_margins(&flat, &cuts, &ds.x);
    let n = 120;
    for batch in [1usize, 7, 64] {
        for threads in [1usize, 2] {
            for pool in [PoolMode::Persistent, PoolMode::Scoped] {
                let slot = Arc::new(ModelSlot::new(flat.clone(), cuts.clone()));
                let service = Service::start(Arc::clone(&slot), opts(batch, threads, pool));
                let outcome = drive_replay(&service, &ds.x, n, 16, None).unwrap();
                let stats = service.shutdown();
                assert_eq!(stats.requests as usize, n);
                assert_eq!(stats.swaps_seen, 0);
                for id in 0..n {
                    assert_eq!(outcome.version_of[id], 1);
                    assert_eq!(
                        outcome.margin_of[id].to_bits(),
                        expected[id % ds.n_rows()].to_bits(),
                        "batch={batch} threads={threads} pool={pool:?} id={id}"
                    );
                }
            }
        }
    }
}

#[test]
fn empty_and_overwide_rows_score_like_their_binned_equivalents() {
    let ds = synthetic::realsim_like(400, 35);
    let b = BinnedDataset::from_dataset(&ds, 32).unwrap();
    let cuts = b.cuts();
    let flat = FlatForest::from_forest(&boosted(&ds, &b, 4, 4));
    let width = ds.n_features() as u32;
    // reference: the all-implicit-zero row and a real row, binned directly
    let empty_then_row0 = CsrMatrix::from_rows(
        ds.n_features(),
        &[Vec::new(), ds.x.row(0).collect::<Vec<(u32, f32)>>()],
    )
    .unwrap();
    let expected = reference_margins(&flat, &cuts, &empty_then_row0);

    let slot = Arc::new(ModelSlot::new(flat, cuts));
    let service = Service::start(Arc::clone(&slot), opts(4, 1, PoolMode::Scoped));
    let (tx, rx) = channel();
    // an empty feature vector, and row 0 with a trailing feature id the
    // model was never trained on (legal: dropped at binning time)
    service.submit(0, Vec::new(), &tx).unwrap();
    let mut overwide: Vec<(u32, f32)> = ds.x.row(0).collect();
    overwide.push((width + 5, 3.25));
    service.submit(1, overwide, &tx).unwrap();
    let mut got = [0.0f32; 2];
    for _ in 0..2 {
        let resp = rx.recv().unwrap();
        got[resp.id as usize] = resp.margin;
    }
    service.shutdown();
    assert_eq!(got[0].to_bits(), expected[0].to_bits());
    assert_eq!(got[1].to_bits(), expected[1].to_bits());
}

#[test]
fn submit_rejects_malformed_feature_vectors() {
    let ds = synthetic::realsim_like(300, 37);
    let b = BinnedDataset::from_dataset(&ds, 16).unwrap();
    let flat = FlatForest::from_forest(&boosted(&ds, &b, 2, 5));
    let slot = Arc::new(ModelSlot::new(flat, b.cuts()));
    let service = Service::start(Arc::clone(&slot), opts(1, 1, PoolMode::Scoped));
    let (tx, rx) = channel();
    let err = service
        .submit(1, vec![(3, 1.0), (3, 2.0)], &tx)
        .unwrap_err()
        .to_string();
    assert!(err.contains("strictly increasing"), "got: {err}");
    let err = service
        .submit(2, vec![(5, 1.0), (2, 2.0)], &tx)
        .unwrap_err()
        .to_string();
    assert!(err.contains("strictly increasing"), "got: {err}");
    let err = service
        .submit(3, vec![(0, f32::NAN)], &tx)
        .unwrap_err()
        .to_string();
    assert!(err.contains("non-finite"), "got: {err}");
    // rejected requests never reach the queue; a valid one still serves
    service.submit(4, vec![(0, 1.5)], &tx).unwrap();
    let resp = rx.recv().unwrap();
    assert_eq!(resp.id, 4);
    assert_eq!(resp.model_version, 1);
    service.shutdown();
}

#[test]
fn shutdown_drains_already_submitted_requests() {
    let ds = synthetic::realsim_like(200, 39);
    let b = BinnedDataset::from_dataset(&ds, 16).unwrap();
    let flat = FlatForest::from_forest(&boosted(&ds, &b, 2, 6));
    let slot = Arc::new(ModelSlot::new(flat, b.cuts()));
    // a huge batch with a long wait: without the drain-on-close
    // guarantee these would sit coalescing when shutdown lands
    let service = Service::start(
        Arc::clone(&slot),
        ServeOptions {
            batch: 64,
            max_wait: Duration::from_millis(250),
            threads: 1,
            pool: PoolMode::Scoped,
        },
    );
    let (tx, rx) = channel();
    for id in 0..10u64 {
        let row: Vec<(u32, f32)> = ds.x.row(id as usize).collect();
        service.submit(id, row, &tx).unwrap();
    }
    let stats = service.shutdown();
    assert_eq!(stats.requests, 10);
    let mut ids: Vec<u64> = rx.try_iter().map(|resp| resp.id).collect();
    ids.sort_unstable();
    assert_eq!(ids, (0..10).collect::<Vec<u64>>());
}
