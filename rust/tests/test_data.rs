//! Cross-module data-path integration: svmlight → Dataset → binning →
//! diversity stats, plus generator realism checks.

use asgbdt::data::stats::{diversity_report, SpeciesTable};
use asgbdt::data::{synthetic, BinnedDataset, CsrMatrix, Dataset};
use asgbdt::io::svmlight;
use asgbdt::util::Rng;

#[test]
fn svmlight_roundtrip_preserves_binning() {
    let ds = synthetic::realsim_like(300, 5);
    let path = std::env::temp_dir().join("asgbdt_it_data.svm");
    svmlight::write_file(&ds, &path).unwrap();
    let back = svmlight::read_file(&path).unwrap();
    assert_eq!(back.n_rows(), ds.n_rows());
    assert_eq!(back.y, ds.y);
    // binning the round-tripped data gives identical bins: the formats
    // must not lose precision that changes quantiles
    let b1 = BinnedDataset::from_dataset(&ds, 32).unwrap();
    let b2 = BinnedDataset::from_dataset(&back, 32).unwrap();
    assert_eq!(b1.bins, b2.bins);
    std::fs::remove_file(&path).ok();
}

#[test]
fn binned_dataset_agrees_with_raw_lookup() {
    let ds = synthetic::realsim_like(200, 6);
    let b = BinnedDataset::from_dataset(&ds, 32).unwrap();
    // for every nonzero, bin_of(row, feat) equals the mapper's bin of the
    // raw value; for absent features it equals the zero bin
    for r in 0..ds.n_rows() {
        for (c, v) in ds.x.row(r) {
            assert_eq!(b.bin_of(r, c), b.mappers[c as usize].bin_of(v));
        }
    }
    let zero_feat = (0..ds.n_features() as u32)
        .find(|&c| ds.x.get(0, c) == 0.0)
        .unwrap();
    assert_eq!(b.bin_of(0, zero_feat), b.mappers[zero_feat as usize].zero_bin);
}

#[test]
fn species_table_consistent_with_dataset_species() {
    for ds in [synthetic::higgs_like(1000, 7), synthetic::realsim_like(500, 7)] {
        let t = SpeciesTable::build(&ds);
        assert_eq!(t.n_species(), ds.n_species());
        assert_eq!(t.row_species.len(), ds.n_rows());
        assert!((t.total() - ds.total_weight()).abs() < 1e-6);
    }
}

#[test]
fn diversity_monotone_in_rate() {
    let ds = synthetic::realsim_like(800, 8);
    let mut last_delta = -1.0;
    let mut last_rho = -1.0;
    for rate in [0.001, 0.01, 0.1, 0.5, 0.9] {
        let rep = diversity_report(&ds, rate);
        assert!(rep.delta >= last_delta);
        assert!(rep.rho >= last_rho - 1e-12);
        last_delta = rep.delta;
        last_rho = rep.rho;
    }
}

#[test]
fn split_is_disjoint_and_complete() {
    let ds = synthetic::higgs_like(500, 9);
    let mut rng = Rng::new(9);
    let (tr, te) = ds.split(0.3, &mut rng);
    assert_eq!(tr.n_rows() + te.n_rows(), 500);
    // weights preserved
    assert!((tr.total_weight() + te.total_weight() - ds.total_weight()).abs() < 1e-6);
}

#[test]
fn generators_cover_paper_regimes() {
    // dimensionality ordering: higgs << realsim
    let h = synthetic::higgs_like(400, 10);
    let r = synthetic::realsim_like(400, 10);
    assert!(h.n_features() < r.n_features());
    // diversity ordering at small rate
    let dh = diversity_report(&h, 0.01);
    let dr = diversity_report(&r, 0.01);
    assert!(dh.delta > dr.delta, "higgs {0} <= realsim {1}", dh.delta, dr.delta);
}

#[test]
fn csr_select_and_fingerprints_compose() {
    let ds = synthetic::realsim_like(100, 11);
    let rows: Vec<usize> = (0..50).collect();
    let sub = ds.subset(&rows, "sub");
    for (i, &r) in rows.iter().enumerate() {
        assert_eq!(sub.x.row_fingerprint(i), ds.x.row_fingerprint(r));
    }
}

#[test]
fn dense_matrix_from_svmlight_text() {
    let text = "1 1:1.5 2:2.5\n0 1:0.5 2:3.5\n";
    let ds = svmlight::parse(text, "dense").unwrap();
    let m: &CsrMatrix = &ds.x;
    assert_eq!(m.n_cols(), 2);
    assert!((m.density() - 1.0).abs() < 1e-12);
    let _d: &Dataset = &ds;
}
