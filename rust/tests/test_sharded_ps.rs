//! Shard-equivalence test layer for the sharded parameter server
//! (`ps/sharded.rs`).
//!
//! The acceptance bar of the sharded-PS change is that `ps_shards` is a
//! pure server-layout knob: every shard count must reproduce the
//! single-shard server bit for bit. The matrix tests drive a
//! `ps_shards=1` reference core, record every tree plus the post-accept
//! state, then replay the identical trees into `ps_shards ∈ {2, 4, 8}`
//! twins across both accept pipelines (`target=fused|serial`) and both
//! executor pool modes (`pool=persistent|scoped`), comparing after every
//! accept (node by node: F, version, sampled rows, targets) and at the
//! end (final-forest serialization, loss curves, staleness stats) — on
//! both a sparse and a dense `testkit` fixture.
//!
//! The lifecycle test runs the real async coordinator for ≥100 trees on
//! persistent executors with a sharded server, pinning that the
//! composed-version publishes and the per-shard accept carving survive
//! a long racing run.

use asgbdt::config::TrainConfig;
use asgbdt::coordinator::train_async;
use asgbdt::data::{synthetic, Dataset};
use asgbdt::ps::{ServerCore, TargetMode, TargetSnapshot};
use asgbdt::runtime::GradientEngine;
use asgbdt::testkit::{binned_for, Gen};
use asgbdt::tree::build_tree;
use asgbdt::util::{PoolMode, Rng};

const N_TREES: usize = 8;

fn cfg_base(target: TargetMode) -> TrainConfig {
    let mut cfg = TrainConfig::default();
    cfg.n_trees = N_TREES;
    cfg.step_length = 0.3;
    cfg.sampling_rate = 0.9;
    cfg.tree.max_leaves = 8;
    cfg.tree.feature_rate = 1.0;
    cfg.max_bins = 16;
    cfg.eval_every = 2;
    cfg.target = target;
    cfg
}

/// Drive a `ps_shards=1` reference core under `target`, then replay the
/// identical trees into every (pool, shard-count) twin and assert
/// node-by-node and final bit-identity.
fn assert_shard_matrix(fixture: &str, ds: &Dataset) {
    for target in [TargetMode::Fused, TargetMode::Serial] {
        let cfg_ref = cfg_base(target);
        let binned = binned_for(ds, &cfg_ref);
        let mut reference =
            ServerCore::new(&cfg_ref, ds, binned.clone(), None, GradientEngine::native()).unwrap();
        let mut rng = Rng::new(29);
        let mut trees = Vec::new();
        let mut states: Vec<(Vec<f32>, TargetSnapshot)> = Vec::new();
        for _ in 0..N_TREES {
            let s = reference.snapshot();
            let tree = build_tree(&binned, &s.rows, &s.grad, &s.hess, &cfg_ref.tree, &mut rng);
            trees.push(tree.clone());
            reference.apply_tree(tree, s.version).unwrap();
            states.push((reference.f.clone(), reference.snapshot()));
        }
        let reference_forest = reference.forest.to_json().to_string();
        let curve_points = |core: &ServerCore| {
            core.curve
                .points
                .iter()
                .map(|p| (p.n_trees, p.train_loss))
                .collect::<Vec<_>>()
        };
        for pool in [PoolMode::Persistent, PoolMode::Scoped] {
            for shards in [2usize, 4, 8] {
                let mut cfg = cfg_ref.clone();
                cfg.ps_shards = shards;
                cfg.pool = pool;
                cfg.score_threads = 3;
                let mut core =
                    ServerCore::new(&cfg, ds, binned.clone(), None, GradientEngine::native())
                        .unwrap();
                // the partition clamps to whole ROW_BLOCKs but always
                // covers the dataset and splits it when asked to
                assert_eq!(core.row_partition().n_rows(), ds.n_rows());
                assert!(core.row_partition().n_shards() >= 2);
                assert!(core.row_partition().n_shards() <= shards);
                for (i, tree) in trees.iter().enumerate() {
                    let s = core.snapshot();
                    let out = core.apply_tree(tree.clone(), s.version).unwrap();
                    let at = format!(
                        "{fixture} target={} pool={} shards={shards} tree={i}",
                        target.as_str(),
                        pool.as_str()
                    );
                    assert!(out.accepted, "push rejected ({at})");
                    let (ref_f, ref_snap) = &states[i];
                    assert_eq!(&core.f, ref_f, "F diverged ({at})");
                    let snap = core.snapshot();
                    assert_eq!(snap.version, ref_snap.version, "version diverged ({at})");
                    assert_eq!(*snap.rows, *ref_snap.rows, "sampled rows diverged ({at})");
                    assert_eq!(*snap.grad, *ref_snap.grad, "grad targets diverged ({at})");
                    assert_eq!(*snap.hess, *ref_snap.hess, "hess targets diverged ({at})");
                }
                let at = format!(
                    "{fixture} target={} pool={} shards={shards}",
                    target.as_str(),
                    pool.as_str()
                );
                assert_eq!(
                    core.forest.to_json().to_string(),
                    reference_forest,
                    "final forest diverged ({at})"
                );
                assert_eq!(
                    curve_points(&core),
                    curve_points(&reference),
                    "loss curves diverged ({at})"
                );
                assert_eq!(
                    core.staleness.samples, reference.staleness.samples,
                    "staleness diverged ({at})"
                );
                // every shard cell advanced with the counter
                let sv = core.shard_versions();
                for shard in 0..sv.n_shards() {
                    assert_eq!(sv.shard_version(shard), N_TREES as u64, "({at})");
                }
                assert_eq!(sv.composed(), N_TREES as u64, "({at})");
            }
        }
    }
}

#[test]
fn sparse_fixture_every_shard_count_matches_single_shard() {
    // 4,600 rows = 9 whole ROW_BLOCKs: ps_shards=8 gets a real multi-
    // block carve (one shard owns two blocks, the rest one each)
    let mut g = Gen {
        rng: Rng::new(401),
        size: 100,
    };
    let fx = g.binned_dataset(4_600, 31, 0.7);
    assert_shard_matrix("sparse", &fx.dataset);
}

#[test]
fn dense_fixture_every_shard_count_matches_single_shard() {
    // sparsity 0.0: every feature present in every row — the dense
    // extreme of the histogram/accept layout (6 blocks, so ps_shards=8
    // also exercises the shard-count clamp)
    let mut g = Gen {
        rng: Rng::new(402),
        size: 100,
    };
    let fx = g.binned_dataset(2_600, 13, 0.0);
    assert_shard_matrix("dense", &fx.dataset);
}

#[test]
fn sharded_async_lifecycle_survives_a_long_run_on_persistent_executors() {
    // ≥100 trees through the real async coordinator with a 4-shard
    // server on persistent executors: racing workers, repeated sharded
    // accept passes, and composed-version publishes on every accept
    let ds = synthetic::realsim_like(1_400, 77);
    let mut cfg = TrainConfig::default();
    cfg.workers = 4;
    cfg.n_trees = 120;
    cfg.step_length = 0.2;
    cfg.sampling_rate = 0.8;
    cfg.tree.max_leaves = 4;
    cfg.max_bins = 16;
    cfg.eval_every = 30;
    cfg.ps_shards = 4;
    cfg.score_threads = 2;
    cfg.pool = PoolMode::Persistent;
    let rep = train_async(&cfg, &ds, None).unwrap();
    assert_eq!(rep.trees_accepted, 120);
    assert_eq!(rep.forest.n_trees(), 120);
    // staleness recorded for every accepted push
    assert_eq!(rep.staleness.samples.len(), 120);
    let first = rep.curve.points.first().unwrap();
    let last = rep.curve.points.last().unwrap();
    assert!(
        last.train_loss < first.train_loss,
        "no descent: {} -> {}",
        first.train_loss,
        last.train_loss
    );
}
