//! Chaos suite for the deterministic fault-injection layer
//! (DESIGN.md §14).
//!
//! Three layers of guarantees are pinned here:
//!
//! * **Transport**: sharded histogram aggregation behind a
//!   [`FaultyTransport`] (drops + duplicates + delays) stays bin-for-bin
//!   equal to the clean dense build — the send-side retry and the
//!   receiver's `(source, epoch)` at-most-once dedup absorb every
//!   injected fault. The same driver run twice produces a bit-identical
//!   fault trace.
//! * **Training**: a 4-worker async run completes exactly `n_trees`
//!   across a (drop-rate × restart-budget) matrix, the final forest is
//!   valid JSON, and the report's death/restart counters match the
//!   injected plan. A worker rigged to always panic with no restart
//!   budget surfaces a *named* stall error instead of deadlocking.
//! * **Determinism**: fault decisions are pure functions of
//!   `(fault_seed, site, attempt)`, so two identical chaos runs agree on
//!   every commonly-exercised key, and every recorded event replays on a
//!   fresh plan with the same seed.
//!
//! CI's chaos-smoke job sweeps `ASGBDT_CHAOS_SEED` over several seeds;
//! locally the suite defaults to seed 1.

use asgbdt::config::TrainConfig;
use asgbdt::coordinator::train_async;
use asgbdt::data::synthetic;
use asgbdt::io::Json;
use asgbdt::ps::{
    aggregate_sharded, FaultyTransport, FeaturePartition, LocalTransport, RowPartition,
};
use asgbdt::testkit::Gen;
use asgbdt::tree::Histogram;
use asgbdt::util::fault::{FaultAction, FaultKind, FaultPlan, FaultSite, FaultSpec};
use asgbdt::util::{Executor, Rng};

/// The base chaos seed: `ASGBDT_CHAOS_SEED` (CI sweeps it), default 1.
fn chaos_seed() -> u64 {
    std::env::var("ASGBDT_CHAOS_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(1)
}

fn chaos_cfg(workers: usize, n_trees: usize) -> TrainConfig {
    let mut cfg = TrainConfig::default();
    cfg.workers = workers;
    cfg.n_trees = n_trees;
    cfg.step_length = 0.2;
    cfg.sampling_rate = 0.8;
    cfg.tree.max_leaves = 8;
    cfg.max_bins = 16;
    cfg.eval_every = 10;
    cfg
}

/// Message-fault spec shared by the matrix tests: `drop` plus fixed
/// duplicate/delay rates (delays kept tiny so suites stay fast).
fn message_spec(drop: f64) -> FaultSpec {
    FaultSpec {
        drop_rate: drop,
        dup_rate: 0.1,
        delay_rate: 0.05,
        max_delay_us: 50,
        ..FaultSpec::default()
    }
}

// ---------------------------------------------------------------------
// transport layer
// ---------------------------------------------------------------------

/// Drive one sharded aggregation round per epoch through a
/// [`FaultyTransport`] armed with `plan`, asserting bin-for-bin equality
/// with the clean dense build every time.
fn assert_faulty_aggregation_clean(
    fx: &asgbdt::testkit::BinnedFixture,
    rows: &[u32],
    dense: &Histogram,
    plan: &FaultPlan,
    at: &str,
) {
    let b = &fx.binned;
    let exec = Executor::scoped(2);
    let rowp = RowPartition::new(b.n_rows, 3);
    let featp = FeaturePartition::new(b, 2);
    let inner = LocalTransport::new(featp.n_shards());
    let max_shards = rowp.n_shards().max(featp.n_shards());
    let faulty = FaultyTransport::new(&inner, plan, max_shards);
    // several epochs so duplicate-parked stale replays from epoch e are
    // drained (and must be discarded) during epoch e+1
    for epoch in 0..3u64 {
        let got = aggregate_sharded(
            b, rows, &fx.grad, &fx.hess, &rowp, &featp, &faulty, &exec, epoch,
        );
        assert!(
            got.totals == dense.totals,
            "totals diverged ({at}, epoch {epoch})"
        );
        for slot in 0..b.total_bins() {
            assert!(
                got.grad[slot] == dense.grad[slot]
                    && got.hess[slot] == dense.hess[slot]
                    && got.count[slot] == dense.count[slot],
                "slot {slot} diverged ({at}, epoch {epoch})"
            );
        }
    }
}

#[test]
fn faulty_transport_aggregation_matches_clean_at_every_drop_rate() {
    let mut g = Gen {
        rng: Rng::new(113),
        size: 100,
    };
    let fx = g.binned_dataset(2_000, 7, 0.5);
    let rows: Vec<u32> = (0..2_000u32).filter(|_| g.rng.bernoulli(0.7)).collect();
    let mut dense = Histogram::zeros(fx.binned.total_bins());
    dense.build(&fx.binned, &rows, &fx.grad, &fx.hess);
    for drop in [0.0, 0.1, 0.2] {
        let plan = FaultPlan::new(chaos_seed(), message_spec(drop));
        let at = format!("drop={drop}");
        assert_faulty_aggregation_clean(&fx, &rows, &dense, &plan, &at);
        if drop == 0.0 {
            // the only injected faults are duplicates/delays, never drops
            assert_eq!(plan.counts().drops, 0, "({at})");
        }
    }
}

#[test]
fn transport_driver_fault_traces_are_bit_identical_across_runs() {
    // the acceptance criterion's strong form: the same deterministic
    // driver (sequential epochs, per-pair ordered sends) run twice under
    // two same-seed plans records the exact same trace, event for event
    let mut g = Gen {
        rng: Rng::new(211),
        size: 100,
    };
    let fx = g.binned_dataset(1_200, 5, 0.4);
    let rows: Vec<u32> = (0..1_200u32).filter(|_| g.rng.bernoulli(0.8)).collect();
    let mut dense = Histogram::zeros(fx.binned.total_bins());
    dense.build(&fx.binned, &rows, &fx.grad, &fx.hess);
    let plan_a = FaultPlan::new(chaos_seed(), message_spec(0.2));
    let plan_b = FaultPlan::new(chaos_seed(), message_spec(0.2));
    assert_faulty_aggregation_clean(&fx, &rows, &dense, &plan_a, "run a");
    assert_faulty_aggregation_clean(&fx, &rows, &dense, &plan_b, "run b");
    let (ta, tb) = (plan_a.trace(), plan_b.trace());
    assert!(!ta.is_empty(), "a 20% drop plan must inject something");
    assert_eq!(ta, tb, "identical chaos runs must record identical traces");
}

// ---------------------------------------------------------------------
// training layer
// ---------------------------------------------------------------------

#[test]
fn chaos_drop_matrix_completes_exactly_n_trees() {
    // message faults only (panic_rate 0): dropped pushes lose trees but
    // never workers, so every cell must deliver exactly n_trees with all
    // workers alive — graceful completion under lossy pushes
    let ds = synthetic::realsim_like(250, 41);
    for (drop, restarts) in [(0.0f64, 0u64), (0.1, 1), (0.2, 2)] {
        let mut cfg = chaos_cfg(4, 16);
        cfg.fault_seed = Some(chaos_seed());
        cfg.fault_drop_rate = drop;
        cfg.fault_dup_rate = 0.1;
        cfg.fault_delay_rate = 0.05;
        cfg.worker_restarts = restarts;
        let at = format!("drop={drop} restarts={restarts}");
        let rep = train_async(&cfg, &ds, None).unwrap();
        assert_eq!(rep.trees_accepted, 16, "({at})");
        assert_eq!(rep.forest.n_trees(), 16, "({at})");
        // the trained forest survives a JSON round trip
        let json = rep.forest.to_json().to_string();
        Json::parse(&json).unwrap_or_else(|e| panic!("forest JSON invalid ({at}): {e}"));
        // no panics injected → nobody died, every worker finished alive
        assert_eq!(rep.supervision.deaths, 0, "({at})");
        assert_eq!(rep.supervision.restarts, 0, "({at})");
        assert_eq!(rep.supervision.workers_final, 4, "({at})");
        assert!(
            rep.fault_trace
                .iter()
                .all(|e| e.action != FaultAction::Panic),
            "({at})"
        );
    }
}

/// Pre-scan a pure plan: can a 4-worker run with this restart budget
/// deliver at least `n_trees` pushes before every worker retires?
/// Decisions are pure functions of the key, so this walks the exact
/// schedule the run will experience — no training needed.
fn plan_is_viable(
    plan: &FaultPlan,
    workers: usize,
    restarts: u64,
    n_trees: usize,
    horizon: u64,
) -> bool {
    let mut delivered = 0usize;
    for wid in 0..workers {
        for inc in 0..=restarts {
            let death = (0..horizon)
                .find(|&c| plan.decide(FaultSite::worker_panic(wid, inc), c) == FaultAction::Panic);
            let Some(death_cycle) = death else {
                // an incarnation with no panic in sight keeps delivering
                // forever: the run completes regardless of the others
                return true;
            };
            delivered += (0..death_cycle)
                .filter(|&c| {
                    plan.decide(FaultSite::worker_push(wid, inc), c) != FaultAction::Drop
                })
                .count();
        }
    }
    delivered >= n_trees
}

#[test]
fn chaos_panic_matrix_with_restarts_completes_and_counts_match() {
    // panics + drops with a restart budget: pick (by pre-scanning the
    // pure plan) a seed whose schedule delivers enough trees, run it,
    // and check the report's counters against the recorded trace
    let ds = synthetic::realsim_like(250, 41);
    let n_trees = 12;
    let (workers, restarts) = (4usize, 2u64);
    let spec = FaultSpec {
        drop_rate: 0.1,
        panic_rate: 0.2,
        ..FaultSpec::default()
    };
    let seed0 = chaos_seed();
    let seed = (seed0..seed0 + 200)
        .find(|&s| plan_is_viable(&FaultPlan::new(s, spec), workers, restarts, n_trees, 400))
        .expect("a viable seed within 200 of the base");
    let mut cfg = chaos_cfg(workers, n_trees);
    cfg.fault_seed = Some(seed);
    cfg.fault_drop_rate = spec.drop_rate;
    cfg.fault_panic_rate = spec.panic_rate;
    cfg.worker_restarts = restarts;
    let rep = train_async(&cfg, &ds, None).unwrap();
    assert_eq!(rep.trees_accepted, n_trees);
    Json::parse(&rep.forest.to_json().to_string()).expect("forest JSON valid");
    // every recorded panic is one death, and vice versa
    let panics = rep
        .fault_trace
        .iter()
        .filter(|e| e.action == FaultAction::Panic)
        .count() as u64;
    assert_eq!(rep.supervision.deaths, panics, "deaths must match the injected plan");
    // every death was either restarted or retired its worker
    assert_eq!(
        rep.supervision.deaths - rep.supervision.restarts,
        (workers - rep.supervision.workers_final) as u64
    );
    assert!(rep.supervision.restarts <= workers as u64 * restarts);
}

#[test]
fn worker_panic_on_first_build_surfaces_named_error() {
    // the regression this layer exists for: a panicked worker used to
    // leave train_async deadlocked on rx.recv(); now a run whose workers
    // all die surfaces a named error — which workers, how far it got
    let ds = synthetic::realsim_like(250, 41);
    let mut cfg = chaos_cfg(1, 8);
    cfg.fault_seed = Some(chaos_seed());
    cfg.fault_panic_rate = 1.0; // dies on its very first build cycle
    cfg.worker_restarts = 0;
    let err = train_async(&cfg, &ds, None).unwrap_err().to_string();
    assert!(err.contains("stalled at 0/8"), "unexpected error: {err}");
    assert!(err.contains("worker 0"), "unexpected error: {err}");
    assert!(err.contains("injected fault"), "unexpected error: {err}");
}

// ---------------------------------------------------------------------
// determinism layer
// ---------------------------------------------------------------------

#[test]
fn two_identical_chaos_runs_record_identical_fault_schedules() {
    // async workers free-run 0–2 extra cycles past the n_trees-th
    // acceptance before observing shutdown, so the *set* of exercised
    // keys has a timing-dependent tail. What is deterministic — and
    // asserted here — is the schedule itself: per site, both runs agree
    // on every commonly-exercised attempt, and every event either run
    // recorded replays exactly on a fresh plan with the same seed.
    let ds = synthetic::realsim_like(250, 41);
    let run = || {
        let mut cfg = chaos_cfg(4, 12);
        cfg.fault_seed = Some(chaos_seed());
        cfg.fault_drop_rate = 0.1;
        cfg.fault_dup_rate = 0.1;
        cfg.fault_panic_rate = 0.2;
        cfg.worker_restarts = 2;
        // viability: reuse the panic-matrix pre-scan seed logic is not
        // needed here — a stalled run would unwrap_err, and the matrix
        // test already pins completion; this test only needs traces
        match train_async(&cfg, &ds, None) {
            Ok(rep) => (rep.fault_trace, cfg),
            Err(_) => {
                // all workers retired under this seed: the fault layer
                // still recorded a trace-worth of panics, but train_async
                // consumed it; rebuild the schedule from the pure plan
                (Vec::new(), cfg)
            }
        }
    };
    let (trace_a, cfg) = run();
    let (trace_b, _) = run();
    let plan = cfg.fault_plan().expect("armed");
    // cross-replay: every recorded event is reproduced by a fresh plan
    for e in trace_a.iter().chain(trace_b.iter()) {
        assert_eq!(
            plan.decide(e.site, e.attempt),
            e.action,
            "event {:?} does not replay",
            e
        );
    }
    // per-site common-prefix equality across the two runs
    use std::collections::BTreeMap;
    let by_site = |trace: &[asgbdt::util::FaultEvent]| {
        let mut m: BTreeMap<(u64, u64), Vec<(u64, FaultAction)>> = BTreeMap::new();
        for e in trace {
            m.entry((e.site.kind.code(), e.site.index))
                .or_default()
                .push((e.attempt, e.action));
        }
        m
    };
    let (ma, mb) = (by_site(&trace_a), by_site(&trace_b));
    for (site, a_events) in &ma {
        if let Some(b_events) = mb.get(site) {
            let common = a_events.len().min(b_events.len());
            assert_eq!(
                &a_events[..common],
                &b_events[..common],
                "fault schedules diverged at site {site:?}"
            );
        }
    }
    // the panic schedule is worker-paced (cycle counters, not wall
    // clock): every panic site's full event list must agree exactly
    for (site, a_events) in &ma {
        if site.0 == FaultKind::WorkerPanic.code() {
            assert_eq!(
                Some(a_events),
                mb.get(site),
                "panic schedule diverged at site {site:?}"
            );
        }
    }
}
