//! The loss-kernel conformance layer (DESIGN.md §17), pinned three ways:
//!
//! 1. **Finite differences** — every loss's closed-form `(l', l'')` must
//!    match central differences of its own `loss_elem` (and `l''` the
//!    differences of `l'`), property-checked over seeded random margins.
//!    Huber is checked away from its kink neighborhood (where one-sided
//!    derivatives differ by construction); multiclass softmax is checked
//!    per class at K ∈ {3, 5}.
//! 2. **Bit-identity matrix** — for every loss, the forest that training
//!    produces is byte-for-byte identical across
//!    `{serial, sync, async} × target={fused, serial} × ps_shards={1, 4}`
//!    under the async determinism envelope (`max_staleness=0`,
//!    `feature_rate=1`). One loss kernel, one answer, whatever pipeline
//!    computed it.
//! 3. **Adaptive-step determinism** — the `step=adaptive` shrink
//!    `v/(1+τ)` is a pure function of the recorded τ: the same staleness
//!    trace replays to the same forest bit for bit, a run that never sees
//!    staleness is exactly `step=fixed`, and checkpoint/resume under
//!    adaptive reproduces the uninterrupted run.

use std::sync::Arc;

use asgbdt::config::{StepMode, TrainConfig, TrainMode};
use asgbdt::coordinator::{train, train_resumed};
use asgbdt::data::{synthetic, BinnedDataset, Dataset};
use asgbdt::io::artifact;
use asgbdt::loss::{multiclass, LossKind, ScalarLoss};
use asgbdt::prop_assert;
use asgbdt::ps::{ServerCore, TargetMode};
use asgbdt::runtime::GradientEngine;
use asgbdt::testkit::{check, close};
use asgbdt::tree::build_tree;
use asgbdt::util::Rng;

// ------------------------------------------------------ finite differences

/// Central-difference step. Small enough for O(h²) truncation to stay
/// under the tolerance, large enough that f32 rounding in `loss_elem`
/// (≈1e-7 relative) doesn't dominate the quotient.
const H: f32 = 1e-2;
const TOL: f64 = 5e-3;

/// FD-check one scalar loss: grad against differenced loss, hess against
/// differenced grad, and linear weight scaling.
fn fd_check_scalar(name: &str, loss: ScalarLoss, seed: u64) {
    check(&format!("fd/{name}"), 300, seed, |g| {
        let f = g.f64_in(-4.0, 4.0) as f32;
        let y = match loss {
            // logistic labels are {0, 1}; the regressions take any target
            ScalarLoss::Logistic => {
                if g.rng.bernoulli(0.5) {
                    1.0
                } else {
                    0.0
                }
            }
            _ => g.f64_in(-3.0, 3.0) as f32,
        };
        if let ScalarLoss::Huber(d) = loss {
            // skip the kink neighborhood |‖r‖ − δ| < 3H: the hessian is
            // genuinely discontinuous there and a symmetric difference
            // straddling the kink measures neither side
            let r = (f - y).abs();
            if (r - d).abs() < 3.0 * H {
                return Ok(());
            }
        }
        let (grad, hess) = loss.grad_hess_at(f, y, 1.0);
        let fd_grad = (loss.loss_elem(f + H, y) as f64 - loss.loss_elem(f - H, y) as f64)
            / (2.0 * H as f64);
        close(fd_grad, grad as f64, TOL)
            .map_err(|e| format!("{name} grad at f={f} y={y}: {e}"))?;
        let (gp, _) = loss.grad_hess_at(f + H, y, 1.0);
        let (gm, _) = loss.grad_hess_at(f - H, y, 1.0);
        let fd_hess = (gp as f64 - gm as f64) / (2.0 * H as f64);
        close(fd_hess, hess as f64, TOL)
            .map_err(|e| format!("{name} hess at f={f} y={y}: {e}"))?;
        prop_assert!(hess >= 0.0, "{name}: negative hessian {hess} at f={f} y={y}");
        // (w·l', w·l'') is linear in w
        let w = g.f64_in(0.1, 3.0) as f32;
        let (gw, hw) = loss.grad_hess_at(f, y, w);
        close(gw as f64, w as f64 * grad as f64, 1e-5)
            .map_err(|e| format!("{name} grad weight scaling: {e}"))?;
        close(hw as f64, w as f64 * hess as f64, 1e-5)
            .map_err(|e| format!("{name} hess weight scaling: {e}"))?;
        Ok(())
    });
}

#[test]
fn logistic_grad_hess_match_finite_differences() {
    fd_check_scalar("logistic", ScalarLoss::Logistic, 101);
}

#[test]
fn squared_grad_hess_match_finite_differences() {
    fd_check_scalar("squared", ScalarLoss::Squared, 102);
}

#[test]
fn huber_grad_hess_match_finite_differences_away_from_the_kink() {
    for delta in [0.7f32, 1.0, 2.5] {
        fd_check_scalar(&format!("huber_d{delta}"), ScalarLoss::Huber(delta), 103);
    }
}

#[test]
fn huber_one_sided_derivatives_bracket_the_kink() {
    // at the kink itself the closed forms pick the inside branch
    // (|r| ≤ δ); just inside the grad is ±(δ − ε) with hess 1, just
    // outside ±δ with hess 0 — the FD property skips this neighborhood,
    // so pin the branch behavior explicitly here
    let d = 1.0f32;
    let eps = 1e-3f32;
    let (g_in, h_in) = ScalarLoss::Huber(d).grad_hess_at(d - eps, 0.0, 1.0);
    assert!((g_in - (d - eps)).abs() < 1e-6);
    assert_eq!(h_in, 1.0);
    let (g_out, h_out) = ScalarLoss::Huber(d).grad_hess_at(d + eps, 0.0, 1.0);
    assert_eq!(g_out, d);
    assert_eq!(h_out, 0.0);
    // the gradient itself is continuous across the kink
    assert!((g_in - g_out).abs() < 2.0 * eps);
}

#[test]
fn multiclass_grad_hess_match_finite_differences_at_k3_and_k5() {
    for k in [3usize, 5] {
        check(&format!("fd/multiclass_k{k}"), 250, 70 + k as u64, |g| {
            // one row in class-major layout (n=1): f[c·1 + 0] = scores[c]
            let scores: Vec<f32> = (0..k).map(|_| g.f64_in(-4.0, 4.0) as f32).collect();
            let yc = g.usize_in(0, k - 1);
            let c = g.usize_in(0, k - 1);
            let y = [yc as f32];
            let w = [1.0f32];
            let gh = multiclass::grad_hess_class(&scores, &y, &w, k, c);
            let mut sp = scores.clone();
            sp[c] += H;
            let mut sm = scores.clone();
            sm[c] -= H;
            let fd_grad = (multiclass::loss_elem(&sp, yc) as f64
                - multiclass::loss_elem(&sm, yc) as f64)
                / (2.0 * H as f64);
            close(fd_grad, gh.grad[0] as f64, TOL)
                .map_err(|e| format!("k={k} c={c} y={yc} grad: {e}"))?;
            let gp = multiclass::grad_hess_class(&sp, &y, &w, k, c).grad[0];
            let gm = multiclass::grad_hess_class(&sm, &y, &w, k, c).grad[0];
            let fd_hess = (gp as f64 - gm as f64) / (2.0 * H as f64);
            close(fd_hess, gh.hess[0] as f64, TOL)
                .map_err(|e| format!("k={k} c={c} y={yc} hess: {e}"))?;
            // p(1 − p) bounds and per-row gradient cancellation
            prop_assert!(
                gh.hess[0] >= 0.0 && gh.hess[0] <= 0.25 + 1e-6,
                "k={k}: hess {} outside [0, 1/4]",
                gh.hess[0]
            );
            let grad_sum: f32 = (0..k)
                .map(|cc| multiclass::grad_hess_class(&scores, &y, &w, k, cc).grad[0])
                .sum();
            prop_assert!(
                grad_sum.abs() < 1e-5,
                "k={k}: class grads sum to {grad_sum}, not 0"
            );
            Ok(())
        });
    }
}

// ----------------------------------------------------- bit-identity matrix

/// Config for one cell of the identity matrix. The async determinism
/// envelope (`max_staleness=0`, `feature_rate=1`) makes every accepted
/// push fresh and every build a pure function of the published target, so
/// all three coordinators must walk the identical tree sequence.
fn matrix_cfg(
    loss: LossKind,
    mode: TrainMode,
    target: TargetMode,
    shards: usize,
) -> TrainConfig {
    let mut cfg = TrainConfig::default();
    cfg.mode = mode;
    cfg.loss = loss;
    if loss == LossKind::Huber {
        cfg.huber_delta = 1.5;
    }
    if loss == LossKind::Multiclass {
        cfg.n_classes = 3;
    }
    cfg.n_trees = 12;
    cfg.step_length = 0.3;
    cfg.sampling_rate = 0.9;
    cfg.workers = 2;
    cfg.tree.max_leaves = 8;
    cfg.max_bins = 16;
    cfg.eval_every = 6;
    cfg.target = target;
    cfg.ps_shards = shards;
    cfg.max_staleness = Some(0);
    cfg.tree.feature_rate = 1.0;
    cfg
}

fn matrix_dataset(loss: LossKind) -> Dataset {
    match loss {
        LossKind::Logistic => synthetic::realsim_like(260, 31),
        LossKind::Squared | LossKind::Huber => synthetic::regression_like(260, 33),
        LossKind::Multiclass => synthetic::multiclass_like(260, 3, 35),
    }
}

#[test]
fn every_loss_is_bit_identical_across_mode_target_and_shard_count() {
    for loss in [
        LossKind::Logistic,
        LossKind::Squared,
        LossKind::Huber,
        LossKind::Multiclass,
    ] {
        let ds = matrix_dataset(loss);
        // reference cell: the strictly serial loop on the fused
        // single-shard server
        let reference = train(
            &matrix_cfg(loss, TrainMode::Serial, TargetMode::Fused, 1),
            &ds,
            None,
        )
        .unwrap();
        let ref_forest = reference.forest.to_json().to_string();
        let ref_loss = reference.curve.points.last().unwrap().train_loss;
        for mode in [TrainMode::Serial, TrainMode::Sync, TrainMode::Async] {
            for target in [TargetMode::Fused, TargetMode::Serial] {
                for shards in [1usize, 4] {
                    let cfg = matrix_cfg(loss, mode, target, shards);
                    let rep = train(&cfg, &ds, None).unwrap();
                    let at = format!(
                        "loss={} mode={} target={} ps_shards={shards}",
                        loss.as_str(),
                        mode.as_str(),
                        target.as_str()
                    );
                    assert_eq!(
                        rep.forest.to_json().to_string(),
                        ref_forest,
                        "forest diverged at {at}"
                    );
                    assert_eq!(
                        rep.curve.points.last().unwrap().train_loss,
                        ref_loss,
                        "final train loss diverged at {at}"
                    );
                }
            }
        }
    }
}

#[test]
fn multiclass_forest_holds_k_trees_per_round_and_descends() {
    let ds = synthetic::multiclass_like(300, 3, 91);
    let cfg = matrix_cfg(LossKind::Multiclass, TrainMode::Serial, TargetMode::Fused, 1);
    let rep = train(&cfg, &ds, None).unwrap();
    // n_trees counts rounds; the forest holds K class trees per round
    assert_eq!(rep.forest.n_trees(), cfg.n_trees * cfg.n_classes);
    let first = rep.curve.points.first().unwrap().train_loss;
    let last = rep.curve.points.last().unwrap().train_loss;
    assert!(
        last < first,
        "softmax loss did not descend: {first} -> {last}"
    );
    // round 0 starts at the uniform-softmax loss ln K
    assert!(
        (first - (3.0f64).ln()).abs() < 0.05,
        "round-0 loss {first} is far from ln 3"
    );
}

// ------------------------------------------------ adaptive-step determinism

/// Drive a core through an explicit staleness trace: each push claims
/// `based_on = version − τ`, so the accept path sees exactly the τ we
/// script (clamped at the version floor early on). Trees are built from
/// the current snapshot — only the *accounting* is stale, which is all
/// the step rule reads.
fn drive_stale(cfg: &TrainConfig, ds: &Dataset, taus: &[u64]) -> (ServerCore, Vec<u64>) {
    let binned = Arc::new(BinnedDataset::from_dataset(ds, cfg.max_bins).unwrap());
    let mut core =
        ServerCore::new(cfg, ds, binned.clone(), None, GradientEngine::native()).unwrap();
    let mut rng = Rng::new(902);
    let mut realized = Vec::new();
    for &tau in taus {
        let s = core.snapshot();
        let tree = build_tree(&binned, &s.rows, &s.grad, &s.hess, &cfg.tree, &mut rng);
        let version = core.n_trees() as u64;
        let out = core.apply_tree(tree, version.saturating_sub(tau)).unwrap();
        assert!(out.accepted, "unbounded-staleness core rejected a push");
        realized.push(out.staleness);
    }
    (core, realized)
}

fn adaptive_core_cfg() -> TrainConfig {
    let mut cfg = TrainConfig::default();
    cfg.step = StepMode::Adaptive;
    cfg.step_length = 0.3;
    cfg.tree.max_leaves = 8;
    cfg.max_bins = 16;
    cfg.eval_every = 8;
    cfg
}

#[test]
fn the_same_staleness_trace_replays_to_a_bit_identical_adaptive_forest() {
    let cfg = adaptive_core_cfg();
    let ds = synthetic::realsim_like(240, 41);
    let taus: Vec<u64> = (0..24).map(|i| [0u64, 1, 3, 0, 7][i % 5]).collect();
    let (a, ta) = drive_stale(&cfg, &ds, &taus);
    let (b, tb) = drive_stale(&cfg, &ds, &taus);
    assert_eq!(ta, tb, "realized staleness traces diverged");
    assert!(ta.iter().any(|&t| t > 0), "trace never went stale");
    assert_eq!(
        a.forest.to_json().to_string(),
        b.forest.to_json().to_string(),
        "same trace, different forest"
    );
    assert_eq!(a.steps.samples, b.steps.samples);
    // the recorded per-tree v IS the rule's output — pure in τ
    for (i, &tau) in ta.iter().enumerate() {
        let want = StepMode::Adaptive.effective(cfg.step_length, tau);
        assert_eq!(a.forest.trees[i].0, want, "tree {i} at tau={tau}");
        assert_eq!(a.steps.samples[i], want, "steps stat {i} at tau={tau}");
    }
    assert!(
        a.steps.min() < cfg.step_length,
        "stale pushes must shrink the effective step"
    );
}

#[test]
fn adaptive_with_an_all_zero_trace_is_exactly_fixed() {
    // under the determinism envelope every accepted push has τ=0, and
    // v/(1+0) is the IEEE identity — adaptive and fixed must produce the
    // same bytes, not merely close ones
    let ds = synthetic::realsim_like(280, 43);
    let mk = |step: StepMode| {
        let mut cfg = matrix_cfg(LossKind::Logistic, TrainMode::Async, TargetMode::Fused, 1);
        cfg.n_trees = 24;
        cfg.workers = 3;
        cfg.step = step;
        train(&cfg, &ds, None).unwrap()
    };
    let fixed = mk(StepMode::Fixed);
    let adaptive = mk(StepMode::Adaptive);
    assert_eq!(
        adaptive.forest.to_json().to_string(),
        fixed.forest.to_json().to_string(),
        "zero-staleness adaptive diverged from fixed"
    );
    assert_eq!(
        adaptive.curve.points.last().unwrap().train_loss,
        fixed.curve.points.last().unwrap().train_loss
    );
    // every recorded effective step is the configured constant
    assert!(adaptive.steps.samples.iter().all(|&v| v == 0.3));
    assert_eq!(adaptive.steps.min(), 0.3);
}

#[test]
fn checkpoint_resume_under_adaptive_step_is_bit_identical() {
    let ds = synthetic::realsim_like(300, 47);
    let dir = std::env::temp_dir().join("asgbdt_loss_adaptive_resume");
    std::fs::create_dir_all(&dir).unwrap();
    // serial + adaptive is an invalid combo (no staleness to adapt to),
    // so the resume matrix is sync + async
    for mode in [TrainMode::Sync, TrainMode::Async] {
        let mut cfg = matrix_cfg(LossKind::Logistic, mode, TargetMode::Fused, 1);
        cfg.n_trees = 40;
        cfg.workers = 3;
        cfg.step = StepMode::Adaptive;
        cfg.eval_every = 10;
        cfg.checkpoint_every = 20;
        cfg.checkpoint_path = Some(dir.join(format!("ck_{}.sgbdt", mode.as_str())));
        let full = train(&cfg, &ds, None).unwrap();
        assert_eq!(full.trees_accepted, 40);
        let ck = artifact::load(&artifact::checkpoint_file(
            cfg.checkpoint_path.as_ref().unwrap(),
            20,
        ))
        .unwrap();
        assert_eq!(ck.loss, "logistic");
        let resumed = train_resumed(&cfg, &ds, None, Some(&ck)).unwrap();
        assert_eq!(
            resumed.forest.to_json().to_string(),
            full.forest.to_json().to_string(),
            "{mode:?}: adaptive resume diverged"
        );
        assert_eq!(
            resumed.curve.points.last().unwrap().train_loss,
            full.curve.points.last().unwrap().train_loss,
            "{mode:?}"
        );
    }
}

#[test]
fn serial_mode_refuses_the_adaptive_step_by_naming_both_knobs() {
    let mut cfg = TrainConfig::default();
    cfg.mode = TrainMode::Serial;
    cfg.step = StepMode::Adaptive;
    let msg = cfg.validate().unwrap_err().to_string();
    assert!(
        msg.contains("step=adaptive") && msg.contains("mode=serial"),
        "error must name both knobs: {msg}"
    );
}
