#!/usr/bin/env python3
"""Generate tests/fixtures/golden.sgbdt — the committed golden artifact.

An independent (Python) implementation of the `.sgbdt` v1 writer, so the
golden bytes pin the Rust reader against the documented layout (DESIGN.md
S16) rather than against the Rust writer's own output. Model: base score
0.5 plus one stump (feature 0, threshold 2.0, v=0.5, leaves -1.0 / +1.0),
one binned feature with uppers [0.0, 2.0, inf].

Re-run only on a deliberate schema bump:  python3 make_golden.py
"""

import json
import math
import struct
from pathlib import Path

MAGIC = b"SGBDTART"
SCHEMA_VERSION = 1


def fnv64(data: bytes) -> int:
    # FNV-1a 64: must match io/artifact.rs (pinned there against the
    # published vectors fnv64(b"") and fnv64(b"a"))
    h = 0xCBF29CE484222325
    for b in data:
        h ^= b
        h = (h * 0x100000001B3) & 0xFFFFFFFFFFFFFFFF
    return h


def hex16(v: int) -> str:
    return f"{v:016x}"


assert hex16(fnv64(b"")) == "cbf29ce484222325"
assert hex16(fnv64(b"a")) == "af63dc4c8601ec8c"

u32 = lambda v: struct.pack("<I", v)
u64 = lambda v: struct.pack("<Q", v)
f32 = lambda v: struct.pack("<f", v)

# forest section: u64 n_trees; per tree: f32 v, u32 n_nodes, then the
# BFS SoA arrays feature[] u32, bin[] u8, threshold[] f32, left[] u32,
# leaf_value[] f32 (left == 0 marks a leaf; right is implicitly left+1)
forest = b"".join(
    [
        u64(1),
        f32(0.5),  # step length v
        u32(3),  # nodes: root split + two leaves
        u32(0) + u32(0) + u32(0),  # feature
        bytes([1, 0, 0]),  # bin
        f32(2.0) + f32(0.0) + f32(0.0),  # threshold
        u32(1) + u32(0) + u32(0),  # left (0 = leaf)
        f32(0.0) + f32(-1.0) + f32(1.0),  # leaf_value
    ]
)

# cuts section: u64 n_features; per feature: u8 zero_bin, u32 n_uppers,
# uppers[] f32
cuts = b"".join([u64(1), bytes([0]), u32(3), f32(0.0) + f32(2.0) + f32(math.inf)])

payload = forest + cuts
manifest = json.dumps(
    {
        "format": "sgbdt",
        "schema_version": SCHEMA_VERSION,
        "config": hex16(0),
        "seed": hex16(42),
        "n_trees": 1,
        "loss": "logistic",
        "base_score": 0.5,
        "cut_digest": hex16(fnv64(cuts)),
        "payload_len": len(payload),
        "sections": [
            {
                "name": "forest",
                "offset": 0,
                "len": len(forest),
                "checksum": hex16(fnv64(forest)),
            },
            {
                "name": "cuts",
                "offset": len(forest),
                "len": len(cuts),
                "checksum": hex16(fnv64(cuts)),
            },
        ],
        "provenance": {"build": "make_golden.py", "train_secs": 0.0},
    },
    separators=(",", ":"),
).encode()

out = Path(__file__).parent / "golden.sgbdt"
out.write_bytes(MAGIC + u64(len(manifest)) + manifest + payload)
print(f"wrote {out} ({out.stat().st_size} bytes)")
