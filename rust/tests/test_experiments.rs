//! Experiment-driver integration: dispatch, output files, and the cheap
//! drivers end-to-end. (The heavier per-figure smoke runs live as unit
//! tests inside each driver module; this file covers the shared surface.)

use asgbdt::experiments::{self, Scale};

#[test]
fn dispatch_rejects_unknown_ids() {
    let out = std::env::temp_dir().join("asgbdt_it_exp");
    assert!(experiments::run("fig99", Scale::Smoke, &out).is_err());
    assert!(experiments::run("", Scale::Smoke, &out).is_err());
}

#[test]
fn all_ids_dispatchable() {
    // every advertised id must be routed (checked by name only — the
    // heavy bodies are exercised in their module tests)
    for id in experiments::all_ids() {
        assert!(
            ["fig4", "fig5", "fig6", "fig7", "fig8", "fig9", "fig10", "ablation"]
                .contains(id)
        );
    }
    assert_eq!(experiments::all_ids().len(), 8);
}

#[test]
fn fig4_writes_expected_csv_columns() {
    let out = std::env::temp_dir().join("asgbdt_it_exp_fig4");
    experiments::run("fig4", Scale::Smoke, &out).unwrap();
    let body = std::fs::read_to_string(out.join("fig4_diversity.csv")).unwrap();
    let header = body.lines().next().unwrap();
    assert_eq!(
        header,
        "dataset,rate,omega,delta,rho,qprime_density_analytic,qprime_density_empirical"
    );
    // 2 datasets x 4 smoke rates = 8 data rows
    assert_eq!(body.lines().count(), 9);
    // analytic and empirical densities agree loosely on every row
    for line in body.lines().skip(1) {
        let cols: Vec<&str> = line.split(',').collect();
        let analytic: f64 = cols[5].parse().unwrap();
        let empirical: f64 = cols[6].parse().unwrap();
        assert!(
            (analytic - empirical).abs() < 0.05,
            "analytic {analytic} vs empirical {empirical}"
        );
    }
    std::fs::remove_dir_all(&out).ok();
}

#[test]
fn fig10_summary_has_paper_shape() {
    let out = std::env::temp_dir().join("asgbdt_it_exp_fig10");
    let j = experiments::run("fig10", Scale::Smoke, &out).unwrap();
    let realsim = j.get("realsim").expect("realsim workload");
    let a = realsim.req_f64("asynch_speedup_32").unwrap();
    let l = realsim.req_f64("lightgbm_speedup_32").unwrap();
    let d = realsim.req_f64("dimboost_speedup_32").unwrap();
    assert!(a > l, "async {a:.1} must beat lightgbm {l:.1}");
    assert!(a > d, "async {a:.1} must beat dimboost {d:.1}");
    assert!(realsim.req_f64("eq13_upper_bound").unwrap() > 1.0);
    std::fs::remove_dir_all(&out).ok();
}
