//! Parameter-server invariants under real concurrency: version
//! monotonicity, exact tree accounting, clean shutdown, rejection
//! bookkeeping, and failure injection (dead workers).
//!
//! Each worker gets a single-thread scoped build executor (the serial
//! build path) — the build-parallel matrix lives in
//! `tests/test_build_pool.rs`.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc;
use std::sync::Arc;

use asgbdt::config::TrainConfig;
use asgbdt::data::synthetic;
use asgbdt::ps::{run_worker, Board, ServerCore, TargetSnapshot};
use asgbdt::runtime::GradientEngine;
use asgbdt::testkit::binned_for;
use asgbdt::tree::TreeParams;
use asgbdt::util::Executor;

fn mini_cfg(workers: usize, n_trees: usize) -> TrainConfig {
    let mut cfg = TrainConfig::default();
    cfg.workers = workers;
    cfg.n_trees = n_trees;
    cfg.step_length = 0.2;
    cfg.sampling_rate = 0.9;
    cfg.tree.max_leaves = 8;
    cfg.max_bins = 16;
    cfg.eval_every = n_trees;
    cfg
}

#[test]
fn board_versions_are_monotone_under_concurrent_pulls() {
    let board = Arc::new(Board::new());
    let max_seen = Arc::new(AtomicU64::new(0));
    std::thread::scope(|s| {
        // pullers assert monotone observation
        let mut handles = Vec::new();
        for _ in 0..4 {
            let b = board.clone();
            let seen = max_seen.clone();
            handles.push(s.spawn(move || {
                let mut last = 0u64;
                while !b.is_shutdown() {
                    let snap = b.pull();
                    assert!(snap.version >= last, "version went backwards");
                    last = snap.version;
                    seen.fetch_max(last, Ordering::Relaxed);
                }
            }));
        }
        for v in 1..=500u64 {
            board.publish(TargetSnapshot {
                version: v,
                grad: Arc::new(vec![0.0; 8]),
                hess: Arc::new(vec![0.0; 8]),
                rows: Arc::new(vec![0]),
            });
        }
        board.request_shutdown();
    });
    assert!(max_seen.load(Ordering::Relaxed) <= 500);
}

#[test]
fn server_accepts_exactly_n_trees_with_racing_workers() {
    let ds = synthetic::realsim_like(250, 1);
    let cfg = mini_cfg(6, 25);
    let binned = binned_for(&ds, &cfg);
    let mut core =
        ServerCore::new(&cfg, &ds, binned.clone(), None, GradientEngine::native()).unwrap();
    let board = Board::new();
    board.publish(core.snapshot());
    let (tx, rx) = mpsc::channel();

    std::thread::scope(|s| {
        for wid in 0..cfg.workers {
            let tx = tx.clone();
            let b = binned.clone();
            let board_ref = &board;
            let params = TreeParams { max_leaves: 4, ..Default::default() };
            s.spawn(move || {
                let exec = Executor::scoped(1);
                run_worker(wid, board_ref, b, params, &exec, tx, 99)
            });
        }
        drop(tx);
        while core.n_trees() < cfg.n_trees {
            let push = rx.recv().unwrap();
            let out = core.apply_tree(push.tree, push.based_on).unwrap();
            if out.accepted {
                board.publish(core.snapshot());
            }
        }
        board.request_shutdown();
        while rx.try_recv().is_ok() {}
    });

    assert_eq!(core.n_trees(), 25);
    assert_eq!(core.forest.n_trees(), 25);
    // staleness recorded for every accepted push
    assert_eq!(core.staleness.samples.len(), 25);
}

#[test]
fn dead_worker_does_not_wedge_training() {
    // failure injection: one worker dies immediately (drops its sender);
    // the remaining workers must still complete the run.
    let ds = synthetic::realsim_like(200, 2);
    let cfg = mini_cfg(3, 12);
    let binned = binned_for(&ds, &cfg);
    let mut core =
        ServerCore::new(&cfg, &ds, binned.clone(), None, GradientEngine::native()).unwrap();
    let board = Board::new();
    board.publish(core.snapshot());
    let (tx, rx) = mpsc::channel();

    std::thread::scope(|s| {
        // the dead worker: never sends anything
        drop(tx.clone());
        // two live workers
        for wid in 0..2 {
            let tx = tx.clone();
            let b = binned.clone();
            let board_ref = &board;
            let params = TreeParams { max_leaves: 4, ..Default::default() };
            s.spawn(move || {
                let exec = Executor::scoped(1);
                run_worker(wid, board_ref, b, params, &exec, tx, 5)
            });
        }
        drop(tx);
        while core.n_trees() < cfg.n_trees {
            let push = rx.recv().expect("live workers keep pushing");
            if core.apply_tree(push.tree, push.based_on).unwrap().accepted {
                board.publish(core.snapshot());
            }
        }
        board.request_shutdown();
        while rx.try_recv().is_ok() {}
    });
    assert_eq!(core.n_trees(), 12);
}

#[test]
fn staleness_bound_filters_but_run_completes() {
    let ds = synthetic::realsim_like(200, 3);
    let mut cfg = mini_cfg(4, 15);
    cfg.max_staleness = Some(1);
    let binned = binned_for(&ds, &cfg);
    let mut core =
        ServerCore::new(&cfg, &ds, binned.clone(), None, GradientEngine::native()).unwrap();
    let board = Board::new();
    board.publish(core.snapshot());
    let (tx, rx) = mpsc::channel();

    std::thread::scope(|s| {
        for wid in 0..cfg.workers {
            let tx = tx.clone();
            let b = binned.clone();
            let board_ref = &board;
            let params = TreeParams { max_leaves: 4, ..Default::default() };
            s.spawn(move || {
                let exec = Executor::scoped(1);
                run_worker(wid, board_ref, b, params, &exec, tx, 17)
            });
        }
        drop(tx);
        while core.n_trees() < cfg.n_trees {
            let push = rx.recv().unwrap();
            if core.apply_tree(push.tree, push.based_on).unwrap().accepted {
                board.publish(core.snapshot());
            }
        }
        board.request_shutdown();
        while rx.try_recv().is_ok() {}
    });
    assert_eq!(core.n_trees(), 15);
    assert!(core.staleness.max() <= 1, "bound violated: {}", core.staleness.max());
}

#[test]
fn snapshot_rows_match_weight_support() {
    let ds = synthetic::realsim_like(300, 4);
    let cfg = mini_cfg(1, 3);
    let binned = binned_for(&ds, &cfg);
    let core =
        ServerCore::new(&cfg, &ds, binned, None, GradientEngine::native()).unwrap();
    let snap = core.snapshot();
    // every sampled row has a nonzero hessian (gradient-mode weight) and
    // every unsampled row is exactly zero in both targets
    for r in 0..ds.n_rows() {
        let sampled = snap.rows.binary_search(&(r as u32)).is_ok();
        if sampled {
            assert!(snap.hess[r] > 0.0);
        } else {
            assert_eq!(snap.grad[r], 0.0);
            assert_eq!(snap.hess[r], 0.0);
        }
    }
}
