//! AOT runtime parity: the PJRT-executed HLO artifacts (JAX/Pallas compile
//! path) must match the pure-Rust oracle bit-for-bit within f32 tolerance.
//!
//! Requires `make artifacts` (the Makefile `test` target guarantees the
//! ordering); every test degrades to an explicit skip message when the
//! artifacts are absent so `cargo test` alone still passes.

use std::path::Path;

use asgbdt::loss::logistic;
use asgbdt::runtime::{EngineKind, GradientEngine, Manifest};
use asgbdt::util::Rng;

const DIR: &str = "artifacts";

fn aot() -> Option<GradientEngine> {
    if !Manifest::exists(Path::new(DIR)) {
        eprintln!("SKIP: no artifacts/ — run `make artifacts`");
        return None;
    }
    let e = GradientEngine::aot(Path::new(DIR)).expect("aot engine");
    assert_eq!(e.kind(), EngineKind::Aot);
    Some(e)
}

fn inputs(n: usize, seed: u64) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
    let mut rng = Rng::new(seed);
    let f: Vec<f32> = (0..n).map(|_| (rng.normal() * 3.0) as f32).collect();
    let y: Vec<f32> = (0..n)
        .map(|_| if rng.bernoulli(0.5) { 1.0 } else { 0.0 })
        .collect();
    let w: Vec<f32> = (0..n)
        .map(|_| if rng.bernoulli(0.2) { 0.0 } else { rng.exponential() as f32 })
        .collect();
    (f, y, w)
}

#[test]
fn aot_grad_hess_matches_native_exact_bucket() {
    let Some(mut e) = aot() else { return };
    let (f, y, w) = inputs(4096, 1);
    let a = e.grad_hess_loss(&f, &y, &w).unwrap();
    let n = logistic::grad_hess_loss(&f, &y, &w);
    for i in 0..f.len() {
        assert!((a.grad[i] - n.grad[i]).abs() < 1e-4, "grad[{i}]");
        assert!((a.hess[i] - n.hess[i]).abs() < 1e-4, "hess[{i}]");
    }
    assert!((a.loss_sum - n.loss_sum).abs() / n.loss_sum.max(1.0) < 1e-4);
    assert!((a.weight_sum - n.weight_sum).abs() / n.weight_sum.max(1.0) < 1e-5);
}

#[test]
fn aot_handles_padding_buckets() {
    let Some(mut e) = aot() else { return };
    // 5000 is not a bucket: the engine pads to 16384
    let (f, y, w) = inputs(5_000, 2);
    let a = e.grad_hess_loss(&f, &y, &w).unwrap();
    let n = logistic::grad_hess_loss(&f, &y, &w);
    assert_eq!(a.grad.len(), 5_000);
    for i in 0..5_000 {
        assert!((a.grad[i] - n.grad[i]).abs() < 1e-4);
    }
    assert!((a.loss_sum - n.loss_sum).abs() / n.loss_sum.max(1.0) < 1e-4);
}

#[test]
fn aot_chunking_beyond_largest_bucket() {
    // a manifest that only declares the 4096 bucket forces the chunked
    // path on a 10_000-row request.
    if !Manifest::exists(Path::new(DIR)) {
        eprintln!("SKIP: no artifacts/");
        return;
    }
    let tmp = std::env::temp_dir().join("asgbdt_chunk_manifest");
    std::fs::create_dir_all(&tmp).unwrap();
    for name in ["grad_hess", "eval"] {
        std::fs::copy(
            Path::new(DIR).join(format!("{name}_4096.hlo.txt")),
            tmp.join(format!("{name}_4096.hlo.txt")),
        )
        .unwrap();
    }
    std::fs::write(
        tmp.join("manifest.json"),
        r#"{"format":"hlo-text","version":1,"buckets":[4096],"block":1024,
            "entries":[{"name":"grad_hess","n":4096,"file":"grad_hess_4096.hlo.txt"},
                       {"name":"eval","n":4096,"file":"eval_4096.hlo.txt"}]}"#,
    )
    .unwrap();
    let mut e = GradientEngine::aot(&tmp).unwrap();
    let (f, y, w) = inputs(10_000, 3);
    let a = e.grad_hess_loss(&f, &y, &w).unwrap();
    let n = logistic::grad_hess_loss(&f, &y, &w);
    assert_eq!(a.grad.len(), 10_000);
    for i in (0..10_000).step_by(977) {
        assert!((a.grad[i] - n.grad[i]).abs() < 1e-4, "grad[{i}]");
    }
    assert!((a.loss_sum - n.loss_sum).abs() / n.loss_sum.max(1.0) < 1e-4);
    let (al, ae, aw) = e.eval_sums(&f, &y, &w).unwrap();
    let (nl, ne, nw) = logistic::eval_sums(&f, &y, &w);
    assert!((al - nl).abs() / nl.max(1.0) < 1e-4);
    assert!((ae - ne).abs() < 1.0); // error counts are integers in spirit
    assert!((aw - nw).abs() / nw.max(1.0) < 1e-5);
    std::fs::remove_dir_all(&tmp).ok();
}

#[test]
fn aot_eval_matches_native() {
    let Some(mut e) = aot() else { return };
    let (f, y, w) = inputs(4096, 4);
    let (al, ae, aw) = e.eval_sums(&f, &y, &w).unwrap();
    let (nl, ne, nw) = logistic::eval_sums(&f, &y, &w);
    assert!((al - nl).abs() / nl.max(1.0) < 1e-4, "{al} vs {nl}");
    assert!((ae - ne).abs() / ne.max(1.0) < 1e-4, "{ae} vs {ne}");
    assert!((aw - nw).abs() / nw.max(1.0) < 1e-5);
}

#[test]
fn aot_extreme_values_finite() {
    let Some(mut e) = aot() else { return };
    let n = 4096;
    let f: Vec<f32> = (0..n).map(|i| if i % 2 == 0 { 80.0 } else { -80.0 }).collect();
    let y: Vec<f32> = (0..n).map(|i| ((i / 2) % 2) as f32).collect();
    let w = vec![1.0f32; n];
    let a = e.grad_hess_loss(&f, &y, &w).unwrap();
    assert!(a.grad.iter().all(|g| g.is_finite()));
    assert!(a.hess.iter().all(|h| h.is_finite()));
    assert!(a.loss_sum.is_finite());
}

#[test]
fn aot_reused_engine_is_consistent_across_calls() {
    let Some(mut e) = aot() else { return };
    let (f, y, w) = inputs(4096, 5);
    let a = e.grad_hess_loss(&f, &y, &w).unwrap();
    let b = e.grad_hess_loss(&f, &y, &w).unwrap();
    assert_eq!(a.grad, b.grad);
    assert_eq!(a.loss_sum, b.loss_sum);
}

#[test]
fn full_training_run_with_aot_engine() {
    // the integration that matters: the async trainer on the AOT path
    if !Manifest::exists(Path::new(DIR)) {
        eprintln!("SKIP: no artifacts/");
        return;
    }
    use asgbdt::config::TrainConfig;
    use asgbdt::coordinator::train_async;
    use asgbdt::data::synthetic;
    let ds = synthetic::realsim_like(500, 6);
    let mut cfg = TrainConfig::default();
    cfg.workers = 2;
    cfg.n_trees = 12;
    cfg.step_length = 0.2;
    cfg.tree.max_leaves = 8;
    cfg.max_bins = 16;
    cfg.eval_every = 4;
    cfg.artifact_dir = DIR.into();
    let rep = train_async(&cfg, &ds, None).unwrap();
    assert_eq!(rep.engine, EngineKind::Aot, "AOT engine must be active");
    let first = rep.curve.points.first().unwrap().train_loss;
    let last = rep.curve.points.last().unwrap().train_loss;
    assert!(last < first, "AOT training did not descend");
}
