//! Simulator integration: the Figure 10 shapes the paper reports must
//! hold across seeds and workloads, not just at one lucky draw.

use asgbdt::simulator::{
    eq13_upper_bound, simulate_async_ps, simulate_dimboost, simulate_lightgbm_fp,
    simulate_sharded_ps_trace, speedup_sweep, ClusterSpec, PhaseTimes, SystemKind,
};

fn spec(w: usize, seed: u64) -> ClusterSpec {
    let mut s = ClusterSpec::new(w);
    s.seed = seed;
    s
}

#[test]
fn paper_headline_realsim_across_seeds() {
    // paper: asynch 14–22x, LightGBM 5–7x, DimBoost 4–6x at 32 workers
    let t = PhaseTimes::realsim_like();
    for seed in [1u64, 7, 42] {
        let rows = speedup_sweep(&t, &[32], 300, 0.15, seed);
        let get = |k: SystemKind| rows.iter().find(|r| r.system == k).unwrap().speedup;
        let a = get(SystemKind::AsynchSgbdt);
        let l = get(SystemKind::LightGbmFp);
        let d = get(SystemKind::DimBoost);
        assert!((12.0..=26.0).contains(&a), "seed {seed}: async {a:.1}");
        assert!((4.0..=9.0).contains(&l), "seed {seed}: lightgbm {l:.1}");
        assert!((3.0..=8.0).contains(&d), "seed {seed}: dimboost {d:.1}");
        assert!(a > l && l > d, "seed {seed}: ordering {a:.1} {l:.1} {d:.1}");
    }
}

#[test]
fn paper_headline_e2006() {
    // paper: asynch-SGBDT ~20x on E2006 at 32 workers
    let t = PhaseTimes::e2006_like();
    let rows = speedup_sweep(&t, &[32], 300, 0.15, 5);
    let a = rows
        .iter()
        .find(|r| r.system == SystemKind::AsynchSgbdt)
        .unwrap()
        .speedup;
    assert!((15.0..=30.0).contains(&a), "e2006 async {a:.1}");
}

#[test]
fn speedup_monotone_in_workers_for_async() {
    let t = PhaseTimes::realsim_like();
    let rows = speedup_sweep(&t, &[1, 2, 4, 8, 16, 32], 200, 0.15, 9);
    let mut last = 0.0;
    for r in rows.iter().filter(|r| r.system == SystemKind::AsynchSgbdt) {
        assert!(r.speedup >= last * 0.98, "async speedup dipped at {}", r.workers);
        last = r.speedup;
    }
}

#[test]
fn the_gap_widens_with_scale() {
    // "Especially with the increase of the number of machines or workers,
    // the gap is expanded" (§VI.C)
    let t = PhaseTimes::realsim_like();
    let gap_at = |w: usize| {
        let a = simulate_async_ps(&spec(1, 3), &t, 150).wall_secs
            / simulate_async_ps(&spec(w, 3), &t, 150).wall_secs;
        let l = simulate_lightgbm_fp(&spec(1, 3), &t, 150).wall_secs
            / simulate_lightgbm_fp(&spec(w, 3), &t, 150).wall_secs;
        a - l
    };
    assert!(gap_at(32) > gap_at(8), "gap should widen with workers");
}

#[test]
fn heterogeneity_hurts_sync_more_than_async() {
    let t = PhaseTimes::realsim_like();
    let homo = ClusterSpec { speed_cv: 0.0, ..spec(16, 4) };
    let hetero = ClusterSpec { speed_cv: 0.4, ..spec(16, 4) };
    let async_ratio = simulate_async_ps(&hetero, &t, 150).wall_secs
        / simulate_async_ps(&homo, &t, 150).wall_secs;
    let sync_ratio = simulate_lightgbm_fp(&hetero, &t, 150).wall_secs
        / simulate_lightgbm_fp(&homo, &t, 150).wall_secs;
    assert!(
        sync_ratio > async_ratio,
        "stragglers must hurt the barrier more: sync x{sync_ratio:.2} vs async x{async_ratio:.2}"
    );
}

#[test]
fn eq13_bound_predicts_async_saturation() {
    let t = PhaseTimes::realsim_like();
    let bound = eq13_upper_bound(&t, &ClusterSpec::new(32));
    // throughput at 4x the bound is within 25% of throughput at the bound:
    // beyond #workers = bound, adding workers buys almost nothing
    let at = |w: usize| simulate_async_ps(&spec(w, 6), &t, 300).trees_per_sec();
    let w_bound = (bound.ceil() as usize).max(1);
    let tp_bound = at(w_bound);
    let tp_4x = at(4 * w_bound);
    assert!(
        tp_4x < tp_bound * 1.25,
        "Eq.13: tp at bound {tp_bound:.1} vs 4x {tp_4x:.1} (bound {bound:.0})"
    );
}

#[test]
fn sharded_tau_distribution_matches_the_single_board() {
    // the staleness a worker observes is arrival-driven (pull → build →
    // push), so splitting the server into shards that publish composed
    // versions must not move the τ distribution: same support, same
    // per-acceptance trace, same mean — only the service time changes
    let t = PhaseTimes::realsim_like();
    for workers in [8usize, 16] {
        let (base, trace1) = simulate_sharded_ps_trace(&spec(workers, 11), &t, 200, 1);
        assert_eq!(trace1.len(), 200, "one τ sample per acceptance");
        // support sanity: τ is bounded by the version counter, and real
        // asynchrony shows up (stale pushes exist at ≥8 racing workers)
        assert!(trace1.iter().all(|&tau| tau < 200), "τ exceeded the version counter");
        assert!(trace1.iter().any(|&tau| tau > 0), "no staleness at {workers} workers");
        for shards in [2usize, 4, 8] {
            let (r, tr) = simulate_sharded_ps_trace(&spec(workers, 11), &t, 200, shards);
            assert_eq!(
                tr, trace1,
                "τ trace diverged at {shards} shards / {workers} workers"
            );
            assert_eq!(
                r.mean_staleness, base.mean_staleness,
                "mean τ diverged at {shards} shards / {workers} workers"
            );
        }
    }
    // monotonicity across scale survives sharding: more workers in
    // flight ⇒ staler pushes, at 1 shard and at 4 alike
    for shards in [1usize, 4] {
        let mean_at = |w: usize| {
            simulate_sharded_ps_trace(&spec(w, 11), &t, 200, shards)
                .0
                .mean_staleness
        };
        assert!(
            mean_at(32) > mean_at(8),
            "mean τ must grow with workers at {shards} shards"
        );
    }
}

#[test]
fn composed_shard_versions_are_monotone_under_concurrent_publishes() {
    use asgbdt::ps::{compose_version, ShardVersions};
    use std::sync::atomic::{AtomicBool, Ordering};

    // composition is the min over cells; empty composes to the init version
    assert_eq!(compose_version(&[3, 5, 4]), 3);
    assert_eq!(compose_version(&[]), 0);

    let sv = ShardVersions::new(4);
    let done = AtomicBool::new(false);
    std::thread::scope(|s| {
        let reader = {
            let sv = &sv;
            let done = &done;
            s.spawn(move || {
                let mut last = 0u64;
                while !done.load(Ordering::Acquire) {
                    let c = sv.composed();
                    assert!(c >= last, "composed version went backwards: {c} < {last}");
                    // cells are monotone, so a composed read can never
                    // exceed any cell observed at-or-after it
                    for shard in 0..sv.n_shards() {
                        assert!(c <= sv.shard_version(shard), "composed {c} passed a cell");
                    }
                    last = c;
                }
                last
            })
        };
        let publishers: Vec<_> = (0..4usize)
            .map(|shard| {
                let sv = &sv;
                s.spawn(move || {
                    for v in 1..=500u64 {
                        sv.publish(shard, v);
                    }
                })
            })
            .collect();
        for p in publishers {
            p.join().unwrap();
        }
        done.store(true, Ordering::Release);
        let last = reader.join().unwrap();
        assert!(last <= 500, "reader saw unpublished composed version {last}");
    });
    // all cells at 500 ⇒ the composition lands exactly on the counter
    assert_eq!(sv.composed(), 500);
}

#[test]
fn dimboost_bottleneck_is_the_server() {
    let t = PhaseTimes::realsim_like();
    let r = simulate_dimboost(&spec(32, 8), &t, 100);
    assert!(
        r.bottleneck_frac > 0.5,
        "central allgather must dominate at 32 workers: {}",
        r.bottleneck_frac
    );
}
