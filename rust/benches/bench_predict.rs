//! Scoring-engine bench: blocked SoA frontier scoring vs the per-row
//! enum walk on the same forest — the two halves of the PR 2 ablation,
//! isolated from training. Also measures the server's single-tree apply
//! primitive (Algorithm 3 step 2), which is what bounds accepted
//! trees/sec once workers outpace the server, and the `microbatch/*`
//! sweep: per-call cost of scoring (and request-time binning) 1/8/64/512
//! rows — the measured basis of the serving `serve_batch` knob
//! (DESIGN.md §15).
use asgbdt::bench_harness::Runner;
use asgbdt::data::{synthetic, BinnedDataset};
use asgbdt::experiments::Scale;
use asgbdt::forest::score::{self, FlatForest, ScratchPool};
use asgbdt::forest::Forest;
use asgbdt::loss::logistic;
use asgbdt::tree::{build_tree_pooled, FlatTree, HistogramPool, TreeParams};
use asgbdt::util::{Executor, PoolMode, Rng};

fn main() {
    let scale = Scale::from_env();
    let n_rows = scale.pick(10_000, 100_000);
    let n_trees = scale.pick(30, 100);
    let mut r = Runner::new("predict");

    let ds = synthetic::realsim_like(n_rows, 7);
    let b = BinnedDataset::from_dataset(&ds, 64).unwrap();
    let w = vec![1.0f32; ds.n_rows()];
    let mut f = vec![0.0f32; ds.n_rows()];
    let mut forest = Forest::new(0.0);
    let rows: Vec<u32> = (0..ds.n_rows() as u32).collect();
    let params = TreeParams {
        max_leaves: 64,
        feature_rate: 0.8,
        ..Default::default()
    };
    let mut rng = Rng::new(3);
    let mut hpool = HistogramPool::new(b.total_bins());
    for _ in 0..n_trees {
        let gh = logistic::grad_hess_loss(&f, &ds.y, &w);
        let t = build_tree_pooled(&b, &rows, &gh.grad, &gh.hess, &params, &mut rng, &mut hpool);
        for (fr, row) in f.iter_mut().zip(0..ds.n_rows()) {
            *fr += 0.1 * t.predict_binned(&b, row);
        }
        forest.push(0.1, t);
    }
    println!(
        "forest: {} trees on {} rows x {} features ({} nnz)",
        forest.n_trees(),
        ds.n_rows(),
        ds.n_features(),
        ds.x.nnz()
    );

    // whole-forest batch scoring, both engines
    let flat = FlatForest::from_forest(&forest);
    let mut pool = ScratchPool::new();
    r.bench("forest/per_row_enum/binned", || {
        forest.predict_all_binned_per_row(&b)
    });
    r.bench("forest/per_row_enum/raw", || forest.predict_all_per_row(&ds.x));
    for threads in [1usize, 2, 4] {
        let exec = Executor::scoped(threads);
        r.bench(&format!("forest/flat_blocked/binned_t{threads}"), || {
            flat.predict_all_binned(&b, &exec, &mut pool)
        });
        r.bench(&format!("forest/flat_blocked/raw_t{threads}"), || {
            flat.predict_all_raw(&ds.x, &exec, &mut pool)
        });
    }
    // compile cost, for context: flattening is O(nodes), paid once/tree
    r.bench("flatten/forest", || FlatForest::from_forest(&forest));

    // the server's step 2: apply one tree to F (train-side, bin space)
    let (v, tree) = forest.trees.last().unwrap().clone();
    let ft = FlatTree::from_tree(&tree);
    let mut fv = vec![0.0f32; ds.n_rows()];
    r.bench("apply/per_row_enum", || {
        for (fr, row) in fv.iter_mut().zip(0..ds.n_rows()) {
            *fr += v * tree.predict_binned(&b, row);
        }
    });
    let mut fv = vec![0.0f32; ds.n_rows()];
    for mode in [PoolMode::Persistent, PoolMode::Scoped] {
        for threads in [1usize, 2, 4] {
            let exec = Executor::new(mode, threads);
            r.bench(
                &format!("apply/flat_blocked_{}_t{threads}", mode.as_str()),
                || score::add_tree_binned(&ft, &b, v, &mut fv, &exec, &mut pool),
            );
        }
    }

    // micro-batch sweep: what one serving-sized call costs. Score and
    // request-time binning are measured separately — their ratio at each
    // size is what the serve_batch knob trades against queue wait.
    let cuts = b.cuts();
    let exec1 = Executor::scoped(1);
    for per_call in [1usize, 8, 64, 512] {
        let idx: Vec<usize> = (0..per_call).map(|i| i % ds.n_rows()).collect();
        let sub = ds.x.select_rows(&idx);
        let batch = cuts.bin_batch(&sub).unwrap();
        let mut margins = Vec::new();
        r.bench(&format!("microbatch/score_rows{per_call}"), || {
            flat.predict_binned_into(&batch, &mut margins, &exec1, &mut pool)
        });
        r.bench(&format!("microbatch/bin_rows{per_call}"), || {
            cuts.bin_batch(&sub).unwrap()
        });
    }
    r.write_csv().unwrap();
}
