//! Full tree builds: histogram strategy (sibling subtraction vs
//! whole-node rebuild), serial vs fork-join vs the feature-parallel
//! engine, by leaves and thread count. The deeper-tree configs (more
//! leaves) are where subtraction pulls furthest ahead: every extra level
//! splits smaller, more unbalanced leaves.
//!
//! The `pool/*` matrix is the worker-side analogue of
//! `bench_ps_throughput`'s accept-path breakdown: persistent-vs-scoped
//! per-tree build cost at 1/2/4/8 threads. A tree build runs dozens of
//! fork-join sections (one sharded histogram per built leaf, one split
//! search per node), so the scoped mode pays dozens of spawn/join
//! cycles per tree where the persistent mode pays condvar wakes on one
//! worker-lifetime pool — the gap is the spawn cost the build pool
//! removes.
use asgbdt::bench_harness::Runner;
use asgbdt::data::{synthetic, BinnedDataset};
use asgbdt::loss::logistic;
use asgbdt::tree::{
    build_tree_feature_parallel, build_tree_forkjoin, build_tree_pooled, HistogramPool,
    HistogramStrategy, TreeParams,
};
use asgbdt::util::{Executor, PoolMode, Rng};

fn main() {
    let mut r = Runner::new("tree_build");
    let ds = synthetic::realsim_like(6_000, 3);
    let b = BinnedDataset::from_dataset(&ds, 64).unwrap();
    let f = vec![0.0f32; ds.n_rows()];
    let w = vec![1.0f32; ds.n_rows()];
    let gh = logistic::grad_hess_loss(&f, &ds.y, &w);
    let rows: Vec<u32> = (0..ds.n_rows() as u32).collect();

    // strategy ablation: same trees, different histogram cost; the gap
    // must widen with tree depth (acceptance gate for the subtraction PR)
    for leaves in [16usize, 64, 256] {
        for strat in [HistogramStrategy::Subtract, HistogramStrategy::Rebuild] {
            let params = TreeParams {
                max_leaves: leaves,
                feature_rate: 0.8,
                strategy: strat,
                ..Default::default()
            };
            let mut rng = Rng::new(5);
            let mut pool = HistogramPool::new(b.total_bins());
            r.bench(&format!("strategy/{}/leaves_{leaves}", strat.as_str()), || {
                build_tree_pooled(&b, &rows, &gh.grad, &gh.hess, &params, &mut rng, &mut pool)
            });
        }
    }

    let params = TreeParams {
        max_leaves: 64,
        feature_rate: 0.8,
        ..Default::default()
    };
    // the sync baseline's cost model: sharded histograms + serial split
    // search on per-section scoped spawns
    for threads in [2usize, 4, 8] {
        let mut rng = Rng::new(5);
        let exec = Executor::scoped(threads);
        r.bench(&format!("forkjoin/threads_{threads}"), || {
            build_tree_forkjoin(&b, &rows, &gh.grad, &gh.hess, &params, &mut rng, &exec)
        });
    }

    // the acceptance matrix: persistent-vs-scoped per-tree build cost for
    // the full feature-parallel engine at 1/2/4/8 threads — at 1 thread
    // both modes are the inline serial build (the no-dispatch floor)
    for mode in [PoolMode::Persistent, PoolMode::Scoped] {
        for threads in [1usize, 2, 4, 8] {
            let exec = Executor::new(mode, threads);
            let mut rng = Rng::new(5);
            let mut pool = HistogramPool::new(b.total_bins());
            r.bench(&format!("pool/{}/threads_{threads}", mode.as_str()), || {
                build_tree_feature_parallel(
                    &b, &rows, &gh.grad, &gh.hess, &params, &mut rng, &exec, &mut pool,
                )
            });
        }
    }
    r.write_csv().unwrap();
}
