//! Full tree builds: serial vs fork-join, by leaves and dataset shape.
use asgbdt::bench_harness::Runner;
use asgbdt::data::{synthetic, BinnedDataset};
use asgbdt::loss::logistic;
use asgbdt::tree::{build_tree, build_tree_forkjoin, TreeParams};
use asgbdt::util::Rng;

fn main() {
    let mut r = Runner::new("tree_build");
    let ds = synthetic::realsim_like(6_000, 3);
    let b = BinnedDataset::from_dataset(&ds, 64).unwrap();
    let f = vec![0.0f32; ds.n_rows()];
    let w = vec![1.0f32; ds.n_rows()];
    let gh = logistic::grad_hess_loss(&f, &ds.y, &w);
    let rows: Vec<u32> = (0..ds.n_rows() as u32).collect();
    for leaves in [16usize, 64, 256] {
        let params = TreeParams { max_leaves: leaves, feature_rate: 0.8, ..Default::default() };
        let mut rng = Rng::new(5);
        r.bench(&format!("serial/leaves_{leaves}"), || {
            build_tree(&b, &rows, &gh.grad, &gh.hess, &params, &mut rng)
        });
    }
    let params = TreeParams { max_leaves: 64, feature_rate: 0.8, ..Default::default() };
    for threads in [2usize, 4, 8] {
        let mut rng = Rng::new(5);
        r.bench(&format!("forkjoin/threads_{threads}"), || {
            build_tree_forkjoin(&b, &rows, &gh.grad, &gh.hess, &params, &mut rng, threads)
        });
    }
    r.write_csv().unwrap();
}
