//! Full tree builds: histogram strategy (sibling subtraction vs
//! whole-node rebuild), serial vs fork-join vs the feature-parallel
//! engine, by leaves and thread count. The deeper-tree configs (more
//! leaves) are where subtraction pulls furthest ahead: every extra level
//! splits smaller, more unbalanced leaves.
use asgbdt::bench_harness::Runner;
use asgbdt::data::{synthetic, BinnedDataset};
use asgbdt::loss::logistic;
use asgbdt::tree::{
    build_tree_feature_parallel, build_tree_forkjoin, build_tree_pooled, HistogramPool,
    HistogramStrategy, TreeParams,
};
use asgbdt::util::Rng;

fn main() {
    let mut r = Runner::new("tree_build");
    let ds = synthetic::realsim_like(6_000, 3);
    let b = BinnedDataset::from_dataset(&ds, 64).unwrap();
    let f = vec![0.0f32; ds.n_rows()];
    let w = vec![1.0f32; ds.n_rows()];
    let gh = logistic::grad_hess_loss(&f, &ds.y, &w);
    let rows: Vec<u32> = (0..ds.n_rows() as u32).collect();

    // strategy ablation: same trees, different histogram cost; the gap
    // must widen with tree depth (acceptance gate for the subtraction PR)
    for leaves in [16usize, 64, 256] {
        for strat in [HistogramStrategy::Subtract, HistogramStrategy::Rebuild] {
            let params = TreeParams {
                max_leaves: leaves,
                feature_rate: 0.8,
                strategy: strat,
                ..Default::default()
            };
            let mut rng = Rng::new(5);
            let mut pool = HistogramPool::new(b.total_bins());
            r.bench(&format!("strategy/{}/leaves_{leaves}", strat.as_str()), || {
                build_tree_pooled(&b, &rows, &gh.grad, &gh.hess, &params, &mut rng, &mut pool)
            });
        }
    }

    let params = TreeParams {
        max_leaves: 64,
        feature_rate: 0.8,
        ..Default::default()
    };
    for threads in [2usize, 4, 8] {
        let mut rng = Rng::new(5);
        r.bench(&format!("forkjoin/threads_{threads}"), || {
            build_tree_forkjoin(&b, &rows, &gh.grad, &gh.hess, &params, &mut rng, threads)
        });
    }
    for threads in [2usize, 4, 8] {
        let mut rng = Rng::new(5);
        let mut pool = HistogramPool::new(b.total_bins());
        r.bench(&format!("feature_parallel/threads_{threads}"), || {
            build_tree_feature_parallel(
                &b, &rows, &gh.grad, &gh.hess, &params, &mut rng, threads, &mut pool,
            )
        });
    }
    r.write_csv().unwrap();
}
