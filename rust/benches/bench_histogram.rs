//! Histogram building — the worker-side hot loop (>90% of tree build).
use asgbdt::bench_harness::Runner;
use asgbdt::data::{synthetic, BinnedDataset};
use asgbdt::loss::logistic;
use asgbdt::tree::histogram::Histogram;

fn main() {
    let mut r = Runner::new("histogram");
    for (name, ds) in [
        ("realsim_4k", synthetic::realsim_like(4_000, 1)),
        ("higgs_4k", synthetic::higgs_like(4_000, 1)),
    ] {
        let b = BinnedDataset::from_dataset(&ds, 64).unwrap();
        let f = vec![0.0f32; ds.n_rows()];
        let w = vec![1.0f32; ds.n_rows()];
        let gh = logistic::grad_hess_loss(&f, &ds.y, &w);
        let rows: Vec<u32> = (0..ds.n_rows() as u32).collect();
        let mut hist = Histogram::zeros(b.total_bins());
        r.bench(&format!("build/{name}/full"), || {
            hist.build(&b, &rows, &gh.grad, &gh.hess)
        });
        let half: Vec<u32> = rows.iter().copied().step_by(2).collect();
        r.bench(&format!("build/{name}/half_rows"), || {
            hist.build(&b, &half, &gh.grad, &gh.hess)
        });
        let mut parent = Histogram::zeros(b.total_bins());
        parent.build(&b, &rows, &gh.grad, &gh.hess);
        let mut sib = Histogram::zeros(b.total_bins());
        sib.build(&b, &half, &gh.grad, &gh.hess);
        let mut child = Histogram::zeros(b.total_bins());
        r.bench(&format!("subtract/{name}"), || {
            child.subtract_from(&parent, &sib)
        });
    }
    r.write_csv().unwrap();
}
