//! Histogram building — the worker-side hot loop (>90% of tree build).
//!
//! The `children/*` groups measure the sibling-subtraction lever directly:
//! producing both child histograms of a split by rebuilding each from its
//! rows vs building only the smaller child and deriving the larger as
//! `parent − small`. The 1/8 : 7/8 partition mirrors the unbalanced
//! splits deep leaf-wise growth produces, where subtraction wins most.
use asgbdt::bench_harness::Runner;
use asgbdt::data::{synthetic, BinnedDataset};
use asgbdt::loss::logistic;
use asgbdt::tree::build_histogram_sharded;
use asgbdt::tree::histogram::{Histogram, HistogramPool};
use asgbdt::util::{Executor, PoolMode};

fn main() {
    let mut r = Runner::new("histogram");
    for (name, ds) in [
        ("realsim_4k", synthetic::realsim_like(4_000, 1)),
        ("higgs_4k", synthetic::higgs_like(4_000, 1)),
    ] {
        let b = BinnedDataset::from_dataset(&ds, 64).unwrap();
        let f = vec![0.0f32; ds.n_rows()];
        let w = vec![1.0f32; ds.n_rows()];
        let gh = logistic::grad_hess_loss(&f, &ds.y, &w);
        let rows: Vec<u32> = (0..ds.n_rows() as u32).collect();
        let mut hist = Histogram::zeros(b.total_bins());
        r.bench(&format!("build/{name}/full"), || {
            hist.build(&b, &rows, &gh.grad, &gh.hess)
        });
        let half: Vec<u32> = rows.iter().copied().step_by(2).collect();
        r.bench(&format!("build/{name}/half_rows"), || {
            hist.build(&b, &half, &gh.grad, &gh.hess)
        });
        let mut parent = Histogram::zeros(b.total_bins());
        parent.build(&b, &rows, &gh.grad, &gh.hess);
        let mut sib = Histogram::zeros(b.total_bins());
        sib.build(&b, &half, &gh.grad, &gh.hess);
        let mut child = Histogram::zeros(b.total_bins());
        r.bench(&format!("subtract/{name}"), || {
            child.subtract_from(&parent, &sib)
        });

        // child-pair production, whole-node rebuild vs sibling subtraction,
        // on the unbalanced partition of deep leaf-wise splits
        let small: Vec<u32> = rows.iter().copied().step_by(8).collect();
        let big: Vec<u32> = rows.iter().copied().filter(|r| r % 8 != 0).collect();
        let mut pool = HistogramPool::new(b.total_bins());
        let mut ch_a = pool.take();
        let mut ch_b = pool.take();
        r.bench(&format!("children/{name}/rebuild_both"), || {
            ch_a.build(&b, &small, &gh.grad, &gh.hess);
            ch_b.build(&b, &big, &gh.grad, &gh.hess);
        });
        r.bench(&format!("children/{name}/subtract"), || {
            ch_a.build(&b, &small, &gh.grad, &gh.hess);
            ch_b.subtract_from(&parent, &ch_a);
        });
        pool.give(ch_a);
        pool.give(ch_b);

        // the build pool's dispatch cost in isolation: one sharded
        // histogram build (the inner fork-join a tree runs once per
        // leaf; the self-contained entry allocates transient partials,
        // where tree builds recycle pooled ones — so this is an upper
        // bound on the in-tree cost), persistent wake vs scoped spawn
        // at 1/2/4/8 threads
        let mut sharded = Histogram::zeros(b.total_bins());
        for mode in [PoolMode::Persistent, PoolMode::Scoped] {
            for threads in [1usize, 2, 4, 8] {
                let exec = Executor::new(mode, threads);
                r.bench(
                    &format!("sharded/{name}/{}/threads_{threads}", mode.as_str()),
                    || build_histogram_sharded(&mut sharded, &b, &rows, &gh.grad, &gh.hess, &exec),
                );
            }
        }
    }
    r.write_csv().unwrap();
}
