//! Figure 7 bench: Higgs-like convergence vs sampling rate (fixed workers).
use asgbdt::bench_harness::Runner;
use asgbdt::experiments::{self, Scale};

fn main() {
    let mut r = Runner::new("fig7_higgs_sampling");
        // experiments are deterministic: one full run is the measurement
    let single = asgbdt::bench_harness::BenchConfig {
        warmup_secs: 0.0,
        measure_secs: 0.0,
        min_iters: 1,
        max_iters: 1,
    };
    let mut r = r.with_config(single);
    let scale = Scale::from_env();
    let out = std::path::Path::new("results");
    let mut summary = None;
    r.bench("experiment/fig7_full", || {
        summary = Some(experiments::run("fig7", scale, out).expect("fig7"));
    });
    println!("summary: {}", summary.unwrap());
    r.write_csv().unwrap();
}
