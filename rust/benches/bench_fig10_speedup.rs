//! Figure 10 bench: end-to-end speedup — asynch-SGBDT vs LightGBM
//! feature-parallel vs DimBoost on calibrated cluster simulations, plus
//! the per-system 32-worker headline numbers.
use asgbdt::bench_harness::Runner;
use asgbdt::experiments::{self, Scale};
use asgbdt::simulator::{simulate_async_ps, ClusterSpec, PhaseTimes};

fn main() {
    let mut r = Runner::new("fig10_speedup");
    // microbench the simulator itself
    let times = PhaseTimes::realsim_like();
    r.bench("simulate/async_32w_200trees", || {
        simulate_async_ps(&ClusterSpec::new(32), &times, 200)
    });
    // full figure (includes a real calibration training run)
    let mut r = r.with_config(asgbdt::bench_harness::BenchConfig {
        warmup_secs: 0.0, measure_secs: 0.0, min_iters: 1, max_iters: 1,
    });
    let scale = Scale::from_env();
    let out = std::path::Path::new("results");
    let mut summary = None;
    r.bench("experiment/fig10_full", || {
        summary = Some(experiments::run("fig10", scale, out).expect("fig10"));
    });
    println!("summary: {}", summary.unwrap());
    r.write_csv().unwrap();
}
