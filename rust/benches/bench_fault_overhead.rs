//! Fault-layer overhead: trees/sec with the fault layer off (the
//! zero-cost default path), with supervision armed but quiet
//! (`worker_restarts` only — heartbeats + catch_unwind, no plan), and
//! with a full plan injecting drops/duplicates — the cost of chaos
//! itself. The all-defaults run constructs no `FaultPlan` and no
//! wrapper, so any gap between `faults_off` and `supervision_only` is
//! the supervision harness, and the gap to `faults_armed` is the
//! injected faults (DESIGN.md §14).
//!
//! Emits the machine-readable snapshot
//! `results/BENCH_fault_overhead.json` (per-config trees/sec plus the
//! armed-overhead fraction) and verifies it parses back.
//! `cargo bench --bench bench_fault_overhead -- --test` runs the same
//! pipeline on a tiny budget — the CI smoke mode.
use asgbdt::bench_harness::{BenchConfig, Runner};
use asgbdt::config::TrainConfig;
use asgbdt::coordinator::train_async;
use asgbdt::data::synthetic;
use asgbdt::io::Json;
use std::collections::BTreeMap;

fn bench_cfg(n_trees: usize) -> TrainConfig {
    let mut cfg = TrainConfig::default();
    cfg.workers = 4;
    cfg.n_trees = n_trees;
    cfg.step_length = 0.1;
    cfg.tree.max_leaves = 32;
    cfg.max_bins = 32;
    cfg.eval_every = n_trees;
    cfg
}

fn main() {
    let test_mode = std::env::args().any(|a| a == "--test");
    let trees = |full: usize| if test_mode { 8 } else { full };
    let mut r = Runner::new("fault_overhead");
    if test_mode {
        r = r.with_config(BenchConfig {
            warmup_secs: 0.0,
            measure_secs: 0.05,
            min_iters: 2,
            max_iters: 10,
        });
    }
    let ds = synthetic::realsim_like(3_000, 9);
    // the three contrast points: all-defaults (no plan, no harness
    // atomics), supervision armed with no faults, and a live chaos plan
    // (completion-safe: drops/dups only, no panics)
    let mut cfg_supervised = bench_cfg(trees(40));
    cfg_supervised.worker_restarts = 2;
    let mut cfg_armed = bench_cfg(trees(40));
    cfg_armed.fault_seed = Some(7);
    cfg_armed.fault_drop_rate = 0.05;
    cfg_armed.fault_dup_rate = 0.02;
    cfg_armed.worker_restarts = 2;
    let configs: Vec<(&str, TrainConfig)> = vec![
        ("faults_off", bench_cfg(trees(40))),
        ("supervision_only", cfg_supervised),
        ("faults_armed", cfg_armed),
    ];
    let mut trees_per_sec: BTreeMap<String, Json> = BTreeMap::new();
    let mut tps_of: BTreeMap<&str, f64> = BTreeMap::new();
    for (name, cfg) in &configs {
        let rep = train_async(cfg, &ds, None).unwrap();
        assert_eq!(rep.trees_accepted, cfg.n_trees, "({name})");
        trees_per_sec.insert((*name).to_string(), Json::Num(rep.trees_per_sec()));
        tps_of.insert(name, rep.trees_per_sec());
        r.record(
            &format!("train/{name}_trees_per_sec (1/x)"),
            1.0 / rep.trees_per_sec(),
        );
        println!(
            "  {name}: {:.2} trees/s, {} faults injected, {} deaths",
            rep.trees_per_sec(),
            rep.fault_trace.len(),
            rep.supervision.deaths,
        );
    }
    let off = tps_of["faults_off"];
    let armed = tps_of["faults_armed"];
    let armed_frac = if off > 0.0 { (off - armed) / off } else { 0.0 };
    println!("  armed overhead: {:.1}% of faults-off throughput", armed_frac * 100.0);
    r.write_csv().unwrap();
    let path = r
        .write_json(vec![
            ("trees_per_sec", Json::Obj(trees_per_sec)),
            (
                "overhead",
                Json::obj(vec![("armed_frac", Json::Num(armed_frac))]),
            ),
        ])
        .unwrap();
    let back = Json::parse_file(&path).unwrap();
    assert_eq!(back.req_str("group").unwrap(), "fault_overhead");
    assert!(!back.req("results").unwrap().as_arr().unwrap().is_empty());
    assert!(back.req("trees_per_sec").unwrap().as_obj().is_some());
    assert!(back.req("overhead").unwrap().as_obj().is_some());
    println!("-- snapshot {} parses back", path.display());
}
