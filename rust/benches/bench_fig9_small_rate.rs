//! Figure 9 bench: sensitivity at normal (0.6) vs extremely small sampling
//! rate; reports the worker-sensitivity gap per rate.
use asgbdt::bench_harness::Runner;
use asgbdt::experiments::fig9;
use asgbdt::experiments::{self, Scale};

fn main() {
    let mut r = Runner::new("fig9_small_rate");
        // experiments are deterministic: one full run is the measurement
    let single = asgbdt::bench_harness::BenchConfig {
        warmup_secs: 0.0,
        measure_secs: 0.0,
        min_iters: 1,
        max_iters: 1,
    };
    let mut r = r.with_config(single);
    let scale = Scale::from_env();
    let out = std::path::Path::new("results");
    let mut summary = None;
    r.bench("experiment/fig9_full", || {
        summary = Some(experiments::run("fig9", scale, out).expect("fig9"));
    });
    let summary = summary.unwrap();
    if let Some(gap) = fig9::sensitivity_gap(&summary, "rate=0.6") {
        println!("sensitivity gap at rate 0.6: {gap:.5}");
    }
    println!("summary: {summary}");
    r.write_csv().unwrap();
}
