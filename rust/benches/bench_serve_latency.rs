//! Closed-loop serving bench: p50/p99 request latency and throughput of
//! the `serve` subsystem across micro-batch size × scoring-thread
//! count, with one model hot-swap published mid-stream in every run (so
//! the measured path includes the swap protocol, not an idealized
//! single-model loop). Each config replays the same synthetic request
//! stream through [`asgbdt::serve::drive_replay`] — the same driver
//! `asgbdt serve` and the hot-swap tests use.
//!
//! Emits the machine-readable snapshot
//! `results/BENCH_serve_latency.json` (per-config p50/p99 seconds and
//! requests/sec) and verifies it parses back. `cargo bench --bench
//! bench_serve_latency -- --test` runs the same sweep on a tiny budget
//! — the CI smoke mode.

use std::collections::BTreeMap;
use std::sync::Arc;
use std::time::Duration;

use asgbdt::bench_harness::{BenchConfig, Runner};
use asgbdt::data::{synthetic, BinnedDataset};
use asgbdt::forest::{FlatForest, Forest};
use asgbdt::io::Json;
use asgbdt::loss::logistic;
use asgbdt::serve::{drive_replay, ModelSlot, ServeOptions, Service};
use asgbdt::tree::{build_tree_pooled, HistogramPool, TreeParams};
use asgbdt::util::{PoolMode, Rng, Summary};

fn main() {
    let test_mode = std::env::args().any(|a| a == "--test");
    let mut r = Runner::new("serve_latency");
    if test_mode {
        r = r.with_config(BenchConfig {
            warmup_secs: 0.0,
            measure_secs: 0.05,
            min_iters: 1,
            max_iters: 2,
        });
    }
    let n_rows = if test_mode { 1_200 } else { 6_000 };
    let n_trees = if test_mode { 6 } else { 40 };
    let n_requests = if test_mode { 240 } else { 4_000 };

    // a boosted forest over the replayed stream's own cuts (the same
    // construction as bench_predict, smaller)
    let ds = synthetic::realsim_like(n_rows, 7);
    let b = BinnedDataset::from_dataset(&ds, 32).unwrap();
    let w = vec![1.0f32; ds.n_rows()];
    let mut f = vec![0.0f32; ds.n_rows()];
    let mut forest = Forest::new(0.0);
    let rows: Vec<u32> = (0..ds.n_rows() as u32).collect();
    let params = TreeParams {
        max_leaves: 32,
        feature_rate: 0.8,
        ..Default::default()
    };
    let mut rng = Rng::new(3);
    let mut hpool = HistogramPool::new(b.total_bins());
    for _ in 0..n_trees {
        let gh = logistic::grad_hess_loss(&f, &ds.y, &w);
        let t = build_tree_pooled(&b, &rows, &gh.grad, &gh.hess, &params, &mut rng, &mut hpool);
        for (fr, row) in f.iter_mut().zip(0..ds.n_rows()) {
            *fr += 0.1 * t.predict_binned(&b, row);
        }
        forest.push(0.1, t);
    }
    let flat = FlatForest::from_forest(&forest);
    let cuts = b.cuts();
    println!(
        "serving {} trees, {} requests/config over {} rows x {} features",
        flat.n_trees(),
        n_requests,
        ds.n_rows(),
        ds.n_features()
    );

    // the acceptance sweep: >= 3 batch sizes x >= 2 thread counts, one
    // hot-swap per run (republishing the same forest — the swap cost
    // without a model change)
    let mut configs: BTreeMap<String, Json> = BTreeMap::new();
    for &batch in &[1usize, 8, 64] {
        for &threads in &[1usize, 2] {
            let slot = Arc::new(ModelSlot::new(flat.clone(), cuts.clone()));
            let opts = ServeOptions {
                batch,
                max_wait: Duration::from_micros(200),
                threads,
                pool: PoolMode::Persistent,
            };
            let service = Service::start(Arc::clone(&slot), opts);
            let swap = Some((n_requests / 2, flat.clone(), cuts.clone()));
            let inflight = (batch * 2).max(8);
            let outcome = drive_replay(&service, &ds.x, n_requests, inflight, swap).unwrap();
            let stats = service.shutdown();
            assert_eq!(stats.requests as usize, n_requests, "(b{batch}_t{threads})");
            // requests submitted after the publish must carry the new tag
            assert!(
                outcome.version_of.iter().any(|&v| v == 2),
                "hot-swap never observed (b{batch}_t{threads})"
            );
            let lat = Summary::of(&outcome.latency_secs);
            let rps = n_requests as f64 / outcome.wall_secs.max(1e-12);
            r.record(&format!("serve/b{batch}_t{threads}/p50_latency"), lat.p50);
            r.record(&format!("serve/b{batch}_t{threads}/p99_latency"), lat.p99);
            let rps_name = format!("serve/b{batch}_t{threads}/throughput_rps (1/x)");
            r.record(&rps_name, 1.0 / rps);
            println!(
                "  b{batch}_t{threads}: p50 {:.1}us p99 {:.1}us | {:.0} req/s, {} batches (max {})",
                lat.p50 * 1e6,
                lat.p99 * 1e6,
                rps,
                stats.batches,
                stats.max_batch
            );
            configs.insert(
                format!("b{batch}_t{threads}"),
                Json::obj(vec![
                    ("batch", Json::Num(batch as f64)),
                    ("threads", Json::Num(threads as f64)),
                    ("p50_latency_s", Json::Num(lat.p50)),
                    ("p99_latency_s", Json::Num(lat.p99)),
                    ("throughput_rps", Json::Num(rps)),
                    ("batches", Json::Num(stats.batches as f64)),
                    ("max_batch", Json::Num(stats.max_batch as f64)),
                ]),
            );
        }
    }
    r.write_csv().unwrap();
    let path = r.write_json(vec![("configs", Json::Obj(configs))]).unwrap();
    let back = Json::parse_file(&path).unwrap();
    assert_eq!(back.req_str("group").unwrap(), "serve_latency");
    assert!(!back.req("results").unwrap().as_arr().unwrap().is_empty());
    let cfgs = back.req("configs").unwrap().as_obj().unwrap();
    assert_eq!(cfgs.len(), 6, "3 batch sizes x 2 thread counts");
    for (name, c) in cfgs {
        for key in ["p50_latency_s", "p99_latency_s", "throughput_rps"] {
            assert!(c.req_f64(key).unwrap().is_finite(), "{name}.{key}");
        }
    }
    println!("-- snapshot {} parses back", path.display());
}
