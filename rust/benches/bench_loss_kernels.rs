//! Loss-kernel bench: per-loss whole-vector grad/hess and eval passes
//! (the produce-target hot loop) plus the multiclass class-gradient
//! pass, with a fixed-vs-adaptive trees-to-target section from the
//! staleness convergence model. Emits `results/BENCH_loss_kernels.json`
//! and parse-checks it before exiting.
use asgbdt::bench_harness::Runner;
use asgbdt::config::StepMode;
use asgbdt::io::Json;
use asgbdt::loss::{multiclass, ScalarLoss};
use asgbdt::simulator::{convergence, simulate_sharded_ps_trace, ClusterSpec, PhaseTimes};
use asgbdt::util::Rng;

fn main() {
    let mut r = Runner::new("loss_kernels");
    let n = if std::env::var("ASGBDT_BENCH_FAST").is_ok() {
        50_000
    } else {
        500_000
    };
    let mut rng = Rng::new(7);
    let f: Vec<f32> = (0..n).map(|_| (rng.normal() * 2.0) as f32).collect();
    let y: Vec<f32> = (0..n).map(|i| (i % 2) as f32).collect();
    let w: Vec<f32> = (0..n).map(|i| if i % 5 == 0 { 0.0 } else { 1.0 }).collect();

    for (name, loss) in [
        ("logistic", ScalarLoss::Logistic),
        ("squared", ScalarLoss::Squared),
        ("huber", ScalarLoss::Huber(1.0)),
    ] {
        r.bench(&format!("grad_hess/{name}"), || loss.grad_hess_loss(&f, &y, &w));
        r.bench(&format!("eval_blocked/{name}"), || {
            loss.eval_sums_blocked(&f, &y, &w, 2048)
        });
    }

    // multiclass: K class-major margin vectors, one class gradient pass
    // (what one boosting round publishes) + the full eval
    let k = 3;
    let rows = n / k;
    let fk: Vec<f32> = (0..k * rows).map(|_| (rng.normal()) as f32).collect();
    let yk: Vec<f32> = (0..rows).map(|i| (i % k) as f32).collect();
    let wk: Vec<f32> = vec![1.0; rows];
    r.bench("grad_hess/multiclass_k3_class0", || {
        multiclass::grad_hess_class(&fk, &yk, &wk, k, 0)
    });
    r.bench("eval/multiclass_k3", || multiclass::eval_sums(&fk, &yk, &wk, k));

    // fixed vs adaptive trees-to-target on simulated staleness traces —
    // the headline table of the adaptive-step sweep, repriced here so
    // the bench snapshot carries it
    let times = PhaseTimes::realsim_like();
    let mut rows_json = Vec::new();
    for workers in [1usize, 8, 64] {
        let (_, trace) = simulate_sharded_ps_trace(&ClusterSpec::new(workers), &times, 4_000, 1);
        let fixed = convergence::trees_to_target(&trace, 0.3, StepMode::Fixed, 0.05);
        let adaptive = convergence::trees_to_target(&trace, 0.3, StepMode::Adaptive, 0.05);
        println!(
            "trees-to-target @ {workers} workers: fixed {fixed:?} adaptive {adaptive:?}"
        );
        rows_json.push((
            format!("workers={workers}"),
            Json::Obj(
                [
                    (
                        "trees_fixed".to_string(),
                        fixed.map_or(Json::Null, |t| Json::Num(t as f64)),
                    ),
                    (
                        "trees_adaptive".to_string(),
                        adaptive.map_or(Json::Null, |t| Json::Num(t as f64)),
                    ),
                ]
                .into_iter()
                .collect(),
            ),
        ));
    }
    let section = Json::Obj(rows_json.into_iter().collect());

    let path = r
        .write_json(vec![("trees_to_target", section)])
        .expect("write BENCH_loss_kernels.json");
    // self-check: the snapshot must parse back (CI re-checks with
    // python json.tool)
    let back = Json::parse_file(&path).expect("snapshot must re-parse");
    assert_eq!(back.req_str("group").unwrap(), "loss_kernels");
    assert!(back.req("trees_to_target").is_ok());
    r.write_csv().unwrap();
}
