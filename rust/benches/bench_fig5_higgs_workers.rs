//! Figure 5 bench: Higgs-like convergence vs worker count (fixed rate).
//! Prints the per-variant loss summaries and regenerates fig5_higgs_workers.csv.
use asgbdt::bench_harness::Runner;
use asgbdt::experiments::{self, Scale};

fn main() {
    let mut r = Runner::new("fig5_higgs_workers");
        // experiments are deterministic: one full run is the measurement
    let single = asgbdt::bench_harness::BenchConfig {
        warmup_secs: 0.0,
        measure_secs: 0.0,
        min_iters: 1,
        max_iters: 1,
    };
    let mut r = r.with_config(single);
    let scale = Scale::from_env();
    let out = std::path::Path::new("results");
    let mut summary = None;
    r.bench("experiment/fig5_full", || {
        summary = Some(experiments::run("fig5", scale, out).expect("fig5"));
    });
    println!("summary: {}", summary.unwrap());
    r.write_csv().unwrap();
}
