//! Produce-target hot path: AOT (PJRT-executed JAX/Pallas HLO) vs the
//! pure-Rust fallback, across batch sizes — the L1/L2 perf measurement
//! recorded in EXPERIMENTS.md §Perf.
use asgbdt::bench_harness::Runner;
use asgbdt::runtime::{EngineKind, GradientEngine};
use asgbdt::util::Rng;

fn inputs(n: usize, seed: u64) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
    let mut rng = Rng::new(seed);
    let f: Vec<f32> = (0..n).map(|_| rng.normal() as f32).collect();
    let y: Vec<f32> = (0..n).map(|_| if rng.bernoulli(0.5) { 1.0 } else { 0.0 }).collect();
    let w: Vec<f32> = (0..n).map(|_| rng.exponential() as f32).collect();
    (f, y, w)
}

fn main() {
    let mut r = Runner::new("grad_pipeline");
    let dir = std::path::Path::new("artifacts");
    for n in [4_096usize, 65_536, 262_144] {
        let (f, y, w) = inputs(n, 7);
        let mut native = GradientEngine::native();
        r.bench(&format!("native/grad_hess_loss/{n}"), || {
            native.grad_hess_loss(&f, &y, &w).unwrap()
        });
        let mut auto = GradientEngine::auto(dir);
        if auto.kind() == EngineKind::Aot {
            // warm the executable cache outside the timing loop
            auto.grad_hess_loss(&f, &y, &w).unwrap();
            r.bench(&format!("aot-pjrt/grad_hess_loss/{n}"), || {
                auto.grad_hess_loss(&f, &y, &w).unwrap()
            });
            r.bench(&format!("aot-pjrt/eval_sums/{n}"), || {
                auto.eval_sums(&f, &y, &w).unwrap()
            });
        } else {
            println!("(artifacts missing — run `make artifacts` for the AOT rows)");
        }
    }
    r.write_csv().unwrap();
}
