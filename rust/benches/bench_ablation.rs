//! Ablation bench: step-length x workers, leaves x sensitivity, bounded
//! staleness (DESIGN.md SS5 ablations row).
use asgbdt::bench_harness::Runner;
use asgbdt::experiments::{self, Scale};

fn main() {
    let mut r = Runner::new("ablation");
        // experiments are deterministic: one full run is the measurement
    let single = asgbdt::bench_harness::BenchConfig {
        warmup_secs: 0.0,
        measure_secs: 0.0,
        min_iters: 1,
        max_iters: 1,
    };
    let mut r = r.with_config(single);
    let scale = Scale::from_env();
    let out = std::path::Path::new("results");
    let mut summary = None;
    r.bench("experiment/ablation_full", || {
        summary = Some(experiments::run("ablation", scale, out).expect("ablation"));
    });
    println!("summary: {}", summary.unwrap());
    r.write_csv().unwrap();
}
