//! Artifact-vs-JSON model load bench: wall time to get a saved model
//! back into scoring shape, at 100 / 1k / 10k trees, for the legacy
//! JSON dump (parse + tree walk + re-flatten) against the `.sgbdt`
//! artifact (validate manifest + checksums + map the SoA bytes —
//! DESIGN.md §16). Also measures first-score latency: cold load plus
//! one scored batch, the "process restart to first prediction" path a
//! serving rollout actually pays.
//!
//! Emits the machine-readable snapshot
//! `results/BENCH_artifact_load.json` and verifies it parses back.
//! `cargo bench --bench bench_artifact_load -- --test` runs tiny sizes
//! — the CI smoke mode.

use std::collections::BTreeMap;
use std::path::PathBuf;

use asgbdt::bench_harness::{BenchConfig, Runner};
use asgbdt::data::{synthetic, BinnedDataset, CsrMatrix};
use asgbdt::forest::{FlatForest, Forest, ScratchPool};
use asgbdt::io::artifact::{self, hex16, ArtifactMeta};
use asgbdt::io::Json;
use asgbdt::tree::{Node, Tree};
use asgbdt::util::Executor;

/// A valid n-tree forest of varied stumps, synthesized directly — the
/// load path under test does not care how the trees were grown, and
/// training 10k trees would dominate the bench's own setup.
fn synth_forest(n_trees: usize, n_features: usize) -> Forest {
    let mut f = Forest::new(0.1);
    for i in 0..n_trees {
        let feature = (i % n_features) as u32;
        let threshold = (i % 7) as f32 * 0.5 - 1.0;
        let v = 0.05 + (i % 3) as f32 * 0.01;
        f.push(
            v,
            Tree {
                nodes: vec![
                    Node::Split {
                        feature,
                        bin: (i % 5) as u8,
                        threshold,
                        left: 1,
                        right: 2,
                    },
                    Node::Leaf {
                        value: -0.5 - (i % 11) as f32 * 0.01,
                    },
                    Node::Leaf {
                        value: 0.5 + (i % 13) as f32 * 0.01,
                    },
                ],
            },
        );
    }
    f
}

fn first_score(flat: &FlatForest, x: &CsrMatrix) -> f32 {
    let exec = Executor::scoped(1);
    let mut pool = ScratchPool::new();
    flat.predict_all_raw(x, &exec, &mut pool)[0]
}

fn main() {
    let test_mode = std::env::args().any(|a| a == "--test");
    let mut r = Runner::new("artifact_load");
    if test_mode {
        r = r.with_config(BenchConfig {
            warmup_secs: 0.0,
            measure_secs: 0.05,
            min_iters: 1,
            max_iters: 2,
        });
    }
    let sizes: &[usize] = if test_mode {
        &[50, 200]
    } else {
        &[100, 1_000, 10_000]
    };

    // one request batch + one set of cuts shared by every size
    let ds = synthetic::realsim_like(400, 7);
    let cuts = BinnedDataset::from_dataset(&ds, 32).unwrap().cuts();
    let dir = std::env::temp_dir().join("asgbdt_bench_artifact_load");
    std::fs::create_dir_all(&dir).unwrap();

    let mut configs: BTreeMap<String, Json> = BTreeMap::new();
    for &n in sizes {
        let forest = synth_forest(n, ds.n_features());
        let flat = FlatForest::from_forest(&forest);
        let json_path: PathBuf = dir.join(format!("model_{n}.json"));
        let sgbdt_path: PathBuf = dir.join(format!("model_{n}.sgbdt"));
        forest.save(&json_path).unwrap();
        let meta = ArtifactMeta {
            config_fingerprint: hex16(0),
            seed: 7,
            loss: "logistic".to_string(),
            train_secs: 0.0,
            trainer: None,
        };
        artifact::save(&sgbdt_path, &flat, &cuts, &meta).unwrap();
        let json_bytes = std::fs::metadata(&json_path).unwrap().len();
        let sgbdt_bytes = std::fs::metadata(&sgbdt_path).unwrap().len();

        // both loaders must produce the same margins before timing them
        let via_json = FlatForest::from_forest(&Forest::load(&json_path).unwrap());
        let via_artifact = artifact::load(&sgbdt_path).unwrap().forest;
        assert_eq!(first_score(&via_json, &ds.x), first_score(&via_artifact, &ds.x));

        let json_load = r
            .bench(&format!("load/t{n}/json"), || {
                FlatForest::from_forest(&Forest::load(&json_path).unwrap()).n_trees()
            })
            .mean();
        let sgbdt_load = r
            .bench(&format!("load/t{n}/sgbdt"), || {
                artifact::load(&sgbdt_path).unwrap().forest.n_trees()
            })
            .mean();
        let json_first = r
            .bench(&format!("first_score/t{n}/json"), || {
                let flat = FlatForest::from_forest(&Forest::load(&json_path).unwrap());
                first_score(&flat, &ds.x)
            })
            .mean();
        let sgbdt_first = r
            .bench(&format!("first_score/t{n}/sgbdt"), || {
                let a = artifact::load(&sgbdt_path).unwrap();
                first_score(&a.forest, &ds.x)
            })
            .mean();
        println!(
            "  t{n}: load json {:.2}ms vs sgbdt {:.2}ms ({:.1}x) | first score {:.2}ms vs {:.2}ms | {} vs {} bytes",
            json_load * 1e3,
            sgbdt_load * 1e3,
            json_load / sgbdt_load.max(1e-12),
            json_first * 1e3,
            sgbdt_first * 1e3,
            json_bytes,
            sgbdt_bytes,
        );
        configs.insert(
            format!("t{n}"),
            Json::obj(vec![
                ("n_trees", Json::Num(n as f64)),
                ("json_load_s", Json::Num(json_load)),
                ("sgbdt_load_s", Json::Num(sgbdt_load)),
                ("json_first_score_s", Json::Num(json_first)),
                ("sgbdt_first_score_s", Json::Num(sgbdt_first)),
                ("json_bytes", Json::Num(json_bytes as f64)),
                ("sgbdt_bytes", Json::Num(sgbdt_bytes as f64)),
                ("load_speedup", Json::Num(json_load / sgbdt_load.max(1e-12))),
            ]),
        );
    }

    r.write_csv().unwrap();
    let path = r.write_json(vec![("configs", Json::Obj(configs))]).unwrap();
    let back = Json::parse_file(&path).unwrap();
    assert_eq!(back.req_str("group").unwrap(), "artifact_load");
    assert!(!back.req("results").unwrap().as_arr().unwrap().is_empty());
    let cfgs = back.req("configs").unwrap().as_obj().unwrap();
    assert_eq!(cfgs.len(), sizes.len());
    for (name, c) in cfgs {
        for key in ["json_load_s", "sgbdt_load_s", "json_first_score_s", "sgbdt_first_score_s"] {
            assert!(c.req_f64(key).unwrap() > 0.0, "{name}.{key}");
        }
    }
    println!("-- snapshot {} parses back", path.display());
}
