//! Parameter-server throughput: accepted trees/sec end-to-end by worker
//! count — the real-thread half of the Figure 10 story, plus board
//! pull/publish micro-latencies, the apply-path (Algorithm 3 step 2)
//! time reported separately for the blocked-SoA and per-row-enum scoring
//! engines, the accept-path breakdown (fused one-pass pipeline vs the
//! serial reference at 1/2/4/8 score threads), and the pool breakdown:
//! persistent parked workers vs per-tree scoped spawns on a deliberately
//! small dataset where spawn/join dominates the accept cost.
//!
//! Besides the human-readable table/CSV, the run emits the machine-
//! readable snapshot `results/BENCH_ps_throughput.json` (per-config
//! trees/sec plus accept-phase fractions) and verifies it parses back.
//! `cargo bench --bench bench_ps_throughput -- --test` runs the same
//! pipeline on a tiny budget — the CI smoke mode.
use asgbdt::bench_harness::{BenchConfig, Runner};
use asgbdt::config::TrainConfig;
use asgbdt::coordinator::{train_async, TrainReport};
use asgbdt::data::synthetic;
use asgbdt::forest::ScoreMode;
use asgbdt::io::Json;
use asgbdt::ps::{Board, TargetMode, TargetSnapshot};
use asgbdt::util::PoolMode;
use std::collections::BTreeMap;
use std::sync::Arc;

/// The shared 4-worker async workload every breakdown below runs
/// (eval pinned to the final tree so `server/eval` stays off the
/// per-tree accept cost).
fn bench_cfg(n_trees: usize, max_leaves: usize) -> TrainConfig {
    let mut cfg = TrainConfig::default();
    cfg.workers = 4;
    cfg.n_trees = n_trees;
    cfg.step_length = 0.1;
    cfg.tree.max_leaves = max_leaves;
    cfg.max_bins = 32;
    cfg.eval_every = n_trees;
    cfg
}

/// Per-tree accept cost on the fused path: everything the server does
/// between receiving a push and publishing the next target — flatten +
/// the one sharded pass + the AOT target fallback (zero natively) +
/// eval. Keep in sync with the serial-side sum in `main`.
fn fused_accept_cost(rep: &TrainReport) -> f64 {
    rep.timer.mean("server/flatten_tree")
        + rep.timer.mean("server/fused_pass")
        + rep.timer.mean("server/produce_target")
        + rep.timer.mean("server/eval")
}

fn main() {
    // `-- --test`: CI smoke mode — same pipeline, tiny tree counts and
    // measurement budget, so the JSON snapshot shape is exercised cheaply
    let test_mode = std::env::args().any(|a| a == "--test");
    let trees = |full: usize| if test_mode { 8 } else { full };
    let mut r = Runner::new("ps_throughput");
    if test_mode {
        r = r.with_config(BenchConfig {
            warmup_secs: 0.0,
            measure_secs: 0.05,
            min_iters: 2,
            max_iters: 10,
        });
    }
    // machine-readable sections for results/BENCH_ps_throughput.json
    let mut trees_per_sec: BTreeMap<String, Json> = BTreeMap::new();
    let mut accept_fracs: BTreeMap<String, Json> = BTreeMap::new();
    // micro: board pull/publish
    let board = Board::new();
    let n = 100_000;
    board.publish(TargetSnapshot {
        version: 1,
        grad: Arc::new(vec![0.0; n]),
        hess: Arc::new(vec![0.0; n]),
        rows: Arc::new((0..n as u32).collect()),
    });
    r.bench("board/pull", || board.pull());
    r.bench("board/publish", || {
        board.publish(TargetSnapshot {
            version: 2,
            grad: Arc::new(Vec::new()),
            hess: Arc::new(Vec::new()),
            rows: Arc::new(Vec::new()),
        })
    });
    // end-to-end trees/sec by worker count, with the apply path (step 2:
    // update F) broken out — the server-side cost the blocked scorer cuts
    let ds = synthetic::realsim_like(3_000, 9);
    for workers in [1usize, 2, 4, 8] {
        let mut cfg = bench_cfg(trees(40), 32);
        cfg.workers = workers;
        let rep = train_async(&cfg, &ds, None).unwrap();
        trees_per_sec.insert(format!("async_w{workers}"), Json::Num(rep.trees_per_sec()));
        r.record(
            &format!("train_async/trees_per_sec_w{workers} (1/x)"),
            1.0 / rep.trees_per_sec(),
        );
        r.record(
            &format!("apply/update_f_per_tree_w{workers}"),
            rep.timer.mean("server/update_f"),
        );
        println!(
            "  workers {workers}: {:.2} trees/s, staleness mean {:.2}, apply {:.1}µs/tree",
            rep.trees_per_sec(),
            rep.staleness.mean(),
            rep.timer.mean("server/update_f") * 1e6,
        );
    }
    // scoring-engine contrast on the same workload (4 workers); both on
    // the serial accept path, where the per-row reference engine lives
    for scoring in [ScoreMode::Flat, ScoreMode::PerRow] {
        let mut cfg = bench_cfg(trees(40), 32);
        cfg.target = TargetMode::Serial;
        cfg.scoring = scoring;
        let rep = train_async(&cfg, &ds, None).unwrap();
        // step-2 time per tree including the flatten only the flat
        // engine pays (zero for perrow), so the comparison is end to end
        let apply = rep.timer.mean("server/update_f") + rep.timer.mean("server/flatten_tree");
        r.record(
            &format!("apply/step2_per_tree_{}", scoring.as_str()),
            apply,
        );
        println!(
            "  scoring {}: apply {:.1}µs/tree (incl. flatten), {:.2} trees/s",
            scoring.as_str(),
            apply * 1e6,
            rep.trees_per_sec(),
        );
    }
    // accept-path breakdown: fused one-pass pipeline vs the serial
    // reference, sharded across 1/2/4/8 score threads (4 workers racing)
    for target in [TargetMode::Fused, TargetMode::Serial] {
        for threads in [1usize, 2, 4, 8] {
            let mut cfg = bench_cfg(trees(40), 32);
            cfg.target = target;
            cfg.score_threads = threads;
            let rep = train_async(&cfg, &ds, None).unwrap();
            // per-tree accept cost by phase: both sums cover the same
            // work — the fused pass folds sampling/target/eval in, so
            // the serial side must count its separate sweeps (sample,
            // produce_target, eval) for symmetry
            let phases: Vec<(&str, f64)> = match target {
                TargetMode::Fused => vec![
                    ("flatten", rep.timer.mean("server/flatten_tree")),
                    ("fused_pass", rep.timer.mean("server/fused_pass")),
                    ("produce_target", rep.timer.mean("server/produce_target")),
                    ("eval", rep.timer.mean("server/eval")),
                ],
                TargetMode::Serial => vec![
                    ("flatten", rep.timer.mean("server/flatten_tree")),
                    ("update_f", rep.timer.mean("server/update_f")),
                    ("sample", rep.timer.mean("server/sample")),
                    ("produce_target", rep.timer.mean("server/produce_target")),
                    ("eval", rep.timer.mean("server/eval")),
                ],
            };
            let accept: f64 = phases.iter().map(|&(_, s)| s).sum();
            let key = format!("{}_t{threads}", target.as_str());
            trees_per_sec.insert(key.clone(), Json::Num(rep.trees_per_sec()));
            accept_fracs.insert(
                key,
                Json::obj(
                    phases
                        .iter()
                        .map(|&(k, s)| (k, Json::Num(if accept > 0.0 { s / accept } else { 0.0 })))
                        .collect(),
                ),
            );
            r.record(
                &format!("accept/{}_t{threads}_per_tree", target.as_str()),
                accept,
            );
            r.record(
                &format!("accept/{}_t{threads}_trees_per_sec (1/x)", target.as_str()),
                1.0 / rep.trees_per_sec(),
            );
            println!(
                "  target {} threads {threads}: accept {:.1}µs/tree, {:.2} trees/s",
                target.as_str(),
                accept * 1e6,
                rep.trees_per_sec(),
            );
        }
    }
    // pool breakdown: persistent parked workers vs per-tree scoped
    // spawns, on a deliberately SMALL dataset (~3 row blocks) where one
    // tree's scoring work is itself only tens of µs — here the scoped
    // path's per-tree thread spawn/join is the dominant accept cost and
    // the persistent pool's condvar handoff is what removes it
    let small = synthetic::realsim_like(1_500, 10);
    for pool in [PoolMode::Persistent, PoolMode::Scoped] {
        for threads in [1usize, 2, 4, 8] {
            let mut cfg = bench_cfg(trees(60), 16);
            cfg.score_threads = threads;
            cfg.pool = pool;
            let rep = train_async(&cfg, &small, None).unwrap();
            let accept = fused_accept_cost(&rep);
            trees_per_sec.insert(
                format!("pool_{}_t{threads}", pool.as_str()),
                Json::Num(rep.trees_per_sec()),
            );
            r.record(
                &format!("pool/{}_t{threads}_accept_per_tree", pool.as_str()),
                accept,
            );
            r.record(
                &format!("pool/{}_t{threads}_trees_per_sec (1/x)", pool.as_str()),
                1.0 / rep.trees_per_sec(),
            );
            println!(
                "  pool {} threads {threads} (small data): accept {:.1}µs/tree, {:.2} trees/s",
                pool.as_str(),
                accept * 1e6,
                rep.trees_per_sec(),
            );
        }
    }
    r.write_csv().unwrap();
    // the machine-readable snapshot, then prove it parses back with the
    // documented sections — the CI smoke mode's whole point
    let path = r
        .write_json(vec![
            ("trees_per_sec", Json::Obj(trees_per_sec)),
            ("accept_phase_fractions", Json::Obj(accept_fracs)),
        ])
        .unwrap();
    let back = Json::parse_file(&path).unwrap();
    assert_eq!(back.req_str("group").unwrap(), "ps_throughput");
    assert!(!back.req("results").unwrap().as_arr().unwrap().is_empty());
    assert!(back.req("trees_per_sec").unwrap().as_obj().is_some());
    assert!(back.req("accept_phase_fractions").unwrap().as_obj().is_some());
    println!("-- snapshot {} parses back", path.display());
}
