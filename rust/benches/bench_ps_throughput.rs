//! Parameter-server throughput: accepted trees/sec end-to-end by worker
//! count — the real-thread half of the Figure 10 story, plus board
//! pull/publish micro-latencies.
use asgbdt::bench_harness::Runner;
use asgbdt::config::TrainConfig;
use asgbdt::coordinator::train_async;
use asgbdt::data::synthetic;
use asgbdt::ps::{Board, TargetSnapshot};
use std::sync::Arc;

fn main() {
    let mut r = Runner::new("ps_throughput");
    // micro: board pull/publish
    let board = Board::new();
    let n = 100_000;
    board.publish(TargetSnapshot {
        version: 1,
        grad: Arc::new(vec![0.0; n]),
        hess: Arc::new(vec![0.0; n]),
        rows: Arc::new((0..n as u32).collect()),
    });
    r.bench("board/pull", || board.pull());
    r.bench("board/publish", || {
        board.publish(TargetSnapshot {
            version: 2,
            grad: Arc::new(Vec::new()),
            hess: Arc::new(Vec::new()),
            rows: Arc::new(Vec::new()),
        })
    });
    // end-to-end trees/sec by worker count
    let ds = synthetic::realsim_like(3_000, 9);
    for workers in [1usize, 2, 4, 8] {
        let mut cfg = TrainConfig::default();
        cfg.workers = workers;
        cfg.n_trees = 40;
        cfg.step_length = 0.1;
        cfg.tree.max_leaves = 32;
        cfg.max_bins = 32;
        cfg.eval_every = 40;
        let rep = train_async(&cfg, &ds, None).unwrap();
        r.record(
            &format!("train_async/trees_per_sec_w{workers} (1/x)"),
            1.0 / rep.trees_per_sec(),
        );
        println!(
            "  workers {workers}: {:.2} trees/s, staleness mean {:.2}",
            rep.trees_per_sec(),
            rep.staleness.mean()
        );
    }
    r.write_csv().unwrap();
}
