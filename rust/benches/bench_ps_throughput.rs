//! Parameter-server throughput: accepted trees/sec end-to-end by worker
//! count — the real-thread half of the Figure 10 story, plus board
//! pull/publish micro-latencies, the apply-path (Algorithm 3 step 2)
//! time reported separately for the blocked-SoA and per-row-enum scoring
//! engines, the accept-path breakdown (fused one-pass pipeline vs the
//! serial reference at 1/2/4/8 score threads), and the pool breakdown:
//! persistent parked workers vs per-tree scoped spawns on a deliberately
//! small dataset where spawn/join dominates the accept cost.
use asgbdt::bench_harness::Runner;
use asgbdt::config::TrainConfig;
use asgbdt::coordinator::{train_async, TrainReport};
use asgbdt::data::synthetic;
use asgbdt::forest::ScoreMode;
use asgbdt::ps::{Board, TargetMode, TargetSnapshot};
use asgbdt::util::PoolMode;
use std::sync::Arc;

/// The shared 4-worker async workload every breakdown below runs
/// (eval pinned to the final tree so `server/eval` stays off the
/// per-tree accept cost).
fn bench_cfg(n_trees: usize, max_leaves: usize) -> TrainConfig {
    let mut cfg = TrainConfig::default();
    cfg.workers = 4;
    cfg.n_trees = n_trees;
    cfg.step_length = 0.1;
    cfg.tree.max_leaves = max_leaves;
    cfg.max_bins = 32;
    cfg.eval_every = n_trees;
    cfg
}

/// Per-tree accept cost on the fused path: everything the server does
/// between receiving a push and publishing the next target — flatten +
/// the one sharded pass + the AOT target fallback (zero natively) +
/// eval. Keep in sync with the serial-side sum in `main`.
fn fused_accept_cost(rep: &TrainReport) -> f64 {
    rep.timer.mean("server/flatten_tree")
        + rep.timer.mean("server/fused_pass")
        + rep.timer.mean("server/produce_target")
        + rep.timer.mean("server/eval")
}

fn main() {
    let mut r = Runner::new("ps_throughput");
    // micro: board pull/publish
    let board = Board::new();
    let n = 100_000;
    board.publish(TargetSnapshot {
        version: 1,
        grad: Arc::new(vec![0.0; n]),
        hess: Arc::new(vec![0.0; n]),
        rows: Arc::new((0..n as u32).collect()),
    });
    r.bench("board/pull", || board.pull());
    r.bench("board/publish", || {
        board.publish(TargetSnapshot {
            version: 2,
            grad: Arc::new(Vec::new()),
            hess: Arc::new(Vec::new()),
            rows: Arc::new(Vec::new()),
        })
    });
    // end-to-end trees/sec by worker count, with the apply path (step 2:
    // update F) broken out — the server-side cost the blocked scorer cuts
    let ds = synthetic::realsim_like(3_000, 9);
    for workers in [1usize, 2, 4, 8] {
        let mut cfg = bench_cfg(40, 32);
        cfg.workers = workers;
        let rep = train_async(&cfg, &ds, None).unwrap();
        r.record(
            &format!("train_async/trees_per_sec_w{workers} (1/x)"),
            1.0 / rep.trees_per_sec(),
        );
        r.record(
            &format!("apply/update_f_per_tree_w{workers}"),
            rep.timer.mean("server/update_f"),
        );
        println!(
            "  workers {workers}: {:.2} trees/s, staleness mean {:.2}, apply {:.1}µs/tree",
            rep.trees_per_sec(),
            rep.staleness.mean(),
            rep.timer.mean("server/update_f") * 1e6,
        );
    }
    // scoring-engine contrast on the same workload (4 workers); both on
    // the serial accept path, where the per-row reference engine lives
    for scoring in [ScoreMode::Flat, ScoreMode::PerRow] {
        let mut cfg = bench_cfg(40, 32);
        cfg.target = TargetMode::Serial;
        cfg.scoring = scoring;
        let rep = train_async(&cfg, &ds, None).unwrap();
        // step-2 time per tree including the flatten only the flat
        // engine pays (zero for perrow), so the comparison is end to end
        let apply = rep.timer.mean("server/update_f") + rep.timer.mean("server/flatten_tree");
        r.record(
            &format!("apply/step2_per_tree_{}", scoring.as_str()),
            apply,
        );
        println!(
            "  scoring {}: apply {:.1}µs/tree (incl. flatten), {:.2} trees/s",
            scoring.as_str(),
            apply * 1e6,
            rep.trees_per_sec(),
        );
    }
    // accept-path breakdown: fused one-pass pipeline vs the serial
    // reference, sharded across 1/2/4/8 score threads (4 workers racing)
    for target in [TargetMode::Fused, TargetMode::Serial] {
        for threads in [1usize, 2, 4, 8] {
            let mut cfg = bench_cfg(40, 32);
            cfg.target = target;
            cfg.score_threads = threads;
            let rep = train_async(&cfg, &ds, None).unwrap();
            // per-tree accept cost: both sums cover the same work — the
            // fused pass folds sampling/target/eval in, so the serial
            // side must count its separate sweeps (sample,
            // produce_target, eval) for symmetry
            let accept = match target {
                TargetMode::Fused => fused_accept_cost(&rep),
                TargetMode::Serial => {
                    rep.timer.mean("server/flatten_tree")
                        + rep.timer.mean("server/update_f")
                        + rep.timer.mean("server/sample")
                        + rep.timer.mean("server/produce_target")
                        + rep.timer.mean("server/eval")
                }
            };
            r.record(
                &format!("accept/{}_t{threads}_per_tree", target.as_str()),
                accept,
            );
            r.record(
                &format!("accept/{}_t{threads}_trees_per_sec (1/x)", target.as_str()),
                1.0 / rep.trees_per_sec(),
            );
            println!(
                "  target {} threads {threads}: accept {:.1}µs/tree, {:.2} trees/s",
                target.as_str(),
                accept * 1e6,
                rep.trees_per_sec(),
            );
        }
    }
    // pool breakdown: persistent parked workers vs per-tree scoped
    // spawns, on a deliberately SMALL dataset (~3 row blocks) where one
    // tree's scoring work is itself only tens of µs — here the scoped
    // path's per-tree thread spawn/join is the dominant accept cost and
    // the persistent pool's condvar handoff is what removes it
    let small = synthetic::realsim_like(1_500, 10);
    for pool in [PoolMode::Persistent, PoolMode::Scoped] {
        for threads in [1usize, 2, 4, 8] {
            let mut cfg = bench_cfg(60, 16);
            cfg.score_threads = threads;
            cfg.pool = pool;
            let rep = train_async(&cfg, &small, None).unwrap();
            let accept = fused_accept_cost(&rep);
            r.record(
                &format!("pool/{}_t{threads}_accept_per_tree", pool.as_str()),
                accept,
            );
            r.record(
                &format!("pool/{}_t{threads}_trees_per_sec (1/x)", pool.as_str()),
                1.0 / rep.trees_per_sec(),
            );
            println!(
                "  pool {} threads {threads} (small data): accept {:.1}µs/tree, {:.2} trees/s",
                pool.as_str(),
                accept * 1e6,
                rep.trees_per_sec(),
            );
        }
    }
    r.write_csv().unwrap();
}
