//! Parameter-server throughput: accepted trees/sec end-to-end by worker
//! count — the real-thread half of the Figure 10 story, plus board
//! pull/publish micro-latencies, the apply-path (Algorithm 3 step 2)
//! time reported separately for the blocked-SoA and per-row-enum scoring
//! engines, and the accept-path breakdown: fused one-pass pipeline vs
//! the serial reference at 1/2/4/8 score threads.
use asgbdt::bench_harness::Runner;
use asgbdt::config::TrainConfig;
use asgbdt::coordinator::train_async;
use asgbdt::data::synthetic;
use asgbdt::forest::ScoreMode;
use asgbdt::ps::{Board, TargetMode, TargetSnapshot};
use std::sync::Arc;

fn main() {
    let mut r = Runner::new("ps_throughput");
    // micro: board pull/publish
    let board = Board::new();
    let n = 100_000;
    board.publish(TargetSnapshot {
        version: 1,
        grad: Arc::new(vec![0.0; n]),
        hess: Arc::new(vec![0.0; n]),
        rows: Arc::new((0..n as u32).collect()),
    });
    r.bench("board/pull", || board.pull());
    r.bench("board/publish", || {
        board.publish(TargetSnapshot {
            version: 2,
            grad: Arc::new(Vec::new()),
            hess: Arc::new(Vec::new()),
            rows: Arc::new(Vec::new()),
        })
    });
    // end-to-end trees/sec by worker count, with the apply path (step 2:
    // update F) broken out — the server-side cost the blocked scorer cuts
    let ds = synthetic::realsim_like(3_000, 9);
    for workers in [1usize, 2, 4, 8] {
        let mut cfg = TrainConfig::default();
        cfg.workers = workers;
        cfg.n_trees = 40;
        cfg.step_length = 0.1;
        cfg.tree.max_leaves = 32;
        cfg.max_bins = 32;
        cfg.eval_every = 40;
        let rep = train_async(&cfg, &ds, None).unwrap();
        r.record(
            &format!("train_async/trees_per_sec_w{workers} (1/x)"),
            1.0 / rep.trees_per_sec(),
        );
        r.record(
            &format!("apply/update_f_per_tree_w{workers}"),
            rep.timer.mean("server/update_f"),
        );
        println!(
            "  workers {workers}: {:.2} trees/s, staleness mean {:.2}, apply {:.1}µs/tree",
            rep.trees_per_sec(),
            rep.staleness.mean(),
            rep.timer.mean("server/update_f") * 1e6,
        );
    }
    // scoring-engine contrast on the same workload (4 workers); both on
    // the serial accept path, where the per-row reference engine lives
    for scoring in [ScoreMode::Flat, ScoreMode::PerRow] {
        let mut cfg = TrainConfig::default();
        cfg.workers = 4;
        cfg.n_trees = 40;
        cfg.step_length = 0.1;
        cfg.tree.max_leaves = 32;
        cfg.max_bins = 32;
        cfg.eval_every = 40;
        cfg.target = TargetMode::Serial;
        cfg.scoring = scoring;
        let rep = train_async(&cfg, &ds, None).unwrap();
        // step-2 time per tree including the flatten only the flat
        // engine pays (zero for perrow), so the comparison is end to end
        let apply = rep.timer.mean("server/update_f") + rep.timer.mean("server/flatten_tree");
        r.record(
            &format!("apply/step2_per_tree_{}", scoring.as_str()),
            apply,
        );
        println!(
            "  scoring {}: apply {:.1}µs/tree (incl. flatten), {:.2} trees/s",
            scoring.as_str(),
            apply * 1e6,
            rep.trees_per_sec(),
        );
    }
    // accept-path breakdown: fused one-pass pipeline vs the serial
    // reference, sharded across 1/2/4/8 score threads (4 workers racing)
    for target in [TargetMode::Fused, TargetMode::Serial] {
        for threads in [1usize, 2, 4, 8] {
            let mut cfg = TrainConfig::default();
            cfg.workers = 4;
            cfg.n_trees = 40;
            cfg.step_length = 0.1;
            cfg.tree.max_leaves = 32;
            cfg.max_bins = 32;
            cfg.eval_every = 40;
            cfg.target = target;
            cfg.score_threads = threads;
            let rep = train_async(&cfg, &ds, None).unwrap();
            // per-tree accept cost: everything the server does between
            // receiving a push and publishing the next target. Both sums
            // cover the same work — the fused pass folds sampling/target/
            // eval in, so the serial side must count its separate sweeps
            // (sample, produce_target, eval) and the fused side its AOT
            // produce_target fallback (zero natively) for symmetry.
            let accept = match target {
                TargetMode::Fused => {
                    rep.timer.mean("server/flatten_tree")
                        + rep.timer.mean("server/fused_pass")
                        + rep.timer.mean("server/produce_target")
                        + rep.timer.mean("server/eval")
                }
                TargetMode::Serial => {
                    rep.timer.mean("server/flatten_tree")
                        + rep.timer.mean("server/update_f")
                        + rep.timer.mean("server/sample")
                        + rep.timer.mean("server/produce_target")
                        + rep.timer.mean("server/eval")
                }
            };
            r.record(
                &format!("accept/{}_t{threads}_per_tree", target.as_str()),
                accept,
            );
            r.record(
                &format!("accept/{}_t{threads}_trees_per_sec (1/x)", target.as_str()),
                1.0 / rep.trees_per_sec(),
            );
            println!(
                "  target {} threads {threads}: accept {:.1}µs/tree, {:.2} trees/s",
                target.as_str(),
                accept * 1e6,
                rep.trees_per_sec(),
            );
        }
    }
    r.write_csv().unwrap();
}
