//! Figure 4 bench: regenerates the diversity/Q'-sparsity data and times
//! the species-table + analytic report machinery.
use asgbdt::bench_harness::Runner;
use asgbdt::data::stats::{diversity_report, SpeciesTable};
use asgbdt::data::synthetic;
use asgbdt::experiments::{self, Scale};

fn main() {
    let mut r = Runner::new("fig4_diversity");
    let lo = synthetic::fig4_low_diversity(1);
    let hi = synthetic::fig4_high_diversity(1);
    r.bench("species_table/fig4a_60k_rows", || SpeciesTable::build(&lo));
    r.bench("species_table/fig4b_14k_rows", || SpeciesTable::build(&hi));
    r.bench("diversity_report/fig4b_rate_1e-3", || diversity_report(&hi, 0.001));
    // full figure regeneration
    let mut r = r.with_config(asgbdt::bench_harness::BenchConfig {
        warmup_secs: 0.0, measure_secs: 0.0, min_iters: 1, max_iters: 1,
    });
    let scale = Scale::from_env();
    let out = std::path::Path::new("results");
    r.bench("experiment/fig4_full", || {
        experiments::run("fig4", scale, out).expect("fig4")
    });
    r.write_csv().unwrap();
}
