//! The sampler executed by the server on every tree push (Algorithm 3,
//! server step 3).
//!
//! Draws are **counter-based**: every row's randomness comes from a
//! [`CounterRng`] keyed on `(key.seed, key.version, row)`, never from a
//! shared sequential stream. A pass is therefore a pure function of its
//! [`SampleKey`] — any contiguous sharding of rows across threads
//! ([`BernoulliSampler::draw_range`]) reproduces exactly the rows and
//! weights of a sequential sweep, which is what lets the server's fused
//! accept pipeline (`ps/shard.rs`) sample inside its row shards while
//! staying bit-identical to the serial reference path for every shard
//! count.

use crate::data::Dataset;
use crate::util::rng::{CounterRng, RandStream};

/// Identity of one sampling pass: all randomness below is a pure
/// function of `(seed, version, row)`. The server keys `version` to the
/// target version being produced, so a pass can be replayed — or
/// sharded — without coordinating any RNG state.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SampleKey {
    /// Stream seed (the server salts its own from `cfg.seed`).
    pub seed: u64,
    /// Target version this pass produces (the server's accept counter).
    pub version: u64,
}

/// One observed sampling pass.
#[derive(Debug, Clone)]
pub struct SamplePass {
    /// Stochastic weights m'_i (0 where the sample was not selected).
    pub weights: Vec<f32>,
    /// Rows with m'_i > 0 (the sampled sub-dataset), ascending.
    pub rows: Vec<u32>,
}

impl SamplePass {
    /// Number of selected rows (support of Q′ restricted to rows).
    pub fn n_selected(&self) -> usize {
        self.rows.len()
    }

    /// Density of the observed Q′ vector over rows.
    pub fn density(&self, n_rows: usize) -> f64 {
        self.rows.len() as f64 / n_rows.max(1) as f64
    }
}

/// Uniform-rate Bernoulli sampler (the paper sets all `R_ij` equal in its
/// experiments; per-sample rates are supported via `rates`).
#[derive(Debug, Clone)]
pub struct BernoulliSampler {
    /// Per-row selection probability R_i ∈ (0, 1].
    rates: Vec<f64>,
    /// Per-row multiplicities m_i (copies share the row's rate).
    multiplicities: Vec<f32>,
}

impl BernoulliSampler {
    /// Uniform rate across all rows of a dataset.
    pub fn uniform(ds: &Dataset, rate: f64) -> Self {
        assert!(
            rate > 0.0 && rate <= 1.0,
            "sampling rate must be in (0,1], got {rate}"
        );
        Self {
            rates: vec![rate; ds.n_rows()],
            multiplicities: ds.m.clone(),
        }
    }

    /// Per-row rates.
    pub fn with_rates(ds: &Dataset, rates: Vec<f64>) -> Self {
        assert_eq!(rates.len(), ds.n_rows());
        assert!(rates.iter().all(|&r| r > 0.0 && r <= 1.0));
        Self {
            rates,
            multiplicities: ds.m.clone(),
        }
    }

    /// Rows this sampler draws over.
    pub fn n_rows(&self) -> usize {
        self.rates.len()
    }

    /// One row of one pass: for row i with multiplicity m_i, draw
    /// Binomial(m_i, R_i) successes (each copy is an independent Q_ij)
    /// and return m'_i = successes / R_i (0.0 when unselected). Pure in
    /// `(key, row)` — this is the kernel every entry point below and the
    /// fused accept pass share.
    #[inline]
    pub fn draw_row(&self, key: SampleKey, row: usize) -> f32 {
        let r = self.rates[row];
        let m = self.multiplicities[row];
        let mut rng = CounterRng::keyed(key.seed, key.version, row as u64);
        let successes = draw_binomial(&mut rng, m as u64, r);
        if successes > 0 {
            (successes as f64 / r) as f32
        } else {
            0.0
        }
    }

    /// Draw rows `[lo, hi)` of a pass: weights written into the
    /// `hi - lo` local-indexed slice, selected global row ids appended
    /// to `rows` (ascending). Shards of one pass concatenate to exactly
    /// [`BernoulliSampler::draw`]'s output.
    pub fn draw_range(
        &self,
        key: SampleKey,
        lo: usize,
        hi: usize,
        weights: &mut [f32],
        rows: &mut Vec<u32>,
    ) {
        assert!(lo <= hi && hi <= self.rates.len());
        assert_eq!(weights.len(), hi - lo);
        for row in lo..hi {
            let w = self.draw_row(key, row);
            weights[row - lo] = w;
            if w > 0.0 {
                rows.push(row as u32);
            }
        }
    }

    /// Draw one full sampling pass for `key`.
    pub fn draw(&self, key: SampleKey) -> SamplePass {
        let n = self.rates.len();
        let mut weights = vec![0.0f32; n];
        let mut rows = Vec::new();
        self.draw_range(key, 0, n, &mut weights, &mut rows);
        SamplePass { weights, rows }
    }

    /// Expected number of selected rows.
    pub fn expected_selected(&self) -> f64 {
        self.rates
            .iter()
            .zip(&self.multiplicities)
            .map(|(&r, &m)| 1.0 - (1.0 - r).powf(m as f64))
            .sum()
    }
}

/// Binomial(n, p) sampler: exact Bernoulli loop for small n (the common
/// case, m_i is almost always small), normal approximation for large n.
/// Generic over the bit source so the keyed per-row stream and the
/// sequential [`crate::util::Rng`] (simulators, tests) share one kernel.
fn draw_binomial<R: RandStream>(rng: &mut R, n: u64, p: f64) -> u64 {
    if n == 0 || p <= 0.0 {
        return 0;
    }
    if p >= 1.0 {
        return n;
    }
    if n <= 64 {
        let mut c = 0;
        for _ in 0..n {
            if rng.bernoulli(p) {
                c += 1;
            }
        }
        c
    } else {
        // normal approximation with continuity correction, clamped
        let mean = n as f64 * p;
        let sd = (n as f64 * p * (1.0 - p)).sqrt();
        let x = (mean + sd * rng.normal() + 0.5).floor();
        x.clamp(0.0, n as f64) as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic;
    use crate::util::Rng;

    fn key(seed: u64, version: u64) -> SampleKey {
        SampleKey { seed, version }
    }

    #[test]
    fn weights_are_unbiased() {
        let ds = synthetic::realsim_like(500, 1);
        let s = BernoulliSampler::uniform(&ds, 0.3);
        let passes = 400;
        let mut mean = vec![0.0f64; ds.n_rows()];
        for v in 0..passes {
            let p = s.draw(key(2, v));
            for i in 0..ds.n_rows() {
                mean[i] += p.weights[i] as f64;
            }
        }
        let avg: f64 = mean.iter().map(|&x| x / passes as f64).sum::<f64>()
            / ds.n_rows() as f64;
        // E[m'_i] = m_i = 1
        assert!((avg - 1.0).abs() < 0.05, "avg={avg}");
    }

    #[test]
    fn selected_rows_match_weights() {
        let ds = synthetic::realsim_like(300, 3);
        let s = BernoulliSampler::uniform(&ds, 0.5);
        let p = s.draw(key(4, 0));
        for (i, &w) in p.weights.iter().enumerate() {
            let in_rows = p.rows.binary_search(&(i as u32)).is_ok();
            assert_eq!(w > 0.0, in_rows);
        }
        assert!(p.rows.windows(2).all(|w| w[0] < w[1]));
    }

    #[test]
    fn passes_are_pure_functions_of_their_key() {
        let ds = synthetic::realsim_like(200, 5);
        let s = BernoulliSampler::uniform(&ds, 0.4);
        let a = s.draw(key(9, 3));
        let b = s.draw(key(9, 3));
        assert_eq!(a.rows, b.rows);
        assert_eq!(a.weights, b.weights);
        // different versions under the same seed are distinct passes
        let c = s.draw(key(9, 4));
        assert_ne!(a.rows, c.rows);
    }

    #[test]
    fn sharded_draws_concatenate_to_the_full_pass() {
        let ds = synthetic::realsim_like(517, 6);
        let s = BernoulliSampler::uniform(&ds, 0.35);
        let k = key(11, 7);
        let full = s.draw(k);
        for n_shards in [2usize, 3, 8] {
            let mut weights = vec![0.0f32; ds.n_rows()];
            let mut rows = Vec::new();
            let per = ds.n_rows().div_ceil(n_shards);
            let mut lo = 0;
            while lo < ds.n_rows() {
                let hi = (lo + per).min(ds.n_rows());
                s.draw_range(k, lo, hi, &mut weights[lo..hi], &mut rows);
                lo = hi;
            }
            assert_eq!(weights, full.weights, "shards={n_shards}");
            assert_eq!(rows, full.rows, "shards={n_shards}");
        }
    }

    #[test]
    fn rate_one_selects_everything_with_exact_weights() {
        let ds = synthetic::realsim_like(100, 5);
        let s = BernoulliSampler::uniform(&ds, 1.0);
        let p = s.draw(key(6, 0));
        assert_eq!(p.n_selected(), 100);
        assert!(p.weights.iter().all(|&w| (w - 1.0).abs() < 1e-6));
    }

    #[test]
    fn small_rate_selects_few() {
        let ds = synthetic::realsim_like(2000, 7);
        let s = BernoulliSampler::uniform(&ds, 0.01);
        let p = s.draw(key(8, 0));
        assert!(p.n_selected() < 100, "selected={}", p.n_selected());
        assert!((s.expected_selected() - 20.0).abs() < 1.0);
        // selected weights are 1/rate
        for &r in &p.rows {
            assert!((p.weights[r as usize] - 100.0).abs() < 1e-3);
        }
    }

    #[test]
    fn multiplicities_scale_weights() {
        // one row with multiplicity 50 at rate 0.5: m' ≈ 50 on average
        let ds = synthetic::fig4_low_diversity(1).subset(&[0], "one");
        let mut ds = ds;
        ds.m = vec![50.0];
        let s = BernoulliSampler::uniform(&ds, 0.5);
        let mean: f64 = (0..2000)
            .map(|v| s.draw(key(9, v)).weights[0] as f64)
            .sum::<f64>()
            / 2000.0;
        assert!((mean - 50.0).abs() < 1.5, "mean={mean}");
    }

    #[test]
    fn binomial_large_n_normal_path() {
        let mut rng = Rng::new(10);
        let n = 10_000u64;
        let p = 0.3;
        let mean: f64 = (0..200)
            .map(|_| draw_binomial(&mut rng, n, p) as f64)
            .sum::<f64>()
            / 200.0;
        assert!((mean - 3000.0).abs() < 30.0, "mean={mean}");
    }

    #[test]
    #[should_panic(expected = "sampling rate")]
    fn zero_rate_panics() {
        let ds = synthetic::realsim_like(10, 1);
        BernoulliSampler::uniform(&ds, 0.0);
    }
}
