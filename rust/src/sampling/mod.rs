//! Bernoulli sampling — the mechanism that turns GBDT training into a
//! stochastic optimization problem (paper §IV, Corollary 1).
//!
//! Each sample copy `(i, j)` carries a Bernoulli variable `Q_ij` with
//! `P(Q_ij = 1) = R_ij`; a sampling pass produces the stochastic weights
//!
//! ```text
//! m'_i = sum_{j=1..m_i} Q_ij / R_ij        (Eq. 10)
//! ```
//!
//! which are unbiased for the multiplicities (`E m'_i = m_i`), so the
//! stochastic target `L'_random = [m'_1 l'_1, ...]` is an unbiased SGD
//! direction for the full loss. The observed support (`m'_i > 0`) is the
//! paper's Q′ vector, whose sparsity drives the scalability analysis.
//!
//! Passes are keyed, not streamed: a [`bernoulli::SampleKey`] fully
//! determines every row's draw (counter-based RNG), so one pass can be
//! computed whole, replayed, or sharded across threads with identical
//! results — the invariance the fused accept pipeline builds on.

pub mod bernoulli;

pub use bernoulli::{BernoulliSampler, SampleKey, SamplePass};
