//! Speedup sweeps over worker counts (Figure 10) + the Eq. 13 bound.

use super::cluster::{ClusterSpec, PhaseTimes};
use super::models::{simulate_async_ps, simulate_dimboost, simulate_lightgbm_fp};

/// Which simulated system a row belongs to.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SystemKind {
    /// The paper's asynchronous PS system.
    AsynchSgbdt,
    /// Feature-parallel fork-join baseline (LightGBM-style).
    LightGbmFp,
    /// AllReduce-per-layer baseline (DimBoost-style).
    DimBoost,
}

impl SystemKind {
    /// The CSV/figure tag of this system.
    pub fn as_str(&self) -> &'static str {
        match self {
            SystemKind::AsynchSgbdt => "asynch-sgbdt",
            SystemKind::LightGbmFp => "lightgbm-fp",
            SystemKind::DimBoost => "dimboost",
        }
    }

    /// All simulated systems, figure order.
    pub fn all() -> [SystemKind; 3] {
        [SystemKind::AsynchSgbdt, SystemKind::LightGbmFp, SystemKind::DimBoost]
    }
}

/// One (system, workers) measurement.
#[derive(Debug, Clone, Copy)]
pub struct SpeedupRow {
    /// Which system produced the row.
    pub system: SystemKind,
    /// Simulated worker count.
    pub workers: usize,
    /// Simulated wall time for the tree budget.
    pub wall_secs: f64,
    /// wall(1 worker of the same system) / wall(this row).
    pub speedup: f64,
    /// Mean realised staleness (async only; 0 for sync systems).
    pub mean_staleness: f64,
    /// Server-busy / barrier-cost fraction of wall.
    pub bottleneck_frac: f64,
}

/// Run all three systems over `worker_counts`, normalising each system by
/// its own single-worker time (the paper's speedup definition — the code
/// setting makes 1-worker asynch-SGBDT and LightGBM equal in real time).
pub fn speedup_sweep(
    times: &PhaseTimes,
    worker_counts: &[usize],
    n_trees: usize,
    speed_cv: f64,
    seed: u64,
) -> Vec<SpeedupRow> {
    let mut rows = Vec::new();
    for system in SystemKind::all() {
        let run = |w: usize| {
            let mut spec = ClusterSpec::new(w);
            spec.speed_cv = speed_cv;
            spec.seed = seed ^ (w as u64) << 1;
            match system {
                SystemKind::AsynchSgbdt => simulate_async_ps(&spec, times, n_trees),
                SystemKind::LightGbmFp => simulate_lightgbm_fp(&spec, times, n_trees),
                SystemKind::DimBoost => simulate_dimboost(&spec, times, n_trees),
            }
        };
        let base = run(1).wall_secs;
        for &w in worker_counts {
            let r = run(w);
            rows.push(SpeedupRow {
                system,
                workers: w,
                wall_secs: r.wall_secs,
                speedup: base / r.wall_secs.max(1e-12),
                mean_staleness: r.mean_staleness,
                bottleneck_frac: r.bottleneck_frac,
            });
        }
    }
    rows
}

/// Eq. 13: `#workers < T(BuildTree) / T(Communicate + BuildTarget)` — the
/// scalability ceiling of asynch-SGBDT given phase times.
pub fn eq13_upper_bound(times: &PhaseTimes, spec: &ClusterSpec) -> f64 {
    let comm = spec.net.xfer(times.target_bytes) + spec.net.xfer(times.tree_bytes);
    times.build_secs / (comm + times.target_secs + times.apply_secs)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sweep_covers_all_systems_and_counts() {
        let rows = speedup_sweep(&PhaseTimes::realsim_like(), &[1, 2, 4], 30, 0.15, 7);
        assert_eq!(rows.len(), 9);
        for r in &rows {
            assert!(r.speedup > 0.0);
            assert!(r.wall_secs > 0.0);
        }
        // speedup at 1 worker is 1 by construction
        for r in rows.iter().filter(|r| r.workers == 1) {
            assert!((r.speedup - 1.0).abs() < 1e-9, "{:?}", r);
        }
    }

    #[test]
    fn paper_shape_at_32_workers() {
        // The paper: asynch-SGBDT 14–22x, LightGBM 5–7x, DimBoost 4–6x.
        // The simulator must reproduce the ordering and rough magnitudes.
        let rows = speedup_sweep(&PhaseTimes::realsim_like(), &[32], 200, 0.15, 11);
        let get = |k: SystemKind| rows.iter().find(|r| r.system == k).unwrap().speedup;
        let a = get(SystemKind::AsynchSgbdt);
        let l = get(SystemKind::LightGbmFp);
        let d = get(SystemKind::DimBoost);
        assert!(a > 10.0 && a < 32.0, "async speedup {a:.1}");
        assert!(l > 3.0 && l < 12.0, "lightgbm speedup {l:.1}");
        assert!(d > 1.0 && d < 10.0, "dimboost speedup {d:.1}");
        assert!(a > l && l >= d * 0.8, "ordering broken: {a:.1} {l:.1} {d:.1}");
    }

    #[test]
    fn eq13_bound_is_finite_and_positive() {
        let spec = ClusterSpec::new(32);
        let b = eq13_upper_bound(&PhaseTimes::realsim_like(), &spec);
        assert!(b > 1.0 && b < 1000.0, "bound={b}");
        // e2006 has longer builds => higher ceiling
        let b2 = eq13_upper_bound(&PhaseTimes::e2006_like(), &spec);
        assert!(b2 > b);
    }
}
