//! Discrete-event cluster simulator — the substrate for the paper's
//! efficiency experiment (Figure 10).
//!
//! The paper measured speedups on a 32-machine Era-supercomputer partition
//! over gigabit TCP/IP. That testbed is a hardware gate for this
//! reproduction, so we model it: per-task node-speed jitter (heterogeneous
//! nodes — the paper's stated reason synchronous scaling dies), a
//! latency/bandwidth network, and the three system architectures under
//! comparison:
//!
//! * **asynch-SGBDT** — workers loop independently; the server applies
//!   pushes FCFS. Throughput saturates at Eq. 13's bound
//!   `#workers < T(build) / T(comm + target)`.
//! * **LightGBM feature-parallel** — fork-join: per tree, every worker
//!   scans its feature share, then a barrier + allgather of split
//!   candidates; the barrier pays the straggler max.
//! * **DimBoost** — PS-based fork-join: histogram allgather through a
//!   central server whose cost grows linearly in worker count.
//!
//! A fourth model, [`simulate_sharded_ps`], reprices the asynch-SGBDT
//! server as `ps_shards` row/feature shards (`ps/sharded.rs`): apply and
//! target production parallelise across shards while a sparse histogram
//! exchange (`PhaseTimes::sparse_touch_frac` of the dense payload) joins
//! the critical path — the cost model behind the sharded PS's
//! staleness-distribution tests.
//!
//! A fifth, [`simulate_async_ps_churn`], runs the asynch-SGBDT model
//! under a worker [`FailureModel`] (exponential MTBF + restart cost +
//! restart budget) — the simulator mirror of the trainer's fault
//! injection and supervision (DESIGN.md §14), predicting trees/sec under
//! churn and stalling short when every worker retires.
//!
//! Phase-time inputs are *calibrated from real single-node measurements*
//! (`PhaseTimes::calibrate`) taken from this crate's own trainers, so the
//! simulated shapes inherit the real compute/communication ratios.

pub mod cluster;
pub mod convergence;
pub mod models;
pub mod speedup;

pub use cluster::{ClusterSpec, FailureModel, NetworkSpec, PhaseTimes};
pub use convergence::{contraction, gap_curve, trees_to_target};
pub use models::{
    simulate_async_ps, simulate_async_ps_churn, simulate_dimboost, simulate_lightgbm_fp,
    simulate_sharded_ps, simulate_sharded_ps_trace, SimResult,
};
pub use speedup::{eq13_upper_bound, speedup_sweep, SpeedupRow, SystemKind};
