//! The three simulated system architectures.

use std::cmp::Reverse;
use std::collections::BinaryHeap;

use crate::util::Rng;

use super::cluster::{ClusterSpec, FailureModel, PhaseTimes};

/// Result of simulating one training run.
#[derive(Debug, Clone, Copy)]
pub struct SimResult {
    /// Wall-clock seconds until the target tree count was reached.
    pub wall_secs: f64,
    /// Trees accepted. Equal to the requested n_trees for every model
    /// except [`simulate_async_ps_churn`], where a run whose workers all
    /// retire (restart budgets exhausted) stalls short.
    pub n_trees: usize,
    /// Mean realised staleness (async only; 0 for sync systems).
    pub mean_staleness: f64,
    /// Fraction of wall time the server was busy (async) or the barrier
    /// cost fraction (sync) — the headline bottleneck indicator.
    pub bottleneck_frac: f64,
}

impl SimResult {
    /// Simulated accepted-tree throughput.
    pub fn trees_per_sec(&self) -> f64 {
        self.n_trees as f64 / self.wall_secs.max(1e-12)
    }
}

/// Asynch-SGBDT on a parameter server, event-driven.
///
/// Workers cycle independently: pull target (net) → build (jittered) →
/// push tree (net). The server is a FCFS queue applying pushes
/// (`apply + target` per acceptance). No barrier anywhere.
///
/// Equivalent to [`simulate_sharded_ps`] at `ps_shards=1` — same RNG
/// stream, same event order, same staleness trace, same wall clock.
pub fn simulate_async_ps(
    spec: &ClusterSpec,
    times: &PhaseTimes,
    n_trees: usize,
) -> SimResult {
    simulate_sharded_ps_trace(spec, times, n_trees, 1).0
}

/// Per-acceptance server service time of a `ps_shards`-way sharded PS.
///
/// Apply + produce-target parallelise across the row shards (each owns
/// `1/S` of the rows); what sharding *adds* is the histogram exchange on
/// the critical path: `2(S-1)` messages per acceptance (scatter one
/// window per peer, gather one per peer), each carrying only the
/// **touched** fraction of its `1/S` slot window
/// (`hist_bytes · sparse_touch_frac / S` — Vasiloudis et al.'s sparse
/// communication). A dense exchange (`sparse_touch_frac = 1`) at high
/// shard counts costs *more* than not sharding at all, which is exactly
/// the regime the sparse encoding exists to avoid.
fn shard_service(spec: &ClusterSpec, times: &PhaseTimes, ps_shards: usize) -> f64 {
    let single = times.apply_secs + times.target_secs;
    if ps_shards <= 1 {
        return single;
    }
    let s = ps_shards as f64;
    let exchange_msg = times.hist_bytes * times.sparse_touch_frac / s;
    single / s + 2.0 * (s - 1.0) * spec.net.xfer(exchange_msg)
}

/// [`simulate_sharded_ps`] plus the per-acceptance staleness trace
/// (τ of each accepted push, in acceptance order) — the observable the
/// staleness-distribution tests compare across shard counts.
///
/// The trace is **arrival-driven**: a worker's next push time is
/// `arrive + pull + build·jitter + push`, independent of the server's
/// service time, so changing `ps_shards` (which only changes service
/// time) reshapes the wall clock but leaves the acceptance order and
/// hence the τ sequence bit-identical at a fixed seed. The tests pin
/// that invariant; composed shard versions change *when* a version is
/// visible, never *which* version a push was built against.
pub fn simulate_sharded_ps_trace(
    spec: &ClusterSpec,
    times: &PhaseTimes,
    n_trees: usize,
    ps_shards: usize,
) -> (SimResult, Vec<u64>) {
    let mut rng = Rng::new(spec.seed);
    let w = spec.n_workers.max(1);
    let pull = spec.net.xfer(times.target_bytes);
    let push = spec.net.xfer(times.tree_bytes);

    // event heap: (ready_time, worker_id) for push arrivals at the server
    let mut heap: BinaryHeap<Reverse<(u64, usize)>> = BinaryHeap::new();
    let to_key = |t: f64| (t * 1e9) as u64;
    let from_key = |k: u64| k as f64 / 1e9;

    // each worker starts with a pull + first build
    for wid in 0..w {
        let t = pull + times.build_secs * spec.jitter(&mut rng) + push;
        heap.push(Reverse((to_key(t), wid)));
    }

    let mut server_free = 0.0f64;
    let mut server_busy_total = 0.0f64;
    let mut accepted = 0usize;
    let mut last_done = 0.0f64;
    // versions for staleness accounting: worker's tree was built against
    // the version current when it started building.
    let mut version_at_start = vec![0u64; w];
    let mut version = 0u64;
    let mut staleness_sum = 0.0f64;
    let mut trace = Vec::with_capacity(n_trees);

    while accepted < n_trees {
        let Reverse((tk, wid)) = heap.pop().expect("heap never empties");
        let arrive = from_key(tk);
        let start = arrive.max(server_free);
        let service = shard_service(spec, times, ps_shards);
        let done = start + service;
        server_free = done;
        server_busy_total += service;
        accepted += 1;
        let tau = version - version_at_start[wid];
        staleness_sum += tau as f64;
        trace.push(tau);
        version += 1;
        last_done = done;
        if accepted >= n_trees {
            break;
        }
        // the worker does not wait for the server: it pulls the then-
        // current version right after pushing (approximated by the version
        // just published for its own accepted tree).
        version_at_start[wid] = version;
        // next push: pull + build + push from `arrive`
        let next = arrive + pull + times.build_secs * spec.jitter(&mut rng) + push;
        heap.push(Reverse((to_key(next), wid)));
    }

    let result = SimResult {
        wall_secs: last_done,
        n_trees,
        mean_staleness: staleness_sum / n_trees.max(1) as f64,
        bottleneck_frac: server_busy_total / last_done.max(1e-12),
    };
    (result, trace)
}

/// Per-worker churn state for [`simulate_async_ps_churn`]: pending
/// failure times, remaining restart budgets and the failure RNG stream
/// (separate from the jitter stream, so arming churn never perturbs the
/// base model's build-time draws).
struct ChurnState<'a> {
    fm: &'a FailureModel,
    next_fail: Vec<f64>,
    lives: Vec<usize>,
    frng: Rng,
}

impl ChurnState<'_> {
    /// When does `wid`'s cycle starting at `start` actually finish?
    /// Every failure inside the cycle loses the in-progress tree and
    /// restarts the cycle after the restart cost — until the cycle fits
    /// between failures (`Some(end)`) or the worker's restart budget
    /// runs out mid-cycle (`None`: the worker retires).
    fn cycle_end(&mut self, wid: usize, mut start: f64, cycle_secs: f64) -> Option<f64> {
        loop {
            if self.next_fail[wid] >= start + cycle_secs {
                return Some(start + cycle_secs);
            }
            if self.lives[wid] == 0 {
                return None;
            }
            self.lives[wid] -= 1;
            start = self.next_fail[wid] + self.fm.restart_secs;
            self.next_fail[wid] = start + self.fm.mtbf_secs * self.frng.exponential();
        }
    }
}

/// [`simulate_async_ps`] under worker churn: each worker fails with
/// exponentially-distributed inter-failure times (mean
/// `failure.mtbf_secs`), loses its in-progress tree, pays
/// `failure.restart_secs` of downtime per granted restart, and retires
/// once its `failure.max_restarts` budget is spent — the simulator
/// mirror of the trainer's supervision loop, predicting trees/sec under
/// churn (DESIGN.md §14). An inactive model ([`FailureModel::none`])
/// reduces to the base model *exactly* (same RNG stream, same events).
/// If every worker retires, the run stalls short: the result's
/// `n_trees` is the accepted count, not the request.
pub fn simulate_async_ps_churn(
    spec: &ClusterSpec,
    times: &PhaseTimes,
    n_trees: usize,
    failure: &FailureModel,
) -> SimResult {
    if !failure.is_active() {
        return simulate_async_ps(spec, times, n_trees);
    }
    let mut rng = Rng::new(spec.seed);
    let mut frng = Rng::new(spec.seed ^ 0xFA11);
    let w = spec.n_workers.max(1);
    let pull = spec.net.xfer(times.target_bytes);
    let push = spec.net.xfer(times.tree_bytes);
    let mut churn = ChurnState {
        fm: failure,
        next_fail: (0..w)
            .map(|_| failure.mtbf_secs * frng.exponential())
            .collect(),
        lives: vec![failure.max_restarts; w],
        frng,
    };

    let mut heap: BinaryHeap<Reverse<(u64, usize)>> = BinaryHeap::new();
    let to_key = |t: f64| (t * 1e9) as u64;
    let from_key = |k: u64| k as f64 / 1e9;

    for wid in 0..w {
        let cycle = pull + times.build_secs * spec.jitter(&mut rng) + push;
        if let Some(t) = churn.cycle_end(wid, 0.0, cycle) {
            heap.push(Reverse((to_key(t), wid)));
        }
    }

    let mut server_free = 0.0f64;
    let mut server_busy_total = 0.0f64;
    let mut accepted = 0usize;
    let mut last_done = 0.0f64;
    let mut version_at_start = vec![0u64; w];
    let mut version = 0u64;
    let mut staleness_sum = 0.0f64;

    while accepted < n_trees {
        // an empty heap means every worker retired: stall short
        let Some(Reverse((tk, wid))) = heap.pop() else {
            break;
        };
        let arrive = from_key(tk);
        let start = arrive.max(server_free);
        let service = times.apply_secs + times.target_secs;
        let done = start + service;
        server_free = done;
        server_busy_total += service;
        accepted += 1;
        staleness_sum += (version - version_at_start[wid]) as f64;
        version += 1;
        last_done = done;
        if accepted >= n_trees {
            break;
        }
        version_at_start[wid] = version;
        let cycle = pull + times.build_secs * spec.jitter(&mut rng) + push;
        if let Some(t) = churn.cycle_end(wid, arrive, cycle) {
            heap.push(Reverse((to_key(t), wid)));
        }
    }

    SimResult {
        wall_secs: last_done,
        n_trees: accepted,
        mean_staleness: staleness_sum / accepted.max(1) as f64,
        bottleneck_frac: server_busy_total / last_done.max(1e-12),
    }
}

/// Asynch-SGBDT on a `ps_shards`-way sharded parameter server: the
/// [`simulate_async_ps`] event model with the per-acceptance service
/// time replaced by the sharded cost (parallel apply/target plus the
/// sparse histogram exchange — see `shard_service`).
pub fn simulate_sharded_ps(
    spec: &ClusterSpec,
    times: &PhaseTimes,
    n_trees: usize,
    ps_shards: usize,
) -> SimResult {
    simulate_sharded_ps_trace(spec, times, n_trees, ps_shards).0
}

/// LightGBM feature-parallel (fork-join): each tree costs
/// `max_w(build/W · jitter_w) + allgather(split candidates) + target`.
/// The barrier pays the straggler max; communication is a ring allgather
/// of per-worker split candidates (small) plus a broadcast of the chosen
/// split per level — modelled as `2(W-1)` latency-dominated messages per
/// tree plus the feature-share histogram exchange.
pub fn simulate_lightgbm_fp(
    spec: &ClusterSpec,
    times: &PhaseTimes,
    n_trees: usize,
) -> SimResult {
    let mut rng = Rng::new(spec.seed ^ 0xf00d);
    let w = spec.n_workers.max(1) as f64;
    let mut wall = 0.0f64;
    let mut barrier_cost = 0.0f64;
    for _ in 0..n_trees {
        // parallel scan of feature shares
        let mut max_build = 0.0f64;
        let mut sum_build = 0.0f64;
        for _ in 0..spec.n_workers.max(1) {
            let b = (times.build_secs / w) * spec.jitter(&mut rng);
            max_build = max_build.max(b);
            sum_build += b;
        }
        let mean_build = sum_build / w;
        barrier_cost += max_build - mean_build;
        // allgather split candidates: 2(W-1) messages of candidate blocks
        let comm = 2.0 * (w - 1.0) * spec.net.xfer(times.hist_bytes / w.max(1.0));
        wall += max_build + comm + times.target_secs;
    }
    SimResult {
        wall_secs: wall,
        n_trees,
        mean_staleness: 0.0,
        bottleneck_frac: barrier_cost / wall.max(1e-12),
    }
}

/// DimBoost/TencentBoost: fork-join with the histogram allgather routed
/// through the central parameter server ("parameter server's allgather is
/// a centralization operation … the burden of the server is the key for
/// scalability" — §VI.C). Server receives W histogram shares serially.
pub fn simulate_dimboost(
    spec: &ClusterSpec,
    times: &PhaseTimes,
    n_trees: usize,
) -> SimResult {
    let mut rng = Rng::new(spec.seed ^ 0xd1b0);
    let w = spec.n_workers.max(1) as f64;
    let mut wall = 0.0f64;
    let mut server_cost = 0.0f64;
    for _ in 0..n_trees {
        let mut max_build = 0.0f64;
        for _ in 0..spec.n_workers.max(1) {
            let b = (times.build_secs / w) * spec.jitter(&mut rng);
            max_build = max_build.max(b);
        }
        // central allgather: server ingests W histogram shares one by one,
        // merges each on the server CPU (~2 GB/s effective merge
        // bandwidth), then broadcasts the merged result. The serial merge
        // is the centralisation burden §VI.C blames for DimBoost's
        // scalability ceiling.
        let merge = w * (times.hist_bytes / 2e9);
        let ingest = w * spec.net.xfer(times.hist_bytes / w);
        let bcast = spec.net.xfer(times.hist_bytes);
        let comm = ingest + merge + bcast;
        server_cost += comm;
        wall += max_build + comm + times.target_secs;
    }
    SimResult {
        wall_secs: wall,
        n_trees,
        mean_staleness: 0.0,
        bottleneck_frac: server_cost / wall.max(1e-12),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec(w: usize) -> ClusterSpec {
        ClusterSpec::new(w)
    }

    #[test]
    fn async_single_worker_matches_closed_form() {
        let mut s = spec(1);
        s.speed_cv = 0.0;
        let t = PhaseTimes::realsim_like();
        let r = simulate_async_ps(&s, &t, 10);
        // worker cycle: pull+build+push, server: apply+target; with one
        // worker the pipeline overlaps build with nothing, so wall ≈
        // 10 * cycle (server service overlaps the next build only after
        // the first arrival). Sanity: within [10*build, 10*(cycle+service)]
        let cycle = s.net.xfer(t.target_bytes) + t.build_secs + s.net.xfer(t.tree_bytes);
        assert!(r.wall_secs >= 10.0 * t.build_secs);
        assert!(r.wall_secs <= 10.0 * (cycle + t.apply_secs + t.target_secs) + 1.0);
        assert_eq!(r.n_trees, 10);
    }

    #[test]
    fn async_scales_until_server_saturates() {
        let t = PhaseTimes::realsim_like();
        let base = simulate_async_ps(&spec(1), &t, 200).trees_per_sec();
        let w8 = simulate_async_ps(&spec(8), &t, 200).trees_per_sec();
        let w32 = simulate_async_ps(&spec(32), &t, 200).trees_per_sec();
        let w128 = simulate_async_ps(&spec(128), &t, 200).trees_per_sec();
        assert!(w8 > 6.0 * base, "8-worker speedup too low: {}", w8 / base);
        assert!(w32 > w8);
        // server-side service time caps throughput (Eq. 13)
        let cap = 1.0 / (t.apply_secs + t.target_secs);
        assert!(w128 <= cap * 1.01);
        // saturation: 128 workers barely beat 32
        assert!(w128 / w32 < 2.0);
    }

    #[test]
    fn sync_speedup_saturates_earlier_than_async() {
        let t = PhaseTimes::realsim_like();
        let n = 100;
        let a1 = simulate_async_ps(&spec(1), &t, n).wall_secs;
        let a32 = simulate_async_ps(&spec(32), &t, n).wall_secs;
        let l1 = simulate_lightgbm_fp(&spec(1), &t, n).wall_secs;
        let l32 = simulate_lightgbm_fp(&spec(32), &t, n).wall_secs;
        let async_speedup = a1 / a32;
        let sync_speedup = l1 / l32;
        assert!(
            async_speedup > 1.8 * sync_speedup,
            "async {async_speedup:.1} vs sync {sync_speedup:.1}"
        );
    }

    #[test]
    fn dimboost_worse_than_lightgbm_at_scale() {
        let t = PhaseTimes::realsim_like();
        let n = 50;
        let l = simulate_dimboost(&spec(1), &t, n).wall_secs
            / simulate_dimboost(&spec(32), &t, n).wall_secs;
        let g = simulate_lightgbm_fp(&spec(1), &t, n).wall_secs
            / simulate_lightgbm_fp(&spec(32), &t, n).wall_secs;
        assert!(l < g * 1.2, "dimboost speedup {l:.1} should not exceed lightgbm {g:.1} by much");
    }

    #[test]
    fn async_staleness_grows_with_workers() {
        let t = PhaseTimes::realsim_like();
        let s1 = simulate_async_ps(&spec(2), &t, 100).mean_staleness;
        let s32 = simulate_async_ps(&spec(32), &t, 100).mean_staleness;
        assert!(s32 > s1);
    }

    #[test]
    fn deterministic_under_seed() {
        let t = PhaseTimes::realsim_like();
        let a = simulate_async_ps(&spec(8), &t, 50);
        let b = simulate_async_ps(&spec(8), &t, 50);
        assert_eq!(a.wall_secs, b.wall_secs);
    }

    #[test]
    fn sharded_at_one_shard_is_the_async_model_exactly() {
        let t = PhaseTimes::realsim_like();
        let a = simulate_async_ps(&spec(16), &t, 120);
        let s = simulate_sharded_ps(&spec(16), &t, 120, 1);
        assert_eq!(a.wall_secs, s.wall_secs);
        assert_eq!(a.mean_staleness, s.mean_staleness);
        assert_eq!(a.bottleneck_frac, s.bottleneck_frac);
    }

    #[test]
    fn sparse_sharding_speeds_a_saturated_server() {
        // at 128 workers the single server is the bottleneck (Eq. 13);
        // sparse-exchange shards cut the per-acceptance service time, so
        // throughput rises — while a *dense* exchange at high shard
        // counts costs more than not sharding at all
        let t = PhaseTimes::realsim_like();
        let single = simulate_sharded_ps(&spec(128), &t, 300, 1).trees_per_sec();
        let s4 = simulate_sharded_ps(&spec(128), &t, 300, 4).trees_per_sec();
        assert!(s4 > 1.5 * single, "4 sparse shards: {s4:.1} vs {single:.1}");
        let mut dense = t;
        dense.sparse_touch_frac = 1.0;
        let d8 = simulate_sharded_ps(&spec(128), &dense, 300, 8).trees_per_sec();
        assert!(d8 < single, "dense 8-shard exchange should lose: {d8:.1} vs {single:.1}");
    }

    #[test]
    fn churn_with_no_failures_is_the_base_model_exactly() {
        let t = PhaseTimes::realsim_like();
        let base = simulate_async_ps(&spec(8), &t, 80);
        let churn = simulate_async_ps_churn(&spec(8), &t, 80, &FailureModel::none());
        assert_eq!(base.wall_secs, churn.wall_secs);
        assert_eq!(base.mean_staleness, churn.mean_staleness);
        assert_eq!(base.n_trees, churn.n_trees);
    }

    #[test]
    fn churn_lowers_throughput_monotonically() {
        // shorter MTBF → more lost trees + more restart downtime →
        // fewer trees/sec; the restart budget is generous so no worker
        // retires and every run still delivers all requested trees
        let t = PhaseTimes::realsim_like();
        let fm = |mtbf: f64| FailureModel {
            mtbf_secs: mtbf,
            restart_secs: 1.0,
            max_restarts: 1000,
        };
        let clean = simulate_async_ps_churn(&spec(8), &t, 100, &FailureModel::none());
        let mild = simulate_async_ps_churn(&spec(8), &t, 100, &fm(2.0));
        let harsh = simulate_async_ps_churn(&spec(8), &t, 100, &fm(0.5));
        assert_eq!(mild.n_trees, 100);
        assert_eq!(harsh.n_trees, 100);
        assert!(
            clean.trees_per_sec() > mild.trees_per_sec(),
            "mild churn should cost throughput: {} vs {}",
            clean.trees_per_sec(),
            mild.trees_per_sec()
        );
        assert!(
            mild.trees_per_sec() > harsh.trees_per_sec(),
            "harsher churn should cost more: {} vs {}",
            mild.trees_per_sec(),
            harsh.trees_per_sec()
        );
    }

    #[test]
    fn churn_retires_workers_and_stalls_short() {
        // failures arrive every ~1 ms against a ~0.6 s build: no cycle
        // ever completes, each worker burns its one restart and retires,
        // and the run reports the trees it actually accepted (none)
        let t = PhaseTimes::realsim_like();
        let fm = FailureModel {
            mtbf_secs: 1e-3,
            restart_secs: 0.1,
            max_restarts: 1,
        };
        let r = simulate_async_ps_churn(&spec(4), &t, 50, &fm);
        assert!(r.n_trees < 50, "all workers retired, got {} trees", r.n_trees);
    }

    #[test]
    fn churn_is_deterministic_under_seed() {
        let t = PhaseTimes::realsim_like();
        let fm = FailureModel {
            mtbf_secs: 1.5,
            restart_secs: 0.5,
            max_restarts: 10,
        };
        let a = simulate_async_ps_churn(&spec(8), &t, 60, &fm);
        let b = simulate_async_ps_churn(&spec(8), &t, 60, &fm);
        assert_eq!(a.wall_secs, b.wall_secs);
        assert_eq!(a.n_trees, b.n_trees);
        assert_eq!(a.mean_staleness, b.mean_staleness);
    }

    #[test]
    fn staleness_trace_is_arrival_driven_and_shard_invariant() {
        // service time never feeds back into push arrival times, so the
        // acceptance order — and hence every τ — is identical at any
        // shard count for a fixed seed
        let t = PhaseTimes::realsim_like();
        let (r1, trace1) = simulate_sharded_ps_trace(&spec(16), &t, 150, 1);
        for shards in [2usize, 4, 8] {
            let (rs, ts) = simulate_sharded_ps_trace(&spec(16), &t, 150, shards);
            assert_eq!(ts, trace1, "τ trace diverged at {shards} shards");
            assert_eq!(rs.mean_staleness, r1.mean_staleness);
        }
    }
}
