//! Analytic convergence model for stale boosting pushes — the bridge
//! between a simulated staleness trace and a trees-to-target-error
//! count, used by the fig9-style fixed-vs-adaptive step sweep
//! (`experiments/adaptive.rs`).
//!
//! Model (DESIGN.md §17): one accepted push at effective step `v_eff`
//! built against a target `τ` versions old multiplies the optimality
//! gap by the quadratic upper bound
//!
//! ```text
//! m(v_eff, τ) = 1 − 2·v_eff + v_eff²·(1 + τ)
//! ```
//!
//! — the standard `(1 − v)²` contraction of a fresh functional-gradient
//! step, plus a curvature term inflated by staleness (a stale direction
//! is still a descent direction in expectation, but its second-order
//! error grows with how far the margin vector moved since the pull;
//! this is the shape behind the paper's Proposition 1 step-length
//! condition). Under `step=fixed` the multiplier exceeds 1 — divergence
//! — once `τ > (2 − v)·(1 − v)/v + …`, i.e. at any fixed `v` there is a
//! staleness beyond which pushes hurt. Under `step=adaptive`
//! (`v_eff = v/(1+τ)`) the multiplier becomes
//! `1 − v·(2 − v)/(1 + τ)`, strictly below 1 for every τ whenever
//! `0 < v < 2`: adaptive steps never diverge, they just slow down.
//!
//! The model is deliberately deterministic — a pure fold over the τ
//! trace — so the sweep is replayable and testable without RNG.

use crate::config::StepMode;

/// One-push contraction factor of the optimality gap at effective step
/// `v_eff` and staleness `τ`, clamped at 0 (a gap cannot go negative).
pub fn contraction(v_eff: f64, tau: u64) -> f64 {
    let m = 1.0 - 2.0 * v_eff + v_eff * v_eff * (1.0 + tau as f64);
    m.max(0.0)
}

/// Fold the contraction over an accepted-push staleness trace: the
/// modelled optimality gap after each push, starting from 1.0. The
/// effective step of push `j` is `mode.effective(v, trace[j])` — the
/// same rule the live server applies (`config::StepMode::effective`).
pub fn gap_curve(trace: &[u64], v: f32, mode: StepMode) -> Vec<f64> {
    let mut gap = 1.0f64;
    trace
        .iter()
        .map(|&tau| {
            let v_eff = mode.effective(v, tau) as f64;
            gap *= contraction(v_eff, tau);
            gap
        })
        .collect()
}

/// Pushes needed to drive the modelled gap to `target` (< 1.0) under
/// the given step rule, or `None` if the trace ends (or the model
/// plateaus/diverges) before reaching it — the y-axis of the
/// fixed-vs-adaptive sweep.
pub fn trees_to_target(trace: &[u64], v: f32, mode: StepMode, target: f64) -> Option<usize> {
    let mut gap = 1.0f64;
    for (j, &tau) in trace.iter().enumerate() {
        let v_eff = mode.effective(v, tau) as f64;
        gap *= contraction(v_eff, tau);
        if gap <= target {
            return Some(j + 1);
        }
    }
    None
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fresh_pushes_contract_identically_under_both_rules() {
        // τ ≡ 0: adaptive divides by 1.0, so the two rules are the same
        // model point for point.
        let trace = vec![0u64; 40];
        let fixed = gap_curve(&trace, 0.3, StepMode::Fixed);
        let adaptive = gap_curve(&trace, 0.3, StepMode::Adaptive);
        assert_eq!(fixed, adaptive);
        assert!(fixed.last().unwrap() < &1e-6, "fresh steps must converge fast");
    }

    #[test]
    fn fixed_steps_diverge_past_the_proposition_1_staleness() {
        // v = 0.3: m(0.3, τ) = 1 − 0.6 + 0.09(1+τ) > 1 ⇔ τ > 5.67
        assert!(contraction(0.3, 0) < 1.0);
        assert!(contraction(0.3, 5) < 1.0);
        assert!(contraction(0.3, 7) > 1.0, "stale fixed push must inflate the gap");
        let trace = vec![8u64; 200];
        assert_eq!(trees_to_target(&trace, 0.3, StepMode::Fixed, 0.1), None);
    }

    #[test]
    fn adaptive_steps_contract_at_every_staleness() {
        for tau in [0u64, 1, 4, 16, 64, 1024] {
            let v_eff = StepMode::Adaptive.effective(0.3, tau) as f64;
            let m = contraction(v_eff, tau);
            assert!(m < 1.0, "τ={tau}: adaptive multiplier {m} must contract");
        }
        // ...so adaptive reaches any target on a trace where fixed diverges
        let trace = vec![8u64; 2_000];
        let adaptive = trees_to_target(&trace, 0.3, StepMode::Adaptive, 0.1).unwrap();
        assert!(adaptive > 0);
        assert_eq!(trees_to_target(&trace, 0.3, StepMode::Fixed, 0.1), None);
    }

    #[test]
    fn staler_traces_need_more_adaptive_trees() {
        let fresh = trees_to_target(&vec![0u64; 500], 0.3, StepMode::Adaptive, 0.01).unwrap();
        let stale = trees_to_target(&vec![6u64; 500], 0.3, StepMode::Adaptive, 0.01).unwrap();
        assert!(
            stale > fresh,
            "staleness must cost trees even under adaptive ({stale} vs {fresh})"
        );
    }
}
