//! Cluster and workload parameterisation.

use crate::util::Rng;

/// Network model: fixed per-message latency + bandwidth.
#[derive(Debug, Clone, Copy)]
pub struct NetworkSpec {
    /// Fixed per-message software latency (seconds).
    pub latency_s: f64,
    /// Effective bandwidth (bytes/second).
    pub bandwidth_bytes_per_s: f64,
}

impl NetworkSpec {
    /// Gigabit TCP/IP over Intel I350 (the paper's interconnect):
    /// ~80 µs round-trip software latency, ~117 MB/s effective.
    pub fn gigabit_tcp() -> NetworkSpec {
        NetworkSpec {
            latency_s: 80e-6,
            bandwidth_bytes_per_s: 117e6,
        }
    }

    /// Transfer time of one message.
    pub fn xfer(&self, bytes: f64) -> f64 {
        self.latency_s + bytes / self.bandwidth_bytes_per_s
    }
}

/// The simulated cluster.
#[derive(Debug, Clone, Copy)]
pub struct ClusterSpec {
    /// Worker-node count.
    pub n_workers: usize,
    /// Coefficient of variation of per-task node speed (the paper: "it is
    /// unlikely that all nodes in a system share the same computation
    /// speed"). 0 = perfectly homogeneous.
    pub speed_cv: f64,
    /// The interconnect model.
    pub net: NetworkSpec,
    /// Seed of the simulator's jitter streams.
    pub seed: u64,
}

impl ClusterSpec {
    /// Paper-like defaults (gigabit TCP, 15% speed CV) at a worker count.
    pub fn new(n_workers: usize) -> ClusterSpec {
        ClusterSpec {
            n_workers,
            speed_cv: 0.15,
            net: NetworkSpec::gigabit_tcp(),
            seed: 42,
        }
    }

    /// Multiplicative task-duration jitter with mean 1 and the configured
    /// CV (gamma-distributed — heavy right tail, like real stragglers).
    pub fn jitter(&self, rng: &mut Rng) -> f64 {
        if self.speed_cv <= 0.0 {
            return 1.0;
        }
        let k = 1.0 / (self.speed_cv * self.speed_cv);
        rng.gamma(k) / k
    }
}

/// Worker failure model for the churn simulation
/// (`simulate_async_ps_churn`): exponentially-distributed failures at a
/// mean time between failures, a fixed restart cost per revival, and a
/// restart budget per worker — the simulator mirror of the trainer's
/// `worker_restarts` supervision (DESIGN.md §14).
#[derive(Debug, Clone, Copy)]
pub struct FailureModel {
    /// Mean time between failures per worker (seconds); infinite = no
    /// failures ever.
    pub mtbf_secs: f64,
    /// Downtime per granted restart (detection + respawn + warmup).
    pub restart_secs: f64,
    /// Restarts each worker may consume before it retires for good.
    pub max_restarts: usize,
}

impl FailureModel {
    /// No failures: churn simulation reduces exactly to the base model.
    pub fn none() -> FailureModel {
        FailureModel {
            mtbf_secs: f64::INFINITY,
            restart_secs: 0.0,
            max_restarts: 0,
        }
    }

    /// Whether this model ever injects a failure.
    pub fn is_active(&self) -> bool {
        self.mtbf_secs.is_finite()
    }
}

/// Single-node phase times + message sizes: the calibration inputs every
/// simulated system shares.
#[derive(Debug, Clone, Copy)]
pub struct PhaseTimes {
    /// One full tree build on one node (seconds).
    pub build_secs: f64,
    /// Produce-target (sample + gradient) on the server.
    pub target_secs: f64,
    /// Apply a tree to F on the server.
    pub apply_secs: f64,
    /// Serialized tree size (bytes) — worker→server push.
    pub tree_bytes: f64,
    /// Target snapshot size (bytes) — server→worker pull.
    pub target_bytes: f64,
    /// Per-feature histogram block size (bytes) — sync allgather payloads.
    pub hist_bytes: f64,
    /// Fraction of histogram bins actually touched by a sampled tree's
    /// rows — what a *sparse* shard exchange ships instead of the dense
    /// `hist_bytes` (Vasiloudis et al.'s sparse-communication argument;
    /// the sharded-PS cost model multiplies `hist_bytes` by this).
    /// 1.0 models a dense exchange.
    pub sparse_touch_frac: f64,
}

impl PhaseTimes {
    /// Defaults shaped like the paper's real-sim runs: tree build dominates
    /// but not overwhelmingly (16–32 workers is the Eq. 13 ceiling — §VI.C
    /// "16 to 32 worker is close to the max number of the worker").
    pub fn realsim_like() -> PhaseTimes {
        PhaseTimes {
            build_secs: 0.60,
            target_secs: 0.022,
            apply_secs: 0.008,
            tree_bytes: 16e3,
            target_bytes: 600e3,
            hist_bytes: 2.5e6,
            // real-sim sparsity: ~10% of (feature, bin) slots touched per
            // sampled tree (matches the testkit fixtures' touch rates)
            sparse_touch_frac: 0.10,
        }
    }

    /// E2006-like: much wider feature space — bigger histograms, longer
    /// builds (400-leaf trees over ~4M features), heavier server apply;
    /// async headroom is larger (paper: ~20x at 32 workers).
    pub fn e2006_like() -> PhaseTimes {
        PhaseTimes {
            build_secs: 1.8,
            target_secs: 0.050,
            apply_secs: 0.030,
            tree_bytes: 30e3,
            target_bytes: 130e3,
            hist_bytes: 12e6,
            // E2006's ~4M-feature space is touched even more thinly
            sparse_touch_frac: 0.05,
        }
    }

    /// Calibrate from a real training report produced by this crate's
    /// trainers on this machine (EXPERIMENTS.md records the values used).
    pub fn calibrate(
        build_secs: f64,
        target_secs: f64,
        apply_secs: f64,
        n_rows: usize,
        n_features: usize,
        max_bins: usize,
        max_leaves: usize,
    ) -> PhaseTimes {
        PhaseTimes {
            build_secs: build_secs.max(1e-7),
            target_secs: target_secs.max(1e-7),
            apply_secs: apply_secs.max(1e-7),
            // tree: ~20 bytes per node, 2*leaves-1 nodes
            tree_bytes: (2 * max_leaves) as f64 * 20.0,
            // snapshot: grad+hess f32 per sampled row (upper bound: all rows)
            target_bytes: (n_rows * 8) as f64,
            // one histogram: bins * features * (g,h,c) = 20 bytes
            hist_bytes: (n_features * max_bins * 20) as f64,
            // conservative single-node default; workload presets override
            sparse_touch_frac: 0.15,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn xfer_includes_latency_and_bandwidth() {
        let net = NetworkSpec::gigabit_tcp();
        let t = net.xfer(117e6); // 1 second of payload
        assert!((t - 1.0 - 80e-6).abs() < 1e-9);
        assert!(net.xfer(0.0) > 0.0);
    }

    #[test]
    fn jitter_mean_one_and_cv() {
        let spec = ClusterSpec {
            speed_cv: 0.3,
            ..ClusterSpec::new(4)
        };
        let mut rng = Rng::new(1);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| spec.jitter(&mut rng)).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!((mean - 1.0).abs() < 0.02, "mean={mean}");
        assert!((var.sqrt() - 0.3).abs() < 0.03, "cv={}", var.sqrt());
    }

    #[test]
    fn zero_cv_is_deterministic() {
        let spec = ClusterSpec {
            speed_cv: 0.0,
            ..ClusterSpec::new(4)
        };
        let mut rng = Rng::new(2);
        assert_eq!(spec.jitter(&mut rng), 1.0);
    }

    #[test]
    fn failure_model_none_is_inactive() {
        let fm = FailureModel::none();
        assert!(!fm.is_active());
        let real = FailureModel {
            mtbf_secs: 30.0,
            restart_secs: 2.0,
            max_restarts: 3,
        };
        assert!(real.is_active());
    }

    #[test]
    fn calibrate_floors_at_epsilon() {
        let pt = PhaseTimes::calibrate(0.0, 0.0, 0.0, 100, 10, 16, 8);
        assert!(pt.build_secs > 0.0);
        assert!(pt.hist_bytes > 0.0);
    }
}
