//! asgbdt — the asynch-SGBDT launcher.
//!
//! ```text
//! asgbdt train [--data <spec>] [--test-frac 0.2] [--model out.sgbdt]
//!              [--resume ck.sgbdt] [k=v ...]
//! asgbdt serve --model model.sgbdt [--data <spec>] [--requests N] [--swap-at N]
//! asgbdt experiment <fig4..fig10|ablation|all> [--scale smoke|paper] [--out results]
//! asgbdt simulate [--workload realsim|e2006] [--workers 1,2,...] [--trees N]
//! asgbdt datagen <realsim|higgs|e2006> <n_rows> <out.svm> [--seed N]
//! asgbdt inspect-artifacts [--dir artifacts]
//! asgbdt help
//! ```
//!
//! `--data` spec: `synthetic:realsim:20000`, `synthetic:higgs:60000`,
//! `synthetic:e2006:8000`, or a path to an svmlight file.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::sync::Arc;

use anyhow::{bail, Context, Result};

use asgbdt::cli::Args;
use asgbdt::config::{ModelFormat, TrainConfig, TrainMode};
use asgbdt::coordinator;
use asgbdt::data::{synthetic, BinCuts, BinnedDataset, Dataset};
use asgbdt::experiments::{self, Scale};
use asgbdt::forest::FlatForest;
use asgbdt::io::artifact::{self, ArtifactMeta};
use asgbdt::io::svmlight;
use asgbdt::runtime::Manifest;
use asgbdt::serve::{drive_replay, require_scalar_loss, ModelSlot, ServeOptions, Service};
use asgbdt::simulator::{speedup_sweep, PhaseTimes};
use asgbdt::util::{Rng, Summary};

fn main() {
    let raw: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = run(&raw) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn run(raw: &[String]) -> Result<()> {
    let args = Args::parse(raw)?;
    match args.command.as_str() {
        "train" => cmd_train(&args),
        "predict" => cmd_predict(&args),
        "serve" => cmd_serve(&args),
        "experiment" => cmd_experiment(&args),
        "simulate" => cmd_simulate(&args),
        "datagen" => cmd_datagen(&args),
        "inspect-artifacts" => cmd_inspect(&args),
        "help" | "" => {
            print!("{}", HELP);
            println!("SUBCOMMANDS:");
            for (name, desc) in asgbdt::cli::SUBCOMMANDS {
                println!("  {name:<18} {desc}");
            }
            Ok(())
        }
        other => bail!("unknown command '{other}' (see `asgbdt help`)"),
    }
}

const HELP: &str = r#"asgbdt — asynchronous parallel stochastic GBDT on a parameter server

USAGE:
  asgbdt train [--data <spec>] [--test-frac F] [--config cfg.json]
               [--model out.sgbdt] [--curve out.csv] [--resume ck.sgbdt]
               [key=value ...]
  asgbdt predict --model model.sgbdt --data <spec> [--out preds.csv]
  asgbdt serve --model model.sgbdt [--data <spec>] [--requests N] [--inflight N]
               [--swap-at N] [--swap-model other.sgbdt] [key=value ...]
  asgbdt experiment <fig4..fig10|ablation|all> [--scale smoke|paper] [--out DIR]
  asgbdt simulate [--workload realsim|e2006] [--workers 1,2,4,...] [--trees N]
  asgbdt datagen <realsim|higgs|e2006> <n_rows> <out.svm> [--seed N]
  asgbdt inspect-artifacts [--dir artifacts]

DATA SPECS:
  synthetic:realsim:<rows> | synthetic:higgs:<rows> | synthetic:e2006:<rows>
  synthetic:regression:<rows> | synthetic:multiclass:<classes>:<rows>
  <path to svmlight file>

CONFIG OVERRIDES (key=value):
  mode=async|sync|serial   workers=N        n_trees=N      step_length=V
  sampling_rate=R          max_leaves=N     feature_rate=R max_bins=N
  grad_mode=gradient|newton max_staleness=N|none  seed=N   eval_every=N
  loss=logistic|squared|huber|multiclass
                               (training objective: binary logloss, squared
                                error, Huber-robust regression, or K-class
                                softmax — K trees per boosting round sharing
                                one sampled structure pass; logistic is
                                default)
  huber_delta=D                (Huber transition point between the quadratic
                                and linear regimes; only legal with
                                loss=huber; 1.0 is default)
  n_classes=K                  (class count for loss=multiclass, K >= 3;
                                labels must be integer ids in [0, K))
  step=fixed|adaptive          (push step scale: fixed uses step_length for
                                every accepted tree; adaptive shrinks it to
                                step_length/(1+tau) per accepted push as a
                                pure function of the recorded staleness tau
                                — deterministic, replays bit for bit; fixed
                                is default, adaptive needs mode=async|sync)
  histogram=subtract|rebuild   (sibling-subtraction child histograms vs
                                whole-node rebuild; subtract is default)
  target=fused|serial          (server accept pipeline: one fused row-sharded
                                pass vs separate sweeps; fused is default,
                                bit-identical outputs)
  scoring=flat|perrow          (serial-path F-update engine; perrow requires
                                target=serial)   score_threads=N
  build_threads=N              (threads per tree build: sharded leaf histograms
                                + work-stealing split search; 1 is default and
                                exactly the serial learner)
  pool=persistent|scoped       (where score_threads AND build_threads come
                                from: lifetime-scoped parked worker pools vs
                                per-section scoped spawns; persistent is
                                default, bit-identical outputs)
  ps_shards=N                  (server shards the PS state is row-partitioned
                                across; shards exchange sparse histograms and
                                publish composed versions; 1 is default,
                                bit-identical outputs at every N)
  fault_seed=N|none            (arm the deterministic fault-injection layer:
                                every drop/duplicate/delay/panic is a pure
                                function of (seed, site, attempt), so chaos
                                runs replay exactly; none is default — no
                                fault-layer code runs)
  fault_drop_rate=R fault_dup_rate=R fault_delay_rate=R fault_panic_rate=R
                               (per-attempt fault probabilities under an armed
                                plan; the three message rates must sum to <= 1)
  worker_restarts=N            (restarts the supervisor grants each panicked
                                async worker, with a fresh derived identity per
                                incarnation; 0 is default — panicked workers
                                retire and training degrades gracefully)
  serve_batch=N                (serving micro-batch size: requests coalesced
                                per scoring call; 64 is default)
  serve_max_wait_us=N          (how long a non-full micro-batch waits for late
                                arrivals before scoring anyway; 200 is default,
                                0 legal only with serve_batch=1)
  serve_threads=N              (scoring executor width of the service's
                                server-lifetime pool; 1 is default)
  serve_model=PATH|none        (forest to serve, as saved by train --model;
                                required under mode=serve — `asgbdt serve
                                --model PATH` sets it; .sgbdt artifacts and
                                JSON forests are both accepted, sniffed by
                                magic bytes rather than extension)
  format=sgbdt|json            (what train --model writes: the versioned
                                .sgbdt artifact — manifest + checksums +
                                flat payload, DESIGN.md §16 — or the legacy
                                JSON forest; sgbdt is default, json stays
                                for one release)
  checkpoint_every=N           (write a resumable checkpoint artifact every
                                N accepted trees; 0 is default — no
                                artifact code runs during training)
  checkpoint_path=PATH|none    (where checkpoints land: PATH holds the
                                latest, PATH with a .tK tag is kept per
                                cadence point; required when
                                checkpoint_every > 0)
"#;

/// Load a model for scoring, whichever format it is on disk: a `.sgbdt`
/// artifact (sniffed by magic, not extension) yields the flat forest
/// plus its own training-time bin cuts and manifest loss name; a JSON
/// forest is flattened here and served with the dataset-derived
/// `fallback` cuts (legacy JSON predates the loss stanza and is always
/// "logistic").
fn load_model(path: &Path, fallback: Option<&BinCuts>) -> Result<(FlatForest, BinCuts, String)> {
    if artifact::sniff(path)? {
        let a = artifact::load(path)?;
        Ok((a.forest, a.cuts, a.loss))
    } else {
        let forest = asgbdt::forest::Forest::load(path)?;
        let cuts = fallback
            .context("JSON models carry no bin cuts — a --data spec is required")?
            .clone();
        Ok((FlatForest::from_forest(&forest), cuts, "logistic".to_string()))
    }
}

fn load_data(spec: &str, seed: u64) -> Result<Dataset> {
    if let Some(rest) = spec.strip_prefix("synthetic:") {
        let (kind, rows) = rest
            .split_once(':')
            .context("synthetic spec must be synthetic:<kind>:<rows>")?;
        if kind == "multiclass" {
            let (k, n) = rows
                .split_once(':')
                .context("multiclass spec must be synthetic:multiclass:<classes>:<rows>")?;
            let k: usize = k.parse().context("bad class count")?;
            let n: usize = n.parse().context("bad row count")?;
            return Ok(synthetic::multiclass_like(n, k, seed));
        }
        let n: usize = rows.parse().context("bad row count")?;
        Ok(match kind {
            "realsim" => synthetic::realsim_like(n, seed),
            "higgs" => synthetic::higgs_like(n, seed),
            "e2006" => synthetic::e2006_like(n, seed),
            "regression" => synthetic::regression_like(n, seed),
            other => bail!("unknown synthetic kind '{other}'"),
        })
    } else {
        svmlight::read_file(Path::new(spec))
    }
}

fn cmd_train(args: &Args) -> Result<()> {
    let mut cfg = match args.opt("config") {
        Some(path) => TrainConfig::load(Path::new(path))?,
        None => TrainConfig::default(),
    };
    for (k, v) in &args.overrides {
        cfg.set(k, v)?;
    }
    if cfg.mode == TrainMode::Serve {
        // `--mode serve` through the train surface: same entrypoint, no
        // trainer — hand the full arg set to the serving command
        return cmd_serve(args);
    }
    cfg.validate()?;

    let data_spec = args.opt_or("data", "synthetic:realsim:8000");
    let ds = load_data(data_spec, cfg.seed)?;
    let test_frac: f64 = args.opt_or("test-frac", "0.2").parse()?;
    let (train_ds, test_ds) = if test_frac > 0.0 {
        let mut rng = Rng::new(cfg.seed);
        let (tr, te) = ds.split(test_frac, &mut rng);
        (tr, Some(te))
    } else {
        (ds, None)
    };

    println!(
        "training mode={} loss={} step={} workers={} trees={} v={} rate={} leaves={} on {} ({} rows x {} features)",
        cfg.mode.as_str(),
        cfg.loss.as_str(),
        cfg.step.as_str(),
        cfg.workers,
        cfg.n_trees,
        cfg.step_length,
        cfg.sampling_rate,
        cfg.tree.max_leaves,
        train_ds.name,
        train_ds.n_rows(),
        train_ds.n_features()
    );
    let resume = match args.opt("resume") {
        Some(path) => {
            let a = artifact::load(Path::new(path))?;
            println!("resuming from {path}: {} checkpointed trees", a.forest.n_trees());
            Some(a)
        }
        None => None,
    };
    let report = coordinator::train_resumed(&cfg, &train_ds, test_ds.as_ref(), resume.as_ref())?;
    println!(
        "done: {} trees in {:.2}s ({:.2} trees/s, engine {}) staleness mean {:.2} max {}",
        report.trees_accepted,
        report.wall_secs,
        report.trees_per_sec(),
        report.engine,
        report.staleness.mean(),
        report.staleness.max()
    );
    if let Some(p) = report.curve.points.last() {
        println!(
            "final: train_loss {:.5} test_loss {:.5} test_err {:.4}",
            p.train_loss, p.test_loss, p.test_error
        );
    }
    println!("-- phases --\n{}", report.timer.report());
    if let Some(path) = args.opt("model") {
        match cfg.model_format {
            ModelFormat::Sgbdt => {
                let meta = ArtifactMeta {
                    config_fingerprint: cfg.fingerprint(),
                    seed: cfg.seed,
                    loss: cfg.loss.as_str().to_string(),
                    train_secs: report.wall_secs,
                    trainer: None,
                };
                let flat = FlatForest::from_forest(&report.forest);
                artifact::save(Path::new(path), &flat, &report.cuts, &meta)?;
                println!("model -> {path} (sgbdt artifact)");
            }
            ModelFormat::Json => {
                report.forest.save(Path::new(path))?;
                println!("model -> {path} (json)");
            }
        }
    }
    if let Some(path) = args.opt("curve") {
        report
            .curve
            .write_csv(Path::new(path), &format!("{}x{}", cfg.mode.as_str(), cfg.workers))?;
        println!("curve -> {path}");
    }
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<()> {
    let mut cfg = match args.opt("config") {
        Some(path) => TrainConfig::load(Path::new(path))?,
        None => TrainConfig::default(),
    };
    for (k, v) in &args.overrides {
        cfg.set(k, v)?;
    }
    cfg.set("mode", "serve")?;
    if let Some(path) = args.opt("model") {
        cfg.serve_model = Some(PathBuf::from(path));
    }
    cfg.validate()?;
    let model_path = cfg.serve_model.clone().expect("validate requires serve_model");

    // the replayed stream: rows of --data become raw requests; its
    // quantile cuts bin those requests for JSON models, while a .sgbdt
    // artifact overrides them with the cuts it was trained under
    let spec = args.opt_or("data", "synthetic:realsim:8000");
    let ds = load_data(spec, cfg.seed)?;
    let data_cuts = BinnedDataset::from_dataset(&ds, cfg.max_bins)?.cuts();
    let (flat, cuts, loss) = load_model(&model_path, Some(&data_cuts))?;
    require_scalar_loss(&loss, "serve")?;
    let n_requests: usize = args.opt_or("requests", "2000").parse()?;
    let inflight_default = (cfg.serve_batch * 2).to_string();
    let inflight: usize = args.opt_or("inflight", &inflight_default).parse()?;
    let swap_at: Option<usize> = match args.opt("swap-at") {
        Some(s) => Some(s.parse()?),
        None => None,
    };
    // --swap-model rolls out a different forest mid-stream; without it a
    // swap republishes the same forest (a rollout of an identical model
    // — the version tag still advances)
    let (swap_flat, swap_cuts) = match args.opt("swap-model") {
        Some(path) => {
            let (sf, sc, swap_loss) = load_model(Path::new(path), Some(&data_cuts))?;
            require_scalar_loss(&swap_loss, "serve --swap-model")?;
            if swap_loss != loss {
                bail!(
                    "serve: --swap-model was trained with loss={swap_loss} but the live \
                     model serves loss={loss} — a hot swap must not change what the \
                     margins mean"
                );
            }
            (sf, sc)
        }
        None => (flat.clone(), cuts.clone()),
    };

    println!(
        "serving {} trees (base {:.4}, loss {loss}) on {}: batch={} wait={}us threads={} requests={}",
        flat.n_trees(),
        flat.base_score,
        ds.name,
        cfg.serve_batch,
        cfg.serve_max_wait_us,
        cfg.serve_threads,
        n_requests,
    );
    let slot = Arc::new(ModelSlot::new(flat, cuts));
    let service = Service::start(Arc::clone(&slot), ServeOptions::from_config(&cfg));
    let swap = swap_at.map(|at| (at, swap_flat, swap_cuts));
    let outcome = drive_replay(&service, &ds.x, n_requests, inflight, swap)?;
    let stats = service.shutdown();

    let lat = Summary::of(&outcome.latency_secs);
    let rps = n_requests as f64 / outcome.wall_secs.max(1e-12);
    let mut per_version: BTreeMap<u64, usize> = BTreeMap::new();
    for &v in &outcome.version_of {
        *per_version.entry(v).or_insert(0) += 1;
    }
    println!(
        "latency p50 {:.1}us p99 {:.1}us mean {:.1}us | {:.0} req/s over {:.2}s",
        lat.p50 * 1e6,
        lat.p99 * 1e6,
        lat.mean * 1e6,
        rps,
        outcome.wall_secs,
    );
    println!(
        "{} micro-batches (max {} rows), {} swap(s) observed; responses per version: {:?}",
        stats.batches, stats.max_batch, stats.swaps_seen, per_version,
    );
    Ok(())
}

fn cmd_predict(args: &Args) -> Result<()> {
    let model_path = args.opt("model").context("--model required")?;
    let spec = args.opt("data").context("--data required")?;
    let ds = load_data(spec, 0)?;
    // prediction walks raw thresholds, so no bin cuts are needed — either
    // format yields a flat forest directly
    let (flat, loss) = if artifact::sniff(Path::new(model_path))? {
        let a = artifact::load(Path::new(model_path))?;
        (a.forest, a.loss)
    } else {
        (
            FlatForest::from_forest(&asgbdt::forest::Forest::load(Path::new(model_path))?),
            "logistic".to_string(),
        )
    };
    let kind = require_scalar_loss(&loss, "predict")?;
    let mut pool = asgbdt::forest::ScratchPool::new();
    let exec = asgbdt::util::Executor::scoped(1);
    let margins = flat.predict_all_raw(&ds.x, &exec, &mut pool);
    let w = vec![1.0f32; ds.n_rows()];
    println!(
        "model: {} trees (base {:.4}, loss {loss}); data: {} rows",
        flat.n_trees(),
        flat.base_score,
        ds.n_rows()
    );
    let classification = kind == asgbdt::loss::LossKind::Logistic;
    if classification {
        println!(
            "logloss {:.5}  error {:.4}  auc {:.4}",
            asgbdt::loss::metrics::logloss(&margins, &ds.y, &w),
            asgbdt::loss::metrics::error_rate(&margins, &ds.y, &w),
            asgbdt::loss::metrics::auc(&margins, &ds.y, &w),
        );
    } else {
        // squared/huber models predict the label directly: report the
        // regression residual metrics instead of threshold statistics
        println!(
            "rmse {:.5}  mae {:.5}",
            asgbdt::loss::metrics::rmse(&margins, &ds.y, &w),
            asgbdt::loss::metrics::mae(&margins, &ds.y, &w),
        );
    }
    if let Some(out) = args.opt("out") {
        let mut csv = if classification {
            asgbdt::io::csv::CsvWriter::new(&["row", "margin", "p", "label"])
        } else {
            asgbdt::io::csv::CsvWriter::new(&["row", "pred", "residual", "label"])
        };
        for (r, &m) in margins.iter().enumerate() {
            let third = if classification {
                format!("{:.6}", asgbdt::loss::logistic::prob(m))
            } else {
                format!("{:.6}", m - ds.y[r])
            };
            csv.row(&[r.to_string(), format!("{m:.6}"), third, format!("{}", ds.y[r])]);
        }
        csv.write(Path::new(out))?;
        println!("predictions -> {out}");
    }
    Ok(())
}

fn cmd_experiment(args: &Args) -> Result<()> {
    let id = args.positional(0).context("experiment id required")?;
    let scale = match args.opt("scale") {
        Some(s) => Scale::parse(s)?,
        None => Scale::from_env(),
    };
    let out_dir = PathBuf::from(args.opt_or("out", "results"));
    let ids: Vec<&str> = if id == "all" {
        experiments::all_ids().to_vec()
    } else {
        vec![id]
    };
    for id in ids {
        println!("== experiment {id} (scale {scale:?}) ==");
        let summary = experiments::run(id, scale, &out_dir)?;
        println!("{summary}");
    }
    Ok(())
}

fn cmd_simulate(args: &Args) -> Result<()> {
    let workload = args.opt_or("workload", "realsim");
    let times = match workload {
        "realsim" => PhaseTimes::realsim_like(),
        "e2006" => PhaseTimes::e2006_like(),
        other => bail!("unknown workload '{other}'"),
    };
    let workers: Vec<usize> = args
        .opt_or("workers", "1,2,4,8,16,32")
        .split(',')
        .map(|s| s.trim().parse().context("bad worker count"))
        .collect::<Result<_>>()?;
    let trees: usize = args.opt_or("trees", "200").parse()?;
    println!(
        "simulating {workload}: build={:.3}s target={:.3}s",
        times.build_secs, times.target_secs
    );
    println!(
        "{:<14} {:>8} {:>10} {:>9} {:>10}",
        "system", "workers", "wall_s", "speedup", "staleness"
    );
    for row in speedup_sweep(&times, &workers, trees, 0.15, 42) {
        println!(
            "{:<14} {:>8} {:>10.2} {:>9.2} {:>10.2}",
            row.system.as_str(),
            row.workers,
            row.wall_secs,
            row.speedup,
            row.mean_staleness
        );
    }
    Ok(())
}

fn cmd_datagen(args: &Args) -> Result<()> {
    let kind = args.positional(0).context("kind required")?;
    let n: usize = args.positional(1).context("n_rows required")?.parse()?;
    let out = args.positional(2).context("output path required")?;
    let seed: u64 = args.opt_or("seed", "42").parse()?;
    let ds = match kind {
        "realsim" => synthetic::realsim_like(n, seed),
        "higgs" => synthetic::higgs_like(n, seed),
        "e2006" => synthetic::e2006_like(n, seed),
        other => bail!("unknown kind '{other}'"),
    };
    svmlight::write_file(&ds, Path::new(out))?;
    println!(
        "wrote {} ({} rows x {} features, density {:.4}%, {} species)",
        out,
        ds.n_rows(),
        ds.n_features(),
        ds.x.density() * 100.0,
        ds.n_species()
    );
    Ok(())
}

fn cmd_inspect(args: &Args) -> Result<()> {
    let dir = PathBuf::from(args.opt_or("dir", "artifacts"));
    if !Manifest::exists(&dir) {
        println!("no manifest under {} — run `make artifacts`", dir.display());
        return Ok(());
    }
    let m = Manifest::load(&dir)?;
    println!("artifact dir: {} (block {})", dir.display(), m.block);
    println!("buckets: {:?}", m.buckets);
    for e in &m.entries {
        let size = std::fs::metadata(dir.join(&e.file))
            .map(|md| md.len())
            .unwrap_or(0);
        println!("  {:<12} n={:<8} {} ({} bytes)", e.name, e.n, e.file, size);
    }
    Ok(())
}
