//! Minimal CLI argument parsing (clap is not in the offline vendor set —
//! DESIGN.md §7).
//!
//! Grammar: `asgbdt <command> [positional ...] [--flag] [--opt value]
//! [key=value ...]`. `key=value` tokens are collected as config overrides.

use anyhow::{bail, Result};

/// Subcommand index. `asgbdt help` renders from this list; keep it in
/// step with the dispatch match in `main.rs` and the README's CLI table
/// when adding a subcommand.
pub const SUBCOMMANDS: &[(&str, &str)] = &[
    ("train", "train a model (mode=async|sync|serial) on a data spec"),
    ("predict", "score a saved model on a data spec"),
    (
        "serve",
        "batched low-latency prediction service with model hot-swap (mode=serve)",
    ),
    (
        "experiment",
        "reproduce a paper figure (fig4..fig10, ablation, all)",
    ),
    ("simulate", "discrete-event cluster speedup sweep (Fig. 10)"),
    ("datagen", "write a synthetic dataset as an svmlight file"),
    ("inspect-artifacts", "list the AOT gradient HLO artifacts"),
    ("help", "print usage"),
];

/// Parsed command line.
#[derive(Debug, Clone, Default)]
pub struct Args {
    /// The subcommand (first token).
    pub command: String,
    /// Bare tokens in order (data specs, figure names...).
    pub positionals: Vec<String>,
    /// `--flag` tokens with no value.
    pub flags: Vec<String>,
    /// `--opt value` pairs in order.
    pub options: Vec<(String, String)>,
    /// `key=value` config overrides, applied in order.
    pub overrides: Vec<(String, String)>,
}

impl Args {
    /// Parse from raw args (excluding argv[0]).
    pub fn parse(raw: &[String]) -> Result<Args> {
        let mut args = Args::default();
        let mut it = raw.iter().peekable();
        if let Some(cmd) = it.next() {
            args.command = cmd.clone();
        }
        while let Some(tok) = it.next() {
            if let Some(name) = tok.strip_prefix("--") {
                // flag or option: option iff next token exists and is not
                // another --flag / key=value
                match it.peek() {
                    Some(next) if !next.starts_with("--") && !next.contains('=') => {
                        args.options.push((name.to_string(), it.next().unwrap().clone()));
                    }
                    _ => args.flags.push(name.to_string()),
                }
            } else if let Some((k, v)) = tok.split_once('=') {
                if k.is_empty() {
                    bail!("empty key in override '{tok}'");
                }
                args.overrides.push((k.to_string(), v.to_string()));
            } else {
                args.positionals.push(tok.clone());
            }
        }
        Ok(args)
    }

    /// Last value of `--name value` (last occurrence wins).
    pub fn opt(&self, name: &str) -> Option<&str> {
        self.options
            .iter()
            .rev()
            .find(|(k, _)| k == name)
            .map(|(_, v)| v.as_str())
    }

    /// [`Args::opt`] with a default.
    pub fn opt_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.opt(name).unwrap_or(default)
    }

    /// Whether `--name` was passed as a bare flag.
    pub fn has_flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    /// The i-th bare token after the subcommand.
    pub fn positional(&self, i: usize) -> Option<&str> {
        self.positionals.get(i).map(|s| s.as_str())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(toks: &[&str]) -> Args {
        Args::parse(&toks.iter().map(|s| s.to_string()).collect::<Vec<_>>()).unwrap()
    }

    #[test]
    fn parses_command_positionals_flags_options_overrides() {
        let a = parse(&[
            "train", "data.svm", "--scale", "paper", "--verbose", "workers=8",
            "sampling_rate=0.5",
        ]);
        assert_eq!(a.command, "train");
        assert_eq!(a.positional(0), Some("data.svm"));
        assert_eq!(a.opt("scale"), Some("paper"));
        assert!(a.has_flag("verbose"));
        assert_eq!(a.overrides.len(), 2);
        assert_eq!(a.overrides[0], ("workers".into(), "8".into()));
    }

    #[test]
    fn flag_followed_by_flag() {
        let a = parse(&["x", "--a", "--b", "v"]);
        assert!(a.has_flag("a"));
        assert_eq!(a.opt("b"), Some("v"));
    }

    #[test]
    fn option_not_confused_by_override() {
        let a = parse(&["x", "--out", "dir", "k=v"]);
        assert_eq!(a.opt("out"), Some("dir"));
        assert_eq!(a.overrides[0].0, "k");
    }

    #[test]
    fn last_option_wins() {
        let a = parse(&["x", "--s", "1", "--s", "2"]);
        assert_eq!(a.opt("s"), Some("2"));
    }

    #[test]
    fn rejects_empty_override_key() {
        let toks: Vec<String> = vec!["x".into(), "=v".into()];
        assert!(Args::parse(&toks).is_err());
    }
}
