//! `FlatTree` — the scoring-side SoA twin of [`super::tree::Tree`].
//!
//! The builder's `Vec<Node>` enum is the right shape for *growing* a tree
//! but a poor one for *scoring* it: every per-row root-to-leaf walk
//! pointer-chases enum variants laid out in construction order, and the
//! server's F-update (Algorithm 3, step 2) pays that cost for every row
//! of every accepted tree. `FlatTree` compiles a shipped tree once into
//! parallel arrays (`feature[]`, `bin[]`, `threshold[]`, `left[]`,
//! `leaf_value[]`) in breadth-first order — siblings are adjacent, so the
//! right child is always `left + 1` and a node's whole decision fits in
//! three tiny array reads.
//!
//! Scoring then runs as a **frontier/partition pass** over a block of row
//! ids ([`FlatTree::partition_binned`] / [`FlatTree::partition_raw`]) —
//! the same in-place two-pointer row partitioning the builder uses to
//! split leaves ([`super::builder`]), just replayed at inference time:
//! all rows of a block enter at the root, each visited node partitions
//! its segment once, and every row ends in exactly one leaf segment.
//! Per node the split feature, bin and threshold stay in registers while
//! a contiguous run of rows is tested, and the block's CSR data stays
//! cache-resident across all `depth` passes — the blocked access pattern
//! that per-row traversal destroys. The block drivers live in
//! [`crate::forest::score`].
//!
//! Everything here is iterative (explicit queues/stacks, no recursion),
//! so adversarially deep trees — e.g. loaded through `io/json.rs` —
//! cannot overflow the call stack.

use crate::data::sparse::CsrMatrix;
use crate::data::BinnedDataset;

use super::tree::{Node, Tree};

/// A decision tree flattened to structure-of-arrays form, breadth-first:
/// node 0 is the root, a split's children are adjacent (`right == left +
/// 1`), and `left[i] == 0` marks a leaf (the root is never a child, so 0
/// is free as a sentinel). All five arrays have one slot per node; the
/// slots a node kind does not use (`leaf_value` of a split, the split
/// fields of a leaf) are zeroed.
#[derive(Debug, Clone, PartialEq)]
pub struct FlatTree {
    /// Split feature per node.
    pub feature: Vec<u32>,
    /// Bin-space split (valid against the training `BinnedDataset`).
    pub bin: Vec<u8>,
    /// Raw-space threshold (valid for any raw feature vector).
    pub threshold: Vec<f32>,
    /// Left-child index; `0` marks a leaf. Right child is `left + 1`.
    pub left: Vec<u32>,
    /// Prediction per leaf node (0 for splits).
    pub leaf_value: Vec<f32>,
}

impl FlatTree {
    /// Compile a (validated) tree into breadth-first SoA form. O(nodes),
    /// iterative. Panics on a malformed tree whose reachable set exceeds
    /// its node count (cycle/DAG) — `Tree::validate` rejects those first
    /// on every untrusted path.
    pub fn from_tree(t: &Tree) -> FlatTree {
        assert!(!t.nodes.is_empty(), "cannot flatten an empty tree");
        let n = t.nodes.len();
        // old node indices in BFS order; position in `order` = new index
        let mut order: Vec<u32> = Vec::with_capacity(n);
        order.push(0);
        let mut flat = FlatTree {
            feature: Vec::with_capacity(n),
            bin: Vec::with_capacity(n),
            threshold: Vec::with_capacity(n),
            left: Vec::with_capacity(n),
            leaf_value: Vec::with_capacity(n),
        };
        let mut head = 0usize;
        while head < order.len() {
            match &t.nodes[order[head] as usize] {
                Node::Leaf { value } => {
                    flat.feature.push(0);
                    flat.bin.push(0);
                    flat.threshold.push(0.0);
                    flat.left.push(0);
                    flat.leaf_value.push(*value);
                }
                Node::Split {
                    feature,
                    bin,
                    threshold,
                    left,
                    right,
                } => {
                    assert!(
                        order.len() + 2 <= n,
                        "malformed tree: more reachable nodes than slots"
                    );
                    let new_left = order.len() as u32;
                    order.push(*left);
                    order.push(*right);
                    flat.feature.push(*feature);
                    flat.bin.push(*bin);
                    flat.threshold.push(*threshold);
                    flat.left.push(new_left);
                    flat.leaf_value.push(0.0);
                }
            }
            head += 1;
        }
        flat
    }

    /// Number of nodes (root included).
    pub fn n_nodes(&self) -> usize {
        self.left.len()
    }

    /// Decompile back to the enum form — the inverse of
    /// [`FlatTree::from_tree`]. The emitted `Tree` keeps this tree's
    /// breadth-first layout (node i stays node i, right child `left +
    /// 1`), so `from_tree(&f.to_tree()) == f` exactly; round-tripping is
    /// lossless. This is what lets a `.sgbdt` artifact — whose payload
    /// *is* these SoA arrays — feed `ServerCore` replay on resume, which
    /// speaks `Tree`.
    pub fn to_tree(&self) -> Tree {
        let nodes = (0..self.n_nodes())
            .map(|i| {
                if self.left[i] == 0 {
                    Node::Leaf {
                        value: self.leaf_value[i],
                    }
                } else {
                    Node::Split {
                        feature: self.feature[i],
                        bin: self.bin[i],
                        threshold: self.threshold[i],
                        left: self.left[i],
                        right: self.left[i] + 1,
                    }
                }
            })
            .collect();
        Tree { nodes }
    }

    /// Whether `node` is a leaf (left-child sentinel 0).
    #[inline]
    pub fn is_leaf(&self, node: usize) -> bool {
        self.left[node] == 0
    }

    /// Per-row bin-space walk over the SoA arrays (same answer as
    /// [`Tree::predict_binned`]; the block path is [`Self::partition_binned`]).
    #[inline]
    pub fn predict_binned(&self, binned: &BinnedDataset, row: usize) -> f32 {
        let mut i = 0usize;
        while self.left[i] != 0 {
            let l = self.left[i] as usize;
            let b = binned.bin_of(row, self.feature[i]);
            i = if b <= self.bin[i] { l } else { l + 1 };
        }
        self.leaf_value[i]
    }

    /// Per-row raw-space walk (same answer as [`Tree::predict_raw`]).
    #[inline]
    pub fn predict_raw(&self, x: &CsrMatrix, row: usize) -> f32 {
        let mut i = 0usize;
        while self.left[i] != 0 {
            let l = self.left[i] as usize;
            let v = x.get(row, self.feature[i]);
            i = if v <= self.threshold[i] { l } else { l + 1 };
        }
        self.leaf_value[i]
    }

    /// The frontier/partition pass, bin-space: route every row id in
    /// `rows` to its leaf in one blocked sweep, calling
    /// `emit(leaf_node, rows_at_leaf)` once per non-empty leaf segment.
    /// `rows` is permuted in place (row order within a segment is
    /// irrelevant to scoring, exactly as in the builder's partition).
    /// `stack` is caller-owned scratch, cleared on entry, so pooled
    /// callers allocate nothing in steady state.
    #[inline]
    pub fn partition_binned(
        &self,
        binned: &BinnedDataset,
        rows: &mut [u32],
        stack: &mut Vec<(u32, usize, usize)>,
        emit: impl FnMut(u32, &[u32]),
    ) {
        self.partition_by(
            rows,
            stack,
            |node, row| binned.bin_of(row as usize, self.feature[node]) <= self.bin[node],
            emit,
        );
    }

    /// The frontier/partition pass, raw-space (threshold traversal over a
    /// CSR matrix — held-out data never binned with the training mapper).
    #[inline]
    pub fn partition_raw(
        &self,
        x: &CsrMatrix,
        rows: &mut [u32],
        stack: &mut Vec<(u32, usize, usize)>,
        emit: impl FnMut(u32, &[u32]),
    ) {
        self.partition_by(
            rows,
            stack,
            |node, row| x.get(row as usize, self.feature[node]) <= self.threshold[node],
            emit,
        );
    }

    /// Shared partition engine: an explicit work stack of
    /// `(node, begin, end)` segments (no recursion — deep trees cannot
    /// overflow), each split node two-pointer-partitioning its segment
    /// the way [`super::builder`] partitions leaf rows.
    fn partition_by(
        &self,
        rows: &mut [u32],
        stack: &mut Vec<(u32, usize, usize)>,
        goes_left: impl Fn(usize, u32) -> bool,
        mut emit: impl FnMut(u32, &[u32]),
    ) {
        if rows.is_empty() {
            return;
        }
        stack.clear();
        stack.push((0, 0, rows.len()));
        while let Some((node, begin, end)) = stack.pop() {
            let l = self.left[node as usize];
            if l == 0 {
                emit(node, &rows[begin..end]);
                continue;
            }
            let seg = &mut rows[begin..end];
            let mut i = 0usize;
            let mut j = seg.len();
            while i < j {
                if goes_left(node as usize, seg[i]) {
                    i += 1;
                } else {
                    j -= 1;
                    seg.swap(i, j);
                }
            }
            let mid = begin + i;
            // empty sides are skipped entirely — a block never visits
            // subtrees none of its rows reach
            if mid < end {
                stack.push((l + 1, mid, end));
            }
            if begin < mid {
                stack.push((l, begin, mid));
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::Dataset;

    fn stump() -> Tree {
        Tree {
            nodes: vec![
                Node::Split {
                    feature: 0,
                    bin: 1,
                    threshold: 2.0,
                    left: 1,
                    right: 2,
                },
                Node::Leaf { value: -1.0 },
                Node::Leaf { value: 1.0 },
            ],
        }
    }

    /// A tree whose enum layout is deliberately NOT breadth-first, to
    /// exercise the relayout: root at 0, but children stored far apart.
    fn scrambled() -> Tree {
        Tree {
            nodes: vec![
                Node::Split { feature: 0, bin: 2, threshold: 3.0, left: 3, right: 1 },
                Node::Split { feature: 1, bin: 1, threshold: 1.5, left: 4, right: 2 },
                Node::Leaf { value: 3.0 },
                Node::Leaf { value: 1.0 },
                Node::Leaf { value: 2.0 },
            ],
        }
    }

    #[test]
    fn flatten_stump_layout() {
        let f = FlatTree::from_tree(&stump());
        assert_eq!(f.n_nodes(), 3);
        assert_eq!(f.left, vec![1, 0, 0]);
        assert!(!f.is_leaf(0) && f.is_leaf(1) && f.is_leaf(2));
        assert_eq!(f.leaf_value[1], -1.0);
        assert_eq!(f.leaf_value[2], 1.0);
    }

    #[test]
    fn flatten_relays_scrambled_trees_breadth_first() {
        let t = scrambled();
        t.validate().unwrap();
        let f = FlatTree::from_tree(&t);
        assert_eq!(f.n_nodes(), 5);
        // BFS: root, then (leaf 1.0, split), then the split's children
        assert_eq!(f.left[0], 1);
        assert!(f.is_leaf(1));
        assert_eq!(f.leaf_value[1], 1.0);
        assert_eq!(f.left[2], 3);
        assert_eq!(f.leaf_value[3], 2.0);
        assert_eq!(f.leaf_value[4], 3.0);
    }

    #[test]
    fn to_tree_inverts_from_tree_exactly() {
        for t in [stump(), scrambled(), Tree::constant(0.25)] {
            let f = FlatTree::from_tree(&t);
            let back = f.to_tree();
            // the decompiled tree is valid and predicts identically...
            back.validate().unwrap();
            let x = CsrMatrix::from_dense(3, 2, &[1.0, 1.0, 4.0, 0.0, 2.0, 2.0]).unwrap();
            for r in 0..3 {
                assert_eq!(back.predict_raw(&x, r), t.predict_raw(&x, r), "row {r}");
            }
            // ...and re-flattening reproduces the SoA arrays bit for bit
            // (to_tree preserves BFS layout, so from_tree is identity on it)
            assert_eq!(FlatTree::from_tree(&back), f);
        }
    }

    #[test]
    fn per_row_walks_match_enum_tree() {
        let t = scrambled();
        let f = FlatTree::from_tree(&t);
        let x = CsrMatrix::from_dense(
            4,
            2,
            &[1.0, 1.0, 1.0, 2.0, 4.0, 0.0, 0.0, 0.0],
        )
        .unwrap();
        let ds = Dataset::new("t", x.clone(), vec![0.0; 4]);
        let b = BinnedDataset::from_dataset(&ds, 16).unwrap();
        for r in 0..4 {
            assert_eq!(f.predict_raw(&x, r), t.predict_raw(&x, r), "raw row {r}");
            assert_eq!(
                f.predict_binned(&b, r),
                t.predict_binned(&b, r),
                "binned row {r}"
            );
        }
    }

    #[test]
    fn partition_routes_every_row_to_its_leaf() {
        let t = scrambled();
        let f = FlatTree::from_tree(&t);
        let x = CsrMatrix::from_dense(
            6,
            2,
            &[1.0, 1.0, 1.0, 2.0, 4.0, 0.0, 0.0, 0.0, 5.0, 9.0, 2.0, 2.0],
        )
        .unwrap();
        let mut rows: Vec<u32> = (0..6).collect();
        let mut stack = Vec::new();
        let mut got = vec![f32::NAN; 6];
        f.partition_raw(&x, &mut rows, &mut stack, |leaf, seg| {
            for &r in seg {
                got[r as usize] = f.leaf_value[leaf as usize];
            }
        });
        for r in 0..6 {
            assert_eq!(got[r], t.predict_raw(&x, r), "row {r}");
        }
        // the pass is a permutation: every row id appears exactly once
        let mut sorted = rows.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..6).collect::<Vec<u32>>());
    }

    #[test]
    fn partition_handles_single_leaf_and_empty_blocks() {
        let f = FlatTree::from_tree(&Tree::constant(0.7));
        let x = CsrMatrix::from_dense(2, 1, &[1.0, 0.0]).unwrap();
        let mut stack = Vec::new();
        let mut rows: Vec<u32> = vec![0, 1];
        let mut hits = 0;
        f.partition_raw(&x, &mut rows, &mut stack, |leaf, seg| {
            assert_eq!(leaf, 0);
            hits += seg.len();
        });
        assert_eq!(hits, 2);
        let mut none: Vec<u32> = Vec::new();
        f.partition_raw(&x, &mut none, &mut stack, |_, _| panic!("no rows"));
    }

    #[test]
    fn flatten_deep_chain_is_stack_safe() {
        // 50k-deep left-spine chain: iterative compile + iterative
        // partition must both survive where recursion would overflow
        let depth = 50_000usize;
        let mut nodes = Vec::with_capacity(2 * depth + 1);
        for i in 0..depth {
            nodes.push(Node::Split {
                feature: 0,
                bin: 0,
                threshold: 0.0,
                left: (2 * i + 1) as u32,
                right: (2 * i + 2) as u32,
            });
            nodes.push(Node::Leaf { value: i as f32 });
        }
        nodes.push(Node::Leaf { value: -1.0 });
        let t = Tree { nodes };
        t.validate().unwrap();
        assert_eq!(t.depth(), depth + 1);
        let f = FlatTree::from_tree(&t);
        assert_eq!(f.n_nodes(), 2 * depth + 1);
        // a row with x0 > 0 goes right at every split: reaches the final leaf
        let x = CsrMatrix::from_dense(1, 1, &[1.0]).unwrap();
        assert_eq!(f.predict_raw(&x, 0), -1.0);
        let mut rows = vec![0u32];
        let mut stack = Vec::new();
        let mut seen = f32::NAN;
        f.partition_raw(&x, &mut rows, &mut stack, |leaf, _| {
            seen = f.leaf_value[leaf as usize];
        });
        assert_eq!(seen, -1.0);
    }
}
