//! The "building the tree" sub-step substrate: a LightGBM-style
//! leaf-wise histogram regression-tree learner.
//!
//! Trees fit the stochastic target `L'_random` (Eq. 10): the tree's
//! prediction for row i approximates `-g_i / w_i` (the negative gradient),
//! with leaf values given by the Newton step `-ΣG / (ΣH + λ)`. In the
//! paper's "gradient step" mode the caller passes `h_i = w_i`, which turns
//! the same formula into the weighted least-squares mean — both modes share
//! one code path (see DESIGN.md §8).
//!
//! Sparse-aware: histograms accumulate only the nonzero (feature, bin)
//! pairs of each row; each feature's implicit-zero bin is reconstructed by
//! subtraction from the leaf totals, making histogram building O(nnz).
//!
//! Hot-path engineering (the >90%-of-worker-time path):
//!
//! * **Sibling subtraction** ([`histogram::HistogramStrategy`], default
//!   `Subtract`): after a split only the smaller child's histogram is
//!   built from rows; the larger is `parent − small`. `Rebuild` keeps the
//!   whole-node baseline for ablations.
//! * **Pooled buffers** ([`histogram::HistogramPool`]): flat
//!   `[n_features × n_bins]` arrays recycled across nodes *and* trees;
//!   workers hold one pool each and stop allocating after the first tree.
//! * **Parallel engines** ([`parallel`]): row-sharded fork-join histogram
//!   building and per-feature work-stealing split search, running on a
//!   caller-owned [`crate::util::Executor`] — under `pool=persistent`
//!   the per-leaf fork-join cycles dispatch onto parked workers instead
//!   of spawning threads (DESIGN.md §12).
//! * **Flat scoring form** ([`flat`]): shipped trees compile once into a
//!   breadth-first SoA [`FlatTree`] whose frontier/partition pass powers
//!   the server's blocked F-update (see `forest/score.rs`); the per-row
//!   enum walk on [`Tree`] stays as the reference implementation.

pub mod builder;
pub mod flat;
pub mod histogram;
pub mod parallel;
pub mod split;
pub mod tree;

pub use builder::{build_tree, build_tree_pooled, TreeParams};
pub use flat::FlatTree;
pub use histogram::{Histogram, HistogramPool, HistogramStrategy};
pub use parallel::{
    best_split_parallel, build_histogram_sharded, build_tree_feature_parallel,
    build_tree_forkjoin, build_tree_forkjoin_pooled,
};
pub use split::SplitInfo;
pub use tree::{Node, Tree};
