//! The "building the tree" sub-step substrate: a LightGBM-style
//! leaf-wise histogram regression-tree learner.
//!
//! Trees fit the stochastic target `L'_random` (Eq. 10): the tree's
//! prediction for row i approximates `-g_i / w_i` (the negative gradient),
//! with leaf values given by the Newton step `-ΣG / (ΣH + λ)`. In the
//! paper's "gradient step" mode the caller passes `h_i = w_i`, which turns
//! the same formula into the weighted least-squares mean — both modes share
//! one code path (see DESIGN.md §8).
//!
//! Sparse-aware: histograms accumulate only the nonzero (feature, bin)
//! pairs of each row; each feature's implicit-zero bin is reconstructed by
//! subtraction from the leaf totals, making histogram building O(nnz).

pub mod builder;
pub mod histogram;
pub mod parallel;
pub mod split;
pub mod tree;

pub use builder::{build_tree, TreeParams};
pub use parallel::build_tree_forkjoin;
pub use histogram::Histogram;
pub use split::SplitInfo;
pub use tree::{Node, Tree};
