//! Per-leaf gradient/hessian histograms over binned features.
//!
//! Perf-critical design (EXPERIMENTS.md §Perf, L3 item 1): the flat arrays
//! span *all* features' bins (hundreds of thousands of slots for
//! high-dimensional sparse data), but any one leaf touches only
//! O(nnz(leaf)) of them. Every operation that used to walk the full arrays
//! — `clear`, `subtract_from`, `merge`, and the split scan's feature
//! enumeration — is instead driven by the `touched` slot list recorded
//! during `build`, making per-leaf cost proportional to the leaf's
//! nonzeros instead of the global bin count (a ~10x tree-build win on
//! real-sim-shaped data).

use crate::data::BinnedDataset;

/// How child histograms are produced after a node split.
///
/// The strategy is a [`super::TreeParams`] knob threaded from the config
/// (`histogram=rebuild|subtract`) so the ablation experiment and the
/// `bench_tree_build` / `bench_histogram` targets can measure the win;
/// both strategies produce identical trees up to f64 rounding in the gain
/// computation (enforced by the equivalence property test in
/// `tests/test_tree.rs`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum HistogramStrategy {
    /// Build both children's histograms from their rows — the whole-node
    /// rebuild baseline, kept for ablations. Cost per split:
    /// O(nnz(left) + nnz(right)) = O(nnz(parent)).
    Rebuild,
    /// Build only the smaller child and derive the larger one as
    /// `parent − small` in O(|parent.touched|) — the classic
    /// sibling-subtraction trick. Cost per split:
    /// O(nnz(smaller child)) + O(|parent.touched|), at worst half of
    /// `Rebuild` and far less on unbalanced (deep leaf-wise) splits.
    #[default]
    Subtract,
}

impl HistogramStrategy {
    /// Parse the `histogram=` config/CLI value.
    pub fn parse(s: &str) -> anyhow::Result<HistogramStrategy> {
        match s {
            "rebuild" => Ok(HistogramStrategy::Rebuild),
            "subtract" => Ok(HistogramStrategy::Subtract),
            other => anyhow::bail!("unknown histogram strategy '{other}' (rebuild|subtract)"),
        }
    }

    /// The config/CLI spelling of this strategy.
    pub fn as_str(&self) -> &'static str {
        match self {
            HistogramStrategy::Rebuild => "rebuild",
            HistogramStrategy::Subtract => "subtract",
        }
    }
}

/// Aggregate statistics of a set of rows.
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct LeafStats {
    /// Sum of gradients.
    pub grad: f64,
    /// Sum of hessians.
    pub hess: f64,
    /// Number of rows.
    pub count: u64,
}

impl LeafStats {
    /// Fold one row's (g, h) in.
    #[inline]
    pub fn add(&mut self, g: f64, h: f64) {
        self.grad += g;
        self.hess += h;
        self.count += 1;
    }

    /// Component-wise difference (`self − other`).
    #[inline]
    pub fn sub(&self, other: &LeafStats) -> LeafStats {
        LeafStats {
            grad: self.grad - other.grad,
            hess: self.hess - other.hess,
            count: self.count - other.count,
        }
    }
}

/// Flat histogram over all features' bins (layout given by
/// `BinnedDataset::offsets`). Accumulators are f64: rows carry weights up
/// to 1/rate which can be large at the paper's extreme sampling rates.
///
/// Invariant: every slot NOT in `touched` is all-zero (grad, hess, count).
#[derive(Debug, Clone)]
pub struct Histogram {
    /// Gradient sum per (feature, bin) slot.
    pub grad: Vec<f64>,
    /// Hessian sum per (feature, bin) slot.
    pub hess: Vec<f64>,
    /// Row count per (feature, bin) slot.
    pub count: Vec<u32>,
    /// Slots with at least one accumulated row, unordered, no duplicates.
    pub touched: Vec<u32>,
    /// Totals over the rows that built this histogram.
    pub totals: LeafStats,
}

impl Histogram {
    /// An all-zero histogram with `total_bins` slots.
    pub fn zeros(total_bins: usize) -> Histogram {
        Histogram {
            grad: vec![0.0; total_bins],
            hess: vec![0.0; total_bins],
            count: vec![0; total_bins],
            touched: Vec::new(),
            totals: LeafStats::default(),
        }
    }

    /// Reset in place — O(|touched|), not O(total_bins).
    pub fn clear(&mut self) {
        for &slot in &self.touched {
            let s = slot as usize;
            self.grad[s] = 0.0;
            self.hess[s] = 0.0;
            self.count[s] = 0;
        }
        self.touched.clear();
        self.totals = LeafStats::default();
    }

    /// Accumulate the given rows' nonzero (feature, bin) pairs.
    ///
    /// `grad`/`hess` are indexed by *global* row id. Implicit zeros are NOT
    /// accumulated; [`Histogram::feature_zero_stats`] reconstructs them.
    pub fn build(
        &mut self,
        binned: &BinnedDataset,
        rows: &[u32],
        grad: &[f32],
        hess: &[f32],
    ) {
        self.clear();
        for &r in rows {
            let r = r as usize;
            let g = grad[r] as f64;
            let h = hess[r] as f64;
            self.totals.add(g, h);
            let lo = binned.indptr[r];
            let hi = binned.indptr[r + 1];
            for k in lo..hi {
                let slot = binned.offsets[binned.feat_ids[k] as usize]
                    + binned.bins[k] as usize;
                if self.count[slot] == 0 {
                    self.touched.push(slot as u32);
                }
                self.grad[slot] += g;
                self.hess[slot] += h;
                self.count[slot] += 1;
            }
        }
    }

    /// Accumulate another histogram into this one (the merge step of
    /// fork-join sharded histogram building — the "allreduce" of the
    /// synchronous baseline). O(|other.touched|).
    pub fn merge(&mut self, other: &Histogram) {
        debug_assert_eq!(self.grad.len(), other.grad.len());
        for &slot in &other.touched {
            let s = slot as usize;
            if self.count[s] == 0 && other.count[s] > 0 {
                self.touched.push(slot);
            }
            self.grad[s] += other.grad[s];
            self.hess[s] += other.hess[s];
            self.count[s] += other.count[s];
        }
        self.totals.grad += other.totals.grad;
        self.totals.hess += other.totals.hess;
        self.totals.count += other.totals.count;
    }

    /// `self = parent - sibling` (the classic histogram-subtraction trick:
    /// build the smaller child, derive the larger in O(|parent.touched|)).
    ///
    /// Slots whose row counts cancel exactly are left untouched (zero),
    /// which also removes the f64 cancellation residue a full subtraction
    /// would leave behind.
    pub fn subtract_from(&mut self, parent: &Histogram, sibling: &Histogram) {
        debug_assert_eq!(parent.grad.len(), sibling.grad.len());
        debug_assert_eq!(self.grad.len(), parent.grad.len());
        self.clear();
        for &slot in &parent.touched {
            let s = slot as usize;
            let c = parent.count[s] - sibling.count[s];
            if c == 0 {
                continue; // all of this slot's rows went to the sibling
            }
            self.grad[s] = parent.grad[s] - sibling.grad[s];
            self.hess[s] = parent.hess[s] - sibling.hess[s];
            self.count[s] = c;
            self.touched.push(slot);
        }
        self.totals = parent.totals.sub(&sibling.totals);
    }

    /// Distinct features with at least one touched slot, ascending — the
    /// only features a split scan needs to visit (a feature absent here
    /// has all leaf rows in its zero bin: unsplittable).
    pub fn touched_features(&self, binned: &BinnedDataset) -> Vec<u32> {
        let mut feats: Vec<u32> = self
            .touched
            .iter()
            .map(|&slot| {
                // offsets is ascending; find f with offsets[f] <= slot < offsets[f+1]
                (binned.offsets.partition_point(|&o| o <= slot as usize) - 1) as u32
            })
            .collect();
        feats.sort_unstable();
        feats.dedup();
        feats
    }

    /// Stats of a feature's *explicit* (nonzero) bins summed.
    pub fn feature_explicit_stats(
        &self,
        binned: &BinnedDataset,
        feat: usize,
    ) -> LeafStats {
        let lo = binned.offsets[feat];
        let hi = binned.offsets[feat + 1];
        let mut s = LeafStats::default();
        for i in lo..hi {
            s.grad += self.grad[i];
            s.hess += self.hess[i];
            s.count += self.count[i] as u64;
        }
        s
    }

    /// The implicit-zero remainder of a feature: rows of this leaf that
    /// have no explicit entry for `feat` (they live in the zero bin).
    pub fn feature_zero_stats(
        &self,
        binned: &BinnedDataset,
        feat: usize,
    ) -> LeafStats {
        self.totals.sub(&self.feature_explicit_stats(binned, feat))
    }
}

/// A reusable pool of flat `[n_features × n_bins]` histogram buffers.
///
/// Ownership / recycling contract:
///
/// * [`HistogramPool::take`] hands out an **arbitrarily dirty** buffer —
///   `build` and `subtract_from` clear on entry (O(|touched|)), so the
///   consumer never sees stale state and `give` never pays a clear.
/// * Every buffer a tree build takes is given back before the build
///   returns (the builder returns all leaf histograms at the end), so a
///   pool held across trees reaches a steady state of at most
///   `max_leaves + 2` buffers — the live leaves plus the parent and the
///   in-flight child during one split — plus one shard partial per
///   build thread when the executor-backed engines shard histograms
///   (`tree/parallel.rs` takes those once per build, not per leaf).
/// * Hold **one pool per worker thread** for the whole training run
///   (see `ps::worker`): allocation then happens once per worker instead
///   of once per node per tree. Pools are plain `&mut` state — never
///   shared across threads.
#[derive(Debug)]
pub struct HistogramPool {
    free: Vec<Histogram>,
    total_bins: usize,
    allocated: usize,
}

impl HistogramPool {
    /// An empty pool handing out `total_bins`-slot histograms.
    pub fn new(total_bins: usize) -> HistogramPool {
        HistogramPool {
            free: Vec::new(),
            total_bins,
            allocated: 0,
        }
    }

    /// Pop a recycled buffer, or allocate a fresh one if the pool is dry.
    /// The buffer may carry stale contents; `build`/`subtract_from` clear.
    pub fn take(&mut self) -> Histogram {
        self.free.pop().unwrap_or_else(|| {
            self.allocated += 1;
            Histogram::zeros(self.total_bins)
        })
    }

    /// Return a buffer for reuse. Not cleared here — clearing is deferred
    /// to the next `build`/`subtract_from`, which must do it anyway.
    pub fn give(&mut self, h: Histogram) {
        debug_assert_eq!(h.grad.len(), self.total_bins);
        self.free.push(h);
    }

    /// Total fresh allocations ever made (recycling metric: steady-state
    /// training keeps this bounded by `max_leaves + 2` per worker).
    pub fn allocated(&self) -> usize {
        self.allocated
    }

    /// Buffers currently parked in the pool.
    pub fn idle(&self) -> usize {
        self.free.len()
    }

    /// Slot count every pooled buffer is sized for.
    pub fn total_bins(&self) -> usize {
        self.total_bins
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{BinnedDataset, CsrMatrix, Dataset};

    fn toy() -> (BinnedDataset, Vec<f32>, Vec<f32>) {
        // 4 rows x 2 features; row 1 has feature 1 missing (implicit zero)
        let x = CsrMatrix::from_rows(
            2,
            &[
                vec![(0, 1.0), (1, 2.0)],
                vec![(0, 3.0)],
                vec![(0, 1.0), (1, 4.0)],
                vec![(0, 3.0), (1, 2.0)],
            ],
        )
        .unwrap();
        let ds = Dataset::new("t", x, vec![1.0, 0.0, 1.0, 0.0]);
        let b = BinnedDataset::from_dataset(&ds, 16).unwrap();
        let grad = vec![1.0, 2.0, 3.0, 4.0];
        let hess = vec![0.5, 0.5, 0.5, 0.5];
        (b, grad, hess)
    }

    /// The untouched-slots-are-zero invariant.
    fn assert_invariant(h: &Histogram) {
        let touched: std::collections::HashSet<u32> = h.touched.iter().copied().collect();
        assert_eq!(touched.len(), h.touched.len(), "duplicate touched slots");
        for s in 0..h.grad.len() {
            if !touched.contains(&(s as u32)) {
                assert_eq!(h.grad[s], 0.0, "slot {s}");
                assert_eq!(h.hess[s], 0.0, "slot {s}");
                assert_eq!(h.count[s], 0, "slot {s}");
            }
        }
    }

    #[test]
    fn build_accumulates_totals() {
        let (b, g, h) = toy();
        let mut hist = Histogram::zeros(b.total_bins());
        hist.build(&b, &[0, 1, 2, 3], &g, &h);
        assert_eq!(hist.totals.count, 4);
        assert!((hist.totals.grad - 10.0).abs() < 1e-12);
        assert!((hist.totals.hess - 2.0).abs() < 1e-12);
        assert_invariant(&hist);
    }

    #[test]
    fn clear_is_touched_driven_and_complete() {
        let (b, g, h) = toy();
        let mut hist = Histogram::zeros(b.total_bins());
        hist.build(&b, &[0, 1, 2, 3], &g, &h);
        assert!(!hist.touched.is_empty());
        hist.clear();
        assert!(hist.touched.is_empty());
        assert!(hist.grad.iter().all(|&x| x == 0.0));
        assert!(hist.count.iter().all(|&c| c == 0));
    }

    #[test]
    fn zero_stats_reconstruct_missing_rows() {
        let (b, g, h) = toy();
        let mut hist = Histogram::zeros(b.total_bins());
        hist.build(&b, &[0, 1, 2, 3], &g, &h);
        // feature 1: row 1 is implicit-zero => zero stats = row 1 only
        let z = hist.feature_zero_stats(&b, 1);
        assert_eq!(z.count, 1);
        assert!((z.grad - 2.0).abs() < 1e-12);
        // feature 0: all rows explicit => zero stats empty
        let z0 = hist.feature_zero_stats(&b, 0);
        assert_eq!(z0.count, 0);
        assert!(z0.grad.abs() < 1e-12);
    }

    #[test]
    fn subtraction_equals_direct_build() {
        let (b, g, h) = toy();
        let mut parent = Histogram::zeros(b.total_bins());
        parent.build(&b, &[0, 1, 2, 3], &g, &h);
        let mut left = Histogram::zeros(b.total_bins());
        left.build(&b, &[0, 1], &g, &h);
        let mut right_direct = Histogram::zeros(b.total_bins());
        right_direct.build(&b, &[2, 3], &g, &h);
        let mut right_sub = Histogram::zeros(b.total_bins());
        right_sub.subtract_from(&parent, &left);
        for i in 0..b.total_bins() {
            assert!((right_sub.grad[i] - right_direct.grad[i]).abs() < 1e-9);
            assert!((right_sub.hess[i] - right_direct.hess[i]).abs() < 1e-9);
            assert_eq!(right_sub.count[i], right_direct.count[i]);
        }
        assert_eq!(right_sub.totals, right_direct.totals);
        assert_invariant(&right_sub);
    }

    #[test]
    fn subtract_after_pool_reuse_clears_stale_state() {
        let (b, g, h) = toy();
        let mut parent = Histogram::zeros(b.total_bins());
        parent.build(&b, &[0, 1, 2, 3], &g, &h);
        let mut left = Histogram::zeros(b.total_bins());
        left.build(&b, &[0], &g, &h);
        // dirty reusable buffer
        let mut reused = Histogram::zeros(b.total_bins());
        reused.build(&b, &[1, 2], &g, &h);
        reused.subtract_from(&parent, &left);
        let mut direct = Histogram::zeros(b.total_bins());
        direct.build(&b, &[1, 2, 3], &g, &h);
        for i in 0..b.total_bins() {
            assert!((reused.grad[i] - direct.grad[i]).abs() < 1e-9, "slot {i}");
            assert_eq!(reused.count[i], direct.count[i], "slot {i}");
        }
        assert_invariant(&reused);
    }

    #[test]
    fn merge_equals_joint_build() {
        let (b, g, h) = toy();
        let mut a = Histogram::zeros(b.total_bins());
        a.build(&b, &[0, 1], &g, &h);
        let mut c = Histogram::zeros(b.total_bins());
        c.build(&b, &[2, 3], &g, &h);
        let mut merged = Histogram::zeros(b.total_bins());
        merged.clear();
        merged.merge(&a);
        merged.merge(&c);
        let mut joint = Histogram::zeros(b.total_bins());
        joint.build(&b, &[0, 1, 2, 3], &g, &h);
        for i in 0..b.total_bins() {
            assert!((merged.grad[i] - joint.grad[i]).abs() < 1e-9);
            assert_eq!(merged.count[i], joint.count[i]);
        }
        assert_eq!(merged.totals, joint.totals);
        assert_invariant(&merged);
    }

    #[test]
    fn touched_features_lists_only_present_features() {
        let (b, g, h) = toy();
        let mut hist = Histogram::zeros(b.total_bins());
        // row 1 only has feature 0
        hist.build(&b, &[1], &g, &h);
        assert_eq!(hist.touched_features(&b), vec![0]);
        hist.build(&b, &[0, 1, 2, 3], &g, &h);
        assert_eq!(hist.touched_features(&b), vec![0, 1]);
    }

    #[test]
    fn subset_of_rows_only() {
        let (b, g, h) = toy();
        let mut hist = Histogram::zeros(b.total_bins());
        hist.build(&b, &[1], &g, &h);
        assert_eq!(hist.totals.count, 1);
        assert!((hist.totals.grad - 2.0).abs() < 1e-12);
    }

    #[test]
    fn pool_reuses_buffers() {
        let mut pool = HistogramPool::new(8);
        let mut h = pool.take();
        h.grad[0] = 5.0;
        h.touched.push(0);
        h.totals.count = 3;
        pool.give(h);
        let h2 = pool.take();
        // pool does not clear on give; build()/subtract_from() clear.
        assert_eq!(h2.grad.len(), 8);
        // the second take came from the free list, not a fresh allocation
        assert_eq!(pool.allocated(), 1);
        assert_eq!(pool.idle(), 0);
        pool.give(h2);
        assert_eq!(pool.idle(), 1);
        assert_eq!(pool.total_bins(), 8);
    }

    #[test]
    fn pool_counts_fresh_allocations() {
        let mut pool = HistogramPool::new(4);
        let a = pool.take();
        let b = pool.take();
        assert_eq!(pool.allocated(), 2);
        pool.give(a);
        pool.give(b);
        let _c = pool.take();
        let _d = pool.take();
        assert_eq!(pool.allocated(), 2, "recycled takes must not allocate");
    }

    #[test]
    fn strategy_parse_roundtrip() {
        assert_eq!(
            HistogramStrategy::parse("rebuild").unwrap(),
            HistogramStrategy::Rebuild
        );
        assert_eq!(
            HistogramStrategy::parse("subtract").unwrap(),
            HistogramStrategy::Subtract
        );
        assert!(HistogramStrategy::parse("magic").is_err());
        for s in [HistogramStrategy::Rebuild, HistogramStrategy::Subtract] {
            assert_eq!(HistogramStrategy::parse(s.as_str()).unwrap(), s);
        }
        assert_eq!(HistogramStrategy::default(), HistogramStrategy::Subtract);
    }
}
