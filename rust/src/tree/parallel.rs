//! Parallel tree-building engines.
//!
//! Two axes of intra-tree parallelism, composable with the sibling
//! subtraction + pooled buffers of [`super::builder`]:
//!
//! * **Row-sharded histogram building** ([`build_tree_forkjoin`]) — the
//!   "parallel part only exists in the sub-step of building the tree"
//!   pattern the paper attributes to LightGBM/TencentBoost (§II): the
//!   rows of each leaf are sharded across `n_threads`, each shard builds
//!   a partial histogram in parallel, and a barrier (thread join) merges
//!   them before split finding — one synchronisation *per histogram*,
//!   many per tree, which is precisely the cost structure asynch-SGBDT
//!   removes at the boosting level.
//! * **Per-feature work-stealing split search**
//!   ([`best_split_parallel`]) — the candidate features of a leaf are
//!   claimed in chunks off a shared atomic cursor by `n_threads` scanners,
//!   so wide/sparse datasets (real-sim: tens of thousands of features,
//!   skewed per-feature bin occupancy) load-balance instead of sharding
//!   statically. The merged result is identical to the serial scan:
//!   per-feature scans are the same code, and ties on gain break towards
//!   the lower feature id exactly like the serial ascending iteration.
//!
//! [`build_tree_feature_parallel`] combines both with a caller-owned
//! [`HistogramPool`] — the full feature-parallel engine used by the
//! benches.

use std::sync::atomic::{AtomicUsize, Ordering};

use crate::data::BinnedDataset;
use crate::util::Rng;

use super::builder::{grow_tree, TreeParams};
use super::histogram::{Histogram, HistogramPool};
use super::split::{best_split, best_split_for_feature, SplitConstraints, SplitInfo};
use super::tree::Tree;

/// Features claimed per steal: large enough to amortise the atomic, small
/// enough to load-balance skewed per-feature scan costs.
const STEAL_CHUNK: usize = 8;

/// Row-sharded histogram build with a merge barrier (the fork-join
/// "allreduce"). Falls back to a serial build for leaves too small to
/// amortise thread spawn.
fn build_sharded(
    hist: &mut Histogram,
    binned: &BinnedDataset,
    leaf_rows: &[u32],
    grad: &[f32],
    hess: &[f32],
    n_threads: usize,
) {
    if n_threads <= 1 || leaf_rows.len() < 2 * n_threads {
        hist.build(binned, leaf_rows, grad, hess);
        return;
    }
    // fork: one partial histogram per row shard
    let shard = leaf_rows.len().div_ceil(n_threads);
    let partials: Vec<Histogram> = std::thread::scope(|s| {
        let handles: Vec<_> = leaf_rows
            .chunks(shard)
            .map(|chunk| {
                s.spawn(move || {
                    let mut h = Histogram::zeros(binned.total_bins());
                    h.build(binned, chunk, grad, hess);
                    h
                })
            })
            .collect();
        // join: the synchronisation barrier
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });
    // allreduce-equivalent merge
    hist.clear();
    for p in &partials {
        hist.merge(p);
    }
}

/// `cand` replaces `best` on strictly higher gain, or on equal gain at a
/// lower feature id — the same winner the serial ascending-feature scan
/// keeps, so parallel and serial search are result-identical.
fn take_better(best: &mut Option<SplitInfo>, cand: Option<SplitInfo>) {
    let Some(c) = cand else { return };
    let replace = match best {
        None => true,
        Some(b) => c.gain > b.gain || (c.gain == b.gain && c.feature < b.feature),
    };
    if replace {
        *best = Some(c);
    }
}

/// Best split across the enabled features, scanned by `n_threads` workers
/// pulling feature chunks off a shared work-stealing cursor.
///
/// Candidate pruning matches [`best_split`]: for sparse leaves only the
/// touched features are enumerated (a feature with no touched slot has
/// every leaf row in its zero bin and cannot split). Returns exactly what
/// the serial scan would.
pub fn best_split_parallel(
    hist: &Histogram,
    binned: &BinnedDataset,
    feature_mask: &[bool],
    cons: &SplitConstraints,
    n_threads: usize,
) -> Option<SplitInfo> {
    // same touched-density switch as the serial path, so the candidate
    // set (and therefore the result) is identical
    let candidates: Vec<u32> = if hist.touched.len() * 8 < binned.total_bins() {
        hist.touched_features(binned)
            .into_iter()
            .filter(|&f| feature_mask[f as usize])
            .collect()
    } else {
        (0..binned.n_features as u32)
            .filter(|&f| feature_mask[f as usize])
            .collect()
    };
    if n_threads <= 1 || candidates.len() < 2 * STEAL_CHUNK {
        let mut best: Option<SplitInfo> = None;
        for &f in &candidates {
            take_better(&mut best, best_split_for_feature(hist, binned, f as usize, cons));
        }
        return best;
    }
    let cursor = AtomicUsize::new(0);
    let locals: Vec<Option<SplitInfo>> = std::thread::scope(|s| {
        let handles: Vec<_> = (0..n_threads)
            .map(|_| {
                s.spawn(|| {
                    let mut local: Option<SplitInfo> = None;
                    loop {
                        // steal the next chunk of features
                        let start = cursor.fetch_add(STEAL_CHUNK, Ordering::Relaxed);
                        if start >= candidates.len() {
                            break;
                        }
                        let end = (start + STEAL_CHUNK).min(candidates.len());
                        for &f in &candidates[start..end] {
                            take_better(
                                &mut local,
                                best_split_for_feature(hist, binned, f as usize, cons),
                            );
                        }
                    }
                    local
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });
    let mut best: Option<SplitInfo> = None;
    for local in locals {
        take_better(&mut best, local);
    }
    best
}

/// Like [`super::build_tree`], but histogram construction is sharded
/// across `n_threads` with a merge barrier (fork-join). Split search stays
/// serial — this is the synchronous-baseline cost model.
pub fn build_tree_forkjoin(
    binned: &BinnedDataset,
    rows: &[u32],
    grad: &[f32],
    hess: &[f32],
    params: &TreeParams,
    rng: &mut Rng,
    n_threads: usize,
) -> Tree {
    let mut pool = HistogramPool::new(binned.total_bins());
    build_tree_forkjoin_pooled(binned, rows, grad, hess, params, rng, n_threads, &mut pool)
}

/// [`build_tree_forkjoin`] with a caller-owned histogram pool (see the
/// [`HistogramPool`] recycling contract). Only the merged per-leaf
/// histograms are pooled; shard partials are thread-local.
#[allow(clippy::too_many_arguments)]
pub fn build_tree_forkjoin_pooled(
    binned: &BinnedDataset,
    rows: &[u32],
    grad: &[f32],
    hess: &[f32],
    params: &TreeParams,
    rng: &mut Rng,
    n_threads: usize,
    pool: &mut HistogramPool,
) -> Tree {
    let n_threads = n_threads.max(1);
    grow_tree(
        binned,
        rows,
        grad,
        hess,
        params,
        rng,
        pool,
        &mut |hist, leaf_rows| build_sharded(hist, binned, leaf_rows, grad, hess, n_threads),
        &|hist, mask, cons| best_split(hist, binned, mask, cons),
    )
}

/// The full feature-parallel engine: row-sharded histogram building *and*
/// per-feature work-stealing split search, over a caller-owned pool.
/// Produces the same tree as [`super::build_tree`] given the same RNG
/// (modulo f64 merge-order rounding in the sharded histogram sums).
#[allow(clippy::too_many_arguments)]
pub fn build_tree_feature_parallel(
    binned: &BinnedDataset,
    rows: &[u32],
    grad: &[f32],
    hess: &[f32],
    params: &TreeParams,
    rng: &mut Rng,
    n_threads: usize,
    pool: &mut HistogramPool,
) -> Tree {
    let n_threads = n_threads.max(1);
    grow_tree(
        binned,
        rows,
        grad,
        hess,
        params,
        rng,
        pool,
        &mut |hist, leaf_rows| build_sharded(hist, binned, leaf_rows, grad, hess, n_threads),
        &|hist, mask, cons| best_split_parallel(hist, binned, mask, cons, n_threads),
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{synthetic, BinnedDataset};
    use crate::loss::logistic;

    #[test]
    fn forkjoin_tree_equals_serial_tree() {
        let ds = synthetic::realsim_like(600, 1);
        let binned = BinnedDataset::from_dataset(&ds, 32).unwrap();
        let f = vec![0.0f32; ds.n_rows()];
        let w = vec![1.0f32; ds.n_rows()];
        let gh = logistic::grad_hess_loss(&f, &ds.y, &w);
        let rows: Vec<u32> = (0..ds.n_rows() as u32).collect();
        let params = TreeParams {
            max_leaves: 16,
            feature_rate: 1.0,
            ..Default::default()
        };
        let serial = super::super::build_tree(
            &binned, &rows, &gh.grad, &gh.hess, &params, &mut Rng::new(5),
        );
        for threads in [2usize, 4, 8] {
            let par = build_tree_forkjoin(
                &binned, &rows, &gh.grad, &gh.hess, &params, &mut Rng::new(5), threads,
            );
            // identical splits: merge order only changes f64 rounding in the
            // 15th digit; structure and leaf count must match exactly.
            assert_eq!(par.n_leaves(), serial.n_leaves(), "threads={threads}");
            for r in 0..ds.n_rows() {
                let a = serial.predict_binned(&binned, r);
                let b = par.predict_binned(&binned, r);
                assert!((a - b).abs() < 1e-4, "row {r}: {a} vs {b}");
            }
        }
    }

    #[test]
    fn forkjoin_single_thread_is_serial() {
        let ds = synthetic::realsim_like(200, 2);
        let binned = BinnedDataset::from_dataset(&ds, 16).unwrap();
        let f = vec![0.0f32; ds.n_rows()];
        let w = vec![1.0f32; ds.n_rows()];
        let gh = logistic::grad_hess_loss(&f, &ds.y, &w);
        let rows: Vec<u32> = (0..ds.n_rows() as u32).collect();
        let params = TreeParams {
            max_leaves: 8,
            feature_rate: 1.0,
            ..Default::default()
        };
        let a =
            super::super::build_tree(&binned, &rows, &gh.grad, &gh.hess, &params, &mut Rng::new(3));
        let b =
            build_tree_forkjoin(&binned, &rows, &gh.grad, &gh.hess, &params, &mut Rng::new(3), 1);
        assert_eq!(a, b);
    }

    #[test]
    fn forkjoin_handles_tiny_leaves() {
        // fewer rows than 2*threads: falls back to serial build per leaf
        let ds = synthetic::realsim_like(10, 3);
        let binned = BinnedDataset::from_dataset(&ds, 16).unwrap();
        let f = vec![0.0f32; 10];
        let w = vec![1.0f32; 10];
        let gh = logistic::grad_hess_loss(&f, &ds.y, &w);
        let rows: Vec<u32> = (0..10).collect();
        let t = build_tree_forkjoin(
            &binned, &rows, &gh.grad, &gh.hess,
            &TreeParams { max_leaves: 4, feature_rate: 1.0, ..Default::default() },
            &mut Rng::new(4), 8,
        );
        t.validate().unwrap();
    }

    #[test]
    fn parallel_split_search_matches_serial_exactly() {
        let ds = synthetic::realsim_like(800, 11);
        let binned = BinnedDataset::from_dataset(&ds, 32).unwrap();
        let f = vec![0.0f32; ds.n_rows()];
        let w = vec![1.0f32; ds.n_rows()];
        let gh = logistic::grad_hess_loss(&f, &ds.y, &w);
        let rows: Vec<u32> = (0..ds.n_rows() as u32).collect();
        let mut hist = Histogram::zeros(binned.total_bins());
        hist.build(&binned, &rows, &gh.grad, &gh.hess);
        let mask = vec![true; binned.n_features];
        let cons = SplitConstraints::default();
        let serial = best_split(&hist, &binned, &mask, &cons);
        for threads in [1usize, 2, 4, 8] {
            let par = best_split_parallel(&hist, &binned, &mask, &cons, threads);
            assert_eq!(par, serial, "threads={threads}");
        }
        // and on a sparse subset (touched-features pruning path)
        let few: Vec<u32> = rows.iter().copied().take(20).collect();
        hist.build(&binned, &few, &gh.grad, &gh.hess);
        let serial = best_split(&hist, &binned, &mask, &cons);
        for threads in [2usize, 4] {
            assert_eq!(best_split_parallel(&hist, &binned, &mask, &cons, threads), serial);
        }
    }

    #[test]
    fn feature_parallel_tree_matches_serial_structure() {
        let ds = synthetic::realsim_like(600, 12);
        let binned = BinnedDataset::from_dataset(&ds, 32).unwrap();
        let f = vec![0.0f32; ds.n_rows()];
        let w = vec![1.0f32; ds.n_rows()];
        let gh = logistic::grad_hess_loss(&f, &ds.y, &w);
        let rows: Vec<u32> = (0..ds.n_rows() as u32).collect();
        let params = TreeParams { max_leaves: 16, feature_rate: 1.0, ..Default::default() };
        let serial = super::super::build_tree(
            &binned, &rows, &gh.grad, &gh.hess, &params, &mut Rng::new(9),
        );
        for threads in [2usize, 4] {
            let mut pool = HistogramPool::new(binned.total_bins());
            let par = build_tree_feature_parallel(
                &binned, &rows, &gh.grad, &gh.hess, &params, &mut Rng::new(9), threads, &mut pool,
            );
            assert_eq!(par.n_leaves(), serial.n_leaves(), "threads={threads}");
            for r in 0..ds.n_rows() {
                let a = serial.predict_binned(&binned, r);
                let b = par.predict_binned(&binned, r);
                assert!((a - b).abs() < 1e-4, "row {r}: {a} vs {b}");
            }
        }
    }

    #[test]
    fn feature_parallel_single_thread_is_exactly_serial() {
        let ds = synthetic::realsim_like(300, 13);
        let binned = BinnedDataset::from_dataset(&ds, 16).unwrap();
        let f = vec![0.0f32; ds.n_rows()];
        let w = vec![1.0f32; ds.n_rows()];
        let gh = logistic::grad_hess_loss(&f, &ds.y, &w);
        let rows: Vec<u32> = (0..ds.n_rows() as u32).collect();
        let params = TreeParams {
            max_leaves: 8,
            feature_rate: 1.0,
            ..Default::default()
        };
        let a =
            super::super::build_tree(&binned, &rows, &gh.grad, &gh.hess, &params, &mut Rng::new(6));
        let mut pool = HistogramPool::new(binned.total_bins());
        let b = build_tree_feature_parallel(
            &binned, &rows, &gh.grad, &gh.hess, &params, &mut Rng::new(6), 1, &mut pool,
        );
        assert_eq!(a, b);
    }
}
