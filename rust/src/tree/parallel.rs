//! Fork-join parallel tree building — the synchronous-baseline substrate.
//!
//! This is the "parallel part only exists in the sub-step of building the
//! tree" pattern the paper attributes to LightGBM/TencentBoost (§II): the
//! rows of each leaf are sharded across `n_threads`, each shard builds a
//! partial histogram in parallel, and a barrier (thread join) merges them
//! before split finding — one synchronisation *per histogram*, many per
//! tree, which is precisely the cost structure asynch-SGBDT removes.

use crate::data::BinnedDataset;
use crate::util::Rng;

use super::builder::{grow_tree, TreeParams};
use super::histogram::Histogram;
use super::tree::Tree;

/// Like [`super::build_tree`], but histogram construction is sharded
/// across `n_threads` with a merge barrier (fork-join).
pub fn build_tree_forkjoin(
    binned: &BinnedDataset,
    rows: &[u32],
    grad: &[f32],
    hess: &[f32],
    params: &TreeParams,
    rng: &mut Rng,
    n_threads: usize,
) -> Tree {
    let n_threads = n_threads.max(1);
    grow_tree(binned, rows, grad, hess, params, rng, &mut |hist, leaf_rows| {
        if n_threads == 1 || leaf_rows.len() < 2 * n_threads {
            hist.build(binned, leaf_rows, grad, hess);
            return;
        }
        // fork: one partial histogram per row shard
        let shard = leaf_rows.len().div_ceil(n_threads);
        let partials: Vec<Histogram> = std::thread::scope(|s| {
            let handles: Vec<_> = leaf_rows
                .chunks(shard)
                .map(|chunk| {
                    s.spawn(move || {
                        let mut h = Histogram::zeros(binned.total_bins());
                        h.build(binned, chunk, grad, hess);
                        h
                    })
                })
                .collect();
            // join: the synchronisation barrier
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        });
        // allreduce-equivalent merge
        hist.clear();
        for p in &partials {
            hist.merge(p);
        }
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{synthetic, BinnedDataset};
    use crate::loss::logistic;

    #[test]
    fn forkjoin_tree_equals_serial_tree() {
        let ds = synthetic::realsim_like(600, 1);
        let binned = BinnedDataset::from_dataset(&ds, 32).unwrap();
        let f = vec![0.0f32; ds.n_rows()];
        let w = vec![1.0f32; ds.n_rows()];
        let gh = logistic::grad_hess_loss(&f, &ds.y, &w);
        let rows: Vec<u32> = (0..ds.n_rows() as u32).collect();
        let params = TreeParams {
            max_leaves: 16,
            feature_rate: 1.0,
            ..Default::default()
        };
        let serial = super::super::build_tree(
            &binned, &rows, &gh.grad, &gh.hess, &params, &mut Rng::new(5),
        );
        for threads in [2usize, 4, 8] {
            let par = build_tree_forkjoin(
                &binned, &rows, &gh.grad, &gh.hess, &params, &mut Rng::new(5), threads,
            );
            // identical splits: merge order only changes f64 rounding in the
            // 15th digit; structure and leaf count must match exactly.
            assert_eq!(par.n_leaves(), serial.n_leaves(), "threads={threads}");
            for r in 0..ds.n_rows() {
                let a = serial.predict_binned(&binned, r);
                let b = par.predict_binned(&binned, r);
                assert!((a - b).abs() < 1e-4, "row {r}: {a} vs {b}");
            }
        }
    }

    #[test]
    fn forkjoin_single_thread_is_serial() {
        let ds = synthetic::realsim_like(200, 2);
        let binned = BinnedDataset::from_dataset(&ds, 16).unwrap();
        let f = vec![0.0f32; ds.n_rows()];
        let w = vec![1.0f32; ds.n_rows()];
        let gh = logistic::grad_hess_loss(&f, &ds.y, &w);
        let rows: Vec<u32> = (0..ds.n_rows() as u32).collect();
        let params = TreeParams { max_leaves: 8, feature_rate: 1.0, ..Default::default() };
        let a = super::super::build_tree(&binned, &rows, &gh.grad, &gh.hess, &params, &mut Rng::new(3));
        let b = build_tree_forkjoin(&binned, &rows, &gh.grad, &gh.hess, &params, &mut Rng::new(3), 1);
        assert_eq!(a, b);
    }

    #[test]
    fn forkjoin_handles_tiny_leaves() {
        // fewer rows than 2*threads: falls back to serial build per leaf
        let ds = synthetic::realsim_like(10, 3);
        let binned = BinnedDataset::from_dataset(&ds, 16).unwrap();
        let f = vec![0.0f32; 10];
        let w = vec![1.0f32; 10];
        let gh = logistic::grad_hess_loss(&f, &ds.y, &w);
        let rows: Vec<u32> = (0..10).collect();
        let t = build_tree_forkjoin(
            &binned, &rows, &gh.grad, &gh.hess,
            &TreeParams { max_leaves: 4, feature_rate: 1.0, ..Default::default() },
            &mut Rng::new(4), 8,
        );
        t.validate().unwrap();
    }
}
