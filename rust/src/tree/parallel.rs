//! Parallel tree-building engines.
//!
//! Two axes of intra-tree parallelism, composable with the sibling
//! subtraction + pooled buffers of [`super::builder`]:
//!
//! * **Row-sharded histogram building** ([`build_histogram_sharded`]) —
//!   the "parallel part only exists in the sub-step of building the
//!   tree" pattern the paper attributes to LightGBM/TencentBoost (§II):
//!   the rows of each leaf are sharded across the executor's threads,
//!   each shard builds a partial histogram in parallel, and a barrier
//!   (the executor's check-in) merges them before split finding — one
//!   synchronisation *per histogram*, many per tree, which is precisely
//!   the cost structure asynch-SGBDT removes at the boosting level.
//! * **Per-feature work-stealing split search**
//!   ([`best_split_parallel`]) — the candidate features of a leaf are
//!   claimed in chunks off a shared atomic cursor by the executor's
//!   scanners, so wide/sparse datasets (real-sim: tens of thousands of
//!   features, skewed per-feature bin occupancy) load-balance instead of
//!   sharding statically. The merged result is identical to the serial
//!   scan: per-feature scans are the same code, and ties on gain break
//!   towards the lower feature id exactly like the serial ascending
//!   iteration.
//!
//! Every engine draws its threads from a caller-owned
//! [`Executor`](crate::util::Executor) instead of spawning per section:
//! under `pool=persistent` the executor parks its workers between
//! sections, so the dozens of fork-join cycles inside one tree build pay
//! a condvar wake each instead of an OS thread spawn/join each (the
//! worker-side analogue of the server's scoring pool — DESIGN.md §12).
//! `pool=scoped` keeps per-section `thread::scope` spawns as the
//! bit-identical reference. Shard boundaries, partial-merge order and
//! split tie-breaking are pure functions of the executor's *thread
//! count*, never of its mode, so trees are bit-identical across modes.
//!
//! [`build_tree_feature_parallel`] combines both engines with a
//! caller-owned [`HistogramPool`] — the full feature-parallel engine
//! used by the async workers, the trainers and the benches.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

use crate::data::BinnedDataset;
use crate::util::{Executor, Rng};

use super::builder::{grow_tree, TreeParams};
use super::histogram::{Histogram, HistogramPool};
use super::split::{best_split, best_split_for_feature, SplitConstraints, SplitInfo};
use super::tree::Tree;

/// Features claimed per steal: large enough to amortise the atomic, small
/// enough to load-balance skewed per-feature scan costs.
const STEAL_CHUNK: usize = 8;

/// Row-sharded histogram build with a merge barrier (the fork-join
/// "allreduce"): each executor worker builds a partial histogram over a
/// contiguous row shard, and the partials are merged in shard order.
///
/// Allocates transient per-shard buffers — the self-contained entry
/// point for one-shot callers (benches). Tree builds run dozens of
/// sharded builds per tree, so the builders below recycle one set of
/// shard partials from their [`HistogramPool`] across every leaf
/// instead (see the private `build_sharded_into`).
pub fn build_histogram_sharded(
    hist: &mut Histogram,
    binned: &BinnedDataset,
    leaf_rows: &[u32],
    grad: &[f32],
    hess: &[f32],
    exec: &Executor,
) {
    let threads = exec.threads();
    if threads <= 1 || leaf_rows.len() < threads {
        hist.build(binned, leaf_rows, grad, hess);
        return;
    }
    let partials: Vec<Mutex<Histogram>> = (0..threads)
        .map(|_| Mutex::new(Histogram::zeros(binned.total_bins())))
        .collect();
    build_sharded_into(hist, binned, leaf_rows, grad, hess, exec, &partials);
}

/// [`build_histogram_sharded`] over caller-owned per-worker partial
/// buffers (`partials.len() >= exec.threads()`, one slot per worker —
/// the mutexes are uncontended and exist to hand each worker `&mut`
/// access to its own slot).
///
/// Falls back to a serial build only when a shard would be empty
/// (`leaf_rows.len() < threads`). The old threshold was `2 × threads`
/// rows — sized to amortise a per-call `thread::scope` spawn — but with
/// dispatch on a persistent executor and pooled partials a parallel
/// section costs a condvar wake plus an O(|touched|) clear, so tiny
/// leaves shard too. The threshold is a function of the thread count
/// only (never the pool mode), which keeps shard boundaries — and
/// therefore f64 merge order — bit-identical across
/// `pool=persistent|scoped`.
fn build_sharded_into(
    hist: &mut Histogram,
    binned: &BinnedDataset,
    leaf_rows: &[u32],
    grad: &[f32],
    hess: &[f32],
    exec: &Executor,
    partials: &[Mutex<Histogram>],
) {
    let threads = exec.threads();
    if threads <= 1 || leaf_rows.len() < threads {
        hist.build(binned, leaf_rows, grad, hess);
        return;
    }
    debug_assert!(partials.len() >= threads, "one partial slot per executor worker");
    // fork: one partial histogram per contiguous row shard
    let shard = leaf_rows.len().div_ceil(threads);
    let n_shards = leaf_rows.len().div_ceil(shard);
    exec.run(n_shards, &|idx| {
        let start = idx * shard;
        let end = (start + shard).min(leaf_rows.len());
        // slot idx belongs to worker idx alone; build() clears the
        // recycled buffer in O(|touched|) before accumulating
        let mut h = partials[idx].lock().unwrap();
        h.build(binned, &leaf_rows[start..end], grad, hess);
    });
    // allreduce-equivalent merge, in shard order (slot i always holds
    // shard i regardless of scheduling)
    hist.clear();
    for m in &partials[..n_shards] {
        hist.merge(&m.lock().unwrap());
    }
}

/// Take `threads` shard-partial buffers from the pool (none needed for
/// a single-thread executor: the sharded build runs inline).
fn take_partials(pool: &mut HistogramPool, threads: usize) -> Vec<Mutex<Histogram>> {
    if threads <= 1 {
        return Vec::new();
    }
    (0..threads).map(|_| Mutex::new(pool.take())).collect()
}

/// Return shard-partial buffers to the pool after a build. Only reached
/// on the non-panicking path (a panicking job unwinds the whole build
/// and simply drops the buffers), so the mutexes cannot be poisoned.
fn give_partials(pool: &mut HistogramPool, partials: Vec<Mutex<Histogram>>) {
    for m in partials {
        pool.give(m.into_inner().unwrap());
    }
}

/// `cand` replaces `best` on strictly higher gain, or on equal gain at a
/// lower feature id — the same winner the serial ascending-feature scan
/// keeps, so parallel and serial search are result-identical.
fn take_better(best: &mut Option<SplitInfo>, cand: Option<SplitInfo>) {
    let Some(c) = cand else { return };
    let replace = match best {
        None => true,
        Some(b) => c.gain > b.gain || (c.gain == b.gain && c.feature < b.feature),
    };
    if replace {
        *best = Some(c);
    }
}

/// Best split across the enabled features, scanned by the executor's
/// workers pulling feature chunks off a shared work-stealing cursor.
///
/// Candidate pruning matches [`best_split`]: for sparse leaves only the
/// touched features are enumerated (a feature with no touched slot has
/// every leaf row in its zero bin and cannot split). Returns exactly what
/// the serial scan would: chunk assignment is scheduling-dependent, but
/// each per-feature scan is the same code, and the merge's
/// lower-feature-id tie-break makes the merged winner independent of
/// which scanner saw it (pinned by the tie property test in
/// `tests/test_build_pool.rs`).
pub fn best_split_parallel(
    hist: &Histogram,
    binned: &BinnedDataset,
    feature_mask: &[bool],
    cons: &SplitConstraints,
    exec: &Executor,
) -> Option<SplitInfo> {
    // same touched-density switch as the serial path, so the candidate
    // set (and therefore the result) is identical
    let candidates: Vec<u32> = if hist.touched.len() * 8 < binned.total_bins() {
        hist.touched_features(binned)
            .into_iter()
            .filter(|&f| feature_mask[f as usize])
            .collect()
    } else {
        (0..binned.n_features as u32)
            .filter(|&f| feature_mask[f as usize])
            .collect()
    };
    let threads = exec.threads();
    if threads <= 1 || candidates.len() < 2 * STEAL_CHUNK {
        let mut best: Option<SplitInfo> = None;
        for &f in &candidates {
            take_better(&mut best, best_split_for_feature(hist, binned, f as usize, cons));
        }
        return best;
    }
    let cursor = AtomicUsize::new(0);
    let locals: Vec<Option<SplitInfo>> = exec.run_collect(threads, &|_idx| {
        let mut local: Option<SplitInfo> = None;
        loop {
            // steal the next chunk of features
            let start = cursor.fetch_add(STEAL_CHUNK, Ordering::Relaxed);
            if start >= candidates.len() {
                break;
            }
            let end = (start + STEAL_CHUNK).min(candidates.len());
            for &f in &candidates[start..end] {
                take_better(
                    &mut local,
                    best_split_for_feature(hist, binned, f as usize, cons),
                );
            }
        }
        local
    });
    let mut best: Option<SplitInfo> = None;
    for local in locals {
        take_better(&mut best, local);
    }
    best
}

/// Like [`super::build_tree`], but histogram construction is sharded
/// across the executor's threads with a merge barrier (fork-join). Split
/// search stays serial — this is the synchronous-baseline cost model.
pub fn build_tree_forkjoin(
    binned: &BinnedDataset,
    rows: &[u32],
    grad: &[f32],
    hess: &[f32],
    params: &TreeParams,
    rng: &mut Rng,
    exec: &Executor,
) -> Tree {
    let mut pool = HistogramPool::new(binned.total_bins());
    build_tree_forkjoin_pooled(binned, rows, grad, hess, params, rng, exec, &mut pool)
}

/// [`build_tree_forkjoin`] with a caller-owned histogram pool (see the
/// [`HistogramPool`] recycling contract). Merged per-leaf histograms
/// *and* the `threads` shard partials come from the pool — the partials
/// are taken once per build and shared by every leaf's fork-join, so a
/// deep tree's many small leaves never pay a buffer allocation.
#[allow(clippy::too_many_arguments)]
pub fn build_tree_forkjoin_pooled(
    binned: &BinnedDataset,
    rows: &[u32],
    grad: &[f32],
    hess: &[f32],
    params: &TreeParams,
    rng: &mut Rng,
    exec: &Executor,
    pool: &mut HistogramPool,
) -> Tree {
    let partials = take_partials(pool, exec.threads());
    let tree = grow_tree(
        binned,
        rows,
        grad,
        hess,
        params,
        rng,
        pool,
        &mut |hist, leaf_rows| {
            build_sharded_into(hist, binned, leaf_rows, grad, hess, exec, &partials)
        },
        &|hist, mask, cons| best_split(hist, binned, mask, cons),
    );
    give_partials(pool, partials);
    tree
}

/// The full feature-parallel engine: row-sharded histogram building *and*
/// per-feature work-stealing split search, over a caller-owned buffer
/// pool and a caller-owned (worker-lifetime) executor. Produces the same
/// tree as [`super::build_tree`] given the same RNG (modulo f64
/// merge-order rounding in the sharded histogram sums); with a
/// single-thread executor it IS [`super::build_tree_pooled`],
/// bit for bit.
#[allow(clippy::too_many_arguments)]
pub fn build_tree_feature_parallel(
    binned: &BinnedDataset,
    rows: &[u32],
    grad: &[f32],
    hess: &[f32],
    params: &TreeParams,
    rng: &mut Rng,
    exec: &Executor,
    pool: &mut HistogramPool,
) -> Tree {
    let partials = take_partials(pool, exec.threads());
    let tree = grow_tree(
        binned,
        rows,
        grad,
        hess,
        params,
        rng,
        pool,
        &mut |hist, leaf_rows| {
            build_sharded_into(hist, binned, leaf_rows, grad, hess, exec, &partials)
        },
        &|hist, mask, cons| best_split_parallel(hist, binned, mask, cons, exec),
    );
    give_partials(pool, partials);
    tree
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{synthetic, BinnedDataset};
    use crate::loss::logistic;
    use crate::util::PoolMode;

    fn both_modes(threads: usize) -> [Executor; 2] {
        [
            Executor::new(PoolMode::Persistent, threads),
            Executor::new(PoolMode::Scoped, threads),
        ]
    }

    #[test]
    fn forkjoin_tree_equals_serial_tree() {
        let ds = synthetic::realsim_like(600, 1);
        let binned = BinnedDataset::from_dataset(&ds, 32).unwrap();
        let f = vec![0.0f32; ds.n_rows()];
        let w = vec![1.0f32; ds.n_rows()];
        let gh = logistic::grad_hess_loss(&f, &ds.y, &w);
        let rows: Vec<u32> = (0..ds.n_rows() as u32).collect();
        let params = TreeParams {
            max_leaves: 16,
            feature_rate: 1.0,
            ..Default::default()
        };
        let serial = super::super::build_tree(
            &binned, &rows, &gh.grad, &gh.hess, &params, &mut Rng::new(5),
        );
        for threads in [2usize, 4, 8] {
            for exec in both_modes(threads) {
                let par = build_tree_forkjoin(
                    &binned, &rows, &gh.grad, &gh.hess, &params, &mut Rng::new(5), &exec,
                );
                // identical splits: merge order only changes f64 rounding in the
                // 15th digit; structure and leaf count must match exactly.
                assert_eq!(par.n_leaves(), serial.n_leaves(), "threads={threads}");
                for r in 0..ds.n_rows() {
                    let a = serial.predict_binned(&binned, r);
                    let b = par.predict_binned(&binned, r);
                    assert!((a - b).abs() < 1e-4, "row {r}: {a} vs {b}");
                }
            }
        }
    }

    #[test]
    fn forkjoin_single_thread_is_serial() {
        let ds = synthetic::realsim_like(200, 2);
        let binned = BinnedDataset::from_dataset(&ds, 16).unwrap();
        let f = vec![0.0f32; ds.n_rows()];
        let w = vec![1.0f32; ds.n_rows()];
        let gh = logistic::grad_hess_loss(&f, &ds.y, &w);
        let rows: Vec<u32> = (0..ds.n_rows() as u32).collect();
        let params = TreeParams {
            max_leaves: 8,
            feature_rate: 1.0,
            ..Default::default()
        };
        let a =
            super::super::build_tree(&binned, &rows, &gh.grad, &gh.hess, &params, &mut Rng::new(3));
        let b = build_tree_forkjoin(
            &binned, &rows, &gh.grad, &gh.hess, &params, &mut Rng::new(3), &Executor::scoped(1),
        );
        assert_eq!(a, b);
    }

    #[test]
    fn forkjoin_handles_tiny_leaves() {
        // fewer rows than threads: falls back to serial build per leaf
        let ds = synthetic::realsim_like(10, 3);
        let binned = BinnedDataset::from_dataset(&ds, 16).unwrap();
        let f = vec![0.0f32; 10];
        let w = vec![1.0f32; 10];
        let gh = logistic::grad_hess_loss(&f, &ds.y, &w);
        let rows: Vec<u32> = (0..10).collect();
        for exec in both_modes(8) {
            let t = build_tree_forkjoin(
                &binned, &rows, &gh.grad, &gh.hess,
                &TreeParams { max_leaves: 4, feature_rate: 1.0, ..Default::default() },
                &mut Rng::new(4), &exec,
            );
            t.validate().unwrap();
        }
    }

    #[test]
    fn sharded_histogram_matches_serial_build_on_small_leaves() {
        // the lowered fallback threshold: any leaf with >= threads rows
        // shards; counts must match the serial build exactly and f64 sums
        // to rounding (exactly, for the dyadic f=0 logistic grads)
        let ds = synthetic::realsim_like(64, 21);
        let binned = BinnedDataset::from_dataset(&ds, 16).unwrap();
        let f = vec![0.0f32; ds.n_rows()];
        let w = vec![1.0f32; ds.n_rows()];
        let gh = logistic::grad_hess_loss(&f, &ds.y, &w);
        let rows: Vec<u32> = (0..ds.n_rows() as u32).collect();
        let mut serial = Histogram::zeros(binned.total_bins());
        serial.build(&binned, &rows[..9], &gh.grad, &gh.hess);
        for exec in both_modes(8) {
            // 9 rows on 8 threads: shards of 2 rows, 5 shards — parallel
            // under the new threshold (old: serial below 16 rows)
            let mut sharded = Histogram::zeros(binned.total_bins());
            build_histogram_sharded(&mut sharded, &binned, &rows[..9], &gh.grad, &gh.hess, &exec);
            assert_eq!(sharded.totals, serial.totals, "mode {:?}", exec.mode());
            for s in 0..binned.total_bins() {
                assert_eq!(sharded.count[s], serial.count[s], "slot {s}");
                assert_eq!(sharded.grad[s], serial.grad[s], "slot {s}");
                assert_eq!(sharded.hess[s], serial.hess[s], "slot {s}");
            }
        }
    }

    #[test]
    fn parallel_split_search_matches_serial_exactly() {
        let ds = synthetic::realsim_like(800, 11);
        let binned = BinnedDataset::from_dataset(&ds, 32).unwrap();
        let f = vec![0.0f32; ds.n_rows()];
        let w = vec![1.0f32; ds.n_rows()];
        let gh = logistic::grad_hess_loss(&f, &ds.y, &w);
        let rows: Vec<u32> = (0..ds.n_rows() as u32).collect();
        let mut hist = Histogram::zeros(binned.total_bins());
        hist.build(&binned, &rows, &gh.grad, &gh.hess);
        let mask = vec![true; binned.n_features];
        let cons = SplitConstraints::default();
        let serial = best_split(&hist, &binned, &mask, &cons);
        for threads in [1usize, 2, 4, 8] {
            for exec in both_modes(threads) {
                let par = best_split_parallel(&hist, &binned, &mask, &cons, &exec);
                assert_eq!(par, serial, "threads={threads} mode={:?}", exec.mode());
            }
        }
        // and on a sparse subset (touched-features pruning path)
        let few: Vec<u32> = rows.iter().copied().take(20).collect();
        hist.build(&binned, &few, &gh.grad, &gh.hess);
        let serial = best_split(&hist, &binned, &mask, &cons);
        for exec in both_modes(4) {
            assert_eq!(best_split_parallel(&hist, &binned, &mask, &cons, &exec), serial);
        }
    }

    #[test]
    fn feature_parallel_tree_matches_serial_structure() {
        let ds = synthetic::realsim_like(600, 12);
        let binned = BinnedDataset::from_dataset(&ds, 32).unwrap();
        let f = vec![0.0f32; ds.n_rows()];
        let w = vec![1.0f32; ds.n_rows()];
        let gh = logistic::grad_hess_loss(&f, &ds.y, &w);
        let rows: Vec<u32> = (0..ds.n_rows() as u32).collect();
        let params = TreeParams { max_leaves: 16, feature_rate: 1.0, ..Default::default() };
        let serial = super::super::build_tree(
            &binned, &rows, &gh.grad, &gh.hess, &params, &mut Rng::new(9),
        );
        for threads in [2usize, 4] {
            for exec in both_modes(threads) {
                let mut pool = HistogramPool::new(binned.total_bins());
                let par = build_tree_feature_parallel(
                    &binned, &rows, &gh.grad, &gh.hess, &params, &mut Rng::new(9), &exec,
                    &mut pool,
                );
                assert_eq!(par.n_leaves(), serial.n_leaves(), "threads={threads}");
                for r in 0..ds.n_rows() {
                    let a = serial.predict_binned(&binned, r);
                    let b = par.predict_binned(&binned, r);
                    assert!((a - b).abs() < 1e-4, "row {r}: {a} vs {b}");
                }
            }
        }
    }

    #[test]
    fn feature_parallel_single_thread_is_exactly_serial() {
        let ds = synthetic::realsim_like(300, 13);
        let binned = BinnedDataset::from_dataset(&ds, 16).unwrap();
        let f = vec![0.0f32; ds.n_rows()];
        let w = vec![1.0f32; ds.n_rows()];
        let gh = logistic::grad_hess_loss(&f, &ds.y, &w);
        let rows: Vec<u32> = (0..ds.n_rows() as u32).collect();
        let params = TreeParams {
            max_leaves: 8,
            feature_rate: 1.0,
            ..Default::default()
        };
        let a =
            super::super::build_tree(&binned, &rows, &gh.grad, &gh.hess, &params, &mut Rng::new(6));
        let mut pool = HistogramPool::new(binned.total_bins());
        let b = build_tree_feature_parallel(
            &binned, &rows, &gh.grad, &gh.hess, &params, &mut Rng::new(6), &Executor::scoped(1),
            &mut pool,
        );
        assert_eq!(a, b);
    }

    #[test]
    fn one_persistent_executor_serves_many_tree_builds() {
        // worker-lifetime reuse: the same pool of parked workers builds
        // 30 trees back to back, each bit-identical to its scoped twin
        let ds = synthetic::realsim_like(300, 14);
        let binned = BinnedDataset::from_dataset(&ds, 16).unwrap();
        let f = vec![0.0f32; ds.n_rows()];
        let w = vec![1.0f32; ds.n_rows()];
        let gh = logistic::grad_hess_loss(&f, &ds.y, &w);
        let rows: Vec<u32> = (0..ds.n_rows() as u32).collect();
        let params = TreeParams { max_leaves: 8, feature_rate: 1.0, ..Default::default() };
        let persistent = Executor::new(PoolMode::Persistent, 4);
        let scoped = Executor::scoped(4);
        let mut pool_p = HistogramPool::new(binned.total_bins());
        let mut pool_s = HistogramPool::new(binned.total_bins());
        let mut rng_p = Rng::new(15);
        let mut rng_s = Rng::new(15);
        for tree in 0..30 {
            let a = build_tree_feature_parallel(
                &binned, &rows, &gh.grad, &gh.hess, &params, &mut rng_p, &persistent, &mut pool_p,
            );
            let b = build_tree_feature_parallel(
                &binned, &rows, &gh.grad, &gh.hess, &params, &mut rng_s, &scoped, &mut pool_s,
            );
            assert_eq!(a, b, "tree {tree} diverged across pool modes");
        }
    }
}
