//! The decision-tree model object produced by the builder and shipped
//! worker → server as the PS "delta" message.

use anyhow::{bail, Result};

use crate::data::sparse::CsrMatrix;
use crate::data::BinnedDataset;
use crate::io::Json;

/// A tree node. Splits send `value <= threshold` (raw feature space) left.
/// Implicit zeros of sparse rows evaluate as `0.0 <= threshold`.
#[derive(Debug, Clone, PartialEq)]
pub enum Node {
    /// An internal test node.
    Split {
        /// Feature the split tests.
        feature: u32,
        /// Bin-space split (valid against the training BinnedDataset).
        bin: u8,
        /// Raw-space threshold (valid for any raw feature vector).
        threshold: f32,
        /// Index of the `<=` child.
        left: u32,
        /// Index of the `>` child.
        right: u32,
    },
    /// A terminal prediction node.
    Leaf {
        /// The leaf's predicted margin contribution.
        value: f32,
    },
}

/// A regression tree. Node 0 is the root.
#[derive(Debug, Clone, PartialEq)]
pub struct Tree {
    /// All nodes; child indices point into this vector.
    pub nodes: Vec<Node>,
}

impl Tree {
    /// A single-leaf (constant) tree.
    pub fn constant(value: f32) -> Tree {
        Tree {
            nodes: vec![Node::Leaf { value }],
        }
    }

    /// Number of nodes (splits + leaves).
    pub fn n_nodes(&self) -> usize {
        self.nodes.len()
    }

    /// Number of leaf nodes.
    pub fn n_leaves(&self) -> usize {
        self.nodes
            .iter()
            .filter(|n| matches!(n, Node::Leaf { .. }))
            .count()
    }

    /// Maximum root-to-leaf depth. Iterative (explicit stack), like
    /// [`Tree::validate`], so arbitrarily deep trees — including
    /// adversarial ones loaded through `io/json.rs` — cannot overflow
    /// the call stack.
    pub fn depth(&self) -> usize {
        if self.nodes.is_empty() {
            return 0;
        }
        let mut max = 0usize;
        let mut stack = vec![(0u32, 1usize)];
        while let Some((i, d)) = stack.pop() {
            match &self.nodes[i as usize] {
                Node::Leaf { .. } => max = max.max(d),
                Node::Split { left, right, .. } => {
                    stack.push((*left, d + 1));
                    stack.push((*right, d + 1));
                }
            }
        }
        max
    }

    /// Predict from a binned training row (bin-space traversal — exact
    /// match with how the tree was grown).
    ///
    /// Reference implementation: one root-to-leaf enum walk per row. Hot
    /// batch paths (the server's F-update, `Forest::predict_all*`) go
    /// through the blocked [`super::FlatTree`] scorer instead; this walk
    /// is kept for single-row use, equivalence tests and the
    /// `scoring=perrow` ablation.
    #[inline]
    pub fn predict_binned(&self, binned: &BinnedDataset, row: usize) -> f32 {
        let mut i = 0u32;
        loop {
            match &self.nodes[i as usize] {
                Node::Leaf { value } => return *value,
                Node::Split {
                    feature,
                    bin,
                    left,
                    right,
                    ..
                } => {
                    let b = binned.bin_of(row, *feature);
                    i = if b <= *bin { *left } else { *right };
                }
            }
        }
    }

    /// Node index of the leaf a binned training row reaches — the same
    /// bin-space walk as [`Tree::predict_binned`], returning the leaf's
    /// position instead of its value. The multiclass accept path routes
    /// every row once and then refits K per-class values onto the shared
    /// structure (`ps/server.rs`).
    #[inline]
    pub fn leaf_of_binned(&self, binned: &BinnedDataset, row: usize) -> u32 {
        let mut i = 0u32;
        loop {
            match &self.nodes[i as usize] {
                Node::Leaf { .. } => return i,
                Node::Split {
                    feature,
                    bin,
                    left,
                    right,
                    ..
                } => {
                    let b = binned.bin_of(row, *feature);
                    i = if b <= *bin { *left } else { *right };
                }
            }
        }
    }

    /// Clone this tree's structure with every leaf's value replaced by
    /// `value_of(node_index)` — the multiclass per-class leaf refit
    /// (split nodes are copied verbatim, so the clone routes rows
    /// identically to `self`).
    pub fn with_leaf_values(&self, value_of: &mut dyn FnMut(usize) -> f32) -> Tree {
        let nodes = self
            .nodes
            .iter()
            .enumerate()
            .map(|(i, n)| match n {
                Node::Leaf { .. } => Node::Leaf { value: value_of(i) },
                split => split.clone(),
            })
            .collect();
        Tree { nodes }
    }

    /// Predict from a raw sparse row (threshold-space traversal — used for
    /// held-out data binned with no mapper). Reference implementation;
    /// see [`Tree::predict_binned`] on where the batch paths live.
    pub fn predict_raw(&self, x: &CsrMatrix, row: usize) -> f32 {
        let mut i = 0u32;
        loop {
            match &self.nodes[i as usize] {
                Node::Leaf { value } => return *value,
                Node::Split {
                    feature,
                    threshold,
                    left,
                    right,
                    ..
                } => {
                    let v = x.get(row, *feature);
                    i = if v <= *threshold { *left } else { *right };
                }
            }
        }
    }

    /// Scale all leaf values (used in ensemble post-processing tests).
    pub fn scale(&mut self, k: f32) {
        for n in &mut self.nodes {
            if let Node::Leaf { value } = n {
                *value *= k;
            }
        }
    }

    /// Largest absolute leaf value.
    pub fn max_abs_leaf(&self) -> f32 {
        self.nodes
            .iter()
            .filter_map(|n| match n {
                Node::Leaf { value } => Some(value.abs()),
                _ => None,
            })
            .fold(0.0, f32::max)
    }

    /// Structural validation: every child index in range, exactly one root,
    /// no cycles (checked by reachability), every non-leaf has two children.
    pub fn validate(&self) -> Result<()> {
        if self.nodes.is_empty() {
            bail!("empty tree");
        }
        let n = self.nodes.len();
        let mut seen = vec![false; n];
        let mut stack = vec![0u32];
        let mut visited = 0usize;
        while let Some(i) = stack.pop() {
            let idx = i as usize;
            if idx >= n {
                bail!("child index {idx} out of range {n}");
            }
            if seen[idx] {
                bail!("node {idx} reachable twice (cycle or DAG)");
            }
            seen[idx] = true;
            visited += 1;
            if let Node::Split { left, right, .. } = &self.nodes[idx] {
                stack.push(*left);
                stack.push(*right);
            }
        }
        if visited != n {
            bail!("{} unreachable nodes", n - visited);
        }
        Ok(())
    }

    // ------------------------------------------------------ serialization

    /// JSON representation (model persistence / wire debugging).
    pub fn to_json(&self) -> Json {
        Json::Arr(
            self.nodes
                .iter()
                .map(|n| match n {
                    Node::Leaf { value } => {
                        Json::obj(vec![("leaf", Json::Num(*value as f64))])
                    }
                    Node::Split {
                        feature,
                        bin,
                        threshold,
                        left,
                        right,
                    } => Json::obj(vec![
                        ("feature", Json::Num(*feature as f64)),
                        ("bin", Json::Num(*bin as f64)),
                        ("threshold", Json::Num(*threshold as f64)),
                        ("left", Json::Num(*left as f64)),
                        ("right", Json::Num(*right as f64)),
                    ]),
                })
                .collect(),
        )
    }

    /// Deserialize (and validate) a tree written by `Tree::to_json`.
    ///
    /// Strict: non-numeric leaf values, non-finite thresholds/leaves,
    /// and integer fields that do not fit their on-model width (`bin` is
    /// a u8, `feature`/`left`/`right` are u32) are rejected rather than
    /// defaulted or silently truncated — truncating a child index would
    /// redirect rows to an unrelated subtree and still pass `validate`.
    pub fn from_json(j: &Json) -> Result<Tree> {
        let arr = j.as_arr().ok_or_else(|| anyhow::anyhow!("tree json must be array"))?;
        let int_field = |item: &Json, key: &str, max: usize| -> Result<usize> {
            let v = item.req_usize(key)?;
            if v > max {
                bail!("field '{key}': {v} exceeds the format's maximum {max}");
            }
            Ok(v)
        };
        let mut nodes = Vec::with_capacity(arr.len());
        for (i, item) in arr.iter().enumerate() {
            if let Some(v) = item.get("leaf") {
                let value = v
                    .as_f64()
                    .ok_or_else(|| anyhow::anyhow!("node {i}: 'leaf' is not a number"))?;
                if !value.is_finite() {
                    bail!("node {i}: non-finite leaf value {value}");
                }
                nodes.push(Node::Leaf {
                    value: value as f32,
                });
            } else {
                let threshold = item.req_f64("threshold")?;
                if !threshold.is_finite() {
                    bail!("node {i}: non-finite threshold {threshold}");
                }
                nodes.push(Node::Split {
                    feature: int_field(item, "feature", u32::MAX as usize)? as u32,
                    bin: int_field(item, "bin", u8::MAX as usize)? as u8,
                    threshold: threshold as f32,
                    left: int_field(item, "left", u32::MAX as usize)? as u32,
                    right: int_field(item, "right", u32::MAX as usize)? as u32,
                });
            }
        }
        let t = Tree { nodes };
        t.validate()?;
        Ok(t)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::Dataset;

    fn stump() -> Tree {
        Tree {
            nodes: vec![
                Node::Split {
                    feature: 0,
                    bin: 1,
                    threshold: 2.0,
                    left: 1,
                    right: 2,
                },
                Node::Leaf { value: -1.0 },
                Node::Leaf { value: 1.0 },
            ],
        }
    }

    #[test]
    fn constant_tree() {
        let t = Tree::constant(0.5);
        assert_eq!(t.n_leaves(), 1);
        assert_eq!(t.depth(), 1);
        t.validate().unwrap();
    }

    #[test]
    fn raw_prediction_thresholds() {
        let t = stump();
        let x = CsrMatrix::from_dense(3, 1, &[1.0, 3.0, 0.0]).unwrap();
        assert_eq!(t.predict_raw(&x, 0), -1.0); // 1.0 <= 2.0
        assert_eq!(t.predict_raw(&x, 1), 1.0); // 3.0 > 2.0
        assert_eq!(t.predict_raw(&x, 2), -1.0); // implicit zero <= 2.0
    }

    #[test]
    fn binned_prediction_consistent_with_raw() {
        let x = CsrMatrix::from_dense(4, 1, &[1.0, 3.0, 0.0, 5.0]).unwrap();
        let ds = Dataset::new("t", x.clone(), vec![0.0; 4]);
        let b = BinnedDataset::from_dataset(&ds, 16).unwrap();
        // build a stump in bin space aligned with raw threshold
        let bin = b.mappers[0].bin_of(2.0);
        let t = Tree {
            nodes: vec![
                Node::Split {
                    feature: 0,
                    bin,
                    threshold: b.mappers[0].upper_of(bin),
                    left: 1,
                    right: 2,
                },
                Node::Leaf { value: -1.0 },
                Node::Leaf { value: 1.0 },
            ],
        };
        for r in 0..4 {
            assert_eq!(t.predict_binned(&b, r), t.predict_raw(&x, r), "row {r}");
        }
    }

    #[test]
    fn depth_is_stack_safe_on_adversarially_deep_trees() {
        // a 200k-deep chain (the kind io/json.rs could hand us): depth()
        // and validate() must both run iteratively, not recurse
        let depth = 200_000usize;
        let mut nodes = Vec::with_capacity(2 * depth + 1);
        for i in 0..depth {
            nodes.push(Node::Split {
                feature: 0,
                bin: 0,
                threshold: 0.0,
                left: (2 * i + 1) as u32,
                right: (2 * i + 2) as u32,
            });
            nodes.push(Node::Leaf { value: 0.0 });
        }
        nodes.push(Node::Leaf { value: 1.0 });
        let t = Tree { nodes };
        t.validate().unwrap();
        assert_eq!(t.depth(), depth + 1);
        assert_eq!(t.n_leaves(), depth + 1);
    }

    #[test]
    fn validate_rejects_out_of_range_children() {
        let t = Tree {
            nodes: vec![Node::Split {
                feature: 0,
                bin: 0,
                threshold: 0.0,
                left: 5,
                right: 6,
            }],
        };
        assert!(t.validate().is_err());
    }

    #[test]
    fn validate_rejects_unreachable() {
        let mut t = stump();
        t.nodes.push(Node::Leaf { value: 9.0 }); // orphan
        assert!(t.validate().is_err());
    }

    #[test]
    fn leaf_routing_and_refit_share_the_prediction_walk() {
        let x = CsrMatrix::from_dense(4, 1, &[1.0, 3.0, 0.0, 5.0]).unwrap();
        let ds = Dataset::new("t", x.clone(), vec![0.0; 4]);
        let b = BinnedDataset::from_dataset(&ds, 16).unwrap();
        let bin = b.mappers[0].bin_of(2.0);
        let t = Tree {
            nodes: vec![
                Node::Split {
                    feature: 0,
                    bin,
                    threshold: b.mappers[0].upper_of(bin),
                    left: 1,
                    right: 2,
                },
                Node::Leaf { value: -1.0 },
                Node::Leaf { value: 1.0 },
            ],
        };
        // the routed leaf's value is exactly the prediction
        for r in 0..4 {
            let leaf = t.leaf_of_binned(&b, r) as usize;
            match &t.nodes[leaf] {
                Node::Leaf { value } => assert_eq!(*value, t.predict_binned(&b, r)),
                _ => panic!("leaf_of_binned returned a split"),
            }
        }
        // refit keeps structure, replaces values by node index
        let refit = t.with_leaf_values(&mut |i| i as f32 * 10.0);
        assert_eq!(refit.n_nodes(), t.n_nodes());
        assert_eq!(refit.nodes[1], Node::Leaf { value: 10.0 });
        assert_eq!(refit.nodes[2], Node::Leaf { value: 20.0 });
        for r in 0..4 {
            assert_eq!(refit.leaf_of_binned(&b, r), t.leaf_of_binned(&b, r), "row {r}");
        }
    }

    #[test]
    fn scale_and_max_abs() {
        let mut t = stump();
        t.scale(0.5);
        assert_eq!(t.max_abs_leaf(), 0.5);
    }

    #[test]
    fn json_roundtrip() {
        let t = stump();
        let j = t.to_json();
        let back = Tree::from_json(&j).unwrap();
        assert_eq!(t, back);
    }

    #[test]
    fn from_json_rejects_malformed_nodes() {
        let reject = |src: &str, needle: &str| {
            let err = Tree::from_json(&Json::parse(src).unwrap())
                .unwrap_err()
                .to_string();
            assert!(err.contains(needle), "{src}: {err}");
        };
        // non-numeric leaf used to default to 0.0 silently
        reject(r#"[{"leaf":"oops"}]"#, "not a number");
        reject(r#"[{"leaf":1e400}]"#, "non-finite");
        // NaN/Infinity are not valid JSON, but an Infinity threshold can
        // arrive via overflow literals
        reject(
            r#"[{"feature":0,"bin":0,"threshold":1e400,"left":1,"right":2},{"leaf":1},{"leaf":2}]"#,
            "non-finite threshold",
        );
        // bin wider than u8 / child index wider than u32 must not truncate
        reject(
            r#"[{"feature":0,"bin":700,"threshold":1.0,"left":1,"right":2},{"leaf":1},{"leaf":2}]"#,
            "'bin'",
        );
        reject(
            r#"[{"feature":0,"bin":0,"threshold":1.0,"left":4294967297,"right":2},{"leaf":1},{"leaf":2}]"#,
            "'left'",
        );
        // missing split field
        reject(r#"[{"feature":0,"bin":0,"left":1,"right":2},{"leaf":1},{"leaf":2}]"#, "threshold");
        // out-of-range children (post-parse structural validation)
        reject(
            r#"[{"feature":0,"bin":0,"threshold":1.0,"left":5,"right":6}]"#,
            "out of range",
        );
    }
}
