//! Best-split search over leaf histograms.
//!
//! Gain is the Newton objective improvement used by xgboost/LightGBM:
//!
//! ```text
//! gain = G_L²/(H_L+λ) + G_R²/(H_R+λ) − G²/(H+λ)
//! ```
//!
//! In gradient mode (h_i = w_i) this reduces to weighted-least-squares
//! variance reduction, matching the paper's "gradient step" setting.

use crate::data::BinnedDataset;

use super::histogram::{Histogram, LeafStats};

/// A candidate split of a leaf.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SplitInfo {
    /// Feature the split tests.
    pub feature: u32,
    /// Rows with bin <= `bin` go left (bin is in the feature's local bin
    /// id space, implicit zeros resolved to the feature's zero bin).
    pub bin: u8,
    /// Raw-value threshold equivalent (v <= threshold goes left).
    pub threshold: f32,
    /// Variance-reduction gain of taking the split.
    pub gain: f64,
    /// Aggregate grad/hess/count of the left child.
    pub left: LeafStats,
    /// Aggregate grad/hess/count of the right child.
    pub right: LeafStats,
}

/// Split-search constraints.
#[derive(Debug, Clone, Copy)]
pub struct SplitConstraints {
    /// L2 regularisation on leaf values.
    pub lambda: f64,
    /// Minimum rows per child.
    pub min_leaf_count: u64,
    /// Minimum hessian mass per child.
    pub min_leaf_hess: f64,
    /// Minimum gain for a split to be taken.
    pub min_gain: f64,
}

impl Default for SplitConstraints {
    fn default() -> Self {
        Self {
            lambda: 1.0,
            min_leaf_count: 1,
            min_leaf_hess: 1e-6,
            min_gain: 1e-12,
        }
    }
}

#[inline]
fn leaf_objective(s: &LeafStats, lambda: f64) -> f64 {
    s.grad * s.grad / (s.hess + lambda)
}

/// Leaf output value: the Newton step −G/(H+λ).
#[inline]
pub fn leaf_value(s: &LeafStats, lambda: f64) -> f32 {
    if s.hess + lambda <= 0.0 {
        0.0
    } else {
        (-s.grad / (s.hess + lambda)) as f32
    }
}

/// Scan one feature of a histogram for the best split point.
///
/// Bins are walked in raw-value order; the feature's implicit-zero mass is
/// injected at the zero bin. Returns None if no admissible split exists.
pub fn best_split_for_feature(
    hist: &Histogram,
    binned: &BinnedDataset,
    feat: usize,
    cons: &SplitConstraints,
) -> Option<SplitInfo> {
    let lo = binned.offsets[feat];
    let hi = binned.offsets[feat + 1];
    let n_bins = hi - lo;
    if n_bins < 2 {
        return None;
    }
    let zero_bin = binned.mappers[feat].zero_bin as usize;
    let zero_extra = hist.feature_zero_stats(binned, feat);
    let total = hist.totals;
    let parent_obj = leaf_objective(&total, cons.lambda);

    let mut left = LeafStats::default();
    let mut best: Option<SplitInfo> = None;
    // walk bins 0..n_bins-1 as split points ("<= bin goes left")
    for b in 0..(n_bins - 1) {
        let slot = lo + b;
        left.grad += hist.grad[slot];
        left.hess += hist.hess[slot];
        left.count += hist.count[slot] as u64;
        if b == zero_bin {
            left.grad += zero_extra.grad;
            left.hess += zero_extra.hess;
            left.count += zero_extra.count;
        }
        let right = total.sub(&left);
        if left.count < cons.min_leaf_count || right.count < cons.min_leaf_count {
            continue;
        }
        if left.hess < cons.min_leaf_hess || right.hess < cons.min_leaf_hess {
            continue;
        }
        let gain = leaf_objective(&left, cons.lambda)
            + leaf_objective(&right, cons.lambda)
            - parent_obj;
        if gain > cons.min_gain && best.map_or(true, |s| gain > s.gain) {
            best = Some(SplitInfo {
                feature: feat as u32,
                bin: b as u8,
                threshold: binned.mappers[feat].upper_of(b as u8),
                gain,
                left,
                right,
            });
        }
    }
    best
}

/// Best split across the features enabled in `feature_mask` (the tree's
/// sampled subset). The multi-threaded equivalent with identical results
/// is [`super::parallel::best_split_parallel`].
///
/// Perf: only features with touched slots can split (a feature absent
/// from the leaf's nonzeros has every row in its zero bin). For small
/// leaves we enumerate `hist.touched_features` — O(nnz(leaf)) — instead
/// of walking all features' bins; near the root (touched ≈ everything)
/// the direct walk is cheaper, so we switch on the touched density.
pub fn best_split(
    hist: &Histogram,
    binned: &BinnedDataset,
    feature_mask: &[bool],
    cons: &SplitConstraints,
) -> Option<SplitInfo> {
    let mut best: Option<SplitInfo> = None;
    let consider = |f: usize, best: &mut Option<SplitInfo>| {
        if let Some(s) = best_split_for_feature(hist, binned, f, cons) {
            if best.map_or(true, |b| s.gain > b.gain) {
                *best = Some(s);
            }
        }
    };
    // touched_features costs O(T log T); the direct walk costs
    // O(total_bins). Pick whichever is smaller.
    if hist.touched.len() * 8 < binned.total_bins() {
        for f in hist.touched_features(binned) {
            if feature_mask[f as usize] {
                consider(f as usize, &mut best);
            }
        }
    } else {
        for (f, &enabled) in feature_mask.iter().enumerate() {
            if enabled {
                consider(f, &mut best);
            }
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{BinnedDataset, CsrMatrix, Dataset};

    /// One feature cleanly separating positive-g rows from negative-g rows.
    fn separable() -> (BinnedDataset, Vec<f32>, Vec<f32>) {
        let n = 40;
        let rows: Vec<Vec<(u32, f32)>> = (0..n)
            .map(|i| vec![(0u32, if i < n / 2 { 1.0f32 } else { 5.0 })])
            .collect();
        let x = CsrMatrix::from_rows(1, &rows).unwrap();
        let ds = Dataset::new("t", x, vec![0.0; n]);
        let b = BinnedDataset::from_dataset(&ds, 16).unwrap();
        let grad: Vec<f32> = (0..n).map(|i| if i < n / 2 { -1.0 } else { 1.0 }).collect();
        let hess = vec![1.0f32; n];
        (b, grad, hess)
    }

    #[test]
    fn finds_the_separating_split() {
        let (b, g, h) = separable();
        let rows: Vec<u32> = (0..40).collect();
        let mut hist = Histogram::zeros(b.total_bins());
        hist.build(&b, &rows, &g, &h);
        let cons = SplitConstraints::default();
        let s = best_split(&hist, &b, &[true], &cons).expect("split exists");
        assert_eq!(s.feature, 0);
        assert_eq!(s.left.count, 20);
        assert_eq!(s.right.count, 20);
        assert!(s.gain > 0.0);
        // threshold separates 1.0 from 5.0
        assert!(s.threshold >= 1.0 && s.threshold < 5.0);
        // leaf values pull opposite directions
        assert!(leaf_value(&s.left, cons.lambda) > 0.0);
        assert!(leaf_value(&s.right, cons.lambda) < 0.0);
    }

    #[test]
    fn no_split_when_gradient_uniform() {
        let (b, _, h) = separable();
        let g = vec![1.0f32; 40];
        let rows: Vec<u32> = (0..40).collect();
        let mut hist = Histogram::zeros(b.total_bins());
        hist.build(&b, &rows, &g, &h);
        let s = best_split(&hist, &b, &[true], &SplitConstraints::default());
        // gain is ~0 everywhere; min_gain filters it out
        assert!(s.is_none() || s.unwrap().gain < 1e-9);
    }

    #[test]
    fn min_leaf_count_blocks_unbalanced_splits() {
        let (b, g, h) = separable();
        let rows: Vec<u32> = (0..40).collect();
        let mut hist = Histogram::zeros(b.total_bins());
        hist.build(&b, &rows, &g, &h);
        let cons = SplitConstraints {
            min_leaf_count: 25, // each side would need 25 of 40
            ..Default::default()
        };
        assert!(best_split(&hist, &b, &[true], &cons).is_none());
    }

    #[test]
    fn implicit_zero_rows_participate() {
        // feature 0: rows 0..10 have implicit zero, rows 10..20 have 2.0;
        // gradient splits exactly along that boundary.
        let rows: Vec<Vec<(u32, f32)>> = (0..20)
            .map(|i| if i < 10 { vec![] } else { vec![(0u32, 2.0f32)] })
            .collect();
        let x = CsrMatrix::from_rows(1, &rows).unwrap();
        let ds = Dataset::new("t", x, vec![0.0; 20]);
        let b = BinnedDataset::from_dataset(&ds, 16).unwrap();
        let g: Vec<f32> = (0..20).map(|i| if i < 10 { -1.0 } else { 1.0 }).collect();
        let h = vec![1.0f32; 20];
        let all: Vec<u32> = (0..20).collect();
        let mut hist = Histogram::zeros(b.total_bins());
        hist.build(&b, &all, &g, &h);
        let s = best_split(&hist, &b, &[true], &SplitConstraints::default())
            .expect("split exists");
        assert_eq!(s.left.count, 10);
        assert_eq!(s.right.count, 10);
        // zero rows go left: threshold >= 0 and < 2
        assert!(s.threshold >= 0.0 && s.threshold < 2.0);
    }

    #[test]
    fn leaf_value_is_newton_step() {
        let s = LeafStats { grad: -6.0, hess: 2.0, count: 4 };
        assert!((leaf_value(&s, 1.0) - 2.0).abs() < 1e-6);
        let z = LeafStats::default();
        assert_eq!(leaf_value(&z, 0.0), 0.0);
    }

    #[test]
    fn lambda_shrinks_values_and_gains() {
        let (b, g, h) = separable();
        let rows: Vec<u32> = (0..40).collect();
        let mut hist = Histogram::zeros(b.total_bins());
        hist.build(&b, &rows, &g, &h);
        let small = SplitConstraints {
            lambda: 0.01,
            ..Default::default()
        };
        let large = SplitConstraints {
            lambda: 100.0,
            ..Default::default()
        };
        let s_small = best_split(&hist, &b, &[true], &small).unwrap();
        let s_large = best_split(&hist, &b, &[true], &large).unwrap();
        assert!(s_small.gain > s_large.gain);
    }
}
