//! Leaf-wise (best-first) tree growth — the LightGBM-style learner the
//! paper reuses as its "building the tree" sub-step (all trainers —
//! async, sync, serial — share this code, which mirrors the paper's setup
//! where asynch-SGBDT and the LightGBM baseline share the treelearner).
//!
//! Hot-path structure: after each split, child histograms are produced
//! per [`HistogramStrategy`] — by default only the **smaller** child is
//! built from its rows and the larger is derived by sibling subtraction
//! (`large = parent − small`), the single biggest histogram-cost lever in
//! GBDT engines. All buffers come from a caller-owned [`HistogramPool`]
//! ([`build_tree_pooled`]) so steady-state training allocates nothing per
//! node; both histogram building and split search are pluggable, which is
//! how [`super::parallel`] injects row-sharded building and per-feature
//! work-stealing split search.

use crate::data::BinnedDataset;
use crate::util::Rng;

use super::histogram::{Histogram, HistogramPool, HistogramStrategy};
use super::split::{best_split, leaf_value, SplitConstraints, SplitInfo};
use super::tree::{Node, Tree};

/// Tree-growth hyperparameters (paper defaults: 100–400 leaves, 80%
/// feature sampling).
#[derive(Debug, Clone, Copy)]
pub struct TreeParams {
    /// Leaf-count cap (leaf-wise growth stops here).
    pub max_leaves: usize,
    /// 0 = unlimited.
    pub max_depth: usize,
    /// Minimum rows per leaf.
    pub min_leaf_count: u64,
    /// Minimum hessian mass per leaf.
    pub min_leaf_hess: f64,
    /// L2 regularisation on leaf values.
    pub lambda: f64,
    /// Minimum gain for a split to be taken.
    pub min_gain: f64,
    /// Fraction of features considered per tree (paper: 0.8).
    pub feature_rate: f64,
    /// How child histograms are produced after a split (default:
    /// sibling subtraction; `Rebuild` is the ablation baseline).
    pub strategy: HistogramStrategy,
}

impl Default for TreeParams {
    fn default() -> Self {
        Self {
            max_leaves: 100,
            max_depth: 0,
            min_leaf_count: 1,
            min_leaf_hess: 1e-6,
            lambda: 1.0,
            min_gain: 1e-12,
            feature_rate: 0.8,
            strategy: HistogramStrategy::Subtract,
        }
    }
}

impl TreeParams {
    fn constraints(&self) -> SplitConstraints {
        SplitConstraints {
            lambda: self.lambda,
            min_leaf_count: self.min_leaf_count,
            min_leaf_hess: self.min_leaf_hess,
            min_gain: self.min_gain,
        }
    }
}

/// A growable leaf during construction.
struct LeafState {
    /// Range into the shared row-index arena.
    begin: usize,
    end: usize,
    hist: Histogram,
    best: Option<SplitInfo>,
    depth: usize,
    /// Index of this leaf's placeholder node in the output tree.
    node_idx: usize,
}

/// Build one regression tree fitting the targets (`grad`, `hess` indexed by
/// global row id) over the sampled `rows`.
///
/// Returns a constant-zero tree when `rows` is empty (the degenerate
/// sampling pass the paper's extreme-small-rate experiment can produce).
///
/// Allocates a transient [`HistogramPool`] per call; long-running callers
/// (worker loops, trainers) should hold a pool across trees and use
/// [`build_tree_pooled`] instead.
pub fn build_tree(
    binned: &BinnedDataset,
    rows: &[u32],
    grad: &[f32],
    hess: &[f32],
    params: &TreeParams,
    rng: &mut Rng,
) -> Tree {
    let mut pool = HistogramPool::new(binned.total_bins());
    build_tree_pooled(binned, rows, grad, hess, params, rng, &mut pool)
}

/// Like [`build_tree`], but recycling histogram buffers through a
/// caller-owned pool. The pool must have been created with this dataset's
/// `total_bins()`; every buffer taken during the build is returned before
/// this function does, so the same pool can serve every tree a worker
/// ever builds (see the [`HistogramPool`] contract).
pub fn build_tree_pooled(
    binned: &BinnedDataset,
    rows: &[u32],
    grad: &[f32],
    hess: &[f32],
    params: &TreeParams,
    rng: &mut Rng,
    pool: &mut HistogramPool,
) -> Tree {
    grow_tree(
        binned,
        rows,
        grad,
        hess,
        params,
        rng,
        pool,
        &mut |hist, leaf_rows| hist.build(binned, leaf_rows, grad, hess),
        &|hist, mask, cons| best_split(hist, binned, mask, cons),
    )
}

/// Tree growth with pluggable histogram construction and split search —
/// the hooks through which [`super::parallel`] injects row-sharded
/// parallel histogram building and per-feature work-stealing split
/// search. `hist_build` fills a (dirty) histogram from a row set;
/// `split_search` scans a histogram for the best admissible split.
#[allow(clippy::too_many_arguments)]
pub(crate) fn grow_tree(
    binned: &BinnedDataset,
    rows: &[u32],
    grad: &[f32],
    hess: &[f32],
    params: &TreeParams,
    rng: &mut Rng,
    pool: &mut HistogramPool,
    hist_build: &mut dyn FnMut(&mut Histogram, &[u32]),
    split_search: &dyn Fn(&Histogram, &[bool], &SplitConstraints) -> Option<SplitInfo>,
) -> Tree {
    let _ = (grad, hess); // flowed through `hist_build`
    if rows.is_empty() {
        return Tree::constant(0.0);
    }
    let cons = params.constraints();

    // feature subset for this tree (paper: random 80%), as a mask so the
    // split search can intersect it with the leaf's touched features
    let n_feat = binned.n_features;
    let k = ((n_feat as f64) * params.feature_rate).ceil().max(1.0) as usize;
    let mut feature_mask = vec![false; n_feat];
    if k >= n_feat {
        feature_mask.fill(true);
    } else {
        for i in rng.sample_indices(n_feat, k) {
            feature_mask[i] = true;
        }
    }

    // shared arena of row ids, partitioned per leaf
    let mut arena: Vec<u32> = rows.to_vec();
    let arena_len = arena.len();

    let mut tree_nodes: Vec<Node> = Vec::new();
    let mut leaves: Vec<LeafState> = Vec::new();

    // root
    let mut root_hist = pool.take();
    hist_build(&mut root_hist, &arena);
    let root_best = split_search(&root_hist, &feature_mask, &cons);
    tree_nodes.push(Node::Leaf {
        value: leaf_value(&root_hist.totals, cons.lambda),
    });
    leaves.push(LeafState {
        begin: 0,
        end: arena_len,
        hist: root_hist,
        best: root_best,
        depth: 1,
        node_idx: 0,
    });

    let mut n_leaves = 1usize;
    while n_leaves < params.max_leaves {
        // pick the splittable leaf with the highest gain
        let Some(li) = leaves
            .iter()
            .enumerate()
            .filter(|(_, l)| l.best.is_some())
            .max_by(|a, b| {
                let ga = a.1.best.unwrap().gain;
                let gb = b.1.best.unwrap().gain;
                ga.partial_cmp(&gb).unwrap()
            })
            .map(|(i, _)| i)
        else {
            break; // nothing splittable
        };
        let leaf = leaves.swap_remove(li);
        let split = leaf.best.unwrap();

        // partition the leaf's arena segment: bin <= split.bin goes left
        let seg = &mut arena[leaf.begin..leaf.end];
        let mid = partition_rows(seg, binned, split.feature, split.bin);
        let (lb, le) = (leaf.begin, leaf.begin + mid);
        let (rb, re) = (leaf.begin + mid, leaf.end);
        debug_assert_eq!((le - lb) as u64, split.left.count, "partition/left mismatch");
        debug_assert_eq!((re - rb) as u64, split.right.count, "partition/right mismatch");

        // child histograms per strategy: subtraction builds only the
        // smaller child and derives the larger as parent − small; rebuild
        // (the ablation baseline) builds both from their rows
        let (left_hist, right_hist) = match params.strategy {
            HistogramStrategy::Subtract => {
                let left_smaller = (le - lb) <= (re - rb);
                let (sb, se) = if left_smaller { (lb, le) } else { (rb, re) };
                let mut small_hist = pool.take();
                hist_build(&mut small_hist, &arena[sb..se]);
                let mut big_hist = pool.take();
                big_hist.subtract_from(&leaf.hist, &small_hist);
                pool.give(leaf.hist);
                if left_smaller {
                    (small_hist, big_hist)
                } else {
                    (big_hist, small_hist)
                }
            }
            HistogramStrategy::Rebuild => {
                let mut left_hist = pool.take();
                hist_build(&mut left_hist, &arena[lb..le]);
                let mut right_hist = pool.take();
                hist_build(&mut right_hist, &arena[rb..re]);
                pool.give(leaf.hist);
                (left_hist, right_hist)
            }
        };

        // emit children; parent placeholder becomes a split node
        let left_idx = tree_nodes.len();
        tree_nodes.push(Node::Leaf {
            value: leaf_value(&split.left, cons.lambda),
        });
        let right_idx = tree_nodes.len();
        tree_nodes.push(Node::Leaf {
            value: leaf_value(&split.right, cons.lambda),
        });
        tree_nodes[leaf.node_idx] = Node::Split {
            feature: split.feature,
            bin: split.bin,
            threshold: split.threshold,
            left: left_idx as u32,
            right: right_idx as u32,
        };

        let child_depth = leaf.depth + 1;
        let depth_ok = params.max_depth == 0 || child_depth < params.max_depth + 1;
        for (begin, end, hist, node_idx) in [
            (lb, le, left_hist, left_idx),
            (rb, re, right_hist, right_idx),
        ] {
            let can_split = depth_ok && (end - begin) >= 2;
            let best = if can_split {
                split_search(&hist, &feature_mask, &cons)
            } else {
                None
            };
            leaves.push(LeafState {
                begin,
                end,
                hist,
                best,
                depth: child_depth,
                node_idx,
            });
        }
        n_leaves += 1;
    }

    // recycle every remaining leaf buffer: the pool's steady state across
    // trees is bounded by max_leaves + 2, so cross-tree callers never
    // allocate again after the first tree
    for leaf in leaves {
        pool.give(leaf.hist);
    }

    let tree = Tree { nodes: tree_nodes };
    debug_assert!(tree.validate().is_ok());
    tree
}

/// Stable in-place partition of row ids by the split predicate; returns the
/// number of rows going left.
fn partition_rows(seg: &mut [u32], binned: &BinnedDataset, feature: u32, bin: u8) -> usize {
    // in-place two-pointer partition (order within sides irrelevant for
    // histogram building)
    let mut i = 0usize;
    let mut j = seg.len();
    while i < j {
        if binned.bin_of(seg[i] as usize, feature) <= bin {
            i += 1;
        } else {
            j -= 1;
            seg.swap(i, j);
        }
    }
    i
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{CsrMatrix, Dataset};
    use crate::loss::logistic;

    /// Four clusters over two features, labels `y = a AND NOT b` — needs a
    /// depth-2 tree but is greedily splittable (unlike exact XOR, whose
    /// root gain is identically zero).
    fn xor_data(n: usize) -> (Dataset, BinnedDataset) {
        let mut rows = Vec::with_capacity(n);
        let mut y = Vec::with_capacity(n);
        for i in 0..n {
            let a = (i / 2) % 2;
            let b = i % 2;
            rows.push(vec![(0u32, a as f32 * 2.0 + 1.0), (1u32, b as f32 * 2.0 + 1.0)]);
            y.push(if a == 1 && b == 0 { 1.0 } else { 0.0 });
        }
        let x = CsrMatrix::from_rows(2, &rows).unwrap();
        let ds = Dataset::new("xor", x, y);
        let b = BinnedDataset::from_dataset(&ds, 16).unwrap();
        (ds, b)
    }

    fn grad_for(ds: &Dataset, f: &[f32]) -> (Vec<f32>, Vec<f32>) {
        let w = vec![1.0f32; ds.n_rows()];
        let gh = logistic::grad_hess_loss(f, &ds.y, &w);
        (gh.grad, gh.hess)
    }

    #[test]
    fn learns_xor_with_four_leaves() {
        let (ds, b) = xor_data(200);
        let f0 = vec![0.0f32; ds.n_rows()];
        let (g, h) = grad_for(&ds, &f0);
        let rows: Vec<u32> = (0..ds.n_rows() as u32).collect();
        let params = TreeParams {
            max_leaves: 4,
            feature_rate: 1.0,
            ..Default::default()
        };
        let mut rng = Rng::new(1);
        let t = build_tree(&b, &rows, &g, &h, &params, &mut rng);
        t.validate().unwrap();
        assert!(t.n_leaves() >= 3 && t.n_leaves() <= 4, "leaves={}", t.n_leaves());
        // every row must move towards its label
        for r in 0..ds.n_rows() {
            let p = t.predict_binned(&b, r);
            if ds.y[r] > 0.5 {
                assert!(p > 0.0, "row {r} pred {p}");
            } else {
                assert!(p < 0.0, "row {r} pred {p}");
            }
        }
    }

    #[test]
    fn respects_max_leaves() {
        let (ds, b) = xor_data(300);
        let (g, h) = grad_for(&ds, &vec![0.0; ds.n_rows()]);
        let rows: Vec<u32> = (0..ds.n_rows() as u32).collect();
        for max_leaves in [1usize, 2, 3] {
            let params = TreeParams {
                max_leaves,
                feature_rate: 1.0,
                ..Default::default()
            };
            let mut rng = Rng::new(2);
            let t = build_tree(&b, &rows, &g, &h, &params, &mut rng);
            assert!(t.n_leaves() <= max_leaves.max(1));
        }
    }

    #[test]
    fn respects_max_depth() {
        let (ds, b) = xor_data(300);
        let (g, h) = grad_for(&ds, &vec![0.0; ds.n_rows()]);
        let rows: Vec<u32> = (0..ds.n_rows() as u32).collect();
        let params = TreeParams {
            max_leaves: 64,
            max_depth: 2,
            feature_rate: 1.0,
            ..Default::default()
        };
        let mut rng = Rng::new(3);
        let t = build_tree(&b, &rows, &g, &h, &params, &mut rng);
        assert!(t.depth() <= 3); // depth counts nodes on path; 2 splits max
    }

    #[test]
    fn empty_rows_give_constant_tree() {
        let (_, b) = xor_data(10);
        let mut rng = Rng::new(4);
        let t = build_tree(&b, &[], &[], &[], &TreeParams::default(), &mut rng);
        assert_eq!(t.n_leaves(), 1);
        assert_eq!(t.predict_raw(&CsrMatrix::from_dense(1, 2, &[0.0, 0.0]).unwrap(), 0), 0.0);
    }

    #[test]
    fn subset_rows_build_on_subset_only() {
        let (ds, b) = xor_data(100);
        let (g, h) = grad_for(&ds, &vec![0.0; ds.n_rows()]);
        // only cluster (0,0) and (1,1): tree trained on those rows
        let rows: Vec<u32> = (0..100u32).filter(|&r| {
            let a = (r / 2) % 2;
            let bb = r % 2;
            a == bb
        }).collect();
        let params = TreeParams { max_leaves: 4, feature_rate: 1.0, ..Default::default() };
        let mut rng = Rng::new(5);
        let t = build_tree(&b, &rows, &g, &h, &params, &mut rng);
        // rows in the subset must be pushed in the right direction
        for &r in &rows {
            let p = t.predict_binned(&b, r as usize);
            if ds.y[r as usize] > 0.5 {
                assert!(p > 0.0);
            } else {
                assert!(p < 0.0);
            }
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let (ds, b) = xor_data(128);
        let (g, h) = grad_for(&ds, &vec![0.0; ds.n_rows()]);
        let rows: Vec<u32> = (0..ds.n_rows() as u32).collect();
        let params = TreeParams { max_leaves: 8, feature_rate: 0.5, ..Default::default() };
        let t1 = build_tree(&b, &rows, &g, &h, &params, &mut Rng::new(7));
        let t2 = build_tree(&b, &rows, &g, &h, &params, &mut Rng::new(7));
        assert_eq!(t1, t2);
    }

    #[test]
    fn rebuild_strategy_matches_subtract_strategy() {
        // logistic grads at f=0 are dyadic rationals (±1.0, hess 1.0), so
        // both strategies' f64 sums are exact and the trees are identical
        let (ds, b) = xor_data(240);
        let (g, h) = grad_for(&ds, &vec![0.0; ds.n_rows()]);
        let rows: Vec<u32> = (0..ds.n_rows() as u32).collect();
        let sub = TreeParams { max_leaves: 8, feature_rate: 1.0, ..Default::default() };
        let reb = TreeParams { strategy: HistogramStrategy::Rebuild, ..sub };
        let t_sub = build_tree(&b, &rows, &g, &h, &sub, &mut Rng::new(11));
        let t_reb = build_tree(&b, &rows, &g, &h, &reb, &mut Rng::new(11));
        assert_eq!(t_sub, t_reb);
    }

    #[test]
    fn pooled_build_recycles_buffers_across_trees() {
        let (ds, b) = xor_data(160);
        let (g, h) = grad_for(&ds, &vec![0.0; ds.n_rows()]);
        let rows: Vec<u32> = (0..ds.n_rows() as u32).collect();
        let params = TreeParams { max_leaves: 4, feature_rate: 1.0, ..Default::default() };
        let mut pool = HistogramPool::new(b.total_bins());
        let mut rng = Rng::new(12);
        for _ in 0..4 {
            build_tree_pooled(&b, &rows, &g, &h, &params, &mut rng, &mut pool);
        }
        // peak concurrent buffers: live leaves + parent + in-flight child
        assert!(
            pool.allocated() <= params.max_leaves + 2,
            "pool allocated {} buffers for 4 trees of {} leaves",
            pool.allocated(),
            params.max_leaves
        );
    }

    #[test]
    fn weighted_rows_shift_leaf_values() {
        // two rows, same features: leaf value is the weighted Newton step
        let x = CsrMatrix::from_dense(2, 1, &[1.0, 1.0]).unwrap();
        let ds = Dataset::new("w", x, vec![1.0, 0.0]);
        let b = BinnedDataset::from_dataset(&ds, 4).unwrap();
        let g = vec![-2.0f32, 1.0];
        let h = vec![1.0f32, 1.0];
        let params = TreeParams {
            max_leaves: 4,
            feature_rate: 1.0,
            lambda: 0.0,
            ..Default::default()
        };
        let mut rng = Rng::new(8);
        let t = build_tree(&b, &[0, 1], &g, &h, &params, &mut rng);
        // unsplittable (identical feature) -> single leaf = -(sum g)/(sum h)
        assert_eq!(t.n_leaves(), 1);
        let v = t.predict_binned(&b, 0);
        assert!((v - 0.5).abs() < 1e-6, "v={v}");
    }
}
