//! Typed configuration for trainers, experiments and the CLI.
//!
//! Configs load from JSON files (`--config path.json`) with CLI `key=value`
//! overrides on top; `validate()` centralises the cross-field checks every
//! entrypoint relies on.

use std::path::{Path, PathBuf};

use anyhow::{bail, Result};

use crate::forest::ScoreMode;
use crate::io::Json;
use crate::loss::{LossKind, ScalarLoss};
use crate::ps::TargetMode;
use crate::tree::{HistogramStrategy, TreeParams};
use crate::util::fault::{FaultPlan, FaultSpec};
use crate::util::PoolMode;

/// Which trainer drives the run (config key `mode`).
///
/// ```
/// use asgbdt::config::TrainMode;
/// assert_eq!(TrainMode::parse("async").unwrap(), TrainMode::Async);
/// assert_eq!(TrainMode::Sync.as_str(), "sync");
/// assert!(TrainMode::parse("quantum").is_err());
/// ```
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TrainMode {
    /// Asynch-SGBDT on the parameter server (the paper's contribution).
    Async,
    /// Fork-join synchronous baseline (LightGBM-style epochs; identical
    /// convergence to serial, simulated cluster wall-clock).
    Sync,
    /// Strictly serial reference.
    Serial,
    /// No trainer at all: load a saved forest (`serve_model`) and run
    /// the batched prediction service (`serve/`, DESIGN.md §15).
    Serve,
}

impl TrainMode {
    /// Parse the `mode=` config/CLI value.
    pub fn parse(s: &str) -> Result<TrainMode> {
        match s {
            "async" => Ok(TrainMode::Async),
            "sync" => Ok(TrainMode::Sync),
            "serial" => Ok(TrainMode::Serial),
            "serve" => Ok(TrainMode::Serve),
            other => bail!("unknown mode '{other}' (async|sync|serial|serve)"),
        }
    }

    /// The config/CLI spelling of this mode.
    pub fn as_str(&self) -> &'static str {
        match self {
            TrainMode::Async => "async",
            TrainMode::Sync => "sync",
            TrainMode::Serial => "serial",
            TrainMode::Serve => "serve",
        }
    }
}

/// How the tree target is formed from the loss derivatives (config key
/// `grad_mode`).
///
/// ```
/// use asgbdt::config::GradMode;
/// assert_eq!(GradMode::parse("newton").unwrap(), GradMode::Newton);
/// assert_eq!(GradMode::Gradient.as_str(), "gradient");
/// ```
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum GradMode {
    /// Paper setting ("we use gradient step in LightGBM boosting"): trees
    /// fit the weighted-mean negative gradient (h_i := w_i).
    Gradient,
    /// Newton step: h_i = w_i * l''(y_i, F_i) (xgboost-style).
    Newton,
}

impl GradMode {
    /// Parse the `grad_mode=` config/CLI value.
    pub fn parse(s: &str) -> Result<GradMode> {
        match s {
            "gradient" => Ok(GradMode::Gradient),
            "newton" => Ok(GradMode::Newton),
            other => bail!("unknown grad mode '{other}' (gradient|newton)"),
        }
    }

    /// The config/CLI spelling of this mode.
    pub fn as_str(&self) -> &'static str {
        match self {
            GradMode::Gradient => "gradient",
            GradMode::Newton => "newton",
        }
    }
}

/// Format of the model file `asgbdt train --model` writes (config key
/// `format`).
///
/// ```
/// use asgbdt::config::ModelFormat;
/// assert_eq!(ModelFormat::parse("sgbdt").unwrap(), ModelFormat::Sgbdt);
/// assert_eq!(ModelFormat::Json.as_str(), "json");
/// assert!(ModelFormat::parse("pickle").is_err());
/// ```
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ModelFormat {
    /// The versioned, checksummed `.sgbdt` artifact (`io/artifact.rs`,
    /// DESIGN.md §16) — the default.
    Sgbdt,
    /// The legacy schema-free JSON dump (`Forest::save`), kept for one
    /// release for downstream tooling still parsing it.
    Json,
}

impl ModelFormat {
    /// Parse the `format=` config/CLI value.
    pub fn parse(s: &str) -> Result<ModelFormat> {
        match s {
            "sgbdt" => Ok(ModelFormat::Sgbdt),
            "json" => Ok(ModelFormat::Json),
            other => bail!("unknown model format '{other}' (sgbdt|json)"),
        }
    }

    /// The config/CLI spelling of this format.
    pub fn as_str(&self) -> &'static str {
        match self {
            ModelFormat::Sgbdt => "sgbdt",
            ModelFormat::Json => "json",
        }
    }
}

/// How the step length responds to observed staleness (config key
/// `step`).
///
/// ```
/// use asgbdt::config::StepMode;
/// assert_eq!(StepMode::parse("adaptive").unwrap(), StepMode::Adaptive);
/// assert_eq!(StepMode::Fixed.as_str(), "fixed");
/// assert!(StepMode::parse("warmup").is_err());
/// // τ = 0 is exactly the fixed step — adaptive degrades to fixed on a
/// // fresh push (v / 1.0 is bit-identical to v in IEEE-754)
/// assert_eq!(StepMode::Adaptive.effective(0.3, 0), 0.3);
/// assert_eq!(StepMode::Adaptive.effective(0.3, 2), 0.1);
/// ```
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StepMode {
    /// Every accepted push applies the configured `step_length` v — the
    /// paper's setting.
    Fixed,
    /// Each accepted push applies v / (1 + τ), where τ is that push's
    /// recorded staleness — the Proposition 1 damping rule (DESIGN.md
    /// §17). A pure per-push function of τ, so replaying a τ trace
    /// reproduces the run bit-for-bit.
    Adaptive,
}

impl StepMode {
    /// Parse the `step=` config/CLI value.
    pub fn parse(s: &str) -> Result<StepMode> {
        match s {
            "fixed" => Ok(StepMode::Fixed),
            "adaptive" => Ok(StepMode::Adaptive),
            other => bail!("unknown step mode '{other}' (fixed|adaptive)"),
        }
    }

    /// The config/CLI spelling of this mode.
    pub fn as_str(&self) -> &'static str {
        match self {
            StepMode::Fixed => "fixed",
            StepMode::Adaptive => "adaptive",
        }
    }

    /// The effective step length for one accepted push of staleness
    /// `tau`: `v` under `fixed`, `v / (1 + τ)` under `adaptive`. At
    /// τ = 0 the two are bit-identical (IEEE division by exactly 1.0).
    #[inline]
    pub fn effective(self, v: f32, tau: u64) -> f32 {
        match self {
            StepMode::Fixed => v,
            StepMode::Adaptive => v / (1.0 + tau as f32),
        }
    }
}

impl Default for StepMode {
    fn default() -> Self {
        StepMode::Fixed
    }
}

/// Full training configuration (paper defaults baked in: 400 trees,
/// v = 0.01, sampling rate 0.8, feature rate 0.8, 100 leaves).
#[derive(Debug, Clone)]
pub struct TrainConfig {
    /// Which trainer drives the run (async / sync / serial).
    pub mode: TrainMode,
    /// Which objective the run trains (config key `loss`): the paper's
    /// binary `logistic` (default), `squared`/`huber` regression, or
    /// `multiclass` softmax over `n_classes` margin vectors.
    pub loss: LossKind,
    /// Gradient-step (paper) vs Newton-step tree targets.
    pub grad_mode: GradMode,
    /// Total trees the server accepts before stopping (paper: 400/1000).
    /// Under `loss=multiclass` this counts boosting *rounds*; each round
    /// pushes `n_classes` structure-sharing trees into the forest.
    pub n_trees: usize,
    /// Step length v (paper: 0.01).
    pub step_length: f32,
    /// Fixed v per push (default) vs the staleness-adaptive
    /// v / (1 + τ) damping rule (config key `step`; DESIGN.md §17).
    pub step: StepMode,
    /// Huber transition width δ (config key `huber_delta`); only read
    /// under `loss=huber`, and `validate` rejects a non-default value
    /// with any other loss rather than silently ignoring it.
    pub huber_delta: f64,
    /// Number of classes K under `loss=multiclass` (labels are integer
    /// class ids `0..K`). 2 (the default) means "binary" and belongs to
    /// the scalar losses; `loss=multiclass` requires K ≥ 3.
    pub n_classes: usize,
    /// Uniform Bernoulli sampling rate R (paper: 0.2–0.8; extreme 5e-6).
    pub sampling_rate: f64,
    /// Number of asynchronous workers (threads, as in the paper's
    /// validity experiments).
    pub workers: usize,
    /// Optional bounded staleness: drop pushes staler than this (None =
    /// unbounded, the paper's setting).
    pub max_staleness: Option<u64>,
    /// Histogram bins per feature.
    pub max_bins: usize,
    /// Tree-construction parameters (leaves, depth, regularisation...).
    pub tree: TreeParams,
    /// Evaluate train/test loss every k accepted trees.
    pub eval_every: usize,
    /// The server's accept pipeline per accepted tree: one fused
    /// row-sharded pass (default) or the serial reference path with
    /// separate sweeps for scoring/sampling/target/eval. Bit-identical
    /// outputs either way (`ps/shard.rs`).
    pub target: TargetMode,
    /// Scoring engine for the serial path's F-update (Algorithm 3 step
    /// 2): blocked SoA (default) or the per-row enum reference path.
    /// The fused pipeline always scores through the blocked engine, so
    /// `scoring=perrow` requires `target=serial`.
    pub scoring: ScoreMode,
    /// Threads sharding the accept pass (fused) / the blocked F-update
    /// (serial). 1 (default) keeps the accept path on the server thread;
    /// raise it when the server, not the workers, is the bottleneck.
    pub score_threads: usize,
    /// Server shards the parameter state is row-partitioned across
    /// (`ps/sharded.rs`): each shard owns a contiguous whole-block slice
    /// of F/weights/grad/hess and publishes its own version; the board
    /// snapshot composes the per-shard versions. 1 (default) is the
    /// single-`ServerCore` path, bit-identical to every prior release;
    /// larger counts are bit-identical by construction (same whole-block
    /// carving as the fused pass) and exist to remove the single-server
    /// serialization point. See DESIGN.md §13.
    pub ps_shards: usize,
    /// Threads each tree build may use for its intra-tree fork-join
    /// sections (sharded leaf histograms + work-stealing split search).
    /// 1 (default) builds exactly the serial learner; raise it when
    /// individual trees, not boosting throughput, are the bottleneck
    /// (deep trees, wide features, few workers). Every build loop — each
    /// async worker, the serial trainer — owns one executor of this many
    /// threads. The sync baseline's fork-join width is its `workers`
    /// count, so `mode=sync` with `build_threads>1` is rejected by
    /// `validate` rather than silently ignored. See DESIGN.md §12.
    pub build_threads: usize,
    /// Where parallel-section threads come from — the server's
    /// `score_threads` scoring executor *and* every `build_threads`
    /// build executor: a lifetime-scoped pool of parked workers
    /// (`persistent`, default — per-section dispatch is a condvar wake)
    /// or per-section scoped spawns (`scoped`, the bit-identical
    /// reference). See DESIGN.md §11–12.
    pub pool: PoolMode,
    /// Base seed for every deterministic stream (sampling pass keys,
    /// feature sub-sampling, synthetic data).
    pub seed: u64,
    /// Arms the deterministic fault-injection layer (DESIGN.md §14).
    /// `None` (default) means **no fault-layer code runs**: no
    /// [`crate::util::FaultPlan`] is built, workers take the bare
    /// unharnessed path, and the default config is byte-identical to
    /// every prior release. `Some(seed)` keys every injected
    /// drop/duplicate/delay/panic as a pure function of
    /// `(seed, site, attempt)`, so chaos runs replay exactly.
    pub fault_seed: Option<u64>,
    /// Probability an armed plan drops a message per send attempt
    /// (senders retry under bounded backoff; see `ps/faulty.rs`).
    pub fault_drop_rate: f64,
    /// Probability an armed plan duplicates a delivered message.
    pub fault_dup_rate: f64,
    /// Probability an armed plan delays a delivery (bounded latency).
    pub fault_delay_rate: f64,
    /// Probability an armed plan panics a worker at a build cycle.
    pub fault_panic_rate: f64,
    /// Restarts the supervisor grants each async worker after a panic
    /// (injected or real). Each restart gets a fresh
    /// incarnation-derived identity seed; past the budget the worker
    /// retires and training degrades gracefully. 0 (default) means a
    /// panicked worker just retires.
    pub worker_restarts: u64,
    /// Where `make artifacts` put the HLO modules.
    pub artifact_dir: PathBuf,
    /// Serving micro-batch size: how many queued requests one scoring
    /// call coalesces (`serve/queue.rs`). Only read under `mode=serve` —
    /// training paths construct no serve machinery.
    pub serve_batch: usize,
    /// How long (microseconds) a non-full micro-batch waits for late
    /// arrivals before scoring anyway. The latency/throughput trade:
    /// 0 legal only with `serve_batch=1`.
    pub serve_max_wait_us: u64,
    /// Scoring executor width for the service's server-lifetime
    /// `Executor` (the serving twin of `score_threads`).
    pub serve_threads: usize,
    /// Forest to serve, as saved by `asgbdt train --model` (`.sgbdt`
    /// artifact or legacy JSON dump, auto-detected by magic sniff).
    /// Required under `mode=serve`; `none` resets.
    pub serve_model: Option<PathBuf>,
    /// What `asgbdt train --model` writes: the versioned `.sgbdt`
    /// artifact (default) or the legacy JSON dump (config key `format`;
    /// `json` stays available for one release).
    pub model_format: ModelFormat,
    /// Write a resumable checkpoint artifact every N accepted trees
    /// (0, the default, turns checkpointing off entirely — no artifact
    /// code runs on the training path). Requires `checkpoint_path`.
    pub checkpoint_every: usize,
    /// Where checkpoints land: the base path holds the latest, and each
    /// checkpoint is also kept as `<stem>.tK.<ext>` at tree K. `none`
    /// resets.
    pub checkpoint_path: Option<PathBuf>,
}

impl Default for TrainConfig {
    fn default() -> Self {
        Self {
            mode: TrainMode::Async,
            loss: LossKind::Logistic,
            grad_mode: GradMode::Gradient,
            n_trees: 400,
            step_length: 0.01,
            step: StepMode::Fixed,
            huber_delta: 1.0,
            n_classes: 2,
            sampling_rate: 0.8,
            workers: 4,
            max_staleness: None,
            max_bins: 64,
            tree: TreeParams::default(),
            eval_every: 10,
            target: TargetMode::Fused,
            scoring: ScoreMode::Flat,
            score_threads: 1,
            ps_shards: 1,
            build_threads: 1,
            pool: PoolMode::Persistent,
            seed: 42,
            fault_seed: None,
            fault_drop_rate: 0.0,
            fault_dup_rate: 0.0,
            fault_delay_rate: 0.0,
            fault_panic_rate: 0.0,
            worker_restarts: 0,
            artifact_dir: PathBuf::from("artifacts"),
            serve_batch: 64,
            serve_max_wait_us: 200,
            serve_threads: 1,
            serve_model: None,
            model_format: ModelFormat::Sgbdt,
            checkpoint_every: 0,
            checkpoint_path: None,
        }
    }
}

impl TrainConfig {
    /// The cross-field checks every entrypoint runs before training.
    /// Rejections from knob conflicts name both knobs involved (the
    /// DESIGN.md §11 decision table lists every combination).
    pub fn validate(&self) -> Result<()> {
        if self.n_trees == 0 {
            bail!("n_trees must be > 0");
        }
        if !(self.step_length > 0.0) || !self.step_length.is_finite() {
            bail!("step_length must be positive and finite");
        }
        if !(self.sampling_rate > 0.0 && self.sampling_rate <= 1.0) {
            bail!("sampling_rate must be in (0, 1]");
        }
        if self.workers == 0 {
            bail!("workers must be >= 1");
        }
        if self.max_bins < 2 || self.max_bins > crate::data::binning::MAX_BINS {
            bail!("max_bins out of range");
        }
        if self.tree.max_leaves == 0 {
            bail!("max_leaves must be >= 1");
        }
        if !(self.tree.feature_rate > 0.0 && self.tree.feature_rate <= 1.0) {
            bail!("feature_rate must be in (0, 1]");
        }
        if self.eval_every == 0 {
            bail!("eval_every must be >= 1");
        }
        if self.score_threads == 0 {
            bail!("score_threads must be >= 1");
        }
        if self.ps_shards == 0 {
            bail!("ps_shards must be >= 1");
        }
        if self.build_threads == 0 {
            bail!("build_threads must be >= 1");
        }
        if self.serve_batch == 0 {
            bail!("serve_batch must be >= 1 (rows coalesced per scoring call)");
        }
        if self.serve_threads == 0 {
            bail!("serve_threads must be >= 1");
        }
        // Cross-field checks: name BOTH conflicting knobs and the fix, so
        // a rejected run tells the user which one to turn (DESIGN.md §11
        // has the full decision table).
        if self.loss == LossKind::Huber
            && (!self.huber_delta.is_finite() || self.huber_delta <= 0.0)
        {
            bail!(
                "huber_delta must be positive and finite, got {}",
                self.huber_delta
            );
        }
        if self.loss != LossKind::Huber && self.huber_delta != 1.0 {
            bail!(
                "conflicting knobs huber_delta={} and loss={}: the transition width only \
                 exists for the Huber loss (it would be silently ignored) — set loss=huber \
                 (to use the δ knob) or huber_delta=1.0 (to keep loss={})",
                self.huber_delta,
                self.loss.as_str(),
                self.loss.as_str()
            );
        }
        if self.n_classes < 2 {
            bail!("n_classes must be >= 2, got {}", self.n_classes);
        }
        if self.loss == LossKind::Multiclass && self.n_classes < 3 {
            bail!(
                "conflicting knobs loss=multiclass and n_classes={}: softmax over two \
                 classes is binary data, which the scalar losses own — set n_classes=K \
                 with K >= 3 (to train K-way softmax) or loss=logistic (to train the \
                 binary objective)",
                self.n_classes
            );
        }
        if self.loss != LossKind::Multiclass && self.n_classes != 2 {
            bail!(
                "conflicting knobs n_classes={} and loss={}: only the multiclass softmax \
                 trains more than two classes — set loss=multiclass (to use n_classes) or \
                 n_classes=2 (to keep loss={})",
                self.n_classes,
                self.loss.as_str(),
                self.loss.as_str()
            );
        }
        if self.step == StepMode::Adaptive && self.mode == TrainMode::Serial {
            bail!(
                "conflicting knobs step=adaptive and mode=serial: the serial trainer \
                 observes zero staleness on every push, so the damping rule never engages \
                 (adaptive ≡ fixed there by definition) — set mode=async|sync (to train \
                 where τ is measured) or step=fixed (to keep mode=serial)"
            );
        }
        if self.target == TargetMode::Fused && self.scoring == ScoreMode::PerRow {
            bail!(
                "conflicting knobs scoring=perrow and target=fused: the per-row reference \
                 engine only exists on the serial accept path — set target=serial (to keep \
                 scoring=perrow) or scoring=flat (to keep target=fused)"
            );
        }
        if self.mode == TrainMode::Sync && self.build_threads > 1 {
            bail!(
                "conflicting knobs mode=sync and build_threads={}: the sync baseline's \
                 fork-join width IS its worker count (it would silently ignore \
                 build_threads) — set workers=N (to widen sync tree builds) or \
                 mode=async|serial (to keep build_threads)",
                self.build_threads
            );
        }
        if self.serve_batch > 1 && self.serve_max_wait_us == 0 {
            bail!(
                "conflicting knobs serve_batch={} and serve_max_wait_us=0: a coalescing \
                 micro-batch needs a wait budget to ever fill — set serve_max_wait_us=N \
                 (to let batches coalesce) or serve_batch=1 (to score every request \
                 alone, no wait)",
                self.serve_batch
            );
        }
        if self.mode == TrainMode::Serve && self.serve_model.is_none() {
            bail!(
                "conflicting knobs mode=serve and serve_model=none: the serving mode \
                 scores a trained forest, not a trainer — set serve_model=path/to/model.json \
                 (as saved by `asgbdt train --model`) or mode=async|sync|serial (to train \
                 instead)"
            );
        }
        if self.checkpoint_every > 0 && self.checkpoint_path.is_none() {
            bail!(
                "conflicting knobs checkpoint_every={} and checkpoint_path=none: periodic \
                 checkpoints need somewhere to land — set checkpoint_path=path/to/ck.sgbdt \
                 (to write resumable artifacts) or checkpoint_every=0 (to keep \
                 checkpointing off)",
                self.checkpoint_every
            );
        }
        let rates = [
            ("fault_drop_rate", self.fault_drop_rate),
            ("fault_dup_rate", self.fault_dup_rate),
            ("fault_delay_rate", self.fault_delay_rate),
            ("fault_panic_rate", self.fault_panic_rate),
        ];
        for (name, rate) in rates {
            if !rate.is_finite() || !(0.0..=1.0).contains(&rate) {
                bail!("{name} must be a finite probability in [0, 1], got {rate}");
            }
        }
        let msg_mass = self.fault_drop_rate + self.fault_dup_rate + self.fault_delay_rate;
        if msg_mass > 1.0 {
            bail!(
                "conflicting knobs fault_drop_rate={} + fault_dup_rate={} + \
                 fault_delay_rate={} exceed 1.0: the three message faults partition one \
                 decision per send attempt — lower them until they sum to at most 1.0",
                self.fault_drop_rate,
                self.fault_dup_rate,
                self.fault_delay_rate
            );
        }
        if self.fault_seed.is_none() {
            if let Some((name, rate)) = rates.iter().find(|(_, r)| *r > 0.0) {
                bail!(
                    "conflicting knobs {name}={rate} and fault_seed=none: fault rates only \
                     take effect under an armed plan — set fault_seed=N (to inject faults \
                     deterministically) or zero the rates (to keep the fault layer off)"
                );
            }
        }
        Ok(())
    }

    /// Build the armed [`FaultPlan`] from `fault_seed` + the rates, or
    /// `None` when the fault layer is off — callers on the `None` path
    /// construct no wrapper and run no fault-layer code (DESIGN.md §14).
    pub fn fault_plan(&self) -> Option<FaultPlan> {
        self.fault_seed.map(|seed| {
            FaultPlan::new(
                seed,
                FaultSpec {
                    drop_rate: self.fault_drop_rate,
                    dup_rate: self.fault_dup_rate,
                    delay_rate: self.fault_delay_rate,
                    panic_rate: self.fault_panic_rate,
                    ..FaultSpec::default()
                },
            )
        })
    }

    /// Whether the async trainer runs the supervision machinery
    /// (heartbeats + restart loop): armed faults or a restart budget.
    pub fn supervised(&self) -> bool {
        self.fault_seed.is_some() || self.worker_restarts > 0
    }

    /// The scalar dispatch value for this config's loss, or `None` under
    /// `loss=multiclass` (which has no single-margin-vector kernel — the
    /// server routes it through its own whole-vector accept path).
    pub fn scalar_loss(&self) -> Option<ScalarLoss> {
        match self.loss {
            LossKind::Logistic => Some(ScalarLoss::Logistic),
            LossKind::Squared => Some(ScalarLoss::Squared),
            LossKind::Huber => Some(ScalarLoss::Huber(self.huber_delta as f32)),
            LossKind::Multiclass => None,
        }
    }

    /// Apply a `key=value` override (CLI surface).
    pub fn set(&mut self, key: &str, value: &str) -> Result<()> {
        match key {
            "mode" => self.mode = TrainMode::parse(value)?,
            "loss" => self.loss = LossKind::parse(value)?,
            "grad_mode" => self.grad_mode = GradMode::parse(value)?,
            "n_trees" => self.n_trees = value.parse()?,
            "step_length" | "v" => self.step_length = value.parse()?,
            "step" | "step_mode" => self.step = StepMode::parse(value)?,
            "huber_delta" => self.huber_delta = value.parse()?,
            "n_classes" => self.n_classes = value.parse()?,
            "sampling_rate" => self.sampling_rate = value.parse()?,
            "workers" => self.workers = value.parse()?,
            "max_staleness" => {
                self.max_staleness = if value == "none" {
                    None
                } else {
                    Some(value.parse()?)
                }
            }
            "max_bins" => self.max_bins = value.parse()?,
            "max_leaves" => self.tree.max_leaves = value.parse()?,
            "max_depth" => self.tree.max_depth = value.parse()?,
            "min_leaf_count" => self.tree.min_leaf_count = value.parse()?,
            "lambda" => self.tree.lambda = value.parse()?,
            "feature_rate" => self.tree.feature_rate = value.parse()?,
            "histogram" | "histogram_strategy" => {
                self.tree.strategy = HistogramStrategy::parse(value)?
            }
            "eval_every" => self.eval_every = value.parse()?,
            "target" | "target_mode" => self.target = TargetMode::parse(value)?,
            "scoring" | "score_mode" => self.scoring = ScoreMode::parse(value)?,
            "score_threads" => self.score_threads = value.parse()?,
            "ps_shards" => self.ps_shards = value.parse()?,
            "build_threads" => self.build_threads = value.parse()?,
            "pool" | "pool_mode" => self.pool = PoolMode::parse(value)?,
            "seed" => self.seed = value.parse()?,
            "fault_seed" => {
                self.fault_seed = if value == "none" {
                    None
                } else {
                    Some(value.parse()?)
                }
            }
            "fault_drop_rate" => self.fault_drop_rate = value.parse()?,
            "fault_dup_rate" => self.fault_dup_rate = value.parse()?,
            "fault_delay_rate" => self.fault_delay_rate = value.parse()?,
            "fault_panic_rate" => self.fault_panic_rate = value.parse()?,
            "worker_restarts" => self.worker_restarts = value.parse()?,
            "artifact_dir" => self.artifact_dir = PathBuf::from(value),
            "serve_batch" => self.serve_batch = value.parse()?,
            "serve_max_wait_us" => self.serve_max_wait_us = value.parse()?,
            "serve_threads" => self.serve_threads = value.parse()?,
            "serve_model" => {
                self.serve_model = if value == "none" {
                    None
                } else {
                    Some(PathBuf::from(value))
                }
            }
            "format" | "model_format" => self.model_format = ModelFormat::parse(value)?,
            "checkpoint_every" => self.checkpoint_every = value.parse()?,
            "checkpoint_path" => {
                self.checkpoint_path = if value == "none" {
                    None
                } else {
                    Some(PathBuf::from(value))
                }
            }
            other => bail!("unknown config key '{other}'"),
        }
        Ok(())
    }

    /// Serialize every knob (the config-file shape `load` reads back).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("mode", Json::Str(self.mode.as_str().into())),
            ("loss", Json::Str(self.loss.as_str().into())),
            ("grad_mode", Json::Str(self.grad_mode.as_str().into())),
            ("n_trees", Json::Num(self.n_trees as f64)),
            ("step_length", Json::Num(self.step_length as f64)),
            ("step", Json::Str(self.step.as_str().into())),
            ("huber_delta", Json::Num(self.huber_delta)),
            ("n_classes", Json::Num(self.n_classes as f64)),
            ("sampling_rate", Json::Num(self.sampling_rate)),
            ("workers", Json::Num(self.workers as f64)),
            (
                "max_staleness",
                self.max_staleness
                    .map(|s| Json::Num(s as f64))
                    .unwrap_or(Json::Null),
            ),
            ("max_bins", Json::Num(self.max_bins as f64)),
            ("max_leaves", Json::Num(self.tree.max_leaves as f64)),
            ("max_depth", Json::Num(self.tree.max_depth as f64)),
            ("min_leaf_count", Json::Num(self.tree.min_leaf_count as f64)),
            ("lambda", Json::Num(self.tree.lambda)),
            ("feature_rate", Json::Num(self.tree.feature_rate)),
            ("histogram", Json::Str(self.tree.strategy.as_str().into())),
            ("eval_every", Json::Num(self.eval_every as f64)),
            ("target", Json::Str(self.target.as_str().into())),
            ("scoring", Json::Str(self.scoring.as_str().into())),
            ("score_threads", Json::Num(self.score_threads as f64)),
            ("ps_shards", Json::Num(self.ps_shards as f64)),
            ("build_threads", Json::Num(self.build_threads as f64)),
            ("pool", Json::Str(self.pool.as_str().into())),
            ("seed", Json::Num(self.seed as f64)),
            (
                "fault_seed",
                self.fault_seed
                    .map(|s| Json::Num(s as f64))
                    .unwrap_or(Json::Null),
            ),
            ("fault_drop_rate", Json::Num(self.fault_drop_rate)),
            ("fault_dup_rate", Json::Num(self.fault_dup_rate)),
            ("fault_delay_rate", Json::Num(self.fault_delay_rate)),
            ("fault_panic_rate", Json::Num(self.fault_panic_rate)),
            ("worker_restarts", Json::Num(self.worker_restarts as f64)),
            (
                "artifact_dir",
                Json::Str(self.artifact_dir.display().to_string()),
            ),
            ("serve_batch", Json::Num(self.serve_batch as f64)),
            (
                "serve_max_wait_us",
                Json::Num(self.serve_max_wait_us as f64),
            ),
            ("serve_threads", Json::Num(self.serve_threads as f64)),
            (
                "serve_model",
                self.serve_model
                    .as_ref()
                    .map(|p| Json::Str(p.display().to_string()))
                    .unwrap_or(Json::Null),
            ),
            ("format", Json::Str(self.model_format.as_str().into())),
            ("checkpoint_every", Json::Num(self.checkpoint_every as f64)),
            (
                "checkpoint_path",
                self.checkpoint_path
                    .as_ref()
                    .map(|p| Json::Str(p.display().to_string()))
                    .unwrap_or(Json::Null),
            ),
        ])
    }

    /// Config fingerprint stored in `.sgbdt` manifests and checked on
    /// `--resume`: FNV-1a 64 over the serialized config with the
    /// byte-plumbing knobs removed (`format`, `checkpoint_every`,
    /// `checkpoint_path`, `artifact_dir`, and the `serve_*` family) —
    /// those change where bytes land or how a model is served, never
    /// which forest gets trained, so resuming with a different
    /// checkpoint cadence or dump format must not be refused.
    pub fn fingerprint(&self) -> String {
        let mut j = self.to_json();
        if let Json::Obj(ref mut o) = j {
            for k in [
                "format",
                "checkpoint_every",
                "checkpoint_path",
                "artifact_dir",
                "serve_batch",
                "serve_max_wait_us",
                "serve_threads",
                "serve_model",
            ] {
                o.remove(k);
            }
        }
        crate::io::artifact::hex16(crate::io::artifact::fnv64(j.to_string().as_bytes()))
    }

    /// Build a config from a JSON object: defaults, then every present
    /// key as an override, then `validate`.
    pub fn from_json(j: &Json) -> Result<TrainConfig> {
        let mut c = TrainConfig::default();
        if let Some(obj) = j.as_obj() {
            for (k, v) in obj {
                let val = match v {
                    Json::Str(s) => s.clone(),
                    Json::Null => "none".to_string(),
                    other => other.to_string(),
                };
                c.set(k, &val)?;
            }
        } else {
            bail!("config must be a JSON object");
        }
        c.validate()?;
        Ok(c)
    }

    /// Load and validate a JSON config file (`--config path.json`).
    pub fn load(path: &Path) -> Result<TrainConfig> {
        TrainConfig::from_json(&Json::parse_file(path)?)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_is_valid_paper_setting() {
        let c = TrainConfig::default();
        c.validate().unwrap();
        assert_eq!(c.n_trees, 400);
        assert!((c.step_length - 0.01).abs() < 1e-9);
        assert!((c.sampling_rate - 0.8).abs() < 1e-12);
        assert!((c.tree.feature_rate - 0.8).abs() < 1e-12);
    }

    #[test]
    fn set_overrides_fields() {
        let mut c = TrainConfig::default();
        c.set("workers", "32").unwrap();
        c.set("mode", "serial").unwrap();
        c.set("sampling_rate", "0.000005").unwrap();
        c.set("max_leaves", "400").unwrap();
        c.set("max_staleness", "16").unwrap();
        c.set("histogram", "rebuild").unwrap();
        c.set("target", "serial").unwrap();
        c.set("scoring", "perrow").unwrap();
        c.set("score_threads", "4").unwrap();
        c.set("build_threads", "3").unwrap();
        c.set("pool", "scoped").unwrap();
        c.set("ps_shards", "4").unwrap();
        assert_eq!(c.target, TargetMode::Serial);
        assert_eq!(c.scoring, ScoreMode::PerRow);
        assert_eq!(c.score_threads, 4);
        assert_eq!(c.ps_shards, 4);
        assert_eq!(c.build_threads, 3);
        assert_eq!(c.pool, PoolMode::Scoped);
        assert_eq!(c.workers, 32);
        assert_eq!(c.mode, TrainMode::Serial);
        assert_eq!(c.max_staleness, Some(16));
        assert_eq!(c.tree.max_leaves, 400);
        assert_eq!(c.tree.strategy, HistogramStrategy::Rebuild);
        c.set("max_staleness", "none").unwrap();
        assert_eq!(c.max_staleness, None);
        assert!(c.set("histogram", "bogus").is_err());
    }

    #[test]
    fn rejects_unknown_key_and_bad_values() {
        let mut c = TrainConfig::default();
        assert!(c.set("bogus", "1").is_err());
        assert!(c.set("mode", "quantum").is_err());
        assert!(c.set("workers", "a lot").is_err());
    }

    #[test]
    fn validate_catches_bad_configs() {
        let mut c = TrainConfig::default();
        c.n_trees = 0;
        assert!(c.validate().is_err());
        let mut c = TrainConfig::default();
        c.sampling_rate = 0.0;
        assert!(c.validate().is_err());
        let mut c = TrainConfig::default();
        c.step_length = -1.0;
        assert!(c.validate().is_err());
        let mut c = TrainConfig::default();
        c.workers = 0;
        assert!(c.validate().is_err());
        let mut c = TrainConfig::default();
        c.score_threads = 0;
        assert!(c.validate().is_err());
        let mut c = TrainConfig::default();
        c.build_threads = 0;
        assert!(c.validate().is_err());
        let mut c = TrainConfig::default();
        c.ps_shards = 0;
        assert!(c.validate().is_err());
    }

    #[test]
    fn ps_shards_defaults_to_single_shard_and_is_orthogonal() {
        // the sharded PS must be opt-in: the default config stays on the
        // single-ServerCore path, and any shard count validates against
        // every target/pool combination (no cross-field conflicts — the
        // sharded pass is bit-identical to the fused one by construction)
        let c = TrainConfig::default();
        assert_eq!(c.ps_shards, 1);
        for shards in [1usize, 2, 8] {
            for target in [TargetMode::Fused, TargetMode::Serial] {
                for pool in [PoolMode::Persistent, PoolMode::Scoped] {
                    let mut c = TrainConfig::default();
                    c.ps_shards = shards;
                    c.target = target;
                    c.pool = pool;
                    c.validate().unwrap();
                }
            }
        }
    }

    #[test]
    fn rejected_knob_combinations_name_both_knobs() {
        // every cross-field rejection must tell the user WHICH pair of
        // knobs conflicts — one test per rejected combination (DESIGN.md
        // §11 decision table)
        // (1) scoring=perrow × target=fused
        let mut c = TrainConfig::default();
        c.scoring = ScoreMode::PerRow;
        assert_eq!(c.target, TargetMode::Fused);
        let msg = c.validate().unwrap_err().to_string();
        assert!(
            msg.contains("scoring=perrow") && msg.contains("target=fused"),
            "error must name the conflicting pair, got: {msg}"
        );
        assert!(msg.contains("target=serial"), "error must name the fix, got: {msg}");
        // ...and each side of the pair is fine once the other moves
        c.target = TargetMode::Serial;
        c.validate().unwrap();
        c.scoring = ScoreMode::Flat;
        c.target = TargetMode::Fused;
        c.validate().unwrap();
        // (2) mode=sync × build_threads>1: sync's fork-join width is its
        // worker count, so the pair is rejected instead of silently
        // ignoring build_threads
        let mut c = TrainConfig::default();
        c.mode = TrainMode::Sync;
        c.build_threads = 4;
        let msg = c.validate().unwrap_err().to_string();
        assert!(
            msg.contains("mode=sync") && msg.contains("build_threads=4"),
            "error must name the conflicting pair, got: {msg}"
        );
        assert!(msg.contains("workers="), "error must name the fix, got: {msg}");
        c.build_threads = 1;
        c.validate().unwrap();
        c.mode = TrainMode::Async;
        c.build_threads = 4;
        c.validate().unwrap();
        // the pool knob is orthogonal: every mode × target × scoring ×
        // build_threads combination that validates keeps validating
        // under either pool
        for pool in [PoolMode::Persistent, PoolMode::Scoped] {
            let mut c = TrainConfig::default();
            c.pool = pool;
            c.build_threads = 2;
            c.validate().unwrap();
            c.target = TargetMode::Serial;
            c.scoring = ScoreMode::PerRow;
            c.validate().unwrap();
        }
    }

    #[test]
    fn loss_and_step_knobs_default_roundtrip_and_dispatch() {
        let c = TrainConfig::default();
        assert_eq!(c.loss, LossKind::Logistic);
        assert_eq!(c.step, StepMode::Fixed);
        assert_eq!(c.huber_delta, 1.0);
        assert_eq!(c.n_classes, 2);
        assert_eq!(c.scalar_loss(), Some(ScalarLoss::Logistic));
        let mut c = TrainConfig::default();
        c.set("loss", "huber").unwrap();
        c.set("huber_delta", "0.5").unwrap();
        c.set("step", "adaptive").unwrap();
        c.validate().unwrap();
        assert_eq!(c.scalar_loss(), Some(ScalarLoss::Huber(0.5)));
        let back = TrainConfig::from_json(&c.to_json()).unwrap();
        assert_eq!(back.loss, LossKind::Huber);
        assert_eq!(back.step, StepMode::Adaptive);
        assert!((back.huber_delta - 0.5).abs() < 1e-12);
        let mut c = TrainConfig::default();
        c.set("loss", "multiclass").unwrap();
        c.set("n_classes", "5").unwrap();
        c.validate().unwrap();
        assert_eq!(c.scalar_loss(), None);
        let back = TrainConfig::from_json(&c.to_json()).unwrap();
        assert_eq!(back.loss, LossKind::Multiclass);
        assert_eq!(back.n_classes, 5);
        assert!(c.set("loss", "hinge").is_err());
        assert!(c.set("step", "warmup").is_err());
    }

    #[test]
    fn multiclass_with_binary_data_names_both_knobs() {
        // K = 2 is binary data: softmax must not masquerade as logistic
        let mut c = TrainConfig::default();
        c.loss = LossKind::Multiclass;
        assert_eq!(c.n_classes, 2);
        let msg = c.validate().unwrap_err().to_string();
        assert!(
            msg.contains("loss=multiclass") && msg.contains("n_classes=2"),
            "error must name the conflicting pair, got: {msg}"
        );
        assert!(msg.contains("loss=logistic"), "error must name the fix, got: {msg}");
        c.n_classes = 3;
        c.validate().unwrap();
        // and K > 2 without multiclass is the mirror-image conflict
        let mut c = TrainConfig::default();
        c.n_classes = 4;
        let msg = c.validate().unwrap_err().to_string();
        assert!(
            msg.contains("n_classes=4") && msg.contains("loss=logistic"),
            "error must name the conflicting pair, got: {msg}"
        );
        assert!(msg.contains("loss=multiclass"), "error must name the fix, got: {msg}");
    }

    #[test]
    fn huber_delta_without_huber_names_both_knobs() {
        let mut c = TrainConfig::default();
        c.huber_delta = 2.5;
        let msg = c.validate().unwrap_err().to_string();
        assert!(
            msg.contains("huber_delta=2.5") && msg.contains("loss=logistic"),
            "error must name the conflicting pair, got: {msg}"
        );
        assert!(msg.contains("loss=huber"), "error must name the fix, got: {msg}");
        c.loss = LossKind::Huber;
        c.validate().unwrap();
        // δ must be a positive finite width under loss=huber
        c.huber_delta = -1.0;
        let msg = c.validate().unwrap_err().to_string();
        assert!(msg.contains("huber_delta"), "got: {msg}");
    }

    #[test]
    fn adaptive_step_in_serial_mode_names_both_knobs() {
        let mut c = TrainConfig::default();
        c.mode = TrainMode::Serial;
        c.step = StepMode::Adaptive;
        let msg = c.validate().unwrap_err().to_string();
        assert!(
            msg.contains("step=adaptive") && msg.contains("mode=serial"),
            "error must name the conflicting pair, got: {msg}"
        );
        assert!(msg.contains("step=fixed"), "error must name the fix, got: {msg}");
        // either side moving resolves it
        c.step = StepMode::Fixed;
        c.validate().unwrap();
        c.step = StepMode::Adaptive;
        c.mode = TrainMode::Async;
        c.validate().unwrap();
        c.mode = TrainMode::Sync;
        c.validate().unwrap();
    }

    #[test]
    fn loss_and_step_move_the_fingerprint() {
        // the objective and the step policy both change which forest gets
        // trained, so they must pin the resume fingerprint
        let base = TrainConfig::default().fingerprint();
        let mut c = TrainConfig::default();
        c.loss = LossKind::Squared;
        assert_ne!(c.fingerprint(), base);
        let mut c = TrainConfig::default();
        c.step = StepMode::Adaptive;
        assert_ne!(c.fingerprint(), base);
        let mut c = TrainConfig::default();
        c.loss = LossKind::Huber;
        c.huber_delta = 0.7;
        assert_ne!(c.fingerprint(), base);
    }

    #[test]
    fn fault_layer_defaults_to_off() {
        // the all-defaults path must build no plan and run unsupervised —
        // the zero-cost guarantee DESIGN.md §14 promises
        let c = TrainConfig::default();
        assert_eq!(c.fault_seed, None);
        assert!(c.fault_plan().is_none());
        assert!(!c.supervised());
        assert_eq!(c.worker_restarts, 0);
        c.validate().unwrap();
    }

    #[test]
    fn fault_knobs_set_arm_and_roundtrip() {
        let mut c = TrainConfig::default();
        c.set("fault_seed", "7").unwrap();
        c.set("fault_drop_rate", "0.1").unwrap();
        c.set("fault_dup_rate", "0.05").unwrap();
        c.set("fault_delay_rate", "0.02").unwrap();
        c.set("fault_panic_rate", "0.01").unwrap();
        c.set("worker_restarts", "2").unwrap();
        c.validate().unwrap();
        assert!(c.supervised());
        let plan = c.fault_plan().unwrap();
        assert_eq!(plan.seed(), 7);
        assert!((plan.spec().drop_rate - 0.1).abs() < 1e-12);
        assert!((plan.spec().panic_rate - 0.01).abs() < 1e-12);
        let back = TrainConfig::from_json(&c.to_json()).unwrap();
        assert_eq!(back.fault_seed, Some(7));
        assert!((back.fault_dup_rate - 0.05).abs() < 1e-12);
        assert_eq!(back.worker_restarts, 2);
        // disarming through the CLI spelling mirrors max_staleness=none
        c.set("fault_seed", "none").unwrap();
        assert_eq!(c.fault_seed, None);
        // restart budget alone still turns supervision on (real panics
        // are supervised even with no injected ones)
        let mut c = TrainConfig::default();
        c.worker_restarts = 1;
        assert!(c.supervised());
        assert!(c.fault_plan().is_none());
        c.validate().unwrap();
    }

    #[test]
    fn fault_rate_rejections_name_both_knobs() {
        // a nonzero rate with no seed is a silent no-op — reject it and
        // name both knobs plus the fix
        let mut c = TrainConfig::default();
        c.fault_drop_rate = 0.3;
        let msg = c.validate().unwrap_err().to_string();
        assert!(
            msg.contains("fault_drop_rate=0.3") && msg.contains("fault_seed=none"),
            "error must name the conflicting pair, got: {msg}"
        );
        assert!(msg.contains("fault_seed=N"), "error must name the fix, got: {msg}");
        c.fault_seed = Some(1);
        c.validate().unwrap();
        // rates outside [0, 1] are rejected by name
        let mut c = TrainConfig::default();
        c.fault_seed = Some(1);
        c.fault_panic_rate = 1.5;
        let msg = c.validate().unwrap_err().to_string();
        assert!(msg.contains("fault_panic_rate"), "got: {msg}");
        // the three message faults partition one draw — their sum > 1.0
        // is rejected naming all three
        let mut c = TrainConfig::default();
        c.fault_seed = Some(1);
        c.fault_drop_rate = 0.5;
        c.fault_dup_rate = 0.4;
        c.fault_delay_rate = 0.2;
        let msg = c.validate().unwrap_err().to_string();
        assert!(
            msg.contains("fault_drop_rate") && msg.contains("fault_delay_rate"),
            "error must name the rates, got: {msg}"
        );
    }

    #[test]
    fn serve_knobs_default_to_inert_and_roundtrip() {
        // training configs must not change shape: the serve knobs exist
        // with defaults that validate, but nothing on a training path
        // reads them (the §15 zero-cost guarantee)
        let c = TrainConfig::default();
        assert_eq!(c.serve_batch, 64);
        assert_eq!(c.serve_max_wait_us, 200);
        assert_eq!(c.serve_threads, 1);
        assert_eq!(c.serve_model, None);
        c.validate().unwrap();
        let mut c = TrainConfig::default();
        c.set("serve_batch", "16").unwrap();
        c.set("serve_max_wait_us", "500").unwrap();
        c.set("serve_threads", "2").unwrap();
        c.set("serve_model", "models/f.json").unwrap();
        let back = TrainConfig::from_json(&c.to_json()).unwrap();
        assert_eq!(back.serve_batch, 16);
        assert_eq!(back.serve_max_wait_us, 500);
        assert_eq!(back.serve_threads, 2);
        assert_eq!(back.serve_model, Some(PathBuf::from("models/f.json")));
        // the CLI reset spelling mirrors max_staleness/fault_seed
        c.set("serve_model", "none").unwrap();
        assert_eq!(c.serve_model, None);
    }

    #[test]
    fn serve_zero_knobs_are_rejected_by_name() {
        let mut c = TrainConfig::default();
        c.serve_batch = 0;
        let msg = c.validate().unwrap_err().to_string();
        assert!(msg.contains("serve_batch"), "got: {msg}");
        let mut c = TrainConfig::default();
        c.serve_threads = 0;
        let msg = c.validate().unwrap_err().to_string();
        assert!(msg.contains("serve_threads"), "got: {msg}");
    }

    #[test]
    fn serve_batch_without_wait_names_both_knobs() {
        // a multi-row batch with a zero wait budget can never coalesce —
        // reject the pair instead of silently degrading to singles
        let mut c = TrainConfig::default();
        c.serve_batch = 32;
        c.serve_max_wait_us = 0;
        let msg = c.validate().unwrap_err().to_string();
        assert!(
            msg.contains("serve_batch=32") && msg.contains("serve_max_wait_us=0"),
            "error must name the conflicting pair, got: {msg}"
        );
        assert!(msg.contains("serve_batch=1"), "error must name the fix, got: {msg}");
        // either side moving resolves it
        c.serve_batch = 1;
        c.validate().unwrap();
        c.serve_batch = 32;
        c.serve_max_wait_us = 100;
        c.validate().unwrap();
    }

    #[test]
    fn serve_mode_without_model_names_both_knobs() {
        let mut c = TrainConfig::default();
        c.mode = TrainMode::Serve;
        assert_eq!(c.serve_model, None);
        let msg = c.validate().unwrap_err().to_string();
        assert!(
            msg.contains("mode=serve") && msg.contains("serve_model=none"),
            "error must name the conflicting pair, got: {msg}"
        );
        assert!(msg.contains("serve_model=path"), "error must name the fix, got: {msg}");
        c.serve_model = Some(PathBuf::from("model.json"));
        c.validate().unwrap();
        // and a model path without serve mode is fine (train then serve
        // from one config file)
        let mut c = TrainConfig::default();
        c.serve_model = Some(PathBuf::from("model.json"));
        c.validate().unwrap();
    }

    #[test]
    fn artifact_knobs_default_to_inert_and_roundtrip() {
        // checkpointing must be opt-in: the default config writes no
        // checkpoints and dumps the versioned artifact format
        let c = TrainConfig::default();
        assert_eq!(c.model_format, ModelFormat::Sgbdt);
        assert_eq!(c.checkpoint_every, 0);
        assert_eq!(c.checkpoint_path, None);
        c.validate().unwrap();
        let mut c = TrainConfig::default();
        c.set("format", "json").unwrap();
        c.set("checkpoint_every", "20").unwrap();
        c.set("checkpoint_path", "out/ck.sgbdt").unwrap();
        c.validate().unwrap();
        let back = TrainConfig::from_json(&c.to_json()).unwrap();
        assert_eq!(back.model_format, ModelFormat::Json);
        assert_eq!(back.checkpoint_every, 20);
        assert_eq!(back.checkpoint_path, Some(PathBuf::from("out/ck.sgbdt")));
        // the CLI reset spelling mirrors serve_model/fault_seed
        c.set("checkpoint_path", "none").unwrap();
        c.set("checkpoint_every", "0").unwrap();
        c.validate().unwrap();
        assert!(c.set("format", "pickle").is_err());
        // a checkpoint path with no cadence is inert, not a conflict
        // (one config file can drive both checkpointed and plain runs)
        let mut c = TrainConfig::default();
        c.checkpoint_path = Some(PathBuf::from("ck.sgbdt"));
        c.validate().unwrap();
    }

    #[test]
    fn checkpoint_without_path_names_both_knobs() {
        let mut c = TrainConfig::default();
        c.checkpoint_every = 20;
        let msg = c.validate().unwrap_err().to_string();
        assert!(
            msg.contains("checkpoint_every=20") && msg.contains("checkpoint_path=none"),
            "error must name the conflicting pair, got: {msg}"
        );
        assert!(
            msg.contains("checkpoint_path=path") && msg.contains("checkpoint_every=0"),
            "error must name the fix, got: {msg}"
        );
        c.checkpoint_path = Some(PathBuf::from("ck.sgbdt"));
        c.validate().unwrap();
    }

    #[test]
    fn fingerprint_pins_trajectory_not_plumbing() {
        let base = TrainConfig::default().fingerprint();
        assert_eq!(base.len(), 16, "fixed-width hex");
        // byte-plumbing knobs must not move the fingerprint: a resumed
        // run may checkpoint on a different cadence or dump a different
        // format without being refused
        let mut c = TrainConfig::default();
        c.checkpoint_every = 20;
        c.checkpoint_path = Some(PathBuf::from("ck.sgbdt"));
        c.model_format = ModelFormat::Json;
        c.serve_batch = 16;
        assert_eq!(c.fingerprint(), base);
        // anything that changes the trained forest must move it
        let mut c = TrainConfig::default();
        c.n_trees = 401;
        assert_ne!(c.fingerprint(), base);
        let mut c = TrainConfig::default();
        c.seed = 43;
        assert_ne!(c.fingerprint(), base);
        let mut c = TrainConfig::default();
        c.mode = TrainMode::Serial;
        assert_ne!(c.fingerprint(), base);
    }

    #[test]
    fn json_roundtrip() {
        let mut c = TrainConfig::default();
        c.set("workers", "8").unwrap();
        c.set("grad_mode", "newton").unwrap();
        c.set("histogram", "rebuild").unwrap();
        c.set("target", "serial").unwrap();
        c.set("scoring", "perrow").unwrap();
        c.set("score_threads", "2").unwrap();
        c.set("build_threads", "4").unwrap();
        c.set("pool", "scoped").unwrap();
        c.set("ps_shards", "2").unwrap();
        let j = c.to_json();
        let back = TrainConfig::from_json(&j).unwrap();
        assert_eq!(back.workers, 8);
        assert_eq!(back.grad_mode, GradMode::Newton);
        assert_eq!(back.mode, TrainMode::Async);
        assert_eq!(back.max_staleness, None);
        assert_eq!(back.tree.strategy, HistogramStrategy::Rebuild);
        assert_eq!(back.target, TargetMode::Serial);
        assert_eq!(back.scoring, ScoreMode::PerRow);
        assert_eq!(back.score_threads, 2);
        assert_eq!(back.build_threads, 4);
        assert_eq!(back.pool, PoolMode::Scoped);
        assert_eq!(back.ps_shards, 2);
    }
}
