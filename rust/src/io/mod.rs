//! Minimal IO substrates: JSON (config + artifact manifests + metric
//! dumps), CSV (experiment outputs), svmlight/LIBSVM datasets, and the
//! versioned `.sgbdt` model artifact (manifest + checksummed binary
//! payload, DESIGN.md §16).
//!
//! serde is not available in the offline vendor set (see DESIGN.md §7), so
//! these are small hand-rolled implementations with full tests.

pub mod artifact;
pub mod csv;
pub mod json;
pub mod svmlight;

pub use json::Json;
