//! Minimal IO substrates: JSON (config + artifact manifests + metric
//! dumps), CSV (experiment outputs), and svmlight/LIBSVM datasets.
//!
//! serde is not available in the offline vendor set (see DESIGN.md §7), so
//! these are small hand-rolled implementations with full tests.

pub mod csv;
pub mod json;
pub mod svmlight;

pub use json::Json;
