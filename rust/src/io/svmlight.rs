//! svmlight / LIBSVM sparse dataset format.
//!
//! The paper's datasets (real-sim, HIGGS, E2006-log1p) ship in this format
//! from the LIBSVM repository; the reader lets users drop in the real files
//! while our synthetic substitutes (see `data::synthetic`) are used when
//! the originals are unavailable. Grammar per line:
//!
//! ```text
//! <label> <index>:<value> <index>:<value> ...   # optional trailing comment
//! ```
//!
//! Indices are 1-based in the file, converted to 0-based in memory.

use std::io::{Read, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::data::sparse::CsrMatrix;
use crate::data::Dataset;

/// Parse svmlight text into a [`Dataset`]. Labels are mapped to {0, 1}:
/// values > 0 become 1 (LIBSVM binary files use {-1,+1} or {0,1}).
pub fn parse(text: &str, name: &str) -> Result<Dataset> {
    let mut indptr = vec![0usize];
    let mut indices: Vec<u32> = Vec::new();
    let mut values: Vec<f32> = Vec::new();
    let mut labels: Vec<f32> = Vec::new();
    let mut n_cols = 0u32;

    for (lineno, raw) in text.lines().enumerate() {
        let line = raw.split('#').next().unwrap_or("").trim();
        if line.is_empty() {
            continue;
        }
        let mut parts = line.split_ascii_whitespace();
        let label: f32 = parts
            .next()
            .unwrap()
            .parse()
            .with_context(|| format!("line {}: bad label", lineno + 1))?;
        labels.push(if label > 0.0 { 1.0 } else { 0.0 });
        let mut last_idx: i64 = -1;
        for tok in parts {
            let (i_str, v_str) = tok
                .split_once(':')
                .with_context(|| format!("line {}: bad pair '{tok}'", lineno + 1))?;
            let idx1: u32 = i_str
                .parse()
                .with_context(|| format!("line {}: bad index '{i_str}'", lineno + 1))?;
            if idx1 == 0 {
                bail!("line {}: svmlight indices are 1-based, got 0", lineno + 1);
            }
            let idx = idx1 - 1;
            if (idx as i64) <= last_idx {
                bail!("line {}: indices not strictly increasing", lineno + 1);
            }
            last_idx = idx as i64;
            let val: f32 = v_str
                .parse()
                .with_context(|| format!("line {}: bad value '{v_str}'", lineno + 1))?;
            if val != 0.0 {
                indices.push(idx);
                values.push(val);
                n_cols = n_cols.max(idx + 1);
            }
        }
        indptr.push(indices.len());
    }

    let n_rows = labels.len();
    let x = CsrMatrix::new(n_rows, n_cols as usize, indptr, indices, values)?;
    Ok(Dataset::new(name, x, labels))
}

/// Read and parse an svmlight file.
pub fn read_file(path: &Path) -> Result<Dataset> {
    let mut f = std::fs::File::open(path)
        .with_context(|| format!("open {}", path.display()))?;
    let mut text = String::new();
    f.read_to_string(&mut text)?;
    let name = path
        .file_stem()
        .map(|s| s.to_string_lossy().into_owned())
        .unwrap_or_else(|| "dataset".into());
    parse(&text, &name)
}

/// Write a dataset in svmlight format (labels as 0/1; 1-based indices).
pub fn write_file(ds: &Dataset, path: &Path) -> Result<()> {
    if let Some(parent) = path.parent() {
        std::fs::create_dir_all(parent)?;
    }
    let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
    for r in 0..ds.n_rows() {
        write!(f, "{}", ds.y[r] as i32)?;
        for (idx, val) in ds.x.row(r) {
            write!(f, " {}:{}", idx + 1, val)?;
        }
        writeln!(f)?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_basic() {
        let ds = parse("1 1:0.5 3:2.0\n-1 2:1.0\n", "t").unwrap();
        assert_eq!(ds.n_rows(), 2);
        assert_eq!(ds.n_features(), 3);
        assert_eq!(ds.y, vec![1.0, 0.0]);
        let row0: Vec<_> = ds.x.row(0).collect();
        assert_eq!(row0, vec![(0u32, 0.5f32), (2, 2.0)]);
    }

    #[test]
    fn skips_comments_and_blank_lines(){
        let ds = parse("# header\n1 1:1.0  # trailing\n\n0 2:3.0\n", "t").unwrap();
        assert_eq!(ds.n_rows(), 2);
    }

    #[test]
    fn rejects_zero_index() {
        assert!(parse("1 0:1.0\n", "t").is_err());
    }

    #[test]
    fn rejects_unsorted_indices() {
        assert!(parse("1 3:1.0 2:1.0\n", "t").is_err());
    }

    #[test]
    fn drops_explicit_zeros() {
        let ds = parse("1 1:0.0 2:5.0\n", "t").unwrap();
        assert_eq!(ds.x.nnz(), 1);
    }

    #[test]
    fn roundtrip_through_file() {
        let ds = parse("1 1:0.5 3:2.0\n0 2:1.5\n", "t").unwrap();
        let path = std::env::temp_dir().join("asgbdt_svm_test.svm");
        write_file(&ds, &path).unwrap();
        let back = read_file(&path).unwrap();
        assert_eq!(back.n_rows(), ds.n_rows());
        assert_eq!(back.y, ds.y);
        assert_eq!(back.x.nnz(), ds.x.nnz());
        std::fs::remove_file(&path).ok();
    }
}
