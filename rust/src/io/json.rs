//! A small, strict JSON implementation (parse + serialize + accessors).
//!
//! Used for the artifact manifest written by `python/compile/aot.py`,
//! config files, and structured experiment outputs. Supports the full JSON
//! grammar except `\u` surrogate pairs beyond the BMP (sufficient here —
//! all producers are ours).

use std::collections::BTreeMap;
use std::fmt;

use anyhow::{anyhow, bail, Result};

/// A JSON value. Objects preserve key order via BTreeMap (deterministic
/// serialization matters for golden tests).
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    /// `null`.
    Null,
    /// `true` / `false`.
    Bool(bool),
    /// Any JSON number (always held as f64).
    Num(f64),
    /// A string.
    Str(String),
    /// An array.
    Arr(Vec<Json>),
    /// An object (key-sorted).
    Obj(BTreeMap<String, Json>),
}

impl Json {
    // ---------------------------------------------------------- accessors

    /// The boolean value, if this is a `Bool`.
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// The numeric value, if this is a `Num`.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    /// The value as a non-negative integer (rejects fractions).
    pub fn as_usize(&self) -> Option<usize> {
        match self {
            Json::Num(n) if *n >= 0.0 && n.fract() == 0.0 => Some(*n as usize),
            _ => None,
        }
    }

    /// The string value, if this is a `Str`.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    /// The elements, if this is an `Arr`.
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    /// The key/value map, if this is an `Obj`.
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }

    /// Object field lookup.
    pub fn get(&self, key: &str) -> Option<&Json> {
        self.as_obj().and_then(|o| o.get(key))
    }

    /// Required-field helpers with contextual errors.
    pub fn req(&self, key: &str) -> Result<&Json> {
        self.get(key).ok_or_else(|| anyhow!("missing field '{key}'"))
    }

    /// Required string field (see [`Json::req`]).
    pub fn req_str(&self, key: &str) -> Result<&str> {
        self.req(key)?
            .as_str()
            .ok_or_else(|| anyhow!("field '{key}' is not a string"))
    }

    /// Required non-negative integer field (see [`Json::req`]).
    pub fn req_usize(&self, key: &str) -> Result<usize> {
        self.req(key)?
            .as_usize()
            .ok_or_else(|| anyhow!("field '{key}' is not a non-negative integer"))
    }

    /// Required numeric field (see [`Json::req`]).
    pub fn req_f64(&self, key: &str) -> Result<f64> {
        self.req(key)?
            .as_f64()
            .ok_or_else(|| anyhow!("field '{key}' is not a number"))
    }

    /// Require string field `key` to hold exactly `expected` — the
    /// manifest format-tag guard shared by every manifest this crate
    /// reads (the HLO artifact manifest in `runtime/artifacts.rs` and
    /// the `.sgbdt` model manifest in `io/artifact.rs`). The error names
    /// the field and the expected-vs-found values.
    pub fn expect_str(&self, key: &str, expected: &str) -> Result<()> {
        let found = self.req_str(key)?;
        if found != expected {
            bail!("field '{key}': expected \"{expected}\", found \"{found}\"");
        }
        Ok(())
    }

    // ------------------------------------------------------- construction

    /// Build an object from `(key, value)` pairs.
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    /// Build a numeric array.
    pub fn from_f64s(xs: &[f64]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x)).collect())
    }

    // ------------------------------------------------------------ parsing

    /// Parse a complete JSON document (rejects trailing garbage).
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser {
            b: text.as_bytes(),
            i: 0,
        };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            bail!("trailing garbage at byte {}", p.i);
        }
        Ok(v)
    }

    /// Read and parse a JSON file, with the path in any error message.
    pub fn parse_file(path: &std::path::Path) -> Result<Json> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow!("read {}: {e}", path.display()))?;
        Json::parse(&text).map_err(|e| anyhow!("parse {}: {e}", path.display()))
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    write!(f, "{}", *n as i64)
                } else {
                    write!(f, "{n}")
                }
            }
            Json::Str(s) => write_escaped(f, s),
            Json::Arr(a) => {
                write!(f, "[")?;
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{v}")?;
                }
                write!(f, "]")
            }
            Json::Obj(o) => {
                write!(f, "{{")?;
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write_escaped(f, k)?;
                    write!(f, ":{v}")?;
                }
                write!(f, "}}")
            }
        }
    }
}

fn write_escaped(f: &mut fmt::Formatter<'_>, s: &str) -> fmt::Result {
    write!(f, "\"")?;
    for c in s.chars() {
        match c {
            '"' => write!(f, "\\\"")?,
            '\\' => write!(f, "\\\\")?,
            '\n' => write!(f, "\\n")?,
            '\r' => write!(f, "\\r")?,
            '\t' => write!(f, "\\t")?,
            c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
            c => write!(f, "{c}")?,
        }
    }
    write!(f, "\"")
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && self.b[self.i].is_ascii_whitespace() {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<()> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            bail!(
                "expected '{}' at byte {}, found {:?}",
                c as char,
                self.i,
                self.peek().map(|b| b as char)
            )
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            bail!("invalid literal at byte {}", self.i)
        }
    }

    fn value(&mut self) -> Result<Json> {
        match self.peek() {
            Some(b'n') => self.lit("null", Json::Null),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => bail!("unexpected {:?} at byte {}", other.map(|b| b as char), self.i),
        }
    }

    fn string(&mut self) -> Result<String> {
        self.eat(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => bail!("unterminated string"),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b'r') => out.push('\r'),
                        Some(b't') => out.push('\t'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                bail!("truncated \\u escape");
                            }
                            let hex =
                                std::str::from_utf8(&self.b[self.i + 1..self.i + 5])?;
                            let code = u32::from_str_radix(hex, 16)?;
                            out.push(
                                char::from_u32(code)
                                    .ok_or_else(|| anyhow!("bad \\u{hex}"))?,
                            );
                            self.i += 4;
                        }
                        other => bail!("bad escape {:?}", other.map(|b| b as char)),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // copy a full UTF-8 scalar
                    let s = std::str::from_utf8(&self.b[self.i..])?;
                    let c = s.chars().next().unwrap();
                    out.push(c);
                    self.i += c.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.i += 1;
        }
        if self.peek() == Some(b'.') {
            self.i += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        if matches!(self.peek(), Some(b'e') | Some(b'E')) {
            self.i += 1;
            if matches!(self.peek(), Some(b'+') | Some(b'-')) {
                self.i += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        let s = std::str::from_utf8(&self.b[start..self.i])?;
        Ok(Json::Num(s.parse::<f64>()?))
    }

    fn array(&mut self) -> Result<Json> {
        self.eat(b'[')?;
        let mut out = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            self.ws();
            out.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(out));
                }
                other => bail!("expected ',' or ']' found {:?}", other.map(|b| b as char)),
            }
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.eat(b'{')?;
        let mut out = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.ws();
            let key = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            let val = self.value()?;
            out.insert(key, val);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(out));
                }
                other => bail!("expected ',' or '}}' found {:?}", other.map(|b| b as char)),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(Json::parse("\"hi\\n\"").unwrap(), Json::Str("hi\n".into()));
    }

    #[test]
    fn parse_nested() {
        let j = Json::parse(r#"{"a":[1,2,{"b":null}],"c":"x"}"#).unwrap();
        assert_eq!(j.get("c").unwrap().as_str().unwrap(), "x");
        let a = j.get("a").unwrap().as_arr().unwrap();
        assert_eq!(a.len(), 3);
        assert_eq!(a[2].get("b").unwrap(), &Json::Null);
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"block":1024,"buckets":[4096,16384],"entries":[{"file":"g.hlo.txt","n":4096}],"format":"hlo-text"}"#;
        let j = Json::parse(src).unwrap();
        let out = j.to_string();
        assert_eq!(Json::parse(&out).unwrap(), j);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse("\"unterminated").is_err());
    }

    #[test]
    fn unicode_escape() {
        assert_eq!(Json::parse(r#""é""#).unwrap(), Json::Str("é".into()));
    }

    #[test]
    fn req_helpers() {
        let j = Json::parse(r#"{"n":5,"s":"x","f":1.5}"#).unwrap();
        assert_eq!(j.req_usize("n").unwrap(), 5);
        assert_eq!(j.req_str("s").unwrap(), "x");
        assert_eq!(j.req_f64("f").unwrap(), 1.5);
        assert!(j.req("missing").is_err());
        assert!(j.req_usize("f").is_err());
    }

    #[test]
    fn expect_str_names_field_and_both_values() {
        let j = Json::parse(r#"{"format":"hlo-text"}"#).unwrap();
        j.expect_str("format", "hlo-text").unwrap();
        let err = j.expect_str("format", "sgbdt").unwrap_err().to_string();
        assert!(err.contains("format") && err.contains("sgbdt") && err.contains("hlo-text"));
        assert!(j.expect_str("missing", "x").is_err());
    }
}
