//! The versioned `.sgbdt` model artifact (DESIGN.md §16).
//!
//! Layout: an 8-byte magic (`SGBDTART`), a little-endian u64 manifest
//! length, a JSON manifest, then a flat little-endian binary payload
//! that *is* the scoring-side state — the [`FlatForest`] breadth-first
//! SoA arrays plus the [`BinCuts`] mappers. Loading is validate-manifest
//! → verify-checksums → map the payload bytes straight into the SoA
//! vectors: no JSON tree walk, no re-flatten, no re-binning of training
//! data to recover cuts.
//!
//! The manifest carries schema version, a config fingerprint, the seed,
//! tree count, loss, a bin-cut digest, per-section byte ranges with
//! FNV-1a 64 checksums, provenance (build string + training wall time),
//! and — for checkpoints — a trainer stanza (mode, trees done, raw RNG
//! state) that makes `asgbdt train --resume` bit-identical to the
//! uninterrupted run (`coordinator/checkpoint.rs`).
//!
//! Every reader failure is a [`SgbdtError`] naming the offending section
//! and the expected-vs-found values; corruption can never surface as a
//! panic or a silently-wrong forest (checksums run before any decode).
//! The writer refuses to emit bytes it cannot itself read back
//! ([`save`] round-trips in memory first).

use std::fmt;
use std::fs;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, Context, Result};

use super::json::Json;
use crate::data::{BinCuts, BinMapper};
use crate::forest::FlatForest;
use crate::tree::FlatTree;

/// File magic: the first 8 bytes of every `.sgbdt` artifact.
pub const MAGIC: [u8; 8] = *b"SGBDTART";

/// The one layout this build writes and reads. Bump on any payload or
/// manifest layout change; the reader rejects anything else with
/// [`SgbdtError::UnknownSchemaVersion`] instead of misparsing bytes.
pub const SCHEMA_VERSION: u64 = 1;

/// Bytes of fixed header before the manifest (magic + manifest length).
const HEADER_LEN: usize = 16;

// ------------------------------------------------------------------ hashing

/// FNV-1a 64 — the section checksum. Hand-rolled (no crates in the
/// offline vendor set); the golden-fixture generator re-implements these
/// two constants in Python, pinned against each other by
/// `tests/test_artifact.rs`.
pub fn fnv64(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// Fixed-width lowercase hex of a u64 — how checksums, digests, seeds
/// and RNG state words are stored in the manifest. JSON numbers are f64
/// (exact only to 2^53), so 64-bit values must travel as strings.
pub fn hex16(v: u64) -> String {
    format!("{v:016x}")
}

fn parse_hex16(s: &str, what: &str) -> std::result::Result<u64, SgbdtError> {
    u64::from_str_radix(s, 16).map_err(|_| SgbdtError::MalformedManifest {
        detail: format!("{what}: not a 64-bit hex value: \"{s}\""),
    })
}

// ------------------------------------------------------------------- errors

/// Every way an artifact can fail to load. Each variant names the
/// offending section and the expected-vs-found values, so a corrupt
/// model in production points at *which bytes* went bad, not just that
/// something did.
#[derive(Debug, Clone, PartialEq)]
pub enum SgbdtError {
    /// The first 8 bytes are not [`MAGIC`] — not an `.sgbdt` file.
    BadMagic {
        /// The bytes actually found at offset 0.
        found: [u8; 8],
    },
    /// The manifest declares a schema this reader does not speak.
    UnknownSchemaVersion {
        /// Version the manifest declares.
        found: u64,
        /// The one version this build reads ([`SCHEMA_VERSION`]).
        supported: u64,
    },
    /// The file ends before a section's declared bytes do.
    Truncated {
        /// Which part ran out of bytes ("header", "manifest", "payload").
        section: String,
        /// Bytes the layout requires.
        expected: u64,
        /// Bytes actually present.
        found: u64,
    },
    /// Manifest `payload_len` disagrees with the bytes after the manifest.
    LengthMismatch {
        /// Payload length the manifest declares.
        manifest: u64,
        /// Payload bytes actually in the file.
        actual: u64,
    },
    /// A section's declared byte range exceeds the payload.
    SectionOutOfBounds {
        /// Section whose range is bad.
        section: String,
        /// `offset + len` the manifest declares.
        end: u64,
        /// Actual payload size.
        payload_len: u64,
    },
    /// A section's bytes hash differently than the manifest recorded.
    ChecksumMismatch {
        /// Section whose checksum failed.
        section: String,
        /// Checksum the manifest recorded.
        expected: u64,
        /// Checksum of the bytes actually present.
        found: u64,
    },
    /// The manifest is not the JSON object the schema requires.
    MalformedManifest {
        /// What was wrong (missing field, bad type, bad value).
        detail: String,
    },
    /// A checksum-valid section decodes to inconsistent structures —
    /// always a writer bug, never silent (the forest is rejected whole).
    MalformedSection {
        /// Section that failed to decode.
        section: String,
        /// What was inconsistent.
        detail: String,
    },
}

impl fmt::Display for SgbdtError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            SgbdtError::BadMagic { found } => write!(
                f,
                "artifact header: bad magic: expected {:?}, found {:?}",
                String::from_utf8_lossy(&MAGIC),
                String::from_utf8_lossy(found)
            ),
            SgbdtError::UnknownSchemaVersion { found, supported } => write!(
                f,
                "manifest field 'schema_version': expected {supported}, found {found} \
                 (artifact written by a different asgbdt build?)"
            ),
            SgbdtError::Truncated {
                section,
                expected,
                found,
            } => write!(
                f,
                "section '{section}': truncated: expected {expected} bytes, found {found}"
            ),
            SgbdtError::LengthMismatch { manifest, actual } => write!(
                f,
                "payload length: manifest declares {manifest} bytes, file carries {actual}"
            ),
            SgbdtError::SectionOutOfBounds {
                section,
                end,
                payload_len,
            } => write!(
                f,
                "section '{section}': declared byte range ends at {end} but the payload \
                 is only {payload_len} bytes"
            ),
            SgbdtError::ChecksumMismatch {
                section,
                expected,
                found,
            } => write!(
                f,
                "section '{section}': checksum mismatch: expected {}, found {}",
                hex16(*expected),
                hex16(*found)
            ),
            SgbdtError::MalformedManifest { detail } => write!(f, "manifest: {detail}"),
            SgbdtError::MalformedSection { section, detail } => {
                write!(f, "section '{section}': {detail}")
            }
        }
    }
}

impl std::error::Error for SgbdtError {}

// ------------------------------------------------------------------- types

/// The checkpoint stanza: which trainer wrote the artifact mid-run and
/// the exact state needed to continue it bit-identically.
#[derive(Debug, Clone, PartialEq)]
pub struct TrainerState {
    /// Trainer mode ("serial", "sync", "async").
    pub mode: String,
    /// Accepted trees at checkpoint time (also the forest's tree count).
    pub trees_done: usize,
    /// Raw xoshiro256** state of the tree-build RNG at the checkpoint
    /// ([`crate::util::Rng::state`]); `None` for the async trainer,
    /// whose determinism comes from the counter-based server RNG, not a
    /// sequential stream.
    pub rng_state: Option<[u64; 4]>,
}

/// What the caller supplies about the training run when writing an
/// artifact (everything else in the manifest is derived from the
/// forest/cuts bytes).
#[derive(Debug, Clone)]
pub struct ArtifactMeta {
    /// [`crate::config::TrainConfig::fingerprint`] of the producing run.
    pub config_fingerprint: String,
    /// Training seed (for provenance; resume trusts `trainer.rng_state`).
    pub seed: u64,
    /// Loss name ("logistic" — the only loss this crate trains).
    pub loss: String,
    /// Training wall time in seconds at write time.
    pub train_secs: f64,
    /// Present iff this artifact is a mid-run checkpoint.
    pub trainer: Option<TrainerState>,
}

/// A fully validated, decoded artifact: the scoring state plus the
/// manifest facts a consumer may want to check or display.
#[derive(Debug, Clone)]
pub struct SgbdtArtifact {
    /// The compiled forest, ready to score (zero re-flatten).
    pub forest: FlatForest,
    /// The training-derived bin cuts (zero re-binning of training data).
    pub cuts: BinCuts,
    /// Schema the artifact was written under.
    pub schema_version: u64,
    /// Config fingerprint of the producing run.
    pub config_fingerprint: String,
    /// Training seed.
    pub seed: u64,
    /// Loss name.
    pub loss: String,
    /// Build string of the producing binary.
    pub build: String,
    /// Training wall time (seconds) when the artifact was written.
    pub train_secs: f64,
    /// Checkpoint stanza, if this artifact is resumable.
    pub trainer: Option<TrainerState>,
}

/// Read-only byte map of an artifact file, the "mmap or read-to-`Vec`
/// fallback behind the same API" seam: every consumer goes through
/// [`PayloadMap::bytes`], so an mmap-backed variant (not available in
/// the offline vendor set — no memmap crate) can slot in without
/// touching any caller.
pub struct PayloadMap {
    bytes: Vec<u8>,
}

impl PayloadMap {
    /// Map a file's bytes read-only.
    pub fn open(path: &Path) -> Result<PayloadMap> {
        Ok(PayloadMap {
            bytes: fs::read(path).with_context(|| format!("read {}", path.display()))?,
        })
    }

    /// The mapped bytes.
    pub fn bytes(&self) -> &[u8] {
        &self.bytes
    }
}

// ------------------------------------------------------------------ writing

fn put_u32(out: &mut Vec<u8>, v: u32) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_u64(out: &mut Vec<u8>, v: u64) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_f32(out: &mut Vec<u8>, v: f32) {
    out.extend_from_slice(&v.to_le_bytes());
}

/// Forest section: u64 tree count, then per tree `f32 step-length, u32
/// node count, feature[] u32, bin[] u8, threshold[] f32, left[] u32,
/// leaf_value[] f32` — the SoA arrays verbatim, in order.
fn encode_forest(forest: &FlatForest) -> Vec<u8> {
    let mut out = Vec::new();
    put_u64(&mut out, forest.n_trees() as u64);
    for (v, t) in &forest.trees {
        put_f32(&mut out, *v);
        put_u32(&mut out, t.n_nodes() as u32);
        for &f in &t.feature {
            put_u32(&mut out, f);
        }
        out.extend_from_slice(&t.bin);
        for &x in &t.threshold {
            put_f32(&mut out, x);
        }
        for &l in &t.left {
            put_u32(&mut out, l);
        }
        for &x in &t.leaf_value {
            put_f32(&mut out, x);
        }
    }
    out
}

/// Cuts section: u64 feature count, then per feature `u8 zero_bin, u32
/// upper-bound count, uppers[] f32`. Offsets are derived state
/// ([`BinCuts::from_mappers`] recomputes them), so they are not stored.
fn encode_cuts(cuts: &BinCuts) -> Vec<u8> {
    let mut out = Vec::new();
    put_u64(&mut out, cuts.n_features() as u64);
    for m in cuts.mappers() {
        out.push(m.zero_bin);
        put_u32(&mut out, m.uppers.len() as u32);
        for &u in &m.uppers {
            put_f32(&mut out, u);
        }
    }
    out
}

fn build_string() -> String {
    concat!("asgbdt-v", env!("CARGO_PKG_VERSION")).to_string()
}

/// Serialize to the on-disk byte layout (header + manifest + payload).
/// Public so tests can corrupt specific bytes without touching disk.
pub fn to_bytes(forest: &FlatForest, cuts: &BinCuts, meta: &ArtifactMeta) -> Vec<u8> {
    to_bytes_with_schema(forest, cuts, meta, SCHEMA_VERSION)
}

/// Test seam: stamp an arbitrary schema version. [`save`]'s self-check
/// makes the writer refuse any version the reader cannot load back.
#[doc(hidden)]
pub fn to_bytes_with_schema(
    forest: &FlatForest,
    cuts: &BinCuts,
    meta: &ArtifactMeta,
    schema_version: u64,
) -> Vec<u8> {
    let forest_bytes = encode_forest(forest);
    let cuts_bytes = encode_cuts(cuts);
    let payload_len = forest_bytes.len() + cuts_bytes.len();
    let sections = Json::Arr(vec![
        Json::obj(vec![
            ("name", Json::Str("forest".into())),
            ("offset", Json::Num(0.0)),
            ("len", Json::Num(forest_bytes.len() as f64)),
            ("checksum", Json::Str(hex16(fnv64(&forest_bytes)))),
        ]),
        Json::obj(vec![
            ("name", Json::Str("cuts".into())),
            ("offset", Json::Num(forest_bytes.len() as f64)),
            ("len", Json::Num(cuts_bytes.len() as f64)),
            ("checksum", Json::Str(hex16(fnv64(&cuts_bytes)))),
        ]),
    ]);
    let mut fields = vec![
        ("format", Json::Str("sgbdt".into())),
        ("schema_version", Json::Num(schema_version as f64)),
        ("config", Json::Str(meta.config_fingerprint.clone())),
        ("seed", Json::Str(hex16(meta.seed))),
        ("n_trees", Json::Num(forest.n_trees() as f64)),
        ("loss", Json::Str(meta.loss.clone())),
        ("base_score", Json::Num(forest.base_score as f64)),
        ("cut_digest", Json::Str(hex16(fnv64(&cuts_bytes)))),
        ("payload_len", Json::Num(payload_len as f64)),
        ("sections", sections),
        (
            "provenance",
            Json::obj(vec![
                ("build", Json::Str(build_string())),
                ("train_secs", Json::Num(meta.train_secs)),
            ]),
        ),
    ];
    if let Some(t) = &meta.trainer {
        fields.push((
            "trainer",
            Json::obj(vec![
                ("mode", Json::Str(t.mode.clone())),
                ("trees", Json::Num(t.trees_done as f64)),
                (
                    "rng",
                    match &t.rng_state {
                        Some(s) => Json::Arr(s.iter().map(|&w| Json::Str(hex16(w))).collect()),
                        None => Json::Null,
                    },
                ),
            ]),
        ));
    }
    let manifest = Json::obj(fields).to_string().into_bytes();
    let mut out = Vec::with_capacity(HEADER_LEN + manifest.len() + payload_len);
    out.extend_from_slice(&MAGIC);
    put_u64(&mut out, manifest.len() as u64);
    out.extend_from_slice(&manifest);
    out.extend_from_slice(&forest_bytes);
    out.extend_from_slice(&cuts_bytes);
    out
}

/// Write an artifact, refusing to emit bytes this build cannot itself
/// read back: the encoded buffer is loaded in memory first, so a
/// schema/layout bug fails at save time, never at deploy time.
pub fn save(path: &Path, forest: &FlatForest, cuts: &BinCuts, meta: &ArtifactMeta) -> Result<()> {
    save_with_schema(path, forest, cuts, meta, SCHEMA_VERSION)
}

/// Test seam behind [`save`] — see [`to_bytes_with_schema`].
#[doc(hidden)]
pub fn save_with_schema(
    path: &Path,
    forest: &FlatForest,
    cuts: &BinCuts,
    meta: &ArtifactMeta,
    schema_version: u64,
) -> Result<()> {
    let bytes = to_bytes_with_schema(forest, cuts, meta, schema_version);
    load_bytes(&bytes).map_err(|e| {
        anyhow!("writer self-check: refusing to emit an artifact this reader cannot load back: {e}")
    })?;
    if let Some(parent) = path.parent() {
        if !parent.as_os_str().is_empty() {
            fs::create_dir_all(parent)
                .with_context(|| format!("create dir {}", parent.display()))?;
        }
    }
    fs::write(path, &bytes).with_context(|| format!("write {}", path.display()))
}

// ------------------------------------------------------------------ reading

/// Probe whether `path` starts with the `.sgbdt` magic (format
/// auto-detection for `serve --model` / `predict --model`, which accept
/// both artifacts and legacy JSON dumps). A file too short to hold the
/// magic is simply "not an artifact", not an error.
pub fn sniff(path: &Path) -> Result<bool> {
    use std::io::Read;
    let mut f = fs::File::open(path).with_context(|| format!("open {}", path.display()))?;
    let mut head = [0u8; 8];
    match f.read_exact(&mut head) {
        Ok(()) => Ok(head == MAGIC),
        Err(_) => Ok(false),
    }
}

/// Load and fully validate an artifact file. Artifact-shaped failures
/// carry a [`SgbdtError`] (downcastable from the returned error);
/// filesystem failures carry the path.
pub fn load(path: &Path) -> Result<SgbdtArtifact> {
    let map = PayloadMap::open(path)?;
    load_bytes(map.bytes()).with_context(|| format!("load {}", path.display()))
}

/// Decode cursor over one checksummed section; every overrun names the
/// section instead of panicking.
struct Cursor<'a> {
    buf: &'a [u8],
    pos: usize,
    section: &'static str,
}

impl<'a> Cursor<'a> {
    fn new(buf: &'a [u8], section: &'static str) -> Cursor<'a> {
        Cursor {
            buf,
            pos: 0,
            section,
        }
    }

    fn take(&mut self, n: usize) -> std::result::Result<&'a [u8], SgbdtError> {
        let end = self.pos.checked_add(n).filter(|&e| e <= self.buf.len());
        match end {
            Some(e) => {
                let s = &self.buf[self.pos..e];
                self.pos = e;
                Ok(s)
            }
            None => Err(SgbdtError::MalformedSection {
                section: self.section.to_string(),
                detail: format!(
                    "needs {n} bytes at offset {}, section holds {}",
                    self.pos,
                    self.buf.len()
                ),
            }),
        }
    }

    fn u8(&mut self) -> std::result::Result<u8, SgbdtError> {
        Ok(self.take(1)?[0])
    }

    fn u32(&mut self) -> std::result::Result<u32, SgbdtError> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn u64(&mut self) -> std::result::Result<u64, SgbdtError> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    fn f32(&mut self) -> std::result::Result<f32, SgbdtError> {
        Ok(f32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn u32s(&mut self, n: usize) -> std::result::Result<Vec<u32>, SgbdtError> {
        let raw = self.take(n.checked_mul(4).unwrap_or(usize::MAX))?;
        Ok(raw.chunks_exact(4).map(|c| u32::from_le_bytes(c.try_into().unwrap())).collect())
    }

    fn f32s(&mut self, n: usize) -> std::result::Result<Vec<f32>, SgbdtError> {
        let raw = self.take(n.checked_mul(4).unwrap_or(usize::MAX))?;
        Ok(raw.chunks_exact(4).map(|c| f32::from_le_bytes(c.try_into().unwrap())).collect())
    }

    fn done(&self) -> std::result::Result<(), SgbdtError> {
        if self.pos != self.buf.len() {
            return Err(SgbdtError::MalformedSection {
                section: self.section.to_string(),
                detail: format!(
                    "{} trailing bytes after the last decoded structure",
                    self.buf.len() - self.pos
                ),
            });
        }
        Ok(())
    }
}

fn decode_forest(
    bytes: &[u8],
    base_score: f32,
) -> std::result::Result<FlatForest, SgbdtError> {
    let bad = |detail: String| SgbdtError::MalformedSection {
        section: "forest".to_string(),
        detail,
    };
    let mut c = Cursor::new(bytes, "forest");
    let n_trees = c.u64()? as usize;
    let mut trees = Vec::with_capacity(n_trees.min(bytes.len() / 8 + 1));
    for ti in 0..n_trees {
        let v = c.f32()?;
        let n = c.u32()? as usize;
        if n == 0 {
            return Err(bad(format!("tree {ti}: zero nodes")));
        }
        let feature = c.u32s(n)?;
        let bin = c.take(n)?.to_vec();
        let threshold = c.f32s(n)?;
        let left = c.u32s(n)?;
        let leaf_value = c.f32s(n)?;
        // structural checks before to_tree (which assumes sane children)
        for (i, &l) in left.iter().enumerate() {
            if l != 0 && (l as usize <= i || l as usize + 1 >= n) {
                return Err(bad(format!(
                    "tree {ti} node {i}: left child {l} breaks the BFS layout \
                     (expected 0 for a leaf, or {} < left, left + 1 < {n})",
                    i
                )));
            }
        }
        let flat = FlatTree {
            feature,
            bin,
            threshold,
            left,
            leaf_value,
        };
        // full validation (every node reachable exactly once, thresholds
        // sane) through the enum twin's validator
        flat.to_tree()
            .validate()
            .map_err(|e| bad(format!("tree {ti}: {e}")))?;
        trees.push((v, flat));
    }
    c.done()?;
    Ok(FlatForest { base_score, trees })
}

fn decode_cuts(bytes: &[u8]) -> std::result::Result<BinCuts, SgbdtError> {
    let mut c = Cursor::new(bytes, "cuts");
    let n_features = c.u64()? as usize;
    let mut mappers = Vec::with_capacity(n_features.min(bytes.len() / 5 + 1));
    for fi in 0..n_features {
        let zero_bin = c.u8()?;
        let n_uppers = c.u32()? as usize;
        let uppers = c.f32s(n_uppers)?;
        if uppers.is_empty() || (zero_bin as usize) >= uppers.len() {
            return Err(SgbdtError::MalformedSection {
                section: "cuts".to_string(),
                detail: format!(
                    "feature {fi}: zero_bin {zero_bin} out of range for {} bins",
                    uppers.len()
                ),
            });
        }
        mappers.push(BinMapper { uppers, zero_bin });
    }
    c.done()?;
    Ok(BinCuts::from_mappers(mappers))
}

/// Decode and validate an in-memory artifact image (the whole-file
/// bytes). This is the entire read path; [`load`] is a thin file
/// wrapper around it.
pub fn load_bytes(bytes: &[u8]) -> std::result::Result<SgbdtArtifact, SgbdtError> {
    let mf = |e: anyhow::Error| SgbdtError::MalformedManifest {
        detail: e.to_string(),
    };
    // -- header
    if bytes.len() < HEADER_LEN {
        return Err(SgbdtError::Truncated {
            section: "header".to_string(),
            expected: HEADER_LEN as u64,
            found: bytes.len() as u64,
        });
    }
    if bytes[..8] != MAGIC {
        return Err(SgbdtError::BadMagic {
            found: bytes[..8].try_into().unwrap(),
        });
    }
    let manifest_len = u64::from_le_bytes(bytes[8..16].try_into().unwrap()) as usize;
    let payload_start = HEADER_LEN.checked_add(manifest_len).unwrap_or(usize::MAX);
    if payload_start > bytes.len() {
        return Err(SgbdtError::Truncated {
            section: "manifest".to_string(),
            expected: manifest_len as u64,
            found: (bytes.len() - HEADER_LEN) as u64,
        });
    }
    // -- manifest
    let text = std::str::from_utf8(&bytes[HEADER_LEN..payload_start]).map_err(|e| {
        SgbdtError::MalformedManifest {
            detail: format!("not UTF-8: {e}"),
        }
    })?;
    let j = Json::parse(text).map_err(mf)?;
    j.expect_str("format", "sgbdt").map_err(mf)?;
    let schema_version = j.req_usize("schema_version").map_err(mf)? as u64;
    if schema_version != SCHEMA_VERSION {
        return Err(SgbdtError::UnknownSchemaVersion {
            found: schema_version,
            supported: SCHEMA_VERSION,
        });
    }
    // -- payload length agreement
    let payload = &bytes[payload_start..];
    let declared = j.req_usize("payload_len").map_err(mf)? as u64;
    if declared != payload.len() as u64 {
        return Err(SgbdtError::LengthMismatch {
            manifest: declared,
            actual: payload.len() as u64,
        });
    }
    // -- sections: bounds then checksums, before any decode
    let mut ranges: Vec<(String, usize, usize, u64)> = Vec::new();
    for s in j
        .req("sections")
        .map_err(mf)?
        .as_arr()
        .ok_or_else(|| SgbdtError::MalformedManifest {
            detail: "field 'sections' is not an array".to_string(),
        })?
    {
        let name = s.req_str("name").map_err(mf)?.to_string();
        let offset = s.req_usize("offset").map_err(mf)?;
        let len = s.req_usize("len").map_err(mf)?;
        let sum = parse_hex16(s.req_str("checksum").map_err(mf)?, "section checksum")?;
        let end = offset.checked_add(len).unwrap_or(usize::MAX);
        if end > payload.len() {
            return Err(SgbdtError::SectionOutOfBounds {
                section: name,
                end: end as u64,
                payload_len: payload.len() as u64,
            });
        }
        let found = fnv64(&payload[offset..end]);
        if found != sum {
            return Err(SgbdtError::ChecksumMismatch {
                section: name,
                expected: sum,
                found,
            });
        }
        ranges.push((name, offset, len, sum));
    }
    let section = |name: &str| -> std::result::Result<&[u8], SgbdtError> {
        ranges
            .iter()
            .find(|(n, _, _, _)| n == name)
            .map(|&(_, off, len, _)| &payload[off..off + len])
            .ok_or_else(|| SgbdtError::MalformedManifest {
                detail: format!("no '{name}' entry in 'sections'"),
            })
    };
    // -- decode (bytes already integrity-checked)
    let base_score = j.req_f64("base_score").map_err(mf)? as f32;
    if !base_score.is_finite() {
        return Err(SgbdtError::MalformedManifest {
            detail: format!("field 'base_score': not finite: {base_score}"),
        });
    }
    let forest = decode_forest(section("forest")?, base_score)?;
    let n_trees = j.req_usize("n_trees").map_err(mf)?;
    if n_trees != forest.n_trees() {
        return Err(SgbdtError::MalformedSection {
            section: "forest".to_string(),
            detail: format!(
                "manifest field 'n_trees' declares {n_trees} trees, payload encodes {}",
                forest.n_trees()
            ),
        });
    }
    let cuts_bytes = section("cuts")?;
    let declared_digest = parse_hex16(j.req_str("cut_digest").map_err(mf)?, "cut_digest")?;
    let found_digest = fnv64(cuts_bytes);
    if declared_digest != found_digest {
        return Err(SgbdtError::ChecksumMismatch {
            section: "cut_digest".to_string(),
            expected: declared_digest,
            found: found_digest,
        });
    }
    let cuts = decode_cuts(cuts_bytes)?;
    // -- provenance + optional trainer stanza
    let prov = j.req("provenance").map_err(mf)?;
    let build = prov.req_str("build").map_err(mf)?.to_string();
    let train_secs = prov.req_f64("train_secs").map_err(mf)?;
    let seed = parse_hex16(j.req_str("seed").map_err(mf)?, "seed")?;
    let trainer = match j.get("trainer") {
        None => None,
        Some(t) => {
            let rng_state = match t.req("rng").map_err(mf)? {
                Json::Null => None,
                Json::Arr(words) if words.len() == 4 => {
                    let mut s = [0u64; 4];
                    for (i, w) in words.iter().enumerate() {
                        let ws = w.as_str().ok_or_else(|| SgbdtError::MalformedManifest {
                            detail: "trainer rng word is not a string".to_string(),
                        })?;
                        s[i] = parse_hex16(ws, "trainer rng word")?;
                    }
                    Some(s)
                }
                other => {
                    return Err(SgbdtError::MalformedManifest {
                        detail: format!("trainer 'rng' must be null or 4 hex words, got {other}"),
                    })
                }
            };
            Some(TrainerState {
                mode: t.req_str("mode").map_err(mf)?.to_string(),
                trees_done: t.req_usize("trees").map_err(mf)?,
                rng_state,
            })
        }
    };
    Ok(SgbdtArtifact {
        forest,
        cuts,
        schema_version,
        config_fingerprint: j.req_str("config").map_err(mf)?.to_string(),
        seed,
        loss: j.req_str("loss").map_err(mf)?.to_string(),
        build,
        train_secs,
        trainer,
    })
}

// --------------------------------------------------------------- checkpoints

/// Per-checkpoint file name: `ck.sgbdt` at tree 20 → `ck.t20.sgbdt`.
/// The base path is also always (re)written as the latest checkpoint,
/// so `--resume <base>` picks up the newest without globbing.
pub fn checkpoint_file(base: &Path, trees: usize) -> PathBuf {
    match base.extension().and_then(|e| e.to_str()) {
        Some(ext) => base.with_extension(format!("t{trees}.{ext}")),
        None => base.with_extension(format!("t{trees}")),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::BinnedDataset;
    use crate::data::CsrMatrix;
    use crate::forest::Forest;
    use crate::tree::{Node, Tree};

    fn meta() -> ArtifactMeta {
        ArtifactMeta {
            config_fingerprint: hex16(0xdead_beef),
            seed: 42,
            loss: "logistic".to_string(),
            train_secs: 1.25,
            trainer: None,
        }
    }

    fn fixture() -> (FlatForest, BinCuts) {
        let x = CsrMatrix::from_dense(4, 2, &[1.0, 0.0, 2.0, 3.0, 0.0, 4.0, 5.0, 6.0]).unwrap();
        let b = BinnedDataset::from_csr(&x, 8).unwrap();
        let mut f = Forest::new(0.5);
        f.push(0.3, Tree {
            nodes: vec![
                Node::Split {
                    feature: 0,
                    bin: 1,
                    threshold: 2.0,
                    left: 1,
                    right: 2,
                },
                Node::Leaf { value: -1.0 },
                Node::Leaf { value: 1.0 },
            ],
        });
        f.push(0.3, Tree::constant(0.25));
        (FlatForest::from_forest(&f), b.cuts())
    }

    #[test]
    fn fnv64_known_vectors() {
        // published FNV-1a 64 test vectors — the Python fixture
        // generator must agree with these exact constants
        assert_eq!(fnv64(b""), 0xcbf2_9ce4_8422_2325);
        assert_eq!(fnv64(b"a"), 0xaf63_dc4c_8601_ec8c);
        assert_eq!(hex16(fnv64(b"")), "cbf29ce484222325");
    }

    #[test]
    fn roundtrip_in_memory_is_exact() {
        let (forest, cuts) = fixture();
        let m = ArtifactMeta {
            trainer: Some(TrainerState {
                mode: "serial".to_string(),
                trees_done: 2,
                rng_state: Some([1, u64::MAX, 3, 0x0123_4567_89ab_cdef]),
            }),
            ..meta()
        };
        let bytes = to_bytes(&forest, &cuts, &m);
        let a = load_bytes(&bytes).unwrap();
        assert_eq!(a.forest.base_score, forest.base_score);
        assert_eq!(a.forest.trees, forest.trees);
        assert_eq!(a.cuts, cuts);
        assert_eq!(a.schema_version, SCHEMA_VERSION);
        assert_eq!(a.seed, 42);
        assert_eq!(a.loss, "logistic");
        assert_eq!(a.config_fingerprint, hex16(0xdead_beef));
        assert_eq!(a.train_secs, 1.25);
        let t = a.trainer.unwrap();
        assert_eq!(t.mode, "serial");
        assert_eq!(t.trees_done, 2);
        // u64::MAX survives (hex strings, not f64 JSON numbers)
        assert_eq!(t.rng_state.unwrap(), [1, u64::MAX, 3, 0x0123_4567_89ab_cdef]);
    }

    #[test]
    fn writer_refuses_schema_it_cannot_read_back() {
        let (forest, cuts) = fixture();
        let dir = std::env::temp_dir().join("asgbdt_artifact_unit");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("future.sgbdt");
        let err = save_with_schema(&path, &forest, &cuts, &meta(), SCHEMA_VERSION + 1)
            .unwrap_err()
            .to_string();
        assert!(err.contains("self-check"), "{err}");
        assert!(err.contains("schema_version"), "{err}");
        assert!(!path.exists(), "refused artifact must not hit disk");
        // the supported version does write, sniffs, and loads
        save(&path, &forest, &cuts, &meta()).unwrap();
        assert!(sniff(&path).unwrap());
        assert_eq!(load(&path).unwrap().forest.trees, forest.trees);
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn sniff_rejects_non_artifacts_without_erroring() {
        let dir = std::env::temp_dir().join("asgbdt_artifact_unit");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("legacy.json");
        std::fs::write(&p, b"{\"base_score\":0.0,\"trees\":[]}").unwrap();
        assert!(!sniff(&p).unwrap());
        let tiny = dir.join("tiny.bin");
        std::fs::write(&tiny, b"abc").unwrap();
        assert!(!sniff(&tiny).unwrap());
        assert!(sniff(&dir.join("missing.sgbdt")).is_err());
    }

    #[test]
    fn checkpoint_file_tags_tree_count_before_extension() {
        assert_eq!(
            checkpoint_file(Path::new("out/ck.sgbdt"), 20),
            PathBuf::from("out/ck.t20.sgbdt")
        );
        assert_eq!(
            checkpoint_file(Path::new("ck"), 7),
            PathBuf::from("ck.t7")
        );
    }
}
