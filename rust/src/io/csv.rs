//! CSV writer for experiment outputs (`results/*.csv`).
//!
//! Quoting follows RFC 4180 for the fields we emit (numbers and simple
//! identifiers; strings are quoted when they contain separators).

use std::io::Write;
use std::path::Path;

use anyhow::{Context, Result};

/// Accumulates rows, writes a CSV file atomically at the end.
#[derive(Debug, Default)]
pub struct CsvWriter {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl CsvWriter {
    /// A writer with the given header row.
    pub fn new(header: &[&str]) -> Self {
        Self {
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    fn quote(field: &str) -> String {
        if field.contains(',') || field.contains('"') || field.contains('\n') {
            format!("\"{}\"", field.replace('"', "\"\""))
        } else {
            field.to_string()
        }
    }

    /// Push one row of stringified fields. Panics if the arity differs from
    /// the header (an arity bug in an experiment driver should be loud).
    pub fn row(&mut self, fields: &[String]) {
        assert_eq!(
            fields.len(),
            self.header.len(),
            "csv row arity {} != header arity {}",
            fields.len(),
            self.header.len()
        );
        self.rows.push(fields.to_vec());
    }

    /// Convenience: push a row of f64s formatted with full precision.
    pub fn row_f64(&mut self, fields: &[f64]) {
        self.row(&fields.iter().map(|x| format!("{x}")).collect::<Vec<_>>());
    }

    /// Rows accumulated so far (header excluded).
    pub fn n_rows(&self) -> usize {
        self.rows.len()
    }

    /// Render to a CSV string.
    pub fn to_string(&self) -> String {
        let mut out = String::new();
        out.push_str(
            &self
                .header
                .iter()
                .map(|h| Self::quote(h))
                .collect::<Vec<_>>()
                .join(","),
        );
        out.push('\n');
        for row in &self.rows {
            out.push_str(
                &row.iter().map(|f| Self::quote(f)).collect::<Vec<_>>().join(","),
            );
            out.push('\n');
        }
        out
    }

    /// Write to `path`, creating parent directories.
    pub fn write(&self, path: &Path) -> Result<()> {
        if let Some(parent) = path.parent() {
            std::fs::create_dir_all(parent)
                .with_context(|| format!("mkdir -p {}", parent.display()))?;
        }
        let mut f = std::fs::File::create(path)
            .with_context(|| format!("create {}", path.display()))?;
        f.write_all(self.to_string().as_bytes())?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_header_and_rows() {
        let mut w = CsvWriter::new(&["a", "b"]);
        w.row(&["1".into(), "x,y".into()]);
        w.row_f64(&[2.5, 3.0]);
        let s = w.to_string();
        assert_eq!(s, "a,b\n1,\"x,y\"\n2.5,3\n");
        assert_eq!(w.n_rows(), 2);
    }

    #[test]
    #[should_panic(expected = "arity")]
    fn arity_mismatch_panics() {
        let mut w = CsvWriter::new(&["a", "b"]);
        w.row(&["1".into()]);
    }

    #[test]
    fn writes_file() {
        let dir = std::env::temp_dir().join("asgbdt_csv_test");
        let path = dir.join("sub/out.csv");
        let mut w = CsvWriter::new(&["x"]);
        w.row(&["quoted \"q\"".into()]);
        w.write(&path).unwrap();
        let body = std::fs::read_to_string(&path).unwrap();
        assert_eq!(body, "x\n\"quoted \"\"q\"\"\"\n");
        std::fs::remove_dir_all(&dir).ok();
    }
}
