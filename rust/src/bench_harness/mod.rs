//! Micro-benchmark harness (criterion is not in the offline vendor set —
//! DESIGN.md §7). Used by all `cargo bench` targets (`harness = false`).
//!
//! Protocol per benchmark: warm up for `warmup_secs`, then run timed
//! iterations until `measure_secs` or `max_iters`, report mean ± std and
//! p50/p99 over per-iteration wall times, with `std::hint::black_box`
//! guarding against dead-code elimination at the call sites.

use std::time::Instant;

use crate::io::Json;
use crate::util::stats::Summary;

/// Harness configuration (env-tunable: ASGBDT_BENCH_FAST=1 shrinks the
/// budget for CI smoke runs).
#[derive(Debug, Clone, Copy)]
pub struct BenchConfig {
    /// Untimed warmup budget before measuring.
    pub warmup_secs: f64,
    /// Measurement budget.
    pub measure_secs: f64,
    /// Measure at least this many iterations (even over budget).
    pub min_iters: usize,
    /// Stop after this many iterations (even under budget).
    pub max_iters: usize,
}

impl Default for BenchConfig {
    fn default() -> Self {
        if std::env::var("ASGBDT_BENCH_FAST").is_ok() {
            BenchConfig {
                warmup_secs: 0.05,
                measure_secs: 0.3,
                min_iters: 3,
                max_iters: 50,
            }
        } else {
            BenchConfig {
                warmup_secs: 0.5,
                measure_secs: 2.0,
                min_iters: 5,
                max_iters: 10_000,
            }
        }
    }
}

/// One benchmark's measured result.
#[derive(Debug, Clone)]
pub struct BenchResult {
    /// Benchmark name (group/case).
    pub name: String,
    /// Iterations measured.
    pub iters: usize,
    /// Per-iteration time distribution.
    pub secs_per_iter: Summary,
}

impl BenchResult {
    /// Mean seconds per iteration.
    pub fn mean(&self) -> f64 {
        self.secs_per_iter.mean
    }

    /// criterion-ish one-liner.
    pub fn line(&self) -> String {
        format!(
            "{:<44} {:>12}/iter  (±{:>10}, p50 {:>10}, p99 {:>10}, n={})",
            self.name,
            fmt_secs(self.secs_per_iter.mean),
            fmt_secs(self.secs_per_iter.std),
            fmt_secs(self.secs_per_iter.p50),
            fmt_secs(self.secs_per_iter.p99),
            self.iters
        )
    }
}

fn fmt_secs(s: f64) -> String {
    if s >= 1.0 {
        format!("{s:.3}s")
    } else if s >= 1e-3 {
        format!("{:.3}ms", s * 1e3)
    } else if s >= 1e-6 {
        format!("{:.3}µs", s * 1e6)
    } else {
        format!("{:.1}ns", s * 1e9)
    }
}

/// The bench runner: collects results, prints a table, optionally writes
/// CSV for EXPERIMENTS.md.
pub struct Runner {
    cfg: BenchConfig,
    results: Vec<BenchResult>,
    group: String,
}

impl Runner {
    /// A runner for a named bench group (one CSV per group).
    pub fn new(group: &str) -> Runner {
        println!("== bench group: {group} ==");
        Runner {
            cfg: BenchConfig::default(),
            results: Vec::new(),
            group: group.to_string(),
        }
    }

    /// Replace the default (env-derived) budget.
    pub fn with_config(mut self, cfg: BenchConfig) -> Runner {
        self.cfg = cfg;
        self
    }

    /// Benchmark a closure. Its return value is black-boxed.
    pub fn bench<T>(&mut self, name: &str, mut f: impl FnMut() -> T) -> &BenchResult {
        // warmup
        let w0 = Instant::now();
        while w0.elapsed().as_secs_f64() < self.cfg.warmup_secs {
            std::hint::black_box(f());
        }
        // measure
        let mut times = Vec::new();
        let m0 = Instant::now();
        while (m0.elapsed().as_secs_f64() < self.cfg.measure_secs
            || times.len() < self.cfg.min_iters)
            && times.len() < self.cfg.max_iters
        {
            let t0 = Instant::now();
            std::hint::black_box(f());
            times.push(t0.elapsed().as_secs_f64());
        }
        let result = BenchResult {
            name: name.to_string(),
            iters: times.len(),
            secs_per_iter: Summary::of(&times),
        };
        println!("{}", result.line());
        self.results.push(result);
        self.results.last().unwrap()
    }

    /// Record an externally-measured scalar (e.g. a simulated wall time)
    /// so it appears in the same table/CSV.
    pub fn record(&mut self, name: &str, secs: f64) {
        let result = BenchResult {
            name: name.to_string(),
            iters: 1,
            secs_per_iter: Summary::of(&[secs]),
        };
        println!("{}", result.line());
        self.results.push(result);
    }

    /// Everything measured so far, in run order.
    pub fn results(&self) -> &[BenchResult] {
        &self.results
    }

    /// Write `results/BENCH_<group>.json` — the machine-readable twin of
    /// the CSV table: every measured result (name, iters, mean/std/p50/
    /// p99 seconds) plus any caller-provided top-level sections (derived
    /// tables like per-config throughput). Deterministic key order (the
    /// [`Json`] object is sorted), so snapshots diff cleanly. Returns the
    /// written path so callers can self-check the snapshot parses.
    pub fn write_json(&self, sections: Vec<(&str, Json)>) -> anyhow::Result<std::path::PathBuf> {
        let results = Json::Arr(
            self.results
                .iter()
                .map(|r| {
                    Json::obj(vec![
                        ("name", Json::Str(r.name.clone())),
                        ("iters", Json::Num(r.iters as f64)),
                        ("mean_s", Json::Num(r.secs_per_iter.mean)),
                        ("std_s", Json::Num(r.secs_per_iter.std)),
                        ("p50_s", Json::Num(r.secs_per_iter.p50)),
                        ("p99_s", Json::Num(r.secs_per_iter.p99)),
                    ])
                })
                .collect(),
        );
        let mut pairs = vec![
            ("group", Json::Str(self.group.clone())),
            ("results", results),
        ];
        pairs.extend(sections);
        let path = std::path::Path::new("results").join(format!("BENCH_{}.json", self.group));
        if let Some(parent) = path.parent() {
            std::fs::create_dir_all(parent)?;
        }
        std::fs::write(&path, Json::obj(pairs).to_string())?;
        println!("-- wrote {}", path.display());
        Ok(path)
    }

    /// Write `results/bench_<group>.csv`.
    pub fn write_csv(&self) -> anyhow::Result<()> {
        let mut w = crate::io::csv::CsvWriter::new(&[
            "group", "name", "iters", "mean_s", "std_s", "p50_s", "p99_s",
        ]);
        for r in &self.results {
            w.row(&[
                self.group.clone(),
                r.name.clone(),
                r.iters.to_string(),
                format!("{:.9}", r.secs_per_iter.mean),
                format!("{:.9}", r.secs_per_iter.std),
                format!("{:.9}", r.secs_per_iter.p50),
                format!("{:.9}", r.secs_per_iter.p99),
            ]);
        }
        let path = std::path::Path::new("results").join(format!("bench_{}.csv", self.group));
        w.write(&path)?;
        println!("-- wrote {}", path.display());
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fast() -> BenchConfig {
        BenchConfig {
            warmup_secs: 0.0,
            measure_secs: 0.01,
            min_iters: 3,
            max_iters: 10,
        }
    }

    #[test]
    fn bench_measures_and_records() {
        let mut r = Runner::new("selftest").with_config(fast());
        let res = r.bench("noop", || 1 + 1).clone();
        assert!(res.iters >= 3);
        assert!(res.mean() >= 0.0);
        r.record("external", 1.5);
        assert_eq!(r.results().len(), 2);
        assert!((r.results()[1].mean() - 1.5).abs() < 1e-12);
    }

    #[test]
    fn write_json_emits_a_parseable_snapshot() {
        let mut r = Runner::new("selftest_json").with_config(fast());
        r.bench("noop", || 1 + 1);
        r.record("external", 0.5);
        let path = r
            .write_json(vec![("extra", Json::obj(vec![("k", Json::Num(1.0))]))])
            .unwrap();
        let back = Json::parse_file(&path).unwrap();
        assert_eq!(back.req_str("group").unwrap(), "selftest_json");
        assert_eq!(back.req("results").unwrap().as_arr().unwrap().len(), 2);
        assert!((back.req("extra").unwrap().req_f64("k").unwrap() - 1.0).abs() < 1e-12);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn fmt_secs_scales_units() {
        assert!(fmt_secs(2.0).ends_with('s'));
        assert!(fmt_secs(2e-3).ends_with("ms"));
        assert!(fmt_secs(2e-6).ends_with("µs"));
        assert!(fmt_secs(2e-9).ends_with("ns"));
    }
}
