//! Squared-error loss for regression:  l(y, F) = ½ (F − y)².
//!
//! Closed forms: l' = F − y, l'' = 1. The eval "error" column is the
//! weighted mean absolute error |F − y| (the natural analogue of the
//! logistic misclassification count for a regression target).
//!
//! Structure mirrors [`super::logistic`] exactly — same zero-weight
//! skip, same f64 accumulator discipline — so the fused per-row kernel
//! and the whole-vector pass stay bit-identical by construction.

use super::GradHess;

/// Per-element loss ½ (F − y)².
#[inline]
pub fn loss_elem(f: f32, y: f32) -> f32 {
    let r = f - y;
    0.5 * r * r
}

/// Per-row target: `(w·l', w·l'')` at margin `f`. The one shared
/// expression both the whole-vector pass ([`grad_hess_loss`]) and the
/// fused sharded accept pass (`ps/shard.rs`) compile.
#[inline]
pub fn grad_hess_at(f: f32, y: f32, w: f32) -> (f32, f32) {
    (w * (f - y), w)
}

/// Whole-vector produce-target pass; same contract as
/// [`super::logistic::grad_hess_loss`].
pub fn grad_hess_loss(f: &[f32], y: &[f32], w: &[f32]) -> GradHess {
    assert_eq!(f.len(), y.len());
    assert_eq!(f.len(), w.len());
    let n = f.len();
    let mut grad = vec![0.0f32; n];
    let mut hess = vec![0.0f32; n];
    let mut loss_sum = 0.0f64;
    let mut weight_sum = 0.0f64;
    for i in 0..n {
        let wi = w[i];
        if wi == 0.0 {
            continue; // padding / unsampled rows are exact no-ops
        }
        let (g, h) = grad_hess_at(f[i], y[i], wi);
        grad[i] = g;
        hess[i] = h;
        loss_sum += (wi * loss_elem(f[i], y[i])) as f64;
        weight_sum += wi as f64;
    }
    GradHess {
        grad,
        hess,
        loss_sum,
        weight_sum,
    }
}

/// Weighted evaluation pass: (loss_sum, abs_err_sum, weight_sum).
pub fn eval_sums(f: &[f32], y: &[f32], w: &[f32]) -> (f64, f64, f64) {
    assert_eq!(f.len(), y.len());
    assert_eq!(f.len(), w.len());
    let mut loss_sum = 0.0f64;
    let mut err_sum = 0.0f64;
    let mut weight_sum = 0.0f64;
    for i in 0..f.len() {
        let wi = w[i] as f64;
        if wi == 0.0 {
            continue;
        }
        loss_sum += wi * loss_elem(f[i], y[i]) as f64;
        err_sum += wi * (f[i] - y[i]).abs() as f64;
        weight_sum += wi;
    }
    (loss_sum, err_sum, weight_sum)
}

/// [`eval_sums`] with the deterministic blocked reduction — see
/// [`super::logistic::eval_sums_blocked`] for why block partials folded
/// in order pin the fused path's eval to the serial path's bitwise.
pub fn eval_sums_blocked(f: &[f32], y: &[f32], w: &[f32], block: usize) -> (f64, f64, f64) {
    assert!(block > 0, "block size must be positive");
    let n = f.len();
    let (mut loss, mut err, mut weight) = (0.0f64, 0.0f64, 0.0f64);
    let mut start = 0usize;
    while start < n {
        let end = (start + block).min(n);
        let (l, e, wsum) = eval_sums(&f[start..end], &y[start..end], &w[start..end]);
        loss += l;
        err += e;
        weight += wsum;
        start = end;
    }
    (loss, err, weight)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn loss_grad_hess_closed_forms() {
        assert_eq!(loss_elem(3.0, 1.0), 2.0);
        let (g, h) = grad_hess_at(3.0, 1.0, 2.0);
        assert_eq!(g, 4.0); // w (F − y)
        assert_eq!(h, 2.0); // w
    }

    #[test]
    fn zero_weight_rows_are_noops() {
        let gh = grad_hess_loss(&[5.0, -3.0], &[0.0, 1.0], &[0.0, 2.0]);
        assert_eq!(gh.grad[0], 0.0);
        assert_eq!(gh.hess[0], 0.0);
        assert!((gh.weight_sum - 2.0).abs() < 1e-12);
    }

    #[test]
    fn grad_hess_at_matches_whole_vector_pass_bitwise() {
        let f = [0.3f32, -0.8, 1.2, 0.0, 4.0];
        let y = [1.0f32, 0.0, 1.0, 0.0, 1.0];
        let w = [1.0f32, 0.0, 2.5, 0.7, 1.0];
        let gh = grad_hess_loss(&f, &y, &w);
        for i in 0..f.len() {
            if w[i] == 0.0 {
                continue;
            }
            let (g, h) = grad_hess_at(f[i], y[i], w[i]);
            assert_eq!(g, gh.grad[i]);
            assert_eq!(h, gh.hess[i]);
        }
    }

    #[test]
    fn eval_reports_absolute_error() {
        let (loss, err, w) = eval_sums(&[1.0, 2.0], &[0.0, 2.0], &[1.0, 3.0]);
        assert!((loss - 0.5).abs() < 1e-12);
        assert!((err - 1.0).abs() < 1e-12); // |1−0|·1 + |2−2|·3
        assert!((w - 4.0).abs() < 1e-12);
    }

    #[test]
    fn blocked_eval_matches_whole_sweep() {
        let n = 513;
        let f: Vec<f32> = (0..n).map(|i| (i as f32) / 100.0 - 2.5).collect();
        let y: Vec<f32> = (0..n).map(|i| ((i * 7) % 10) as f32 / 3.0).collect();
        let w: Vec<f32> = (0..n).map(|i| if i % 5 == 0 { 0.0 } else { 1.0 }).collect();
        let whole = eval_sums_blocked(&f, &y, &w, n);
        for block in [1usize, 64, 512] {
            let b = eval_sums_blocked(&f, &y, &w, block);
            assert!((b.0 - whole.0).abs() < 1e-9 * (1.0 + whole.0.abs()));
            assert!((b.1 - whole.1).abs() < 1e-9 * (1.0 + whole.1.abs()));
            assert_eq!(b.2, whole.2);
        }
    }
}
