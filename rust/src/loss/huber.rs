//! Huber loss for robust regression, kink at |F − y| = δ:
//!
//! ```text
//! l(y, F) = ½ r²             for |r| ≤ δ       (r = F − y)
//!         = δ (|r| − ½ δ)    for |r| > δ
//! ```
//!
//! Closed forms: l' = r (inside), δ·sign(r) (outside); l'' = 1 inside,
//! 0 outside. A zero hessian is safe for leaf fitting because the
//! builder's Newton step divides by H + λ with λ > 0. The eval "error"
//! column is the weighted mean absolute error, same as `squared`.
//!
//! Structure mirrors [`super::logistic`] — zero-weight skip, f64
//! accumulators — so fused and whole-vector passes are bit-identical.

use super::GradHess;

/// Per-element Huber loss at transition width `delta`.
#[inline]
pub fn loss_elem(f: f32, y: f32, delta: f32) -> f32 {
    let r = f - y;
    let a = r.abs();
    if a <= delta {
        0.5 * r * r
    } else {
        delta * (a - 0.5 * delta)
    }
}

/// Per-row target: `(w·l', w·l'')` at margin `f` — the shared expression
/// both the whole-vector pass and the fused accept pass compile.
#[inline]
pub fn grad_hess_at(f: f32, y: f32, w: f32, delta: f32) -> (f32, f32) {
    let r = f - y;
    if r.abs() <= delta {
        (w * r, w)
    } else {
        (w * delta * r.signum(), 0.0)
    }
}

/// Whole-vector produce-target pass; same contract as
/// [`super::logistic::grad_hess_loss`].
pub fn grad_hess_loss(f: &[f32], y: &[f32], w: &[f32], delta: f32) -> GradHess {
    assert_eq!(f.len(), y.len());
    assert_eq!(f.len(), w.len());
    let n = f.len();
    let mut grad = vec![0.0f32; n];
    let mut hess = vec![0.0f32; n];
    let mut loss_sum = 0.0f64;
    let mut weight_sum = 0.0f64;
    for i in 0..n {
        let wi = w[i];
        if wi == 0.0 {
            continue; // padding / unsampled rows are exact no-ops
        }
        let (g, h) = grad_hess_at(f[i], y[i], wi, delta);
        grad[i] = g;
        hess[i] = h;
        loss_sum += (wi * loss_elem(f[i], y[i], delta)) as f64;
        weight_sum += wi as f64;
    }
    GradHess {
        grad,
        hess,
        loss_sum,
        weight_sum,
    }
}

/// Weighted evaluation pass: (loss_sum, abs_err_sum, weight_sum).
pub fn eval_sums(f: &[f32], y: &[f32], w: &[f32], delta: f32) -> (f64, f64, f64) {
    assert_eq!(f.len(), y.len());
    assert_eq!(f.len(), w.len());
    let mut loss_sum = 0.0f64;
    let mut err_sum = 0.0f64;
    let mut weight_sum = 0.0f64;
    for i in 0..f.len() {
        let wi = w[i] as f64;
        if wi == 0.0 {
            continue;
        }
        loss_sum += wi * loss_elem(f[i], y[i], delta) as f64;
        err_sum += wi * (f[i] - y[i]).abs() as f64;
        weight_sum += wi;
    }
    (loss_sum, err_sum, weight_sum)
}

/// [`eval_sums`] with the deterministic blocked reduction (see
/// [`super::logistic::eval_sums_blocked`]).
pub fn eval_sums_blocked(
    f: &[f32],
    y: &[f32],
    w: &[f32],
    delta: f32,
    block: usize,
) -> (f64, f64, f64) {
    assert!(block > 0, "block size must be positive");
    let n = f.len();
    let (mut loss, mut err, mut weight) = (0.0f64, 0.0f64, 0.0f64);
    let mut start = 0usize;
    while start < n {
        let end = (start + block).min(n);
        let (l, e, wsum) = eval_sums(&f[start..end], &y[start..end], &w[start..end], delta);
        loss += l;
        err += e;
        weight += wsum;
        start = end;
    }
    (loss, err, weight)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quadratic_inside_linear_outside() {
        let d = 1.0;
        assert_eq!(loss_elem(0.5, 0.0, d), 0.125);
        // outside: δ(|r| − δ/2) = 1·(3 − 0.5) = 2.5
        assert_eq!(loss_elem(3.0, 0.0, d), 2.5);
        let (g, h) = grad_hess_at(0.5, 0.0, 1.0, d);
        assert_eq!((g, h), (0.5, 1.0));
        let (g, h) = grad_hess_at(-3.0, 0.0, 1.0, d);
        assert_eq!((g, h), (-1.0, 0.0));
    }

    #[test]
    fn loss_is_continuous_at_the_kink() {
        let d = 1.5f32;
        let eps = 1e-4f32;
        let inside = loss_elem(d - eps, 0.0, d);
        let outside = loss_elem(d + eps, 0.0, d);
        assert!((inside - outside).abs() < 1e-3, "{inside} vs {outside}");
        // gradient is continuous too (r → δ·sign(r) at |r| = δ)
        let (gi, _) = grad_hess_at(d - eps, 0.0, 1.0, d);
        let (go, _) = grad_hess_at(d + eps, 0.0, 1.0, d);
        assert!((gi - go).abs() < 1e-3, "{gi} vs {go}");
    }

    #[test]
    fn zero_weight_rows_are_noops() {
        let gh = grad_hess_loss(&[5.0, -3.0], &[0.0, 1.0], &[0.0, 2.0], 1.0);
        assert_eq!(gh.grad[0], 0.0);
        assert_eq!(gh.hess[0], 0.0);
        assert!((gh.weight_sum - 2.0).abs() < 1e-12);
    }

    #[test]
    fn grad_hess_at_matches_whole_vector_pass_bitwise() {
        let d = 0.8f32;
        let f = [0.3f32, -0.8, 1.2, 0.0, 4.0];
        let y = [1.0f32, 0.0, 1.0, 0.0, 1.0];
        let w = [1.0f32, 0.0, 2.5, 0.7, 1.0];
        let gh = grad_hess_loss(&f, &y, &w, d);
        for i in 0..f.len() {
            if w[i] == 0.0 {
                continue;
            }
            let (g, h) = grad_hess_at(f[i], y[i], w[i], d);
            assert_eq!(g, gh.grad[i]);
            assert_eq!(h, gh.hess[i]);
        }
    }

    #[test]
    fn blocked_eval_matches_whole_sweep() {
        let n = 257;
        let f: Vec<f32> = (0..n).map(|i| (i as f32) / 40.0 - 3.0).collect();
        let y: Vec<f32> = (0..n).map(|i| ((i * 3) % 7) as f32 / 2.0).collect();
        let w = vec![1.0f32; n];
        let whole = eval_sums_blocked(&f, &y, &w, 1.0, n);
        for block in [1usize, 64, 256] {
            let b = eval_sums_blocked(&f, &y, &w, 1.0, block);
            assert!((b.0 - whole.0).abs() < 1e-9 * (1.0 + whole.0.abs()));
            assert!((b.1 - whole.1).abs() < 1e-9 * (1.0 + whole.1.abs()));
            assert_eq!(b.2, whole.2);
        }
    }
}
