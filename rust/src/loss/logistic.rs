//! The paper's logistic loss:  p = e^F/(e^F + e^-F) = sigmoid(2F),
//! l(y, F) = -y log p - (1-y) log(1-p), y ∈ {0, 1}.
//!
//! Closed forms: l' = 2(p - y), l'' = 4 p (1 - p).

/// Result of one produce-target pass.
#[derive(Debug, Clone)]
pub struct GradHess {
    /// g_i = w_i * l'(y_i, F_i) — the stochastic target L'_random (Eq. 10).
    pub grad: Vec<f32>,
    /// h_i = w_i * l''(y_i, F_i).
    pub hess: Vec<f32>,
    /// sum_i w_i * l(y_i, F_i).
    pub loss_sum: f64,
    /// sum_i w_i.
    pub weight_sum: f64,
}

/// p = sigmoid(2F).
#[inline]
pub fn prob(f: f32) -> f32 {
    let t = 2.0 * f;
    if t >= 0.0 {
        let e = (-t).exp();
        1.0 / (1.0 + e)
    } else {
        let e = t.exp();
        e / (1.0 + e)
    }
}

/// Numerically stable softplus.
#[inline]
fn softplus(x: f32) -> f32 {
    x.max(0.0) + (-x.abs()).exp().ln_1p()
}

/// Per-element loss l(y, F), stable for |F| >> 1.
#[inline]
pub fn loss_elem(f: f32, y: f32) -> f32 {
    let two_f = 2.0 * f;
    y * softplus(-two_f) + (1.0 - y) * softplus(two_f)
}

/// Per-row target: `(w·l', w·l'')` at margin `f`. The one shared
/// expression every produce-target path compiles — the whole-vector
/// pass ([`grad_hess_loss`]) and the fused sharded accept pass
/// (`ps/shard.rs`) both call this, so their per-row f32 results are
/// bit-identical by construction.
#[inline]
pub fn grad_hess_at(f: f32, y: f32, w: f32) -> (f32, f32) {
    let p = prob(f);
    (w * 2.0 * (p - y), w * 4.0 * p * (1.0 - p))
}

/// Pure-Rust produce-target pass over padded-free vectors; mirrors the
/// L2 model function `grad_hess_loss` in `python/compile/model.py`.
pub fn grad_hess_loss(f: &[f32], y: &[f32], w: &[f32]) -> GradHess {
    assert_eq!(f.len(), y.len());
    assert_eq!(f.len(), w.len());
    let n = f.len();
    let mut grad = vec![0.0f32; n];
    let mut hess = vec![0.0f32; n];
    let mut loss_sum = 0.0f64;
    let mut weight_sum = 0.0f64;
    for i in 0..n {
        let wi = w[i];
        if wi == 0.0 {
            continue; // padding / unsampled rows are exact no-ops
        }
        let (g, h) = grad_hess_at(f[i], y[i], wi);
        grad[i] = g;
        hess[i] = h;
        loss_sum += (wi * loss_elem(f[i], y[i])) as f64;
        weight_sum += wi as f64;
    }
    GradHess {
        grad,
        hess,
        loss_sum,
        weight_sum,
    }
}

/// Weighted evaluation pass: (loss_sum, err_sum, weight_sum); mirrors the
/// L2 `eval_metrics`.
pub fn eval_sums(f: &[f32], y: &[f32], w: &[f32]) -> (f64, f64, f64) {
    assert_eq!(f.len(), y.len());
    assert_eq!(f.len(), w.len());
    let mut loss_sum = 0.0f64;
    let mut err_sum = 0.0f64;
    let mut weight_sum = 0.0f64;
    for i in 0..f.len() {
        let wi = w[i] as f64;
        if wi == 0.0 {
            continue;
        }
        loss_sum += wi * loss_elem(f[i], y[i]) as f64;
        let pred = if f[i] > 0.0 { 1.0 } else { 0.0 };
        err_sum += wi * (pred - y[i]).abs() as f64;
        weight_sum += wi;
    }
    (loss_sum, err_sum, weight_sum)
}

/// [`eval_sums`] with a deterministic blocked reduction: per-`block`
/// partial sums (each starting from 0.0) folded left-to-right in block
/// order. The total is therefore independent of *who* computed each
/// block — a sequential sweep and any contiguous sharding of whole
/// blocks across threads produce bit-identical f64 sums, which is what
/// makes the fused accept path's eval match the serial path exactly.
pub fn eval_sums_blocked(f: &[f32], y: &[f32], w: &[f32], block: usize) -> (f64, f64, f64) {
    assert!(block > 0, "block size must be positive");
    let n = f.len();
    let (mut loss, mut err, mut weight) = (0.0f64, 0.0f64, 0.0f64);
    let mut start = 0usize;
    while start < n {
        let end = (start + block).min(n);
        let (l, e, wsum) = eval_sums(&f[start..end], &y[start..end], &w[start..end]);
        loss += l;
        err += e;
        weight += wsum;
        start = end;
    }
    (loss, err, weight)
}

/// Fold per-block `(loss, err, weight)` partials in block order — the
/// other half of [`eval_sums_blocked`], used when the blocks were filled
/// by sharded threads.
pub fn fold_eval_blocks(blocks: &[(f64, f64, f64)]) -> (f64, f64, f64) {
    let (mut loss, mut err, mut weight) = (0.0f64, 0.0f64, 0.0f64);
    for &(l, e, w) in blocks {
        loss += l;
        err += e;
        weight += w;
    }
    (loss, err, weight)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn prob_is_sigmoid_2f() {
        assert!((prob(0.0) - 0.5).abs() < 1e-7);
        assert!((prob(10.0) - 1.0).abs() < 1e-6);
        assert!(prob(-10.0) < 1e-6);
        // symmetric
        assert!((prob(0.3) + prob(-0.3) - 1.0).abs() < 1e-6);
    }

    #[test]
    fn loss_at_zero_is_log2() {
        assert!((loss_elem(0.0, 0.0) - std::f32::consts::LN_2).abs() < 1e-6);
        assert!((loss_elem(0.0, 1.0) - std::f32::consts::LN_2).abs() < 1e-6);
    }

    #[test]
    fn loss_finite_at_extremes() {
        for &f in &[-80.0f32, 80.0] {
            for &y in &[0.0f32, 1.0] {
                assert!(loss_elem(f, y).is_finite());
            }
        }
        // confident-correct is near zero, confident-wrong is ~2|F|
        assert!(loss_elem(40.0, 1.0) < 1e-6);
        assert!((loss_elem(40.0, 0.0) - 80.0).abs() < 1e-3);
    }

    #[test]
    fn grad_matches_finite_difference() {
        let eps = 1e-3f32;
        for &f in &[-2.0f32, -0.5, 0.0, 0.7, 3.0] {
            for &y in &[0.0f32, 1.0] {
                let g = 2.0 * (prob(f) - y);
                let fd = (loss_elem(f + eps, y) - loss_elem(f - eps, y)) / (2.0 * eps);
                assert!((g - fd).abs() < 1e-3, "f={f} y={y} g={g} fd={fd}");
            }
        }
    }

    #[test]
    fn hess_matches_finite_difference_of_grad() {
        let eps = 1e-3f32;
        for &f in &[-1.5f32, 0.0, 0.9] {
            let h = {
                let p = prob(f);
                4.0 * p * (1.0 - p)
            };
            let g = |f: f32| 2.0 * (prob(f) - 1.0);
            let fd = (g(f + eps) - g(f - eps)) / (2.0 * eps);
            assert!((h - fd).abs() < 1e-2, "f={f} h={h} fd={fd}");
        }
    }

    #[test]
    fn zero_weight_rows_are_noops() {
        let gh = grad_hess_loss(&[5.0, -3.0], &[0.0, 1.0], &[0.0, 2.0]);
        assert_eq!(gh.grad[0], 0.0);
        assert_eq!(gh.hess[0], 0.0);
        assert!((gh.weight_sum - 2.0).abs() < 1e-12);
    }

    #[test]
    fn weights_scale_linearly() {
        let f = [0.3f32, -0.8, 1.2];
        let y = [1.0f32, 0.0, 1.0];
        let w1 = [1.0f32, 1.0, 1.0];
        let w2 = [2.0f32, 2.0, 2.0];
        let a = grad_hess_loss(&f, &y, &w1);
        let b = grad_hess_loss(&f, &y, &w2);
        for i in 0..3 {
            assert!((2.0 * a.grad[i] - b.grad[i]).abs() < 1e-6);
            assert!((2.0 * a.hess[i] - b.hess[i]).abs() < 1e-6);
        }
        assert!((2.0 * a.loss_sum - b.loss_sum).abs() < 1e-9);
    }

    #[test]
    fn grad_hess_at_matches_whole_vector_pass_bitwise() {
        // the shared per-row expression the fused shard kernel compiles
        // must reproduce grad_hess_loss exactly, weight for weight
        let f = [0.3f32, -0.8, 1.2, 0.0, 4.0];
        let y = [1.0f32, 0.0, 1.0, 0.0, 1.0];
        let w = [1.0f32, 0.0, 2.5, 0.7, 1.0];
        let gh = grad_hess_loss(&f, &y, &w);
        for i in 0..f.len() {
            if w[i] == 0.0 {
                continue;
            }
            let (g, h) = grad_hess_at(f[i], y[i], w[i]);
            assert_eq!(g, gh.grad[i]);
            assert_eq!(h, gh.hess[i]);
        }
    }

    #[test]
    fn blocked_eval_is_block_count_invariant() {
        // per-block partials folded in order: identical totals whether the
        // sweep is one block, many blocks, or per-block partials folded
        // from a table — the fused accept path's shard invariance
        let n = 1037;
        let f: Vec<f32> = (0..n).map(|i| ((i * 37 % 100) as f32 - 50.0) / 13.0).collect();
        let y: Vec<f32> = (0..n).map(|i| (i % 2) as f32).collect();
        let w: Vec<f32> = (0..n).map(|i| if i % 7 == 0 { 0.0 } else { 1.5 }).collect();
        let whole = eval_sums_blocked(&f, &y, &w, n);
        for block in [1usize, 64, 512, 513] {
            let b = eval_sums_blocked(&f, &y, &w, block);
            // block partials are each exact; only the fold order could
            // differ, and it is fixed — so totals for the same block size
            // are reproducible, and across block sizes they agree tightly
            let again = eval_sums_blocked(&f, &y, &w, block);
            assert_eq!(b, again, "block={block} not deterministic");
            assert!((b.0 - whole.0).abs() < 1e-9 * (1.0 + whole.0.abs()));
            assert_eq!(b.1, whole.1);
            assert_eq!(b.2, whole.2);
        }
        // folding a precomputed partial table reproduces the sweep bitwise
        let block = 512;
        let mut parts = Vec::new();
        let mut start = 0;
        while start < n {
            let end = (start + block).min(n);
            parts.push(eval_sums(&f[start..end], &y[start..end], &w[start..end]));
            start = end;
        }
        assert_eq!(fold_eval_blocks(&parts), eval_sums_blocked(&f, &y, &w, block));
    }

    #[test]
    fn eval_sums_error_counting() {
        // f>0 predicts 1
        let (loss, err, w) = eval_sums(&[1.0, -1.0, 1.0], &[1.0, 1.0, 0.0], &[1.0; 3]);
        assert!((err - 2.0).abs() < 1e-12); // rows 1 and 2 wrong
        assert!((w - 3.0).abs() < 1e-12);
        assert!(loss > 0.0);
    }
}
