//! The pluggable loss layer: the `loss=` knob ([`LossKind`]) and the
//! scalar dispatch point ([`ScalarLoss`]) compiled into every
//! produce-target path.
//!
//! Design (DESIGN.md §17): the three scalar losses (logistic, squared,
//! huber) share one margin vector and one per-row `(w·l', w·l'')`
//! expression, so the fused sharded accept pass (`ps/shard.rs`), the
//! whole-vector fallback ([`crate::runtime::GradientEngine`]) and the
//! serial reference sweeps all stay bit-identical per loss — exactly
//! the discipline the logistic path already obeys. Multiclass softmax
//! is *not* a [`ScalarLoss`]: it carries K class-major margin vectors
//! and goes through its own whole-vector accept path in `ps/server.rs`
//! (the same shape as the AOT bucket fallback), so the scalar kernels
//! never see it.

use anyhow::{bail, Result};

use super::{huber, logistic, multiclass, squared, GradHess};

/// Which objective the run trains (`loss=` knob).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LossKind {
    /// Binary logistic loss on y ∈ {0, 1} — the paper's objective and
    /// the default.
    Logistic,
    /// Squared error ½(F − y)² for regression targets.
    Squared,
    /// Huber loss for robust regression; transition width `huber_delta`.
    Huber,
    /// K-class softmax over `n_classes` parallel margin vectors.
    Multiclass,
}

impl LossKind {
    /// Parse the `loss=` knob.
    ///
    /// ```
    /// use asgbdt::loss::LossKind;
    /// assert_eq!(LossKind::parse("huber").unwrap(), LossKind::Huber);
    /// assert_eq!(LossKind::parse("logistic").unwrap(), LossKind::default());
    /// assert!(LossKind::parse("hinge").is_err());
    /// ```
    pub fn parse(s: &str) -> Result<LossKind> {
        match s {
            "logistic" => Ok(LossKind::Logistic),
            "squared" => Ok(LossKind::Squared),
            "huber" => Ok(LossKind::Huber),
            "multiclass" => Ok(LossKind::Multiclass),
            other => bail!(
                "unknown loss '{other}' (expected 'logistic', 'squared', 'huber' or 'multiclass')"
            ),
        }
    }

    /// The knob spelling (inverse of [`LossKind::parse`]); also the name
    /// recorded in `.sgbdt` artifact manifests.
    pub fn as_str(&self) -> &'static str {
        match self {
            LossKind::Logistic => "logistic",
            LossKind::Squared => "squared",
            LossKind::Huber => "huber",
            LossKind::Multiclass => "multiclass",
        }
    }
}

impl Default for LossKind {
    fn default() -> Self {
        LossKind::Logistic
    }
}

/// A scalar (single-margin-vector) loss, dispatched per row inside the
/// fused accept kernel and per vector inside the gradient engine. `Copy`
/// so it travels by value into [`crate::ps::AcceptInputs`] and shard
/// closures.
///
/// The `Logistic` arm delegates verbatim to [`logistic`] — same
/// functions the pre-pluggable code called — so logistic runs are
/// bit-identical to the logistic-only trainer.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum ScalarLoss {
    /// Binary logistic loss.
    Logistic,
    /// Squared error.
    Squared,
    /// Huber loss with its transition width δ.
    Huber(f32),
}

impl ScalarLoss {
    /// Per-row target `(w·l', w·l'')` at margin `f` — the one shared
    /// expression the fused shard kernel and the whole-vector pass both
    /// compile (see [`logistic::grad_hess_at`]).
    #[inline]
    pub fn grad_hess_at(self, f: f32, y: f32, w: f32) -> (f32, f32) {
        match self {
            ScalarLoss::Logistic => logistic::grad_hess_at(f, y, w),
            ScalarLoss::Squared => squared::grad_hess_at(f, y, w),
            ScalarLoss::Huber(d) => huber::grad_hess_at(f, y, w, d),
        }
    }

    /// Per-element loss l(y, F).
    #[inline]
    pub fn loss_elem(self, f: f32, y: f32) -> f32 {
        match self {
            ScalarLoss::Logistic => logistic::loss_elem(f, y),
            ScalarLoss::Squared => squared::loss_elem(f, y),
            ScalarLoss::Huber(d) => huber::loss_elem(f, y, d),
        }
    }

    /// Whole-vector produce-target pass (the AOT-style bucket fallback
    /// and the serial reference path).
    pub fn grad_hess_loss(self, f: &[f32], y: &[f32], w: &[f32]) -> GradHess {
        match self {
            ScalarLoss::Logistic => logistic::grad_hess_loss(f, y, w),
            ScalarLoss::Squared => squared::grad_hess_loss(f, y, w),
            ScalarLoss::Huber(d) => huber::grad_hess_loss(f, y, w, d),
        }
    }

    /// Weighted evaluation pass: (loss_sum, err_sum, weight_sum).
    pub fn eval_sums(self, f: &[f32], y: &[f32], w: &[f32]) -> (f64, f64, f64) {
        match self {
            ScalarLoss::Logistic => logistic::eval_sums(f, y, w),
            ScalarLoss::Squared => squared::eval_sums(f, y, w),
            ScalarLoss::Huber(d) => huber::eval_sums(f, y, w, d),
        }
    }

    /// [`ScalarLoss::eval_sums`] with the deterministic blocked
    /// reduction that pins fused-path evals to the serial path bitwise.
    pub fn eval_sums_blocked(
        self,
        f: &[f32],
        y: &[f32],
        w: &[f32],
        block: usize,
    ) -> (f64, f64, f64) {
        match self {
            ScalarLoss::Logistic => logistic::eval_sums_blocked(f, y, w, block),
            ScalarLoss::Squared => squared::eval_sums_blocked(f, y, w, block),
            ScalarLoss::Huber(d) => huber::eval_sums_blocked(f, y, w, d, block),
        }
    }
}

impl Default for ScalarLoss {
    fn default() -> Self {
        ScalarLoss::Logistic
    }
}

/// The base (tree-zero) margin for a scalar loss: the constant F that
/// minimises the weighted training loss, mirroring the logistic path's
/// positive-rate logit. For squared/huber this is the weighted label
/// mean (huber shares it — exact for symmetric residuals, and the
/// boosting rounds correct any remainder).
pub fn scalar_base_score(loss: ScalarLoss, y: &[f32], positive_rate: f64) -> f32 {
    match loss {
        ScalarLoss::Logistic => {
            crate::forest::Forest::base_from_positive_rate(positive_rate)
        }
        ScalarLoss::Squared | ScalarLoss::Huber(_) => {
            if y.is_empty() {
                return 0.0;
            }
            let sum: f64 = y.iter().map(|&v| v as f64).sum();
            (sum / y.len() as f64) as f32
        }
    }
}

/// Re-export point for the multiclass kernels so callers can treat
/// `loss::kernel` as the dispatch hub (`multiclass` has no
/// [`ScalarLoss`] arm — see the module docs).
pub use multiclass::{eval_sums as multiclass_eval_sums, grad_hess_class};

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_roundtrips_every_kind() {
        for kind in [
            LossKind::Logistic,
            LossKind::Squared,
            LossKind::Huber,
            LossKind::Multiclass,
        ] {
            assert_eq!(LossKind::parse(kind.as_str()).unwrap(), kind);
        }
        let err = LossKind::parse("absolute").unwrap_err().to_string();
        assert!(err.contains("unknown loss"), "{err}");
    }

    #[test]
    fn logistic_arm_is_the_legacy_kernel_bitwise() {
        let f = [0.3f32, -0.8, 1.2, 0.0];
        let y = [1.0f32, 0.0, 1.0, 0.0];
        let w = [1.0f32, 0.5, 2.5, 0.0];
        let a = ScalarLoss::Logistic.grad_hess_loss(&f, &y, &w);
        let b = logistic::grad_hess_loss(&f, &y, &w);
        assert_eq!(a.grad, b.grad);
        assert_eq!(a.hess, b.hess);
        assert_eq!(a.loss_sum, b.loss_sum);
        assert_eq!(
            ScalarLoss::Logistic.eval_sums_blocked(&f, &y, &w, 2),
            logistic::eval_sums_blocked(&f, &y, &w, 2)
        );
    }

    #[test]
    fn dispatch_reaches_each_kernel() {
        let (g, h) = ScalarLoss::Squared.grad_hess_at(3.0, 1.0, 1.0);
        assert_eq!((g, h), (2.0, 1.0));
        let (g, h) = ScalarLoss::Huber(1.0).grad_hess_at(3.0, 0.0, 1.0);
        assert_eq!((g, h), (1.0, 0.0));
        let (g, _) = ScalarLoss::Logistic.grad_hess_at(0.0, 1.0, 1.0);
        assert_eq!(g, -1.0);
    }

    #[test]
    fn base_scores_per_loss() {
        let y = [1.0f32, 2.0, 3.0, 6.0];
        let b = scalar_base_score(ScalarLoss::Squared, &y, 0.5);
        assert!((b - 3.0).abs() < 1e-6);
        let b = scalar_base_score(ScalarLoss::Huber(1.0), &y, 0.5);
        assert!((b - 3.0).abs() < 1e-6);
        // logistic ignores y and uses the positive-rate logit
        let b = scalar_base_score(ScalarLoss::Logistic, &y, 0.5);
        assert_eq!(b, 0.0);
        assert_eq!(scalar_base_score(ScalarLoss::Squared, &[], 0.5), 0.0);
    }
}
