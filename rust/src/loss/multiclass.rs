//! Multiclass softmax loss over K parallel margin vectors.
//!
//! Layout: the margin state is **class-major** — one `Vec<f32>` of
//! length `K · n`, where class `c`'s margin for row `i` lives at
//! `f[c · n + i]`. Labels are integer class ids `0 ≤ y < K` stored in
//! the dataset's `f32` label vector. With
//!
//! ```text
//! p_c(i) = exp(F_c(i)) / Σ_j exp(F_j(i))      (stable: max-shifted)
//! l(y, F) = −log p_y
//! ```
//!
//! the per-class diagonal-Newton targets are the standard softmax forms
//! l'_c = p_c − 1{y = c} and l''_c = p_c (1 − p_c).
//!
//! The eval "error" column counts argmax misclassifications (ties break
//! toward the lowest class id, matching a first-max scan).

use super::GradHess;

/// Stable in-place softmax of one row's K scores.
#[inline]
pub fn softmax(scores: &mut [f32]) {
    let m = scores.iter().copied().fold(f32::NEG_INFINITY, f32::max);
    let mut sum = 0.0f32;
    for s in scores.iter_mut() {
        *s = (*s - m).exp();
        sum += *s;
    }
    for s in scores.iter_mut() {
        *s /= sum;
    }
}

/// Copy row `i`'s K margins out of the class-major state `f` (length
/// `k · n`) into `out` and softmax them in place.
#[inline]
pub fn probs_at(f: &[f32], k: usize, n: usize, i: usize, out: &mut [f32]) {
    debug_assert_eq!(f.len(), k * n);
    debug_assert_eq!(out.len(), k);
    for (c, o) in out.iter_mut().enumerate() {
        *o = f[c * n + i];
    }
    softmax(out);
}

/// Per-row loss −log p_y via the max-shifted log-sum-exp (stable for
/// margins far from zero). `scores` is the row's K raw margins.
#[inline]
pub fn loss_elem(scores: &[f32], y_class: usize) -> f32 {
    let m = scores.iter().copied().fold(f32::NEG_INFINITY, f32::max);
    let lse: f32 = scores.iter().map(|&s| (s - m).exp()).sum::<f32>().ln() + m;
    lse - scores[y_class]
}

/// Whole-vector produce-target pass for **one class** `c`: grad/hess of
/// length `n` against the class-major margin state `f` (length `k · n`).
/// Same zero-weight-skip contract as [`super::logistic::grad_hess_loss`];
/// `loss_sum` is the full softmax loss (summed once, not per class).
pub fn grad_hess_class(f: &[f32], y: &[f32], w: &[f32], k: usize, c: usize) -> GradHess {
    let n = y.len();
    assert_eq!(f.len(), k * n);
    assert_eq!(w.len(), n);
    assert!(c < k);
    let mut grad = vec![0.0f32; n];
    let mut hess = vec![0.0f32; n];
    let mut loss_sum = 0.0f64;
    let mut weight_sum = 0.0f64;
    let mut scores = vec![0.0f32; k];
    for i in 0..n {
        let wi = w[i];
        if wi == 0.0 {
            continue; // padding / unsampled rows are exact no-ops
        }
        for (cc, s) in scores.iter_mut().enumerate() {
            *s = f[cc * n + i];
        }
        let yc = y[i] as usize;
        loss_sum += (wi * loss_elem(&scores, yc)) as f64;
        weight_sum += wi as f64;
        softmax(&mut scores);
        let p = scores[c];
        let ind = if yc == c { 1.0 } else { 0.0 };
        grad[i] = wi * (p - ind);
        hess[i] = wi * p * (1.0 - p);
    }
    GradHess {
        grad,
        hess,
        loss_sum,
        weight_sum,
    }
}

/// Weighted evaluation pass over the class-major state: (softmax
/// loss_sum, argmax misclassification count, weight_sum).
pub fn eval_sums(f: &[f32], y: &[f32], w: &[f32], k: usize) -> (f64, f64, f64) {
    let n = y.len();
    assert_eq!(f.len(), k * n);
    assert_eq!(w.len(), n);
    let mut loss_sum = 0.0f64;
    let mut err_sum = 0.0f64;
    let mut weight_sum = 0.0f64;
    let mut scores = vec![0.0f32; k];
    for i in 0..n {
        let wi = w[i] as f64;
        if wi == 0.0 {
            continue;
        }
        for (cc, s) in scores.iter_mut().enumerate() {
            *s = f[cc * n + i];
        }
        let yc = y[i] as usize;
        loss_sum += wi * loss_elem(&scores, yc) as f64;
        let mut best = 0usize;
        for (cc, &s) in scores.iter().enumerate() {
            if s > scores[best] {
                best = cc;
            }
        }
        if best != yc {
            err_sum += wi;
        }
        weight_sum += wi;
    }
    (loss_sum, err_sum, weight_sum)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn softmax_sums_to_one_and_orders() {
        let mut s = [1.0f32, 2.0, 0.5];
        softmax(&mut s);
        let total: f32 = s.iter().sum();
        assert!((total - 1.0).abs() < 1e-6);
        assert!(s[1] > s[0] && s[0] > s[2]);
    }

    #[test]
    fn softmax_is_shift_stable() {
        let mut a = [1000.0f32, 1001.0, 999.0];
        softmax(&mut a);
        let mut b = [0.0f32, 1.0, -1.0];
        softmax(&mut b);
        for (x, y) in a.iter().zip(&b) {
            assert!((x - y).abs() < 1e-6);
        }
    }

    #[test]
    fn uniform_scores_give_log_k_loss() {
        let scores = [0.0f32; 4];
        assert!((loss_elem(&scores, 2) - (4.0f32).ln()).abs() < 1e-6);
    }

    #[test]
    fn grads_sum_to_zero_across_classes() {
        // Σ_c (p_c − 1{y=c}) = 1 − 1 = 0 per row
        let k = 3;
        let n = 5;
        let f: Vec<f32> = (0..k * n).map(|i| ((i * 13 % 17) as f32 - 8.0) / 4.0).collect();
        let y = vec![0.0f32, 1.0, 2.0, 1.0, 0.0];
        let w = vec![1.0f32, 2.0, 1.0, 0.0, 1.5];
        let per_class: Vec<GradHess> =
            (0..k).map(|c| grad_hess_class(&f, &y, &w, k, c)).collect();
        for i in 0..n {
            let s: f32 = per_class.iter().map(|gh| gh.grad[i]).sum();
            assert!(s.abs() < 1e-5, "row {i}: grads sum to {s}");
        }
        // zero-weight row is a no-op in every class
        for gh in &per_class {
            assert_eq!(gh.grad[3], 0.0);
            assert_eq!(gh.hess[3], 0.0);
        }
    }

    #[test]
    fn eval_counts_argmax_errors() {
        // 2 rows, k=2, class-major: f = [f0(r0), f0(r1), f1(r0), f1(r1)]
        let f = [2.0f32, -1.0, 0.0, 1.0]; // row0 → class 0, row1 → class 1
        let y = [0.0f32, 0.0];
        let w = [1.0f32, 1.0];
        let (loss, err, wsum) = eval_sums(&f, &y, &w, 2);
        assert!((err - 1.0).abs() < 1e-12); // row1 predicted 1, labelled 0
        assert!((wsum - 2.0).abs() < 1e-12);
        assert!(loss > 0.0);
    }

    #[test]
    fn probs_at_reads_class_major_layout() {
        let n = 2;
        let f = [0.0f32, 5.0, 1.0, 5.0, 2.0, 5.0]; // k=3: row0 scores 0,1,2
        let mut p = [0.0f32; 3];
        probs_at(&f, 3, n, 0, &mut p);
        assert!(p[2] > p[1] && p[1] > p[0]);
        probs_at(&f, 3, n, 1, &mut p);
        for v in p {
            assert!((v - 1.0 / 3.0).abs() < 1e-6); // row1 scores all 5.0
        }
    }
}
