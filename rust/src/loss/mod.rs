//! Loss kernels (the paper's §III.A logistic parameterisation plus the
//! pluggable regression/multiclass objectives) and evaluation metrics.
//!
//! `logistic` is the pure-Rust implementation — the cross-check oracle and
//! fallback for the AOT (JAX/Pallas → HLO) path executed by [`crate::runtime`].
//! Numerics are pinned to `python/compile/kernels/ref.py` by tests in
//! `rust/tests/test_runtime.rs`. `squared`, `huber` and `multiclass`
//! mirror its structure; `kernel` is the dispatch layer (`loss=` knob +
//! [`ScalarLoss`]) the engine and the fused accept pass compile against.
//! Conformance (finite-difference grad/hess checks, bit-identity across
//! execution paths) is pinned by `rust/tests/test_loss.rs`.

pub mod huber;
pub mod kernel;
pub mod logistic;
pub mod metrics;
pub mod multiclass;
pub mod squared;

pub use kernel::{scalar_base_score, LossKind, ScalarLoss};
pub use logistic::{grad_hess_loss, GradHess};
pub use metrics::{accuracy, auc, error_rate, logloss, mae, rmse};
