//! Logistic loss (the paper's §III.A parameterisation) and evaluation
//! metrics.
//!
//! `logistic` is the pure-Rust implementation — the cross-check oracle and
//! fallback for the AOT (JAX/Pallas → HLO) path executed by [`crate::runtime`].
//! Numerics are pinned to `python/compile/kernels/ref.py` by tests in
//! `rust/tests/test_runtime.rs`.

pub mod logistic;
pub mod metrics;

pub use logistic::{grad_hess_loss, GradHess};
pub use metrics::{accuracy, auc, error_rate, logloss};
