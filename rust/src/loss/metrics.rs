//! Evaluation metrics on raw margins F (threshold at 0).

use super::logistic::loss_elem;

/// Weighted mean logloss.
pub fn logloss(f: &[f32], y: &[f32], w: &[f32]) -> f64 {
    assert_eq!(f.len(), y.len());
    assert_eq!(f.len(), w.len());
    let mut num = 0.0f64;
    let mut den = 0.0f64;
    for i in 0..f.len() {
        num += (w[i] * loss_elem(f[i], y[i])) as f64;
        den += w[i] as f64;
    }
    if den > 0.0 {
        num / den
    } else {
        0.0
    }
}

/// Weighted misclassification rate (F > 0 predicts class 1).
pub fn error_rate(f: &[f32], y: &[f32], w: &[f32]) -> f64 {
    assert_eq!(f.len(), y.len());
    let mut num = 0.0f64;
    let mut den = 0.0f64;
    for i in 0..f.len() {
        let pred = if f[i] > 0.0 { 1.0 } else { 0.0 };
        num += (w[i] * (pred - y[i]).abs()) as f64;
        den += w[i] as f64;
    }
    if den > 0.0 {
        num / den
    } else {
        0.0
    }
}

/// Weighted accuracy.
pub fn accuracy(f: &[f32], y: &[f32], w: &[f32]) -> f64 {
    1.0 - error_rate(f, y, w)
}

/// Weighted root-mean-square error of raw predictions against labels.
pub fn rmse(f: &[f32], y: &[f32], w: &[f32]) -> f64 {
    assert_eq!(f.len(), y.len());
    assert_eq!(f.len(), w.len());
    let mut num = 0.0f64;
    let mut den = 0.0f64;
    for i in 0..f.len() {
        let r = (f[i] - y[i]) as f64;
        num += w[i] as f64 * r * r;
        den += w[i] as f64;
    }
    if den > 0.0 {
        (num / den).sqrt()
    } else {
        0.0
    }
}

/// Weighted mean absolute error of raw predictions against labels.
pub fn mae(f: &[f32], y: &[f32], w: &[f32]) -> f64 {
    assert_eq!(f.len(), y.len());
    assert_eq!(f.len(), w.len());
    let mut num = 0.0f64;
    let mut den = 0.0f64;
    for i in 0..f.len() {
        num += w[i] as f64 * (f[i] - y[i]).abs() as f64;
        den += w[i] as f64;
    }
    if den > 0.0 {
        num / den
    } else {
        0.0
    }
}

/// Weighted ROC-AUC via the rank statistic (ties get midranks).
pub fn auc(f: &[f32], y: &[f32], w: &[f32]) -> f64 {
    assert_eq!(f.len(), y.len());
    let n = f.len();
    let mut order: Vec<usize> = (0..n).collect();
    order.sort_by(|&a, &b| f[a].partial_cmp(&f[b]).unwrap());
    // midrank assignment over ties
    let mut rank = vec![0.0f64; n];
    let mut i = 0;
    let mut cum = 0.0f64; // weighted rank position
    while i < n {
        let mut j = i;
        let mut tie_w = 0.0f64;
        while j < n && f[order[j]] == f[order[i]] {
            tie_w += w[order[j]] as f64;
            j += 1;
        }
        // weighted midrank: cum + tie_w/2
        for k in i..j {
            rank[order[k]] = cum + tie_w / 2.0;
        }
        cum += tie_w;
        i = j;
    }
    let mut pos_w = 0.0f64;
    let mut neg_w = 0.0f64;
    let mut pos_rank_sum = 0.0f64;
    for k in 0..n {
        let wk = w[k] as f64;
        if y[k] > 0.5 {
            pos_w += wk;
            pos_rank_sum += wk * rank[k];
        } else {
            neg_w += wk;
        }
    }
    if pos_w == 0.0 || neg_w == 0.0 {
        return 0.5;
    }
    // Wilcoxon–Mann–Whitney with weighted midranks:
    // AUC = (sum of positive ranks - pos_w^2/2) / (pos_w * neg_w)
    (pos_rank_sum - pos_w * pos_w / 2.0) / (pos_w * neg_w)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn logloss_random_classifier_is_log2() {
        let f = vec![0.0f32; 100];
        let y: Vec<f32> = (0..100).map(|i| (i % 2) as f32).collect();
        let w = vec![1.0f32; 100];
        assert!((logloss(&f, &y, &w) - std::f64::consts::LN_2).abs() < 1e-6);
    }

    #[test]
    fn error_rate_perfect_and_worst() {
        let y: Vec<f32> = (0..10).map(|i| (i % 2) as f32).collect();
        let right: Vec<f32> = y.iter().map(|&v| (v - 0.5) * 4.0).collect();
        let wrong: Vec<f32> = y.iter().map(|&v| (0.5 - v) * 4.0).collect();
        let w = vec![1.0f32; 10];
        assert_eq!(error_rate(&right, &y, &w), 0.0);
        assert_eq!(error_rate(&wrong, &y, &w), 1.0);
        assert_eq!(accuracy(&right, &y, &w), 1.0);
    }

    #[test]
    fn auc_perfect_separation_is_one() {
        let f = vec![-2.0f32, -1.0, 1.0, 2.0];
        let y = vec![0.0f32, 0.0, 1.0, 1.0];
        let w = vec![1.0f32; 4];
        assert!((auc(&f, &y, &w) - 1.0).abs() < 1e-9);
    }

    #[test]
    fn auc_reversed_is_zero() {
        let f = vec![2.0f32, 1.0, -1.0, -2.0];
        let y = vec![0.0f32, 0.0, 1.0, 1.0];
        let w = vec![1.0f32; 4];
        assert!(auc(&f, &y, &w).abs() < 1e-9);
    }

    #[test]
    fn auc_ties_give_half() {
        let f = vec![0.5f32; 6];
        let y = vec![0.0f32, 1.0, 0.0, 1.0, 0.0, 1.0];
        let w = vec![1.0f32; 6];
        assert!((auc(&f, &y, &w) - 0.5).abs() < 1e-9);
    }

    #[test]
    fn auc_degenerate_classes_half() {
        let f = vec![0.1f32, 0.2];
        assert_eq!(auc(&f, &[1.0, 1.0], &[1.0, 1.0]), 0.5);
    }

    #[test]
    fn regression_metrics_match_hand_sums() {
        let f = vec![1.0f32, 3.0, -2.0];
        let y = vec![0.0f32, 1.0, -2.0];
        let w = vec![1.0f32; 3];
        // residuals 1, 2, 0 => rmse sqrt(5/3), mae 1
        assert!((rmse(&f, &y, &w) - (5.0f64 / 3.0).sqrt()).abs() < 1e-12);
        assert!((mae(&f, &y, &w) - 1.0).abs() < 1e-12);
        assert_eq!(rmse(&[], &[], &[]), 0.0);
    }

    #[test]
    fn weights_matter() {
        // one heavily weighted wrong sample dominates error rate
        let f = vec![1.0f32, -1.0];
        let y = vec![1.0f32, 1.0];
        assert!((error_rate(&f, &y, &[1.0, 9.0]) - 0.9).abs() < 1e-9);
    }
}
