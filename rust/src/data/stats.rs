//! Dataset diversity statistics — the quantities the paper's scalability
//! analysis is built on (Ω, Δ, and the sparsity of the observed Q′ vector;
//! §V.B "Analysis: Scalability and Sensitivity" and Figure 4).

use std::collections::HashMap;

use crate::data::Dataset;

/// Species-level view of a dataset: distinct (x, y) rows and how often
/// each occurs (the paper's m_j multiplicities, recovered from data).
#[derive(Debug, Clone)]
pub struct SpeciesTable {
    /// multiplicity (weighted count) per species
    pub counts: Vec<f64>,
    /// species id per row
    pub row_species: Vec<u32>,
}

impl SpeciesTable {
    /// Group a dataset's rows into species (identical feature vector +
    /// label) and accumulate multiplicities.
    pub fn build(ds: &Dataset) -> SpeciesTable {
        let mut ids: HashMap<(u64, u32), u32> = HashMap::new();
        let mut counts: Vec<f64> = Vec::new();
        let mut row_species = Vec::with_capacity(ds.n_rows());
        for r in 0..ds.n_rows() {
            let key = (ds.x.row_fingerprint(r), ds.y[r].to_bits());
            let id = *ids.entry(key).or_insert_with(|| {
                counts.push(0.0);
                (counts.len() - 1) as u32
            });
            counts[id as usize] += ds.m[r] as f64;
            row_species.push(id);
        }
        SpeciesTable { counts, row_species }
    }

    /// Number of distinct species (the paper's Ω).
    pub fn n_species(&self) -> usize {
        self.counts.len()
    }

    /// Total weight over species.
    pub fn total(&self) -> f64 {
        self.counts.iter().sum()
    }

    /// Diversity ratio: n_species / n_rows ∈ (0, 1].
    pub fn diversity_ratio(&self) -> f64 {
        self.n_species() as f64 / self.row_species.len().max(1) as f64
    }
}

/// Analytic diversity report for a dataset under a uniform sampling rate r
/// (all R_ij = r), matching the paper's notation:
///
/// * `omega` — Ω: the number of species (max support of Q′).
/// * `delta` — Δ = max_i P(Q'_i = 1) = max_i 1 - (1-r)^{m_i}.
/// * `qprime_density` — E[#(Q'_i = 1)] / Ω: expected density of the
///   observed Q′ vector in one sampling pass.
/// * `rho` — probability two independent sampling passes overlap in at
///   least one species: 1 - Π_i (1 - P(Q'_i=1)^2)... computed in log space.
#[derive(Debug, Clone)]
pub struct DiversityReport {
    /// Sample count of the dataset.
    pub n_rows: usize,
    /// Ω — number of species.
    pub omega: usize,
    /// Δ — max per-species selection probability.
    pub delta: f64,
    /// Expected Q′ density per sampling pass.
    pub qprime_density: f64,
    /// Probability two passes overlap in some species.
    pub rho: f64,
    /// Ω / n_rows.
    pub diversity_ratio: f64,
}

/// Compute the report for sampling rate `rate`.
pub fn diversity_report(ds: &Dataset, rate: f64) -> DiversityReport {
    let table = SpeciesTable::build(ds);
    report_from_species(&table, rate, ds.n_rows())
}

/// Same, reusing a prebuilt species table (rate sweeps).
pub fn report_from_species(
    table: &SpeciesTable,
    rate: f64,
    n_rows: usize,
) -> DiversityReport {
    assert!((0.0..=1.0).contains(&rate), "rate must be in [0,1]");
    let omega = table.n_species();
    let mut delta: f64 = 0.0;
    let mut expected_on = 0.0;
    let mut log_no_overlap = 0.0;
    for &m in &table.counts {
        // P(Q'_i = 1) = 1 - (1-r)^m
        let p_on = 1.0 - (1.0 - rate).powf(m);
        delta = delta.max(p_on);
        expected_on += p_on;
        // overlap of two independent passes on species i: p_on^2
        let p2 = (p_on * p_on).min(1.0 - 1e-15);
        log_no_overlap += (1.0 - p2).ln();
    }
    DiversityReport {
        n_rows,
        omega,
        delta,
        qprime_density: if omega > 0 { expected_on / omega as f64 } else { 0.0 },
        rho: 1.0 - log_no_overlap.exp(),
        diversity_ratio: table.diversity_ratio(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic;

    #[test]
    fn species_table_counts_duplicates() {
        let ds = synthetic::fig4_low_diversity(1);
        let t = SpeciesTable::build(&ds);
        assert_eq!(t.n_species(), 3);
        let mut counts = t.counts.clone();
        counts.sort_by(|a, b| a.partial_cmp(b).unwrap());
        assert_eq!(counts, vec![10_000.0, 20_000.0, 30_000.0]);
        assert!((t.total() - 60_000.0).abs() < 1e-9);
    }

    #[test]
    fn low_diversity_gives_dense_qprime_and_high_delta() {
        let lo = synthetic::fig4_low_diversity(1);
        let hi = synthetic::fig4_high_diversity(1);
        let r = 0.001; // small sampling rate
        let rep_lo = diversity_report(&lo, r);
        let rep_hi = diversity_report(&hi, r);
        // paper Figure 4: low diversity => Q' dense even at tiny rates
        assert!(rep_lo.qprime_density > 0.99, "lo density={}", rep_lo.qprime_density);
        assert!(rep_hi.qprime_density < 0.05, "hi density={}", rep_hi.qprime_density);
        assert!(rep_lo.delta > 0.99);
        assert!(rep_hi.delta < 0.05);
    }

    #[test]
    fn rho_increases_with_rate() {
        let ds = synthetic::fig4_high_diversity(2);
        let lo = diversity_report(&ds, 0.0005);
        let hi = diversity_report(&ds, 0.5);
        assert!(lo.rho < hi.rho);
        assert!(hi.rho > 0.99);
    }

    #[test]
    fn rate_zero_turns_everything_off() {
        let ds = synthetic::fig4_high_diversity(3);
        let rep = diversity_report(&ds, 0.0);
        assert_eq!(rep.delta, 0.0);
        assert_eq!(rep.qprime_density, 0.0);
        assert!(rep.rho.abs() < 1e-12);
    }

    #[test]
    fn rate_one_turns_everything_on() {
        let ds = synthetic::fig4_high_diversity(4);
        let rep = diversity_report(&ds, 1.0);
        assert!((rep.delta - 1.0).abs() < 1e-12);
        assert!((rep.qprime_density - 1.0).abs() < 1e-9);
        assert!(rep.rho > 1.0 - 1e-9);
    }
}
