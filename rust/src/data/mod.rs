//! Dataset substrates: sparse matrices, binning, synthetic generators and
//! diversity statistics.
//!
//! The paper evaluates on LIBSVM datasets (real-sim, HIGGS, E2006-log1p)
//! which are not redistributable here; `synthetic` builds statistical
//! stand-ins that preserve the properties the theory cares about
//! (dimensionality, sparsity, sample diversity — see DESIGN.md §3). Real
//! files can be dropped in via `io::svmlight`.

pub mod binning;
pub mod dataset;
pub mod sparse;
pub mod stats;
pub mod synthetic;

pub use binning::{BinCuts, BinMapper, BinnedDataset};
pub use dataset::Dataset;
pub use sparse::CsrMatrix;
