//! Feature quantization (histogram binning) — the substrate of the
//! LightGBM-style "building the tree" sub-step the paper reuses.
//!
//! Each feature's raw values are quantized into at most `max_bins` ordered
//! bins by (approximate) quantiles of the observed nonzero values. Zero is
//! forced onto a bin boundary so that the implicit zeros of sparse data map
//! to a single well-defined `zero_bin`, which lets the histogram builder
//! accumulate only nonzero entries and reconstruct the zero bin by
//! subtraction (`leaf_total - sum(nonzero bins)`) — the trick that makes
//! sparse histogram building O(nnz) instead of O(n_rows * n_features).

use anyhow::{bail, Result};

use super::dataset::Dataset;
use super::sparse::CsrMatrix;

/// Maximum bins representable (bin ids are stored as u8).
pub const MAX_BINS: usize = 256;

/// Per-feature quantizer: ordered upper bounds, `bin_of(v)` = first bin
/// whose upper bound is >= v. The last bound is +inf.
#[derive(Debug, Clone, PartialEq)]
pub struct BinMapper {
    /// Upper bound of each bin (ascending); last is f32::INFINITY.
    pub uppers: Vec<f32>,
    /// Bin that raw value 0.0 maps to (implicit-zero bin for sparse data).
    pub zero_bin: u8,
}

impl BinMapper {
    /// Build from the feature's nonzero values (order irrelevant).
    /// `n_total_rows` is used to weigh the implicit zeros when choosing
    /// quantile boundaries.
    pub fn from_values(mut vals: Vec<f32>, max_bins: usize) -> BinMapper {
        assert!(max_bins >= 2 && max_bins <= MAX_BINS);
        vals.retain(|v| v.is_finite());
        vals.sort_by(|a, b| a.partial_cmp(b).unwrap());
        vals.dedup();
        // candidate cut points: distinct values; downsample to max_bins-2
        // interior bounds (reserve one bin ending exactly at 0.0 and the
        // +inf tail).
        let mut uppers: Vec<f32> = Vec::new();
        let interior = max_bins.saturating_sub(2).max(1);
        if vals.len() <= interior {
            uppers.extend_from_slice(&vals);
        } else {
            for k in 1..=interior {
                let idx = k * vals.len() / (interior + 1);
                uppers.push(vals[idx.min(vals.len() - 1)]);
            }
            uppers.dedup();
        }
        // force 0.0 onto a boundary so zeros get a dedicated upper bound
        if !uppers.contains(&0.0) {
            uppers.push(0.0);
        }
        uppers.sort_by(|a, b| a.partial_cmp(b).unwrap());
        uppers.dedup();
        uppers.push(f32::INFINITY);
        debug_assert!(uppers.len() <= MAX_BINS);
        let zero_bin = uppers
            .iter()
            .position(|&u| 0.0 <= u)
            .expect("inf tail guarantees a bin") as u8;
        BinMapper { uppers, zero_bin }
    }

    /// Number of bins.
    pub fn n_bins(&self) -> usize {
        self.uppers.len()
    }

    /// Map a raw value to its bin.
    #[inline]
    pub fn bin_of(&self, v: f32) -> u8 {
        // first upper >= v  <=>  partition_point(upper < v)
        let pos = self.uppers.partition_point(|&u| u < v);
        debug_assert!(pos < self.uppers.len());
        pos as u8
    }

    /// Raw-value upper bound of a bin (split threshold "v <= upper").
    pub fn upper_of(&self, bin: u8) -> f32 {
        self.uppers[bin as usize]
    }
}

/// A dataset quantized for histogram tree building: the original CSR
/// sparsity pattern with u8 bin ids instead of raw values, plus the
/// per-feature mappers and flat-histogram offsets.
#[derive(Debug, Clone)]
pub struct BinnedDataset {
    /// Per-feature value→bin quantizers.
    pub mappers: Vec<BinMapper>,
    /// Row-major nonzero bins: same indptr/indices as the source CSR.
    pub indptr: Vec<usize>,
    /// Feature id of each nonzero (parallel to `bins`).
    pub feat_ids: Vec<u32>,
    /// Local bin id of each nonzero (parallel to `feat_ids`).
    pub bins: Vec<u8>,
    /// Flat histogram offset per feature (prefix sum of n_bins).
    pub offsets: Vec<usize>,
    /// Row count.
    pub n_rows: usize,
    /// Feature count.
    pub n_features: usize,
}

impl BinnedDataset {
    /// Quantize a dataset with at most `max_bins` bins per feature.
    pub fn from_dataset(ds: &Dataset, max_bins: usize) -> Result<BinnedDataset> {
        Self::from_csr(&ds.x, max_bins)
    }

    /// Quantize a raw CSR matrix.
    pub fn from_csr(x: &CsrMatrix, max_bins: usize) -> Result<BinnedDataset> {
        if max_bins < 2 || max_bins > MAX_BINS {
            bail!("max_bins must be in [2, {MAX_BINS}], got {max_bins}");
        }
        let n_features = x.n_cols();
        // gather nonzero values per feature
        let mut per_feat: Vec<Vec<f32>> = vec![Vec::new(); n_features];
        for r in 0..x.n_rows() {
            for (c, v) in x.row(r) {
                per_feat[c as usize].push(v);
            }
        }
        let mappers: Vec<BinMapper> = per_feat
            .into_iter()
            .map(|vals| BinMapper::from_values(vals, max_bins))
            .collect();
        // quantize nonzeros in place of values
        let mut bins = Vec::with_capacity(x.nnz());
        for r in 0..x.n_rows() {
            for (c, v) in x.row(r) {
                bins.push(mappers[c as usize].bin_of(v));
            }
        }
        let mut offsets = Vec::with_capacity(n_features + 1);
        let mut acc = 0usize;
        for m in &mappers {
            offsets.push(acc);
            acc += m.n_bins();
        }
        offsets.push(acc);
        Ok(BinnedDataset {
            mappers,
            indptr: x.indptr.clone(),
            feat_ids: x.indices.clone(),
            bins,
            offsets,
            n_rows: x.n_rows(),
            n_features,
        })
    }

    /// Total flat histogram size (sum of per-feature bins).
    pub fn total_bins(&self) -> usize {
        *self.offsets.last().unwrap()
    }

    /// Iterate a row's (feature, bin) pairs (nonzeros only).
    #[inline]
    pub fn row(&self, r: usize) -> impl Iterator<Item = (u32, u8)> + '_ {
        let lo = self.indptr[r];
        let hi = self.indptr[r + 1];
        self.feat_ids[lo..hi]
            .iter()
            .copied()
            .zip(self.bins[lo..hi].iter().copied())
    }

    /// Bin of (row, feature), resolving implicit zeros.
    pub fn bin_of(&self, r: usize, feat: u32) -> u8 {
        let lo = self.indptr[r];
        let hi = self.indptr[r + 1];
        match self.feat_ids[lo..hi].binary_search(&feat) {
            Ok(pos) => self.bins[lo + pos],
            Err(_) => self.mappers[feat as usize].zero_bin,
        }
    }

    /// Extract the training-derived cuts (mappers + offsets) without the
    /// training matrix — everything request-time binning needs. The
    /// serving layer (`serve/`) carries one [`BinCuts`] next to each
    /// model so arriving raw feature vectors quantize onto exactly the
    /// bins the trees were built against.
    pub fn cuts(&self) -> BinCuts {
        BinCuts {
            mappers: self.mappers.clone(),
            offsets: self.offsets.clone(),
        }
    }
}

/// Training-derived quantizer state detached from the training matrix:
/// the per-feature [`BinMapper`]s plus the flat-histogram offsets.
///
/// Until this type existed only whole training matrices could be binned
/// ([`BinnedDataset::from_csr`] derives fresh cuts from the data it
/// bins). `BinCuts` re-applies *existing* cuts to new rows —
/// [`BinCuts::bin_row`] for a single raw feature vector at request time,
/// [`BinCuts::bin_batch`] for a matrix — producing the same `(feature,
/// bin)` pattern training-time binning of the same rows would have
/// produced (property-tested in `tests/test_properties.rs`). That makes
/// the output directly scoreable by the bin-space engines
/// ([`crate::tree::FlatTree::partition_binned`]): a tree split `bin_of(v)
/// <= bin` decides identically to its raw-space twin `v <= upper_of(bin)`
/// because both sides come from the same mapper.
#[derive(Debug, Clone, PartialEq)]
pub struct BinCuts {
    mappers: Vec<BinMapper>,
    offsets: Vec<usize>,
}

impl BinCuts {
    /// Rebuild cuts from bare per-feature mappers, recomputing the flat
    /// histogram offsets as the prefix sums of each mapper's `n_bins()`
    /// — the same arithmetic [`BinnedDataset::from_csr`] runs at
    /// training time. This is the deserialization entry point: the
    /// `.sgbdt` artifact (`io/artifact.rs`) persists only the mappers
    /// (uppers + zero_bin) because the offsets are derived state.
    pub fn from_mappers(mappers: Vec<BinMapper>) -> BinCuts {
        let mut offsets = Vec::with_capacity(mappers.len() + 1);
        let mut acc = 0usize;
        for m in &mappers {
            offsets.push(acc);
            acc += m.n_bins();
        }
        offsets.push(acc);
        BinCuts { mappers, offsets }
    }
    /// Number of features the cuts were derived from.
    pub fn n_features(&self) -> usize {
        self.mappers.len()
    }

    /// Total flat histogram size (sum of per-feature bins).
    pub fn total_bins(&self) -> usize {
        *self.offsets.last().unwrap_or(&0)
    }

    /// The per-feature quantizers.
    pub fn mappers(&self) -> &[BinMapper] {
        &self.mappers
    }

    /// The shared row-binning core: validates and quantizes one row's
    /// `(feature, value)` pairs, appending to `feat_out`/`bin_out`.
    ///
    /// Rejections (malformed serving input): feature ids not strictly
    /// increasing, or non-finite values. Feature ids at or beyond
    /// [`BinCuts::n_features`] are silently *dropped* instead: no tree
    /// built on these cuts ever tests such a feature, so dropping is
    /// exactly what the raw-space scorer's "never asked for" behaviour
    /// does — and it keeps requests from models of a different width
    /// scoreable across a hot-swap.
    fn bin_row_inner<I>(&self, row: I, feat_out: &mut Vec<u32>, bin_out: &mut Vec<u8>) -> Result<()>
    where
        I: Iterator<Item = (u32, f32)>,
    {
        let mut prev: Option<u32> = None;
        for (c, v) in row {
            if let Some(p) = prev {
                if c <= p {
                    bail!("feature ids must be strictly increasing: id {c} after {p}");
                }
            }
            prev = Some(c);
            if !v.is_finite() {
                bail!("non-finite value {v} for feature {c}");
            }
            if let Some(m) = self.mappers.get(c as usize) {
                feat_out.push(c);
                bin_out.push(m.bin_of(v));
            }
        }
        Ok(())
    }

    /// Quantize one raw sparse row (strictly increasing feature ids,
    /// finite values) onto the cuts, appending `(feature, bin)` pairs to
    /// the output buffers. Malformed rows (unordered ids, non-finite
    /// values) fail; ids at or beyond [`BinCuts::n_features`] are
    /// silently dropped — no tree built on these cuts ever tests them.
    pub fn bin_row(
        &self,
        row: &[(u32, f32)],
        feat_out: &mut Vec<u32>,
        bin_out: &mut Vec<u8>,
    ) -> Result<()> {
        self.bin_row_inner(row.iter().copied(), feat_out, bin_out)
    }

    /// A zero-row [`BinnedDataset`] carrying these cuts, ready for
    /// [`BinCuts::fill_batch`]. The serving loop builds one per model and
    /// refills it per micro-batch, so the mapper clone is paid once per
    /// hot-swap rather than once per batch.
    pub fn empty_batch(&self) -> BinnedDataset {
        BinnedDataset {
            mappers: self.mappers.clone(),
            indptr: vec![0],
            feat_ids: Vec::new(),
            bins: Vec::new(),
            offsets: self.offsets.clone(),
            n_rows: 0,
            n_features: self.n_features(),
        }
    }

    /// Rebin a batch of raw rows into a reusable [`BinCuts::empty_batch`]
    /// scratch in place (the serving hot path — steady state allocates
    /// nothing beyond buffer growth). Fails on the first malformed row;
    /// the scratch is left cleared-but-partial, safe to refill.
    pub fn fill_batch(&self, rows: &[&[(u32, f32)]], into: &mut BinnedDataset) -> Result<()> {
        assert_eq!(
            into.n_features,
            self.n_features(),
            "batch scratch was built from different cuts"
        );
        debug_assert_eq!(into.offsets, self.offsets);
        into.indptr.clear();
        into.indptr.push(0);
        into.feat_ids.clear();
        into.bins.clear();
        into.n_rows = 0;
        for row in rows {
            self.bin_row_inner(row.iter().copied(), &mut into.feat_ids, &mut into.bins)?;
            into.indptr.push(into.feat_ids.len());
        }
        into.n_rows = rows.len();
        Ok(())
    }

    /// Quantize a whole raw CSR matrix on these cuts into a standalone
    /// [`BinnedDataset`] — the same sparsity pattern and bin ids
    /// training-time binning of the same matrix produces (the
    /// `tests/test_properties.rs` equivalence), without re-deriving any
    /// cut from the data.
    pub fn bin_batch(&self, x: &CsrMatrix) -> Result<BinnedDataset> {
        let mut out = self.empty_batch();
        out.indptr.reserve(x.n_rows());
        out.feat_ids.reserve(x.nnz());
        out.bins.reserve(x.nnz());
        for r in 0..x.n_rows() {
            self.bin_row_inner(x.row(r), &mut out.feat_ids, &mut out.bins)?;
            out.indptr.push(out.feat_ids.len());
        }
        out.n_rows = x.n_rows();
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::dataset::Dataset;

    #[test]
    fn mapper_orders_bins_and_maps_zero() {
        let m = BinMapper::from_values(vec![1.0, 2.0, 3.0, 4.0], 8);
        assert!(m.uppers.windows(2).all(|w| w[0] < w[1]));
        assert_eq!(m.uppers.last().copied().unwrap(), f32::INFINITY);
        assert_eq!(m.bin_of(0.0), m.zero_bin);
        // monotonic: larger values get >= bins
        assert!(m.bin_of(0.5) <= m.bin_of(1.5));
        assert!(m.bin_of(1.5) <= m.bin_of(3.5));
        assert!(m.bin_of(100.0) as usize == m.n_bins() - 1);
    }

    #[test]
    fn mapper_zero_has_exact_boundary() {
        let m = BinMapper::from_values(vec![-2.0, -1.0, 1.0, 2.0], 16);
        // 0.0 must sit exactly at an upper bound
        assert!(m.uppers.contains(&0.0));
        assert_eq!(m.upper_of(m.zero_bin), 0.0);
        // negatives strictly below zero map strictly below or equal zero_bin
        assert!(m.bin_of(-1.5) <= m.zero_bin);
        assert!(m.bin_of(0.5) > m.zero_bin);
    }

    #[test]
    fn mapper_caps_bins() {
        let vals: Vec<f32> = (0..10_000).map(|i| i as f32 * 0.001 + 0.001).collect();
        let m = BinMapper::from_values(vals, 64);
        assert!(m.n_bins() <= 64);
        assert!(m.n_bins() >= 32); // quantiles actually spread
    }

    #[test]
    fn binned_dataset_roundtrip() {
        let x = CsrMatrix::from_rows(
            3,
            &[
                vec![(0, 1.0), (2, 5.0)],
                vec![(1, 2.0)],
                vec![(0, 3.0), (1, 4.0), (2, 6.0)],
            ],
        )
        .unwrap();
        let ds = Dataset::new("t", x, vec![1.0, 0.0, 1.0]);
        let b = BinnedDataset::from_dataset(&ds, 16).unwrap();
        assert_eq!(b.n_rows, 3);
        assert_eq!(b.n_features, 3);
        assert_eq!(b.offsets.len(), 4);
        assert_eq!(b.total_bins(), b.mappers.iter().map(|m| m.n_bins()).sum());
        // implicit zero resolution
        assert_eq!(b.bin_of(1, 0), b.mappers[0].zero_bin);
        // explicit nonzero must not be the zero bin
        assert_ne!(b.bin_of(0, 0), b.mappers[0].zero_bin);
        // ordering within a feature: 1.0 < 3.0
        assert!(b.bin_of(0, 0) <= b.bin_of(2, 0));
    }

    #[test]
    fn rejects_bad_max_bins() {
        let x = CsrMatrix::from_dense(1, 1, &[1.0]).unwrap();
        assert!(BinnedDataset::from_csr(&x, 1).is_err());
        assert!(BinnedDataset::from_csr(&x, 1000).is_err());
    }

    #[test]
    fn distinct_values_get_distinct_bins_when_room() {
        let m = BinMapper::from_values(vec![1.0, 2.0, 3.0], 16);
        let b1 = m.bin_of(1.0);
        let b2 = m.bin_of(2.0);
        let b3 = m.bin_of(3.0);
        assert!(b1 < b2 && b2 < b3);
    }

    fn sample_binned() -> (CsrMatrix, BinnedDataset) {
        let x = CsrMatrix::from_rows(
            3,
            &[
                vec![(0, 1.0), (2, 5.0)],
                vec![(1, 2.0)],
                vec![(0, 3.0), (1, 4.0), (2, 6.0)],
            ],
        )
        .unwrap();
        let b = BinnedDataset::from_csr(&x, 16).unwrap();
        (x, b)
    }

    #[test]
    fn cuts_rebin_the_training_matrix_identically() {
        let (x, b) = sample_binned();
        let cuts = b.cuts();
        assert_eq!(cuts.n_features(), b.n_features);
        assert_eq!(cuts.total_bins(), b.total_bins());
        let again = cuts.bin_batch(&x).unwrap();
        assert_eq!(again.indptr, b.indptr);
        assert_eq!(again.feat_ids, b.feat_ids);
        assert_eq!(again.bins, b.bins);
        assert_eq!(again.offsets, b.offsets);
        assert_eq!(again.n_rows, b.n_rows);
    }

    #[test]
    fn bin_row_matches_batch_and_drops_unknown_features() {
        let (_, b) = sample_binned();
        let cuts = b.cuts();
        let (mut feats, mut bins) = (Vec::new(), Vec::new());
        cuts.bin_row(&[(0, 3.0), (1, 4.0), (2, 6.0)], &mut feats, &mut bins)
            .unwrap();
        assert_eq!(feats, vec![0, 1, 2]);
        assert_eq!(bins, (0..3).map(|f| b.bin_of(2, f)).collect::<Vec<u8>>());
        // ids beyond the cuts' width are dropped, not an error — a tree
        // built on these cuts never tests them
        feats.clear();
        bins.clear();
        cuts.bin_row(&[(1, 2.0), (9, 1.0)], &mut feats, &mut bins)
            .unwrap();
        assert_eq!(feats, vec![1]);
        assert_eq!(bins, vec![b.bin_of(1, 1)]);
        // the empty row bins to the empty pattern (all-implicit zeros)
        feats.clear();
        bins.clear();
        cuts.bin_row(&[], &mut feats, &mut bins).unwrap();
        assert!(feats.is_empty() && bins.is_empty());
    }

    #[test]
    fn from_mappers_rederives_offsets_exactly() {
        let (_, b) = sample_binned();
        let cuts = b.cuts();
        // round-trip through bare mappers — what the .sgbdt artifact
        // persists — must reproduce the cuts bit for bit (PartialEq
        // covers uppers, zero_bins, and the recomputed offsets)
        let rebuilt = BinCuts::from_mappers(cuts.mappers().to_vec());
        assert_eq!(rebuilt, cuts);
        assert_eq!(rebuilt.total_bins(), b.total_bins());
        // degenerate: zero features still yields a valid [0] offset table
        let empty = BinCuts::from_mappers(Vec::new());
        assert_eq!(empty.n_features(), 0);
        assert_eq!(empty.total_bins(), 0);
    }

    #[test]
    fn bin_row_rejects_malformed_requests() {
        let (_, b) = sample_binned();
        let cuts = b.cuts();
        let (mut feats, mut bins) = (Vec::new(), Vec::new());
        let dup = cuts.bin_row(&[(1, 2.0), (1, 3.0)], &mut feats, &mut bins);
        assert!(dup.unwrap_err().to_string().contains("strictly increasing"));
        let unordered = cuts.bin_row(&[(2, 2.0), (0, 3.0)], &mut feats, &mut bins);
        assert!(unordered.is_err());
        let nan = cuts.bin_row(&[(0, f32::NAN)], &mut feats, &mut bins);
        assert!(nan.unwrap_err().to_string().contains("non-finite"));
    }

    #[test]
    fn fill_batch_reuses_scratch_across_refills() {
        let (x, b) = sample_binned();
        let cuts = b.cuts();
        let mut scratch = cuts.empty_batch();
        assert_eq!(scratch.n_rows, 0);
        let rows: Vec<Vec<(u32, f32)>> = (0..x.n_rows()).map(|r| x.row(r).collect()).collect();
        let refs: Vec<&[(u32, f32)]> = rows.iter().map(|r| r.as_slice()).collect();
        cuts.fill_batch(&refs, &mut scratch).unwrap();
        assert_eq!(scratch.indptr, b.indptr);
        assert_eq!(scratch.bins, b.bins);
        // refill with a different shape: state fully replaced
        cuts.fill_batch(&refs[1..2], &mut scratch).unwrap();
        assert_eq!(scratch.n_rows, 1);
        assert_eq!(scratch.indptr, vec![0, 1]);
        assert_eq!(scratch.bin_of(0, 1), b.bin_of(1, 1));
        assert_eq!(scratch.bin_of(0, 0), b.mappers[0].zero_bin);
    }
}
