//! The labelled dataset container used throughout the trainers.
//!
//! Matches the paper's problem setting (§III.A): samples `(x_i, y_i)` with
//! multiplicity `m_i` — distinct `(x_j, y_j)` are "species" and `m_i`
//! counts how often each occurs. For file-loaded data every row has
//! `m_i = 1`; the low-diversity synthetic sets use `m_i > 1` to model the
//! paper's Figure 4(a) regime.

use anyhow::{bail, Result};

use super::sparse::CsrMatrix;
use crate::util::Rng;

/// A binary-classification dataset: CSR features, {0,1} labels, and
/// per-sample multiplicities `m_i` (all 1.0 unless constructed otherwise).
#[derive(Debug, Clone)]
pub struct Dataset {
    /// Display name (corpus tag) used in logs and outputs.
    pub name: String,
    /// The feature matrix.
    pub x: CsrMatrix,
    /// Labels in {0.0, 1.0}.
    pub y: Vec<f32>,
    /// Multiplicities m_i >= 1 (paper §III.A). The effective loss is
    /// `sum_i m_i * l(y_i, F_i)`.
    pub m: Vec<f32>,
}

impl Dataset {
    /// Construct with unit multiplicities.
    pub fn new(name: &str, x: CsrMatrix, y: Vec<f32>) -> Self {
        let n = x.n_rows();
        assert_eq!(y.len(), n, "labels/rows mismatch");
        Self {
            name: name.to_string(),
            x,
            y,
            m: vec![1.0; n],
        }
    }

    /// Construct with explicit multiplicities.
    pub fn with_multiplicity(
        name: &str,
        x: CsrMatrix,
        y: Vec<f32>,
        m: Vec<f32>,
    ) -> Result<Self> {
        if y.len() != x.n_rows() || m.len() != x.n_rows() {
            bail!("labels/multiplicity/rows mismatch");
        }
        if m.iter().any(|&v| v < 1.0 || !v.is_finite()) {
            bail!("multiplicities must be finite and >= 1");
        }
        Ok(Self {
            name: name.to_string(),
            x,
            y,
            m,
        })
    }

    /// Number of samples.
    pub fn n_rows(&self) -> usize {
        self.x.n_rows()
    }

    /// Number of features.
    pub fn n_features(&self) -> usize {
        self.x.n_cols()
    }

    /// Total weighted count `sum_i m_i`.
    pub fn total_weight(&self) -> f64 {
        self.m.iter().map(|&v| v as f64).sum()
    }

    /// Weighted positive-label fraction (used for the base score, the
    /// paper's initial tree outputs `sum m_i y_i / sum m_i`).
    pub fn positive_rate(&self) -> f64 {
        let num: f64 = self
            .y
            .iter()
            .zip(&self.m)
            .map(|(&y, &m)| (y * m) as f64)
            .sum();
        num / self.total_weight()
    }

    /// Split into (train, test) by a shuffled row partition.
    pub fn split(&self, test_fraction: f64, rng: &mut Rng) -> (Dataset, Dataset) {
        assert!((0.0..1.0).contains(&test_fraction));
        let n = self.n_rows();
        let mut order: Vec<usize> = (0..n).collect();
        rng.shuffle(&mut order);
        let n_test = ((n as f64) * test_fraction).round() as usize;
        let (test_rows, train_rows) = order.split_at(n_test);
        (self.subset(train_rows, "train"), self.subset(test_rows, "test"))
    }

    /// Row-subset dataset (suffix appended to the name).
    pub fn subset(&self, rows: &[usize], suffix: &str) -> Dataset {
        Dataset {
            name: format!("{}-{}", self.name, suffix),
            x: self.x.select_rows(rows),
            y: rows.iter().map(|&r| self.y[r]).collect(),
            m: rows.iter().map(|&r| self.m[r]).collect(),
        }
    }

    /// Count distinct feature-row species via fingerprinting — the
    /// "diversity of the samples in the dataset" the paper's analysis
    /// keys on (size of Q′ support).
    pub fn n_species(&self) -> usize {
        let mut set = std::collections::HashSet::with_capacity(self.n_rows());
        for r in 0..self.n_rows() {
            set.insert((self.x.row_fingerprint(r), self.y[r].to_bits()));
        }
        set.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> Dataset {
        let x = CsrMatrix::from_dense(4, 2, &[1.0, 0.0, 0.0, 2.0, 1.0, 0.0, 3.0, 3.0])
            .unwrap();
        Dataset::new("tiny", x, vec![1.0, 0.0, 1.0, 0.0])
    }

    #[test]
    fn unit_multiplicity_by_default() {
        let d = tiny();
        assert_eq!(d.m, vec![1.0; 4]);
        assert!((d.total_weight() - 4.0).abs() < 1e-12);
        assert!((d.positive_rate() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn multiplicity_weights_positive_rate() {
        let d = tiny();
        let d2 =
            Dataset::with_multiplicity("t", d.x.clone(), d.y.clone(), vec![3.0, 1.0, 1.0, 1.0])
                .unwrap();
        // positives: rows 0 (m=3) and 2 (m=1) => 4/6
        assert!((d2.positive_rate() - 4.0 / 6.0).abs() < 1e-12);
    }

    #[test]
    fn rejects_bad_multiplicity() {
        let d = tiny();
        assert!(Dataset::with_multiplicity("t", d.x.clone(), d.y.clone(), vec![0.5; 4]).is_err());
        assert!(Dataset::with_multiplicity("t", d.x.clone(), d.y.clone(), vec![1.0; 3]).is_err());
    }

    #[test]
    fn split_partitions_rows() {
        let d = tiny();
        let mut rng = Rng::new(1);
        let (tr, te) = d.split(0.25, &mut rng);
        assert_eq!(tr.n_rows() + te.n_rows(), 4);
        assert_eq!(te.n_rows(), 1);
        assert_eq!(tr.n_features(), 2);
    }

    #[test]
    fn species_counts_duplicates_once() {
        // rows 0 and 1 identical, row 2 differs
        let x = CsrMatrix::from_dense(3, 1, &[1.0, 1.0, 2.0]).unwrap();
        let d = Dataset::new("s", x, vec![1.0, 1.0, 0.0]);
        assert_eq!(d.n_species(), 2);
    }
}
