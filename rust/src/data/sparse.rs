//! Compressed sparse row (CSR) matrix of f32 feature values.

use anyhow::{bail, Result};

/// CSR matrix. `indices[indptr[r]..indptr[r+1]]` are the column ids of row
/// `r`, strictly increasing; `values` are the matching nonzeros.
#[derive(Debug, Clone, PartialEq)]
pub struct CsrMatrix {
    n_rows: usize,
    n_cols: usize,
    /// Row start offsets into `indices`/`values` (length n_rows + 1).
    pub indptr: Vec<usize>,
    /// Column ids of the nonzeros, strictly increasing within a row.
    pub indices: Vec<u32>,
    /// The nonzero values (parallel to `indices`).
    pub values: Vec<f32>,
}

impl CsrMatrix {
    /// Validating constructor.
    pub fn new(
        n_rows: usize,
        n_cols: usize,
        indptr: Vec<usize>,
        indices: Vec<u32>,
        values: Vec<f32>,
    ) -> Result<Self> {
        if indptr.len() != n_rows + 1 {
            bail!("indptr len {} != n_rows+1 {}", indptr.len(), n_rows + 1);
        }
        if indices.len() != values.len() {
            bail!("indices/values length mismatch");
        }
        if *indptr.last().unwrap_or(&0) != indices.len() {
            bail!("indptr tail != nnz");
        }
        for r in 0..n_rows {
            if indptr[r] > indptr[r + 1] {
                bail!("indptr not monotone at row {r}");
            }
            let row = &indices[indptr[r]..indptr[r + 1]];
            for w in row.windows(2) {
                if w[0] >= w[1] {
                    bail!("row {r}: column ids not strictly increasing");
                }
            }
            if let Some(&last) = row.last() {
                if last as usize >= n_cols {
                    bail!("row {r}: column {last} >= n_cols {n_cols}");
                }
            }
        }
        Ok(Self { n_rows, n_cols, indptr, indices, values })
    }

    /// Build from per-row (col, val) pair lists.
    pub fn from_rows(n_cols: usize, rows: &[Vec<(u32, f32)>]) -> Result<Self> {
        let mut indptr = vec![0usize];
        let mut indices = Vec::new();
        let mut values = Vec::new();
        for row in rows {
            for &(c, v) in row {
                indices.push(c);
                values.push(v);
            }
            indptr.push(indices.len());
        }
        Self::new(rows.len(), n_cols, indptr, indices, values)
    }

    /// Dense constructor (row-major input), zeros dropped.
    pub fn from_dense(n_rows: usize, n_cols: usize, data: &[f32]) -> Result<Self> {
        assert_eq!(data.len(), n_rows * n_cols);
        let rows: Vec<Vec<(u32, f32)>> = (0..n_rows)
            .map(|r| {
                (0..n_cols)
                    .filter_map(|c| {
                        let v = data[r * n_cols + c];
                        (v != 0.0).then_some((c as u32, v))
                    })
                    .collect()
            })
            .collect();
        Self::from_rows(n_cols, &rows)
    }

    /// Number of rows.
    pub fn n_rows(&self) -> usize {
        self.n_rows
    }

    /// Number of columns.
    pub fn n_cols(&self) -> usize {
        self.n_cols
    }

    /// Number of stored nonzeros.
    pub fn nnz(&self) -> usize {
        self.indices.len()
    }

    /// Fraction of nonzero entries.
    pub fn density(&self) -> f64 {
        if self.n_rows == 0 || self.n_cols == 0 {
            0.0
        } else {
            self.nnz() as f64 / (self.n_rows as f64 * self.n_cols as f64)
        }
    }

    /// Iterate a row's (col, value) pairs.
    pub fn row(&self, r: usize) -> impl Iterator<Item = (u32, f32)> + '_ {
        let lo = self.indptr[r];
        let hi = self.indptr[r + 1];
        self.indices[lo..hi]
            .iter()
            .copied()
            .zip(self.values[lo..hi].iter().copied())
    }

    /// Number of nonzeros in a row.
    pub fn row_nnz(&self, r: usize) -> usize {
        self.indptr[r + 1] - self.indptr[r]
    }

    /// Value at (r, c) — binary search within the row; 0.0 if absent.
    pub fn get(&self, r: usize, c: u32) -> f32 {
        let lo = self.indptr[r];
        let hi = self.indptr[r + 1];
        match self.indices[lo..hi].binary_search(&c) {
            Ok(pos) => self.values[lo + pos],
            Err(_) => 0.0,
        }
    }

    /// Select a subset of rows (in the given order) into a new matrix.
    pub fn select_rows(&self, rows: &[usize]) -> CsrMatrix {
        let mut indptr = vec![0usize];
        let mut indices = Vec::new();
        let mut values = Vec::new();
        for &r in rows {
            let lo = self.indptr[r];
            let hi = self.indptr[r + 1];
            indices.extend_from_slice(&self.indices[lo..hi]);
            values.extend_from_slice(&self.values[lo..hi]);
            indptr.push(indices.len());
        }
        CsrMatrix {
            n_rows: rows.len(),
            n_cols: self.n_cols,
            indptr,
            indices,
            values,
        }
    }

    /// Column-wise nonzero counts.
    pub fn col_nnz(&self) -> Vec<usize> {
        let mut counts = vec![0usize; self.n_cols];
        for &c in &self.indices {
            counts[c as usize] += 1;
        }
        counts
    }

    /// A stable 64-bit fingerprint of a row's sparsity pattern + values,
    /// used to detect duplicate samples (species) for diversity stats.
    pub fn row_fingerprint(&self, r: usize) -> u64 {
        let mut h: u64 = 0xcbf2_9ce4_8422_2325;
        for (c, v) in self.row(r) {
            h ^= c as u64;
            h = h.wrapping_mul(0x1000_0000_01b3);
            h ^= v.to_bits() as u64;
            h = h.wrapping_mul(0x1000_0000_01b3);
        }
        h
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small() -> CsrMatrix {
        // [[1, 0, 2], [0, 0, 0], [0, 3, 0]]
        CsrMatrix::new(3, 3, vec![0, 2, 2, 3], vec![0, 2, 1], vec![1.0, 2.0, 3.0])
            .unwrap()
    }

    #[test]
    fn basics() {
        let m = small();
        assert_eq!(m.n_rows(), 3);
        assert_eq!(m.n_cols(), 3);
        assert_eq!(m.nnz(), 3);
        assert_eq!(m.row_nnz(1), 0);
        assert_eq!(m.get(0, 2), 2.0);
        assert_eq!(m.get(0, 1), 0.0);
        assert!((m.density() - 3.0 / 9.0).abs() < 1e-12);
    }

    #[test]
    fn validation_rejects_bad_shapes() {
        assert!(CsrMatrix::new(2, 2, vec![0, 1], vec![0], vec![1.0]).is_err()); // indptr len
        assert!(CsrMatrix::new(1, 2, vec![0, 2], vec![1, 0], vec![1.0, 1.0]).is_err()); // unsorted
        assert!(CsrMatrix::new(1, 1, vec![0, 1], vec![5], vec![1.0]).is_err()); // col oob
        assert!(CsrMatrix::new(1, 1, vec![0, 2], vec![0], vec![1.0]).is_err()); // tail
    }

    #[test]
    fn from_dense_drops_zeros() {
        let m = CsrMatrix::from_dense(2, 2, &[0.0, 1.0, 2.0, 0.0]).unwrap();
        assert_eq!(m.nnz(), 2);
        assert_eq!(m.get(0, 1), 1.0);
        assert_eq!(m.get(1, 0), 2.0);
    }

    #[test]
    fn select_rows_subsets() {
        let m = small();
        let s = m.select_rows(&[2, 0]);
        assert_eq!(s.n_rows(), 2);
        assert_eq!(s.get(0, 1), 3.0);
        assert_eq!(s.get(1, 0), 1.0);
    }

    #[test]
    fn fingerprints_distinguish_rows() {
        let m = small();
        assert_ne!(m.row_fingerprint(0), m.row_fingerprint(2));
        // identical rows hash identically
        let d = CsrMatrix::from_dense(2, 2, &[1.0, 2.0, 1.0, 2.0]).unwrap();
        assert_eq!(d.row_fingerprint(0), d.row_fingerprint(1));
    }

    #[test]
    fn col_nnz_counts() {
        let m = small();
        assert_eq!(m.col_nnz(), vec![1, 1, 1]);
    }
}
