//! Synthetic dataset generators — statistical stand-ins for the paper's
//! LIBSVM datasets (see DESIGN.md §3 for the substitution argument).
//!
//! Three regimes matter to the theory:
//! * **realsim_like** — high-dimensional sparse, near-unique rows (high
//!   sample diversity → sparse Q′ overlap → low ρ/Δ → asynch-friendly).
//! * **higgs_like** — low-dimensional dense with many near-duplicate rows
//!   (low diversity → dense Q′ → high ρ/Δ → asynch-hostile; the paper's
//!   negative benchmark).
//! * **e2006_like** — very-high-dimensional sparse with few rows (tree
//!   build dominated by feature scans; the Eq. 13 upper-bound regime).
//!
//! All generators produce *learnable* structure: labels follow a sparse
//! linear logit plus noise, so loss curves actually descend and the
//! convergence figures are meaningful.

use crate::data::sparse::CsrMatrix;
use crate::data::Dataset;
use crate::util::Rng;

/// Spec for a synthetic sparse classification corpus.
#[derive(Debug, Clone)]
pub struct SparseSpec {
    /// Samples to generate.
    pub n_rows: usize,
    /// Feature-space width.
    pub n_features: usize,
    /// Mean nonzeros per row.
    pub nnz_per_row: usize,
    /// Label noise: probability of flipping the model label.
    pub label_noise: f64,
    /// Power-law exponent for feature popularity (1.0 ≈ Zipf).
    pub popularity_alpha: f64,
}

/// real-sim-like: 72,309 x 20,958 at ~0.25% density in the original;
/// defaults scale linearly to any n_rows.
pub fn realsim_spec(n_rows: usize) -> SparseSpec {
    SparseSpec {
        n_rows,
        n_features: 20_958.min(4 * n_rows.max(64)),
        nnz_per_row: 52, // original avg nnz/row ≈ 51.5
        label_noise: 0.02,
        popularity_alpha: 1.1,
    }
}

/// E2006-log1p-like: 16,087 x 4.27M in the original. We keep the
/// rows-much-smaller-than-features shape (features capped for memory).
pub fn e2006_spec(n_rows: usize) -> SparseSpec {
    SparseSpec {
        n_rows,
        n_features: (32 * n_rows).clamp(1 << 12, 1 << 19),
        nnz_per_row: 120,
        label_noise: 0.05,
        popularity_alpha: 1.3,
    }
}

/// Generate a sparse corpus per spec. Rows are near-unique (high
/// diversity): feature ids drawn from a power-law, tf-idf-like positive
/// values, labels from a sparse ground-truth logit.
pub fn sparse_classification(spec: &SparseSpec, seed: u64) -> Dataset {
    let mut rng = Rng::new(seed);
    let d = spec.n_features;
    // ground-truth weights on a subset of features
    let mut w = vec![0.0f64; d];
    for wi in w.iter_mut() {
        if rng.bernoulli(0.3) {
            *wi = rng.normal() * 2.0;
        }
    }
    // power-law feature popularity: p(f) ∝ (f+1)^-alpha via inverse CDF
    // approximation: f = floor(d * u^(1/(1-alpha))) is unstable for alpha>1,
    // use Zipf-by-rejection-free approximation: draw u, map through
    // cumulative weights precomputed in chunks.
    let cum = power_law_cdf(d, spec.popularity_alpha);

    let mut rows: Vec<Vec<(u32, f32)>> = Vec::with_capacity(spec.n_rows);
    let mut labels = Vec::with_capacity(spec.n_rows);
    for _ in 0..spec.n_rows {
        let k = sample_row_nnz(&mut rng, spec.nnz_per_row, d);
        let mut feats = std::collections::BTreeMap::new();
        for _ in 0..k {
            let f = sample_from_cdf(&cum, rng.uniform());
            // tf-idf-like positive magnitude
            let v = (0.1 + rng.exponential() * 0.5) as f32;
            feats.entry(f as u32).or_insert(v);
        }
        let logit: f64 = feats
            .iter()
            .map(|(&f, &v)| w[f as usize] * v as f64)
            .sum::<f64>()
            * 0.8;
        let p = 1.0 / (1.0 + (-logit).exp());
        let mut y = if rng.bernoulli(p) { 1.0 } else { 0.0 };
        if rng.bernoulli(spec.label_noise) {
            y = 1.0 - y;
        }
        labels.push(y as f32);
        rows.push(feats.into_iter().collect());
    }
    let x = CsrMatrix::from_rows(d, &rows).expect("generator emits valid CSR");
    Dataset::new("sparse-synth", x, labels)
}

/// real-sim-like corpus (name tagged for experiment outputs).
pub fn realsim_like(n_rows: usize, seed: u64) -> Dataset {
    let mut ds = sparse_classification(&realsim_spec(n_rows), seed);
    ds.name = "realsim-like".into();
    ds
}

/// E2006-log1p-like corpus.
pub fn e2006_like(n_rows: usize, seed: u64) -> Dataset {
    let mut ds = sparse_classification(&e2006_spec(n_rows), seed);
    ds.name = "e2006-like".into();
    ds
}

/// Seeded sparse **regression** corpus for `loss=squared|huber`: same
/// power-law sparse features as [`realsim_like`], continuous labels from
/// the sparse linear response plus Gaussian noise, and a small fraction
/// of heavy-tailed outliers (where huber's robustness shows). Labels
/// are centred near 3.0 so the mean-label base score is exercised away
/// from zero.
pub fn regression_like(n_rows: usize, seed: u64) -> Dataset {
    let spec = realsim_spec(n_rows);
    let mut rng = Rng::new(seed ^ 0x5eed_4e97);
    let d = spec.n_features;
    let mut w = vec![0.0f64; d];
    for wi in w.iter_mut() {
        if rng.bernoulli(0.3) {
            *wi = rng.normal();
        }
    }
    let cum = power_law_cdf(d, spec.popularity_alpha);
    let mut rows: Vec<Vec<(u32, f32)>> = Vec::with_capacity(n_rows);
    let mut labels = Vec::with_capacity(n_rows);
    for _ in 0..n_rows {
        let k = sample_row_nnz(&mut rng, spec.nnz_per_row, d);
        let mut feats = std::collections::BTreeMap::new();
        for _ in 0..k {
            let f = sample_from_cdf(&cum, rng.uniform());
            let v = (0.1 + rng.exponential() * 0.5) as f32;
            feats.entry(f as u32).or_insert(v);
        }
        let response: f64 = feats
            .iter()
            .map(|(&f, &v)| w[f as usize] * v as f64)
            .sum::<f64>();
        let noise = if rng.bernoulli(0.03) {
            rng.normal() * 8.0 // heavy-tailed outlier
        } else {
            rng.normal() * 0.3
        };
        labels.push((3.0 + response + noise) as f32);
        rows.push(feats.into_iter().collect());
    }
    let x = CsrMatrix::from_rows(d, &rows).expect("generator emits valid CSR");
    let mut ds = Dataset::new("regression-like", x, labels);
    ds.name = "regression-like".into();
    ds
}

/// Seeded sparse **K-class** corpus for `loss=multiclass`: K independent
/// sparse ground-truth weight vectors; each row's label is the argmax
/// class logit, flipped to a uniformly random class with small
/// probability. Labels are integer class ids in `[0, K)` stored as f32
/// (the layout `ps/server.rs` validates).
pub fn multiclass_like(n_rows: usize, n_classes: usize, seed: u64) -> Dataset {
    assert!(n_classes >= 2, "multiclass_like needs n_classes >= 2");
    let spec = realsim_spec(n_rows);
    let mut rng = Rng::new(seed ^ 0x3c1a_55e5);
    let d = spec.n_features;
    let mut w = vec![vec![0.0f64; d]; n_classes];
    for wc in w.iter_mut() {
        for wi in wc.iter_mut() {
            if rng.bernoulli(0.3) {
                *wi = rng.normal() * 2.0;
            }
        }
    }
    let cum = power_law_cdf(d, spec.popularity_alpha);
    let mut rows: Vec<Vec<(u32, f32)>> = Vec::with_capacity(n_rows);
    let mut labels = Vec::with_capacity(n_rows);
    for _ in 0..n_rows {
        let k = sample_row_nnz(&mut rng, spec.nnz_per_row, d);
        let mut feats = std::collections::BTreeMap::new();
        for _ in 0..k {
            let f = sample_from_cdf(&cum, rng.uniform());
            let v = (0.1 + rng.exponential() * 0.5) as f32;
            feats.entry(f as u32).or_insert(v);
        }
        let mut best = 0usize;
        let mut best_logit = f64::NEG_INFINITY;
        for (c, wc) in w.iter().enumerate() {
            let logit: f64 = feats
                .iter()
                .map(|(&f, &v)| wc[f as usize] * v as f64)
                .sum();
            if logit > best_logit {
                best_logit = logit;
                best = c;
            }
        }
        let y = if rng.bernoulli(spec.label_noise) {
            rng.range(0, n_classes)
        } else {
            best
        };
        labels.push(y as f32);
        rows.push(feats.into_iter().collect());
    }
    let x = CsrMatrix::from_rows(d, &rows).expect("generator emits valid CSR");
    let mut ds = Dataset::new("multiclass-like", x, labels);
    ds.name = "multiclass-like".into();
    ds
}

/// higgs_like: 28 dense physics-like features, two overlapping Gaussian
/// classes, high label noise — and crucially *low sample diversity*: rows
/// are snapped to a coarse grid so many rows coincide (Figure 4(a)
/// regime). `n_species_target` controls how many distinct rows exist.
pub fn higgs_like(n_rows: usize, seed: u64) -> Dataset {
    higgs_like_with_diversity(n_rows, n_rows / 8, seed)
}

/// higgs_like with an explicit target number of distinct rows (species).
pub fn higgs_like_with_diversity(
    n_rows: usize,
    n_species_target: usize,
    seed: u64,
) -> Dataset {
    let mut rng = Rng::new(seed);
    let d = 28usize;
    let n_species = n_species_target.clamp(2, n_rows.max(2));
    // generate the species pool
    let mut species: Vec<(Vec<f32>, f32)> = Vec::with_capacity(n_species);
    // class-separating direction
    let dir: Vec<f64> = (0..d).map(|_| rng.normal()).collect();
    let norm = dir.iter().map(|x| x * x).sum::<f64>().sqrt();
    for _ in 0..n_species {
        let class = rng.bernoulli(0.5);
        let shift = if class { 0.35 } else { -0.35 };
        let mut row = Vec::with_capacity(d);
        for dim in dir.iter().take(d) {
            let v = rng.normal() + shift * dim / norm * 2.0;
            // snap to a coarse grid (quantized detector readouts)
            row.push(((v * 4.0).round() / 4.0) as f32);
        }
        // heavy label noise keeps Bayes error high, as in real HIGGS
        let y = if rng.bernoulli(0.15) { !class } else { class };
        species.push((row, if y { 1.0 } else { 0.0 }));
    }
    // draw rows from the species pool with multiplicity
    let mut rows: Vec<Vec<(u32, f32)>> = Vec::with_capacity(n_rows);
    let mut labels = Vec::with_capacity(n_rows);
    for _ in 0..n_rows {
        let s = rng.range(0, n_species);
        let (row, y) = &species[s];
        rows.push(
            row.iter()
                .enumerate()
                .filter(|(_, &v)| v != 0.0)
                .map(|(i, &v)| (i as u32, v))
                .collect(),
        );
        labels.push(*y);
    }
    let x = CsrMatrix::from_rows(d, &rows).expect("valid CSR");
    let mut ds = Dataset::new("higgs-like", x, labels);
    ds.name = "higgs-like".into();
    ds
}

/// Figure 4 illustration datasets: an explicit low-diversity corpus of a
/// few species with large multiplicities (4a) vs an all-unique corpus (4b).
pub fn fig4_low_diversity(seed: u64) -> Dataset {
    // species A1 x 10000, A2 x 20000, A3 x 30000 — exactly the paper's 4(a)
    let mut rng = Rng::new(seed);
    let d = 16;
    let mk = |rng: &mut Rng| -> Vec<(u32, f32)> {
        (0..d)
            .filter_map(|i| {
                let v = (rng.normal() as f32 * 2.0).round();
                (v != 0.0).then_some((i as u32, v))
            })
            .collect()
    };
    let species = [(mk(&mut rng), 1.0f32), (mk(&mut rng), 0.0), (mk(&mut rng), 1.0)];
    let counts = [10_000usize, 20_000, 30_000];
    let mut rows = Vec::new();
    let mut labels = Vec::new();
    for (s, &c) in species.iter().zip(&counts) {
        for _ in 0..c {
            rows.push(s.0.clone());
            labels.push(s.1);
        }
    }
    let x = CsrMatrix::from_rows(d, &rows).expect("valid CSR");
    let mut ds = Dataset::new("fig4a-low-diversity", x, labels);
    ds.name = "fig4a-low-diversity".into();
    ds
}

/// Figure 4(b): 14,000 samples, each appearing once.
pub fn fig4_high_diversity(seed: u64) -> Dataset {
    let spec = SparseSpec {
        n_rows: 14_000,
        n_features: 4096,
        nnz_per_row: 30,
        label_noise: 0.02,
        popularity_alpha: 1.1,
    };
    let mut ds = sparse_classification(&spec, seed);
    ds.name = "fig4b-high-diversity".into();
    ds
}

// ------------------------------------------------------------------ internals

/// Row nnz ~ Poisson-ish around the mean (clamped to [1, d]).
fn sample_row_nnz(rng: &mut Rng, mean: usize, d: usize) -> usize {
    let jitter = (rng.normal() * (mean as f64).sqrt()).round() as i64;
    ((mean as i64 + jitter).max(1) as usize).min(d)
}

/// Cumulative distribution over features f with p(f) ∝ (f+1)^-alpha.
fn power_law_cdf(d: usize, alpha: f64) -> Vec<f64> {
    let mut cum = Vec::with_capacity(d);
    let mut acc = 0.0;
    for f in 0..d {
        acc += ((f + 1) as f64).powf(-alpha);
        cum.push(acc);
    }
    let total = acc;
    for c in cum.iter_mut() {
        *c /= total;
    }
    cum
}

/// Inverse-CDF sampling via binary search.
fn sample_from_cdf(cum: &[f64], u: f64) -> usize {
    cum.partition_point(|&c| c < u).min(cum.len() - 1)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn realsim_like_is_sparse_and_diverse() {
        let ds = realsim_like(2000, 42);
        assert_eq!(ds.n_rows(), 2000);
        assert!(ds.x.density() < 0.02, "density={}", ds.x.density());
        // high diversity: nearly all rows distinct
        assert!(ds.n_species() > 1990, "species={}", ds.n_species());
        // both classes present
        let pos = ds.positive_rate();
        assert!(pos > 0.1 && pos < 0.9, "pos={pos}");
    }

    #[test]
    fn higgs_like_is_dense_and_low_diversity() {
        let ds = higgs_like(4000, 7);
        assert_eq!(ds.n_features(), 28);
        assert!(ds.x.density() > 0.5, "density={}", ds.x.density());
        // low diversity: far fewer species than rows
        assert!(ds.n_species() <= 4000 / 8 + 1, "species={}", ds.n_species());
    }

    #[test]
    fn higgs_diversity_knob_works() {
        let lo = higgs_like_with_diversity(2000, 10, 3);
        let hi = higgs_like_with_diversity(2000, 2000, 3);
        assert!(lo.n_species() <= 10);
        assert!(hi.n_species() > lo.n_species() * 10);
    }

    #[test]
    fn generators_are_deterministic() {
        let a = realsim_like(500, 9);
        let b = realsim_like(500, 9);
        assert_eq!(a.y, b.y);
        assert_eq!(a.x, b.x);
        let c = realsim_like(500, 10);
        assert_ne!(a.y, c.y);
    }

    #[test]
    fn labels_are_learnable_not_random() {
        // A trivial single-feature threshold should beat 50/50 on the
        // separating structure: check the logit direction correlates with
        // labels by comparing class-conditional means of a common feature.
        let ds = realsim_like(4000, 11);
        // count agreement between most popular feature presence and labels;
        // weak but must differ from exact independence for learnability.
        let pos = ds.positive_rate();
        assert!(pos > 0.2 && pos < 0.8);
    }

    #[test]
    fn regression_like_has_continuous_centred_labels() {
        let ds = regression_like(2_000, 21);
        assert_eq!(ds.n_rows(), 2_000);
        assert!(ds.x.density() < 0.02, "density={}", ds.x.density());
        // labels are continuous (not {0,1}) and centred near 3.0
        let non_binary = ds.y.iter().filter(|&&y| y != 0.0 && y != 1.0).count();
        assert!(non_binary > 1_900, "only {non_binary} non-binary labels");
        let mean = ds.y.iter().map(|&y| y as f64).sum::<f64>() / ds.n_rows() as f64;
        assert!((mean - 3.0).abs() < 0.5, "mean={mean}");
        // the outlier tail exists but is rare
        let spread = ds.y.iter().map(|&y| (y as f64 - mean).abs());
        let far = spread.filter(|&d| d > 5.0).count();
        assert!(far > 0 && far < ds.n_rows() / 10, "outliers={far}");
        // deterministic per seed
        let again = regression_like(2_000, 21);
        assert_eq!(ds.y, again.y);
        assert_ne!(ds.y, regression_like(2_000, 22).y);
    }

    #[test]
    fn multiclass_like_labels_are_class_ids_all_present() {
        for k in [3usize, 5] {
            let ds = multiclass_like(1_500, k, 33);
            assert_eq!(ds.n_rows(), 1_500);
            let mut counts = vec![0usize; k];
            for &y in &ds.y {
                assert!(y >= 0.0 && y.fract() == 0.0 && (y as usize) < k, "label {y}");
                counts[y as usize] += 1;
            }
            // every class occupied, none overwhelmingly dominant
            for (c, &n) in counts.iter().enumerate() {
                assert!(n > 0, "class {c} empty (k={k})");
                assert!(n < 1_400, "class {c} has {n}/1500 rows (k={k})");
            }
            let again = multiclass_like(1_500, k, 33);
            assert_eq!(ds.y, again.y);
        }
    }

    #[test]
    fn fig4_datasets_match_paper_shapes() {
        let lo = fig4_low_diversity(1);
        assert_eq!(lo.n_rows(), 60_000);
        assert_eq!(lo.n_species(), 3);
        let hi = fig4_high_diversity(1);
        assert_eq!(hi.n_rows(), 14_000);
        assert!(hi.n_species() > 13_900);
    }

    #[test]
    fn e2006_like_shape() {
        let ds = e2006_like(400, 5);
        assert_eq!(ds.n_rows(), 400);
        assert!(ds.n_features() >= 1 << 12);
        assert!(ds.x.density() < 0.05);
    }

    #[test]
    fn power_law_cdf_monotone_normalised() {
        let cum = power_law_cdf(100, 1.1);
        assert!(cum.windows(2).all(|w| w[0] < w[1]));
        assert!((cum.last().unwrap() - 1.0).abs() < 1e-12);
        // head features much more likely than tail
        assert!(cum[0] > 0.05);
    }
}
