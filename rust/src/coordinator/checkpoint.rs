//! Checkpoint/resume plumbing shared by all three trainers.
//!
//! A checkpoint is an ordinary `.sgbdt` artifact (`io/artifact.rs`) with
//! the trainer stanza filled in: mode, accepted-tree count, and — for the
//! sequential-RNG trainers — the raw xoshiro256** state of the
//! tree-build RNG. Restore replays the checkpointed trees through
//! [`ServerCore::replay_tree`], which re-runs the accept pipeline's
//! deterministic arithmetic in the original operation order, so after
//! replay the server state (F, targets, sampler keys, loss curve) is
//! bit-identical to the uninterrupted run at the same tree count; the
//! restored RNG state then continues the build stream exactly. The
//! result: `train --resume <ck>` produces the same final forest, bit for
//! bit, as the run that was never interrupted (pinned per-mode by
//! `tests/test_artifact.rs`).

use std::path::PathBuf;

use anyhow::{anyhow, bail, Result};

use crate::config::{StepMode, TrainConfig};
use crate::data::{BinCuts, BinnedDataset};
use crate::forest::FlatForest;
use crate::io::artifact::{self, ArtifactMeta, SgbdtArtifact, TrainerState};
use crate::ps::ServerCore;
use crate::util::Rng;

/// The per-run checkpoint sink a trainer consults after every accepted
/// tree. With `checkpoint_every=0` (the default) [`Checkpointer::due`]
/// is a constant `false` and no artifact code runs — the same zero-cost
/// contract as the fault layer.
pub(crate) struct Checkpointer {
    every: usize,
    path: Option<PathBuf>,
    n_trees_target: usize,
    fingerprint: String,
    seed: u64,
    loss: String,
    mode: &'static str,
    cuts: BinCuts,
}

impl Checkpointer {
    /// Capture what every checkpoint of this run shares (fingerprint,
    /// cuts, mode). Cheap when checkpointing is off — the cuts clone is
    /// the only cost, paid once per run.
    pub fn new(cfg: &TrainConfig, binned: &BinnedDataset, mode: &'static str) -> Checkpointer {
        Checkpointer {
            every: cfg.checkpoint_every,
            path: cfg.checkpoint_path.clone(),
            n_trees_target: cfg.n_trees,
            fingerprint: cfg.fingerprint(),
            seed: cfg.seed,
            loss: cfg.loss.as_str().to_string(),
            mode,
            cuts: binned.cuts(),
        }
    }

    /// Whether the tree that took the accept counter to `n` triggers a
    /// checkpoint. The final tree never does — the run is about to write
    /// its real model artifact anyway.
    pub fn due(&self, n: usize) -> bool {
        self.every > 0 && n > 0 && n % self.every == 0 && n < self.n_trees_target
    }

    /// Write the checkpoint: the base path always holds the latest, and
    /// a `<stem>.tK.<ext>` copy keeps every cadence point so a run can
    /// be resumed from any of them.
    pub fn write(&self, core: &ServerCore, rng: Option<&Rng>, wall_secs: f64) -> Result<()> {
        let path = self
            .path
            .as_ref()
            .expect("validate() rejects checkpoint_every>0 without checkpoint_path");
        let flat = FlatForest::from_forest(&core.forest);
        let meta = ArtifactMeta {
            config_fingerprint: self.fingerprint.clone(),
            seed: self.seed,
            loss: self.loss.clone(),
            train_secs: wall_secs,
            trainer: Some(TrainerState {
                mode: self.mode.to_string(),
                trees_done: core.n_trees(),
                rng_state: rng.map(|r| r.state()),
            }),
        };
        artifact::save(&artifact::checkpoint_file(path, core.n_trees()), &flat, &self.cuts, &meta)?;
        artifact::save(path, &flat, &self.cuts, &meta)
    }
}

/// Restore a fresh [`ServerCore`] to a checkpoint's state by replaying
/// its trees, after verifying the checkpoint actually belongs to this
/// run: same loss, same config fingerprint, same trainer mode, same bin
/// cuts (i.e. the same training data). Each tree is replayed at the
/// step scale recorded in the artifact — that is what makes
/// `step=adaptive` checkpoints (whose per-tree scales vary with the
/// recorded staleness) restore bit for bit; under `step=fixed` every
/// recorded scale must additionally equal this run's `step_length`.
/// Multiclass checkpoints replay in rounds of `n_classes` class trees.
/// Returns the checkpointed build-RNG state (`None` for async, whose
/// builds draw nothing at `feature_rate=1` and whose sampling is
/// counter-keyed inside the core).
pub(crate) fn restore(
    core: &mut ServerCore,
    a: &SgbdtArtifact,
    cfg: &TrainConfig,
    mode: &str,
    binned: &BinnedDataset,
) -> Result<Option<[u64; 4]>> {
    let trainer = a.trainer.as_ref().ok_or_else(|| {
        anyhow!(
            "--resume: artifact is a final model, not a checkpoint (no trainer stanza — \
             checkpoints are written by checkpoint_every=N)"
        )
    })?;
    if trainer.mode != mode {
        bail!(
            "--resume: checkpoint was written by mode={}, this run is mode={mode} — \
             resume with the mode that wrote it",
            trainer.mode
        );
    }
    if a.loss != cfg.loss.as_str() {
        bail!(
            "--resume: checkpoint was trained with loss={}, this run trains loss={} — \
             resumed training must keep the loss that wrote the checkpoint",
            a.loss,
            cfg.loss.as_str()
        );
    }
    let expected = cfg.fingerprint();
    if a.config_fingerprint != expected {
        bail!(
            "--resume: config fingerprint mismatch: this run is {expected}, checkpoint was \
             trained under {} — resumed training must use the training-equivalent config \
             (byte-plumbing knobs like checkpoint_every/format may differ)",
            a.config_fingerprint
        );
    }
    // trees_done counts accepted pushes: rounds for multiclass (the
    // forest then holds n_classes trees per round), trees otherwise.
    let k = if cfg.scalar_loss().is_some() { 1 } else { cfg.n_classes };
    if trainer.trees_done * k != a.forest.n_trees() {
        bail!(
            "--resume: trainer stanza claims {} trees{} but the artifact holds {}",
            trainer.trees_done,
            if k > 1 { format!(" of {k} classes each") } else { String::new() },
            a.forest.n_trees()
        );
    }
    if trainer.trees_done > cfg.n_trees {
        bail!(
            "--resume: checkpoint already holds {} trees, past this run's n_trees={}",
            trainer.trees_done,
            cfg.n_trees
        );
    }
    if a.cuts != binned.cuts() {
        bail!(
            "--resume: checkpoint bin cuts differ from this run's training data — resume \
             must use the exact dataset (and max_bins) the checkpoint was trained on"
        );
    }
    if k == 1 {
        for (i, (v, ft)) in a.forest.trees.iter().enumerate() {
            if cfg.step == StepMode::Fixed && *v != cfg.step_length {
                bail!(
                    "--resume: tree {i} was pushed with step length {v}, this run uses \
                     step=fixed step_length={} — the checkpoint belongs to a different \
                     configuration",
                    cfg.step_length
                );
            }
            core.replay_tree_with(ft.to_tree(), *v)?;
        }
    } else {
        for (round, chunk) in a.forest.trees.chunks(k).enumerate() {
            let v = chunk[0].0;
            if chunk.iter().any(|(vi, _)| *vi != v) {
                bail!(
                    "--resume: multiclass round {round} stores mixed step scales — the \
                     artifact's class trees are not from one accepted push"
                );
            }
            if cfg.step == StepMode::Fixed && v != cfg.step_length {
                bail!(
                    "--resume: round {round} was pushed with step length {v}, this run \
                     uses step=fixed step_length={} — the checkpoint belongs to a \
                     different configuration",
                    cfg.step_length
                );
            }
            core.replay_round(chunk.iter().map(|(_, ft)| ft.to_tree()).collect(), v)?;
        }
    }
    Ok(trainer.rng_state)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{synthetic, Dataset};
    use crate::runtime::GradientEngine;
    use std::sync::Arc;

    fn setup() -> (TrainConfig, Dataset, Arc<BinnedDataset>) {
        let mut cfg = TrainConfig::default();
        cfg.mode = crate::config::TrainMode::Serial;
        cfg.n_trees = 8;
        cfg.step_length = 0.3;
        cfg.max_bins = 16;
        cfg.tree.max_leaves = 4;
        let ds = synthetic::realsim_like(120, 7);
        let binned = Arc::new(BinnedDataset::from_dataset(&ds, cfg.max_bins).unwrap());
        (cfg, ds, binned)
    }

    fn artifact_for(
        cfg: &TrainConfig,
        binned: &BinnedDataset,
        mode: &str,
        trees_done: usize,
    ) -> SgbdtArtifact {
        let core_forest = crate::forest::Forest::new(0.0);
        let meta = ArtifactMeta {
            config_fingerprint: cfg.fingerprint(),
            seed: cfg.seed,
            loss: cfg.loss.as_str().to_string(),
            train_secs: 0.0,
            trainer: Some(TrainerState {
                mode: mode.to_string(),
                trees_done,
                rng_state: Some(Rng::new(1).state()),
            }),
        };
        let bytes = artifact::to_bytes(
            &FlatForest::from_forest(&core_forest),
            &binned.cuts(),
            &meta,
        );
        artifact::load_bytes(&bytes).unwrap()
    }

    #[test]
    fn restore_rejects_foreign_checkpoints_by_name() {
        let (cfg, ds, binned) = setup();
        let engine = GradientEngine::auto(&cfg.artifact_dir);
        let mut core = ServerCore::new(&cfg, &ds, binned.clone(), None, engine).unwrap();
        // wrong mode
        let a = artifact_for(&cfg, &binned, "async", 0);
        let err = restore(&mut core, &a, &cfg, "serial", &binned)
            .unwrap_err()
            .to_string();
        assert!(err.contains("mode=async") && err.contains("mode=serial"), "{err}");
        // wrong loss (checked before the fingerprint so the error names
        // the actual disagreement, not just "configs differ")
        let mut sq = cfg.clone();
        sq.loss = crate::loss::LossKind::Squared;
        let a = artifact_for(&sq, &binned, "serial", 0);
        let err = restore(&mut core, &a, &cfg, "serial", &binned)
            .unwrap_err()
            .to_string();
        assert!(err.contains("loss=squared") && err.contains("loss=logistic"), "{err}");
        // wrong config fingerprint
        let mut other = cfg.clone();
        other.seed = 99;
        let a = artifact_for(&other, &binned, "serial", 0);
        let err = restore(&mut core, &a, &cfg, "serial", &binned)
            .unwrap_err()
            .to_string();
        assert!(err.contains("fingerprint"), "{err}");
        // trainer stanza trees disagree with the payload
        let a = artifact_for(&cfg, &binned, "serial", 3);
        let err = restore(&mut core, &a, &cfg, "serial", &binned)
            .unwrap_err()
            .to_string();
        assert!(err.contains("claims 3 trees") && err.contains("holds 0"), "{err}");
        // a final model (no stanza) is not resumable
        let meta = ArtifactMeta {
            config_fingerprint: cfg.fingerprint(),
            seed: cfg.seed,
            loss: "logistic".to_string(),
            train_secs: 0.0,
            trainer: None,
        };
        let bytes = artifact::to_bytes(
            &FlatForest::from_forest(&crate::forest::Forest::new(0.0)),
            &binned.cuts(),
            &meta,
        );
        let a = artifact::load_bytes(&bytes).unwrap();
        let err = restore(&mut core, &a, &cfg, "serial", &binned)
            .unwrap_err()
            .to_string();
        assert!(err.contains("trainer stanza"), "{err}");
        // different training data (different cuts)
        let other_ds = synthetic::realsim_like(120, 8);
        let other_binned = BinnedDataset::from_dataset(&other_ds, cfg.max_bins).unwrap();
        let a = artifact_for(&cfg, &other_binned, "serial", 0);
        let err = restore(&mut core, &a, &cfg, "serial", &binned)
            .unwrap_err()
            .to_string();
        assert!(err.contains("bin cuts"), "{err}");
    }

    #[test]
    fn checkpointer_due_respects_cadence_and_skips_the_final_tree() {
        let (mut cfg, _, binned) = setup();
        cfg.checkpoint_every = 2;
        cfg.checkpoint_path = Some(PathBuf::from("ck.sgbdt"));
        let ck = Checkpointer::new(&cfg, &binned, "serial");
        let due: Vec<usize> = (0..=8).filter(|&n| ck.due(n)).collect();
        assert_eq!(due, vec![2, 4, 6], "n_trees=8: never at 0 or at the final tree");
        // off by default: no artifact code on the plain path
        cfg.checkpoint_every = 0;
        cfg.checkpoint_path = None;
        let off = Checkpointer::new(&cfg, &binned, "serial");
        assert!((0..=8).all(|n| !off.due(n)));
    }
}
