//! Synchronous fork-join baseline (LightGBM-style).
//!
//! Algorithmically identical to serial GBDT — one fresh target per tree,
//! zero staleness — but the build-tree sub-step forks `cfg.workers` threads
//! per histogram and joins them (the barrier). This is the "parallel part
//! only exists in the sub-step of building the tree" pattern of §II; its
//! scaling saturates with worker count while convergence per tree matches
//! serial exactly, which is what Figures 5–10 contrast against.
//!
//! Each accepted tree goes through the accept pipeline selected by
//! `cfg.target` inside [`ServerCore::apply_tree`] — the fused
//! row-sharded pass by default, or the serial reference sweeps
//! (`cfg.scoring` / `cfg.score_threads`) — on the scoring
//! [`crate::util::Executor`] the core builds once at startup
//! (`cfg.pool`). The tree-building side holds its own run-lifetime
//! executor of `cfg.workers` threads under the same `pool` knob:
//! `pool=scoped` reproduces the historical spawn-per-histogram
//! fork-join cost, `pool=persistent` (default) keeps the barriers but
//! parks the threads between histograms — same trees bit for bit
//! either way. As in the other trainers, `cfg.ps_shards` only changes
//! the server-internal accept layout (`ps/sharded.rs`), never the trees.

use std::sync::Arc;

use anyhow::{anyhow, Result};

use crate::config::TrainConfig;
use crate::data::{BinnedDataset, Dataset};
use crate::io::artifact::SgbdtArtifact;
use crate::metrics::SupervisionStats;
use crate::ps::ServerCore;
use crate::runtime::GradientEngine;
use crate::tree::{build_tree_forkjoin_pooled, HistogramPool};
use crate::util::stats::Summary;
use crate::util::{Executor, Rng, Stopwatch};

use super::checkpoint::{self, Checkpointer};
use super::report::TrainReport;

/// Train with the synchronous fork-join baseline: serial convergence,
/// `cfg.workers`-way parallel histogram building per tree.
pub fn train_sync(
    cfg: &TrainConfig,
    train: &Dataset,
    test: Option<&Dataset>,
) -> Result<TrainReport> {
    train_sync_resumed(cfg, train, test, None)
}

/// [`train_sync`], optionally picking up from a checkpoint artifact —
/// same replay-then-restore-RNG contract as
/// [`super::train_serial_resumed`] (the sync trainer shares the serial
/// sampling stream, so the same RNG state applies).
pub fn train_sync_resumed(
    cfg: &TrainConfig,
    train: &Dataset,
    test: Option<&Dataset>,
    resume: Option<&SgbdtArtifact>,
) -> Result<TrainReport> {
    let cfg = cfg.clone();
    cfg.validate()?;
    let clock = Stopwatch::new();
    let binned = Arc::new(BinnedDataset::from_dataset(train, cfg.max_bins)?);
    let engine = GradientEngine::auto_for(&cfg.artifact_dir, cfg.scalar_loss());
    let mut core = ServerCore::new(&cfg, train, binned.clone(), test, engine)?;
    let mut rng = Rng::new(cfg.seed ^ 0x0ddb_a11);
    if let Some(a) = resume {
        let state = checkpoint::restore(&mut core, a, &cfg, "sync", &binned)?
            .ok_or_else(|| anyhow!("--resume: sync checkpoint is missing its RNG state"))?;
        rng = Rng::from_state(state);
    }
    let ckpt = Checkpointer::new(&cfg, &binned, "sync");
    let mut build_times = Vec::with_capacity(cfg.n_trees);
    // merged per-leaf histograms recycled across all n_trees builds
    let mut pool = HistogramPool::new(binned.total_bins());
    // run-lifetime build executor: the fork-join barriers stay (that is
    // the cost model this baseline exists to measure), but under
    // pool=persistent the per-histogram spawns become condvar wakes on
    // one pool of cfg.workers parked threads; pool=scoped keeps the
    // spawn-per-histogram reference cost
    let build_exec = Executor::new(cfg.pool, cfg.workers);

    while core.n_trees() < cfg.n_trees {
        let snapshot = core.snapshot();
        let mut sw = Stopwatch::new();
        let tree = build_tree_forkjoin_pooled(
            &binned,
            &snapshot.rows,
            &snapshot.grad,
            &snapshot.hess,
            &cfg.tree,
            &mut rng,
            &build_exec,
            &mut pool,
        );
        build_times.push(sw.lap());
        core.apply_tree(tree, snapshot.version)?;
        if ckpt.due(core.n_trees()) {
            ckpt.write(&core, Some(&rng), clock.elapsed())?;
        }
    }

    let engine = core.engine_kind();
    Ok(TrainReport {
        trees_accepted: core.n_trees(),
        trees_rejected: core.staleness.rejected,
        wall_secs: clock.elapsed(),
        build_times: Summary::of(&build_times),
        engine,
        mode: "sync".into(),
        workers: cfg.workers,
        supervision: SupervisionStats::all_alive(cfg.workers),
        fault_trace: Vec::new(),
        cuts: binned.cuts(),
        forest: core.forest,
        curve: core.curve,
        staleness: core.staleness,
        steps: core.steps,
        timer: core.timer,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::train_serial;
    use crate::data::synthetic;

    fn small_cfg(workers: usize) -> TrainConfig {
        let mut cfg = TrainConfig::default();
        cfg.n_trees = 12;
        cfg.step_length = 0.3;
        cfg.sampling_rate = 0.9;
        cfg.workers = workers;
        cfg.tree.max_leaves = 8;
        cfg.max_bins = 16;
        cfg.eval_every = 4;
        cfg
    }

    #[test]
    fn sync_converges_identically_to_serial() {
        // same seed => same sampling stream => same trees => same curve;
        // the fork-join parallelism must not change the algorithm.
        let ds = synthetic::realsim_like(300, 21);
        let serial = train_serial(&small_cfg(1), &ds, None).unwrap();
        let sync = train_sync(&small_cfg(4), &ds, None).unwrap();
        let ls: Vec<f64> = serial.curve.points.iter().map(|p| p.train_loss).collect();
        let lp: Vec<f64> = sync.curve.points.iter().map(|p| p.train_loss).collect();
        assert_eq!(ls.len(), lp.len());
        for (a, b) in ls.iter().zip(&lp) {
            assert!((a - b).abs() < 1e-6, "{a} vs {b}");
        }
        assert_eq!(sync.staleness.max(), 0);
        assert_eq!(sync.mode, "sync");
        assert_eq!(sync.workers, 4);
    }
}
