//! The asynch-SGBDT trainer — Algorithm 3 end to end.
//!
//! Topology (threads as workers, matching the paper's validity
//! experiments): the calling thread becomes the *server* (it owns the
//! PJRT gradient engine, which is not `Send`); `cfg.workers` spawned
//! threads run the worker loop. Workers pull versioned target snapshots
//! from the [`crate::ps::Board`] and push trees over an mpsc channel;
//! the server applies each push (update F → resample → produce target →
//! publish) and stops after `cfg.n_trees` accepted trees.
//!
//! Staleness τ is *measured*, not configured: with more workers, more
//! pushes race a given target version and τ grows — the knob the paper's
//! Proposition 1 ties to the required step length.
//!
//! Each spawned worker owns a `HistogramPool` *and* a build
//! [`crate::util::Executor`] for its whole lifetime (see `ps::worker`):
//! histogram buffers are allocated only on the first tree, and with
//! `build_threads>1` the intra-tree fork-join cycles (sharded leaf
//! histograms, work-stealing split search) dispatch onto the worker's
//! own pool of parked threads instead of spawning per leaf —
//! `cfg.pool` governs worker-side build executors exactly as it governs
//! the server's scoring executor. `cfg.tree.strategy` selects sibling
//! subtraction (default) or whole-node rebuild for every worker.
//!
//! On the server side, every accepted tree runs the accept pipeline
//! selected by `cfg.target`: the fused row-sharded pass (default,
//! `ps/shard.rs`) folds the F-update, the counter-keyed Bernoulli
//! sample, the new target's grad/hess and the eval partials into one
//! sweep across `cfg.score_threads` shards; `target=serial` keeps the
//! reference sweeps (blocked SoA scoring per `cfg.scoring`). Those
//! shards run on the server's [`crate::util::Executor`], constructed
//! once when `ServerCore` is built: under `pool=persistent` (default) a
//! [`crate::util::ScorePool`] keeps the workers parked between trees,
//! so the accept path pays a condvar wake instead of `score_threads`
//! OS-thread spawn/joins per accepted tree. The accept path bounds
//! accepted trees/sec at high worker counts — measured by
//! `bench_ps_throughput`'s fused-vs-serial and persistent-vs-scoped
//! breakdowns.
//!
//! With `cfg.ps_shards > 1` the server routes its fused pass through the
//! sharded PS (`ps/sharded.rs`): the accept sweep is carved at the row
//! partition's boundaries instead of the thread budget's, and each
//! publish advances every shard's version cell before composing the
//! board-visible version. The coordinator is unchanged — same board,
//! same channel, same loop — because sharding is a server-internal
//! layout, pinned bit-identical by `tests/test_sharded_ps.rs`.

use std::sync::mpsc;
use std::sync::Arc;

use anyhow::Result;

use crate::config::TrainConfig;
use crate::data::{BinnedDataset, Dataset};
use crate::ps::{run_worker, Board, ServerCore};
use crate::runtime::GradientEngine;
use crate::util::stats::Summary;
use crate::util::{Executor, Stopwatch};

use super::report::TrainReport;

/// Train asynchronously on the parameter server: `cfg.workers` worker
/// threads race pulls/builds/pushes while the calling thread runs the
/// server accept loop until `cfg.n_trees` trees are accepted.
pub fn train_async(
    cfg: &TrainConfig,
    train: &Dataset,
    test: Option<&Dataset>,
) -> Result<TrainReport> {
    let cfg = cfg.clone();
    cfg.validate()?;
    let clock = Stopwatch::new();
    let binned = Arc::new(BinnedDataset::from_dataset(train, cfg.max_bins)?);
    let engine = GradientEngine::auto(&cfg.artifact_dir);
    let mut core = ServerCore::new(&cfg, train, binned.clone(), test, engine)?;

    let board = Board::new();
    board.publish(core.snapshot());
    let (tx, rx) = mpsc::channel();

    let mut build_times: Vec<f64> = Vec::with_capacity(cfg.n_trees);

    std::thread::scope(|s| -> Result<()> {
        // fork the workers
        let mut handles = Vec::with_capacity(cfg.workers);
        for wid in 0..cfg.workers {
            let tx = tx.clone();
            let binned = binned.clone();
            let board_ref = &board;
            let params = cfg.tree;
            let seed = cfg.seed;
            let (pool_mode, build_threads) = (cfg.pool, cfg.build_threads);
            handles.push(s.spawn(move || {
                // worker-lifetime build executor, owned on the worker's own
                // thread: one pool of parked threads per worker (executors
                // are never shared — ScorePool serializes concurrent
                // dispatchers, which would serialize the workers' builds)
                let exec = Executor::new(pool_mode, build_threads);
                run_worker(wid, board_ref, binned, params, &exec, tx, seed)
            }));
        }
        drop(tx); // server holds only the receiver

        // the server accept loop
        while core.n_trees() < cfg.n_trees {
            let push = match rx.recv() {
                Ok(p) => p,
                Err(_) => break, // all workers gone (shouldn't happen)
            };
            build_times.push(push.build_secs);
            let outcome = core.apply_tree(push.tree, push.based_on)?;
            if outcome.accepted {
                board.publish(core.snapshot());
            }
        }

        // stop the world; drain in-flight pushes so senders never block
        board.request_shutdown();
        while let Ok(_ignored) = rx.try_recv() {}
        for h in handles {
            let _ = h.join();
        }
        // final drain (workers may have pushed between drain and join)
        while let Ok(_ignored) = rx.try_recv() {}
        Ok(())
    })?;

    let engine = core.engine_kind();
    Ok(TrainReport {
        trees_accepted: core.n_trees(),
        trees_rejected: core.staleness.rejected,
        wall_secs: clock.elapsed(),
        build_times: Summary::of(&build_times),
        engine,
        mode: "async".into(),
        workers: cfg.workers,
        forest: core.forest,
        curve: core.curve,
        staleness: core.staleness,
        timer: core.timer,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic;

    fn small_cfg(workers: usize, n_trees: usize) -> TrainConfig {
        let mut cfg = TrainConfig::default();
        cfg.n_trees = n_trees;
        cfg.step_length = 0.2;
        cfg.sampling_rate = 0.8;
        cfg.workers = workers;
        cfg.tree.max_leaves = 8;
        cfg.max_bins = 16;
        cfg.eval_every = 10;
        cfg
    }

    #[test]
    fn async_trains_exactly_n_trees_and_descends() {
        let ds = synthetic::realsim_like(400, 31);
        let rep = train_async(&small_cfg(4, 30), &ds, None).unwrap();
        assert_eq!(rep.trees_accepted, 30);
        assert_eq!(rep.forest.n_trees(), 30);
        let first = rep.curve.points.first().unwrap().train_loss;
        let last = rep.curve.points.last().unwrap().train_loss;
        assert!(last < first, "loss did not descend: {first} -> {last}");
        assert_eq!(rep.mode, "async");
    }

    #[test]
    fn staleness_is_measured_and_bounded() {
        // NOTE: even one worker can run several versions ahead of the
        // server (the push channel is unbounded and the worker keeps
        // rebuilding on the stale target — exactly the delayed-SGD model),
        // so absolute staleness levels are timing-dependent. The stable
        // invariants: τ is recorded for every accepted push, τ < n_trees,
        // and many racing workers produce nonzero staleness.
        let ds = synthetic::realsim_like(300, 32);
        let one = train_async(&small_cfg(1, 24), &ds, None).unwrap();
        let many = train_async(&small_cfg(8, 24), &ds, None).unwrap();
        assert_eq!(one.staleness.samples.len(), 24);
        assert_eq!(many.staleness.samples.len(), 24);
        assert!(one.staleness.max() < 24);
        assert!(many.staleness.max() < 24);
        assert!(
            many.staleness.mean() >= 1.0,
            "8 racing workers should show real staleness, got {}",
            many.staleness.mean()
        );
    }

    #[test]
    fn async_with_parallel_build_workers_completes_and_descends() {
        // every worker holds its own persistent build executor: 3 workers
        // × 2 build threads racing the server for 15 accepted trees
        let ds = synthetic::realsim_like(300, 34);
        let mut cfg = small_cfg(3, 15);
        cfg.build_threads = 2;
        let rep = train_async(&cfg, &ds, None).unwrap();
        assert_eq!(rep.trees_accepted, 15);
        let first = rep.curve.points.first().unwrap().train_loss;
        let last = rep.curve.points.last().unwrap().train_loss;
        assert!(last < first, "loss did not descend: {first} -> {last}");
    }

    #[test]
    fn async_with_sharded_ps_completes_and_descends() {
        // ps_shards=2 through the full async lifecycle: the sharded
        // accept route and composed versions behind a live worker race
        // (bit-identity is pinned separately in tests/test_sharded_ps.rs)
        let ds = synthetic::realsim_like(1_200, 35);
        let mut cfg = small_cfg(3, 15);
        cfg.ps_shards = 2;
        cfg.score_threads = 2;
        let rep = train_async(&cfg, &ds, None).unwrap();
        assert_eq!(rep.trees_accepted, 15);
        let first = rep.curve.points.first().unwrap().train_loss;
        let last = rep.curve.points.last().unwrap().train_loss;
        assert!(last < first, "loss did not descend: {first} -> {last}");
    }

    #[test]
    fn bounded_staleness_rejects_under_pressure() {
        let ds = synthetic::realsim_like(300, 33);
        let mut cfg = small_cfg(8, 20);
        cfg.max_staleness = Some(0); // only fresh pushes accepted
        let rep = train_async(&cfg, &ds, None).unwrap();
        assert_eq!(rep.trees_accepted, 20);
        // with 8 racing workers and tau<=0 required, rejections must occur
        assert!(rep.trees_rejected > 0, "expected rejected pushes");
        assert_eq!(rep.staleness.max(), 0); // accepted ones all fresh
    }
}
