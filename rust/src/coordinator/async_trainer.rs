//! The asynch-SGBDT trainer — Algorithm 3 end to end.
//!
//! Topology (threads as workers, matching the paper's validity
//! experiments): the calling thread becomes the *server* (it owns the
//! PJRT gradient engine, which is not `Send`); `cfg.workers` spawned
//! threads run the worker loop. Workers pull versioned target snapshots
//! from the [`crate::ps::Board`] and push trees over an mpsc channel;
//! the server applies each push (update F → resample → produce target →
//! publish) and stops after `cfg.n_trees` accepted trees.
//!
//! Staleness τ is *measured*, not configured: with more workers, more
//! pushes race a given target version and τ grows — the knob the paper's
//! Proposition 1 ties to the required step length.
//!
//! Each spawned worker owns a `HistogramPool` *and* a build
//! [`crate::util::Executor`] for its whole lifetime (see `ps::worker`):
//! histogram buffers are allocated only on the first tree, and with
//! `build_threads>1` the intra-tree fork-join cycles (sharded leaf
//! histograms, work-stealing split search) dispatch onto the worker's
//! own pool of parked threads instead of spawning per leaf —
//! `cfg.pool` governs worker-side build executors exactly as it governs
//! the server's scoring executor. `cfg.tree.strategy` selects sibling
//! subtraction (default) or whole-node rebuild for every worker.
//!
//! On the server side, every accepted tree runs the accept pipeline
//! selected by `cfg.target`: the fused row-sharded pass (default,
//! `ps/shard.rs`) folds the F-update, the counter-keyed Bernoulli
//! sample, the new target's grad/hess and the eval partials into one
//! sweep across `cfg.score_threads` shards; `target=serial` keeps the
//! reference sweeps (blocked SoA scoring per `cfg.scoring`). Those
//! shards run on the server's [`crate::util::Executor`], constructed
//! once when `ServerCore` is built: under `pool=persistent` (default) a
//! [`crate::util::ScorePool`] keeps the workers parked between trees,
//! so the accept path pays a condvar wake instead of `score_threads`
//! OS-thread spawn/joins per accepted tree. The accept path bounds
//! accepted trees/sec at high worker counts — measured by
//! `bench_ps_throughput`'s fused-vs-serial and persistent-vs-scoped
//! breakdowns.
//!
//! With `cfg.ps_shards > 1` the server routes its fused pass through the
//! sharded PS (`ps/sharded.rs`): the accept sweep is carved at the row
//! partition's boundaries instead of the thread budget's, and each
//! publish advances every shard's version cell before composing the
//! board-visible version. The coordinator is unchanged — same board,
//! same channel, same loop — because sharding is a server-internal
//! layout, pinned bit-identical by `tests/test_sharded_ps.rs`.

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::mpsc;
use std::sync::Arc;

use anyhow::{bail, Result};

use crate::config::TrainConfig;
use crate::data::{BinnedDataset, Dataset};
use crate::io::artifact::SgbdtArtifact;
use crate::metrics::SupervisionStats;
use crate::ps::{run_worker_harnessed, Board, ServerCore, WorkerHarness};
use crate::runtime::GradientEngine;
use crate::util::fault::worker_identity_seed;
use crate::util::stats::Summary;
use crate::util::{Executor, Stopwatch};

use super::checkpoint::{self, Checkpointer};
use super::report::TrainReport;

/// What one worker thread's supervision loop reports back on exit.
struct WorkerExit {
    /// The final panic message if the worker retired dead (restart
    /// budget exhausted, or shutdown arrived while it was down).
    died: Option<String>,
    /// Restarts the supervisor granted this worker.
    restarts: u64,
}

/// Render a panic payload for the run report / stall error.
fn panic_message(payload: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    }
}

/// Train asynchronously on the parameter server: `cfg.workers` worker
/// threads race pulls/builds/pushes while the calling thread runs the
/// server accept loop until `cfg.n_trees` trees are accepted.
pub fn train_async(
    cfg: &TrainConfig,
    train: &Dataset,
    test: Option<&Dataset>,
) -> Result<TrainReport> {
    train_async_resumed(cfg, train, test, None)
}

/// [`train_async`], optionally picking up from a checkpoint artifact.
/// The checkpointed trees are replayed through the accept pipeline
/// *before* the first board publish, so workers start pulling at the
/// checkpoint's target version. No RNG state is involved: worker builds
/// draw nothing at `feature_rate=1`, and the server's Bernoulli sampler
/// is counter-keyed on `(seed, version, row)` — both are functions of
/// the replayed state. Resumed runs are bit-identical given the same
/// determinism envelope that makes plain async runs repeatable
/// (`max_staleness=0`, `feature_rate=1` — see `tests/test_artifact.rs`).
pub fn train_async_resumed(
    cfg: &TrainConfig,
    train: &Dataset,
    test: Option<&Dataset>,
    resume: Option<&SgbdtArtifact>,
) -> Result<TrainReport> {
    let cfg = cfg.clone();
    cfg.validate()?;
    let clock = Stopwatch::new();
    let binned = Arc::new(BinnedDataset::from_dataset(train, cfg.max_bins)?);
    let engine = GradientEngine::auto_for(&cfg.artifact_dir, cfg.scalar_loss());
    let mut core = ServerCore::new(&cfg, train, binned.clone(), test, engine)?;
    if let Some(a) = resume {
        // async checkpoints carry no sequential RNG words — ignore them
        let _ = checkpoint::restore(&mut core, a, &cfg, "async", &binned)?;
    }
    let ckpt = Checkpointer::new(&cfg, &binned, "async");

    // the fault plan and supervision flag drive everything below; with
    // the default config (`fault_seed=none`, `worker_restarts=0`) no
    // plan exists, the board has no heartbeat cells and each worker runs
    // a single bare incarnation — the zero-cost path (DESIGN.md §14)
    let plan = cfg.fault_plan();
    let supervised = cfg.supervised();
    let restarts_allowed = if supervised { cfg.worker_restarts } else { 0 };

    let board = if supervised {
        Board::with_heartbeats(cfg.workers)
    } else {
        Board::new()
    };
    board.publish(core.snapshot());
    let (tx, rx) = mpsc::channel();

    let mut build_times: Vec<f64> = Vec::with_capacity(cfg.n_trees);

    let exits = std::thread::scope(|s| -> Result<Vec<(usize, WorkerExit)>> {
        // fork the workers, each under its own supervision loop
        let mut handles = Vec::with_capacity(cfg.workers);
        for wid in 0..cfg.workers {
            let tx = tx.clone();
            let binned = binned.clone();
            let board_ref = &board;
            let params = cfg.tree;
            let base_seed = cfg.seed;
            let plan_ref = plan.as_ref();
            let (pool_mode, build_threads) = (cfg.pool, cfg.build_threads);
            handles.push(s.spawn(move || {
                let mut incarnation = 0u64;
                let mut restarts = 0u64;
                loop {
                    // each incarnation gets a fresh derived identity so a
                    // restarted worker never replays its predecessor's
                    // sampling/fault streams
                    let seed = worker_identity_seed(base_seed, wid, incarnation);
                    let harness = WorkerHarness {
                        incarnation,
                        faults: plan_ref,
                        heartbeat: supervised,
                    };
                    // worker-lifetime build executor, owned on the worker's
                    // own thread: one pool of parked threads per worker
                    // (executors are never shared — ScorePool serializes
                    // concurrent dispatchers, which would serialize the
                    // workers' builds)
                    let result = catch_unwind(AssertUnwindSafe(|| {
                        let exec = Executor::new(pool_mode, build_threads);
                        run_worker_harnessed(
                            wid,
                            board_ref,
                            binned.clone(),
                            params,
                            &exec,
                            tx.clone(),
                            seed,
                            &harness,
                        )
                    }));
                    match result {
                        Ok(_pushed) => return WorkerExit { died: None, restarts },
                        Err(payload) => {
                            let msg = panic_message(payload.as_ref());
                            if restarts >= restarts_allowed || board_ref.is_shutdown() {
                                return WorkerExit { died: Some(msg), restarts };
                            }
                            restarts += 1;
                            incarnation += 1;
                        }
                    }
                }
            }));
        }
        drop(tx); // server holds only the receiver

        // the server accept loop
        while core.n_trees() < cfg.n_trees {
            let push = match rx.recv() {
                Ok(p) => p,
                Err(_) => break, // every worker retired: surface a stall below
            };
            build_times.push(push.build_secs);
            let outcome = core.apply_tree(push.tree, push.based_on)?;
            if outcome.accepted {
                board.publish(core.snapshot());
                if ckpt.due(core.n_trees()) {
                    ckpt.write(&core, None, clock.elapsed())?;
                }
            }
        }

        // stop the world; drain in-flight pushes so senders never block
        board.request_shutdown();
        while let Ok(_ignored) = rx.try_recv() {}
        let mut exits: Vec<(usize, WorkerExit)> = Vec::with_capacity(handles.len());
        for (wid, h) in handles.into_iter().enumerate() {
            let exit = match h.join() {
                Ok(e) => e,
                // a panic that escaped the supervision loop itself (not
                // the harnessed worker body) still surfaces by name
                Err(payload) => WorkerExit {
                    died: Some(panic_message(payload.as_ref())),
                    restarts: 0,
                },
            };
            exits.push((wid, exit));
        }
        // final drain (workers may have pushed between drain and join)
        while let Ok(_ignored) = rx.try_recv() {}

        // a worker panic must never hang or silently truncate training:
        // if the run stalled short, name every dead worker and its panic
        if core.n_trees() < cfg.n_trees {
            let dead: Vec<String> = exits
                .iter()
                .filter_map(|(wid, e)| e.died.as_ref().map(|m| format!("worker {wid}: {m}")))
                .collect();
            let detail = if dead.is_empty() {
                "no panics recorded — push channel closed early".to_string()
            } else {
                dead.join("; ")
            };
            bail!(
                "async training stalled at {}/{} trees: all workers exited ({detail})",
                core.n_trees(),
                cfg.n_trees
            );
        }
        Ok(exits)
    })?;

    let deaths: u64 = exits
        .iter()
        .map(|(_, e)| e.restarts + u64::from(e.died.is_some()))
        .sum();
    let restarts: u64 = exits.iter().map(|(_, e)| e.restarts).sum();
    let workers_final = exits.iter().filter(|(_, e)| e.died.is_none()).count();
    let fault_trace = plan.as_ref().map(|p| p.trace()).unwrap_or_default();

    let engine = core.engine_kind();
    Ok(TrainReport {
        trees_accepted: core.n_trees(),
        trees_rejected: core.staleness.rejected,
        wall_secs: clock.elapsed(),
        build_times: Summary::of(&build_times),
        engine,
        mode: "async".into(),
        workers: cfg.workers,
        supervision: SupervisionStats {
            workers: cfg.workers,
            deaths,
            restarts,
            workers_final,
        },
        fault_trace,
        cuts: binned.cuts(),
        forest: core.forest,
        curve: core.curve,
        staleness: core.staleness,
        steps: core.steps,
        timer: core.timer,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic;

    fn small_cfg(workers: usize, n_trees: usize) -> TrainConfig {
        let mut cfg = TrainConfig::default();
        cfg.n_trees = n_trees;
        cfg.step_length = 0.2;
        cfg.sampling_rate = 0.8;
        cfg.workers = workers;
        cfg.tree.max_leaves = 8;
        cfg.max_bins = 16;
        cfg.eval_every = 10;
        cfg
    }

    #[test]
    fn async_trains_exactly_n_trees_and_descends() {
        let ds = synthetic::realsim_like(400, 31);
        let rep = train_async(&small_cfg(4, 30), &ds, None).unwrap();
        assert_eq!(rep.trees_accepted, 30);
        assert_eq!(rep.forest.n_trees(), 30);
        let first = rep.curve.points.first().unwrap().train_loss;
        let last = rep.curve.points.last().unwrap().train_loss;
        assert!(last < first, "loss did not descend: {first} -> {last}");
        assert_eq!(rep.mode, "async");
        // unsupervised default: no deaths, no faults, everyone alive
        assert_eq!(rep.supervision, SupervisionStats::all_alive(4));
        assert!(rep.fault_trace.is_empty());
    }

    #[test]
    fn staleness_is_measured_and_bounded() {
        // NOTE: even one worker can run several versions ahead of the
        // server (the push channel is unbounded and the worker keeps
        // rebuilding on the stale target — exactly the delayed-SGD model),
        // so absolute staleness levels are timing-dependent. The stable
        // invariants: τ is recorded for every accepted push, τ < n_trees,
        // and many racing workers produce nonzero staleness.
        let ds = synthetic::realsim_like(300, 32);
        let one = train_async(&small_cfg(1, 24), &ds, None).unwrap();
        let many = train_async(&small_cfg(8, 24), &ds, None).unwrap();
        assert_eq!(one.staleness.samples.len(), 24);
        assert_eq!(many.staleness.samples.len(), 24);
        assert!(one.staleness.max() < 24);
        assert!(many.staleness.max() < 24);
        assert!(
            many.staleness.mean() >= 1.0,
            "8 racing workers should show real staleness, got {}",
            many.staleness.mean()
        );
    }

    #[test]
    fn async_with_parallel_build_workers_completes_and_descends() {
        // every worker holds its own persistent build executor: 3 workers
        // × 2 build threads racing the server for 15 accepted trees
        let ds = synthetic::realsim_like(300, 34);
        let mut cfg = small_cfg(3, 15);
        cfg.build_threads = 2;
        let rep = train_async(&cfg, &ds, None).unwrap();
        assert_eq!(rep.trees_accepted, 15);
        let first = rep.curve.points.first().unwrap().train_loss;
        let last = rep.curve.points.last().unwrap().train_loss;
        assert!(last < first, "loss did not descend: {first} -> {last}");
    }

    #[test]
    fn async_with_sharded_ps_completes_and_descends() {
        // ps_shards=2 through the full async lifecycle: the sharded
        // accept route and composed versions behind a live worker race
        // (bit-identity is pinned separately in tests/test_sharded_ps.rs)
        let ds = synthetic::realsim_like(1_200, 35);
        let mut cfg = small_cfg(3, 15);
        cfg.ps_shards = 2;
        cfg.score_threads = 2;
        let rep = train_async(&cfg, &ds, None).unwrap();
        assert_eq!(rep.trees_accepted, 15);
        let first = rep.curve.points.first().unwrap().train_loss;
        let last = rep.curve.points.last().unwrap().train_loss;
        assert!(last < first, "loss did not descend: {first} -> {last}");
    }

    #[test]
    fn bounded_staleness_rejects_under_pressure() {
        let ds = synthetic::realsim_like(300, 33);
        let mut cfg = small_cfg(8, 20);
        cfg.max_staleness = Some(0); // only fresh pushes accepted
        let rep = train_async(&cfg, &ds, None).unwrap();
        assert_eq!(rep.trees_accepted, 20);
        // with 8 racing workers and tau<=0 required, rejections must occur
        assert!(rep.trees_rejected > 0, "expected rejected pushes");
        assert_eq!(rep.staleness.max(), 0); // accepted ones all fresh
    }
}
