//! Trainers: the asynchronous PS trainer (the paper's contribution) and
//! the synchronous fork-join / serial baselines, behind one `train()`
//! entrypoint.
//!
//! All three share the same `ServerCore` state machine and the same tree
//! learner, so convergence differences between modes are attributable to
//! the parallelisation strategy alone — the comparison the paper makes.

pub mod async_trainer;
pub mod report;
pub mod serial_trainer;
pub mod sync_trainer;

pub use async_trainer::train_async;
pub use report::TrainReport;
pub use serial_trainer::train_serial;
pub use sync_trainer::train_sync;

use anyhow::{bail, Result};

use crate::config::{TrainConfig, TrainMode};
use crate::data::Dataset;

/// Train per `cfg.mode`. `test` enables held-out loss on the curve.
pub fn train(cfg: &TrainConfig, train: &Dataset, test: Option<&Dataset>) -> Result<TrainReport> {
    match cfg.mode {
        TrainMode::Async => train_async(cfg, train, test),
        TrainMode::Sync => train_sync(cfg, train, test),
        TrainMode::Serial => train_serial(cfg, train, test),
        TrainMode::Serve => bail!(
            "mode=serve is not a trainer — run `asgbdt serve --model path/to/model.json` \
             (serve::Service scores a saved forest; see DESIGN.md §15)"
        ),
    }
}
