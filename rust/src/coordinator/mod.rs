//! Trainers: the asynchronous PS trainer (the paper's contribution) and
//! the synchronous fork-join / serial baselines, behind one `train()`
//! entrypoint.
//!
//! All three share the same `ServerCore` state machine and the same tree
//! learner, so convergence differences between modes are attributable to
//! the parallelisation strategy alone — the comparison the paper makes.

pub mod async_trainer;
pub(crate) mod checkpoint;
pub mod report;
pub mod serial_trainer;
pub mod sync_trainer;

pub use async_trainer::{train_async, train_async_resumed};
pub use report::TrainReport;
pub use serial_trainer::{train_serial, train_serial_resumed};
pub use sync_trainer::{train_sync, train_sync_resumed};

use anyhow::{bail, Result};

use crate::config::{TrainConfig, TrainMode};
use crate::data::Dataset;
use crate::io::artifact::SgbdtArtifact;

/// Train per `cfg.mode`. `test` enables held-out loss on the curve.
pub fn train(cfg: &TrainConfig, train: &Dataset, test: Option<&Dataset>) -> Result<TrainReport> {
    train_resumed(cfg, train, test, None)
}

/// [`train`], optionally resuming from a checkpoint artifact
/// (`asgbdt train --resume ck.sgbdt`). The checkpoint must have been
/// written by the same `cfg.mode` under a training-equivalent config —
/// `coordinator::checkpoint::restore` verifies both by name.
pub fn train_resumed(
    cfg: &TrainConfig,
    train: &Dataset,
    test: Option<&Dataset>,
    resume: Option<&SgbdtArtifact>,
) -> Result<TrainReport> {
    match cfg.mode {
        TrainMode::Async => train_async_resumed(cfg, train, test, resume),
        TrainMode::Sync => train_sync_resumed(cfg, train, test, resume),
        TrainMode::Serial => train_serial_resumed(cfg, train, test, resume),
        TrainMode::Serve => bail!(
            "mode=serve is not a trainer — run `asgbdt serve --model path/to/model.json` \
             (serve::Service scores a saved forest; see DESIGN.md §15)"
        ),
    }
}
