//! Serial reference trainer — Friedman's loop, strictly ordered: sample →
//! produce target → build tree → apply. The convergence baseline every
//! figure compares against (τ ≡ 0).
//!
//! The apply half of the loop (inside [`ServerCore::apply_tree`]) runs
//! the accept pipeline selected by `cfg.target` — the fused row-sharded
//! pass (default) or the serial reference sweeps per `cfg.scoring` /
//! `cfg.score_threads` — just like the sync and async trainers; the
//! serial mode is where the scoring and accept-path ablations isolate
//! pure apply cost. Scoring threads come from the `ServerCore`'s
//! [`crate::util::Executor`], built once here at startup (`cfg.pool`);
//! tree builds run on a separate run-lifetime build executor
//! (`cfg.build_threads`, default 1 = exactly the serial learner).
//! `cfg.ps_shards > 1` likewise routes the apply half through the
//! sharded PS (`ps/sharded.rs`) without touching this loop — the
//! sharded carving is bit-identical, so even the serial baseline can
//! run on a sharded server and reproduce itself exactly.

use std::sync::Arc;

use anyhow::{anyhow, Result};

use crate::config::TrainConfig;
use crate::data::{BinnedDataset, Dataset};
use crate::io::artifact::SgbdtArtifact;
use crate::metrics::SupervisionStats;
use crate::ps::ServerCore;
use crate::runtime::GradientEngine;
use crate::tree::{build_tree_feature_parallel, HistogramPool};
use crate::util::stats::Summary;
use crate::util::{Executor, Rng, Stopwatch};

use super::checkpoint::{self, Checkpointer};
use super::report::TrainReport;

/// Train strictly serially (Friedman's loop) — the τ ≡ 0 convergence
/// baseline every figure compares against.
pub fn train_serial(
    cfg: &TrainConfig,
    train: &Dataset,
    test: Option<&Dataset>,
) -> Result<TrainReport> {
    train_serial_resumed(cfg, train, test, None)
}

/// [`train_serial`], optionally picking up from a checkpoint artifact:
/// the checkpointed trees are replayed through the accept pipeline and
/// the build RNG restored, so the continuation is bit-identical to the
/// run that was never interrupted.
pub fn train_serial_resumed(
    cfg: &TrainConfig,
    train: &Dataset,
    test: Option<&Dataset>,
    resume: Option<&SgbdtArtifact>,
) -> Result<TrainReport> {
    let cfg = cfg.clone();
    cfg.validate()?;
    let clock = Stopwatch::new();
    let binned = Arc::new(BinnedDataset::from_dataset(train, cfg.max_bins)?);
    let engine = GradientEngine::auto_for(&cfg.artifact_dir, cfg.scalar_loss());
    let mut core = ServerCore::new(&cfg, train, binned.clone(), test, engine)?;
    let mut rng = Rng::new(cfg.seed ^ 0x0ddb_a11);
    if let Some(a) = resume {
        let state = checkpoint::restore(&mut core, a, &cfg, "serial", &binned)?
            .ok_or_else(|| anyhow!("--resume: serial checkpoint is missing its RNG state"))?;
        rng = Rng::from_state(state);
    }
    let ckpt = Checkpointer::new(&cfg, &binned, "serial");
    let mut build_times = Vec::with_capacity(cfg.n_trees);
    // histogram buffers recycled across all n_trees builds
    let mut pool = HistogramPool::new(binned.total_bins());
    // run-lifetime build executor: the default build_threads=1 makes the
    // feature-parallel engine exactly the serial learner (the τ ≡ 0
    // baseline stays strictly serial); build_threads>1 parallelises the
    // inside of each build while keeping the boosting order serial
    let build_exec = Executor::new(cfg.pool, cfg.build_threads);

    while core.n_trees() < cfg.n_trees {
        let snapshot = core.snapshot();
        let mut sw = Stopwatch::new();
        let tree = build_tree_feature_parallel(
            &binned,
            &snapshot.rows,
            &snapshot.grad,
            &snapshot.hess,
            &cfg.tree,
            &mut rng,
            &build_exec,
            &mut pool,
        );
        build_times.push(sw.lap());
        core.apply_tree(tree, snapshot.version)?;
        if ckpt.due(core.n_trees()) {
            ckpt.write(&core, Some(&rng), clock.elapsed())?;
        }
    }

    let engine = core.engine_kind();
    Ok(TrainReport {
        trees_accepted: core.n_trees(),
        trees_rejected: core.staleness.rejected,
        wall_secs: clock.elapsed(),
        build_times: Summary::of(&build_times),
        engine,
        mode: "serial".into(),
        workers: 1,
        supervision: SupervisionStats::all_alive(1),
        fault_trace: Vec::new(),
        cuts: binned.cuts(),
        forest: core.forest,
        curve: core.curve,
        staleness: core.staleness,
        steps: core.steps,
        timer: core.timer,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic;

    fn small_cfg() -> TrainConfig {
        let mut cfg = TrainConfig::default();
        cfg.n_trees = 20;
        cfg.step_length = 0.3;
        cfg.sampling_rate = 0.9;
        cfg.tree.max_leaves = 8;
        cfg.max_bins = 16;
        cfg.eval_every = 5;
        cfg
    }

    #[test]
    fn serial_training_descends_and_reports() {
        let ds = synthetic::realsim_like(400, 17);
        let mut rng = Rng::new(1);
        let (tr, te) = ds.split(0.25, &mut rng);
        let rep = train_serial(&small_cfg(), &tr, Some(&te)).unwrap();
        assert_eq!(rep.trees_accepted, 20);
        assert_eq!(rep.forest.n_trees(), 20);
        assert_eq!(rep.staleness.max(), 0, "serial must have zero staleness");
        let first = rep.curve.points.first().unwrap();
        let last = rep.curve.points.last().unwrap();
        assert!(last.train_loss < first.train_loss);
        assert!(last.test_loss.is_finite());
        assert!(rep.trees_per_sec() > 0.0);
    }

    #[test]
    fn sharded_server_reproduces_the_serial_baseline_exactly() {
        // ps_shards=4 under the strictly serial loop: the sharded accept
        // carving must leave the τ ≡ 0 baseline bit-identical
        let ds = synthetic::realsim_like(2_600, 19);
        let a = train_serial(&small_cfg(), &ds, None).unwrap();
        let mut cfg = small_cfg();
        cfg.ps_shards = 4;
        cfg.score_threads = 2;
        let b = train_serial(&cfg, &ds, None).unwrap();
        let la: Vec<f64> = a.curve.points.iter().map(|p| p.train_loss).collect();
        let lb: Vec<f64> = b.curve.points.iter().map(|p| p.train_loss).collect();
        assert_eq!(la, lb, "sharded serial curve diverged");
        assert_eq!(a.forest.n_trees(), b.forest.n_trees());
    }

    #[test]
    fn deterministic_given_seed() {
        let ds = synthetic::realsim_like(200, 18);
        let a = train_serial(&small_cfg(), &ds, None).unwrap();
        let b = train_serial(&small_cfg(), &ds, None).unwrap();
        let la: Vec<f64> = a.curve.points.iter().map(|p| p.train_loss).collect();
        let lb: Vec<f64> = b.curve.points.iter().map(|p| p.train_loss).collect();
        assert_eq!(la, lb);
    }
}
