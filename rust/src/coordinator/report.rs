//! The result of a training run: model + telemetry.

use std::path::Path;

use anyhow::Result;

use crate::data::BinCuts;
use crate::forest::Forest;
use crate::io::Json;
use crate::metrics::{LossCurve, StalenessStats, StepStats, SupervisionStats};
use crate::runtime::EngineKind;
use crate::util::fault::FaultEvent;
use crate::util::stats::Summary;
use crate::util::timer::PhaseTimer;

/// Everything a trainer hands back.
#[derive(Debug)]
pub struct TrainReport {
    /// The trained model.
    pub forest: Forest,
    /// The bin boundaries the model was trained against — what a
    /// `.sgbdt` artifact embeds so serving never re-derives binning.
    pub cuts: BinCuts,
    /// Train/test loss by accepted-tree count and wall clock.
    pub curve: LossCurve,
    /// Realised staleness of accepted (and count of rejected) pushes.
    pub staleness: StalenessStats,
    /// Effective step length of every accepted push (constant under
    /// `step=fixed`; the τ-shrunk trace under `step=adaptive`).
    pub steps: StepStats,
    /// Per-phase server/worker time accounting.
    pub timer: PhaseTimer,
    /// Total wall-clock of the training loop.
    pub wall_secs: f64,
    /// Trees the server accepted (== forest size).
    pub trees_accepted: usize,
    /// Pushes dropped by the bounded-staleness filter.
    pub trees_rejected: u64,
    /// Which gradient engine produced the targets (native or AOT).
    pub engine: EngineKind,
    /// Distribution of worker-side tree build times (secs).
    pub build_times: Summary,
    /// Mode tag ("async"/"sync"/"serial") + worker count for outputs.
    pub mode: String,
    /// Worker count the run was configured with.
    pub workers: usize,
    /// Supervision outcome: deaths, restarts and the realised worker
    /// count at shutdown (all-alive for sync/serial and unsupervised
    /// async runs).
    pub supervision: SupervisionStats,
    /// Every fault the armed [`crate::util::FaultPlan`] injected, in
    /// canonical `(site, attempt)` order — empty when the fault layer is
    /// off. Two runs with the same `fault_seed` and rates record
    /// identical traces over the attempts both runs exercised
    /// (DESIGN.md §14).
    pub fault_trace: Vec<FaultEvent>,
}

impl TrainReport {
    /// Trees accepted per wall-clock second — the throughput measure the
    /// speedup figures are built from.
    pub fn trees_per_sec(&self) -> f64 {
        if self.wall_secs > 0.0 {
            self.trees_accepted as f64 / self.wall_secs
        } else {
            0.0
        }
    }

    /// Structured summary (dropped next to CSV outputs by experiments).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("mode", Json::Str(self.mode.clone())),
            ("workers", Json::Num(self.workers as f64)),
            ("engine", Json::Str(self.engine.to_string())),
            ("trees_accepted", Json::Num(self.trees_accepted as f64)),
            ("trees_rejected", Json::Num(self.trees_rejected as f64)),
            ("wall_secs", Json::Num(self.wall_secs)),
            ("trees_per_sec", Json::Num(self.trees_per_sec())),
            (
                "final_train_loss",
                Json::Num(self.curve.final_train_loss().unwrap_or(f64::NAN)),
            ),
            (
                "final_test_loss",
                Json::Num(self.curve.final_test_loss().unwrap_or(f64::NAN)),
            ),
            ("staleness_mean", Json::Num(self.staleness.mean())),
            ("staleness_max", Json::Num(self.staleness.max() as f64)),
            ("step_effective_mean", Json::Num(self.steps.mean())),
            ("step_effective_min", Json::Num(self.steps.min() as f64)),
            ("build_time_mean", Json::Num(self.build_times.mean)),
            ("worker_deaths", Json::Num(self.supervision.deaths as f64)),
            (
                "worker_restarts",
                Json::Num(self.supervision.restarts as f64),
            ),
            (
                "workers_final",
                Json::Num(self.supervision.workers_final as f64),
            ),
            (
                "faults_injected",
                Json::Num(self.fault_trace.len() as f64),
            ),
        ])
    }

    /// Write [`TrainReport::to_json`] to a file, creating parent dirs.
    pub fn write_summary(&self, path: &Path) -> Result<()> {
        if let Some(parent) = path.parent() {
            std::fs::create_dir_all(parent)?;
        }
        std::fs::write(path, self.to_json().to_string())?;
        Ok(())
    }
}
