//! The additive model F(x) — the GBDT forest.

pub mod gbdt;

pub use gbdt::Forest;
