//! The additive model F(x) — the GBDT forest ([`gbdt`]) and the blocked
//! batch scoring engine ([`score`]) that serves the server's F-update and
//! all `predict_all*` hot paths.

pub mod gbdt;
pub mod score;

pub use gbdt::Forest;
pub use score::{FlatForest, ScoreMode, ScratchPool};
