//! The blocked scoring engine — the server side of Algorithm 3, step 2.
//!
//! Every accepted tree forces the server to update the prediction vector
//! **F** over all training rows (and the held-out margins when a test set
//! is attached), so scoring sits on the accept loop's critical path and
//! bounds async throughput just as much as histogram building bounds the
//! workers. This module turns that update into a blocked, row-sharded
//! partition pass:
//!
//! * each shipped tree is compiled once into a [`FlatTree`]
//!   (`tree/flat.rs`) — SoA arrays instead of the pointer-chasing
//!   `Vec<Node>` enum;
//! * rows are walked in cache-sized blocks of [`ROW_BLOCK`]; within a
//!   block the tree routes all rows to their leaves in one
//!   frontier/partition sweep, and the server's step 2 collapses to
//!   `F[r] += v * leaf_value[leaf_of[r]]` per leaf segment;
//! * blocks are claimed dynamically by up to `score_threads` workers
//!   obtained from a [`crate::util::Executor`] (the server-lifetime
//!   [`crate::util::ScorePool`] under `pool=persistent`, per-call scoped
//!   spawns under `pool=scoped`) — the same claim-a-chunk load-balancing
//!   as the split search's work-stealing cursor in `tree/parallel.rs`,
//!   with a mutexed block iterator instead of an atomic because each
//!   claim hands out a disjoint `&mut` slice of F;
//! * the per-block scratch (row-id buffer + partition stack) is pooled
//!   ([`ScratchPool`]) under the same take/give contract as
//!   [`crate::tree::HistogramPool`], so a long-lived server allocates
//!   scoring buffers only on its first tree.
//!
//! The per-row enum walk ([`crate::tree::Tree::predict_binned`] /
//! [`super::Forest::predict_raw`]) stays as the reference implementation;
//! [`ScoreMode`] selects between the two engines (config key
//! `scoring=flat|perrow`) for the equivalence tests and the ablation.
//! Both engines produce **bit-identical** F vectors: the blocked pass
//! performs exactly the same f32 operations in the same per-row order,
//! only grouped by leaf instead of by row.
//!
//! The whole-block carving rule established here (`ROW_BLOCK`-aligned
//! contiguous shards, per/rem spread) is load-bearing beyond this
//! module: the fused accept pass (`ps/shard.rs`) and the sharded
//! parameter server's row partition (`ps/sharded.rs::RowPartition`) cut
//! at the same boundaries, which is why server shards can re-run this
//! engine's kernels over their owned slices and stay bit-identical.

use std::sync::Mutex;

use crate::data::sparse::CsrMatrix;
use crate::data::BinnedDataset;
use crate::tree::FlatTree;
use crate::util::Executor;

use super::Forest;

/// Rows per scoring block. 512 row ids plus their CSR nonzeros stay
/// L2-resident across all `depth` partition passes of a block, which is
/// the locality the per-row walk gives up.
pub const ROW_BLOCK: usize = 512;

/// Which engine performs the server's F-update (step 2, config key
/// `scoring` — serial accept path only; see DESIGN.md §11).
///
/// ```
/// use asgbdt::forest::ScoreMode;
/// assert_eq!(ScoreMode::parse("flat").unwrap(), ScoreMode::Flat);
/// assert_eq!(ScoreMode::PerRow.as_str(), "perrow");
/// assert_eq!(ScoreMode::default(), ScoreMode::Flat);
/// ```
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum ScoreMode {
    /// Per-row enum traversal — the reference implementation, kept for
    /// equivalence tests and the scoring ablation.
    PerRow,
    /// Blocked SoA frontier scoring (this module).
    #[default]
    Flat,
}

impl ScoreMode {
    /// Parse the `scoring=` config/CLI value.
    pub fn parse(s: &str) -> anyhow::Result<ScoreMode> {
        match s {
            "perrow" | "per_row" => Ok(ScoreMode::PerRow),
            "flat" => Ok(ScoreMode::Flat),
            other => anyhow::bail!("unknown scoring mode '{other}' (flat|perrow)"),
        }
    }

    /// The config/CLI spelling of this mode.
    pub fn as_str(&self) -> &'static str {
        match self {
            ScoreMode::PerRow => "perrow",
            ScoreMode::Flat => "flat",
        }
    }
}

/// Reusable per-block scoring scratch: the row-id buffer the partition
/// pass permutes (the `leaf_of` working set) and the explicit segment
/// stack. Arbitrarily dirty between uses — every pass refills both.
#[derive(Debug, Default)]
pub struct ScoreScratch {
    rows: Vec<u32>,
    stack: Vec<(u32, usize, usize)>,
}

impl ScoreScratch {
    /// An empty scratch (buffers grow on first use).
    pub fn new() -> ScoreScratch {
        ScoreScratch::default()
    }

    /// Load the block's row ids `[start, start + len)`.
    #[inline]
    fn fill(&mut self, start: usize, len: usize) {
        self.rows.clear();
        self.rows.extend(start as u32..(start + len) as u32);
    }
}

/// Pool of scoring scratch buffers, mirroring the [`crate::tree::HistogramPool`]
/// contract: `take` hands out a possibly-dirty buffer, every taker gives
/// it back, and a long-lived owner (the server, a trainer) reaches a
/// steady state of `score_threads` buffers after the first tree.
#[derive(Debug, Default)]
pub struct ScratchPool {
    free: Vec<ScoreScratch>,
    allocated: usize,
}

impl ScratchPool {
    /// An empty pool; buffers are allocated lazily by `take`.
    pub fn new() -> ScratchPool {
        ScratchPool::default()
    }

    /// Hand out a (possibly dirty) scratch, allocating only when the
    /// pool is empty.
    pub fn take(&mut self) -> ScoreScratch {
        self.free.pop().unwrap_or_else(|| {
            self.allocated += 1;
            ScoreScratch::new()
        })
    }

    /// Return a scratch for reuse.
    pub fn give(&mut self, s: ScoreScratch) {
        self.free.push(s);
    }

    /// Total fresh allocations ever made (steady state: one per scoring
    /// thread).
    pub fn allocated(&self) -> usize {
        self.allocated
    }

    /// Buffers currently parked in the pool.
    pub fn idle(&self) -> usize {
        self.free.len()
    }
}

/// Run `block_fn(start_row, f_block, scratch)` over every [`ROW_BLOCK`]
/// chunk of `f`. With more than one executor thread (and enough rows to
/// be worth it) the chunks are claimed dynamically off a shared iterator
/// by the executor's workers (each chunk is a disjoint `&mut` slice of
/// F, so claims are mutually exclusive by construction); otherwise they
/// run on the calling thread. Scratches come from — and return to —
/// `pool` either way, and the result is independent of both the worker
/// count and the executor mode: each block's f32 operations are a pure
/// function of the block, whichever thread runs it.
fn drive_blocks(
    f: &mut [f32],
    exec: &Executor,
    pool: &mut ScratchPool,
    block_fn: impl Fn(usize, &mut [f32], &mut ScoreScratch) + Sync,
) {
    let n_blocks = f.len().div_ceil(ROW_BLOCK).max(1);
    let n_active = exec.threads().clamp(1, n_blocks);
    if n_active == 1 || f.len() <= 2 * ROW_BLOCK {
        let mut scratch = pool.take();
        for (bi, chunk) in f.chunks_mut(ROW_BLOCK).enumerate() {
            block_fn(bi * ROW_BLOCK, chunk, &mut scratch);
        }
        pool.give(scratch);
        return;
    }
    // one scratch slot per worker; the slot mutex is uncontended (each
    // worker index locks only its own slot, once per dispatch)
    let scratches: Vec<Mutex<ScoreScratch>> =
        (0..n_active).map(|_| Mutex::new(pool.take())).collect();
    let work = Mutex::new(f.chunks_mut(ROW_BLOCK).enumerate());
    exec.run(n_active, &|tid| {
        let mut scratch = scratches[tid].lock().unwrap();
        loop {
            // claim the next block (lock held for next() only)
            let item = work.lock().unwrap().next();
            let Some((bi, chunk)) = item else { break };
            block_fn(bi * ROW_BLOCK, chunk, &mut scratch);
        }
    });
    for s in scratches {
        pool.give(s.into_inner().unwrap());
    }
}

/// Score one block of one tree, bin-space: partition the block's rows to
/// their leaves and add `v * leaf_value` per segment. The per-row result
/// is bit-identical to `f[r] += v * tree.predict_binned(..)` — same f32
/// multiply, same single add per row. Public because the fused accept
/// pipeline (`ps/shard.rs`) drives its own per-shard block loop instead
/// of [`drive_blocks`]'s dynamic claiming.
#[inline]
pub fn add_block_binned(
    flat: &FlatTree,
    binned: &BinnedDataset,
    v: f32,
    start: usize,
    f_block: &mut [f32],
    scratch: &mut ScoreScratch,
) {
    scratch.fill(start, f_block.len());
    let ScoreScratch { rows, stack } = scratch;
    flat.partition_binned(binned, rows, stack, |leaf, seg| {
        let add = v * flat.leaf_value[leaf as usize];
        for &r in seg {
            f_block[r as usize - start] += add;
        }
    });
}

/// [`add_block_binned`], raw-space (threshold traversal of a CSR matrix).
#[inline]
fn add_block_raw(
    flat: &FlatTree,
    x: &CsrMatrix,
    v: f32,
    start: usize,
    f_block: &mut [f32],
    scratch: &mut ScoreScratch,
) {
    scratch.fill(start, f_block.len());
    let ScoreScratch { rows, stack } = scratch;
    flat.partition_raw(x, rows, stack, |leaf, seg| {
        let add = v * flat.leaf_value[leaf as usize];
        for &r in seg {
            f_block[r as usize - start] += add;
        }
    });
}

/// The server's step 2 over the training rows:
/// `F[r] += v * tree(r)` for every row, bin-space, blocked. Threads come
/// from `exec` — the server's long-lived executor on the accept path, or
/// [`Executor::scoped`] for one-shot callers.
pub fn add_tree_binned(
    flat: &FlatTree,
    binned: &BinnedDataset,
    v: f32,
    f: &mut [f32],
    exec: &Executor,
    pool: &mut ScratchPool,
) {
    drive_blocks(f, exec, pool, |start, chunk, scratch| {
        add_block_binned(flat, binned, v, start, chunk, scratch);
    });
}

/// The server's step 2 over held-out rows: raw-space (threshold)
/// traversal of a CSR matrix, blocked.
pub fn add_tree_raw(
    flat: &FlatTree,
    x: &CsrMatrix,
    v: f32,
    f: &mut [f32],
    exec: &Executor,
    pool: &mut ScratchPool,
) {
    drive_blocks(f, exec, pool, |start, chunk, scratch| {
        add_block_raw(flat, x, v, start, chunk, scratch);
    });
}

/// A forest compiled for batch scoring: base score plus `(v, FlatTree)`
/// pairs. Compile once (O(total nodes)), score many — each row block is
/// initialised to the base score and then receives every tree in push
/// order while its data is cache-resident, so the per-row f32 operation
/// sequence matches [`Forest::predict_raw`] exactly (bit-identical
/// margins).
#[derive(Debug, Clone, Default)]
pub struct FlatForest {
    /// The forest's constant initial margin.
    pub base_score: f32,
    /// `(step length, compiled tree)` pairs in acceptance order.
    pub trees: Vec<(f32, FlatTree)>,
}

impl FlatForest {
    /// Compile every tree of a [`Forest`] into its SoA scoring form.
    pub fn from_forest(forest: &Forest) -> FlatForest {
        FlatForest {
            base_score: forest.base_score,
            trees: forest
                .trees
                .iter()
                .map(|(v, t)| (*v, FlatTree::from_tree(t)))
                .collect(),
        }
    }

    /// Number of compiled trees.
    pub fn n_trees(&self) -> usize {
        self.trees.len()
    }

    /// Blocked margins for all rows of a raw matrix.
    pub fn predict_all_raw(
        &self,
        x: &CsrMatrix,
        exec: &Executor,
        pool: &mut ScratchPool,
    ) -> Vec<f32> {
        let mut f = vec![0.0f32; x.n_rows()];
        drive_blocks(&mut f, exec, pool, |start, chunk, scratch| {
            chunk.fill(self.base_score);
            for (v, t) in &self.trees {
                add_block_raw(t, x, *v, start, chunk, scratch);
            }
        });
        f
    }

    /// Blocked margins on the training (binned) representation.
    pub fn predict_all_binned(
        &self,
        b: &BinnedDataset,
        exec: &Executor,
        pool: &mut ScratchPool,
    ) -> Vec<f32> {
        let mut f = Vec::new();
        self.predict_binned_into(b, &mut f, exec, pool);
        f
    }

    /// [`FlatForest::predict_all_binned`] into a caller-owned buffer
    /// (cleared and resized to `b.n_rows`). The serving loop
    /// (`serve/service.rs`) scores every micro-batch through this so the
    /// steady state allocates no fresh margin vector per batch. Each
    /// row's margin is base + per-tree leaf adds in push order,
    /// independent of block layout — so micro-batched scoring is
    /// bit-identical to whole-matrix scoring of the same rows.
    pub fn predict_binned_into(
        &self,
        b: &BinnedDataset,
        out: &mut Vec<f32>,
        exec: &Executor,
        pool: &mut ScratchPool,
    ) {
        out.clear();
        out.resize(b.n_rows, 0.0);
        drive_blocks(out, exec, pool, |start, chunk, scratch| {
            chunk.fill(self.base_score);
            for (v, t) in &self.trees {
                add_block_binned(t, b, *v, start, chunk, scratch);
            }
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{synthetic, Dataset};
    use crate::loss::logistic;
    use crate::tree::{build_tree, Tree, TreeParams};
    use crate::util::{PoolMode, Rng};

    fn boosted(ds: &Dataset, b: &BinnedDataset, n_trees: usize, seed: u64) -> Forest {
        let w = vec![1.0f32; ds.n_rows()];
        let mut f = vec![0.0f32; ds.n_rows()];
        let mut forest = Forest::new(0.3);
        let rows: Vec<u32> = (0..ds.n_rows() as u32).collect();
        let params = TreeParams {
            max_leaves: 12,
            feature_rate: 0.9,
            ..Default::default()
        };
        let mut rng = Rng::new(seed);
        for _ in 0..n_trees {
            let gh = logistic::grad_hess_loss(&f, &ds.y, &w);
            let t = build_tree(b, &rows, &gh.grad, &gh.hess, &params, &mut rng);
            for r in 0..ds.n_rows() {
                f[r] += 0.2 * t.predict_binned(b, r);
            }
            forest.push(0.2, t);
        }
        forest
    }

    #[test]
    fn add_tree_binned_matches_per_row_bitwise() {
        let ds = synthetic::realsim_like(1_500, 51);
        let b = BinnedDataset::from_dataset(&ds, 32).unwrap();
        let forest = boosted(&ds, &b, 3, 5);
        for mode in [PoolMode::Persistent, PoolMode::Scoped] {
            for threads in [1usize, 2, 4] {
                let exec = Executor::new(mode, threads);
                let mut pool = ScratchPool::new();
                let mut f_flat = vec![0.1f32; ds.n_rows()];
                let mut f_ref = vec![0.1f32; ds.n_rows()];
                for (v, t) in &forest.trees {
                    let flat = FlatTree::from_tree(t);
                    add_tree_binned(&flat, &b, *v, &mut f_flat, &exec, &mut pool);
                    for r in 0..ds.n_rows() {
                        f_ref[r] += v * t.predict_binned(&b, r);
                    }
                }
                assert_eq!(f_flat, f_ref, "mode={mode:?} threads={threads}");
            }
        }
    }

    #[test]
    fn add_tree_raw_matches_per_row_bitwise() {
        let ds = synthetic::realsim_like(1_100, 52);
        let b = BinnedDataset::from_dataset(&ds, 32).unwrap();
        let forest = boosted(&ds, &b, 2, 6);
        for exec in [Executor::scoped(3), Executor::new(PoolMode::Persistent, 3)] {
            let mut pool = ScratchPool::new();
            let mut f_flat = vec![0.0f32; ds.n_rows()];
            let mut f_ref = vec![0.0f32; ds.n_rows()];
            for (v, t) in &forest.trees {
                let flat = FlatTree::from_tree(t);
                add_tree_raw(&flat, &ds.x, *v, &mut f_flat, &exec, &mut pool);
                for r in 0..ds.n_rows() {
                    f_ref[r] += v * t.predict_raw(&ds.x, r);
                }
            }
            assert_eq!(f_flat, f_ref, "mode={:?}", exec.mode());
        }
    }

    #[test]
    fn flat_forest_matches_reference_predictions_bitwise() {
        let ds = synthetic::realsim_like(1_300, 53);
        let b = BinnedDataset::from_dataset(&ds, 32).unwrap();
        let forest = boosted(&ds, &b, 4, 7);
        let flat = FlatForest::from_forest(&forest);
        assert_eq!(flat.n_trees(), 4);
        let mut pool = ScratchPool::new();
        for mode in [PoolMode::Persistent, PoolMode::Scoped] {
            for threads in [1usize, 2, 4] {
                let exec = Executor::new(mode, threads);
                let raw = flat.predict_all_raw(&ds.x, &exec, &mut pool);
                let binned = flat.predict_all_binned(&b, &exec, &mut pool);
                for r in 0..ds.n_rows() {
                    assert_eq!(raw[r], forest.predict_raw(&ds.x, r), "raw row {r}");
                    let mut want = forest.base_score;
                    for (v, t) in &forest.trees {
                        want += v * t.predict_binned(&b, r);
                    }
                    assert_eq!(binned[r], want, "binned row {r}");
                }
            }
        }
    }

    #[test]
    fn scratch_pool_reaches_steady_state() {
        let ds = synthetic::realsim_like(2_100, 54);
        let b = BinnedDataset::from_dataset(&ds, 16).unwrap();
        let forest = boosted(&ds, &b, 2, 8);
        let flat = FlatForest::from_forest(&forest);
        for exec in [Executor::scoped(3), Executor::new(PoolMode::Persistent, 3)] {
            let mut pool = ScratchPool::new();
            for _ in 0..5 {
                flat.predict_all_binned(&b, &exec, &mut pool);
            }
            assert!(
                pool.allocated() <= 3,
                "pooled scoring allocated {} scratches for 3 threads",
                pool.allocated()
            );
            assert_eq!(pool.idle(), pool.allocated(), "scratch leaked");
        }
    }

    #[test]
    fn empty_forest_and_tiny_inputs() {
        let flat = FlatForest::from_forest(&Forest::new(0.25));
        let x = CsrMatrix::from_dense(3, 1, &[1.0, 0.0, 2.0]).unwrap();
        let mut pool = ScratchPool::new();
        let exec = Executor::scoped(4);
        assert_eq!(flat.predict_all_raw(&x, &exec, &mut pool), vec![0.25; 3]);
        // zero-row input
        let empty = CsrMatrix::from_dense(0, 1, &[]).unwrap();
        assert_eq!(
            flat.predict_all_raw(&empty, &exec, &mut pool),
            Vec::<f32>::new()
        );
        // constant tree adds its value everywhere
        let mut f = vec![1.0f32; 3];
        let ft = FlatTree::from_tree(&Tree::constant(2.0));
        add_tree_raw(&ft, &x, 0.5, &mut f, &Executor::scoped(1), &mut pool);
        assert_eq!(f, vec![2.0; 3]);
    }

    #[test]
    fn score_mode_parse_roundtrip() {
        assert_eq!(ScoreMode::parse("flat").unwrap(), ScoreMode::Flat);
        assert_eq!(ScoreMode::parse("perrow").unwrap(), ScoreMode::PerRow);
        assert_eq!(ScoreMode::parse("per_row").unwrap(), ScoreMode::PerRow);
        assert!(ScoreMode::parse("soa").is_err());
        for m in [ScoreMode::Flat, ScoreMode::PerRow] {
            assert_eq!(ScoreMode::parse(m.as_str()).unwrap(), m);
        }
        assert_eq!(ScoreMode::default(), ScoreMode::Flat);
    }
}
