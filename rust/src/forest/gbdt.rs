//! Forest container: base score + v-scaled trees (Algorithm 3's
//! `F^j(x) = F^{j-1}(x) + v * Tree_{k(j)}`).

use anyhow::Result;

use crate::data::sparse::CsrMatrix;
use crate::data::BinnedDataset;
use crate::io::Json;
use crate::tree::Tree;

/// An additive tree model. `base_score` is the margin of the initial
/// constant tree (the paper's server init: mean label mapped to margin).
#[derive(Debug, Clone, Default)]
pub struct Forest {
    /// Margin of the initial constant tree.
    pub base_score: f32,
    /// (step length v at push time, tree)
    pub trees: Vec<(f32, Tree)>,
}

impl Forest {
    /// An empty forest with the given initial margin.
    pub fn new(base_score: f32) -> Forest {
        Forest {
            base_score,
            trees: Vec::new(),
        }
    }

    /// Initial margin from a positive rate p: F0 = 0.5 * logit(p) (inverse
    /// of p = sigmoid(2F)). Clamped for degenerate all-one/all-zero labels.
    pub fn base_from_positive_rate(p: f64) -> f32 {
        let p = p.clamp(1e-6, 1.0 - 1e-6);
        (0.5 * (p / (1.0 - p)).ln()) as f32
    }

    /// Number of accepted trees (excluding the constant base).
    pub fn n_trees(&self) -> usize {
        self.trees.len()
    }

    /// Append a tree with step length v.
    pub fn push(&mut self, v: f32, tree: Tree) {
        self.trees.push((v, tree));
    }

    /// Margin prediction for one raw sparse row.
    ///
    /// Reference implementation: re-walks every tree's `Vec<Node>` enum
    /// per call. Batch callers go through [`super::score::FlatForest`]
    /// (which [`Forest::predict_all`] does internally); this stays for
    /// single-row use and as the equivalence baseline.
    pub fn predict_raw(&self, x: &CsrMatrix, row: usize) -> f32 {
        let mut f = self.base_score;
        for (v, t) in &self.trees {
            f += v * t.predict_raw(x, row);
        }
        f
    }

    /// Margin predictions for all rows of a raw matrix, via the blocked
    /// SoA scorer (bit-identical to calling [`Forest::predict_raw`] per
    /// row). Callers that score repeatedly or want threads should compile
    /// a [`super::score::FlatForest`] once instead.
    pub fn predict_all(&self, x: &CsrMatrix) -> Vec<f32> {
        let mut pool = super::score::ScratchPool::new();
        let exec = crate::util::Executor::scoped(1);
        super::score::FlatForest::from_forest(self).predict_all_raw(x, &exec, &mut pool)
    }

    /// Margin predictions on the training (binned) representation, via
    /// the blocked SoA scorer (see [`Forest::predict_all`]).
    pub fn predict_all_binned(&self, b: &BinnedDataset) -> Vec<f32> {
        let mut pool = super::score::ScratchPool::new();
        let exec = crate::util::Executor::scoped(1);
        super::score::FlatForest::from_forest(self).predict_all_binned(b, &exec, &mut pool)
    }

    /// Reference batch prediction: the per-row enum walk, one
    /// [`Forest::predict_raw`] per row. Kept (hidden) for equivalence
    /// tests and the scoring ablation/benches — not a hot path.
    #[doc(hidden)]
    pub fn predict_all_per_row(&self, x: &CsrMatrix) -> Vec<f32> {
        (0..x.n_rows()).map(|r| self.predict_raw(x, r)).collect()
    }

    /// Reference batch prediction on the binned representation (per-row
    /// enum walk). See `Forest::predict_all_per_row`.
    #[doc(hidden)]
    pub fn predict_all_binned_per_row(&self, b: &BinnedDataset) -> Vec<f32> {
        let mut f = vec![self.base_score; b.n_rows];
        for (v, t) in &self.trees {
            for (r, fr) in f.iter_mut().enumerate() {
                *fr += v * t.predict_binned(b, r);
            }
        }
        f
    }

    /// Staged margins after each tree (loss-curve evaluation).
    pub fn staged_margins_raw(&self, x: &CsrMatrix, row: usize) -> Vec<f32> {
        let mut out = Vec::with_capacity(self.trees.len() + 1);
        let mut f = self.base_score;
        out.push(f);
        for (v, t) in &self.trees {
            f += v * t.predict_raw(x, row);
            out.push(f);
        }
        out
    }

    // ------------------------------------------------------ serialization

    /// Serialize to the model-file JSON shape (`base_score` + tree list).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("base_score", Json::Num(self.base_score as f64)),
            (
                "trees",
                Json::Arr(
                    self.trees
                        .iter()
                        .map(|(v, t)| {
                            Json::obj(vec![
                                ("v", Json::Num(*v as f64)),
                                ("tree", t.to_json()),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }

    /// Deserialize a forest produced by [`Forest::to_json`]. Strict like
    /// [`Tree::from_json`]: non-finite `base_score` or step lengths are
    /// rejected — a NaN here would poison every margin the model ever
    /// emits without failing a single later operation.
    pub fn from_json(j: &Json) -> Result<Forest> {
        let base_score = j.req_f64("base_score")?;
        if !base_score.is_finite() {
            anyhow::bail!("field 'base_score': non-finite value {base_score}");
        }
        let mut forest = Forest::new(base_score as f32);
        for (i, item) in j
            .req("trees")?
            .as_arr()
            .ok_or_else(|| anyhow::anyhow!("trees must be array"))?
            .iter()
            .enumerate()
        {
            let v = item.req_f64("v")?;
            if !v.is_finite() {
                anyhow::bail!("tree {i}: non-finite step length {v}");
            }
            let t = Tree::from_json(item.req("tree")?)
                .map_err(|e| anyhow::anyhow!("tree {i}: {e}"))?;
            forest.push(v as f32, t);
        }
        Ok(forest)
    }

    /// Write the model file (creating parent directories as needed).
    pub fn save(&self, path: &std::path::Path) -> Result<()> {
        if let Some(parent) = path.parent() {
            std::fs::create_dir_all(parent)?;
        }
        std::fs::write(path, self.to_json().to_string())?;
        Ok(())
    }

    /// Load a model file written by [`Forest::save`].
    pub fn load(path: &std::path::Path) -> Result<Forest> {
        Forest::from_json(&Json::parse_file(path)?)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tree::Node;

    fn stump(v: f32) -> Tree {
        Tree {
            nodes: vec![
                Node::Split {
                    feature: 0,
                    bin: 0,
                    threshold: 1.5,
                    left: 1,
                    right: 2,
                },
                Node::Leaf { value: -v },
                Node::Leaf { value: v },
            ],
        }
    }

    #[test]
    fn additive_prediction() {
        let mut f = Forest::new(0.1);
        f.push(0.5, stump(1.0));
        f.push(0.5, stump(2.0));
        let x = CsrMatrix::from_dense(2, 1, &[1.0, 2.0]).unwrap();
        // row 0: 0.1 + 0.5*(-1) + 0.5*(-2) = -1.4
        assert!((f.predict_raw(&x, 0) + 1.4).abs() < 1e-6);
        // row 1: 0.1 + 0.5*(1) + 0.5*(2) = 1.6
        assert!((f.predict_raw(&x, 1) - 1.6).abs() < 1e-6);
    }

    #[test]
    fn predict_all_routes_through_blocked_scorer_bit_identically() {
        let mut f = Forest::new(0.1);
        f.push(0.5, stump(1.0));
        f.push(0.25, stump(2.0));
        let x = CsrMatrix::from_dense(5, 1, &[1.0, 2.0, 0.0, 1.5, 3.0]).unwrap();
        assert_eq!(f.predict_all(&x), f.predict_all_per_row(&x));
    }

    #[test]
    fn staged_margins_accumulate() {
        let mut f = Forest::new(0.0);
        f.push(1.0, stump(1.0));
        f.push(1.0, stump(1.0));
        let x = CsrMatrix::from_dense(1, 1, &[2.0]).unwrap();
        assert_eq!(f.staged_margins_raw(&x, 0), vec![0.0, 1.0, 2.0]);
    }

    #[test]
    fn base_from_positive_rate_inverts_sigmoid2f() {
        for &p in &[0.1f64, 0.5, 0.9] {
            let f = Forest::base_from_positive_rate(p);
            let back = crate::loss::logistic::prob(f) as f64;
            assert!((back - p).abs() < 1e-5, "p={p} back={back}");
        }
        // degenerate rates stay finite
        assert!(Forest::base_from_positive_rate(0.0).is_finite());
        assert!(Forest::base_from_positive_rate(1.0).is_finite());
    }

    #[test]
    fn json_roundtrip_and_file_io() {
        let mut f = Forest::new(0.25);
        f.push(0.01, stump(3.0));
        let j = f.to_json();
        let back = Forest::from_json(&j).unwrap();
        assert_eq!(back.base_score, 0.25);
        assert_eq!(back.n_trees(), 1);
        assert_eq!(back.trees[0].0, 0.01);

        let path = std::env::temp_dir().join("asgbdt_forest_test.json");
        f.save(&path).unwrap();
        let loaded = Forest::load(&path).unwrap();
        assert_eq!(loaded.n_trees(), 1);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn from_json_rejects_malformed_models() {
        let reject = |src: &str, needle: &str| {
            let err = Forest::from_json(&Json::parse(src).unwrap())
                .unwrap_err()
                .to_string();
            assert!(err.contains(needle), "{src}: {err}");
        };
        reject(r#"{"trees":[]}"#, "base_score");
        reject(r#"{"base_score":1e400,"trees":[]}"#, "non-finite");
        reject(r#"{"base_score":0.1,"trees":{}}"#, "must be array");
        reject(
            r#"{"base_score":0.1,"trees":[{"v":1e400,"tree":[{"leaf":0.0}]}]}"#,
            "step length",
        );
        reject(r#"{"base_score":0.1,"trees":[{"tree":[{"leaf":0.0}]}]}"#, "'v'");
        // malformed inner tree errors carry the tree index
        reject(
            r#"{"base_score":0.1,"trees":[{"v":0.1,"tree":[{"leaf":"x"}]}]}"#,
            "tree 0",
        );
    }
}
