//! Figure 6: real-sim — convergence vs worker count at a fixed sampling
//! rate.
//!
//! Paper setting: 400 trees, 100 leaves, v = 0.01, feature rate 0.8.
//! Expected shape: real-sim is high-dimensional sparse (high diversity),
//! so convergence-per-tree barely degrades as workers (staleness) grow —
//! the paper's headline validity result.

use std::path::Path;

use anyhow::Result;

use crate::data::synthetic;
use crate::io::Json;

use super::common::{base_cfg, convergence_sweep, split, worker_counts, Scale, Variant};

/// Run the Figure 6 experiment (realsim-like convergence by worker count) at `scale`, writing CSV + summary JSON into `out_dir`.
pub fn run(scale: Scale, out_dir: &Path) -> Result<Json> {
    let n_rows = scale.pick(2_000, 20_000);
    let ds = synthetic::realsim_like(n_rows, 606);
    let (train_ds, test_ds) = split(&ds, 0.2, 606);

    let variants = worker_counts(scale)
        .into_iter()
        .map(|w| {
            let mut cfg = base_cfg(scale, 6_000 + w as u64);
            cfg.workers = w;
            cfg.n_trees = scale.pick(48, 400);
            cfg.step_length = scale.pick(0.1, 0.01);
            cfg.sampling_rate = 0.8;
            cfg.tree.max_leaves = scale.pick(16, 100);
            cfg.tree.feature_rate = 0.8;
            Variant {
                tag: format!("workers={w}"),
                cfg,
            }
        })
        .collect();

    let (_reports, summary) =
        convergence_sweep("fig6_realsim_workers", &train_ds, Some(&test_ds), variants, out_dir)?;
    Ok(summary)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig6_high_diversity_is_staleness_insensitive() {
        let dir = std::env::temp_dir().join("asgbdt_fig6_test");
        let j = run(Scale::Smoke, &dir).unwrap();
        let obj = j.as_obj().unwrap();
        // loss AUC across worker counts should stay close (insensitivity):
        let aucs: Vec<f64> = obj.values().map(|v| v.req_f64("loss_auc").unwrap()).collect();
        let max = aucs.iter().cloned().fold(f64::MIN, f64::max);
        let min = aucs.iter().cloned().fold(f64::MAX, f64::min);
        assert!(
            max - min < 0.12,
            "worker count changed convergence too much on a high-diversity set: {aucs:?}"
        );
        std::fs::remove_dir_all(&dir).ok();
    }
}
