//! Figure 5: Higgs — convergence vs worker count at a fixed sampling rate.
//!
//! Paper setting: 1000 trees, 20 leaves, v = 0.01, feature rate 0.8,
//! sampling rate fixed (0.8). Expected shape: Higgs is low-diversity, so
//! more workers (more staleness) visibly *slows* convergence per tree —
//! the paper's negative benchmark.

use std::path::Path;

use anyhow::Result;

use crate::data::synthetic;
use crate::io::Json;

use super::common::{base_cfg, convergence_sweep, split, worker_counts, Scale, Variant};

/// Run the Figure 5 experiment (higgs-like convergence by worker count) at `scale`, writing CSV + summary JSON into `out_dir`.
pub fn run(scale: Scale, out_dir: &Path) -> Result<Json> {
    let n_rows = scale.pick(3_000, 60_000);
    let ds = synthetic::higgs_like(n_rows, 505);
    let (train_ds, test_ds) = split(&ds, 0.2, 505);

    let variants = worker_counts(scale)
        .into_iter()
        .map(|w| {
            let mut cfg = base_cfg(scale, 5_000 + w as u64);
            cfg.workers = w;
            cfg.n_trees = scale.pick(48, 1000);
            cfg.step_length = scale.pick(0.1, 0.01);
            cfg.sampling_rate = 0.8;
            cfg.tree.max_leaves = 20;
            cfg.tree.feature_rate = 0.8;
            Variant {
                tag: format!("workers={w}"),
                cfg,
            }
        })
        .collect();

    let (_reports, summary) =
        convergence_sweep("fig5_higgs_workers", &train_ds, Some(&test_ds), variants, out_dir)?;
    Ok(summary)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig5_runs_and_all_variants_converge() {
        let dir = std::env::temp_dir().join("asgbdt_fig5_test");
        let j = run(Scale::Smoke, &dir).unwrap();
        let obj = j.as_obj().unwrap();
        assert!(obj.len() >= 2);
        for (tag, v) in obj {
            let loss = v.req_f64("final_train_loss").unwrap();
            assert!(loss.is_finite() && loss < std::f64::consts::LN_2 + 0.05, "{tag}: {loss}");
        }
        assert!(dir.join("fig5_higgs_workers.csv").exists());
        std::fs::remove_dir_all(&dir).ok();
    }
}
