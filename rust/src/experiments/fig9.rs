//! Figure 9: sensitivity at a normal (0.6) vs extremely small sampling
//! rate (paper: 5e-6 ≈ 500 samples per pass on real-sim).
//!
//! Expected shape (paper conclusions 1 & 3): the tiny rate *reduces
//! sensitivity* to the worker count (curves for 1 vs many workers nearly
//! coincide) but *slows convergence* overall (distorted trees from ~500
//! samples).

use std::path::Path;

use anyhow::Result;

use crate::data::synthetic;
use crate::io::Json;

use super::common::{base_cfg, convergence_sweep, split, Scale, Variant};

/// Run the Figure 9 experiment (extreme small-rate setting) at `scale`, writing CSV + summary JSON into `out_dir`.
pub fn run(scale: Scale, out_dir: &Path) -> Result<Json> {
    let n_rows = scale.pick(2_000, 20_000);
    let ds = synthetic::realsim_like(n_rows, 909);
    let (train_ds, test_ds) = split(&ds, 0.2, 909);
    // "approximately 500 samples on average in each sampling subdataset"
    let tiny_rate = (500.0 / train_ds.n_rows() as f64).min(0.5);
    let normal_rate = 0.6;
    let worker_pair = scale.pick((1usize, 4usize), (1usize, 16usize));

    let mut variants = Vec::new();
    for rate in [normal_rate, tiny_rate] {
        for workers in [worker_pair.0, worker_pair.1] {
            let mut cfg = base_cfg(scale, 9_000 + workers as u64);
            cfg.workers = workers;
            cfg.n_trees = scale.pick(48, 400);
            cfg.step_length = scale.pick(0.1, 0.01);
            cfg.sampling_rate = rate;
            cfg.tree.max_leaves = scale.pick(16, 100);
            cfg.tree.feature_rate = 0.8;
            variants.push(Variant {
                tag: format!("rate={rate:.6}_workers={workers}"),
                cfg,
            });
        }
    }

    let (_reports, summary) =
        convergence_sweep("fig9_small_rate", &train_ds, Some(&test_ds), variants, out_dir)?;
    Ok(summary)
}

/// Sensitivity measure used by the bench: |AUC(many workers) − AUC(1)|.
pub fn sensitivity_gap(summary: &Json, rate_prefix: &str) -> Option<f64> {
    let obj = summary.as_obj()?;
    let mut aucs: Vec<f64> = obj
        .iter()
        .filter(|(k, _)| k.starts_with(rate_prefix))
        .map(|(_, v)| v.req_f64("loss_auc").ok())
        .collect::<Option<Vec<_>>>()?;
    if aucs.len() < 2 {
        return None;
    }
    aucs.sort_by(|a, b| a.partial_cmp(b).unwrap());
    Some(aucs.last()? - aucs.first()?)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig9_runs_four_variants() {
        let dir = std::env::temp_dir().join("asgbdt_fig9_test");
        let j = run(Scale::Smoke, &dir).unwrap();
        assert_eq!(j.as_obj().unwrap().len(), 4);
        // both gaps computable
        assert!(sensitivity_gap(&j, "rate=0.6").is_some());
        std::fs::remove_dir_all(&dir).ok();
    }
}
