//! Figure 4: sample diversity → sparsity of the observed Q′ vector.
//!
//! Reproduces the paper's illustration with measurements: the 4(a)
//! low-diversity corpus (3 species × {10k, 20k, 30k} multiplicity) keeps
//! Q′ dense even at tiny sampling rates, while the 4(b) all-unique corpus
//! (14,000 singletons) makes Q′ sparse — the analytic Δ/ρ estimates and an
//! empirical sampling check are both reported.

use std::path::Path;

use anyhow::Result;

use crate::data::stats::{diversity_report, SpeciesTable};
use crate::data::synthetic;
use crate::io::csv::CsvWriter;
use crate::io::Json;
use crate::sampling::{BernoulliSampler, SampleKey};

use super::common::Scale;

/// Run the Figure 4 experiment (sampling-diversity validity) at `scale`, writing CSV + summary JSON into `out_dir`.
pub fn run(scale: Scale, out_dir: &Path) -> Result<Json> {
    let rates = scale.pick(
        vec![0.001, 0.01, 0.1, 0.5],
        vec![0.000005, 0.0001, 0.001, 0.01, 0.1, 0.2, 0.5, 0.8],
    );
    let empirical_draws = scale.pick(5, 20);

    let datasets = vec![
        ("fig4a-low-diversity", synthetic::fig4_low_diversity(1)),
        ("fig4b-high-diversity", synthetic::fig4_high_diversity(1)),
    ];

    let mut csv = CsvWriter::new(&[
        "dataset", "rate", "omega", "delta", "rho", "qprime_density_analytic",
        "qprime_density_empirical",
    ]);
    let mut summary = Vec::new();
    for (name, ds) in &datasets {
        let table = SpeciesTable::build(ds);
        for &rate in &rates {
            let rep = diversity_report(ds, rate);
            // empirical check: average observed row-support density over draws
            let sampler = BernoulliSampler::uniform(ds, rate);
            let mut dens = 0.0;
            for v in 0..empirical_draws {
                let pass = sampler.draw(SampleKey { seed: 7, version: v as u64 });
                // species-level density: fraction of species with >=1 row on
                let mut on = vec![false; table.n_species()];
                for &r in pass.rows.iter() {
                    on[table.row_species[r as usize] as usize] = true;
                }
                dens += on.iter().filter(|&&b| b).count() as f64
                    / table.n_species().max(1) as f64;
            }
            dens /= empirical_draws as f64;
            csv.row(&[
                name.to_string(),
                format!("{rate}"),
                rep.omega.to_string(),
                format!("{:.6}", rep.delta),
                format!("{:.6}", rep.rho),
                format!("{:.6}", rep.qprime_density),
                format!("{:.6}", dens),
            ]);
        }
        let rep_small = diversity_report(ds, rates[0]);
        summary.push((
            name.to_string(),
            Json::obj(vec![
                ("omega", Json::Num(rep_small.omega as f64)),
                ("delta_at_smallest_rate", Json::Num(rep_small.delta)),
                ("qprime_density_at_smallest_rate", Json::Num(rep_small.qprime_density)),
            ]),
        ));
    }
    csv.write(&out_dir.join("fig4_diversity.csv"))?;
    Ok(Json::Obj(summary.into_iter().collect()))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig4_shows_the_diversity_contrast() {
        let dir = std::env::temp_dir().join("asgbdt_fig4_test");
        let j = run(Scale::Smoke, &dir).unwrap();
        let lo = j.get("fig4a-low-diversity").unwrap();
        let hi = j.get("fig4b-high-diversity").unwrap();
        // low diversity: Q' dense (delta ~ 1) even at the smallest rate
        assert!(lo.req_f64("delta_at_smallest_rate").unwrap() > 0.9);
        // high diversity: Q' sparse at the same rate
        assert!(hi.req_f64("qprime_density_at_smallest_rate").unwrap() < 0.1);
        assert!(dir.join("fig4_diversity.csv").exists());
        std::fs::remove_dir_all(&dir).ok();
    }
}
