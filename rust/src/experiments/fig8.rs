//! Figure 8: real-sim — convergence vs sampling rate at a fixed worker
//! count. Paper observation: "sampling rates between 0.2 and 0.8 exert a
//! slight effect on the convergence speed in this dataset".

use std::path::Path;

use anyhow::Result;

use crate::data::synthetic;
use crate::io::Json;

use super::common::{base_cfg, convergence_sweep, sampling_rates, split, Scale, Variant};

/// Run the Figure 8 experiment (realsim-like convergence by sampling rate) at `scale`, writing CSV + summary JSON into `out_dir`.
pub fn run(scale: Scale, out_dir: &Path) -> Result<Json> {
    let n_rows = scale.pick(2_000, 20_000);
    let ds = synthetic::realsim_like(n_rows, 808);
    let (train_ds, test_ds) = split(&ds, 0.2, 808);
    let workers = scale.pick(4, 16);

    let variants = sampling_rates(scale)
        .into_iter()
        .map(|rate| {
            let mut cfg = base_cfg(scale, 8_000 + (rate * 1000.0) as u64);
            cfg.workers = workers;
            cfg.n_trees = scale.pick(48, 400);
            cfg.step_length = scale.pick(0.1, 0.01);
            cfg.sampling_rate = rate;
            cfg.tree.max_leaves = scale.pick(16, 100);
            cfg.tree.feature_rate = 0.8;
            Variant {
                tag: format!("rate={rate}"),
                cfg,
            }
        })
        .collect();

    let (_reports, summary) =
        convergence_sweep("fig8_realsim_sampling", &train_ds, Some(&test_ds), variants, out_dir)?;
    Ok(summary)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig8_rates_within_band_converge_similarly() {
        let dir = std::env::temp_dir().join("asgbdt_fig8_test");
        let j = run(Scale::Smoke, &dir).unwrap();
        let aucs: Vec<f64> = j
            .as_obj()
            .unwrap()
            .values()
            .map(|v| v.req_f64("loss_auc").unwrap())
            .collect();
        let max = aucs.iter().cloned().fold(f64::MIN, f64::max);
        let min = aucs.iter().cloned().fold(f64::MAX, f64::min);
        // paper: rates in [0.2, 0.8] barely change real-sim convergence
        assert!(max - min < 0.15, "rates changed convergence too much: {aucs:?}");
        std::fs::remove_dir_all(&dir).ok();
    }
}
