//! Shared machinery for the figure drivers.

use std::path::Path;

use anyhow::Result;

use crate::config::{TrainConfig, TrainMode};
use crate::coordinator::{train, TrainReport};
use crate::data::Dataset;
use crate::forest::{FlatForest, ScratchPool};
use crate::io::csv::CsvWriter;
use crate::io::Json;
use crate::loss::metrics;
use crate::util::Rng;

/// Experiment size: Smoke for CI/tests, Paper for figure regeneration.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Scale {
    /// Seconds-scale sizes for CI and tests.
    Smoke,
    /// Paper-fidelity sizes for figure regeneration.
    Paper,
}

impl Scale {
    /// `ASGBDT_SCALE=paper` upgrades benches/CLI runs.
    pub fn from_env() -> Scale {
        match std::env::var("ASGBDT_SCALE").as_deref() {
            Ok("paper") => Scale::Paper,
            _ => Scale::Smoke,
        }
    }

    /// Parse the `--scale` CLI value.
    pub fn parse(s: &str) -> Result<Scale> {
        match s {
            "smoke" => Ok(Scale::Smoke),
            "paper" => Ok(Scale::Paper),
            other => anyhow::bail!("unknown scale '{other}' (smoke|paper)"),
        }
    }

    /// Choose between a smoke-sized and a paper-sized value.
    pub fn pick<T>(&self, smoke: T, paper: T) -> T {
        match self {
            Scale::Smoke => smoke,
            Scale::Paper => paper,
        }
    }
}

/// A tagged training variation within a sweep.
pub struct Variant {
    /// Row tag in the long-format CSV.
    pub tag: String,
    /// The full config this variant trains with.
    pub cfg: TrainConfig,
}

/// Run a set of variants on (train, test), appending all loss curves into
/// one long-format CSV (`<name>.csv`: tag, n_trees, train_loss, ...).
/// Returns (csv rows, per-variant reports).
pub fn convergence_sweep(
    name: &str,
    train_ds: &Dataset,
    test_ds: Option<&Dataset>,
    variants: Vec<Variant>,
    out_dir: &Path,
) -> Result<(Vec<TrainReport>, Json)> {
    let mut csv = CsvWriter::new(&[
        "tag", "n_trees", "train_loss", "test_loss", "test_error", "wall_secs",
    ]);
    let mut reports = Vec::new();
    let mut summary_items = Vec::new();
    for v in variants {
        log::info!("[{name}] running variant {}", v.tag);
        let rep = train(&v.cfg, train_ds, test_ds)?;
        for p in &rep.curve.points {
            csv.row(&[
                v.tag.clone(),
                p.n_trees.to_string(),
                format!("{:.6}", p.train_loss),
                format!("{:.6}", p.test_loss),
                format!("{:.6}", p.test_error),
                format!("{:.4}", p.wall_secs),
            ]);
        }
        // final test error re-scored from scratch through the blocked
        // batch engine — also cross-checks the server's incremental
        // held-out margins against a full forest evaluation
        let final_test_error = test_ds
            .map(|t| {
                let mut pool = ScratchPool::new();
                let exec = crate::util::Executor::scoped(1);
                let margins =
                    FlatForest::from_forest(&rep.forest).predict_all_raw(&t.x, &exec, &mut pool);
                metrics::error_rate(&margins, &t.y, &t.m)
            })
            .unwrap_or(f64::NAN);
        // the accept loop's own cost, per phase, as fractions of the run's
        // wall clock — how much of the server's time scoring/sampling/
        // target production (or the fused pass that folds them) consumed
        let accept_fractions: Vec<(String, Json)> = rep
            .timer
            .rows()
            .iter()
            .filter(|(name, _, _)| name.starts_with("server/"))
            .map(|(name, secs, _)| {
                (
                    name["server/".len()..].to_string(),
                    Json::Num(secs / rep.wall_secs.max(1e-12)),
                )
            })
            .collect();
        summary_items.push((
            v.tag.clone(),
            Json::obj(vec![
                (
                    "final_train_loss",
                    Json::Num(rep.curve.final_train_loss().unwrap_or(f64::NAN)),
                ),
                ("final_test_error", Json::Num(final_test_error)),
                ("loss_auc", Json::Num(rep.curve.train_loss_auc())),
                ("staleness_mean", Json::Num(rep.staleness.mean())),
                ("trees_per_sec", Json::Num(rep.trees_per_sec())),
                (
                    // the serial path's pure step-2 sweep (0 under fused)
                    "apply_f_secs",
                    Json::Num(rep.timer.total("server/update_f")),
                ),
                (
                    // the fused pipeline's whole accept pass: F-update +
                    // sampling + target + eval partials (0 under serial)
                    "fused_pass_secs",
                    Json::Num(rep.timer.total("server/fused_pass")),
                ),
                (
                    "accept_phase_fractions",
                    Json::Obj(accept_fractions.into_iter().collect()),
                ),
                ("wall_secs", Json::Num(rep.wall_secs)),
            ]),
        ));
        reports.push(rep);
    }
    let path = out_dir.join(format!("{name}.csv"));
    csv.write(&path)?;
    log::info!("[{name}] wrote {}", path.display());
    let summary = Json::Obj(summary_items.into_iter().collect());
    Ok((reports, summary))
}

/// Baseline async config shared by the convergence figures.
pub fn base_cfg(scale: Scale, seed: u64) -> TrainConfig {
    let mut cfg = TrainConfig::default();
    cfg.mode = TrainMode::Async;
    cfg.seed = seed;
    cfg.eval_every = scale.pick(5, 10);
    cfg.max_bins = scale.pick(32, 64);
    cfg
}

/// Split a dataset deterministically for an experiment.
pub fn split(ds: &Dataset, test_frac: f64, seed: u64) -> (Dataset, Dataset) {
    let mut rng = Rng::new(seed);
    ds.split(test_frac, &mut rng)
}

/// Worker sweep per scale (paper: 1..32).
pub fn worker_counts(scale: Scale) -> Vec<usize> {
    scale.pick(vec![1, 2, 4], vec![1, 2, 4, 8, 16, 32])
}

/// Sampling-rate sweep per scale (paper: 0.2..0.8).
pub fn sampling_rates(scale: Scale) -> Vec<f64> {
    scale.pick(vec![0.4, 0.8], vec![0.2, 0.4, 0.6, 0.8])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scale_pick_and_parse() {
        assert_eq!(Scale::parse("smoke").unwrap(), Scale::Smoke);
        assert_eq!(Scale::parse("paper").unwrap(), Scale::Paper);
        assert!(Scale::parse("huge").is_err());
        assert_eq!(Scale::Smoke.pick(1, 2), 1);
        assert_eq!(Scale::Paper.pick(1, 2), 2);
    }

    #[test]
    fn sweeps_are_scale_dependent() {
        assert!(worker_counts(Scale::Paper).contains(&32));
        assert!(!worker_counts(Scale::Smoke).contains(&32));
        assert_eq!(sampling_rates(Scale::Paper).len(), 4);
    }
}
