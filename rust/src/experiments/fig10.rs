//! Figure 10: end-to-end speedup — asynch-SGBDT vs LightGBM
//! feature-parallel vs DimBoost, on real-sim-like and E2006-like
//! workloads, 1–32 workers.
//!
//! Two measurement layers (DESIGN.md §3):
//! 1. **Real threads** (like the paper's validity runs): asynch-SGBDT
//!    throughput with 1..k worker threads on this machine.
//! 2. **Simulated cluster** (the paper's Era testbed is a hardware gate):
//!    the discrete-event model calibrated with phase times measured from a
//!    real single-worker run on this machine.
//!
//! Also prints the Eq. 13 scalability bound for each workload.

use std::path::Path;

use anyhow::Result;

use crate::config::TrainConfig;
use crate::coordinator::train_serial;
use crate::data::synthetic;
use crate::io::csv::CsvWriter;
use crate::io::Json;
use crate::simulator::{eq13_upper_bound, speedup_sweep, ClusterSpec, PhaseTimes};

use super::common::{base_cfg, Scale};

/// Measure single-node phase times by running a short serial training.
fn calibrate(ds: &crate::data::Dataset, cfg: &TrainConfig) -> Result<PhaseTimes> {
    let rep = train_serial(cfg, ds, None)?;
    let build = rep.build_times.mean.max(1e-7);
    let target = rep.timer.mean("server/produce_target")
        + rep.timer.mean("server/sample");
    let apply = rep.timer.mean("server/update_f");
    Ok(PhaseTimes::calibrate(
        build,
        target,
        apply,
        ds.n_rows(),
        ds.n_features(),
        cfg.max_bins,
        cfg.tree.max_leaves,
    ))
}

/// Run the Figure 10 experiment (simulated cluster speedup sweep, calibrated from measured phase times) at `scale`, writing CSV + summary JSON into `out_dir`.
pub fn run(scale: Scale, out_dir: &Path) -> Result<Json> {
    let worker_counts: Vec<usize> = vec![1, 2, 4, 8, 16, 32];
    let sim_trees = scale.pick(100, 400);

    let mut csv = CsvWriter::new(&[
        "workload", "system", "workers", "wall_secs", "speedup", "mean_staleness",
        "bottleneck_frac",
    ]);
    let mut summary = Vec::new();

    for (workload, n_rows, leaves) in [
        ("realsim", scale.pick(2_000usize, 20_000), scale.pick(64usize, 400)),
        ("e2006", scale.pick(800, 8_000), scale.pick(64, 400)),
    ] {
        let ds = if workload == "realsim" {
            synthetic::realsim_like(n_rows, 1010)
        } else {
            synthetic::e2006_like(n_rows, 1010)
        };
        // calibration run (short); the serial accept path keeps the
        // per-phase split (sample/produce_target/update_f) the simulator
        // is calibrated from — the fused pipeline folds them into one
        let mut cal_cfg = base_cfg(scale, 1010);
        cal_cfg.mode = crate::config::TrainMode::Serial;
        cal_cfg.target = crate::ps::TargetMode::Serial;
        cal_cfg.n_trees = scale.pick(8, 30);
        cal_cfg.sampling_rate = 0.8;
        cal_cfg.tree.max_leaves = leaves;
        cal_cfg.eval_every = cal_cfg.n_trees;
        let times = calibrate(&ds, &cal_cfg)?;
        log::info!(
            "[fig10:{workload}] calibrated build={:.4}s target={:.4}s apply={:.4}s",
            times.build_secs, times.target_secs, times.apply_secs
        );

        let rows = speedup_sweep(&times, &worker_counts, sim_trees, 0.15, 1010);
        for r in &rows {
            csv.row(&[
                workload.to_string(),
                r.system.as_str().to_string(),
                r.workers.to_string(),
                format!("{:.4}", r.wall_secs),
                format!("{:.3}", r.speedup),
                format!("{:.3}", r.mean_staleness),
                format!("{:.4}", r.bottleneck_frac),
            ]);
        }
        let bound = eq13_upper_bound(&times, &ClusterSpec::new(32));
        let at32 = |sys: &str| {
            rows.iter()
                .find(|r| r.system.as_str() == sys && r.workers == 32)
                .map(|r| r.speedup)
                .unwrap_or(f64::NAN)
        };
        summary.push((
            workload.to_string(),
            Json::obj(vec![
                ("eq13_upper_bound", Json::Num(bound)),
                ("asynch_speedup_32", Json::Num(at32("asynch-sgbdt"))),
                ("lightgbm_speedup_32", Json::Num(at32("lightgbm-fp"))),
                ("dimboost_speedup_32", Json::Num(at32("dimboost"))),
                ("calibrated_build_secs", Json::Num(times.build_secs)),
                ("calibrated_target_secs", Json::Num(times.target_secs)),
            ]),
        ));
    }
    csv.write(&out_dir.join("fig10_speedup.csv"))?;
    Ok(Json::Obj(summary.into_iter().collect()))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig10_reproduces_the_paper_ordering() {
        let dir = std::env::temp_dir().join("asgbdt_fig10_test");
        let j = run(Scale::Smoke, &dir).unwrap();
        for workload in ["realsim", "e2006"] {
            let w = j.get(workload).unwrap();
            let a = w.req_f64("asynch_speedup_32").unwrap();
            let l = w.req_f64("lightgbm_speedup_32").unwrap();
            let d = w.req_f64("dimboost_speedup_32").unwrap();
            // the paper's headline: async >> sync baselines at 32 workers
            assert!(a > l && a > d, "{workload}: {a:.1} vs {l:.1}/{d:.1}");
            assert!(a > 5.0, "{workload}: async speedup too low {a:.1}");
        }
        assert!(dir.join("fig10_speedup.csv").exists());
        std::fs::remove_dir_all(&dir).ok();
    }
}
