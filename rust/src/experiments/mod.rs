//! Experiment drivers — one per paper figure (see DESIGN.md §5 for the
//! index). Each driver regenerates its figure's data as CSV under
//! `results/` and returns a JSON summary; bench targets and the CLI
//! (`asgbdt experiment <id>`) are thin wrappers around these.
//!
//! Every driver honours [`Scale`]: `Smoke` (seconds; CI and `cargo test`)
//! vs `Paper` (paper-shaped sizes; minutes).

pub mod ablation;
pub mod adaptive;
pub mod common;
pub mod fig10;
pub mod fig4;
pub mod fig5;
pub mod fig6;
pub mod fig7;
pub mod fig8;
pub mod fig9;

pub use common::Scale;

use std::path::Path;

use anyhow::{bail, Result};

use crate::io::Json;

/// Run an experiment by figure id ("fig4" … "fig10", "ablation",
/// "adaptive").
pub fn run(id: &str, scale: Scale, out_dir: &Path) -> Result<Json> {
    match id {
        "fig4" => fig4::run(scale, out_dir),
        "fig5" => fig5::run(scale, out_dir),
        "fig6" => fig6::run(scale, out_dir),
        "fig7" => fig7::run(scale, out_dir),
        "fig8" => fig8::run(scale, out_dir),
        "fig9" => fig9::run(scale, out_dir),
        "fig10" => fig10::run(scale, out_dir),
        "ablation" => ablation::run(scale, out_dir),
        "adaptive" => adaptive::run(scale, out_dir),
        other => bail!("unknown experiment '{other}' (fig4..fig10, ablation, adaptive)"),
    }
}

/// All experiment ids, in paper order (the adaptive-step sweep rides at
/// the end — it extends fig9's sensitivity story past the paper).
pub fn all_ids() -> &'static [&'static str] {
    &[
        "fig4", "fig5", "fig6", "fig7", "fig8", "fig9", "fig10", "ablation", "adaptive",
    ]
}
