//! Fig-9-style sweep: fixed vs staleness-adaptive step length as the
//! worker count grows.
//!
//! Staleness traces come from the event-driven cluster simulator
//! ([`crate::simulator::simulate_sharded_ps_trace`] — the same arrival
//! model as Figure 10), and each trace is folded through the analytic
//! convergence model ([`crate::simulator::convergence`], DESIGN.md §17)
//! under both step rules. The expected shape: at low worker counts
//! (τ ≈ 0) the two rules coincide; past the Proposition 1 staleness the
//! fixed step needs ever more trees — or never reaches the target at
//! all — while `step=adaptive` (`v/(1+τ)`) keeps contracting, so
//! adaptive's trees-to-target is no worse than fixed's at the highest
//! worker count.
//!
//! Output: `adaptive_step.csv` (one row per worker count × step mode)
//! and a JSON summary keyed `workers=N` with both counts. A fixed run
//! that never reaches the target reports `trees: null`.

use std::path::Path;

use anyhow::Result;

use crate::config::StepMode;
use crate::io::csv::CsvWriter;
use crate::io::Json;
use crate::simulator::{convergence, simulate_sharded_ps_trace, ClusterSpec, PhaseTimes};

use super::common::Scale;

/// Step length the sweep evaluates (paper-ish boosting step; large
/// enough that the Proposition 1 staleness bound actually bites inside
/// the simulated worker range).
const STEP: f32 = 0.3;
/// Target optimality gap (fraction of the starting gap).
const TARGET: f64 = 0.05;

/// Run the adaptive-step sweep at `scale`, writing CSV + summary JSON
/// into `out_dir`.
pub fn run(scale: Scale, out_dir: &Path) -> Result<Json> {
    let workers = scale.pick(vec![1, 4, 16, 64], vec![1, 2, 4, 8, 16, 32, 64, 128]);
    let trace_len = scale.pick(2_000, 20_000);
    let times = PhaseTimes::realsim_like();

    let mut csv = CsvWriter::new(&["workers", "step", "trees_to_target", "staleness_mean"]);
    let mut summary_items = Vec::new();
    for &w in &workers {
        let (sim, trace) = simulate_sharded_ps_trace(&ClusterSpec::new(w), &times, trace_len, 1);
        let mut row = Vec::new();
        for mode in [StepMode::Fixed, StepMode::Adaptive] {
            let trees = convergence::trees_to_target(&trace, STEP, mode, TARGET);
            csv.row(&[
                w.to_string(),
                mode.as_str().to_string(),
                trees.map_or("never".to_string(), |t| t.to_string()),
                format!("{:.3}", sim.mean_staleness),
            ]);
            row.push((
                format!("trees_{}", mode.as_str()),
                trees.map_or(Json::Null, |t| Json::Num(t as f64)),
            ));
        }
        row.push(("staleness_mean".to_string(), Json::Num(sim.mean_staleness)));
        summary_items.push((format!("workers={w}"), Json::Obj(row.into_iter().collect())));
    }
    std::fs::create_dir_all(out_dir)?;
    let path = out_dir.join("adaptive_step.csv");
    csv.write(&path)?;
    log::info!("[adaptive] wrote {}", path.display());
    Ok(Json::Obj(summary_items.into_iter().collect()))
}

/// `(fixed, adaptive)` trees-to-target at the sweep's highest worker
/// count (`None` = that rule never reached the target) — the headline
/// the bench and the acceptance check read.
pub fn highest_worker_outcome(summary: &Json) -> Option<(Option<f64>, Option<f64>)> {
    let obj = summary.as_obj()?;
    // keys sort lexicographically; find the numerically largest count
    let key = obj
        .keys()
        .max_by_key(|k| k.trim_start_matches("workers=").parse::<usize>().unwrap_or(0))?;
    let row = obj.get(key)?.as_obj()?;
    let get = |name: &str| match row.get(name) {
        Some(Json::Num(n)) => Some(*n),
        _ => None,
    };
    Some((get("trees_fixed"), get("trees_adaptive")))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn adaptive_beats_fixed_at_the_highest_worker_count() {
        let dir = std::env::temp_dir().join("asgbdt_adaptive_test");
        let j = run(Scale::Smoke, &dir).unwrap();
        assert_eq!(j.as_obj().unwrap().len(), 4);
        let (fixed, adaptive) = highest_worker_outcome(&j).unwrap();
        let adaptive = adaptive.expect("adaptive must always reach the target");
        // fixed either never converges at 64 simulated workers or needs
        // at least as many trees — the acceptance shape of the sweep
        match fixed {
            None => {}
            Some(f) => assert!(adaptive <= f, "adaptive {adaptive} vs fixed {f}"),
        }
        assert!(dir.join("adaptive_step.csv").exists());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn both_rules_coincide_with_one_worker() {
        let dir = std::env::temp_dir().join("asgbdt_adaptive_w1_test");
        let j = run(Scale::Smoke, &dir).unwrap();
        let row = j.as_obj().unwrap().get("workers=1").unwrap().as_obj().unwrap();
        // a single worker never races itself: τ ≡ 0, same model point
        assert_eq!(row.get("trees_fixed"), row.get("trees_adaptive"));
        std::fs::remove_dir_all(&dir).ok();
    }
}
