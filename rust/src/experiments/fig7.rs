//! Figure 7: Higgs — convergence vs sampling rate at a fixed worker count.

use std::path::Path;

use anyhow::Result;

use crate::data::synthetic;
use crate::io::Json;

use super::common::{base_cfg, convergence_sweep, sampling_rates, split, Scale, Variant};

/// Run the Figure 7 experiment (higgs-like convergence by sampling rate) at `scale`, writing CSV + summary JSON into `out_dir`.
pub fn run(scale: Scale, out_dir: &Path) -> Result<Json> {
    let n_rows = scale.pick(3_000, 60_000);
    let ds = synthetic::higgs_like(n_rows, 707);
    let (train_ds, test_ds) = split(&ds, 0.2, 707);
    let workers = scale.pick(4, 16);

    let variants = sampling_rates(scale)
        .into_iter()
        .map(|rate| {
            let mut cfg = base_cfg(scale, 7_000 + (rate * 1000.0) as u64);
            cfg.workers = workers;
            cfg.n_trees = scale.pick(48, 1000);
            cfg.step_length = scale.pick(0.1, 0.01);
            cfg.sampling_rate = rate;
            cfg.tree.max_leaves = 20;
            cfg.tree.feature_rate = 0.8;
            Variant {
                tag: format!("rate={rate}"),
                cfg,
            }
        })
        .collect();

    let (_reports, summary) =
        convergence_sweep("fig7_higgs_sampling", &train_ds, Some(&test_ds), variants, out_dir)?;
    Ok(summary)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig7_runs_all_rates() {
        let dir = std::env::temp_dir().join("asgbdt_fig7_test");
        let j = run(Scale::Smoke, &dir).unwrap();
        assert!(j.as_obj().unwrap().len() >= 2);
        assert!(dir.join("fig7_higgs_sampling.csv").exists());
        std::fs::remove_dir_all(&dir).ok();
    }
}
