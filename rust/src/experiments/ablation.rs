//! Ablations for the §V.B general conclusions not directly covered by the
//! figures:
//!
//! * **step-length × workers** (conclusions 2 & 4): larger v converges
//!   faster per tree but amplifies staleness noise; the safe v shrinks as
//!   workers grow.
//! * **leaves × sensitivity** (conclusion 6): more leaves → higher
//!   effective sample diversity → lower sensitivity to worker count.
//! * **bounded staleness** (extension beyond the paper): rejecting stale
//!   pushes trades throughput for per-tree quality.
//! * **histogram strategy** (system ablation): sibling subtraction vs
//!   whole-node rebuild in the tree hot path — identical forests by
//!   construction, different build cost (the `bench_tree_build` /
//!   `bench_histogram` targets measure the same axis in isolation).
//! * **scoring engine** (system ablation): blocked SoA frontier scoring
//!   vs the per-row enum walk in the server's F-update — bit-identical F
//!   vectors by construction, different apply cost (`bench_predict`
//!   measures the same axis in isolation).

use std::path::Path;

use anyhow::Result;

use crate::config::TrainMode;
use crate::data::synthetic;
use crate::forest::ScoreMode;
use crate::io::csv::CsvWriter;
use crate::io::Json;
use crate::ps::TargetMode;
use crate::tree::HistogramStrategy;

use super::common::{base_cfg, convergence_sweep, split, Scale, Variant};

/// Run the engineering ablation sweep (histogram strategy, scoring engine, accept pipeline) at `scale`, writing CSV + summary JSON into `out_dir`.
pub fn run(scale: Scale, out_dir: &Path) -> Result<Json> {
    let n_rows = scale.pick(1_500, 12_000);
    let ds = synthetic::realsim_like(n_rows, 111);
    let (train_ds, test_ds) = split(&ds, 0.2, 111);
    let n_trees = scale.pick(40, 200);
    let many_workers = scale.pick(4, 16);

    // ---- (a) step length × workers
    let mut variants = Vec::new();
    for &v in &scale.pick(vec![0.05f32, 0.3], vec![0.01f32, 0.05, 0.2]) {
        for workers in [1usize, many_workers] {
            let mut cfg = base_cfg(scale, 40_000 + workers as u64);
            cfg.workers = workers;
            cfg.n_trees = n_trees;
            cfg.step_length = v;
            cfg.sampling_rate = 0.8;
            cfg.tree.max_leaves = scale.pick(16, 64);
            variants.push(Variant {
                tag: format!("v={v}_workers={workers}"),
                cfg,
            });
        }
    }
    let (_r1, step_summary) =
        convergence_sweep("ablation_step_length", &train_ds, Some(&test_ds), variants, out_dir)?;

    // ---- (b) leaves × worker sensitivity
    let mut variants = Vec::new();
    for &leaves in &scale.pick(vec![4usize, 32], vec![8usize, 64, 400]) {
        for workers in [1usize, many_workers] {
            let mut cfg = base_cfg(scale, 41_000 + workers as u64 + leaves as u64);
            cfg.workers = workers;
            cfg.n_trees = n_trees;
            cfg.step_length = scale.pick(0.1, 0.02);
            cfg.sampling_rate = 0.8;
            cfg.tree.max_leaves = leaves;
            variants.push(Variant {
                tag: format!("leaves={leaves}_workers={workers}"),
                cfg,
            });
        }
    }
    let (_r2, leaves_summary) =
        convergence_sweep("ablation_leaves", &train_ds, Some(&test_ds), variants, out_dir)?;

    // ---- (c) bounded staleness (system extension)
    let mut variants = Vec::new();
    for max_tau in [None, Some(2u64), Some(0u64)] {
        let mut cfg = base_cfg(scale, 42_000);
        cfg.workers = many_workers;
        cfg.n_trees = n_trees;
        cfg.step_length = scale.pick(0.1, 0.02);
        cfg.sampling_rate = 0.8;
        cfg.tree.max_leaves = scale.pick(16, 64);
        cfg.max_staleness = max_tau;
        variants.push(Variant {
            tag: format!(
                "max_tau={}",
                max_tau.map(|t| t.to_string()).unwrap_or_else(|| "inf".into())
            ),
            cfg,
        });
    }
    let (reports, staleness_summary) = convergence_sweep(
        "ablation_bounded_staleness",
        &train_ds,
        Some(&test_ds),
        variants,
        out_dir,
    )?;

    // rejected-push accounting for the bounded-staleness table
    let mut csv = CsvWriter::new(&["max_tau", "accepted", "rejected", "trees_per_sec"]);
    for rep in &reports {
        csv.row(&[
            rep.mode.clone(),
            rep.trees_accepted.to_string(),
            rep.trees_rejected.to_string(),
            format!("{:.3}", rep.trees_per_sec()),
        ]);
    }
    csv.write(&out_dir.join("ablation_staleness_throughput.csv"))?;

    // ---- (d) histogram strategy (sibling subtraction vs whole-node rebuild)
    let strategies = [HistogramStrategy::Subtract, HistogramStrategy::Rebuild];
    let mut variants = Vec::new();
    for strat in strategies {
        let mut cfg = base_cfg(scale, 43_000);
        cfg.mode = TrainMode::Serial; // serial: wall-time delta is pure build cost
        cfg.n_trees = n_trees;
        cfg.step_length = scale.pick(0.1, 0.02);
        cfg.sampling_rate = 0.8;
        cfg.tree.max_leaves = scale.pick(16, 64);
        cfg.tree.strategy = strat;
        variants.push(Variant {
            tag: format!("hist={}", strat.as_str()),
            cfg,
        });
    }
    let (hist_reports, hist_summary) = convergence_sweep(
        "ablation_histogram_strategy",
        &train_ds,
        Some(&test_ds),
        variants,
        out_dir,
    )?;

    // same forests, different build cost: record the per-tree build times
    let mut csv = CsvWriter::new(&["strategy", "mean_build_s", "p99_build_s", "trees_per_sec"]);
    for (strat, rep) in strategies.iter().zip(&hist_reports) {
        csv.row(&[
            strat.as_str().to_string(),
            format!("{:.6}", rep.build_times.mean),
            format!("{:.6}", rep.build_times.p99),
            format!("{:.3}", rep.trees_per_sec()),
        ]);
    }
    csv.write(&out_dir.join("ablation_histogram_build_times.csv"))?;

    // ---- (e) scoring engine (blocked SoA vs per-row enum F-update)
    let scorings = [ScoreMode::Flat, ScoreMode::PerRow];
    let mut variants = Vec::new();
    for scoring in scorings {
        let mut cfg = base_cfg(scale, 44_000);
        cfg.mode = TrainMode::Serial; // serial: apply-time delta is pure scoring cost
        // the per-row engine only exists on the serial accept path; both
        // variants use it so the delta isolates the scoring engine alone
        cfg.target = TargetMode::Serial;
        cfg.n_trees = n_trees;
        cfg.step_length = scale.pick(0.1, 0.02);
        cfg.sampling_rate = 0.8;
        cfg.tree.max_leaves = scale.pick(16, 64);
        cfg.scoring = scoring;
        variants.push(Variant {
            tag: format!("scoring={}", scoring.as_str()),
            cfg,
        });
    }
    let (score_reports, score_summary) = convergence_sweep(
        "ablation_scoring_engine",
        &train_ds,
        Some(&test_ds),
        variants,
        out_dir,
    )?;

    // identical F vectors, different apply cost: record step-2 time —
    // `apply_total_s` includes the per-tree flatten that only the flat
    // engine pays (zero for perrow), so the engines compare end to end
    let mut csv = CsvWriter::new(&[
        "scoring", "update_f_total_s", "flatten_total_s", "apply_total_s", "trees_per_sec",
    ]);
    for (scoring, rep) in scorings.iter().zip(&score_reports) {
        let update_f = rep.timer.total("server/update_f");
        let flatten = rep.timer.total("server/flatten_tree");
        csv.row(&[
            scoring.as_str().to_string(),
            format!("{update_f:.6}"),
            format!("{flatten:.6}"),
            format!("{:.6}", update_f + flatten),
            format!("{:.3}", rep.trees_per_sec()),
        ]);
    }
    csv.write(&out_dir.join("ablation_scoring_apply_times.csv"))?;

    Ok(Json::obj(vec![
        ("step_length", step_summary),
        ("leaves", leaves_summary),
        ("bounded_staleness", staleness_summary),
        ("histogram_strategy", hist_summary),
        ("scoring_engine", score_summary),
    ]))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ablation_produces_all_five_studies() {
        let dir = std::env::temp_dir().join("asgbdt_ablation_test");
        let j = run(Scale::Smoke, &dir).unwrap();
        assert!(j.get("step_length").is_some());
        assert!(j.get("leaves").is_some());
        assert!(j.get("bounded_staleness").is_some());
        assert!(j.get("histogram_strategy").is_some());
        assert!(j.get("scoring_engine").is_some());
        assert!(dir.join("ablation_step_length.csv").exists());
        assert!(dir.join("ablation_leaves.csv").exists());
        assert!(dir.join("ablation_histogram_strategy.csv").exists());
        assert!(dir.join("ablation_histogram_build_times.csv").exists());
        assert!(dir.join("ablation_scoring_engine.csv").exists());
        assert!(dir.join("ablation_scoring_apply_times.csv").exists());
        std::fs::remove_dir_all(&dir).ok();
    }
}
