//! Mini property-based testing harness + shared dataset fixtures.
//!
//! proptest is not in the offline vendor set (DESIGN.md §7), so this is a
//! small substitute: seeded generators with a *size ramp* (early cases are
//! small, so the first failure tends to be near-minimal — a poor man's
//! shrinking) and a failure report that pins the exact case seed for
//! deterministic reproduction.
//!
//! The fixture side ([`BinnedFixture`], [`logistic_fixture`],
//! [`Gen::binned_dataset`]) centralises the dataset setup that the tree
//! and PS integration tests used to hand-roll at every call site: bin a
//! dataset, take the logistic gradients at margin 0 with unit weights
//! (grad ±1.0, hess 1.0 — dyadic rationals, so f64 partial sums are
//! *exact* and bit-identity assertions are robust to summation order),
//! and list every row id.

use std::sync::Arc;

use crate::config::TrainConfig;
use crate::data::{BinnedDataset, CsrMatrix, Dataset};
use crate::loss::logistic;
use crate::util::Rng;

/// A binned dataset with matching tree-build targets: the shape every
/// histogram/tree/PS test needs before it can build anything.
pub struct BinnedFixture {
    /// The raw labelled dataset the fixture was binned from.
    pub dataset: Dataset,
    /// The dataset binned for histogram building.
    pub binned: BinnedDataset,
    /// Logistic gradients at margin 0 with unit weights (±1.0 per row:
    /// l' = 2(p − y) at p = ½).
    pub grad: Vec<f32>,
    /// Logistic hessians at margin 0 with unit weights (1.0 per row:
    /// l'' = 4p(1 − p) at p = ½).
    pub hess: Vec<f32>,
    /// Every row id, `0..n_rows` — the full-dataset build set.
    pub rows: Vec<u32>,
}

/// Bin `ds` and compute the margin-0 logistic targets — the hand-rolled
/// `f=0 / w=1 / grad_hess_loss / rows` block previously copy-pasted
/// across `tests/test_tree.rs` and `tests/test_ps.rs`.
pub fn logistic_fixture(ds: &Dataset, max_bins: usize) -> BinnedFixture {
    let binned = BinnedDataset::from_dataset(ds, max_bins).expect("fixture binning");
    let f = vec![0.0f32; ds.n_rows()];
    let w = vec![1.0f32; ds.n_rows()];
    let gh = logistic::grad_hess_loss(&f, &ds.y, &w);
    BinnedFixture {
        dataset: ds.clone(),
        binned,
        grad: gh.grad,
        hess: gh.hess,
        rows: (0..ds.n_rows() as u32).collect(),
    }
}

/// Bin `ds` at the config's bin count and share it behind an [`Arc`] —
/// the setup the PS integration tests need when they publish their own
/// board snapshots (where the full [`logistic_fixture`], which also
/// computes grad/hess targets, would be wasted work).
pub fn binned_for(ds: &Dataset, cfg: &TrainConfig) -> Arc<BinnedDataset> {
    Arc::new(BinnedDataset::from_dataset(ds, cfg.max_bins).expect("fixture binning"))
}

/// Generation context handed to properties: seeded RNG + current size.
pub struct Gen {
    /// Per-case seeded RNG (fork it for independent streams).
    pub rng: Rng,
    /// Grows 1 → 100 across the case ramp.
    pub size: usize,
}

impl Gen {
    /// Uniform usize in [lo, hi], span scaled down for small sizes.
    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        debug_assert!(hi >= lo);
        let span = (hi - lo).min(self.size.max(1) * (hi - lo) / 100 + 1);
        lo + self.rng.below((span + 1) as u64) as usize
    }

    /// Uniform f64 in [lo, hi).
    pub fn f64_in(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.rng.uniform() * (hi - lo)
    }

    /// Vector of f32 drawn from N(0, scale), length n.
    pub fn vec_normal(&mut self, n: usize, scale: f32) -> Vec<f32> {
        (0..n).map(|_| self.rng.normal() as f32 * scale).collect()
    }

    /// Vector of {0,1} labels.
    pub fn labels(&mut self, n: usize) -> Vec<f32> {
        (0..n)
            .map(|_| if self.rng.bernoulli(0.5) { 1.0 } else { 0.0 })
            .collect()
    }

    /// A randomly generated sparse binary-classification dataset, binned
    /// and paired with matching margin-0 logistic grad/hess targets.
    ///
    /// Each of the `features` columns is present in a row with
    /// probability `1 − sparsity`; values are drawn from a small integer
    /// set so bins are well-populated at any size. Rows may be entirely
    /// implicit-zero — the histogram code must handle that, so fixtures
    /// exercise it.
    pub fn binned_dataset(
        &mut self,
        rows: usize,
        features: usize,
        sparsity: f64,
    ) -> BinnedFixture {
        let features = features.max(1);
        let mut mat: Vec<Vec<(u32, f32)>> = vec![Vec::new(); rows];
        for (r, row) in mat.iter_mut().enumerate() {
            for f in 0..features {
                if !self.rng.bernoulli(sparsity) {
                    let v = 1.0 + self.rng.below(5) as f32;
                    row.push((f as u32, v));
                }
            }
            // keep the matrix non-degenerate at extreme sparsity: row 0
            // always carries at least one explicit nonzero
            if r == 0 && row.is_empty() {
                row.push((0, 1.0));
            }
        }
        let x = CsrMatrix::from_rows(features, &mat).expect("fixture matrix");
        let y = self.labels(rows);
        let ds = Dataset::new("gen", x, y);
        logistic_fixture(&ds, 16)
    }

    /// Non-negative weights with occasional zeros (padding-like).
    pub fn weights(&mut self, n: usize) -> Vec<f32> {
        (0..n)
            .map(|_| {
                if self.rng.bernoulli(0.15) {
                    0.0
                } else {
                    self.rng.exponential() as f32
                }
            })
            .collect()
    }
}

/// Run `prop` over `cases` generated inputs. On failure, panics with the
/// case index and seed so [`check_one`] can replay it exactly.
pub fn check<P>(name: &str, cases: usize, seed: u64, mut prop: P)
where
    P: FnMut(&mut Gen) -> Result<(), String>,
{
    for case in 0..cases {
        let case_seed = seed
            .wrapping_mul(0x9E37_79B9_7F4A_7C15)
            .wrapping_add(case as u64);
        let size = 1 + case * 100 / cases.max(1);
        let mut g = Gen {
            rng: Rng::new(case_seed),
            size,
        };
        if let Err(msg) = prop(&mut g) {
            panic!(
                "property '{name}' failed at case {case}/{cases} \
                 (replay: check_one(\"{name}\", {case_seed}, {size}, ..)): {msg}"
            );
        }
    }
}

/// Replay a single failing case printed by [`check`].
pub fn check_one<P>(name: &str, case_seed: u64, size: usize, mut prop: P)
where
    P: FnMut(&mut Gen) -> Result<(), String>,
{
    let mut g = Gen {
        rng: Rng::new(case_seed),
        size,
    };
    if let Err(msg) = prop(&mut g) {
        panic!("property '{name}' failed on replay: {msg}");
    }
}

/// Approximate equality helper for property bodies (relative tolerance).
pub fn close(a: f64, b: f64, tol: f64) -> Result<(), String> {
    if (a - b).abs() <= tol * (1.0 + a.abs().max(b.abs())) {
        Ok(())
    } else {
        Err(format!("{a} != {b} (tol {tol})"))
    }
}

/// Assertion macro for property bodies: early-returns an Err with context.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)*) => {
        if !($cond) {
            return Err(format!($($fmt)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn check_passes_trivially_true_property() {
        check("true", 50, 1, |g| {
            let n = g.usize_in(0, 100);
            if n <= 100 {
                Ok(())
            } else {
                Err("impossible".into())
            }
        });
    }

    #[test]
    #[should_panic(expected = "property 'fails'")]
    fn check_panics_with_replay_info() {
        check("fails", 20, 2, |g| {
            let n = g.usize_in(0, 10);
            Err(format!("n={n}"))
        });
    }

    #[test]
    fn size_ramps_up() {
        let mut sizes = Vec::new();
        check("ramp", 10, 3, |g| {
            sizes.push(g.size);
            Ok(())
        });
        assert!(sizes.first().unwrap() < sizes.last().unwrap());
    }

    #[test]
    fn close_tolerates_relative_error() {
        assert!(close(1.0, 1.0 + 1e-9, 1e-6).is_ok());
        assert!(close(1e6, 1e6 + 1.0, 1e-5).is_ok());
        assert!(close(1.0, 2.0, 1e-6).is_err());
    }

    #[test]
    fn binned_dataset_fixture_is_consistent() {
        let mut g = Gen {
            rng: Rng::new(7),
            size: 100,
        };
        let fx = g.binned_dataset(60, 12, 0.5);
        assert_eq!(fx.dataset.n_rows(), 60);
        assert_eq!(fx.dataset.n_features(), 12);
        assert_eq!(fx.binned.n_features, 12);
        assert_eq!(fx.grad.len(), 60);
        assert_eq!(fx.hess.len(), 60);
        assert_eq!(fx.rows.len(), 60);
        // margin-0 logistic targets are dyadic: ±1.0 grads, 1.0 hessians
        assert!(fx.grad.iter().all(|&gr| gr == 1.0 || gr == -1.0));
        assert!(fx.hess.iter().all(|&h| h == 1.0));
        // sparsity=1 degenerates gracefully (one seeded nonzero survives)
        let fx = g.binned_dataset(5, 3, 1.0);
        assert_eq!(fx.dataset.n_rows(), 5);
        assert!(fx.dataset.x.density() > 0.0);
    }

    #[test]
    fn binned_for_bins_at_the_configs_bin_count() {
        let mut g = Gen {
            rng: Rng::new(9),
            size: 100,
        };
        let fx = g.binned_dataset(40, 6, 0.4);
        let mut cfg = TrainConfig::default();
        cfg.max_bins = 8;
        let b = binned_for(&fx.dataset, &cfg);
        assert_eq!(b.n_features, 6);
        assert!(b.total_bins() <= 6 * 8, "bins exceed max_bins budget");
    }

    #[test]
    fn generators_produce_expected_shapes() {
        let mut g = Gen {
            rng: Rng::new(4),
            size: 100,
        };
        let v = g.vec_normal(10, 2.0);
        assert_eq!(v.len(), 10);
        let y = g.labels(100);
        assert!(y.iter().all(|&l| l == 0.0 || l == 1.0));
        let w = g.weights(100);
        assert!(w.iter().all(|&x| x >= 0.0));
        assert!(w.iter().any(|&x| x == 0.0)); // padding-like zeros occur
    }
}
