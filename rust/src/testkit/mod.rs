//! Mini property-based testing harness.
//!
//! proptest is not in the offline vendor set (DESIGN.md §7), so this is a
//! small substitute: seeded generators with a *size ramp* (early cases are
//! small, so the first failure tends to be near-minimal — a poor man's
//! shrinking) and a failure report that pins the exact case seed for
//! deterministic reproduction.

use crate::util::Rng;

/// Generation context handed to properties: seeded RNG + current size.
pub struct Gen {
    /// Per-case seeded RNG (fork it for independent streams).
    pub rng: Rng,
    /// Grows 1 → 100 across the case ramp.
    pub size: usize,
}

impl Gen {
    /// Uniform usize in [lo, hi], span scaled down for small sizes.
    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        debug_assert!(hi >= lo);
        let span = (hi - lo).min(self.size.max(1) * (hi - lo) / 100 + 1);
        lo + self.rng.below((span + 1) as u64) as usize
    }

    /// Uniform f64 in [lo, hi).
    pub fn f64_in(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.rng.uniform() * (hi - lo)
    }

    /// Vector of f32 drawn from N(0, scale), length n.
    pub fn vec_normal(&mut self, n: usize, scale: f32) -> Vec<f32> {
        (0..n).map(|_| self.rng.normal() as f32 * scale).collect()
    }

    /// Vector of {0,1} labels.
    pub fn labels(&mut self, n: usize) -> Vec<f32> {
        (0..n)
            .map(|_| if self.rng.bernoulli(0.5) { 1.0 } else { 0.0 })
            .collect()
    }

    /// Non-negative weights with occasional zeros (padding-like).
    pub fn weights(&mut self, n: usize) -> Vec<f32> {
        (0..n)
            .map(|_| {
                if self.rng.bernoulli(0.15) {
                    0.0
                } else {
                    self.rng.exponential() as f32
                }
            })
            .collect()
    }
}

/// Run `prop` over `cases` generated inputs. On failure, panics with the
/// case index and seed so [`check_one`] can replay it exactly.
pub fn check<P>(name: &str, cases: usize, seed: u64, mut prop: P)
where
    P: FnMut(&mut Gen) -> Result<(), String>,
{
    for case in 0..cases {
        let case_seed = seed
            .wrapping_mul(0x9E37_79B9_7F4A_7C15)
            .wrapping_add(case as u64);
        let size = 1 + case * 100 / cases.max(1);
        let mut g = Gen {
            rng: Rng::new(case_seed),
            size,
        };
        if let Err(msg) = prop(&mut g) {
            panic!(
                "property '{name}' failed at case {case}/{cases} \
                 (replay: check_one(\"{name}\", {case_seed}, {size}, ..)): {msg}"
            );
        }
    }
}

/// Replay a single failing case printed by [`check`].
pub fn check_one<P>(name: &str, case_seed: u64, size: usize, mut prop: P)
where
    P: FnMut(&mut Gen) -> Result<(), String>,
{
    let mut g = Gen {
        rng: Rng::new(case_seed),
        size,
    };
    if let Err(msg) = prop(&mut g) {
        panic!("property '{name}' failed on replay: {msg}");
    }
}

/// Approximate equality helper for property bodies (relative tolerance).
pub fn close(a: f64, b: f64, tol: f64) -> Result<(), String> {
    if (a - b).abs() <= tol * (1.0 + a.abs().max(b.abs())) {
        Ok(())
    } else {
        Err(format!("{a} != {b} (tol {tol})"))
    }
}

/// Assertion macro for property bodies: early-returns an Err with context.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)*) => {
        if !($cond) {
            return Err(format!($($fmt)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn check_passes_trivially_true_property() {
        check("true", 50, 1, |g| {
            let n = g.usize_in(0, 100);
            if n <= 100 {
                Ok(())
            } else {
                Err("impossible".into())
            }
        });
    }

    #[test]
    #[should_panic(expected = "property 'fails'")]
    fn check_panics_with_replay_info() {
        check("fails", 20, 2, |g| {
            let n = g.usize_in(0, 10);
            Err(format!("n={n}"))
        });
    }

    #[test]
    fn size_ramps_up() {
        let mut sizes = Vec::new();
        check("ramp", 10, 3, |g| {
            sizes.push(g.size);
            Ok(())
        });
        assert!(sizes.first().unwrap() < sizes.last().unwrap());
    }

    #[test]
    fn close_tolerates_relative_error() {
        assert!(close(1.0, 1.0 + 1e-9, 1e-6).is_ok());
        assert!(close(1e6, 1e6 + 1.0, 1e-5).is_ok());
        assert!(close(1.0, 2.0, 1e-6).is_err());
    }

    #[test]
    fn generators_produce_expected_shapes() {
        let mut g = Gen {
            rng: Rng::new(4),
            size: 100,
        };
        let v = g.vec_normal(10, 2.0);
        assert_eq!(v.len(), 10);
        let y = g.labels(100);
        assert!(y.iter().all(|&l| l == 0.0 || l == 1.0));
        let w = g.weights(100);
        assert!(w.iter().all(|&x| x >= 0.0));
        assert!(w.iter().any(|&x| x == 0.0)); // padding-like zeros occur
    }
}
