//! Deterministic fault injection (DESIGN.md §14).
//!
//! A [`FaultPlan`] decides, for every *(site, attempt)* key, whether the
//! action at that key is delivered cleanly or suffers an injected fault
//! (drop / duplicate / delay on message sites, panic on worker sites).
//! Decisions come from [`CounterRng::keyed`] on
//! `(fault_seed, site_code, attempt)` — a pure function of the key, with
//! no sequential RNG state — so a chaos run is exactly replayable: the
//! same seed and the same exercised keys produce the same faults, no
//! matter how threads interleave. The plan also records every injected
//! fault into a trace ([`FaultPlan::trace`]) that tests diff across runs
//! and the run report surfaces.
//!
//! With `fault_seed` unset no plan exists at all: the trainers skip the
//! wrapper types entirely and the hot path carries zero fault-layer
//! atomics (see `coordinator/async_trainer.rs`).

use std::sync::Mutex;
use std::time::Duration;

use crate::util::rng::{CounterRng, RandStream};

/// Which class of injection site a fault key addresses. The kind is the
/// high bits of the site code, so streams never collide across kinds.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum FaultKind {
    /// A `HistShardMsg` send on the shard transport (index packs
    /// `from_shard << 16 | to_shard`).
    ShardSend,
    /// A worker's tree push into the server channel (index packs
    /// `worker_id << 16 | incarnation`).
    WorkerPush,
    /// A worker build cycle that may panic (index packs
    /// `worker_id << 16 | incarnation`).
    WorkerPanic,
}

impl FaultKind {
    /// Stable numeric code (the high 16 bits of a site code).
    pub fn code(self) -> u64 {
        match self {
            FaultKind::ShardSend => 1,
            FaultKind::WorkerPush => 2,
            FaultKind::WorkerPanic => 3,
        }
    }

    /// Human-readable kind name (trace rendering).
    pub fn as_str(self) -> &'static str {
        match self {
            FaultKind::ShardSend => "shard_send",
            FaultKind::WorkerPush => "worker_push",
            FaultKind::WorkerPanic => "worker_panic",
        }
    }
}

/// One injection site: a kind plus a packed entity index. Together with
/// an attempt counter it forms the full key every decision is derived
/// from.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct FaultSite {
    /// The site class.
    pub kind: FaultKind,
    /// Packed entity index (see [`FaultKind`] for each kind's packing).
    pub index: u64,
}

impl FaultSite {
    /// The transport site for messages from `from_shard` to `to_shard`.
    pub fn shard_send(from_shard: usize, to_shard: usize) -> FaultSite {
        FaultSite {
            kind: FaultKind::ShardSend,
            index: ((from_shard as u64) << 16) | to_shard as u64,
        }
    }

    /// The push site for one worker incarnation.
    pub fn worker_push(worker_id: usize, incarnation: u64) -> FaultSite {
        FaultSite {
            kind: FaultKind::WorkerPush,
            index: ((worker_id as u64) << 16) | incarnation,
        }
    }

    /// The panic site for one worker incarnation.
    pub fn worker_panic(worker_id: usize, incarnation: u64) -> FaultSite {
        FaultSite {
            kind: FaultKind::WorkerPanic,
            index: ((worker_id as u64) << 16) | incarnation,
        }
    }

    /// The site's `CounterRng` stream: kind in the high bits, packed
    /// index below — distinct sites never share a key stream.
    pub fn stream(self) -> u64 {
        (self.kind.code() << 48) | self.index
    }
}

/// What the plan decided for one *(site, attempt)* key.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum FaultAction {
    /// No fault: the action proceeds normally.
    Deliver,
    /// The message is lost (the sender retries with a fresh attempt).
    Drop,
    /// The message is delivered twice now plus a stale replay later —
    /// exercising both same-epoch dedup and the cross-epoch filter.
    Duplicate,
    /// The message is delivered after a bounded injected latency.
    Delay,
    /// The worker incarnation panics at this build cycle.
    Panic,
}

impl FaultAction {
    /// Human-readable action name (trace rendering).
    pub fn as_str(self) -> &'static str {
        match self {
            FaultAction::Deliver => "deliver",
            FaultAction::Drop => "drop",
            FaultAction::Duplicate => "duplicate",
            FaultAction::Delay => "delay",
            FaultAction::Panic => "panic",
        }
    }
}

/// One recorded injected fault: the key it fired at and what happened.
/// Clean deliveries are not recorded (the trace holds faults only).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct FaultEvent {
    /// The site the fault fired at.
    pub site: FaultSite,
    /// The attempt counter value at that site.
    pub attempt: u64,
    /// The injected action (never [`FaultAction::Deliver`]).
    pub action: FaultAction,
}

/// Fault-rate configuration: one decision per message-site key
/// partitions a single uniform draw into drop / duplicate / delay /
/// deliver (so the three rates must sum to ≤ 1); worker-panic sites use
/// `panic_rate` independently.
#[derive(Debug, Clone, Copy)]
pub struct FaultSpec {
    /// Probability a message-site key drops its message.
    pub drop_rate: f64,
    /// Probability a message-site key duplicates its message.
    pub dup_rate: f64,
    /// Probability a message-site key delays its message.
    pub delay_rate: f64,
    /// Probability a worker-panic-site key panics the incarnation.
    pub panic_rate: f64,
    /// Upper bound on an injected delay, microseconds.
    pub max_delay_us: u64,
}

impl Default for FaultSpec {
    fn default() -> Self {
        FaultSpec {
            drop_rate: 0.0,
            dup_rate: 0.0,
            delay_rate: 0.0,
            panic_rate: 0.0,
            max_delay_us: 500,
        }
    }
}

/// Tally of a trace by action — what the run report surfaces.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct FaultCounts {
    /// Messages dropped.
    pub drops: u64,
    /// Messages duplicated.
    pub dups: u64,
    /// Messages delayed.
    pub delays: u64,
    /// Worker incarnations panicked.
    pub panics: u64,
}

impl FaultCounts {
    /// Tally a trace.
    pub fn of(trace: &[FaultEvent]) -> FaultCounts {
        let mut c = FaultCounts::default();
        for e in trace {
            match e.action {
                FaultAction::Drop => c.drops += 1,
                FaultAction::Duplicate => c.dups += 1,
                FaultAction::Delay => c.delays += 1,
                FaultAction::Panic => c.panics += 1,
                FaultAction::Deliver => {}
            }
        }
        c
    }

    /// Total injected faults.
    pub fn total(&self) -> u64 {
        self.drops + self.dups + self.delays + self.panics
    }
}

/// Salt separating the delay-magnitude draw from the action draw, so
/// both are independent pure functions of the same *(site, attempt)* key.
const DELAY_SALT: u64 = 0xDE1A_ED01;

/// Salt for worker-incarnation identity seeds (see
/// [`worker_identity_seed`]).
const IDENTITY_SALT: u64 = 0x1DE2_717E;

/// The deterministic fault plan: seed + rates + the trace of every fault
/// actually injected. Decisions ([`FaultPlan::decide`]) are pure; only
/// recording ([`FaultPlan::apply`]) touches shared state, behind a mutex
/// that exists only when faults are armed.
#[derive(Debug)]
pub struct FaultPlan {
    seed: u64,
    spec: FaultSpec,
    trace: Mutex<Vec<FaultEvent>>,
}

impl FaultPlan {
    /// A plan from a seed and rates.
    pub fn new(seed: u64, spec: FaultSpec) -> FaultPlan {
        FaultPlan {
            seed,
            spec,
            trace: Mutex::new(Vec::new()),
        }
    }

    /// The plan's seed.
    pub fn seed(&self) -> u64 {
        self.seed
    }

    /// The plan's rates.
    pub fn spec(&self) -> FaultSpec {
        self.spec
    }

    /// The action at one *(site, attempt)* key — a pure function of
    /// `(seed, site, attempt)`: calling it any number of times, from any
    /// thread, in any order, yields the same answer. Message sites
    /// partition a single uniform draw by the cumulative rates;
    /// worker-panic sites draw an independent Bernoulli at `panic_rate`.
    pub fn decide(&self, site: FaultSite, attempt: u64) -> FaultAction {
        let mut rng = CounterRng::keyed(self.seed, site.stream(), attempt);
        if site.kind == FaultKind::WorkerPanic {
            return if rng.bernoulli(self.spec.panic_rate) {
                FaultAction::Panic
            } else {
                FaultAction::Deliver
            };
        }
        let u = rng.uniform();
        if u < self.spec.drop_rate {
            FaultAction::Drop
        } else if u < self.spec.drop_rate + self.spec.dup_rate {
            FaultAction::Duplicate
        } else if u < self.spec.drop_rate + self.spec.dup_rate + self.spec.delay_rate {
            FaultAction::Delay
        } else {
            FaultAction::Deliver
        }
    }

    /// [`decide`](FaultPlan::decide), recording the event into the trace
    /// when it is a fault. The injection points call this exactly once
    /// per exercised key, so the trace is the set of exercised keys that
    /// decided non-`Deliver`.
    pub fn apply(&self, site: FaultSite, attempt: u64) -> FaultAction {
        let action = self.decide(site, attempt);
        if action != FaultAction::Deliver {
            self.trace.lock().unwrap().push(FaultEvent {
                site,
                attempt,
                action,
            });
        }
        action
    }

    /// The injected delay at one key — pure, bounded by
    /// `spec.max_delay_us`, drawn independently of the action decision.
    pub fn delay_for(&self, site: FaultSite, attempt: u64) -> Duration {
        let mut rng = CounterRng::keyed(self.seed ^ DELAY_SALT, site.stream(), attempt);
        Duration::from_micros((rng.uniform() * self.spec.max_delay_us as f64) as u64)
    }

    /// The recorded fault trace in canonical *(kind, index, attempt)*
    /// order — identical regardless of the thread interleaving that
    /// produced it, since each event's content is a pure function of its
    /// key.
    pub fn trace(&self) -> Vec<FaultEvent> {
        let mut t = self.trace.lock().unwrap().clone();
        t.sort_unstable_by_key(|e| (e.site.kind.code(), e.site.index, e.attempt));
        t
    }

    /// Tally of the recorded trace.
    pub fn counts(&self) -> FaultCounts {
        FaultCounts::of(&self.trace())
    }
}

/// The RNG seed for one worker incarnation. Incarnation 0 keeps the
/// run's base seed unchanged (a supervised but fault-free run builds the
/// same trees as an unsupervised one); each restart derives a fresh
/// identity from `CounterRng` so a replacement worker never replays its
/// predecessor's sampling stream.
pub fn worker_identity_seed(base_seed: u64, worker_id: usize, incarnation: u64) -> u64 {
    if incarnation == 0 {
        return base_seed;
    }
    CounterRng::keyed(base_seed ^ IDENTITY_SALT, worker_id as u64, incarnation).next_u64()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec() -> FaultSpec {
        FaultSpec {
            drop_rate: 0.2,
            dup_rate: 0.1,
            delay_rate: 0.1,
            panic_rate: 0.3,
            max_delay_us: 200,
        }
    }

    fn all_sites() -> Vec<FaultSite> {
        let mut sites = Vec::new();
        for from in 0..3 {
            for to in 0..3 {
                sites.push(FaultSite::shard_send(from, to));
            }
        }
        for wid in 0..4 {
            for inc in 0..3 {
                sites.push(FaultSite::worker_push(wid, inc));
                sites.push(FaultSite::worker_panic(wid, inc));
            }
        }
        sites
    }

    #[test]
    fn decisions_are_pure_functions_of_the_key() {
        let a = FaultPlan::new(7, spec());
        let b = FaultPlan::new(7, spec());
        let c = FaultPlan::new(8, spec());
        let mut diverged = false;
        for site in all_sites() {
            for attempt in 0..50 {
                assert_eq!(a.decide(site, attempt), b.decide(site, attempt));
                // re-asking the same plan never changes the answer
                assert_eq!(a.decide(site, attempt), a.decide(site, attempt));
                diverged |= a.decide(site, attempt) != c.decide(site, attempt);
            }
        }
        assert!(diverged, "different seeds should disagree somewhere");
    }

    #[test]
    fn all_actions_occur_and_rates_partition_one_draw() {
        let plan = FaultPlan::new(11, spec());
        let mut seen = std::collections::HashSet::new();
        for attempt in 0..500 {
            seen.insert(plan.decide(FaultSite::shard_send(0, 1), attempt));
            seen.insert(plan.decide(FaultSite::worker_panic(0, 0), attempt));
        }
        for action in [
            FaultAction::Deliver,
            FaultAction::Drop,
            FaultAction::Duplicate,
            FaultAction::Delay,
            FaultAction::Panic,
        ] {
            assert!(seen.contains(&action), "never saw {}", action.as_str());
        }
        // message sites never panic; panic sites never drop
        for attempt in 0..500 {
            assert_ne!(
                plan.decide(FaultSite::shard_send(0, 1), attempt),
                FaultAction::Panic
            );
            let p = plan.decide(FaultSite::worker_panic(0, 0), attempt);
            assert!(p == FaultAction::Panic || p == FaultAction::Deliver);
        }
    }

    #[test]
    fn zero_rates_never_fault_and_rate_one_always_does() {
        let off = FaultPlan::new(3, FaultSpec::default());
        for site in all_sites() {
            for attempt in 0..100 {
                assert_eq!(off.decide(site, attempt), FaultAction::Deliver);
            }
        }
        let hard = FaultPlan::new(
            3,
            FaultSpec {
                drop_rate: 1.0,
                panic_rate: 1.0,
                ..FaultSpec::default()
            },
        );
        assert_eq!(
            hard.decide(FaultSite::shard_send(1, 0), 9),
            FaultAction::Drop
        );
        assert_eq!(
            hard.decide(FaultSite::worker_panic(2, 1), 0),
            FaultAction::Panic
        );
    }

    #[test]
    fn trace_is_canonical_and_replays() {
        let plan = FaultPlan::new(5, spec());
        // exercise keys in a deliberately scrambled order
        for attempt in [7u64, 1, 4, 0, 9, 3] {
            for site in [
                FaultSite::worker_push(1, 0),
                FaultSite::shard_send(2, 0),
                FaultSite::worker_panic(0, 1),
            ] {
                plan.apply(site, attempt);
            }
        }
        let trace = plan.trace();
        assert!(!trace.is_empty(), "rates high enough to fault somewhere");
        // canonical order: sorted by (kind, index, attempt)
        let mut keys: Vec<_> = trace
            .iter()
            .map(|e| (e.site.kind.code(), e.site.index, e.attempt))
            .collect();
        let sorted = {
            let mut s = keys.clone();
            s.sort_unstable();
            s
        };
        assert_eq!(keys, sorted);
        keys.dedup();
        assert_eq!(keys.len(), trace.len(), "each key recorded at most once");
        // replay: re-deciding every traced key reproduces its action
        for e in &trace {
            assert_eq!(plan.decide(e.site, e.attempt), e.action);
        }
        // counts tally the trace
        let c = plan.counts();
        assert_eq!(c.total() as usize, trace.len());
    }

    #[test]
    fn delays_are_bounded_and_pure() {
        let plan = FaultPlan::new(6, spec());
        for attempt in 0..50 {
            let d = plan.delay_for(FaultSite::shard_send(0, 1), attempt);
            assert!(d.as_micros() <= 200);
            assert_eq!(d, plan.delay_for(FaultSite::shard_send(0, 1), attempt));
        }
    }

    #[test]
    fn identity_seeds_fresh_per_incarnation() {
        assert_eq!(worker_identity_seed(42, 3, 0), 42);
        let a = worker_identity_seed(42, 3, 1);
        let b = worker_identity_seed(42, 3, 2);
        let c = worker_identity_seed(42, 2, 1);
        assert_ne!(a, 42);
        assert_ne!(a, b);
        assert_ne!(a, c);
        assert_eq!(a, worker_identity_seed(42, 3, 1));
    }
}
