//! Persistent thread pools for every per-tree parallel section —
//! server-side scoring *and* worker-side tree building.
//!
//! Every accepted tree runs a parallel section on the server's accept
//! path: the blocked F-update (`forest/score.rs`) and the fused accept
//! pass (`ps/shard.rs`) both fan work out across `score_threads`
//! threads. Until this module existed they did so with per-tree
//! `std::thread::scope` spawns — an OS thread create + join per tree,
//! which costs tens of microseconds and sits directly on the accept
//! loop's critical path. On small datasets (where one tree's scoring
//! work is itself tens of microseconds) spawn/join *dominates* the
//! accept cost and erases the benefit of sharding; `bench_ps_throughput`
//! measures exactly this.
//!
//! The worker's tree builder has the same cost structure, only worse:
//! `tree/parallel.rs` runs one sharded histogram build per leaf and one
//! work-stealing split search per node — dozens of parallel sections
//! *per tree* (the fork-join-inside-tree-building pattern the paper's
//! §II pins on LightGBM/TencentBoost). Those sections produce
//! per-worker *outputs* — per-scanner `SplitInfo` candidates, partial
//! `Histogram`s — which is what [`Executor::run_collect`] adds on top
//! of the fire-and-forget [`Executor::run`]: each active index's return
//! value lands in its own slot, in index order, so merge order is a
//! pure function of the index range and bit-identity across pool modes
//! stays structural. (The tree builders' histogram sections use the
//! same per-worker-slot idea with pooled buffers through `run` — see
//! `tree/parallel.rs` — so their hot path allocates nothing per leaf.)
//! `bench_tree_build`/`bench_histogram` measure the per-tree build cost
//! under both modes.
//!
//! [`ScorePool`] keeps `score_threads` workers parked on a condvar for
//! the lifetime of the server and hands them one job per parallel
//! section:
//!
//! * **Epoch-stamped handoff** — each [`ScorePool::run`] call bumps an
//!   epoch counter under the pool mutex and wakes the workers; a worker
//!   runs a job exactly once per epoch (it remembers the last epoch it
//!   served), so a spurious wakeup or a slow worker can never run a job
//!   twice or skip one.
//! * **Scoped borrows without scoped threads** — the job closure may
//!   borrow stack data (`&mut` F-slices, scratch buffers): `run` erases
//!   its lifetime to hand it to the parked workers, and does not return
//!   until every worker has checked in for the epoch, so the borrow
//!   outlives every use (the same guarantee `thread::scope` gives,
//!   amortised over the pool's lifetime).
//! * **Panic propagation** — a panicking job is caught on the worker,
//!   carried back under the mutex, and re-raised on the caller thread by
//!   `run` (first payload wins), mirroring the `join().unwrap()`
//!   behaviour of the scoped path. The pool itself stays usable after a
//!   propagated panic.
//! * **Clean shutdown** — dropping the pool flags shutdown, wakes every
//!   worker and joins them; no thread outlives the pool.
//!
//! [`Executor`] is the knob-selected front door: `pool=persistent`
//! (default) dispatches parallel sections onto a [`ScorePool`];
//! `pool=scoped` keeps the original per-section `thread::scope` spawns
//! as the bit-identical reference implementation. Both run the same job
//! closures over the same index range, so every engine equivalence test
//! holds under either mode — the only difference is *where the threads
//! come from*, never what they compute.

use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

/// How parallel sections obtain their threads (config key
/// `pool=persistent|scoped`; see DESIGN.md §11–12). One knob governs
/// both pools: the server's scoring executor (`score_threads`) and each
/// worker's tree-build executor (`build_threads`).
///
/// ```
/// use asgbdt::util::PoolMode;
/// assert_eq!(PoolMode::parse("persistent").unwrap(), PoolMode::Persistent);
/// assert_eq!(PoolMode::Scoped.as_str(), "scoped");
/// assert_eq!(PoolMode::default(), PoolMode::Persistent);
/// ```
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum PoolMode {
    /// One server-lifetime [`ScorePool`] of parked workers; per-tree
    /// dispatch is a condvar wake instead of an OS thread spawn.
    #[default]
    Persistent,
    /// Per-section `std::thread::scope` spawns — the reference
    /// implementation the pool is tested bit-identical against.
    Scoped,
}

impl PoolMode {
    /// Parse the `pool=` config/CLI value.
    pub fn parse(s: &str) -> anyhow::Result<PoolMode> {
        match s {
            "persistent" => Ok(PoolMode::Persistent),
            "scoped" => Ok(PoolMode::Scoped),
            other => anyhow::bail!("unknown pool mode '{other}' (persistent|scoped)"),
        }
    }

    /// The config/CLI spelling of this mode.
    pub fn as_str(&self) -> &'static str {
        match self {
            PoolMode::Persistent => "persistent",
            PoolMode::Scoped => "scoped",
        }
    }
}

/// A borrowed job closure, as every `run` entry point receives it.
type JobRef<'a> = &'a (dyn Fn(usize) + Sync);

/// [`JobRef`] with its lifetime erased into a raw pointer (`*const dyn`
/// defaults to the `'static` object bound) for storage in [`PoolState`].
type RawJob = *const (dyn Fn(usize) + Sync);

/// A dispatched job: a lifetime-erased pointer to the caller's closure
/// plus how many worker indices participate this epoch.
///
/// Safety: the pointer is only dereferenced between the epoch bump that
/// published it and the last worker check-in for that epoch, and
/// [`ScorePool::run`] blocks the owning borrow until that check-in.
#[derive(Clone, Copy)]
struct Job {
    ptr: RawJob,
    active: usize,
}

// The raw pointer is handed between threads under the pool mutex and only
// dereferenced while `run` keeps the underlying closure alive (see Job).
unsafe impl Send for Job {}

/// State shared between the caller and the parked workers, guarded by
/// one mutex (jobs are rare — one per accepted tree — so contention is
/// nil; correctness, not throughput, picks the lock).
struct PoolState {
    /// Bumped once per dispatched job; workers serve each epoch once.
    epoch: u64,
    /// The current job; `None` between epochs.
    job: Option<Job>,
    /// Workers that have not yet checked in for the current epoch.
    remaining: usize,
    /// First panic payload raised by a job this epoch, if any.
    panic: Option<Box<dyn std::any::Any + Send>>,
    /// Set by drop: workers exit instead of waiting for the next epoch.
    shutdown: bool,
}

struct Shared {
    state: Mutex<PoolState>,
    /// Workers park here between epochs.
    work_cv: Condvar,
    /// The caller parks here until `remaining` hits zero.
    done_cv: Condvar,
    /// Held for the whole of [`ScorePool::run`]: the epoch protocol
    /// assumes one dispatch in flight, and the lifetime-erased job
    /// pointer makes a second concurrent dispatch unsound, so callers
    /// racing `run` on a shared pool serialize here instead.
    dispatch: Mutex<()>,
}

/// A fixed-size pool of parked scoring workers living as long as its
/// owner (the server, a trainer, a bench). See the module docs for the
/// handoff protocol.
pub struct ScorePool {
    shared: Arc<Shared>,
    handles: Vec<JoinHandle<()>>,
}

impl std::fmt::Debug for ScorePool {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("ScorePool").field("threads", &self.handles.len()).finish()
    }
}

impl ScorePool {
    /// Spawn `threads` parked workers (at least one).
    pub fn new(threads: usize) -> ScorePool {
        let threads = threads.max(1);
        let shared = Arc::new(Shared {
            state: Mutex::new(PoolState {
                epoch: 0,
                job: None,
                remaining: 0,
                panic: None,
                shutdown: false,
            }),
            work_cv: Condvar::new(),
            done_cv: Condvar::new(),
            dispatch: Mutex::new(()),
        });
        let handles = (0..threads)
            .map(|idx| {
                let shared = shared.clone();
                std::thread::Builder::new()
                    .name(format!("score-{idx}"))
                    .spawn(move || worker_loop(idx, &shared))
                    .expect("spawn score pool worker")
            })
            .collect();
        ScorePool { shared, handles }
    }

    /// Number of pooled workers.
    pub fn threads(&self) -> usize {
        self.handles.len()
    }

    /// Run `job(idx)` for every `idx < active` on the pooled workers and
    /// wait for all of them. `active` is clamped to the pool size;
    /// `active == 0` is a no-op. Panics raised by the job are re-raised
    /// here after every worker has checked in.
    pub fn run(&self, active: usize, job: &(dyn Fn(usize) + Sync)) {
        let active = active.min(self.threads());
        if active == 0 {
            return;
        }
        // Erase the borrow's lifetime: safe because this function blocks
        // until every worker has checked in for the epoch, after which no
        // worker holds the pointer (see Job).
        let ptr = unsafe { std::mem::transmute::<JobRef<'_>, RawJob>(job) };
        // one dispatch in flight at a time (see Shared::dispatch); the
        // guard also recovers from a previous caller that panicked out
        let _dispatch = match self.shared.dispatch.lock() {
            Ok(g) => g,
            Err(poisoned) => poisoned.into_inner(),
        };
        {
            let mut st = self.shared.state.lock().unwrap();
            debug_assert_eq!(st.remaining, 0, "overlapping ScorePool::run calls");
            st.job = Some(Job { ptr, active });
            st.epoch += 1;
            // every worker checks in (inactive indices check in without
            // running the job) so `remaining == 0` proves nobody still
            // holds the job pointer
            st.remaining = self.threads();
            self.shared.work_cv.notify_all();
            let mut st = self
                .shared
                .done_cv
                .wait_while(st, |st| st.remaining > 0)
                .unwrap();
            st.job = None;
            if let Some(payload) = st.panic.take() {
                drop(st);
                resume_unwind(payload);
            }
        }
    }
}

impl Drop for ScorePool {
    fn drop(&mut self) {
        {
            let mut st = self.shared.state.lock().unwrap();
            st.shutdown = true;
            self.shared.work_cv.notify_all();
        }
        for h in self.handles.drain(..) {
            // workers catch job panics, so join only fails if a worker
            // thread itself died — never panic out of drop for that
            let _ = h.join();
        }
    }
}

/// The parked-worker loop: wait for an unseen epoch (or shutdown), run
/// the job for this worker's index if it is active, check in.
fn worker_loop(idx: usize, shared: &Shared) {
    let mut seen = 0u64;
    loop {
        let Some((epoch, job)) = wait_for_epoch(shared, seen) else {
            return;
        };
        seen = epoch;
        if idx < job.active {
            // the caller keeps the closure alive until we check in below
            let f = unsafe { &*job.ptr };
            if let Err(payload) = catch_unwind(AssertUnwindSafe(|| f(idx))) {
                let mut st = shared.state.lock().unwrap();
                if st.panic.is_none() {
                    st.panic = Some(payload);
                }
            }
        }
        let mut st = shared.state.lock().unwrap();
        st.remaining -= 1;
        if st.remaining == 0 {
            shared.done_cv.notify_all();
        }
    }
}

/// Park until the epoch moves past `seen` (returning the new epoch and
/// its job) or shutdown is flagged (returning `None`).
fn wait_for_epoch(shared: &Shared, seen: u64) -> Option<(u64, Job)> {
    let st = shared.state.lock().unwrap();
    let st = shared
        .work_cv
        .wait_while(st, |st| !st.shutdown && st.epoch == seen)
        .unwrap();
    if st.shutdown {
        return None;
    }
    Some((st.epoch, st.job.expect("epoch bumped without a job")))
}

/// The execution resource behind every parallel section, selected once
/// at startup by the `pool` knob and owned for its user's lifetime:
/// [`crate::ps::ServerCore`] constructs one from `cfg.pool` /
/// `cfg.score_threads` for the accept path, and every tree-building
/// loop (each async worker, the sync/serial trainers) constructs one
/// from `cfg.pool` / its build thread budget for
/// [`crate::tree::build_tree_feature_parallel`] and friends.
///
/// `run(active, job)` / `run_collect(active, job)` have identical
/// semantics in both modes — `job(idx)` for each `idx < active`, return
/// after all complete (outputs in index order), propagate job panics —
/// so engines built on them are oblivious to where their threads come
/// from, and bit-identity across modes is structural.
#[derive(Debug)]
pub enum Executor {
    /// Per-section `std::thread::scope` spawns (reference).
    Scoped {
        /// Thread budget a parallel section may request.
        threads: usize,
    },
    /// Dispatch onto a server-lifetime [`ScorePool`].
    Persistent(ScorePool),
}

impl Executor {
    /// Build the executor for a mode and thread budget (clamped to ≥ 1).
    ///
    /// A budget of 1 never engages a parallel section (every engine runs
    /// its single-thread work inline on the caller), so `persistent`
    /// falls back to the spawn-free scoped executor rather than parking
    /// a worker that can never receive work — which is why the default
    /// config (`score_threads=1`) costs no extra thread.
    pub fn new(mode: PoolMode, threads: usize) -> Executor {
        match mode {
            PoolMode::Persistent if threads > 1 => {
                Executor::Persistent(ScorePool::new(threads))
            }
            _ => Executor::Scoped { threads: threads.max(1) },
        }
    }

    /// A scoped executor — the zero-setup default for one-shot callers
    /// (batch prediction helpers, tests) that don't hold a pool.
    pub fn scoped(threads: usize) -> Executor {
        Executor::new(PoolMode::Scoped, threads)
    }

    /// Which mode this executor runs in.
    pub fn mode(&self) -> PoolMode {
        match self {
            Executor::Scoped { .. } => PoolMode::Scoped,
            Executor::Persistent(_) => PoolMode::Persistent,
        }
    }

    /// The thread budget parallel sections may request from `run`.
    pub fn threads(&self) -> usize {
        match self {
            Executor::Scoped { threads } => *threads,
            Executor::Persistent(pool) => pool.threads(),
        }
    }

    /// Like [`Executor::run`], but each `job(idx)` produces an output,
    /// returned as a `Vec` in **index order** (slot `i` holds `job(i)`'s
    /// result regardless of which OS thread ran it or when it finished).
    /// This is the entry point for fork-join sections whose workers
    /// produce values to merge — partial histograms, per-scanner split
    /// candidates — where a deterministic merge order is what keeps the
    /// result independent of scheduling. `active` clamps to the thread
    /// budget; job panics propagate after every worker has checked in,
    /// and the executor stays usable afterwards.
    pub fn run_collect<T: Send>(&self, active: usize, job: &(dyn Fn(usize) -> T + Sync)) -> Vec<T> {
        let active = active.min(self.threads());
        if active == 0 {
            return Vec::new();
        }
        // one slot per active index: each worker writes only its own slot,
        // so the mutexes are uncontended and exist purely to move T out
        let slots: Vec<Mutex<Option<T>>> = (0..active).map(|_| Mutex::new(None)).collect();
        self.run(active, &|idx| {
            let out = job(idx);
            *slots[idx].lock().unwrap() = Some(out);
        });
        slots
            .into_iter()
            .map(|s| {
                s.into_inner()
                    .expect("slot mutex cannot be poisoned: no panic can occur while it is held")
                    .expect("run returned, so every active worker filled its slot")
            })
            .collect()
    }

    /// Run `job(idx)` for every `idx < active` (clamped to the thread
    /// budget) and wait for all of them; job panics propagate to the
    /// caller in both modes.
    pub fn run(&self, active: usize, job: &(dyn Fn(usize) + Sync)) {
        match self {
            Executor::Scoped { threads } => {
                let active = active.min(*threads);
                if active == 0 {
                    return;
                }
                std::thread::scope(|s| {
                    let handles: Vec<_> = (0..active).map(|idx| s.spawn(move || job(idx))).collect();
                    for h in handles {
                        if let Err(payload) = h.join() {
                            resume_unwind(payload);
                        }
                    }
                });
            }
            Executor::Persistent(pool) => pool.run(active, job),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    fn both_modes(threads: usize) -> [Executor; 2] {
        [
            Executor::new(PoolMode::Persistent, threads),
            Executor::new(PoolMode::Scoped, threads),
        ]
    }

    #[test]
    fn runs_every_active_index_exactly_once() {
        for exec in both_modes(4) {
            for active in [0usize, 1, 3, 4, 9] {
                let hits: Vec<AtomicUsize> = (0..4).map(|_| AtomicUsize::new(0)).collect();
                exec.run(active, &|idx| {
                    hits[idx].fetch_add(1, Ordering::Relaxed);
                });
                let want = active.min(4);
                for (i, h) in hits.iter().enumerate() {
                    let expect = usize::from(i < want);
                    assert_eq!(
                        h.load(Ordering::Relaxed),
                        expect,
                        "mode {:?} active {active} idx {i}",
                        exec.mode()
                    );
                }
            }
        }
    }

    #[test]
    fn persistent_pool_reused_across_many_trees() {
        // the tentpole's reuse contract: one pool serves the whole run —
        // here 150 "trees" (epochs) of parallel work on the same 3 workers
        let exec = Executor::new(PoolMode::Persistent, 3);
        let total = AtomicUsize::new(0);
        for tree in 0..150 {
            exec.run(3, &|idx| {
                total.fetch_add(tree * 3 + idx, Ordering::Relaxed);
            });
        }
        // sum over trees of (3*tree + 0) + (3*tree + 1) + (3*tree + 2)
        let want: usize = (0..150).map(|t| 9 * t + 3).sum();
        assert_eq!(total.load(Ordering::Relaxed), want);
    }

    #[test]
    fn borrowed_mutable_state_visible_after_run() {
        // run() must not return before every worker finished writing —
        // the scoped-borrow guarantee the scoring engines rely on
        for exec in both_modes(4) {
            let slots: Vec<Mutex<u64>> = (0..4).map(|_| Mutex::new(0)).collect();
            for round in 1..=5u64 {
                exec.run(4, &|idx| {
                    *slots[idx].lock().unwrap() += round;
                });
            }
            for s in &slots {
                assert_eq!(*s.lock().unwrap(), 15, "mode {:?}", exec.mode());
            }
        }
    }

    #[test]
    fn run_collect_returns_outputs_in_index_order() {
        for exec in both_modes(4) {
            for active in [0usize, 1, 3, 4, 9] {
                let got = exec.run_collect(active, &|idx| idx * 10 + 1);
                let want: Vec<usize> = (0..active.min(4)).map(|i| i * 10 + 1).collect();
                assert_eq!(got, want, "mode {:?} active {active}", exec.mode());
            }
        }
    }

    #[test]
    fn run_collect_moves_nontrivial_owned_outputs() {
        // the shape the tree builder uses: each worker returns an owned
        // heap value (a partial histogram stand-in), merged in slot order
        for exec in both_modes(3) {
            let parts = exec.run_collect(3, &|idx| vec![idx as u64; idx + 1]);
            assert_eq!(parts, vec![vec![0], vec![1, 1], vec![2, 2, 2]]);
        }
    }

    #[test]
    fn run_collect_panic_propagates_and_executor_stays_usable() {
        // the output-producing path must give the same panic contract as
        // run(): first payload re-raised, pool reusable afterwards
        for exec in both_modes(3) {
            let r = catch_unwind(AssertUnwindSafe(|| {
                exec.run_collect(3, &|idx| {
                    if idx == 1 {
                        panic!("boom from collecting worker");
                    }
                    idx
                })
            }));
            assert!(r.is_err(), "mode {:?} swallowed the panic", exec.mode());
            let ok = exec.run_collect(3, &|idx| idx + 100);
            assert_eq!(ok, vec![100, 101, 102], "mode {:?}", exec.mode());
        }
    }

    #[test]
    fn job_panic_propagates_and_pool_survives() {
        for exec in both_modes(2) {
            let r = catch_unwind(AssertUnwindSafe(|| {
                exec.run(2, &|idx| {
                    if idx == 1 {
                        panic!("boom from worker");
                    }
                });
            }));
            assert!(r.is_err(), "mode {:?} swallowed the panic", exec.mode());
            // the pool must stay usable after a propagated panic
            let ok = AtomicUsize::new(0);
            exec.run(2, &|_| {
                ok.fetch_add(1, Ordering::Relaxed);
            });
            assert_eq!(ok.load(Ordering::Relaxed), 2, "mode {:?}", exec.mode());
        }
    }

    #[test]
    fn concurrent_run_callers_serialize_safely() {
        // two threads racing run() on a shared pool: dispatches must
        // serialize (Shared::dispatch), each job running to completion
        let pool = ScorePool::new(2);
        let counters: Vec<AtomicUsize> = (0..2).map(|_| AtomicUsize::new(0)).collect();
        std::thread::scope(|s| {
            for c in &counters {
                let pool = &pool;
                s.spawn(move || {
                    for _ in 0..50 {
                        pool.run(2, &|_| {
                            c.fetch_add(1, Ordering::Relaxed);
                        });
                    }
                });
            }
        });
        for c in &counters {
            assert_eq!(c.load(Ordering::Relaxed), 100);
        }
    }

    #[test]
    fn drop_shuts_workers_down() {
        let pool = ScorePool::new(3);
        let shared = pool.shared.clone();
        drop(pool); // joins all workers
        // after drop this is the only Arc left — no worker thread holds one
        assert_eq!(Arc::strong_count(&shared), 1);
        assert!(shared.state.lock().unwrap().shutdown);
    }

    #[test]
    fn zero_and_oversized_thread_counts_clamp() {
        let pool = ScorePool::new(0);
        assert_eq!(pool.threads(), 1);
        let exec = Executor::new(PoolMode::Scoped, 0);
        assert_eq!(exec.threads(), 1);
        // active beyond the budget clamps instead of hanging
        let n = AtomicUsize::new(0);
        exec.run(10, &|_| {
            n.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(n.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn pool_mode_parse_roundtrip() {
        assert_eq!(PoolMode::parse("persistent").unwrap(), PoolMode::Persistent);
        assert_eq!(PoolMode::parse("scoped").unwrap(), PoolMode::Scoped);
        assert!(PoolMode::parse("rayon").is_err());
        for m in [PoolMode::Persistent, PoolMode::Scoped] {
            assert_eq!(PoolMode::parse(m.as_str()).unwrap(), m);
        }
        assert_eq!(PoolMode::default(), PoolMode::Persistent);
    }
}
