//! Deterministic, fast PRNG (xoshiro256** seeded via splitmix64), plus a
//! counter-based stream ([`CounterRng`]) for order-free randomness.
//!
//! Every stochastic component in the crate (sampling, synthetic data,
//! simulators, property tests) draws from these generators so that runs
//! are reproducible from a single `u64` seed — a requirement for the
//! paper's convergence experiments, where curves for different worker
//! counts must share identical datasets and sampling streams.
//!
//! [`Rng`] is a *sequential* stream: the value a draw produces depends on
//! every draw before it, which makes it unusable wherever work is sharded
//! (two shards would need to know how many values the other consumed).
//! [`CounterRng`] is the shard-safe alternative: a stream keyed on
//! `(seed, stream, element)` whose draws are pure functions of the key,
//! so any partition of elements across threads sees exactly the bits a
//! sequential sweep would. The server's fused accept pipeline keys one
//! stream per `(seed, version, row)` (see `sampling/bernoulli.rs`).

/// xoshiro256** by Blackman & Vigna; state seeded with splitmix64.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Create a generator from a seed. Distinct seeds give independent
    /// streams (seeded through splitmix64, never raw).
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        Self {
            s: [
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
            ],
        }
    }

    /// Derive an independent child stream (for per-worker / per-tree RNGs).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0xA24B_AED4_963E_E407))
    }

    /// Export the raw xoshiro256** state — the checkpoint surface.
    ///
    /// A sequential stream's next draw depends on every draw before it,
    /// so resuming a training run bit-identically requires capturing the
    /// exact state words, not the seed: [`Rng::from_state`] of a
    /// captured state continues the stream precisely where the original
    /// instance left off (`io/artifact.rs` stores these four words in
    /// the checkpoint's trainer stanza).
    pub fn state(&self) -> [u64; 4] {
        self.s
    }

    /// Rebuild a generator from a [`Rng::state`] export, continuing the
    /// original stream exactly. The state is used raw (no splitmix64
    /// re-seeding — that would start a different stream).
    pub fn from_state(s: [u64; 4]) -> Rng {
        Rng { s }
    }

    /// Next raw 64 random bits.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [0, 1).
    #[inline]
    pub fn uniform_f32(&mut self) -> f32 {
        self.uniform() as f32
    }

    /// Uniform integer in [0, n). n must be > 0. Uses Lemire's method.
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        let mut x = self.next_u64();
        let mut m = (x as u128).wrapping_mul(n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128).wrapping_mul(n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform usize in [lo, hi).
    #[inline]
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        debug_assert!(hi > lo);
        lo + self.below((hi - lo) as u64) as usize
    }

    /// Bernoulli draw with probability p.
    #[inline]
    pub fn bernoulli(&mut self, p: f64) -> bool {
        self.uniform() < p
    }

    /// Standard normal via Box–Muller (cached second value dropped for
    /// simplicity; this is not a hot path).
    pub fn normal(&mut self) -> f64 {
        let u1 = 1.0 - self.uniform(); // (0, 1]
        let u2 = self.uniform();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Exponential with mean 1.
    pub fn exponential(&mut self) -> f64 {
        -(1.0 - self.uniform()).ln()
    }

    /// Gamma(shape k >= 0.01) via Marsaglia–Tsang (used for heterogeneous
    /// node-speed models in the cluster simulator).
    pub fn gamma(&mut self, k: f64) -> f64 {
        if k < 1.0 {
            // boost: gamma(k) = gamma(k+1) * U^{1/k}
            let g = self.gamma(k + 1.0);
            return g * self.uniform().powf(1.0 / k);
        }
        let d = k - 1.0 / 3.0;
        let c = 1.0 / (9.0 * d).sqrt();
        loop {
            let x = self.normal();
            let v = (1.0 + c * x).powi(3);
            if v <= 0.0 {
                continue;
            }
            let u = self.uniform();
            if u < 1.0 - 0.0331 * x.powi(4)
                || u.ln() < 0.5 * x * x + d * (1.0 - v + v.ln())
            {
                return d * v;
            }
        }
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below((i + 1) as u64) as usize;
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from [0, n) (Floyd's algorithm); output
    /// sorted ascending.
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n, "sample_indices: k={k} > n={n}");
        let mut chosen = std::collections::HashSet::with_capacity(k);
        for j in (n - k)..n {
            let t = self.below((j + 1) as u64) as usize;
            if !chosen.insert(t) {
                chosen.insert(j);
            }
        }
        let mut out: Vec<usize> = chosen.into_iter().collect();
        out.sort_unstable();
        out
    }
}

/// Uniform-bits source shared by the sequential [`Rng`] and the
/// counter-based [`CounterRng`]; the derived draws (uniform, Bernoulli,
/// normal) use identical formulas on both, so a consumer written against
/// this trait (e.g. the Bernoulli sampler's binomial kernel) produces the
/// same value from the same bits regardless of which generator feeds it.
pub trait RandStream {
    /// The next 64 uniform bits of the stream.
    fn next_u64(&mut self) -> u64;

    /// Uniform f64 in [0, 1) — same 53-bit construction as [`Rng::uniform`].
    #[inline]
    fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Bernoulli draw with probability p.
    #[inline]
    fn bernoulli(&mut self, p: f64) -> bool {
        self.uniform() < p
    }

    /// Standard normal via Box–Muller — same formula as [`Rng::normal`].
    fn normal(&mut self) -> f64 {
        let u1 = 1.0 - self.uniform(); // (0, 1]
        let u2 = self.uniform();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }
}

impl RandStream for Rng {
    #[inline]
    fn next_u64(&mut self) -> u64 {
        Rng::next_u64(self)
    }
}

/// Counter-based (stateless-keyed) stream: all draws are pure functions
/// of `(seed, stream, element)` plus the number of values already taken
/// from this instance. Two `CounterRng`s built from the same key yield
/// identical sequences no matter what any other key's stream consumed —
/// the property that makes a row-sharded sampling pass bit-identical to
/// a sequential one for every shard count.
///
/// Internally this is a splitmix64 sequence whose starting state is the
/// key folded through three finalisation rounds; a handful of draws per
/// key (the sampler needs 1–2 for almost every row) is exactly the
/// regime splitmix64 is designed for.
#[derive(Clone, Debug)]
pub struct CounterRng {
    state: u64,
}

impl CounterRng {
    /// Build the stream for one `(seed, stream, element)` key.
    pub fn keyed(seed: u64, stream: u64, element: u64) -> CounterRng {
        let mut s = seed;
        let a = splitmix64(&mut s);
        let mut s = stream ^ a;
        let b = splitmix64(&mut s);
        let mut s = element ^ b.rotate_left(17);
        let state = splitmix64(&mut s);
        CounterRng { state }
    }
}

impl RandStream for CounterRng {
    #[inline]
    fn next_u64(&mut self) -> u64 {
        splitmix64(&mut self.state)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn state_roundtrip_resumes_the_stream_exactly() {
        // the checkpoint/resume contract: capture state mid-stream, keep
        // drawing on the original, and a generator rebuilt from the
        // capture must reproduce every subsequent draw bit for bit
        let mut a = Rng::new(42);
        for _ in 0..37 {
            a.next_u64();
        }
        let snap = a.state();
        let tail: Vec<u64> = (0..64).map(|_| a.next_u64()).collect();
        let mut b = Rng::from_state(snap);
        let resumed: Vec<u64> = (0..64).map(|_| b.next_u64()).collect();
        assert_eq!(tail, resumed);
        // and the non-integer draws ride on the same bits
        let mut c = Rng::from_state(snap);
        for _ in 0..64 {
            c.next_u64();
        }
        assert_eq!(a.uniform(), c.uniform());
    }

    #[test]
    fn distinct_seeds_diverge() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(
            (0..4).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..4).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn uniform_in_unit_interval_and_roughly_uniform() {
        let mut r = Rng::new(3);
        let n = 100_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
            sum += u;
        }
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn below_bounds_and_coverage() {
        let mut r = Rng::new(4);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let v = r.below(10) as usize;
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(5);
        let n = 200_000;
        let (mut s, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let x = r.normal();
            s += x;
            s2 += x * x;
        }
        let mean = s / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.03, "var={var}");
    }

    #[test]
    fn gamma_mean_matches_shape() {
        let mut r = Rng::new(6);
        for &k in &[0.5, 1.0, 4.0] {
            let n = 50_000;
            let mean: f64 = (0..n).map(|_| r.gamma(k)).sum::<f64>() / n as f64;
            assert!((mean - k).abs() < 0.1 * k.max(0.5), "k={k} mean={mean}");
        }
    }

    #[test]
    fn sample_indices_distinct_sorted() {
        let mut r = Rng::new(8);
        let s = r.sample_indices(100, 30);
        assert_eq!(s.len(), 30);
        assert!(s.windows(2).all(|w| w[0] < w[1]));
        assert!(s.iter().all(|&i| i < 100));
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(9);
        let mut v: Vec<u32> = (0..50).collect();
        r.shuffle(&mut v);
        let mut s = v.clone();
        s.sort_unstable();
        assert_eq!(s, (0..50).collect::<Vec<u32>>());
    }

    #[test]
    fn counter_rng_is_a_pure_function_of_its_key() {
        let a: Vec<u64> = {
            let mut r = CounterRng::keyed(7, 3, 41);
            (0..8).map(|_| r.next_u64()).collect()
        };
        // an unrelated stream consuming values must not perturb the key
        let mut noise = CounterRng::keyed(7, 3, 40);
        for _ in 0..1000 {
            noise.next_u64();
        }
        let b: Vec<u64> = {
            let mut r = CounterRng::keyed(7, 3, 41);
            (0..8).map(|_| r.next_u64()).collect()
        };
        assert_eq!(a, b);
    }

    #[test]
    fn counter_rng_keys_decorrelate_every_coordinate() {
        let first = |s, v, e| CounterRng::keyed(s, v, e).next_u64();
        let base = first(1, 2, 3);
        assert_ne!(base, first(2, 2, 3), "seed ignored");
        assert_ne!(base, first(1, 3, 3), "stream ignored");
        assert_ne!(base, first(1, 2, 4), "element ignored");
        // swapping coordinates must not alias streams
        assert_ne!(first(1, 2, 3), first(1, 3, 2));
    }

    #[test]
    fn counter_rng_uniform_is_roughly_uniform_across_elements() {
        // one draw per element, the sampler's access pattern
        let n = 100_000u64;
        let mean: f64 = (0..n)
            .map(|e| CounterRng::keyed(11, 5, e).uniform())
            .sum::<f64>()
            / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn rand_stream_formulas_match_rng_inherent_methods() {
        // the trait defaults must produce the very bits Rng's own methods
        // do, so generic consumers are drop-in for existing call sites
        let mut a = Rng::new(12);
        let mut b = Rng::new(12);
        for _ in 0..50 {
            assert_eq!(a.uniform(), RandStream::uniform(&mut b));
        }
        let mut a = Rng::new(13);
        let mut b = Rng::new(13);
        for _ in 0..20 {
            assert_eq!(a.normal(), RandStream::normal(&mut b));
        }
    }
}
