//! Bounded exponential backoff for idle polling loops.
//!
//! A worker that polls a not-yet-published board with bare
//! `yield_now()` burns a full core doing nothing (and steals cycles
//! from the server thread it is waiting on). [`Backoff`] escalates:
//! a few yield rounds first (so a result that is microseconds away is
//! picked up immediately), then sleeps that double per round up to a
//! hard cap — idle cost drops to near zero while the worst-case extra
//! latency stays bounded by the cap.

use std::thread;
use std::time::Duration;

/// Yield rounds before the first sleep.
const SPIN_ROUNDS: u32 = 4;
/// First sleep duration, doubled each subsequent round.
const BASE_PAUSE_US: u64 = 50;
/// Ceiling on a single pause — also the worst-case extra latency a
/// parked worker pays once the awaited state appears.
const MAX_PAUSE_US: u64 = 2_000;

/// Escalating yield → sleep pauser. `idle()` once per empty poll,
/// `reset()` on every successful poll.
#[derive(Debug, Default, Clone)]
pub struct Backoff {
    round: u32,
}

impl Backoff {
    /// A backoff starting in the yield (spin) phase.
    pub fn new() -> Backoff {
        Backoff::default()
    }

    /// Pause for the current round (yield while spinning, sleep after),
    /// then advance the round.
    pub fn idle(&mut self) {
        match Self::pause_after(self.round) {
            None => thread::yield_now(),
            Some(d) => thread::sleep(d),
        }
        self.round = self.round.saturating_add(1);
    }

    /// Back to the spin phase (call when a poll succeeds).
    pub fn reset(&mut self) {
        self.round = 0;
    }

    /// The pause schedule as a pure function of the round: `None` means
    /// yield, `Some(d)` means sleep for `d`. Split out so the schedule
    /// (growth + cap) is unit-testable without sleeping.
    pub fn pause_after(round: u32) -> Option<Duration> {
        if round < SPIN_ROUNDS {
            return None;
        }
        // clamp the exponent before shifting so the round counter can
        // grow unbounded without overflowing the shift
        let exp = (round - SPIN_ROUNDS).min(62) as u64;
        let us = BASE_PAUSE_US
            .saturating_mul(1u64.checked_shl(exp as u32).unwrap_or(u64::MAX))
            .min(MAX_PAUSE_US);
        Some(Duration::from_micros(us))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn schedule_spins_then_grows_then_caps() {
        for r in 0..SPIN_ROUNDS {
            assert_eq!(Backoff::pause_after(r), None, "round {r} should yield");
        }
        let first = Backoff::pause_after(SPIN_ROUNDS).unwrap();
        assert_eq!(first, Duration::from_micros(BASE_PAUSE_US));
        let second = Backoff::pause_after(SPIN_ROUNDS + 1).unwrap();
        assert_eq!(second, first * 2);
        // monotone non-decreasing and capped, even far past the cap point
        let mut prev = Duration::ZERO;
        for r in SPIN_ROUNDS..SPIN_ROUNDS + 80 {
            let d = Backoff::pause_after(r).unwrap();
            assert!(d >= prev);
            assert!(d <= Duration::from_micros(MAX_PAUSE_US));
            prev = d;
        }
        assert_eq!(prev, Duration::from_micros(MAX_PAUSE_US));
        // no overflow at absurd rounds
        assert_eq!(
            Backoff::pause_after(u32::MAX).unwrap(),
            Duration::from_micros(MAX_PAUSE_US)
        );
    }

    #[test]
    fn reset_returns_to_spin_phase() {
        let mut b = Backoff::new();
        for _ in 0..SPIN_ROUNDS + 3 {
            b.idle();
        }
        assert!(Backoff::pause_after(b.round).is_some());
        b.reset();
        assert_eq!(b.round, 0);
        assert!(Backoff::pause_after(b.round).is_none());
    }
}
