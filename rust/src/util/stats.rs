//! Streaming and batch statistics used by benches, the simulator and the
//! diversity analysis.

/// Summary statistics over a sample of f64 values.
#[derive(Debug, Clone, PartialEq)]
pub struct Summary {
    /// Sample size.
    pub n: usize,
    /// Arithmetic mean.
    pub mean: f64,
    /// Population standard deviation.
    pub std: f64,
    /// Smallest value.
    pub min: f64,
    /// Largest value.
    pub max: f64,
    /// Median (interpolated).
    pub p50: f64,
    /// 90th percentile (interpolated).
    pub p90: f64,
    /// 99th percentile (interpolated).
    pub p99: f64,
}

impl Summary {
    /// Compute a summary; returns a zeroed summary for an empty slice.
    pub fn of(xs: &[f64]) -> Summary {
        if xs.is_empty() {
            return Summary {
                n: 0,
                mean: 0.0,
                std: 0.0,
                min: 0.0,
                max: 0.0,
                p50: 0.0,
                p90: 0.0,
                p99: 0.0,
            };
        }
        let n = xs.len();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>()
            / n.max(1) as f64;
        let mut sorted: Vec<f64> = xs.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        Summary {
            n,
            mean,
            std: var.sqrt(),
            min: sorted[0],
            max: sorted[n - 1],
            p50: percentile_sorted(&sorted, 0.50),
            p90: percentile_sorted(&sorted, 0.90),
            p99: percentile_sorted(&sorted, 0.99),
        }
    }
}

/// Percentile by linear interpolation on a pre-sorted slice.
pub fn percentile_sorted(sorted: &[f64], q: f64) -> f64 {
    assert!(!sorted.is_empty());
    let pos = q.clamp(0.0, 1.0) * (sorted.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        sorted[lo]
    } else {
        let frac = pos - lo as f64;
        sorted[lo] * (1.0 - frac) + sorted[hi] * frac
    }
}

/// Welford online mean/variance accumulator.
#[derive(Debug, Clone, Default)]
pub struct Welford {
    n: u64,
    mean: f64,
    m2: f64,
}

impl Welford {
    /// An empty accumulator.
    pub fn new() -> Self {
        Self::default()
    }

    /// Fold one observation in.
    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
    }

    /// Observations so far.
    pub fn n(&self) -> u64 {
        self.n
    }

    /// Running mean (0 before any observation).
    pub fn mean(&self) -> f64 {
        self.mean
    }

    /// Population variance.
    pub fn variance(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.m2 / self.n as f64
        }
    }

    /// Population standard deviation.
    pub fn std(&self) -> f64 {
        self.variance().sqrt()
    }
}

/// Pearson correlation of two equal-length slices (0 if degenerate).
pub fn pearson(xs: &[f64], ys: &[f64]) -> f64 {
    assert_eq!(xs.len(), ys.len());
    let n = xs.len();
    if n < 2 {
        return 0.0;
    }
    let mx = xs.iter().sum::<f64>() / n as f64;
    let my = ys.iter().sum::<f64>() / n as f64;
    let (mut sxy, mut sxx, mut syy) = (0.0, 0.0, 0.0);
    for i in 0..n {
        let dx = xs[i] - mx;
        let dy = ys[i] - my;
        sxy += dx * dy;
        sxx += dx * dx;
        syy += dy * dy;
    }
    if sxx <= 0.0 || syy <= 0.0 {
        0.0
    } else {
        sxy / (sxx * syy).sqrt()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basics() {
        let s = Summary::of(&[1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(s.n, 5);
        assert!((s.mean - 3.0).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
        assert!((s.p50 - 3.0).abs() < 1e-12);
    }

    #[test]
    fn summary_empty_is_zero() {
        let s = Summary::of(&[]);
        assert_eq!(s.n, 0);
        assert_eq!(s.mean, 0.0);
    }

    #[test]
    fn percentile_interpolates() {
        let v = [0.0, 10.0];
        assert!((percentile_sorted(&v, 0.5) - 5.0).abs() < 1e-12);
        assert_eq!(percentile_sorted(&v, 0.0), 0.0);
        assert_eq!(percentile_sorted(&v, 1.0), 10.0);
    }

    #[test]
    fn welford_matches_batch() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        let mut w = Welford::new();
        for &x in &xs {
            w.push(x);
        }
        assert!((w.mean() - 5.0).abs() < 1e-12);
        assert!((w.variance() - 4.0).abs() < 1e-12);
    }

    #[test]
    fn pearson_perfect_and_anti() {
        let xs = [1.0, 2.0, 3.0];
        assert!((pearson(&xs, &[2.0, 4.0, 6.0]) - 1.0).abs() < 1e-12);
        assert!((pearson(&xs, &[6.0, 4.0, 2.0]) + 1.0).abs() < 1e-12);
        assert_eq!(pearson(&xs, &[5.0, 5.0, 5.0]), 0.0);
    }
}
