//! Wall-clock timing helpers used by trainers, benches and the profiler.

use std::time::{Duration, Instant};

/// A restartable stopwatch accumulating named phase durations.
#[derive(Debug)]
pub struct Stopwatch {
    start: Instant,
    last: Instant,
}

impl Default for Stopwatch {
    fn default() -> Self {
        Self::new()
    }
}

impl Stopwatch {
    /// Start timing now.
    pub fn new() -> Self {
        let now = Instant::now();
        Self { start: now, last: now }
    }

    /// Seconds since construction.
    pub fn elapsed(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }

    /// Seconds since the previous `lap()` (or construction).
    pub fn lap(&mut self) -> f64 {
        let now = Instant::now();
        let d = now.duration_since(self.last).as_secs_f64();
        self.last = now;
        d
    }
}

/// Accumulates time spent per named phase — the lightweight profiler used
/// by trainers to report where worker/server time goes (the measurements
/// that calibrate the cluster simulator and feed Eq. 13).
#[derive(Debug, Default, Clone)]
pub struct PhaseTimer {
    phases: Vec<(String, Duration, u64)>,
}

impl PhaseTimer {
    /// An empty timer.
    pub fn new() -> Self {
        Self::default()
    }

    /// Record one observation of `phase` taking `d`.
    pub fn record(&mut self, phase: &str, d: Duration) {
        if let Some(e) = self.phases.iter_mut().find(|e| e.0 == phase) {
            e.1 += d;
            e.2 += 1;
        } else {
            self.phases.push((phase.to_string(), d, 1));
        }
    }

    /// Time a closure under `phase`.
    pub fn time<T>(&mut self, phase: &str, f: impl FnOnce() -> T) -> T {
        let t0 = Instant::now();
        let out = f();
        self.record(phase, t0.elapsed());
        out
    }

    /// Total seconds in a phase (0 if never recorded).
    pub fn total(&self, phase: &str) -> f64 {
        self.phases
            .iter()
            .find(|e| e.0 == phase)
            .map(|e| e.1.as_secs_f64())
            .unwrap_or(0.0)
    }

    /// Mean seconds per observation of a phase.
    pub fn mean(&self, phase: &str) -> f64 {
        self.phases
            .iter()
            .find(|e| e.0 == phase)
            .map(|e| e.1.as_secs_f64() / e.2.max(1) as f64)
            .unwrap_or(0.0)
    }

    /// Number of observations of a phase.
    pub fn count(&self, phase: &str) -> u64 {
        self.phases.iter().find(|e| e.0 == phase).map(|e| e.2).unwrap_or(0)
    }

    /// Merge another timer's accumulations into this one.
    pub fn merge(&mut self, other: &PhaseTimer) {
        for (name, d, c) in &other.phases {
            if let Some(e) = self.phases.iter_mut().find(|e| &e.0 == name) {
                e.1 += *d;
                e.2 += *c;
            } else {
                self.phases.push((name.clone(), *d, *c));
            }
        }
    }

    /// `(phase, total_secs, count)` rows, insertion-ordered.
    pub fn rows(&self) -> Vec<(String, f64, u64)> {
        self.phases
            .iter()
            .map(|(n, d, c)| (n.clone(), d.as_secs_f64(), *c))
            .collect()
    }

    /// Human-readable one-line-per-phase report.
    pub fn report(&self) -> String {
        let mut s = String::new();
        for (name, d, c) in &self.phases {
            let secs = d.as_secs_f64();
            s.push_str(&format!(
                "{name:<24} total {secs:>9.4}s  n={c:<8} mean {:>9.6}s\n",
                secs / (*c).max(1) as f64
            ));
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stopwatch_monotonic() {
        let mut sw = Stopwatch::new();
        let a = sw.lap();
        let b = sw.lap();
        assert!(a >= 0.0 && b >= 0.0);
        assert!(sw.elapsed() >= a + b - 1e-9);
    }

    #[test]
    fn phase_timer_accumulates_and_merges() {
        let mut t = PhaseTimer::new();
        t.record("x", Duration::from_millis(10));
        t.record("x", Duration::from_millis(20));
        t.record("y", Duration::from_millis(5));
        assert_eq!(t.count("x"), 2);
        assert!((t.total("x") - 0.030).abs() < 1e-9);
        assert!((t.mean("x") - 0.015).abs() < 1e-9);

        let mut u = PhaseTimer::new();
        u.record("x", Duration::from_millis(30));
        u.merge(&t);
        assert_eq!(u.count("x"), 3);
        assert!((u.total("x") - 0.060).abs() < 1e-9);
        assert_eq!(u.count("y"), 1);
    }

    #[test]
    fn time_closure_returns_value() {
        let mut t = PhaseTimer::new();
        let v = t.time("work", || 41 + 1);
        assert_eq!(v, 42);
        assert_eq!(t.count("work"), 1);
    }
}
