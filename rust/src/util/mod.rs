//! Small shared utilities: deterministic RNGs (sequential + counter-based),
//! idle backoff, timing, streaming stats.

pub mod backoff;
pub mod rng;
pub mod stats;
pub mod timer;

pub use backoff::Backoff;
pub use rng::{CounterRng, RandStream, Rng};
pub use stats::Summary;
pub use timer::Stopwatch;
