//! Small shared utilities: deterministic RNGs (sequential + counter-based),
//! idle backoff, the persistent scoring thread pool, deterministic fault
//! injection, timing, streaming stats.

pub mod backoff;
pub mod fault;
pub mod pool;
pub mod rng;
pub mod stats;
pub mod timer;

pub use backoff::Backoff;
pub use fault::{FaultAction, FaultCounts, FaultEvent, FaultKind, FaultPlan, FaultSite, FaultSpec};
pub use pool::{Executor, PoolMode, ScorePool};
pub use rng::{CounterRng, RandStream, Rng};
pub use stats::Summary;
pub use timer::Stopwatch;
