//! Small shared utilities: deterministic RNGs (sequential + counter-based),
//! idle backoff, the persistent scoring thread pool, timing, streaming
//! stats.

pub mod backoff;
pub mod pool;
pub mod rng;
pub mod stats;
pub mod timer;

pub use backoff::Backoff;
pub use pool::{Executor, PoolMode, ScorePool};
pub use rng::{CounterRng, RandStream, Rng};
pub use stats::Summary;
pub use timer::Stopwatch;
