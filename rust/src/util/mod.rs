//! Small shared utilities: deterministic RNG, timing, streaming stats.

pub mod rng;
pub mod stats;
pub mod timer;

pub use rng::Rng;
pub use stats::Summary;
pub use timer::Stopwatch;
