//! Low-latency batched prediction serving with versioned model
//! hot-swap (DESIGN.md §15).
//!
//! The training side of this codebase ends at a saved forest; the
//! paper's "millions of users" north star needs the other half —
//! scoring raw feature vectors as they arrive. This subsystem is that
//! half, built from parts the trainer already has: requests coalesce
//! into micro-batches ([`queue`]), get quantized at request time on the
//! training-derived cuts ([`crate::data::BinCuts`]), and are scored by
//! the blocked [`crate::forest::FlatForest`] engine on a
//! server-lifetime [`crate::util::Executor`] ([`service`]). Models
//! hot-swap mid-traffic through [`swap`] — the serving twin of the
//! parameter server's `Board`, with the same monotone-version
//! `RwLock<Arc<_>>` publication contract — so every response is tagged
//! with the forest version that scored it, in-flight batches finish on
//! the old model, and no batch ever mixes two versions.
//!
//! Knobs: `serve_batch` (rows per micro-batch), `serve_max_wait_us`
//! (coalescing wait), `serve_threads` (scoring width), `serve_model`
//! (forest to load) — see `config::validate` for the rejected
//! combinations and DESIGN.md §15 for the decision table. Entry point:
//! `asgbdt serve`; measurements: `bench_serve_latency` and the
//! `microbatch/*` group of `bench_predict`.

pub mod queue;
pub mod service;
pub mod swap;

pub use queue::{Pending, RequestQueue, ServeRequest, ServeResponse};
pub use service::{drive_replay, ReplayOutcome, ServeOptions, Service, ServiceStats};
pub use swap::{ModelSlot, ServingModel};
