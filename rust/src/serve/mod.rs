//! Low-latency batched prediction serving with versioned model
//! hot-swap (DESIGN.md §15).
//!
//! The training side of this codebase ends at a saved forest; the
//! paper's "millions of users" north star needs the other half —
//! scoring raw feature vectors as they arrive. This subsystem is that
//! half, built from parts the trainer already has: requests coalesce
//! into micro-batches ([`queue`]), get quantized at request time on the
//! training-derived cuts ([`crate::data::BinCuts`]), and are scored by
//! the blocked [`crate::forest::FlatForest`] engine on a
//! server-lifetime [`crate::util::Executor`] ([`service`]). Models
//! hot-swap mid-traffic through [`swap`] — the serving twin of the
//! parameter server's `Board`, with the same monotone-version
//! `RwLock<Arc<_>>` publication contract — so every response is tagged
//! with the forest version that scored it, in-flight batches finish on
//! the old model, and no batch ever mixes two versions.
//!
//! Knobs: `serve_batch` (rows per micro-batch), `serve_max_wait_us`
//! (coalescing wait), `serve_threads` (scoring width), `serve_model`
//! (forest to load) — see `config::validate` for the rejected
//! combinations and DESIGN.md §15 for the decision table. Entry point:
//! `asgbdt serve`; measurements: `bench_serve_latency` and the
//! `microbatch/*` group of `bench_predict`.

pub mod queue;
pub mod service;
pub mod swap;

pub use queue::{Pending, RequestQueue, ServeRequest, ServeResponse};
pub use service::{drive_replay, ReplayOutcome, ServeOptions, Service, ServiceStats};
pub use swap::{ModelSlot, ServingModel};

use anyhow::{bail, Context, Result};

use crate::loss::LossKind;

/// Gate a model's manifest loss name before it reaches a scalar scoring
/// surface (`serve`, `predict`): any known scalar loss passes; a
/// `multiclass` manifest (whose forest holds one tree per class per
/// round, meaningless as a single margin) and an unknown name are both
/// refused by name. `surface` prefixes the error so the caller's
/// command is visible in it.
pub fn require_scalar_loss(loss: &str, surface: &str) -> Result<LossKind> {
    let kind = LossKind::parse(loss)
        .with_context(|| format!("{surface}: model manifest names a loss this build cannot score"))?;
    if kind == LossKind::Multiclass {
        bail!(
            "{surface}: model was trained with loss=multiclass — its forest holds one tree \
             per class per round and the scalar margin path cannot score it"
        );
    }
    Ok(kind)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scalar_losses_pass_the_serving_gate() {
        for name in ["logistic", "squared", "huber"] {
            assert_eq!(require_scalar_loss(name, "serve").unwrap().as_str(), name);
        }
    }

    #[test]
    fn multiclass_and_unknown_losses_are_refused_by_name() {
        let err = format!("{:#}", require_scalar_loss("multiclass", "serve").unwrap_err());
        assert!(err.contains("serve") && err.contains("loss=multiclass"), "{err}");
        let err = format!("{:#}", require_scalar_loss("hinge", "predict").unwrap_err());
        assert!(err.contains("predict") && err.contains("hinge"), "{err}");
    }
}
