//! The serving loop: queue → micro-batch → bin → score → respond.
//!
//! [`Service::start`] spawns one batcher thread that owns the hot
//! path's long-lived state — a server-lifetime [`Executor`]
//! (`serve_threads` workers stay parked between batches under
//! `pool=persistent`), a [`ScratchPool`], a reusable binned-batch
//! scratch and a reusable margin buffer — so the steady state does no
//! thread spawning and no per-batch allocation. Per micro-batch the
//! loop: drains up to `serve_batch` requests ([`RequestQueue`]),
//! snapshots the current model *once* ([`ModelSlot::load`] — the swap
//! point; a publish lands between batches, never inside one), rebins
//! the raw rows on that model's cuts ([`BinCuts::fill_batch`]), scores
//! them blocked ([`FlatForest::predict_binned_into`]) and replies with
//! the margin tagged by the version that scored it.
//!
//! [`drive_replay`] is the closed-loop driver shared by `asgbdt serve`,
//! `bench_serve_latency` and the hot-swap tests: it replays matrix rows
//! as requests with a bounded in-flight window and records per-request
//! latency, version tag and margin.

use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use crate::config::TrainConfig;
use crate::data::{BinCuts, CsrMatrix};
use crate::forest::{FlatForest, ScratchPool};
use crate::util::{Executor, PoolMode};

use super::queue::{Pending, RequestQueue, ServeRequest, ServeResponse};
use super::swap::ModelSlot;

/// The serving knobs, lifted out of [`TrainConfig`] (see the knob table
/// in DESIGN.md §15).
#[derive(Debug, Clone, Copy)]
pub struct ServeOptions {
    /// Micro-batch size: rows coalesced per scoring call (`serve_batch`).
    pub batch: usize,
    /// How long a non-full batch waits for late arrivals
    /// (`serve_max_wait_us`).
    pub max_wait: Duration,
    /// Scoring executor width (`serve_threads`).
    pub threads: usize,
    /// Executor flavour for the scoring threads (`pool`).
    pub pool: PoolMode,
}

impl ServeOptions {
    /// Lift the serve knobs from a validated config.
    pub fn from_config(cfg: &TrainConfig) -> ServeOptions {
        ServeOptions {
            batch: cfg.serve_batch,
            max_wait: Duration::from_micros(cfg.serve_max_wait_us),
            threads: cfg.serve_threads,
            pool: cfg.pool,
        }
    }
}

/// Lifetime counters the batcher thread reports at shutdown.
#[derive(Debug, Clone, Copy, Default)]
pub struct ServiceStats {
    /// Requests scored and replied to.
    pub requests: u64,
    /// Micro-batches scored.
    pub batches: u64,
    /// Largest micro-batch actually coalesced.
    pub max_batch: usize,
    /// Model swaps observed by the batcher (publishes that landed while
    /// traffic was flowing).
    pub swaps_seen: u64,
}

/// A running prediction service: the queue handle plus the batcher
/// thread. Dropping it (or calling [`Service::shutdown`]) closes the
/// queue, drains what was already submitted and joins the thread.
#[derive(Debug)]
pub struct Service {
    queue: Arc<RequestQueue>,
    slot: Arc<ModelSlot>,
    batcher: Option<JoinHandle<ServiceStats>>,
}

impl Service {
    /// Spawn the batcher thread serving models published to `slot`.
    pub fn start(slot: Arc<ModelSlot>, opts: ServeOptions) -> Service {
        let queue = Arc::new(RequestQueue::new());
        let batcher = {
            let queue = Arc::clone(&queue);
            let slot = Arc::clone(&slot);
            std::thread::Builder::new()
                .name("serve-batcher".into())
                .spawn(move || batcher_loop(&queue, &slot, opts))
                .expect("spawn serve batcher")
        };
        Service {
            queue,
            slot,
            batcher: Some(batcher),
        }
    }

    /// The model slot this service scores from — publish here to
    /// hot-swap mid-traffic.
    pub fn slot(&self) -> &Arc<ModelSlot> {
        &self.slot
    }

    /// Submit one raw request; the scored [`ServeResponse`] arrives on
    /// `reply`. Validates the feature vector up front (strictly
    /// increasing ids, finite values) so the batcher never sees a
    /// malformed row; ids beyond the current model's width are legal and
    /// ignored at binning time (the width may change across a swap).
    pub fn submit(
        &self,
        id: u64,
        features: Vec<(u32, f32)>,
        reply: &Sender<ServeResponse>,
    ) -> Result<()> {
        for (i, &(c, v)) in features.iter().enumerate() {
            if i > 0 && c <= features[i - 1].0 {
                bail!(
                    "request {id}: feature ids must be strictly increasing (id {c} after {})",
                    features[i - 1].0
                );
            }
            if !v.is_finite() {
                bail!("request {id}: non-finite value {v} for feature {c}");
            }
        }
        self.queue.push(Pending {
            request: ServeRequest { id, features },
            reply: reply.clone(),
        })
    }

    /// Requests queued but not yet scored.
    pub fn queue_len(&self) -> usize {
        self.queue.len()
    }

    /// Stop accepting requests, drain and score everything already
    /// queued, join the batcher and return its lifetime counters.
    pub fn shutdown(mut self) -> ServiceStats {
        self.queue.close();
        let batcher = self.batcher.take().expect("batcher joined once");
        batcher.join().expect("serve batcher panicked")
    }
}

impl Drop for Service {
    fn drop(&mut self) {
        // shutdown() takes the handle; this covers early drops (tests,
        // error paths) so the batcher never outlives its owner
        self.queue.close();
        if let Some(h) = self.batcher.take() {
            let _ = h.join();
        }
    }
}

fn batcher_loop(queue: &RequestQueue, slot: &ModelSlot, opts: ServeOptions) -> ServiceStats {
    let exec = Executor::new(opts.pool, opts.threads);
    let mut scratch_pool = ScratchPool::new();
    let mut stats = ServiceStats::default();
    let mut model = slot.load();
    let mut batch = model.cuts.empty_batch();
    let mut margins: Vec<f32> = Vec::new();
    let mut pending: Vec<Pending> = Vec::new();
    loop {
        pending.clear();
        if !queue.pop_batch(opts.batch, opts.max_wait, &mut pending) {
            break;
        }
        // the swap point: snapshot the model once per micro-batch, so
        // every row of this batch — bins and trees both — comes from
        // exactly one version, and a concurrent publish takes effect at
        // the next batch boundary
        let cur = slot.load();
        if cur.version() != model.version() {
            batch = cur.cuts.empty_batch();
            stats.swaps_seen += 1;
            model = cur;
        }
        let mut rows: Vec<&[(u32, f32)]> = Vec::with_capacity(pending.len());
        for p in &pending {
            rows.push(p.request.features.as_slice());
        }
        model
            .cuts
            .fill_batch(&rows, &mut batch)
            .expect("submit validated every feature vector");
        model
            .forest
            .predict_binned_into(&batch, &mut margins, &exec, &mut scratch_pool);
        for (p, &margin) in pending.iter().zip(margins.iter()) {
            // a dropped receiver means the caller abandoned the request
            let _ = p.reply.send(ServeResponse {
                id: p.request.id,
                margin,
                model_version: model.version(),
            });
        }
        stats.requests += pending.len() as u64;
        stats.batches += 1;
        stats.max_batch = stats.max_batch.max(pending.len());
    }
    stats
}

/// What [`drive_replay`] measured, indexed by request id (request `i`
/// replays source row `i % n_rows`).
#[derive(Debug, Clone)]
pub struct ReplayOutcome {
    /// Wall-clock seconds for the whole replay (throughput basis).
    pub wall_secs: f64,
    /// Submit-to-response latency per request, seconds.
    pub latency_secs: Vec<f64>,
    /// Version tag each response carried.
    pub version_of: Vec<u64>,
    /// Margin each response carried.
    pub margin_of: Vec<f32>,
}

/// Replay `n_requests` rows of `source` (round-robin) through a running
/// service, closed-loop: at most `inflight` requests are outstanding at
/// once, and each response admits the next submit. `swap` = `Some((at,
/// forest, cuts))` publishes the new model to the service's slot
/// immediately before request `at` is submitted (no-op if `at >=
/// n_requests`) — the mid-stream hot-swap the version-tag tests and the
/// CI smoke exercise. Used by `asgbdt serve`, `bench_serve_latency` and
/// `tests/test_serve.rs` so they all measure the same loop.
pub fn drive_replay(
    service: &Service,
    source: &CsrMatrix,
    n_requests: usize,
    inflight: usize,
    swap: Option<(usize, FlatForest, BinCuts)>,
) -> Result<ReplayOutcome> {
    let inflight = inflight.max(1);
    let (tx, rx): (Sender<ServeResponse>, Receiver<ServeResponse>) = channel();
    let t0 = Instant::now();
    let mut submitted_at = vec![t0; n_requests];
    let mut out = ReplayOutcome {
        wall_secs: 0.0,
        latency_secs: vec![0.0; n_requests],
        version_of: vec![0; n_requests],
        margin_of: vec![0.0; n_requests],
    };
    let mut swap = swap;
    let mut next = 0usize;
    let mut done = 0usize;
    let mut outstanding = 0usize;
    while done < n_requests {
        while outstanding < inflight && next < n_requests {
            if swap.as_ref().is_some_and(|(at, _, _)| *at == next) {
                let (_, forest, cuts) = swap.take().expect("checked above");
                service.slot().publish(forest, cuts);
            }
            let features: Vec<(u32, f32)> = source.row(next % source.n_rows()).collect();
            submitted_at[next] = Instant::now();
            service.submit(next as u64, features, &tx)?;
            outstanding += 1;
            next += 1;
        }
        let resp = rx.recv().context("serve batcher dropped its replies")?;
        let id = resp.id as usize;
        out.latency_secs[id] = submitted_at[id].elapsed().as_secs_f64();
        out.version_of[id] = resp.model_version;
        out.margin_of[id] = resp.margin;
        outstanding -= 1;
        done += 1;
    }
    out.wall_secs = t0.elapsed().as_secs_f64();
    Ok(out)
}
