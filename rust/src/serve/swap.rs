//! Versioned model hot-swap: the serving twin of `ps::server::Board`.
//!
//! A [`ModelSlot`] holds the current [`ServingModel`] behind
//! `RwLock<Arc<_>>` — the same publication idiom the parameter server's
//! `Board` uses for target snapshots, and the same contract: versions
//! are monotone, a publish is an `Arc` pointer exchange under a
//! microseconds-long write lock, and readers clone the `Arc` out so the
//! snapshot they scored against can never be torn or freed under them.
//! The serving hot path takes the lock exactly once per *micro-batch*
//! (not per request, and never while scoring), so a swap lands between
//! batches: in-flight batches finish on the old model, every response
//! is tagged with the version that actually scored it, and no batch
//! ever mixes trees from two versions.

use std::sync::{Arc, RwLock};

use crate::data::BinCuts;
use crate::forest::FlatForest;

/// One immutable published model: a compiled forest, the training cuts
/// raw requests must be binned with, and the monotone version stamped
/// into every response it scores.
#[derive(Debug)]
pub struct ServingModel {
    version: u64,
    /// The compiled forest that scores micro-batches.
    pub forest: FlatForest,
    /// The training-derived cuts that quantize raw request rows.
    pub cuts: BinCuts,
}

impl ServingModel {
    /// The monotone version tag (1 for the model a slot starts with,
    /// incremented by one per [`ModelSlot::publish`]).
    pub fn version(&self) -> u64 {
        self.version
    }
}

/// The swap point: current model behind `RwLock<Arc<_>>`.
///
/// `load` is a read-lock + `Arc` clone; `publish` is a write-lock +
/// pointer exchange. Neither ever blocks on scoring, because scoring
/// happens entirely outside the lock on a cloned `Arc`.
#[derive(Debug)]
pub struct ModelSlot {
    current: RwLock<Arc<ServingModel>>,
}

impl ModelSlot {
    /// Install the initial model as version 1.
    pub fn new(forest: FlatForest, cuts: BinCuts) -> ModelSlot {
        ModelSlot {
            current: RwLock::new(Arc::new(ServingModel {
                version: 1,
                forest,
                cuts,
            })),
        }
    }

    /// Current model (cheap: read lock + `Arc` clone). The caller keeps
    /// scoring on this snapshot even if a publish lands concurrently.
    pub fn load(&self) -> Arc<ServingModel> {
        Arc::clone(&self.current.read().unwrap())
    }

    /// Current version without keeping the snapshot. Derived from the
    /// snapshot itself (no side-channel counter), so it can never tear
    /// against `load` — same reasoning as `Board::version`.
    pub fn version(&self) -> u64 {
        self.current.read().unwrap().version
    }

    /// Publish a new model, returning its version (`old + 1` — the
    /// increment happens under the write lock, so versions are monotone
    /// by construction even under concurrent publishers).
    pub fn publish(&self, forest: FlatForest, cuts: BinCuts) -> u64 {
        let mut cur = self.current.write().unwrap();
        let version = cur.version + 1;
        *cur = Arc::new(ServingModel {
            version,
            forest,
            cuts,
        });
        version
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{BinnedDataset, CsrMatrix};
    use crate::forest::Forest;

    fn fixture() -> (FlatForest, BinCuts) {
        let x = CsrMatrix::from_dense(4, 2, &[1.0, 0.0, 2.0, 3.0, 0.0, 4.0, 5.0, 6.0]).unwrap();
        let b = BinnedDataset::from_csr(&x, 8).unwrap();
        (FlatForest::from_forest(&Forest::new(0.5)), b.cuts())
    }

    #[test]
    fn versions_are_monotone_and_snapshots_stable() {
        let (flat, cuts) = fixture();
        let slot = ModelSlot::new(flat.clone(), cuts.clone());
        assert_eq!(slot.version(), 1);
        let held = slot.load();
        assert_eq!(held.version(), 1);
        assert_eq!(slot.publish(flat.clone(), cuts.clone()), 2);
        assert_eq!(slot.publish(flat, cuts), 3);
        assert_eq!(slot.version(), 3);
        // the snapshot loaded before the publishes is untouched
        assert_eq!(held.version(), 1);
        assert_eq!(slot.load().version(), 3);
    }

    #[test]
    fn concurrent_publishers_never_skip_or_repeat_a_version() {
        let (flat, cuts) = fixture();
        let slot = std::sync::Arc::new(ModelSlot::new(flat.clone(), cuts.clone()));
        let mut seen: Vec<u64> = std::thread::scope(|s| {
            let handles: Vec<_> = (0..4)
                .map(|_| {
                    let slot = std::sync::Arc::clone(&slot);
                    let (f, c) = (flat.clone(), cuts.clone());
                    s.spawn(move || {
                        (0..8)
                            .map(|_| slot.publish(f.clone(), c.clone()))
                            .collect::<Vec<u64>>()
                    })
                })
                .collect();
            let mut versions = Vec::new();
            for h in handles {
                versions.extend(h.join().unwrap());
            }
            versions
        });
        seen.sort_unstable();
        // 32 publishes on top of version 1: exactly 2..=33, no gaps, no dups
        assert_eq!(seen, (2..=33).collect::<Vec<u64>>());
        assert_eq!(slot.version(), 33);
    }
}
