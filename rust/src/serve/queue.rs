//! The coalescing request queue: many producers submit raw feature
//! vectors, one batcher thread drains them in micro-batches.
//!
//! [`RequestQueue::pop_batch`] implements the two serving knobs: it
//! blocks until at least one request exists, then keeps waiting — up to
//! `serve_max_wait_us` — for the batch to fill to `serve_batch` rows
//! before draining, trading a bounded per-request wait for the much
//! better per-row cost of blocked batch scoring (measured by the
//! `microbatch/*` group of `bench_predict`). Closing the queue wakes
//! everything: producers start failing fast, the consumer drains what
//! is left (no request submitted before `close` is ever dropped) and
//! then sees end-of-stream.

use std::collections::VecDeque;
use std::sync::mpsc::Sender;
use std::sync::{Condvar, Mutex};
use std::time::{Duration, Instant};

use anyhow::{bail, Result};

/// One raw scoring request: caller-chosen id echoed in the response,
/// plus the sparse feature vector (strictly increasing ids, finite
/// values — validated at submit time by `Service::submit`).
#[derive(Debug, Clone)]
pub struct ServeRequest {
    /// Caller-chosen correlation id, echoed verbatim in the response.
    pub id: u64,
    /// Sparse raw features as `(feature id, value)` pairs.
    pub features: Vec<(u32, f32)>,
}

/// One scored response: the margin and the version of the forest that
/// produced it (every row of a micro-batch carries the same version —
/// the swap protocol's no-mixed-batch guarantee, `swap.rs`).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ServeResponse {
    /// The request's correlation id.
    pub id: u64,
    /// Raw margin F(x) of the forest that scored this request.
    pub margin: f32,
    /// Version of the [`super::ServingModel`] that scored this request.
    pub model_version: u64,
}

/// A queued request plus the channel its response goes back on.
#[derive(Debug)]
pub struct Pending {
    /// The request as submitted.
    pub request: ServeRequest,
    /// Where the scored response is sent (send errors are ignored — a
    /// caller that dropped its receiver has abandoned the request).
    pub reply: Sender<ServeResponse>,
}

#[derive(Debug, Default)]
struct QueueState {
    pending: VecDeque<Pending>,
    closed: bool,
}

/// The MPSC coalescing queue between submitters and the batcher thread.
#[derive(Debug, Default)]
pub struct RequestQueue {
    state: Mutex<QueueState>,
    arrived: Condvar,
}

impl RequestQueue {
    /// An open, empty queue.
    pub fn new() -> RequestQueue {
        RequestQueue::default()
    }

    /// Enqueue one request (FIFO). Fails once the queue is closed.
    pub fn push(&self, pending: Pending) -> Result<()> {
        let mut st = self.state.lock().unwrap();
        if st.closed {
            bail!("serve queue is closed");
        }
        st.pending.push_back(pending);
        drop(st);
        self.arrived.notify_all();
        Ok(())
    }

    /// Requests currently waiting.
    pub fn len(&self) -> usize {
        self.state.lock().unwrap().pending.len()
    }

    /// Whether nothing is waiting.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Close the queue: subsequent pushes fail, and once the remaining
    /// requests are drained `pop_batch` reports end-of-stream.
    /// Idempotent.
    pub fn close(&self) {
        self.state.lock().unwrap().closed = true;
        self.arrived.notify_all();
    }

    /// Drain the next micro-batch (FIFO order) into `out`: block until
    /// at least one request is queued, then wait up to `max_wait` for
    /// the batch to fill to `max` rows (a closed queue or a full batch
    /// cuts the wait short). Returns `false` — with `out` untouched —
    /// only at end-of-stream: closed and fully drained. Spurious
    /// condvar wakeups just re-run the checks.
    pub fn pop_batch(&self, max: usize, max_wait: Duration, out: &mut Vec<Pending>) -> bool {
        let max = max.max(1);
        let mut st = self.state.lock().unwrap();
        loop {
            if !st.pending.is_empty() {
                break;
            }
            if st.closed {
                return false;
            }
            st = self.arrived.wait(st).unwrap();
        }
        if max > 1 && !max_wait.is_zero() {
            let deadline = Instant::now() + max_wait;
            while st.pending.len() < max && !st.closed {
                let now = Instant::now();
                if now >= deadline {
                    break;
                }
                st = self.arrived.wait_timeout(st, deadline - now).unwrap().0;
            }
        }
        for _ in 0..max.min(st.pending.len()) {
            out.push(st.pending.pop_front().unwrap());
        }
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc::channel;

    fn pending(id: u64) -> (Pending, std::sync::mpsc::Receiver<ServeResponse>) {
        let (tx, rx) = channel();
        let request = ServeRequest {
            id,
            features: vec![(0, 1.0)],
        };
        (Pending { request, reply: tx }, rx)
    }

    #[test]
    fn pops_in_fifo_order_capped_at_max() {
        let q = RequestQueue::new();
        let mut rxs = Vec::new();
        for id in 0..5 {
            let (p, rx) = pending(id);
            q.push(p).unwrap();
            rxs.push(rx);
        }
        assert_eq!(q.len(), 5);
        let mut out = Vec::new();
        assert!(q.pop_batch(3, Duration::ZERO, &mut out));
        assert_eq!(
            out.iter().map(|p| p.request.id).collect::<Vec<u64>>(),
            vec![0, 1, 2]
        );
        out.clear();
        assert!(q.pop_batch(3, Duration::ZERO, &mut out));
        assert_eq!(
            out.iter().map(|p| p.request.id).collect::<Vec<u64>>(),
            vec![3, 4]
        );
        assert!(q.is_empty());
    }

    #[test]
    fn close_fails_pushes_and_drains_then_ends_stream() {
        let q = RequestQueue::new();
        q.push(pending(7).0).unwrap();
        q.close();
        q.close(); // idempotent
        assert!(q.push(pending(8).0).is_err());
        let mut out = Vec::new();
        assert!(q.pop_batch(16, Duration::from_millis(50), &mut out));
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].request.id, 7);
        out.clear();
        assert!(!q.pop_batch(16, Duration::from_millis(50), &mut out));
        assert!(out.is_empty());
    }

    #[test]
    fn pop_waits_for_late_arrivals_up_to_the_batch_size() {
        let q = std::sync::Arc::new(RequestQueue::new());
        let producer = {
            let q = std::sync::Arc::clone(&q);
            std::thread::spawn(move || {
                for id in 0..4 {
                    q.push(pending(id).0).unwrap();
                    std::thread::sleep(Duration::from_millis(2));
                }
            })
        };
        // generous wait: the consumer should coalesce all 4 even though
        // they arrive spread out
        let mut out = Vec::new();
        assert!(q.pop_batch(4, Duration::from_secs(2), &mut out));
        assert_eq!(out.len(), 4);
        producer.join().unwrap();
    }

    #[test]
    fn zero_wait_serves_singles_immediately() {
        let q = RequestQueue::new();
        q.push(pending(1).0).unwrap();
        q.push(pending(2).0).unwrap();
        let mut out = Vec::new();
        // max=1: no coalescing wait even with a wait budget
        assert!(q.pop_batch(1, Duration::from_secs(1), &mut out));
        assert_eq!(out.len(), 1);
    }
}
