//! Artifact manifest: what `python/compile/aot.py` emitted and how the
//! runtime picks a batch-size bucket per request.

use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Result};

use crate::io::Json;

/// One emitted HLO artifact.
#[derive(Debug, Clone)]
pub struct Entry {
    /// Model function name ("grad_hess", "eval").
    pub name: String,
    /// Padded vector length this module was lowered for.
    pub n: usize,
    /// File name relative to the artifact dir.
    pub file: String,
}

/// Parsed `artifacts/manifest.json`.
#[derive(Debug, Clone)]
pub struct Manifest {
    /// Directory the manifest (and artifacts) live in.
    pub dir: PathBuf,
    /// Padded row-count buckets, ascending.
    pub buckets: Vec<usize>,
    /// Pallas block size the kernels were lowered with.
    pub block: usize,
    /// One entry per compiled (function, bucket) artifact.
    pub entries: Vec<Entry>,
}

impl Manifest {
    /// Load `<dir>/manifest.json`.
    pub fn load(dir: &Path) -> Result<Manifest> {
        let j = Json::parse_file(&dir.join("manifest.json"))?;
        // same format-tag guard as the .sgbdt model manifest (io/artifact.rs)
        j.expect_str("format", "hlo-text")?;
        let buckets: Vec<usize> = j
            .req("buckets")?
            .as_arr()
            .ok_or_else(|| anyhow!("buckets must be an array"))?
            .iter()
            .map(|b| b.as_usize().ok_or_else(|| anyhow!("bad bucket")))
            .collect::<Result<_>>()?;
        if buckets.is_empty() {
            bail!("no buckets in manifest");
        }
        if !buckets.windows(2).all(|w| w[0] < w[1]) {
            bail!("buckets must be strictly increasing");
        }
        let block = j.req_usize("block")?;
        let mut entries = Vec::new();
        for e in j
            .req("entries")?
            .as_arr()
            .ok_or_else(|| anyhow!("entries must be an array"))?
        {
            entries.push(Entry {
                name: e.req_str("name")?.to_string(),
                n: e.req_usize("n")?,
                file: e.req_str("file")?.to_string(),
            });
        }
        Ok(Manifest {
            dir: dir.to_path_buf(),
            buckets,
            block,
            entries,
        })
    }

    /// True if a manifest exists under `dir`.
    pub fn exists(dir: &Path) -> bool {
        dir.join("manifest.json").is_file()
    }

    /// Smallest bucket >= n, or the largest bucket if n exceeds all
    /// (callers then chunk by that bucket).
    pub fn bucket_for(&self, n: usize) -> usize {
        for &b in &self.buckets {
            if b >= n {
                return b;
            }
        }
        *self.buckets.last().unwrap()
    }

    /// The biggest padded row count any artifact covers.
    pub fn largest_bucket(&self) -> usize {
        *self.buckets.last().unwrap()
    }

    /// Path of the artifact for (name, bucket).
    pub fn path_for(&self, name: &str, bucket: usize) -> Result<PathBuf> {
        self.entries
            .iter()
            .find(|e| e.name == name && e.n == bucket)
            .map(|e| self.dir.join(&e.file))
            .ok_or_else(|| anyhow!("no artifact for {name}@{bucket} in manifest"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn write_manifest(dir: &Path, body: &str) {
        std::fs::create_dir_all(dir).unwrap();
        std::fs::write(dir.join("manifest.json"), body).unwrap();
    }

    fn tmpdir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("asgbdt_manifest_{tag}"));
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    const GOOD: &str = r#"{"format":"hlo-text","version":1,"buckets":[1024,4096],"block":1024,
        "entries":[{"name":"grad_hess","n":1024,"file":"grad_hess_1024.hlo.txt"},
                   {"name":"grad_hess","n":4096,"file":"grad_hess_4096.hlo.txt"}]}"#;

    #[test]
    fn loads_and_selects_buckets() {
        let d = tmpdir("good");
        write_manifest(&d, GOOD);
        let m = Manifest::load(&d).unwrap();
        assert_eq!(m.buckets, vec![1024, 4096]);
        assert_eq!(m.bucket_for(1), 1024);
        assert_eq!(m.bucket_for(1024), 1024);
        assert_eq!(m.bucket_for(1025), 4096);
        assert_eq!(m.bucket_for(100_000), 4096); // chunking case
        assert!(m.path_for("grad_hess", 4096).unwrap().ends_with("grad_hess_4096.hlo.txt"));
        assert!(m.path_for("eval", 1024).is_err());
        std::fs::remove_dir_all(&d).ok();
    }

    #[test]
    fn rejects_bad_format() {
        let d = tmpdir("badfmt");
        write_manifest(&d, r#"{"format":"protobuf","buckets":[1],"block":1,"entries":[]}"#);
        assert!(Manifest::load(&d).is_err());
        std::fs::remove_dir_all(&d).ok();
    }

    #[test]
    fn rejects_unsorted_buckets() {
        let d = tmpdir("unsorted");
        write_manifest(
            &d,
            r#"{"format":"hlo-text","buckets":[4096,1024],"block":1024,"entries":[]}"#,
        );
        assert!(Manifest::load(&d).is_err());
        std::fs::remove_dir_all(&d).ok();
    }

    #[test]
    fn exists_probe() {
        let d = tmpdir("exists");
        assert!(!Manifest::exists(&d.join("nope")));
        write_manifest(&d, GOOD);
        assert!(Manifest::exists(&d));
        std::fs::remove_dir_all(&d).ok();
    }
}
