//! The AOT runtime bridge: load the HLO-text artifacts emitted by
//! `python/compile/aot.py` and execute them on the PJRT CPU client from
//! the server's produce-target hot path.
//!
//! Interchange is HLO *text* (`HloModuleProto::from_text_file`), not
//! serialized protos — jax ≥ 0.5 emits 64-bit instruction ids that
//! xla_extension 0.5.1 rejects; the text parser reassigns ids (see
//! DESIGN.md and /opt/xla-example/README.md).
//!
//! `GradientEngine` is the public entry: `Aot` when artifacts are present,
//! `Native` (pure-Rust, [`crate::loss::logistic`]) otherwise, so the whole
//! test suite runs with or without `make artifacts`. The two paths are
//! cross-checked to 1e-4 by `rust/tests/test_runtime.rs`.
//!
//! PJRT handles are not `Send`: one engine is owned by one thread (the PS
//! server thread in the trainers), which is exactly the paper's topology —
//! the server produces targets, workers only build trees.

pub mod artifacts;
pub mod engine;

pub use artifacts::Manifest;
pub use engine::{EngineKind, GradientEngine};
