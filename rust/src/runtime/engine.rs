//! The gradient engine: AOT (PJRT-executed HLO artifacts) with a pure-Rust
//! fallback, behind one API.

#[cfg(feature = "aot")]
use std::collections::HashMap;
use std::path::Path;

#[cfg(feature = "aot")]
use anyhow::Context;
use anyhow::Result;

use crate::loss::logistic::{self, GradHess};
use crate::loss::ScalarLoss;

use super::artifacts::Manifest;

/// Which backend a [`GradientEngine`] is using.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EngineKind {
    /// HLO artifacts executed via the PJRT CPU client (the paper stack).
    Aot,
    /// Pure-Rust fallback ([`crate::loss::logistic`]).
    Native,
}

impl std::fmt::Display for EngineKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            EngineKind::Aot => write!(f, "aot-pjrt"),
            EngineKind::Native => write!(f, "native-rust"),
        }
    }
}

/// Compiled-executable cache keyed by (model fn, bucket).
#[cfg(feature = "aot")]
struct AotState {
    client: xla::PjRtClient,
    manifest: Manifest,
    exes: HashMap<(String, usize), xla::PjRtLoadedExecutable>,
    /// Scratch padding buffers reused across calls (hot-path alloc control).
    pad_f: Vec<f32>,
    pad_y: Vec<f32>,
    pad_w: Vec<f32>,
}

/// Uninhabited stand-in for [`GradientEngine`]'s AOT state when the crate
/// is built without the `aot` feature: the `Some` arm of every dispatch is
/// statically unreachable and the native path is the only one.
#[cfg(not(feature = "aot"))]
enum NoAot {}

/// The produce-target engine. Not `Send` in Aot mode (PJRT handles);
/// constructed on and owned by the thread that runs the server loop.
pub struct GradientEngine {
    #[cfg(feature = "aot")]
    aot: Option<AotState>,
    #[cfg(not(feature = "aot"))]
    aot: Option<NoAot>,
    /// The scalar loss the native path dispatches on. Always `Logistic`
    /// in Aot mode — the HLO artifacts are compiled logistic kernels, so
    /// [`GradientEngine::auto_for`] only attempts the AOT upgrade for
    /// the logistic objective.
    loss: ScalarLoss,
}

impl GradientEngine {
    /// AOT engine from an artifact directory (must contain manifest.json).
    #[cfg(feature = "aot")]
    pub fn aot(artifact_dir: &Path) -> Result<GradientEngine> {
        let manifest = Manifest::load(artifact_dir)
            .with_context(|| format!("loading manifest from {}", artifact_dir.display()))?;
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(GradientEngine {
            aot: Some(AotState {
                client,
                manifest,
                exes: HashMap::new(),
                pad_f: Vec::new(),
                pad_y: Vec::new(),
                pad_w: Vec::new(),
            }),
            loss: ScalarLoss::Logistic,
        })
    }

    /// AOT engine stub for builds without the `aot` feature: always an
    /// error, so [`GradientEngine::auto`] degrades to the native path.
    #[cfg(not(feature = "aot"))]
    pub fn aot(_artifact_dir: &Path) -> Result<GradientEngine> {
        anyhow::bail!("this binary was built without the `aot` feature (PJRT/XLA bindings)")
    }

    /// Pure-Rust engine on the logistic loss (the historical default).
    pub fn native() -> GradientEngine {
        GradientEngine::native_for(ScalarLoss::Logistic)
    }

    /// Pure-Rust engine dispatching on `loss`.
    pub fn native_for(loss: ScalarLoss) -> GradientEngine {
        GradientEngine { aot: None, loss }
    }

    /// AOT if artifacts exist under `dir`, else native — logistic loss
    /// (the objective the HLO artifacts are compiled for). `make
    /// artifacts` upgrades the hot path, its absence never breaks the
    /// build.
    pub fn auto(dir: &Path) -> GradientEngine {
        if Manifest::exists(dir) {
            match GradientEngine::aot(dir) {
                Ok(e) => return e,
                Err(err) => {
                    log::warn!("AOT engine unavailable ({err:#}); using native fallback");
                }
            }
        }
        GradientEngine::native()
    }

    /// The engine for a training config's loss — what the trainers call.
    /// Only `Some(Logistic)` may upgrade to AOT (the artifacts are
    /// compiled logistic kernels); any other scalar loss runs native.
    /// `None` is the multiclass objective, whose K-vector targets never
    /// go through the scalar engine at all (`ps/server.rs` calls
    /// `loss::multiclass` directly) — it gets an inert native engine so
    /// [`GradientEngine::kind`] still reports a backend.
    pub fn auto_for(dir: &Path, loss: Option<ScalarLoss>) -> GradientEngine {
        match loss {
            Some(ScalarLoss::Logistic) => GradientEngine::auto(dir),
            Some(other) => GradientEngine::native_for(other),
            None => GradientEngine::native(),
        }
    }

    /// Which backend this engine currently runs on.
    pub fn kind(&self) -> EngineKind {
        if self.aot.is_some() {
            EngineKind::Aot
        } else {
            EngineKind::Native
        }
    }

    /// Produce-target pass (Algorithm 3 server step 4): g, h, Σloss, Σw.
    pub fn grad_hess_loss(&mut self, f: &[f32], y: &[f32], w: &[f32]) -> Result<GradHess> {
        assert_eq!(f.len(), y.len());
        assert_eq!(f.len(), w.len());
        match &mut self.aot {
            None => Ok(self.loss.grad_hess_loss(f, y, w)),
            #[cfg(feature = "aot")]
            Some(state) => state.grad_hess_loss(f, y, w),
            #[cfg(not(feature = "aot"))]
            Some(impossible) => match *impossible {},
        }
    }

    /// Evaluation pass: (Σloss, Σerr, Σw).
    pub fn eval_sums(&mut self, f: &[f32], y: &[f32], w: &[f32]) -> Result<(f64, f64, f64)> {
        assert_eq!(f.len(), y.len());
        assert_eq!(f.len(), w.len());
        match &mut self.aot {
            None => Ok(self.loss.eval_sums(f, y, w)),
            #[cfg(feature = "aot")]
            Some(state) => state.eval_sums(f, y, w),
            #[cfg(not(feature = "aot"))]
            Some(impossible) => match *impossible {},
        }
    }

    /// True when the engine's per-row math is plain thread-safe Rust, so
    /// the fused accept pipeline can run grad/hess/eval *inside* its
    /// row shards (`ps/shard.rs`). False for AOT: PJRT handles are
    /// neither `Send` nor shard-wise, so the fused path falls back to
    /// whole-vector engine calls for the target and eval (sampling and
    /// the F-update stay fused and sharded either way).
    pub fn supports_ranges(&self) -> bool {
        self.aot.is_none()
    }

    /// Range (shard-wise) produce-target: grad/hess/Σ over rows
    /// `[lo, hi)` only, returned in local indexing. Public API for
    /// shard-wise engine consumers; the fused accept pipeline itself
    /// inlines the native per-row kernel (`logistic::grad_hess_at`)
    /// instead of going through the engine, because the AOT variant of
    /// this call executes its bucketed whole-vector artifact on the
    /// padded sub-slice — correct, but paying artifact padding per
    /// call.
    pub fn grad_hess_loss_range(
        &mut self,
        f: &[f32],
        y: &[f32],
        w: &[f32],
        lo: usize,
        hi: usize,
    ) -> Result<GradHess> {
        assert!(lo <= hi && hi <= f.len(), "range [{lo}, {hi}) out of bounds");
        self.grad_hess_loss(&f[lo..hi], &y[lo..hi], &w[lo..hi])
    }

    /// Range (shard-wise) evaluation: (Σloss, Σerr, Σw) over `[lo, hi)`.
    pub fn eval_sums_range(
        &mut self,
        f: &[f32],
        y: &[f32],
        w: &[f32],
        lo: usize,
        hi: usize,
    ) -> Result<(f64, f64, f64)> {
        assert!(lo <= hi && hi <= f.len(), "range [{lo}, {hi}) out of bounds");
        self.eval_sums(&f[lo..hi], &y[lo..hi], &w[lo..hi])
    }

    /// Evaluation with the accept pipeline's deterministic blocked
    /// reduction: native engines fold per-`block` partial sums in block
    /// order — the exact reduction the fused sharded pass performs, so
    /// `target=fused` and `target=serial` report bit-identical loss
    /// curves. The AOT engine keeps its whole-vector (bucketed) artifact
    /// execution: its reduction lives inside the compiled module, and
    /// fused mode falls back to this same call, so the two modes still
    /// agree under AOT.
    pub fn eval_sums_blocked(
        &mut self,
        f: &[f32],
        y: &[f32],
        w: &[f32],
        block: usize,
    ) -> Result<(f64, f64, f64)> {
        if self.supports_ranges() {
            assert_eq!(f.len(), y.len());
            assert_eq!(f.len(), w.len());
            Ok(self.loss.eval_sums_blocked(f, y, w, block))
        } else {
            self.eval_sums(f, y, w)
        }
    }

    /// The scalar loss this engine's native kernels dispatch on.
    pub fn loss(&self) -> ScalarLoss {
        self.loss
    }
}

#[cfg(feature = "aot")]
impl AotState {
    /// Get-or-compile the executable for (name, bucket).
    fn exe(&mut self, name: &str, bucket: usize) -> Result<&xla::PjRtLoadedExecutable> {
        let key = (name.to_string(), bucket);
        if !self.exes.contains_key(&key) {
            let path = self.manifest.path_for(name, bucket)?;
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().context("non-utf8 artifact path")?,
            )
            .with_context(|| format!("parsing HLO text {}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .with_context(|| format!("compiling {name}@{bucket}"))?;
            log::info!("compiled artifact {name}@{bucket}");
            self.exes.insert(key.clone(), exe);
        }
        Ok(self.exes.get(&key).unwrap())
    }

    /// Pad (f, y, w) into the scratch buffers up to `padded` (w zeros).
    fn pad_chunk(&mut self, f: &[f32], y: &[f32], w: &[f32], padded: usize) {
        debug_assert!(f.len() <= padded);
        self.pad_f.clear();
        self.pad_f.extend_from_slice(f);
        self.pad_f.resize(padded, 0.0);
        self.pad_y.clear();
        self.pad_y.extend_from_slice(y);
        self.pad_y.resize(padded, 0.0);
        self.pad_w.clear();
        self.pad_w.extend_from_slice(w);
        self.pad_w.resize(padded, 0.0); // w=0 padding rows are exact no-ops
    }

    fn grad_hess_loss(&mut self, f: &[f32], y: &[f32], w: &[f32]) -> Result<GradHess> {
        let n = f.len();
        let chunk = self.manifest.largest_bucket();
        let mut grad = Vec::with_capacity(n);
        let mut hess = Vec::with_capacity(n);
        let mut loss_sum = 0.0f64;
        let mut weight_sum = 0.0f64;
        let mut start = 0usize;
        while start < n {
            let end = (start + chunk).min(n);
            let len = end - start;
            let bucket = self.manifest.bucket_for(len);
            self.pad_chunk(&f[start..end], &y[start..end], &w[start..end], bucket);
            let lit_f = xla::Literal::vec1(&self.pad_f);
            let lit_y = xla::Literal::vec1(&self.pad_y);
            let lit_w = xla::Literal::vec1(&self.pad_w);
            let exe = self.exe("grad_hess", bucket)?;
            let result = exe.execute::<xla::Literal>(&[lit_f, lit_y, lit_w])?[0][0]
                .to_literal_sync()?;
            let (g_lit, h_lit, l_lit, w_lit) = result.to_tuple4()?;
            let g = g_lit.to_vec::<f32>()?;
            let h = h_lit.to_vec::<f32>()?;
            grad.extend_from_slice(&g[..len]);
            hess.extend_from_slice(&h[..len]);
            loss_sum += l_lit.get_first_element::<f32>()? as f64;
            weight_sum += w_lit.get_first_element::<f32>()? as f64;
            start = end;
        }
        Ok(GradHess {
            grad,
            hess,
            loss_sum,
            weight_sum,
        })
    }

    fn eval_sums(&mut self, f: &[f32], y: &[f32], w: &[f32]) -> Result<(f64, f64, f64)> {
        let n = f.len();
        let chunk = self.manifest.largest_bucket();
        let mut loss_sum = 0.0f64;
        let mut err_sum = 0.0f64;
        let mut weight_sum = 0.0f64;
        let mut start = 0usize;
        while start < n {
            let end = (start + chunk).min(n);
            let len = end - start;
            let bucket = self.manifest.bucket_for(len);
            self.pad_chunk(&f[start..end], &y[start..end], &w[start..end], bucket);
            let lit_f = xla::Literal::vec1(&self.pad_f);
            let lit_y = xla::Literal::vec1(&self.pad_y);
            let lit_w = xla::Literal::vec1(&self.pad_w);
            let exe = self.exe("eval", bucket)?;
            let result = exe.execute::<xla::Literal>(&[lit_f, lit_y, lit_w])?[0][0]
                .to_literal_sync()?;
            let (l_lit, e_lit, w_lit) = result.to_tuple3()?;
            loss_sum += l_lit.get_first_element::<f32>()? as f64;
            err_sum += e_lit.get_first_element::<f32>()? as f64;
            weight_sum += w_lit.get_first_element::<f32>()? as f64;
            start = end;
        }
        Ok((loss_sum, err_sum, weight_sum))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn native_engine_matches_logistic() {
        let mut e = GradientEngine::native();
        assert_eq!(e.kind(), EngineKind::Native);
        let f = [0.5f32, -1.0, 2.0];
        let y = [1.0f32, 0.0, 1.0];
        let w = [1.0f32, 2.0, 0.5];
        let gh = e.grad_hess_loss(&f, &y, &w).unwrap();
        let direct = logistic::grad_hess_loss(&f, &y, &w);
        assert_eq!(gh.grad, direct.grad);
        assert_eq!(gh.hess, direct.hess);
        assert!((gh.loss_sum - direct.loss_sum).abs() < 1e-12);
    }

    #[test]
    fn auto_without_artifacts_is_native() {
        let e = GradientEngine::auto(Path::new("/definitely/not/a/dir"));
        assert_eq!(e.kind(), EngineKind::Native);
        assert!(e.supports_ranges());
    }

    #[test]
    fn range_kernels_match_whole_vector_slices() {
        let mut e = GradientEngine::native();
        let n = 100;
        let f: Vec<f32> = (0..n).map(|i| (i as f32 - 50.0) / 17.0).collect();
        let y: Vec<f32> = (0..n).map(|i| (i % 2) as f32).collect();
        let w: Vec<f32> = (0..n).map(|i| if i % 5 == 0 { 0.0 } else { 1.0 }).collect();
        let (lo, hi) = (13, 77);
        let gh = e.grad_hess_loss_range(&f, &y, &w, lo, hi).unwrap();
        let direct = logistic::grad_hess_loss(&f[lo..hi], &y[lo..hi], &w[lo..hi]);
        assert_eq!(gh.grad, direct.grad);
        assert_eq!(gh.hess, direct.hess);
        let ev = e.eval_sums_range(&f, &y, &w, lo, hi).unwrap();
        assert_eq!(ev, logistic::eval_sums(&f[lo..hi], &y[lo..hi], &w[lo..hi]));
    }

    #[test]
    fn blocked_eval_native_matches_logistic_blocked() {
        let mut e = GradientEngine::native();
        let n = 700;
        let f: Vec<f32> = (0..n).map(|i| ((i * 31 % 97) as f32 - 48.0) / 11.0).collect();
        let y: Vec<f32> = (0..n).map(|i| (i % 2) as f32).collect();
        let w = vec![1.0f32; n];
        assert_eq!(
            e.eval_sums_blocked(&f, &y, &w, 512).unwrap(),
            logistic::eval_sums_blocked(&f, &y, &w, 512)
        );
    }

    #[test]
    fn native_for_dispatches_on_the_requested_loss() {
        let f = [0.5f32, -1.0, 2.0];
        let y = [1.0f32, 0.0, 1.0];
        let w = [1.0f32, 2.0, 0.5];
        let mut e = GradientEngine::native_for(ScalarLoss::Squared);
        assert_eq!(e.loss(), ScalarLoss::Squared);
        let gh = e.grad_hess_loss(&f, &y, &w).unwrap();
        let direct = crate::loss::squared::grad_hess_loss(&f, &y, &w);
        assert_eq!(gh.grad, direct.grad);
        assert_eq!(gh.hess, direct.hess);
        let mut e = GradientEngine::native_for(ScalarLoss::Huber(0.8));
        let gh = e.grad_hess_loss(&f, &y, &w).unwrap();
        let direct = crate::loss::huber::grad_hess_loss(&f, &y, &w, 0.8);
        assert_eq!(gh.grad, direct.grad);
        assert_eq!(
            e.eval_sums_blocked(&f, &y, &w, 2).unwrap(),
            crate::loss::huber::eval_sums_blocked(&f, &y, &w, 0.8, 2)
        );
    }

    #[test]
    fn auto_for_only_upgrades_logistic() {
        let dir = Path::new("/definitely/not/a/dir");
        let e = GradientEngine::auto_for(dir, Some(ScalarLoss::Huber(1.0)));
        assert_eq!(e.kind(), EngineKind::Native);
        assert_eq!(e.loss(), ScalarLoss::Huber(1.0));
        // multiclass (None) gets an inert native engine
        let e = GradientEngine::auto_for(dir, None);
        assert_eq!(e.kind(), EngineKind::Native);
        let e = GradientEngine::auto_for(dir, Some(ScalarLoss::Logistic));
        assert_eq!(e.loss(), ScalarLoss::Logistic);
    }

    // AOT-path numerics are covered by rust/tests/test_runtime.rs, which
    // requires `make artifacts` to have run (the Makefile `test` target
    // guarantees that ordering).
}
