//! # asynch-sgbdt
//!
//! Reproduction of *"Asynch-SGBDT: Train a Stochastic Gradient Boosting
//! Decision Tree in an Asynchronous Parallel Manner"* (Cheng, Xia, Li,
//! Zhang) as a three-layer Rust + JAX + Pallas stack:
//!
//! * **L3 (this crate)** — the paper's system contribution: a parameter
//!   server ([`ps`]) on which workers build trees fully asynchronously
//!   ([`coordinator`]), plus every substrate the paper depends on: the
//!   histogram decision-tree learner ([`tree`]), dataset machinery
//!   ([`data`]), Bernoulli sampling + Q′ diversity statistics
//!   ([`sampling`]), synchronous fork-join / serial baselines, and the
//!   discrete-event cluster simulator ([`simulator`]) behind the paper's
//!   speedup study.
//! * **L2/L1 (build time, `python/`)** — the produce-target sub-step
//!   (fused logistic grad/hess/loss, Eq. 10) as a JAX function wrapping a
//!   Pallas kernel, AOT-lowered to HLO-text artifacts.
//! * **Runtime bridge** ([`runtime`]) — loads those artifacts through the
//!   PJRT CPU client (`xla` crate) and executes them on the server's hot
//!   path. Python never runs at training time.
//!
//! See `DESIGN.md` for the full system inventory and the experiment index
//! mapping every paper figure to a module and bench target — §11 maps
//! the three scoring engines (per-row, blocked SoA, fused sharded) and
//! the persistent scoring pool onto Algorithm 3's server steps, with the
//! decision table for the `scoring`/`target`/`pool` knobs.

// The docs ARE part of the deliverable: every public item carries rustdoc
// and CI builds `cargo doc` with -D warnings, so a missing doc (or a
// broken intra-doc link) fails the build rather than rotting silently.
#![warn(missing_docs)]

pub mod bench_harness;
pub mod cli;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod experiments;
pub mod forest;
pub mod io;
pub mod loss;
pub mod metrics;
pub mod ps;
pub mod runtime;
pub mod sampling;
pub mod serve;
pub mod simulator;
pub mod testkit;
pub mod tree;
pub mod util;

/// Crate-wide result alias.
pub type Result<T> = anyhow::Result<T>;
